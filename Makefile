# Build-time entry points.  The request path is pure Rust; Python only
# runs here, to lower the L2 graphs into artifacts/ (DESIGN.md §1).

ARTIFACTS := artifacts/manifest.json

.PHONY: artifacts test bench fmt

artifacts: $(ARTIFACTS)

$(ARTIFACTS): python/compile/*.py python/compile/kernels/*.py
	cd python && python -m compile.aot --out-dir ../artifacts

test:
	cargo test -q

bench:
	cargo bench

fmt:
	cargo fmt --check
