# Build-time entry points.  The request path is pure Rust; Python only
# runs here, to lower the L2 graphs into artifacts/ (DESIGN.md §1).

ARTIFACTS := artifacts/manifest.json

.PHONY: artifacts test bench bench-store fmt lint doc

artifacts: $(ARTIFACTS)

$(ARTIFACTS): python/compile/*.py python/compile/kernels/*.py
	cd python && python -m compile.aot --out-dir ../artifacts

test:
	cargo test -q

bench:
	cargo bench

# Scheduling-core dispatch throughput: indexed vs naive reference
# (EXPERIMENTS.md §Store).  STORE_BENCH_QUICK=1 for a fast smoke run.
bench-store:
	cargo bench --bench store_throughput

fmt:
	cargo fmt --check

# Repo-specific static pass (DESIGN.md §2.9): lock discipline,
# determinism, SAFETY coverage, WAL replay parity.  The self-test run
# first proves every rule still fires on its fixture.
lint:
	cargo run -p pallas-lint -- --self-test
	cargo run -p pallas-lint

# API docs, warning-free (the advisory CI step runs the same command).
doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
