// pallas-lint-fixture: rust/src/sim/fixture.rs expect=determinism
// Wall-clock time in a determinism-critical path: a soak transcript
// that reads the host clock is no longer a pure function of the seed.

use std::time::Instant;

pub fn stamp() -> Instant {
    Instant::now()
}
