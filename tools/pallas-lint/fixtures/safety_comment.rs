// pallas-lint-fixture: rust/src/transport/fixture.rs expect=safety-comment
// An unsafe block with no `// SAFETY:` justification above it.

pub fn read_first(p: *const u8) -> u8 {
    unsafe { *p }
}
