// pallas-lint-fixture: rust/src/store/fixture_clean.rs expect=none
// Disciplined code: ranked wrapper lock, SAFETY-commented unsafe, and
// test-region residue that the #[cfg(test)] exemption must ignore.

use crate::util::lockcheck::{CheckedMutex, Rank};

pub fn build() -> CheckedMutex<u32> {
    CheckedMutex::new(Rank::test(1, 0), 0)
}

pub fn read_first(p: *const u8) -> u8 {
    // SAFETY: callers pass a pointer to at least one readable byte.
    unsafe { *p }
}

#[cfg(test)]
mod tests {
    use std::sync::Mutex;
    use std::time::Instant;

    #[test]
    fn test_residue_is_exempt() {
        let _ = Mutex::new(Instant::now());
    }
}
