// pallas-lint-fixture: rust/src/store/wal.rs expect=wal-replay
// OP_ORPHAN is emitted by an append site but recover() has no replay
// arm for it — a record type that would be silently lost on restart.

const OP_KEPT: u8 = 1;
const OP_ORPHAN: u8 = 2;

struct Enc(Vec<u8>);
impl Enc {
    fn new(op: u8) -> Enc {
        Enc(vec![op])
    }
}

pub fn append_both() -> (Vec<u8>, Vec<u8>) {
    (Enc::new(OP_KEPT).0, Enc::new(OP_ORPHAN).0)
}

pub fn replay(op: u8) {
    match op {
        OP_KEPT => {}
        _ => {}
    }
}
