// pallas-lint-fixture: rust/src/store/fixture.rs expect=raw-lock
// A raw std::sync lock constructed in lock-disciplined code: the ranked
// witness cannot see it, so the linter must refuse it.

use std::sync::Mutex;

pub fn build() -> Mutex<u32> {
    Mutex::new(0)
}
