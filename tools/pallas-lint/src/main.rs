//! `pallas-lint` — repo-specific static checks the stock toolchain
//! cannot express (DESIGN.md §2.9).  Std-only by design, like the rest
//! of the tree; a hand-rolled line scanner, not a parser, because every
//! rule here is lexical.
//!
//! Rules:
//!
//! * **raw-lock** — no raw `std::sync::Mutex`/`RwLock`/`Condvar`
//!   construction in `store/`, `coordinator/`, `transport/`: every lock
//!   there must be a ranked `util::lockcheck` wrapper so the debug-build
//!   deadlock witness sees it.
//! * **determinism** — no `Instant::now`/`SystemTime::now`/`HashMap` in
//!   the determinism-critical paths (`sim/`, `store/wal.rs`): soak
//!   transcripts and WAL replay must be a pure function of the seed, so
//!   time comes from `util::clock::Clock` and iteration order from
//!   `BTreeMap`.
//! * **safety-comment** — every `unsafe` site carries a `// SAFETY:`
//!   comment in its immediately preceding comment block (or same line).
//! * **wal-replay** — every WAL opcode emitted by an append site in
//!   `store/wal.rs` has a matching replay arm, so a new record type
//!   cannot ship without recovery coverage.
//!
//! Findings in `#[cfg(test)]` regions (tests sit at file bottoms
//! throughout this tree) are exempt.  Residue that is genuinely fine is
//! suppressed via `allowlist.txt` (`rule|path-suffix|pattern|why`), one
//! justified line per entry.
//!
//! `--self-test` runs the rules over `fixtures/` — each fixture's
//! header names the rule it must trip (or `none`), which is the CI
//! proof that every rule actually fires.

use std::env;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

#[derive(Debug)]
struct Finding {
    path: String,
    line: usize, // 1-based
    rule: &'static str,
    msg: String,
}

/// One `rule|path-suffix|pattern|justification` suppression.
struct Allow {
    rule: String,
    path_suffix: String,
    pattern: String,
    #[allow(dead_code)]
    justification: String,
    used: std::cell::Cell<bool>,
}

fn main() -> ExitCode {
    let mut root = PathBuf::from("rust/src");
    let mut allowlist_path: Option<PathBuf> = None;
    let mut self_test = false;
    let mut args = env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => root = PathBuf::from(args.next().expect("--root needs a dir")),
            "--allowlist" => {
                allowlist_path = Some(PathBuf::from(args.next().expect("--allowlist needs a file")))
            }
            "--self-test" => self_test = true,
            other => {
                eprintln!("pallas-lint: unknown argument {other:?}");
                eprintln!("usage: pallas-lint [--root DIR] [--allowlist FILE] [--self-test]");
                return ExitCode::from(2);
            }
        }
    }

    if self_test {
        return run_self_test();
    }

    let allowlist_path = allowlist_path
        .unwrap_or_else(|| Path::new(env!("CARGO_MANIFEST_DIR")).join("allowlist.txt"));
    let allows = match load_allowlist(&allowlist_path) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("pallas-lint: cannot read {}: {e}", allowlist_path.display());
            return ExitCode::from(2);
        }
    };

    let mut files = Vec::new();
    collect_rs(&root, &mut files);
    files.sort();
    if files.is_empty() {
        eprintln!("pallas-lint: no .rs files under {}", root.display());
        return ExitCode::from(2);
    }

    let mut findings = Vec::new();
    let mut suppressed = 0usize;
    for f in &files {
        let src = match fs::read_to_string(f) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("pallas-lint: cannot read {}: {e}", f.display());
                return ExitCode::from(2);
            }
        };
        let path = f.to_string_lossy().replace('\\', "/");
        for finding in lint_file(&path, &src) {
            let raw_line = src.lines().nth(finding.line - 1).unwrap_or("");
            if allows.iter().any(|a| a.matches(&finding, raw_line)) {
                suppressed += 1;
            } else {
                findings.push(finding);
            }
        }
    }

    for a in &allows {
        if !a.used.get() {
            eprintln!(
                "pallas-lint: warning: stale allow-list entry ({}|{}|{})",
                a.rule, a.path_suffix, a.pattern
            );
        }
    }

    if findings.is_empty() {
        println!(
            "pallas-lint: {} file(s) clean ({} finding(s) allow-listed)",
            files.len(),
            suppressed
        );
        ExitCode::SUCCESS
    } else {
        for f in &findings {
            println!("{}:{}: [{}] {}", f.path, f.line, f.rule, f.msg);
        }
        println!("pallas-lint: {} finding(s)", findings.len());
        ExitCode::FAILURE
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

fn load_allowlist(path: &Path) -> std::io::Result<Vec<Allow>> {
    let mut out = Vec::new();
    for (i, line) in fs::read_to_string(path)?.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.splitn(4, '|');
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(rule), Some(suffix), Some(pattern), Some(why)) if !why.trim().is_empty() => {
                out.push(Allow {
                    rule: rule.trim().to_string(),
                    path_suffix: suffix.trim().to_string(),
                    pattern: pattern.trim().to_string(),
                    justification: why.trim().to_string(),
                    used: std::cell::Cell::new(false),
                });
            }
            _ => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!(
                        "{}:{}: expected rule|path-suffix|pattern|justification",
                        path.display(),
                        i + 1
                    ),
                ));
            }
        }
    }
    Ok(out)
}

impl Allow {
    fn matches(&self, f: &Finding, raw_line: &str) -> bool {
        let hit =
            self.rule == f.rule && f.path.ends_with(&self.path_suffix) && raw_line.contains(&self.pattern);
        if hit {
            self.used.set(true);
        }
        hit
    }
}

// ---------------------------------------------------------------------------
// Scanner: per-line code with strings and comments blanked out
// ---------------------------------------------------------------------------

/// Blank every string/char literal and comment to spaces, preserving
/// line structure, so the rules match only real code tokens.  The raw
/// lines stay available for comment-text checks (`// SAFETY:`).
fn blank_noncode(src: &str) -> Vec<String> {
    #[derive(PartialEq)]
    enum St {
        Code,
        LineComment,
        BlockComment(u32),
        Str,
        RawStr(u32),
        Char,
    }
    let b = src.as_bytes();
    let mut out = String::with_capacity(src.len());
    let mut st = St::Code;
    let mut i = 0;
    let mut prev_ident = false; // was the previous code byte an identifier byte?
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            if st == St::LineComment {
                st = St::Code;
            }
            out.push('\n');
            prev_ident = false;
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
                    st = St::LineComment;
                    out.push(' ');
                } else if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    st = St::BlockComment(1);
                    out.push(' ');
                } else if c == b'"' {
                    st = St::Str;
                    out.push(' ');
                } else if (c == b'r' || c == b'R') && !prev_ident && is_raw_string_start(b, i) {
                    let hashes = count_hashes(b, i + 1);
                    st = St::RawStr(hashes);
                    out.push(' ');
                    // Skip the r##…# prefix and opening quote.
                    for _ in 0..(hashes as usize + 1) {
                        i += 1;
                        out.push(' ');
                    }
                } else if c == b'\'' && !prev_ident && is_char_literal(b, i) {
                    st = St::Char;
                    out.push(' ');
                } else {
                    out.push(c as char);
                    prev_ident = c.is_ascii_alphanumeric() || c == b'_';
                    i += 1;
                    continue;
                }
                prev_ident = false;
            }
            St::LineComment => out.push(' '),
            St::BlockComment(depth) => {
                if c == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                    st = if depth == 1 { St::Code } else { St::BlockComment(depth - 1) };
                    continue;
                } else if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                    st = St::BlockComment(depth + 1);
                    continue;
                }
                out.push(' ');
            }
            St::Str => {
                if c == b'\\' && i + 1 < b.len() {
                    out.push(' ');
                    if b[i + 1] != b'\n' {
                        out.push(' ');
                        i += 2;
                        continue;
                    }
                } else {
                    out.push(' ');
                    if c == b'"' {
                        st = St::Code;
                    }
                }
            }
            St::RawStr(hashes) => {
                out.push(' ');
                if c == b'"' && closes_raw_string(b, i, hashes) {
                    for _ in 0..hashes as usize {
                        i += 1;
                        out.push(' ');
                    }
                    st = St::Code;
                }
            }
            St::Char => {
                if c == b'\\' && i + 1 < b.len() && b[i + 1] != b'\n' {
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                    continue;
                }
                out.push(' ');
                if c == b'\'' {
                    st = St::Code;
                }
            }
        }
        i += 1;
    }
    out.lines().map(|l| l.to_string()).collect()
}

fn is_raw_string_start(b: &[u8], i: usize) -> bool {
    let mut j = i + 1;
    while j < b.len() && b[j] == b'#' {
        j += 1;
    }
    j < b.len() && b[j] == b'"'
}

fn count_hashes(b: &[u8], mut i: usize) -> u32 {
    let mut n = 0;
    while i < b.len() && b[i] == b'#' {
        n += 1;
        i += 1;
    }
    n
}

fn closes_raw_string(b: &[u8], i: usize, hashes: u32) -> bool {
    let mut j = i + 1;
    for _ in 0..hashes {
        if j >= b.len() || b[j] != b'#' {
            return false;
        }
        j += 1;
    }
    true
}

/// `'x'` / `'\n'` is a char literal; `'a` in `<'a>` is a lifetime.
fn is_char_literal(b: &[u8], i: usize) -> bool {
    if i + 2 < b.len() && b[i + 1] == b'\\' {
        return true;
    }
    i + 2 < b.len() && b[i + 2] == b'\''
}

/// Byte offset of every `needle` occurrence in `code` not preceded by
/// an identifier byte (so `Mutex::new` does not match `CheckedMutex::new`).
fn token_positions(code: &str, needle: &str) -> Vec<usize> {
    let needs_boundary = needle
        .as_bytes()
        .first()
        .is_some_and(|c| c.is_ascii_alphanumeric() || *c == b'_');
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(rel) = code[from..].find(needle) {
        let at = from + rel;
        let bounded = !needs_boundary || at == 0 || {
            let prev = code.as_bytes()[at - 1];
            !(prev.is_ascii_alphanumeric() || prev == b'_')
        };
        if bounded {
            out.push(at);
        }
        from = at + needle.len().max(1);
    }
    out
}

fn has_token(code: &str, needle: &str) -> bool {
    !token_positions(code, needle).is_empty()
}

/// Index of the first `#[cfg(test)]` line — everything from there to EOF
/// is test code (house style keeps tests at the bottom) and exempt.
fn test_region_start(code_lines: &[String]) -> usize {
    code_lines
        .iter()
        .position(|l| l.trim_start().starts_with("#[cfg(test)]"))
        .unwrap_or(code_lines.len())
}

// ---------------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------------

fn lint_file(path: &str, src: &str) -> Vec<Finding> {
    let raw_lines: Vec<&str> = src.lines().collect();
    let code_lines = blank_noncode(src);
    let limit = test_region_start(&code_lines);
    let mut out = Vec::new();

    let in_lock_scope = ["/store/", "/coordinator/", "/transport/"]
        .iter()
        .any(|d| path.contains(d));
    let in_determinism_scope = path.contains("/sim/") || path.ends_with("store/wal.rs");

    for (i, code) in code_lines.iter().enumerate().take(limit) {
        if in_lock_scope {
            for raw_ctor in ["Mutex::new", "RwLock::new", "Condvar::new"] {
                if has_token(code, raw_ctor) {
                    out.push(Finding {
                        path: path.to_string(),
                        line: i + 1,
                        rule: "raw-lock",
                        msg: format!(
                            "raw std::sync::{raw_ctor} in lock-disciplined code; use the ranked \
                             util::lockcheck wrapper (or allow-list with a justification)"
                        ),
                    });
                }
            }
        }
        if in_determinism_scope {
            for (tok, fix) in [
                ("Instant::now(", "util::clock::Clock"),
                ("SystemTime::now(", "util::clock::Clock"),
                ("HashMap", "BTreeMap"),
            ] {
                if has_token(code, tok) {
                    out.push(Finding {
                        path: path.to_string(),
                        line: i + 1,
                        rule: "determinism",
                        msg: format!(
                            "{} in a determinism-critical path; use {fix} so transcripts stay a \
                             pure function of the seed (or allow-list with a justification)",
                            tok.trim_end_matches('(')
                        ),
                    });
                }
            }
        }
        if has_token(code, "unsafe") && !has_safety_comment(&raw_lines, &code_lines, i) {
            out.push(Finding {
                path: path.to_string(),
                line: i + 1,
                rule: "safety-comment",
                msg: "unsafe without a `// SAFETY:` comment in the preceding comment block"
                    .to_string(),
            });
        }
    }

    if path.ends_with("store/wal.rs") {
        out.extend(check_wal_replay(path, &code_lines, limit));
    }

    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// `// SAFETY:` on the same line, or anywhere in the contiguous block of
/// comments/attributes directly above.  Consecutive one-line
/// `unsafe impl`s may share one block (the runtime Send/Sync pattern).
fn has_safety_comment(raw: &[&str], code: &[String], i: usize) -> bool {
    if raw[i].contains("SAFETY:") {
        return true;
    }
    let mut j = i;
    while j > 0 {
        j -= 1;
        let t = raw[j].trim_start();
        if t.contains("SAFETY:") {
            return true;
        }
        let ct = code[j].trim();
        let skippable = t.is_empty()
            || t.starts_with("//")
            || t.starts_with("#[")
            || ct.starts_with("unsafe impl");
        if !skippable {
            return false;
        }
    }
    false
}

/// Every opcode emitted by an append site (`Enc::new(OP_X)` / `.u8(OP_X`)
/// must have a replay arm (`OP_X =>`) somewhere in the file.
fn check_wal_replay(path: &str, code_lines: &[String], limit: usize) -> Vec<Finding> {
    let mut emitted: Vec<(String, usize)> = Vec::new(); // (opcode, first emit line)
    let mut armed: Vec<String> = Vec::new();
    for (i, code) in code_lines.iter().enumerate() {
        for pat in ["Enc::new(OP_", ".u8(OP_"] {
            for at in token_positions(code, pat) {
                if i >= limit {
                    continue; // test-only emitters don't demand arms
                }
                let name = opcode_at(code, at + pat.len() - "OP_".len());
                if !name.is_empty() && !emitted.iter().any(|(n, _)| *n == name) {
                    emitted.push((name, i + 1));
                }
            }
        }
        for at in token_positions(code, "OP_") {
            let name = opcode_at(code, at);
            if !name.is_empty() && code[at + name.len()..].trim_start().starts_with("=>") {
                armed.push(name);
            }
        }
    }
    emitted
        .into_iter()
        .filter(|(name, _)| !armed.contains(name))
        .map(|(name, line)| Finding {
            path: path.to_string(),
            line,
            rule: "wal-replay",
            msg: format!("opcode {name} is emitted by an append site but has no replay arm"),
        })
        .collect()
}

/// The `OP_…` identifier starting at byte `at`.
fn opcode_at(code: &str, at: usize) -> String {
    code[at..]
        .chars()
        .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
        .collect()
}

// ---------------------------------------------------------------------------
// Self-test over fixtures/
// ---------------------------------------------------------------------------

fn run_self_test() -> ExitCode {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures");
    let mut files = Vec::new();
    collect_rs(&dir, &mut files);
    files.sort();
    if files.is_empty() {
        eprintln!("pallas-lint: no fixtures under {}", dir.display());
        return ExitCode::from(2);
    }
    let mut failed = false;
    for f in &files {
        let src = fs::read_to_string(f).expect("fixture readable");
        let header = src.lines().next().unwrap_or("");
        let Some(rest) = header.strip_prefix("// pallas-lint-fixture: ") else {
            eprintln!("{}: missing `// pallas-lint-fixture: <path> expect=<rule>` header", f.display());
            failed = true;
            continue;
        };
        let mut parts = rest.split_whitespace();
        let (Some(vpath), Some(expect)) = (parts.next(), parts.next().and_then(|e| e.strip_prefix("expect="))) else {
            eprintln!("{}: malformed fixture header", f.display());
            failed = true;
            continue;
        };
        let findings = lint_file(vpath, &src);
        let ok = if expect == "none" {
            findings.is_empty()
        } else {
            findings.len() == 1 && findings[0].rule == expect
        };
        if ok {
            println!("self-test ok: {} trips {expect}", f.file_name().unwrap().to_string_lossy());
        } else {
            eprintln!(
                "self-test FAILED: {} expected exactly one `{expect}` finding, got {:?}",
                f.display(),
                findings
            );
            failed = true;
        }
    }
    if failed {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blanking_strips_strings_and_comments() {
        let src = "let a = \"Mutex::new\"; // Mutex::new in a comment\nlet b = Mutex::new(0);\n";
        let lines = blank_noncode(src);
        assert!(!has_token(&lines[0], "Mutex::new"));
        assert!(has_token(&lines[1], "Mutex::new"));
    }

    #[test]
    fn blanking_handles_raw_strings_and_chars() {
        let src = "let s = r#\"Instant::now()\"#;\nlet c = '\"';\nlet t = Instant::now();\n";
        let lines = blank_noncode(src);
        assert!(!has_token(&lines[0], "Instant::now("));
        assert!(has_token(&lines[2], "Instant::now("));
        // The char literal must not open a string state.
        assert!(has_token(&lines[1], "let"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }\nlet m = Mutex::new(1);\n";
        let lines = blank_noncode(src);
        assert!(has_token(&lines[1], "Mutex::new"));
    }

    #[test]
    fn token_boundary_excludes_wrappers() {
        assert!(!has_token("CheckedMutex::new(rank, v)", "Mutex::new"));
        assert!(has_token("std::sync::Mutex::new(v)", "Mutex::new"));
        assert!(!has_token("let unsafer = 1;", "unsafe"));
    }

    #[test]
    fn safety_walkback_accepts_block_and_rejects_bare() {
        let src = "// SAFETY: fine because reasons.\nlet x = unsafe { f() };\nlet y = unsafe { g() };\n";
        let raw: Vec<&str> = src.lines().collect();
        let code = blank_noncode(src);
        assert!(has_safety_comment(&raw, &code, 1));
        assert!(!has_safety_comment(&raw, &code, 2));
    }

    #[test]
    fn safety_walkback_shares_block_across_unsafe_impls() {
        let src = "// SAFETY: shared justification.\nunsafe impl Send for A {}\nunsafe impl Sync for A {}\n";
        let raw: Vec<&str> = src.lines().collect();
        let code = blank_noncode(src);
        assert!(has_safety_comment(&raw, &code, 1));
        assert!(has_safety_comment(&raw, &code, 2));
    }

    #[test]
    fn test_region_is_exempt() {
        let src = "fn main() {}\n#[cfg(test)]\nmod tests {\n    use std::sync::Mutex;\n    fn f() { let _ = Mutex::new(0); }\n}\n";
        let findings = lint_file("rust/src/store/x.rs", src);
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn wal_replay_flags_armless_opcode() {
        let src = "const OP_A: u8 = 1;\nconst OP_B: u8 = 2;\nfn f() { let e = Enc::new(OP_A); }\nfn g(x: u8) { match x { OP_A => {} _ => {} } }\nfn h() { let e = Enc::new(OP_B); }\n";
        let findings = lint_file("rust/src/store/wal.rs", src);
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert_eq!(findings[0].rule, "wal-replay");
        assert!(findings[0].msg.contains("OP_B"));
    }

    #[test]
    fn determinism_scope_is_path_limited() {
        let src = "fn f() { let t = Instant::now(); }\n";
        assert_eq!(lint_file("rust/src/sim/mod.rs", src).len(), 1);
        assert_eq!(lint_file("rust/src/store/wal.rs", src).len(), 1);
        assert!(lint_file("rust/src/store/sched.rs", src).is_empty());
    }
}
