//! Table 2 — Results of Distributed MNIST Benchmark.
//!
//! The paper classifies 1,000 MNIST test images against 60,000 training
//! images with 1–4 browser clients, on a desktop (OPTIPLEX 8010) and a
//! tablet (Nexus 7), reporting elapsed time and its ratio to 1 client:
//!
//! | env     | clients | paper s | paper ratio |
//! |---------|---------|---------|-------------|
//! | desktop | 1..4    | 107/62/52/46   | 1 / 0.58 / 0.49 / 0.43 |
//! | tablet  | 1..4    | 768/413/293/255| 1 / 0.54 / 0.38 / 0.33 |
//!
//! Here the same ticket grid (query windows × training chunks through
//! the `knn_chunk` Pallas artifact) runs on simulated devices: real
//! numerics + coordination + transport, device speed modelled by
//! padding (DESIGN.md §7).  Default scale is 400×12,000 (24 tickets) so
//! the whole sweep finishes in minutes on one vCPU; set
//! SASHIMI_BENCH_FULL=1 for the paper's 1,000×60,000.  Absolute seconds
//! are not comparable to the paper's hardware — the *ratio columns* are
//! the reproduced quantity.

use sashimi::data;
use sashimi::runtime;
use sashimi::tasks::knn::project::{run, KnnRunConfig};
use sashimi::transport::LinkModel;
use sashimi::util::bench::Table;
use sashimi::worker::DeviceProfile;

fn main() -> anyhow::Result<()> {
    let full = std::env::var("SASHIMI_BENCH_FULL").is_ok();
    // Default scale keeps the compute/download balance in the paper's
    // regime (compute ≈ 2-3x per-client downloads on the desktop) while
    // finishing in ~2 min on one vCPU; FULL is the paper's exact scale.
    let (n_queries, n_train) = if full { (1_000, 60_000) } else { (600, 24_000) };
    let rt = runtime::open_shared()?;
    eprintln!("generating synthetic MNIST ({n_train} train / {n_queries} queries)...");
    let train = data::mnist_train(n_train, 1);
    let queries = data::mnist_test(n_queries, 2);

    let paper: &[(&str, [f64; 4])] =
        &[("desktop", [1.0, 0.58, 0.49, 0.43]), ("tablet", [1.0, 0.54, 0.38, 0.33])];

    let mut table = Table::new(
        "Table 2 — Distributed MNIST kNN (elapsed & ratio vs 1 client)",
        &["env", "clients", "elapsed s", "ratio", "paper ratio", "accuracy"],
    );

    for (env_name, paper_ratios) in paper {
        let profile = match *env_name {
            "desktop" => DeviceProfile::desktop(),
            _ => DeviceProfile::tablet(),
        };
        let mut base = None;
        for clients in 1..=4usize {
            let cfg = KnnRunConfig {
                n_queries,
                n_train,
                clients,
                profile: profile.clone(),
                // The paper's clients sat on a campus LAN; every client
                // downloads the train chunks once (the fixed overhead
                // that makes Table 2's speedup sub-linear).
                link: LinkModel::CAMPUS,
                sleep_on_link: true,
                small: false,
            };
            let r = run(rt.clone(), &queries, &train, &cfg)?;
            let base_s = *base.get_or_insert(r.elapsed_s);
            table.row(&[
                env_name.to_string(),
                clients.to_string(),
                format!("{:.1}", r.elapsed_s),
                format!("{:.2}", r.elapsed_s / base_s),
                format!("{:.2}", paper_ratios[clients - 1]),
                format!("{:.0}%", r.accuracy * 100.0),
            ]);
            eprintln!(
                "{env_name} x{clients}: {:.1}s ({} tickets, {} redistributions)",
                r.elapsed_s, r.tickets, r.redistributions
            );
        }
    }
    table.print();
    println!(
        "note: absolute seconds are device-model-scaled; the reproduced\n\
         quantity is the ratio column (sub-linear speedup, stronger for\n\
         the slower device — the paper's §2.2.2 observation)."
    );
    Ok(())
}
