//! Figure 3 — Error Rate vs wall-clock time for Sukiyaki vs ConvNetJS.
//!
//! The paper plots test error against elapsed time while both libraries
//! train the Fig 2 CNN on CIFAR-10: Sukiyaki's curve falls much faster
//! (more batches per unit time at equal per-batch dynamics).
//!
//! Here both engines start from identical weights and consume identical
//! batch streams; each gets the same wall-clock budget and we sample the
//! held-out error rate on a fixed evaluation batch at equal step
//! intervals.  Reproduced shape: at any fixed wall-clock cut, Sukiyaki's
//! error ≤ ConvNetJS's; per-*step* curves coincide (same algorithm).

use sashimi::data::{self, loader::BatchLoader};
use sashimi::nn::{metrics, NativeEngine, ParamSet, TrainEngine, XlaEngine};
use sashimi::runtime;
use sashimi::util::bench::Series;
use sashimi::util::rng::SplitMix64;

struct CurvePoint {
    wall_ms: f64,
    step: u64,
    err: f64,
}

fn run_engine(
    engine: &mut dyn TrainEngine,
    dataset: &sashimi::data::Dataset,
    eval: &(sashimi::runtime::Tensor, Vec<usize>),
    budget_ms: f64,
    eval_every: u64,
) -> anyhow::Result<Vec<CurvePoint>> {
    let spec_batch = eval.1.len();
    let mut loader = BatchLoader::new(dataset, spec_batch, 5);
    let mut points = Vec::new();
    let t0 = std::time::Instant::now();
    let mut step = 0u64;
    while t0.elapsed().as_secs_f64() * 1e3 < budget_ms {
        let (x, y, _) = loader.next_batch();
        engine.train_batch(&x, &y)?;
        step += 1;
        if step % eval_every == 0 {
            // Evaluation cost is excluded from neither engine — both pay
            // it identically through the same forward interface.
            let err = metrics::error_rate(&engine.forward(&eval.0)?, &eval.1) as f64;
            points.push(CurvePoint { wall_ms: t0.elapsed().as_secs_f64() * 1e3, step, err });
        }
    }
    Ok(points)
}

fn main() -> anyhow::Result<()> {
    let rt = runtime::open_shared()?;
    let spec = rt.net("cifar")?.clone();
    let dataset = data::cifar_train(2_000, 9);
    let test = data::cifar_test(500, 10);
    let eval_idx: Vec<usize> = (0..spec.batch).collect();
    let eval = (test.batch_images(&eval_idx), eval_idx.iter().map(|&i| test.labels[i]).collect::<Vec<_>>());

    let mut rng = SplitMix64::new(4);
    let init = ParamSet::init(&spec, &mut rng);
    let budget_ms = std::env::var("SASHIMI_FIG3_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(30_000.0);

    eprintln!("running sukiyaki for {budget_ms:.0} ms...");
    let mut xla = XlaEngine::from_params(rt.clone(), "cifar", init.clone())?;
    xla.warm()?;
    let xla_points = run_engine(&mut xla, &dataset, &eval, budget_ms, 10)?;

    eprintln!("running convnetjs baseline for {budget_ms:.0} ms...");
    let mut naive = NativeEngine::from_params(&spec, init);
    let naive_points = run_engine(&mut naive, &dataset, &eval, budget_ms, 10)?;

    let mut series = Series::new(
        "Figure 3 — error rate vs wall-clock (cifar, batch 50)",
        "wall_s",
        &["sukiyaki_err", "sukiyaki_step", "convnetjs_err", "convnetjs_step"],
    );
    let n = xla_points.len().max(naive_points.len());
    for i in 0..n {
        let x = xla_points.get(i.min(xla_points.len().saturating_sub(1)));
        let c = naive_points.get(i.min(naive_points.len().saturating_sub(1)));
        if let (Some(x), Some(c)) = (x, c) {
            series.point(
                x.wall_ms / 1e3,
                &[x.err, x.step as f64, c.err, c.step as f64],
            );
        }
    }
    series.print();

    let (x_steps, c_steps) = (
        xla_points.last().map(|p| p.step).unwrap_or(0),
        naive_points.last().map(|p| p.step).unwrap_or(0),
    );
    let (x_err, c_err) = (
        xla_points.last().map(|p| p.err).unwrap_or(1.0),
        naive_points.last().map(|p| p.err).unwrap_or(1.0),
    );
    println!(
        "in {budget_ms:.0} ms: sukiyaki {x_steps} steps -> {:.1}% err | convnetjs {c_steps} steps -> {:.1}% err",
        x_err * 100.0,
        c_err * 100.0
    );
    anyhow::ensure!(x_steps > c_steps, "sukiyaki must complete more steps per wall-clock");
    anyhow::ensure!(
        x_err <= c_err + 0.05,
        "sukiyaki's error at the budget cut must not be worse"
    );
    Ok(())
}
