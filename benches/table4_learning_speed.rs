//! Table 4 — Numbers of Batches Learned per 1 min (+ Figure 2's model).
//!
//! Paper (MacBook Pro, Fig 2 CIFAR CNN, batch 50):
//!
//! |            | ConvNetJS Node.js | ConvNetJS Firefox | Sukiyaki Node.js | Sukiyaki Firefox |
//! |------------|-------------------|-------------------|------------------|------------------|
//! | batches/min| 17.55             | 2.44              | 545.39           | 31.39            |
//!
//! Here: ConvNetJS → the faithful scalar baseline (`nn::convnetjs`),
//! Sukiyaki → the AOT/XLA engine whose hot path is the Pallas matmul
//! (`cifar_train_step`), both from identical weights on identical batch
//! streams.  Two derived columns:
//!
//! * "browser-throttled" applies the paper's own measured engine ratios
//!   (Firefox/Node: 7.2x for ConvNetJS, 17.4x for Sukiyaki) — we cannot
//!   run a JS engine, so those two constants are taken from Table 4
//!   itself and only redistribute our measured native numbers;
//! * `cifar_train_step_jnp` (pure-jnp lowering, no Pallas) isolates the
//!   interpret-mode kernel overhead for the §Perf log.

use sashimi::data::{self, loader::BatchLoader};
use sashimi::nn::{NativeEngine, ParamSet, TrainEngine, XlaEngine};
use sashimi::runtime;
use sashimi::util::bench::Table;
use sashimi::util::rng::SplitMix64;
use sashimi::worker::DeviceProfile;

fn batches_per_min(engine: &mut dyn TrainEngine, loader: &mut BatchLoader, warmup: usize, steps: usize) -> anyhow::Result<(f64, f64)> {
    for _ in 0..warmup {
        let (x, y, _) = loader.next_batch();
        engine.train_batch(&x, &y)?;
    }
    let t0 = std::time::Instant::now();
    let mut last_loss = 0.0f32;
    for _ in 0..steps {
        let (x, y, _) = loader.next_batch();
        last_loss = engine.train_batch(&x, &y)?;
    }
    let ms = t0.elapsed().as_secs_f64() * 1e3 / steps as f64;
    Ok((60_000.0 / ms, last_loss as f64))
}

fn main() -> anyhow::Result<()> {
    let rt = runtime::open_shared()?;
    let spec = rt.net("cifar")?.clone();
    let dataset = data::cifar_train(1_000, 9);
    let mut rng = SplitMix64::new(4);
    let init = ParamSet::init(&spec, &mut rng);

    let steps = 20;
    let mut results: Vec<(String, f64)> = Vec::new();

    {
        let mut naive = NativeEngine::from_params(&spec, init.clone());
        let mut loader = BatchLoader::new(&dataset, spec.batch, 5);
        let (bpm, _) = batches_per_min(&mut naive, &mut loader, 2, steps)?;
        results.push(("convnetjs (native rust)".into(), bpm));
    }
    {
        let mut xla = XlaEngine::from_params(rt.clone(), "cifar", init.clone())?;
        xla.warm()?;
        let mut loader = BatchLoader::new(&dataset, spec.batch, 5);
        let (bpm, _) = batches_per_min(&mut xla, &mut loader, 2, steps)?;
        results.push(("sukiyaki (xla+pallas)".into(), bpm));
    }
    {
        let mut jnp = XlaEngine::from_params(rt.clone(), "cifar", init.clone())?
            .with_train_artifact("cifar_train_step_jnp");
        let mut loader = BatchLoader::new(&dataset, spec.batch, 5);
        let (bpm, _) = batches_per_min(&mut jnp, &mut loader, 2, steps)?;
        results.push(("sukiyaki (pure-jnp ref)".into(), bpm));
    }

    let naive_bpm = results[0].1;
    let pallas_bpm = results[1].1;

    let mut table = Table::new(
        "Table 4 — batches learned per minute (Fig 2 CIFAR CNN, batch 50)",
        &["engine", "measured bpm", "browser-throttled bpm", "paper bpm (Node/Firefox)"],
    );
    table.row(&[
        "ConvNetJS-analog".into(),
        format!("{:.1}", naive_bpm),
        format!("{:.1}", naive_bpm / DeviceProfile::firefox_convnetjs_factor()),
        "17.55 / 2.44".into(),
    ]);
    table.row(&[
        "Sukiyaki (pallas)".into(),
        format!("{:.1}", pallas_bpm),
        format!("{:.1}", pallas_bpm / DeviceProfile::firefox_sukiyaki_factor()),
        "545.39 / 31.39".into(),
    ]);
    table.row(&[
        "Sukiyaki (jnp ref)".into(),
        format!("{:.1}", results[2].1),
        format!("{:.1}", results[2].1 / DeviceProfile::firefox_sukiyaki_factor()),
        "—".into(),
    ]);
    table.print();

    println!(
        "shape check: Sukiyaki/ConvNetJS speedup = {:.1}x (paper: 31.1x on Node).\n\
         The gap narrows here because (a) the ConvNetJS stand-in runs as\n\
         native Rust rather than a JS engine, and (b) 'GPGPU' is a single\n\
         CPU core — see EXPERIMENTS.md §Table4 for the full analysis.",
        pallas_bpm / naive_bpm
    );
    anyhow::ensure!(pallas_bpm > naive_bpm, "Sukiyaki must beat the ConvNetJS baseline");
    Ok(())
}
