//! Figure 5 — Learning Speed by Distributed Deep Learning.
//!
//! The paper varies 1–4 browser clients training the conv layers while
//! the server trains the FC layers, and plots training speed relative to
//! stand-alone:
//!
//! * FC line: ≈1.5× stand-alone, flat in the number of clients (the
//!   server is devoted to FC work);
//! * conv line: grows ∝ clients;
//! * total at 4 clients ≈ 2× stand-alone.
//!
//! Here every device (server and clients) is modelled at the same speed
//! factor (DESIGN.md §7), so the ratios are internally consistent:
//! stand-alone = the padded server running the fused full train-step;
//! distributed = the hybrid algorithm on a live cluster.  The paper's
//! Fig 4 net has no published layer table, so the Fig 2 CIFAR topology
//! is reused (DESIGN.md §5); because its FC block is far cheaper
//! relative to conv than the paper's (unknown) Fig 4 net, the *FC ratio
//! level* differs while the *shape* (flat FC, ∝N conv) is reproduced —
//! see EXPERIMENTS.md §Fig5.

use sashimi::data;
use sashimi::dist::{self, Cluster, ClusterConfig};
use sashimi::nn::{ParamSet, TrainEngine, XlaEngine};
use sashimi::runtime;
use sashimi::util::bench::{Series, Table};
use sashimi::util::clock::PaddedTimer;
use sashimi::util::rng::SplitMix64;
use sashimi::worker::DeviceProfile;

// Every modelled device (server + up to 4 clients) runs at 0.15x host
// speed: 5 x 0.15 = 0.75 <= 1, so the single host core can sustain the
// modelled fleet without queueing artifacts (DESIGN.md §7).
const DEVICE_SPEED: f64 = 0.15;

fn main() -> anyhow::Result<()> {
    let rt = runtime::open_shared()?;
    let net = std::env::var("SASHIMI_FIG5_NET").unwrap_or_else(|_| "cifar".into());
    let spec = rt.net(&net)?.clone();
    let dataset =
        if net == "cifar" { data::cifar_train(1_000, 9) } else { data::mnist_train(1_000, 9) };
    let rounds: u64 = std::env::var("SASHIMI_FIG5_ROUNDS").ok().and_then(|v| v.parse().ok()).unwrap_or(4);

    // --- stand-alone baseline: padded server runs the fused step -------
    let mut rng = SplitMix64::new(4);
    let init = ParamSet::init(&spec, &mut rng);
    let mut engine = XlaEngine::from_params(rt.clone(), &net, init)?;
    engine.warm()?;
    let mut loader = data::loader::BatchLoader::new(&dataset, spec.batch, 5);
    let steps = 10;
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        let (x, y, _) = loader.next_batch();
        let timer = PaddedTimer::start();
        engine.train_batch(&x, &y)?;
        timer.pad_to(timer.elapsed_ms(), DEVICE_SPEED);
    }
    let standalone_rate = steps as f64 / t0.elapsed().as_secs_f64();
    eprintln!("stand-alone (padded server): {standalone_rate:.3} batches/s");

    // --- hybrid with 1..4 clients ---------------------------------------
    let mut table = Table::new(
        "Figure 5 — training speed relative to stand-alone",
        &["clients", "conv rate", "conv ratio", "fc rate", "fc ratio", "paper conv", "paper fc"],
    );
    let mut series = Series::new("Fig 5 series", "clients", &["conv_ratio", "fc_ratio"]);
    // Paper's reading from the bars: conv ∝ N (≈0.5, 1.0, 1.5, 2.0 of
    // stand-alone for their setup), FC flat at ≈1.5.
    let paper_conv = [0.5, 1.0, 1.5, 2.0];
    for clients in 1..=4usize {
        let mut cfg = ClusterConfig::quick_test(&net, clients);
        cfg.profile = DeviceProfile::with_speed("fleet", DEVICE_SPEED);
        cfg.n_shards = clients * 2;
        let cluster = Cluster::start(cfg, rt.clone(), &dataset)?;
        let hycfg = dist::hybrid::HybridConfig {
            rounds,
            seed: 42,
            max_replay_per_round: 400,
            poll_ms: 2,
            server_speed: DEVICE_SPEED,
        };
        let r = dist::hybrid::train(&cluster, &hycfg)?;
        cluster.shutdown();
        let conv_ratio = r.stats.conv_batches_per_s / standalone_rate;
        let fc_ratio = r.stats.fc_steps_per_s / standalone_rate;
        table.row(&[
            clients.to_string(),
            format!("{:.3}", r.stats.conv_batches_per_s),
            format!("{:.2}", conv_ratio),
            format!("{:.3}", r.stats.fc_steps_per_s),
            format!("{:.2}", fc_ratio),
            format!("{:.2}", paper_conv[clients - 1]),
            "1.50".into(),
        ]);
        series.point(clients as f64, &[conv_ratio, fc_ratio]);
        eprintln!(
            "clients={clients}: conv {:.2}x, fc {:.2}x ({} replay fc steps), loss {:.3}",
            conv_ratio, fc_ratio, r.replay_steps, r.stats.mean_loss_last_round
        );
    }
    table.print();
    series.print();
    println!(
        "shape checks: conv ratio grows ≈linearly with clients; fc ratio\n\
         is flat in clients and >1 (server devoted to FC).  The fc *level*\n\
         exceeds the paper's 1.5 because Fig 2's FC block is far cheaper\n\
         than conv — the paper's Fig 4 net is unpublished (DESIGN.md §5)."
    );
    Ok(())
}
