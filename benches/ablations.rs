//! Ablations of the design choices DESIGN.md §6 calls out.
//!
//! 1. scheduler: virtual-created-time redistribution vs no-redistribution
//!    FIFO under a flaky client (completion time of a fixed workload);
//! 2. requeue-timeout sweep: how the 5-minute rule (scaled) trades
//!    duplicate work against stall time;
//! 3. recompute-vs-ship: bytes a hybrid client would upload per shard if
//!    it shipped conv activations instead of recomputing the forward;
//! 4. gradient aggregation: weighted vs unweighted mean with unequal
//!    shard sizes (numeric effect on the update);
//! 5. communication model: hybrid vs MLitB floats/round across model
//!    scales (where the paper's byte advantage kicks in);
//! 6. AdaGrad-β: the paper's stabilised update vs vanilla AdaGrad (β=0)
//!    early-training loss trajectories on the naive engine.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use sashimi::coordinator::{Distributor, Framework};
use sashimi::data;
use sashimi::dist::CommModel;
use sashimi::nn::convnetjs::NaiveNet;
use sashimi::nn::params::ParamSet;
use sashimi::runtime::NetSpec;
use sashimi::store::{Scheduler as _, StoreConfig};
use sashimi::tasks::{TaskContext, TaskDef, TaskOutput};
use sashimi::transport::local::{self, FaultPlan};
use sashimi::transport::{Conn, LinkModel};
use sashimi::util::bench::Table;
use sashimi::util::json::Value;
use sashimi::util::rng::SplitMix64;
use sashimi::worker::{DeviceProfile, Worker};

/// Fixed-cost work unit so device/scheduling effects dominate.
struct FixedCostTask(f64);
impl TaskDef for FixedCostTask {
    fn name(&self) -> &str {
        "fixed_cost"
    }
    fn execute(&self, _i: &Value, _c: &mut dyn TaskContext) -> anyhow::Result<TaskOutput> {
        Ok(TaskOutput { value: Value::Bool(true), modelled_ms: Some(self.0) })
    }
}

/// Run `n_tickets` fixed-cost tickets with one healthy and one flaky
/// worker under the given store config; return completion seconds.
fn run_flaky_workload(cfg: StoreConfig, n_tickets: usize, cost_ms: f64) -> anyhow::Result<(f64, u64, u64)> {
    let fw = Framework::builder().store_config(cfg).build();
    let task = fw.create_task(Arc::new(FixedCostTask(cost_ms)));
    task.calculate((0..n_tickets).map(|i| Value::num(i as f64)).collect());
    let task_id = task.id;
    let dist = Distributor::new(&fw);
    let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
    dist.serve(Box::new(listener));
    let stop = Arc::new(AtomicBool::new(false));
    let flaky = {
        let connector = connector.clone();
        let registry = fw.registry_snapshot();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut w = Worker::new("flaky", DeviceProfile::native(), registry);
            w.run(
                || Ok(Box::new(connector.connect_with_fault(FaultPlan { die_after_sends: Some(4) })?)
                    as Box<dyn Conn>),
                &stop,
            )
        })
    };
    let healthy = {
        let connector = connector.clone();
        let registry = fw.registry_snapshot();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut w = Worker::new("healthy", DeviceProfile::native(), registry);
            w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop)
        })
    };
    let t0 = std::time::Instant::now();
    let done = fw.store().wait_results_timeout(task_id, 120_000).is_some();
    let elapsed = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::SeqCst);
    let _ = flaky.join();
    let _ = healthy.join();
    anyhow::ensure!(done, "workload did not finish");
    let p = fw.store().progress(None);
    Ok((elapsed, p.redistributions, p.duplicate_results))
}

fn ablation_scheduler() -> anyhow::Result<()> {
    let mut table = Table::new(
        "Ablation 1+2 — redistribution policy under a flaky client (20 x 30 ms tickets)",
        &["policy", "requeue ms", "completion s", "redistributions", "dup results"],
    );
    for (name, requeue, min_redist) in [
        ("vct (paper, fast)", 200u64, 50u64),
        ("vct (paper, medium)", 800, 200),
        ("vct (paper, slow)", 3_000, 800),
        ("fifo, no redistribution", 20_000, 20_000),
    ] {
        let cfg = StoreConfig {
            requeue_after_ms: requeue,
            min_redistribute_ms: min_redist,
            requeue_on_error: true,
        };
        let (s, redist, dup) = run_flaky_workload(cfg, 20, 30.0)?;
        table.row(&[
            name.into(),
            requeue.to_string(),
            format!("{s:.2}"),
            redist.to_string(),
            dup.to_string(),
        ]);
    }
    table.print();
    println!("shorter requeue recovers dropped tickets sooner at the cost of duplicates;\nno-redistribution FIFO stalls on every dropped ticket (paper §2.1.2 rationale).\n");
    Ok(())
}

fn activation_floats(net: &NetSpec) -> usize {
    // What shipping all conv activations would cost per sample: every
    // conv output (pre-pool) + pooled maps, vs just the boundary.
    let mut hw = net.input_hw;
    let mut floats = 0usize;
    for c in &net.convs {
        floats += hw * hw * c.cout; // conv output
        hw /= 2;
        floats += hw * hw * c.cout; // pooled
    }
    floats
}

fn ablation_recompute(rt: &sashimi::runtime::SharedRuntime) -> anyhow::Result<()> {
    let mut table = Table::new(
        "Ablation 3 — recompute conv fwd vs ship activations (per 50-sample shard)",
        &["net", "ship activations MB", "ship dfeat MB (paper)", "recompute cost ms"],
    );
    for net in ["mnist", "cifar"] {
        let spec = rt.net(net)?.clone();
        let act_mb = activation_floats(&spec) as f64 * spec.batch as f64 * 4.0 / 1e6;
        let dfeat_mb = (spec.batch * spec.fc_in) as f64 * 4.0 / 1e6;
        // Measure the recompute cost: conv_fwd artifact time.
        let mut rng = SplitMix64::new(1);
        let params = ParamSet::init(&spec, &mut rng);
        let conv = params.conv_subset(&spec);
        let x = sashimi::runtime::Tensor::uniform(&spec.x_shape(), &mut rng, 1.0);
        let mut args = conv.ordered();
        args.push(x);
        rt.exec(&format!("{net}_conv_fwd"), &args)?; // warm
        let t0 = std::time::Instant::now();
        for _ in 0..5 {
            rt.exec(&format!("{net}_conv_fwd"), &args)?;
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / 5.0;
        table.row(&[
            net.into(),
            format!("{act_mb:.2}"),
            format!("{dfeat_mb:.2}"),
            format!("{ms:.1}"),
        ]);
    }
    table.print();
    println!("the paper's recompute choice trades one conv forward per shard for a\n~10x reduction in upload bytes on Internet links (DESIGN.md §6.1).\n");
    Ok(())
}

fn ablation_aggregation() -> anyhow::Result<()> {
    use sashimi::dist::aggregate_gradients;
    use sashimi::nn::params::ParamSet;
    // Two shards: 40 samples with small gradients, 10 samples with large.
    let spec_holder = {
        // Reuse the mnist manifest spec for realistic shapes.
        let rt = sashimi::runtime::open_shared()?;
        rt.net("mnist")?.clone()
    };
    let mut g_small = ParamSet::zeros(&spec_holder);
    let mut g_large = ParamSet::zeros(&spec_holder);
    for v in g_small.get_mut("fc_b")?.data_mut() {
        *v = 0.1;
    }
    for v in g_large.get_mut("fc_b")?.data_mut() {
        *v = 1.0;
    }
    let weighted =
        aggregate_gradients(&[(40.0, g_small.clone()), (10.0, g_large.clone())])?;
    let unweighted = aggregate_gradients(&[(1.0, g_small), (1.0, g_large)])?;
    let w = weighted.get("fc_b")?.data()[0];
    let u = unweighted.get("fc_b")?.data()[0];
    let mut table = Table::new(
        "Ablation 4 — weighted vs unweighted gradient averaging (40 small + 10 large samples)",
        &["scheme", "aggregated fc_b[0]", "bias vs sample mean"],
    );
    let true_mean = (40.0 * 0.1 + 10.0 * 1.0) / 50.0;
    table.row(&["weighted by samples (paper)".into(), format!("{w:.3}"), format!("{:+.1}%", (w - true_mean) / true_mean * 100.0)]);
    table.row(&["plain mean of clients".into(), format!("{u:.3}"), format!("{:+.1}%", (u - true_mean) / true_mean * 100.0)]);
    table.print();
    Ok(())
}

fn ablation_comm_model(rt: &sashimi::runtime::SharedRuntime) -> anyhow::Result<()> {
    let mut table = Table::new(
        "Ablation 5 — communication model: floats/round, hybrid vs MLitB (4 workers, 4 shards)",
        &["model", "conv params", "fc params", "boundary", "hybrid Mfloats", "mlitb Mfloats", "hybrid wins"],
    );
    let mut rows: Vec<(String, CommModel)> = vec![
        ("mnist (ours)".into(), CommModel::of(rt.net("mnist")?)),
        ("cifar (ours, Fig 2)".into(), CommModel::of(rt.net("cifar")?)),
        (
            "AlexNet-scale".into(),
            CommModel { conv_params: 3_700_000, fc_params: 58_600_000, boundary: 50 * 9216 },
        ),
        (
            "VGG-16-scale".into(),
            CommModel { conv_params: 14_700_000, fc_params: 124_000_000, boundary: 50 * 25088 },
        ),
    ];
    for (name, m) in rows.drain(..) {
        table.row(&[
            name,
            m.conv_params.to_string(),
            m.fc_params.to_string(),
            m.boundary.to_string(),
            format!("{:.2}", m.hybrid_floats(4, 4) as f64 / 1e6),
            format!("{:.2}", m.mlitb_floats(4, 4) as f64 / 1e6),
            m.hybrid_wins(4, 4).to_string(),
        ]);
    }
    table.print();
    println!("the paper's byte advantage is a property of FC-dominated nets (its\nmotivating regime); on Fig-2-scale models the boundary dominates.\n");
    Ok(())
}

fn ablation_adagrad_beta(rt: &sashimi::runtime::SharedRuntime) -> anyhow::Result<()> {
    let spec = rt.net("mnist")?.clone();
    let dataset = data::mnist_train(500, 9);
    let mut table = Table::new(
        "Ablation 6 — AdaGrad-β (paper §3.1) vs vanilla AdaGrad (β=0), first 15 steps",
        &["beta", "loss step 1", "loss step 5", "loss step 15", "max |Δθ| step 1"],
    );
    for beta in [1.0f32, 0.0] {
        let mut spec_b = spec.clone();
        spec_b.beta = beta;
        let mut rng = SplitMix64::new(4);
        let mut nn = NaiveNet::new(&spec_b, &mut rng);
        let before = nn.params.clone();
        let mut loader = data::loader::BatchLoader::new(&dataset, spec.batch, 5);
        let mut losses = Vec::new();
        let mut max_step1 = 0.0f32;
        for step in 0..15 {
            let (x, y, _) = loader.next_batch();
            losses.push(nn.train_batch(&x, &y)?);
            if step == 0 {
                for name in before.names() {
                    let a = before.get(name)?;
                    let b = nn.params.get(name)?;
                    for (x0, x1) in a.data().iter().zip(b.data()) {
                        max_step1 = max_step1.max((x0 - x1).abs());
                    }
                }
            }
        }
        table.row(&[
            format!("{beta}"),
            format!("{:.4}", losses[0]),
            format!("{:.4}", losses[4]),
            format!("{:.4}", losses[14]),
            format!("{:.4}", max_step1),
        ]);
    }
    table.print();
    println!("β=0 takes full-lr steps on the first (tiny-gradient) updates — the\ninstability the paper's modification removes.\n");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let rt = sashimi::runtime::open_shared()?;
    ablation_scheduler()?;
    ablation_recompute(&rt)?;
    ablation_aggregation()?;
    ablation_comm_model(&rt)?;
    ablation_adagrad_beta(&rt)?;
    Ok(())
}
