//! Dispatch throughput of the scheduling core: the indexed, sharded
//! [`IndexedStore`] vs the O(n)-scan [`NaiveStore`] reference, at
//! 1k/100k/1M live tickets under 1–16 concurrent clients — plus the
//! durability tax: the same protocol through [`WalStore`] under each
//! fsync policy (WAL-off / OS-cache / group-commit / fsync-per-record),
//! so EXPERIMENTS.md §WAL records what `--state-dir` costs.
//!
//! Protocol: each client thread runs dispatch→error-requeue cycles
//! (`next_ticket` + `report_error`) for a fixed wall-clock window.  The
//! requeue restores the picked ticket to the undistributed pool, so the
//! live-ticket count stays exactly at the configured size for both
//! backends — no done-ticket accumulation skews the naive numbers, and
//! the measured cost is the pure §2.1.2 dispatch decision (`SELECT ...
//! ORDER BY vct LIMIT 1` + state update).  Error buffers are drained
//! periodically through the drain API so they never dominate memory.
//!
//! Acceptance floor (ISSUE 2): ≥10× `next_ticket` throughput vs the
//! naive store at 100k live tickets.  Numbers land in EXPERIMENTS.md.
//!
//! A third table sweeps the batched pipeline (ISSUE 4): dispatch→
//! complete drains at batch size k ∈ {1, 4, 16, 64} through
//! `next_tickets`/`complete_batch`, on the raw indexed store and on the
//! WAL under group commit — where the acknowledgement fix fsyncs every
//! completion, so k divides the fsync count directly.  Acceptance
//! floor: k=16 ≥ 3× the k=1 path on the same backend
//! (EXPERIMENTS.md §Batch).
//!
//! A fourth table measures the active failure path (ISSUE 5):
//! dispatch→release cycles through `next_tickets`/`release_batch` at
//! k ∈ {1, 16} — the cost of handing a disconnecting client's batch
//! back, on the raw indexed store and on the WAL (one `ReleaseBatch`
//! frame per batch; EXPERIMENTS.md §Release).
//!
//! A fifth table is the sharded-dispatch contention sweep (ISSUE 7):
//! clients ∈ {1, 2, 4, 8, 16} × dispatch shards ∈ {1, 4, 16} at 1M live
//! tickets, running `next_tickets(16)`/`release_batch` cycles — the
//! many-frontend pattern the per-shard ready/fallback indexes with
//! work-stealing exist for.  Acceptance floor: ≥ 4× throughput at
//! 16 clients / 16 shards vs the 1-shard single-mutex configuration
//! (EXPERIMENTS.md §Shard).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use sashimi::store::{
    IndexedStore, NaiveStore, Scheduler, StoreConfig, SyncPolicy, TaskId, WalConfig, WalStore,
};
use sashimi::util::bench::Table;
use sashimi::util::clock;
use sashimi::util::json::Value;

/// Timeouts far beyond the bench horizon: only the primary VCT path runs.
fn quiet_cfg() -> StoreConfig {
    StoreConfig {
        requeue_after_ms: 1_000_000_000_000, // ~31 years
        min_redistribute_ms: 1_000_000_000_000,
        requeue_on_error: true,
    }
}

fn fill(store: &dyn Scheduler, n: usize) {
    // Batched creation keeps the peak argument vector bounded.
    let batch = 100_000;
    let mut made = 0usize;
    while made < n {
        let take = batch.min(n - made);
        let args: Vec<Value> = (0..take).map(|i| Value::num((made + i) as f64)).collect();
        store.create_tickets(TaskId(1), "bench", args, clock::now_ms());
        made += take;
    }
}

/// Dispatch→requeue cycles across `clients` threads for `window_ms`;
/// returns tickets dispatched per second.
fn measure(store: Arc<dyn Scheduler>, clients: usize, window_ms: u64) -> f64 {
    // Warm the caches and the allocator off the clock.
    for _ in 0..16 {
        if let Some(t) = store.next_ticket("warmup", clock::now_ms()) {
            let _ = store.report_error(t.id, String::new());
        }
    }
    let _ = store.drain_errors();
    let stop = Arc::new(AtomicBool::new(false));
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|w| {
            let store = Arc::clone(&store);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let client = format!("c{w}");
                let mut ops = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    if let Some(t) = store.next_ticket(&client, clock::now_ms()) {
                        let _ = store.report_error(t.id, String::new());
                        ops += 1;
                        if ops % 4096 == 0 {
                            let _ = store.drain_errors();
                        }
                    }
                }
                ops
            })
        })
        .collect();
    clock::sleep_ms(window_ms);
    stop.store(true, Ordering::SeqCst);
    let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let elapsed = t0.elapsed().as_secs_f64();
    total as f64 / elapsed
}

/// Drain `n` pre-filled tickets through dispatch→complete cycles at
/// batch size `k` across `clients` threads; returns tickets/sec.
/// `k == 1` takes the singular `next_ticket`/`complete` path, so the
/// sweep's baseline is exactly the unbatched protocol.
fn measure_drain(store: Arc<dyn Scheduler>, clients: usize, k: usize) -> f64 {
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|w| {
            let store = Arc::clone(&store);
            std::thread::spawn(move || {
                let client = format!("c{w}");
                let mut done = 0u64;
                loop {
                    if k == 1 {
                        match store.next_ticket(&client, clock::now_ms()) {
                            Some(t) => {
                                if store.complete(t.id, Value::Null).unwrap_or(false) {
                                    done += 1;
                                }
                            }
                            None => break,
                        }
                    } else {
                        let batch = store.next_tickets(&client, clock::now_ms(), k);
                        if batch.is_empty() {
                            break;
                        }
                        let results: Vec<_> =
                            batch.iter().map(|t| (t.id, Value::Null)).collect();
                        done += store.complete_batch(results).unwrap_or(0) as u64;
                    }
                }
                done
            })
        })
        .collect();
    let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    total as f64 / t0.elapsed().as_secs_f64()
}

/// Dispatch→release cycles across `clients` threads for `window_ms` at
/// batch size `k`; returns tickets released per second.  Every released
/// batch returns to the pool immediately, so the live-ticket count is
/// invariant — the measured cost is the pure release transition (plus
/// one WAL frame per batch on the durable backend).
fn measure_release(store: Arc<dyn Scheduler>, clients: usize, k: usize, window_ms: u64) -> f64 {
    let stop = Arc::new(AtomicBool::new(false));
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|w| {
            let store = Arc::clone(&store);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let client = format!("c{w}");
                let mut ops = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let batch = store.next_tickets(&client, clock::now_ms(), k);
                    if batch.is_empty() {
                        continue;
                    }
                    let ids: Vec<_> = batch.iter().map(|t| t.id).collect();
                    ops += store.release_batch(&ids).into_iter().filter(|&f| f).count() as u64;
                }
                ops
            })
        })
        .collect();
    clock::sleep_ms(window_ms);
    stop.store(true, Ordering::SeqCst);
    let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    total as f64 / t0.elapsed().as_secs_f64()
}

/// A WAL store in a throwaway directory under the OS temp dir.
fn wal_store(sync: SyncPolicy, tag: &str) -> (WalStore, std::path::PathBuf) {
    let dir = std::env::temp_dir().join(format!("sashimi-bench-wal-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let wal_cfg = WalConfig {
        sync,
        segment_max_bytes: 64 << 20,
        // No checkpoints inside the measurement window: the table is the
        // pure append/fsync overhead (checkpoint cost amortises over
        // `checkpoint_every`, far beyond a 700 ms window).
        checkpoint_every: 0,
        dispatch_shards: 1,
    };
    (WalStore::open(&dir, quiet_cfg(), wal_cfg).expect("bench WAL store"), dir)
}

fn main() {
    let quick = std::env::var("STORE_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    // Quick mode still covers 100k: that is the acceptance point.
    let sizes: Vec<usize> =
        if quick { vec![1_000, 100_000] } else { vec![1_000, 100_000, 1_000_000] };
    let clients = [1usize, 4, 16];
    let window_ms = 700u64;

    let mut table = Table::new(
        "Store dispatch throughput (tickets/sec dispatched)",
        &["live tickets", "clients", "naive t/s", "indexed t/s", "speedup"],
    );
    for &n in &sizes {
        for &c in &clients {
            let naive: Arc<dyn Scheduler> = Arc::new(NaiveStore::new(quiet_cfg()));
            fill(naive.as_ref(), n);
            let naive_tps = measure(naive, c, window_ms);

            let indexed: Arc<dyn Scheduler> = Arc::new(IndexedStore::new(quiet_cfg()));
            fill(indexed.as_ref(), n);
            let indexed_tps = measure(indexed, c, window_ms);

            table.row(&[
                n.to_string(),
                c.to_string(),
                format!("{naive_tps:.0}"),
                format!("{indexed_tps:.0}"),
                format!("{:.1}x", indexed_tps / naive_tps.max(1e-9)),
            ]);
        }
    }
    table.print();
    println!(
        "Acceptance floor: indexed >= 10x naive at 100k live tickets; record the table in EXPERIMENTS.md.\n"
    );

    // ---- Durability tax: the same dispatch protocol through the WAL ----
    let wal_sizes: Vec<usize> = if quick { vec![1_000] } else { vec![1_000, 100_000] };
    let wal_clients = [1usize, 4];
    let variants: [(&str, Option<SyncPolicy>); 4] = [
        ("wal-off", None),
        ("os-cache", Some(SyncPolicy::OsOnly)),
        ("group-10ms", Some(SyncPolicy::GroupCommitMs(10))),
        ("fsync-each", Some(SyncPolicy::EveryRecord)),
    ];
    let mut wal_table = Table::new(
        "WAL overhead (tickets/sec dispatched, dispatch+requeue cycles)",
        &["live tickets", "clients", "variant", "t/s", "vs wal-off"],
    );
    for &n in &wal_sizes {
        for &c in &wal_clients {
            let mut baseline = 0.0f64;
            for (name, sync) in variants {
                let (tps, cleanup) = match sync {
                    None => {
                        let store: Arc<dyn Scheduler> = Arc::new(IndexedStore::new(quiet_cfg()));
                        fill(store.as_ref(), n);
                        (measure(store, c, window_ms), None)
                    }
                    Some(policy) => {
                        let (store, dir) = wal_store(policy, &format!("{n}-{c}-{name}"));
                        let store: Arc<dyn Scheduler> = Arc::new(store);
                        fill(store.as_ref(), n);
                        (measure(store, c, window_ms), Some(dir))
                    }
                };
                if sync.is_none() {
                    baseline = tps;
                }
                wal_table.row(&[
                    n.to_string(),
                    c.to_string(),
                    name.to_string(),
                    format!("{tps:.0}"),
                    format!("{:.2}x", tps / baseline.max(1e-9)),
                ]);
                if let Some(dir) = cleanup {
                    let _ = std::fs::remove_dir_all(&dir);
                }
            }
        }
    }
    wal_table.print();
    println!(
        "WAL variants: os-cache survives process crashes, group-10ms bounds power-loss \
         data loss to 10 ms, fsync-each survives power loss per record (DESIGN.md §2.2).\n"
    );

    // ---- Batch sweep: dispatch+complete throughput vs batch size k ----
    let batch_n: usize = if quick { 20_000 } else { 100_000 };
    let ks = [1usize, 4, 16, 64];
    let mut batch_table = Table::new(
        "Batched pipeline throughput (tickets/sec, 4 clients, drain protocol)",
        &["backend", "k", "t/s", "vs k=1"],
    );
    for backend in ["indexed", "wal-group50"] {
        let mut baseline = 0.0f64;
        for &k in &ks {
            let mut cleanup: Option<std::path::PathBuf> = None;
            // The WAL backend drains a smaller pool: after the
            // acknowledgement fix, k=1 pays one fsync per ticket, and
            // 100k serialized fsyncs would take minutes (the python
            // model shrinks its fsync-bound pools the same way).
            let n = if backend == "indexed" { batch_n } else { batch_n / 20 };
            let store: Arc<dyn Scheduler> = if backend == "indexed" {
                Arc::new(IndexedStore::new(quiet_cfg()))
            } else {
                let (s, dir) = wal_store(SyncPolicy::GroupCommitMs(50), &format!("batch-{k}"));
                cleanup = Some(dir);
                Arc::new(s)
            };
            fill(store.as_ref(), n);
            let tps = measure_drain(Arc::clone(&store), 4, k);
            if k == 1 {
                baseline = tps;
            }
            batch_table.row(&[
                backend.to_string(),
                k.to_string(),
                format!("{tps:.0}"),
                format!("{:.1}x", tps / baseline.max(1e-9)),
            ]);
            drop(store);
            if let Some(dir) = cleanup {
                let _ = std::fs::remove_dir_all(&dir);
            }
        }
    }
    batch_table.print();
    println!(
        "Acceptance floor (ISSUE 4): k=16 >= 3x the k=1 path on the same backend — \
         on wal-group50 the acknowledgement fix fsyncs per complete call, so k divides \
         the fsync count.  Record the table in EXPERIMENTS.md §Batch.\n"
    );

    // ---- Release path: the cost of handing a batch back ----
    let release_n: usize = if quick { 20_000 } else { 100_000 };
    let mut release_table = Table::new(
        "Release-path throughput (tickets/sec released, 4 clients, dispatch+release cycles)",
        &["backend", "k", "t/s", "vs k=1"],
    );
    for backend in ["indexed", "wal-os-cache"] {
        let mut baseline = 0.0f64;
        for &k in &[1usize, 16] {
            let mut cleanup: Option<std::path::PathBuf> = None;
            let store: Arc<dyn Scheduler> = if backend == "indexed" {
                Arc::new(IndexedStore::new(quiet_cfg()))
            } else {
                let (s, dir) = wal_store(SyncPolicy::OsOnly, &format!("release-{k}"));
                cleanup = Some(dir);
                Arc::new(s)
            };
            fill(store.as_ref(), release_n);
            let tps = measure_release(Arc::clone(&store), 4, k, window_ms);
            if k == 1 {
                baseline = tps;
            }
            release_table.row(&[
                backend.to_string(),
                k.to_string(),
                format!("{tps:.0}"),
                format!("{:.1}x", tps / baseline.max(1e-9)),
            ]);
            drop(store);
            if let Some(dir) = cleanup {
                let _ = std::fs::remove_dir_all(&dir);
            }
        }
    }
    release_table.print();
    println!(
        "Release path (ISSUE 5): what a disconnecting client's batch costs to hand back — \
         one dispatch-mutex pass plus (durable backend) one ReleaseBatch frame per batch. \
         Record the table in EXPERIMENTS.md §Release.\n"
    );

    // ---- Shard sweep: contention scaling of the dispatch core ----
    let shard_n: usize = if quick { 50_000 } else { 1_000_000 };
    let shard_clients: Vec<usize> = if quick { vec![1, 4, 16] } else { vec![1, 2, 4, 8, 16] };
    let shard_counts = [1usize, 4, 16];
    let mut shard_table = Table::new(
        "Sharded dispatch contention sweep (tickets/sec, next_tickets(16)+release_batch cycles)",
        &["live tickets", "clients", "shards", "t/s", "steals", "vs 1 shard"],
    );
    // (1-shard, 16-shard) throughput at the largest client count.
    let mut accept = (0.0f64, 0.0f64);
    for &c in &shard_clients {
        let mut baseline = 0.0f64;
        for &s in &shard_counts {
            let store: Arc<dyn Scheduler> =
                Arc::new(IndexedStore::with_dispatch_shards(quiet_cfg(), s));
            fill(store.as_ref(), shard_n);
            let tps = measure_release(Arc::clone(&store), c, 16, window_ms);
            let stats = store.stats();
            if s == 1 {
                baseline = tps;
            }
            if c == *shard_clients.last().unwrap() {
                if s == 1 {
                    accept.0 = tps;
                }
                if s == 16 {
                    accept.1 = tps;
                }
            }
            shard_table.row(&[
                shard_n.to_string(),
                c.to_string(),
                s.to_string(),
                format!("{tps:.0}"),
                stats.steal_successes.to_string(),
                format!("{:.1}x", tps / baseline.max(1e-9)),
            ]);
            drop(store);
        }
    }
    shard_table.print();
    println!(
        "Acceptance floor (ISSUE 7): {:.1}x at {} clients / 16 shards vs 1 shard (floor 4x) — \
         per-shard VCT indexes with work-stealing keep client threads off a global dispatch \
         mutex.  Record the table in EXPERIMENTS.md §Shard.\n",
        accept.1 / accept.0.max(1e-9),
        shard_clients.last().unwrap()
    );
}
