//! Crash/recovery for the write-ahead-logged ticket store.
//!
//! The acceptance property (ISSUE 3): kill the coordinator mid-dispatch,
//! recover from the WAL directory, and the recovered store must be
//! *differential-test identical* to an uninterrupted run — same dispatch
//! order, progress counters, duplicate/error accounting and collected
//! results.  The 256-case random-op suite below mirrors the
//! `IndexedStore`-vs-`NaiveStore` differential in
//! `rust/tests/properties.rs`, with a crash spliced into the middle.
//!
//! Crashes are simulated with `std::mem::forget`: no flush-on-drop, no
//! final fsync, no checkpoint — only what each append already pushed to
//! the OS survives, exactly the process-kill contract of
//! `SyncPolicy::OsOnly` (the leaked file handle closes at process exit).

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use sashimi::coordinator::{Distributor, Framework};
use sashimi::prop_assert;
use sashimi::store::{
    IndexedStore, Scheduler, StoreConfig, SyncPolicy, TaskId, TicketId, WalConfig, WalStore,
};
use sashimi::tasks::is_prime::IsPrimeTask;
use sashimi::transport::{local, Conn, LinkModel};
use sashimi::util::json::Value;
use sashimi::util::proptest::check;
use sashimi::util::rng::SplitMix64;
use sashimi::worker::{DeviceProfile, Worker};

fn temp_dir(tag: &str) -> PathBuf {
    static N: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "sashimi-walrec-{tag}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Drive one random operation on both stores and assert they agree.
/// Returns an error message on divergence.
fn random_op(
    rng: &mut SplitMix64,
    walled: &dyn Scheduler,
    control: &dyn Scheduler,
    now: &mut u64,
    created: &mut Vec<TicketId>,
    step: u64,
) -> Result<(), String> {
    let tasks = [TaskId(1), TaskId(2), TaskId(3)];
    match rng.gen_range(12) {
        10 => {
            // Singular release: logged as a one-entry ReleaseBatch
            // record; replay must agree on the released flag.
            let id = if !created.is_empty() && rng.gen_range(8) != 0 {
                created[rng.gen_range(created.len() as u64) as usize]
            } else {
                TicketId(created.len() as u64 + 1_000)
            };
            let a = walled.release(id);
            let b = control.release(id);
            prop_assert!(a == b, "release diverges on {id:?}: {a} vs {b}");
        }
        11 => {
            // Batched release (repeats/unknowns included): one framed
            // ReleaseBatch record with per-entry flags.
            let n = 1 + rng.gen_range(4) as usize;
            let ids: Vec<TicketId> = (0..n)
                .map(|_| {
                    if !created.is_empty() && rng.gen_range(8) != 0 {
                        created[rng.gen_range(created.len() as u64) as usize]
                    } else {
                        TicketId(created.len() as u64 + 1_000)
                    }
                })
                .collect();
            let a = walled.release_batch(&ids);
            let b = control.release_batch(&ids);
            prop_assert!(a == b, "release_batch flags diverge on {ids:?}: {a:?} vs {b:?}");
        }
        8 => {
            // Batched dispatch: one DispatchBatch WAL record; replay
            // must re-pick the identical prefix.
            let client = format!("c{}", rng.gen_range(4));
            let k = 1 + rng.gen_range(4) as usize;
            let a = walled.next_tickets(&client, *now, k);
            let b = control.next_tickets(&client, *now, k);
            prop_assert!(a == b, "batch dispatch (k={k}) diverges at t={now}: {a:?} vs {b:?}");
        }
        9 => {
            // Batched completion: one CompleteBatch record carrying
            // per-entry accepted flags (duplicates included).
            let n = 1 + rng.gen_range(3) as usize;
            let entries: Vec<(TicketId, Value)> = (0..n)
                .map(|_| {
                    let id = if !created.is_empty() && rng.gen_range(8) != 0 {
                        created[rng.gen_range(created.len() as u64) as usize]
                    } else {
                        TicketId(created.len() as u64 + 1_000)
                    };
                    (id, Value::num(id.0 as f64))
                })
                .collect();
            let a = walled.complete_batch(entries.clone());
            let b = control.complete_batch(entries);
            prop_assert!(a.is_err() == b.is_err(), "complete_batch error status diverges");
            if let (Ok(x), Ok(y)) = (a, b) {
                prop_assert!(x == y, "complete_batch accepted counts diverge");
            }
        }
        0 | 1 => {
            let task = tasks[rng.gen_range(3) as usize];
            let n = 1 + rng.gen_range(3);
            let args: Vec<Value> = (0..n).map(|i| Value::num((step * 10 + i) as f64)).collect();
            let a = walled.create_tickets(task, "t", args.clone(), *now);
            let b = control.create_tickets(task, "t", args, *now);
            prop_assert!(a == b, "created ids diverge: {a:?} vs {b:?}");
            created.extend(a);
        }
        2 | 3 | 4 => {
            let client = format!("c{}", rng.gen_range(4));
            let a = walled.next_ticket(&client, *now);
            let b = control.next_ticket(&client, *now);
            prop_assert!(a == b, "dispatch diverges at t={now}: {a:?} vs {b:?}");
        }
        5 => {
            let id = if !created.is_empty() && rng.gen_range(8) != 0 {
                created[rng.gen_range(created.len() as u64) as usize]
            } else {
                TicketId(created.len() as u64 + 1_000)
            };
            let v = Value::num(id.0 as f64);
            let a = walled.complete(id, v.clone());
            let b = control.complete(id, v);
            prop_assert!(a.is_err() == b.is_err(), "complete() error status diverges on {id:?}");
            if let (Ok(x), Ok(y)) = (a, b) {
                prop_assert!(x == y, "first-result-wins diverges on {id:?}");
            }
        }
        6 => {
            if !created.is_empty() {
                let id = created[rng.gen_range(created.len() as u64) as usize];
                walled.report_error(id, "e".into()).map_err(|e| e.to_string())?;
                control.report_error(id, "e".into()).map_err(|e| e.to_string())?;
            }
        }
        _ => *now += rng.gen_range(150),
    }
    Ok(())
}

/// Assert the two stores are observably identical right now.
fn assert_same_state(
    walled: &dyn Scheduler,
    control: &dyn Scheduler,
    at: &str,
) -> Result<(), String> {
    let (gp, gq) = (walled.progress(None), control.progress(None));
    prop_assert!(gp == gq, "global progress diverges {at}: {gp:?} vs {gq:?}");
    for task in [TaskId(1), TaskId(2), TaskId(3)] {
        let (tp, tq) = (walled.progress(Some(task)), control.progress(Some(task)));
        prop_assert!(tp == tq, "progress for {task:?} diverges {at}: {tp:?} vs {tq:?}");
        prop_assert!(
            walled.is_task_done(task) == control.is_task_done(task),
            "is_task_done diverges for {task:?} {at}"
        );
    }
    prop_assert!(
        walled.error_count() == control.error_count(),
        "cumulative error counts diverge {at}"
    );
    Ok(())
}

/// The acceptance suite: 256 random-op runs, each killed at a random
/// point (often right after a dispatch), recovered, then driven to
/// completion in lockstep with the uninterrupted control store.  Each
/// case draws a dispatch-shard count from {1, 2, 8}: shards = 1 is the
/// legacy single-stream layout, shards > 1 exercises the per-shard
/// segment streams and the LSN-ordered merge on recovery (the control
/// is an in-memory store with the *same* shard layout, so the lockstep
/// comparison pins the sharded dispatch order too).
#[test]
fn recovered_store_is_differential_identical_to_uninterrupted_run() {
    check("wal-crash-recovery", 256, |rng| {
        let cfg = StoreConfig {
            requeue_after_ms: 20 + rng.gen_range(300),
            min_redistribute_ms: rng.gen_range(80),
            requeue_on_error: rng.gen_range(2) == 0,
            ..StoreConfig::default()
        };
        let shards = [1usize, 2, 8][rng.gen_range(3) as usize];
        // Small segments and short checkpoint cadence so the suite also
        // crashes across rotations and truncations — per-shard-stream
        // rotations included (floors keep the fsync count per case
        // bounded).
        let wal_cfg = WalConfig {
            sync: SyncPolicy::OsOnly,
            segment_max_bytes: 2048 + rng.gen_range(8192),
            checkpoint_every: 16 + rng.gen_range(64),
            dispatch_shards: shards,
        };
        let dir = temp_dir("diff");
        let walled = WalStore::open(&dir, cfg.clone(), wal_cfg).map_err(|e| e.to_string())?;
        let control = IndexedStore::with_dispatch_shards(cfg, shards);
        let mut now = 0u64;
        let mut created: Vec<TicketId> = Vec::new();

        // Phase 1: random ops until the crash point.  Ending on a
        // dispatch (ops 2..=4 dominate) is the "kill mid-dispatch" case:
        // the dispatched ticket is in flight, unacknowledged, mid-window.
        let crash_after = 10 + rng.gen_range(120);
        for step in 0..crash_after {
            random_op(rng, &walled, &control, &mut now, &mut created, step)?;
        }
        // A batch dispatch at the crash point, so a DispatchBatch
        // record can be the last (possibly torn-after) thing in the log.
        let batch = walled.next_tickets("killer", now, 2);
        let cbatch = control.next_tickets("killer", now, 2);
        prop_assert!(batch == cbatch, "crash-point batch diverges");
        // ...and a release right at the crash point, so a ReleaseBatch
        // record can be the torn tail instead (crash mid-release).
        if let Some(t) = batch.first() {
            let a = walled.release_batch(&[t.id]);
            let b = control.release_batch(&[t.id]);
            prop_assert!(a == b, "crash-point release diverges");
        }
        let _ = walled.next_ticket("killer", now); // guarantee an in-flight dispatch
        let _ = control.next_ticket("killer", now);
        assert_same_state(&walled, &control, "pre-crash")?;

        // Crash: no drop glue runs.
        std::mem::forget(walled);
        let recovered = WalStore::recover(&dir).map_err(|e| e.to_string())?;
        assert_same_state(&recovered, &control, "post-recovery")?;

        // Phase 2: keep running random ops on the *recovered* store in
        // lockstep with the never-crashed control.
        for step in crash_after..crash_after + 40 {
            random_op(rng, &recovered, &control, &mut now, &mut created, step)?;
            assert_same_state(&recovered, &control, "post-recovery op")?;
        }

        // Drain both to completion along an identical path.
        for _ in 0..20_000 {
            now += 17;
            let a = recovered.next_ticket("drain", now);
            let b = control.next_ticket("drain", now);
            prop_assert!(a == b, "drain dispatch diverges at t={now}");
            match a {
                Some(t) => {
                    let x = recovered
                        .complete(t.id, Value::num(t.index as f64))
                        .map_err(|e| e.to_string())?;
                    let y = control
                        .complete(t.id, Value::num(t.index as f64))
                        .map_err(|e| e.to_string())?;
                    prop_assert!(x == y, "drain completion accounting diverges on {:?}", t.id);
                }
                None => {
                    if [TaskId(1), TaskId(2), TaskId(3)]
                        .iter()
                        .all(|&t| recovered.is_task_done(t))
                    {
                        break;
                    }
                }
            }
        }
        for task in [TaskId(1), TaskId(2), TaskId(3)] {
            prop_assert!(recovered.is_task_done(task), "drain left {task:?} unfinished");
            let a = recovered.wait_results_timeout(task, 0);
            let b = control.wait_results_timeout(task, 0);
            prop_assert!(a == b, "collected results diverge for {task:?}");
        }
        let (ea, eb) = (recovered.drain_errors(), control.drain_errors());
        prop_assert!(ea == eb, "buffered error reports diverge");
        drop(recovered);
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
}

/// A second crash *after* recovery must recover again (log-on-log) —
/// at every shard layout, so sharded recovery's LSN counter and
/// per-stream segment seqs survive being re-crashed mid-generation.
#[test]
fn recovery_survives_repeated_crashes() {
    check("wal-double-crash", 32, |rng| {
        let cfg = StoreConfig {
            requeue_after_ms: 50 + rng.gen_range(200),
            min_redistribute_ms: 1 + rng.gen_range(50),
            requeue_on_error: true,
            ..StoreConfig::default()
        };
        let shards = [1usize, 2, 8][rng.gen_range(3) as usize];
        let wal_cfg = WalConfig {
            sync: SyncPolicy::OsOnly,
            segment_max_bytes: 2048,
            checkpoint_every: 8 + rng.gen_range(16),
            dispatch_shards: shards,
        };
        let dir = temp_dir("double");
        let control = IndexedStore::with_dispatch_shards(cfg.clone(), shards);
        let mut now = 0u64;
        let mut created: Vec<TicketId> = Vec::new();
        let mut step = 0u64;
        let mut walled = WalStore::open(&dir, cfg, wal_cfg).map_err(|e| e.to_string())?;
        for _crash in 0..3 {
            for _ in 0..15 {
                random_op(rng, &walled, &control, &mut now, &mut created, step)?;
                step += 1;
            }
            std::mem::forget(walled);
            walled = WalStore::recover_with(&dir, wal_cfg).map_err(|e| e.to_string())?;
            assert_same_state(&walled, &control, "after re-crash")?;
        }
        drop(walled);
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
}

/// Crash with a torn frame at the tail of one shard stream's newest
/// segment, after forcing every stream through size rotations: the
/// torn tail must be dropped, every intact record across all segment
/// generations replayed in LSN order, and the recovered store must
/// stay in lockstep with the uninterrupted control.
#[test]
fn sharded_crash_mid_stream_rotation_recovers() {
    check("wal-shard-rotation-crash", 16, |rng| {
        let cfg = StoreConfig {
            requeue_after_ms: 50 + rng.gen_range(200),
            min_redistribute_ms: 1 + rng.gen_range(50),
            requeue_on_error: true,
            ..StoreConfig::default()
        };
        let wal_cfg = WalConfig {
            sync: SyncPolicy::OsOnly,
            segment_max_bytes: 200, // every burst record forces a rotation
            checkpoint_every: 0,    // keep every segment generation live
            dispatch_shards: 4,
        };
        let dir = temp_dir("rotate");
        let walled = WalStore::open(&dir, cfg.clone(), wal_cfg).map_err(|e| e.to_string())?;
        let control = IndexedStore::with_dispatch_shards(cfg, 4);
        let mut now = 0u64;
        let mut created: Vec<TicketId> = Vec::new();
        for step in 0..60 {
            random_op(rng, &walled, &control, &mut now, &mut created, step)?;
        }
        // A deterministic dispatch+complete burst.  Dispatch records are
        // the per-stream traffic (each visited shard logs its own
        // DispatchShard record on its own stream), and 200 consecutive
        // ids put ≥50 tickets on each of the 4 shards — at least two
        // ~200-byte 20-id dispatch records per stream, each alone past
        // the rotation threshold, so every stream must have rotated.
        let drive = |s: &dyn Scheduler, now: &mut u64| -> Result<(), String> {
            let ids = s.create_tickets(
                TaskId(1),
                "t",
                (0..200).map(|i| Value::num(i as f64)).collect(),
                *now,
            );
            let mut burst_done = 0usize;
            for _ in 0..ids.len() / 20 {
                *now += 30;
                let got = s.next_tickets("burst", *now, 20);
                prop_assert!(got.len() == 20, "burst dispatch came up short: {}", got.len());
                burst_done += s
                    .complete_batch(got.iter().map(|t| (t.id, Value::Null)).collect())
                    .map_err(|e| e.to_string())?;
            }
            prop_assert!(burst_done == 200, "burst completion came up short: {burst_done}");
            Ok(())
        };
        let mut now_w = now;
        drive(&walled, &mut now_w)?;
        drive(&control, &mut now)?;
        prop_assert!(now_w == now, "burst clocks diverged");
        assert_same_state(&walled, &control, "pre-crash")?;
        std::mem::forget(walled);
        // Tear the newest segment of stream 1 mid-frame with garbage.
        let mut newest: Option<(u64, PathBuf)> = None;
        let mut stream1_segments = 0usize;
        for entry in std::fs::read_dir(&dir).map_err(|e| e.to_string())? {
            let path = entry.map_err(|e| e.to_string())?.path();
            let name = path.file_name().unwrap().to_string_lossy().into_owned();
            if let Some(rest) = name.strip_prefix("wal-s001-") {
                stream1_segments += 1;
                let seq: u64 =
                    rest.trim_end_matches(".log").parse().map_err(|e| format!("{e}"))?;
                if newest.as_ref().map(|(s, _)| seq > *s).unwrap_or(true) {
                    newest = Some((seq, path));
                }
            }
        }
        prop_assert!(
            stream1_segments >= 2,
            "burst did not rotate stream 1 ({stream1_segments} segments)"
        );
        let (_, tail_path) = newest.unwrap();
        use std::io::Write as _;
        std::fs::OpenOptions::new()
            .append(true)
            .open(&tail_path)
            .and_then(|mut f| f.write_all(&[0xDE, 0xAD, 0xBE, 0xEF, 0x01]))
            .map_err(|e| e.to_string())?;
        let recovered = WalStore::recover_with(&dir, wal_cfg).map_err(|e| e.to_string())?;
        assert_same_state(&recovered, &control, "post-rotation-crash")?;
        // The recovered store keeps working in lockstep.
        for step in 300..320 {
            random_op(rng, &recovered, &control, &mut now, &mut created, step)?;
            assert_same_state(&recovered, &control, "post-recovery op")?;
        }
        drop(recovered);
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
}

/// fsync-per-record path: same recovery contract under the strictest
/// durability policy (kept small — every record pays an fsync).
#[test]
fn every_record_fsync_recovers_exactly() {
    let cfg = StoreConfig { requeue_after_ms: 100, min_redistribute_ms: 10, requeue_on_error: true, ..StoreConfig::default() };
    let wal_cfg = WalConfig {
        sync: SyncPolicy::EveryRecord,
        segment_max_bytes: 1 << 20,
        checkpoint_every: 0,
        dispatch_shards: 1,
    };
    let dir = temp_dir("fsync");
    let s = WalStore::open(&dir, cfg.clone(), wal_cfg).unwrap();
    let control = IndexedStore::new(cfg);
    let drive = |a: &dyn Scheduler| {
        let ids =
            a.create_tickets(TaskId(1), "t", (0..6).map(|i| Value::num(i as f64)).collect(), 0);
        for i in 0..4u64 {
            let t = a.next_ticket("c", i).unwrap();
            a.complete(t.id, Value::num(t.index as f64)).unwrap();
        }
        a.report_error(ids[4], "late".into()).unwrap();
    };
    drive(&s);
    drive(&control);
    std::mem::forget(s);
    let r = WalStore::recover(&dir).unwrap();
    assert_same_state(&r, &control, "fsync-per-record").unwrap();
    drop(r);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The group-commit acknowledgement contract (ROADMAP follow-on):
/// under `GroupCommitMs`, a completion must be fsynced before
/// `complete`/`complete_batch` returns — so the Ack the distributor
/// then sends is never inside the group-commit loss window.  Creates
/// and dispatches may stay dirty until the background flusher fires;
/// acknowledged results may not, and one fsync covers a whole batch.
#[test]
fn group_commit_completions_are_durable_before_ack() {
    let dir = temp_dir("ack");
    let cfg =
        StoreConfig { requeue_after_ms: 1000, min_redistribute_ms: 10, requeue_on_error: true, ..StoreConfig::default() };
    // Flush interval far beyond the test horizon: only the ack path can
    // be fsyncing anything.
    // dispatch_shards stays 1: the ack contract is per *call*, and the
    // sharded layout syncs only the completion's own stream — earlier
    // creates on sibling streams may legitimately stay dirty, which is
    // what `has_unsynced_appends` (any stream) would report.
    let wal_cfg = WalConfig {
        sync: SyncPolicy::GroupCommitMs(600_000),
        segment_max_bytes: 1 << 20,
        checkpoint_every: 0,
        dispatch_shards: 1,
    };
    let s = WalStore::open(&dir, cfg, wal_cfg).unwrap();
    s.create_tickets(TaskId(1), "t", (0..4).map(|i| Value::num(i as f64)).collect(), 0);
    assert!(s.has_unsynced_appends(), "creates may wait for the flusher");
    let t = s.next_ticket("c", 1).unwrap();
    assert!(s.has_unsynced_appends(), "dispatches may wait for the flusher");
    s.complete(t.id, Value::num(0.0)).unwrap();
    assert!(!s.has_unsynced_appends(), "a returned complete() must be fsynced");
    // Batched completion: one fsync covers the whole batch.
    let batch = s.next_tickets("c", 2, 2);
    assert_eq!(batch.len(), 2);
    assert!(s.has_unsynced_appends());
    let accepted = s
        .complete_batch(batch.iter().map(|t| (t.id, Value::num(t.index as f64))).collect())
        .unwrap();
    assert_eq!(accepted, 2);
    assert!(!s.has_unsynced_appends(), "a returned complete_batch() must be fsynced");
    // Crash now: every acknowledged result must survive recovery.
    std::mem::forget(s);
    let r = WalStore::recover(&dir).unwrap();
    assert_eq!(r.progress(None).done, 3);
    drop(r);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The one-liner restart story: a coordinator serving real workers over
/// the browser protocol crashes mid-project; `WalStore::recover` plus the
/// same serve wiring finishes the project without re-executing done
/// tickets.
#[test]
fn coordinator_restart_resumes_project_mid_dispatch() {
    let dir = temp_dir("serve");
    let store_cfg = StoreConfig {
        requeue_after_ms: 50, // orphaned in-flight tickets redistribute fast
        min_redistribute_ms: 5,
        requeue_on_error: true,
        ..StoreConfig::default()
    };
    let wal_cfg = WalConfig {
        sync: SyncPolicy::OsOnly,
        segment_max_bytes: 1 << 20,
        checkpoint_every: 64,
        dispatch_shards: 4,
    };

    // --- first life -------------------------------------------------------
    let wal = Arc::new(WalStore::open(&dir, store_cfg.clone(), wal_cfg).unwrap());
    let fw = Framework::builder().scheduler(Arc::clone(&wal) as Arc<dyn Scheduler>).build();
    let task = fw.create_task(Arc::new(IsPrimeTask));
    task.calculate(
        (1..=200).map(|i| Value::obj(vec![("candidate", Value::num(i as f64))])).collect(),
    );
    let dist = Distributor::new(&fw);
    let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
    let acceptor = dist.serve(Box::new(listener));
    let stop = Arc::new(AtomicBool::new(false));
    let workers: Vec<_> = (0..2)
        .map(|i| {
            let connector = connector.clone();
            let registry = fw.registry_snapshot();
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut w = Worker::new(&format!("w{i}"), DeviceProfile::native(), registry);
                w.max_tickets = Some(40); // finish a bounded slice, then exit
                w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop)
            })
        })
        .collect();
    for w in workers {
        let _ = w.join().unwrap();
    }
    // Kill mid-dispatch: one more ticket goes out and is never answered.
    let orphan = wal.next_ticket("doomed", sashimi::util::clock::now_ms()).unwrap();
    let before = wal.progress(None);
    assert_eq!(before.done, 80, "two workers × 40 tickets");
    assert_eq!(before.in_flight, 1, "the orphaned dispatch");
    dist.stop();
    drop(connector);
    let _ = acceptor.join();
    std::mem::forget(fw);
    std::mem::forget(task);
    match Arc::try_unwrap(wal) {
        Ok(w) => std::mem::forget(w), // crash: skip Drop's flush/checkpoint
        Err(arc) => std::mem::forget(arc),
    }

    // --- second life ------------------------------------------------------
    let recovered = Arc::new(WalStore::recover_with(&dir, wal_cfg).unwrap());
    let after = recovered.progress(None);
    assert_eq!(after, before, "recovery restores the mid-dispatch state exactly");
    let fw2 = Framework::builder().scheduler(Arc::clone(&recovered) as Arc<dyn Scheduler>).build();
    // The recovered project is re-attached by id; fresh tasks allocate
    // above it (the builder seeds the allocator from the store).
    let task2 = fw2.attach_task(TaskId(1), Arc::new(IsPrimeTask));
    assert_eq!(task2.id, TaskId(1));
    assert_eq!(
        fw2.create_task(Arc::new(IsPrimeTask)).id,
        TaskId(2),
        "no collision with the recovered task"
    );
    let dist2 = Distributor::new(&fw2);
    let (listener2, connector2) = local::endpoint(LinkModel::FAST_LAN, false);
    let acceptor2 = dist2.serve(Box::new(listener2));
    let stop2 = Arc::new(AtomicBool::new(false));
    let finishers: Vec<_> = (0..2)
        .map(|i| {
            let connector = connector2.clone();
            let registry = fw2.registry_snapshot();
            let stop = Arc::clone(&stop2);
            std::thread::spawn(move || {
                let mut w = Worker::new(&format!("r{i}"), DeviceProfile::native(), registry);
                w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop)
            })
        })
        .collect();
    let results = task2.block();
    stop2.store(true, Ordering::SeqCst);
    dist2.stop();
    drop(connector2);
    let _ = acceptor2.join();
    for f in finishers {
        let _ = f.join();
    }
    assert_eq!(results.len(), 200);
    let n_primes = results.iter().filter(|r| r.get("is_prime").unwrap().as_bool().unwrap()).count();
    assert_eq!(n_primes, 46); // π(200): done-ticket results survived the crash
    let p = recovered.progress(None);
    assert_eq!(p.done, 200);
    // The orphaned ticket was redistributed, not lost: either its requeue
    // window expired (a redistribution) or the doomed client's answer
    // never came (covered above by done == 200 either way).
    assert!(p.done >= before.done, "no executed work was re-lost");
    let _ = orphan;
    drop(task2);
    drop(fw2);
    match Arc::try_unwrap(recovered) {
        Ok(w) => drop(w),
        Err(_) => {}
    }
    let _ = std::fs::remove_dir_all(&dir);
}
