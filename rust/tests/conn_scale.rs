//! Connection-scale smoke (ISSUE 8): the epoll gateway holds 5 000
//! concurrent idle connections — each a registered session, none a
//! thread — while a handful of real workers still complete tasks
//! through the crowd.
//!
//! The test raises `RLIMIT_NOFILE` itself (both socket ends live in
//! this process, so 5 000 connections cost ~10 000 fds) and skips with
//! a message when the environment cannot grant enough — the repo's
//! artifact-gated-skip idiom, so constrained sandboxes stay green while
//! CI enforces the bound.

use std::io::{BufRead, BufReader, Write as _};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use sashimi::coordinator::gateway::{process_rss_kb, process_thread_count, raise_nofile_limit};
use sashimi::coordinator::{Distributor, Framework, Gateway, GatewayConfig};
use sashimi::store::Scheduler as _;
use sashimi::tasks::is_prime::IsPrimeTask;
use sashimi::transport::tcp::TcpConn;
use sashimi::transport::{Conn, Message};
use sashimi::util::json::Value;
use sashimi::worker::{DeviceProfile, Worker};

const IDLE_CONNS: usize = 5_000;
const ACTIVE_WORKERS: usize = 4;
const TICKETS: usize = 256;

#[test]
fn gateway_holds_5k_idle_connections_while_workers_drain_tasks() {
    // Both ends of every connection are ours: ~2 fds per connection
    // plus slack for the suite's own files.
    let want_fds = (IDLE_CONNS as u64) * 2 + 512;
    match raise_nofile_limit(want_fds) {
        Ok(cur) if cur >= want_fds => {}
        Ok(cur) => {
            eprintln!(
                "skipping conn_scale: RLIMIT_NOFILE caps at {cur}, need {want_fds} \
                 (hard limit too low in this environment)"
            );
            return;
        }
        Err(e) => {
            eprintln!("skipping conn_scale: cannot raise RLIMIT_NOFILE: {e:#}");
            return;
        }
    }

    let threads_before = process_thread_count().unwrap_or(0);

    let fw = Framework::builder().build();
    let task = fw.create_task(Arc::new(IsPrimeTask));
    task.calculate(
        (0..TICKETS)
            .map(|i| Value::obj(vec![("candidate", Value::num(i as f64 + 2.0))]))
            .collect(),
    );
    let task_id = task.id;
    let dist = Distributor::new(&fw);
    // Heartbeats off: the whole point of the crowd is that it stays
    // silent, and idle-but-alive browsers must not be culled.
    let gw = Gateway::bind(&dist, GatewayConfig { heartbeat_ms: 0 }, Some("127.0.0.1:0"), None)
        .unwrap();
    let addr = gw.tcp_addr().unwrap();

    // Phase 1: the idle crowd.  Plain blocking sockets, one Hello each,
    // then silence with the socket held open.
    let mut crowd: Vec<TcpStream> = Vec::with_capacity(IDLE_CONNS);
    for i in 0..IDLE_CONNS {
        // Brief retries ride out accept-backlog pressure when the test
        // thread outruns the reactor's accept loop.
        let mut s = {
            let mut attempt = 0;
            loop {
                match TcpStream::connect(&addr) {
                    Ok(s) => break s,
                    Err(e) if attempt < 50 => {
                        attempt += 1;
                        let _ = e;
                        std::thread::sleep(Duration::from_millis(20));
                    }
                    Err(e) => panic!("connect {i} of {IDLE_CONNS} failed: {e}"),
                }
            }
        };
        let hello = Message::Hello { client: format!("idle-{i}"), profile: "crowd".into() };
        s.write_all(format!("{}\n", hello.encode()).as_bytes()).unwrap();
        crowd.push(s);
    }
    // Every Hello gets its Ack — proof each crowd member has a live
    // session, not just a socket in a backlog.
    for (i, s) in crowd.iter().enumerate() {
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap_or_else(|e| panic!("ack read for idle-{i} failed: {e}"));
        assert!(
            matches!(Message::decode(line.trim_end()).unwrap(), Message::Ack),
            "idle-{i} got {line:?}"
        );
    }
    let deadline = Instant::now() + Duration::from_secs(30);
    while (gw.stats.open.load(Ordering::Relaxed) as usize) < IDLE_CONNS {
        assert!(Instant::now() < deadline, "gateway never registered the full crowd");
        std::thread::sleep(Duration::from_millis(10));
    }

    // Phase 2: active workers push the whole task set through the crowd.
    let stop = Arc::new(AtomicBool::new(false));
    let mut joins = Vec::new();
    for i in 0..ACTIVE_WORKERS {
        let addr = addr.clone();
        let registry = fw.registry_snapshot();
        let stop = Arc::clone(&stop);
        joins.push(std::thread::spawn(move || {
            let mut w = Worker::new(&format!("active-{i}"), DeviceProfile::native(), registry);
            w.run(|| Ok(Box::new(TcpConn::connect(&addr)?) as Box<dyn Conn>), &stop)
        }));
    }
    let results = fw
        .store()
        .wait_results_timeout(task_id, 120_000)
        .expect("workers must finish despite the crowd");
    stop.store(true, Ordering::SeqCst);
    let mut completed = 0u64;
    for j in joins {
        completed += j.join().unwrap().tickets_completed;
    }
    assert_eq!(results.len(), TICKETS);
    assert_eq!(completed, TICKETS as u64);

    // The scale claims.  Threads: the crowd must not have spawned any —
    // only the reactor plus whatever the suite already ran.  Memory: a
    // connection is a session + buffers, so 5k of them fit comfortably
    // under a GiB even with the test harness around them.
    let threads_now = process_thread_count().unwrap_or(0);
    assert!(
        threads_now < threads_before + 100,
        "thread explosion: {threads_before} -> {threads_now} threads for {IDLE_CONNS} conns"
    );
    if let Some(rss) = process_rss_kb() {
        assert!(
            rss < 1_048_576,
            "RSS {rss} KiB for {IDLE_CONNS} idle conns — memory is not bounded"
        );
    }
    assert!(
        gw.stats.open.load(Ordering::Relaxed) as usize >= IDLE_CONNS,
        "idle connections were culled (open={})",
        gw.stats.open.load(Ordering::Relaxed)
    );
    assert!(
        dist.client_count() >= IDLE_CONNS,
        "crowd sessions lost: client_count={}",
        dist.client_count()
    );
    assert_eq!(
        gw.stats.dead_peer_kills.load(Ordering::Relaxed),
        0,
        "heartbeat_ms=0 must never kill an idle peer"
    );

    drop(crowd);
    gw.shutdown();
}
