//! End-to-end Sashimi: projects distributed across real worker loops
//! over both transports, including the XLA-backed kNN workload.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use sashimi::coordinator::{console, Distributor, Framework};
use sashimi::data;
use sashimi::runtime::{self, Tensor};
use sashimi::tasks::is_prime::IsPrimeTask;
use sashimi::tasks::knn::KnnChunkTask;
use sashimi::transport::tcp::{TcpConn, TcpListenerWrap};
use sashimi::transport::{local, Conn, LinkModel, Listener};
use sashimi::util::json::Value;
use sashimi::worker::{DeviceProfile, Worker};

fn spawn_workers(
    fw: &Arc<Framework>,
    connector: &local::LocalConnector,
    n: usize,
    stop: &Arc<AtomicBool>,
    rt: Option<runtime::SharedRuntime>,
) -> Vec<std::thread::JoinHandle<sashimi::worker::WorkerReport>> {
    (0..n)
        .map(|i| {
            let connector = connector.clone();
            let registry = fw.registry_snapshot();
            let stop = Arc::clone(stop);
            let rt = rt.clone();
            std::thread::spawn(move || {
                let mut w = Worker::new(&format!("w{i}"), DeviceProfile::native(), registry);
                if let Some(rt) = rt {
                    w = w.with_runtime(rt);
                }
                w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop)
            })
        })
        .collect()
}

/// The appendix's PrimeListMakerProject, 1..=1000, three browser nodes.
#[test]
fn prime_project_over_local_transport() {
    let fw = Framework::builder().build();
    let task = fw.create_task(Arc::new(IsPrimeTask));
    task.calculate((1..=1000).map(|i| Value::obj(vec![("candidate", Value::num(i as f64))])).collect());

    let dist = Distributor::new(&fw);
    let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
    dist.serve(Box::new(listener));
    let stop = Arc::new(AtomicBool::new(false));
    let workers = spawn_workers(&fw, &connector, 3, &stop, None);

    let results = task.block();
    stop.store(true, Ordering::SeqCst);
    for w in workers {
        let _ = w.join().unwrap();
    }
    assert_eq!(results.len(), 1000);
    let primes: Vec<usize> = results
        .iter()
        .enumerate()
        .filter(|(_, r)| r.get("is_prime").unwrap().as_bool().unwrap())
        .map(|(i, _)| i + 1)
        .collect();
    assert_eq!(primes.len(), 168); // π(1000)
    assert_eq!(primes[0], 2);
    assert_eq!(*primes.last().unwrap(), 997);

    // Console reflects the finished project.  `snap.clients` counts
    // only *connected* workers and the fleet is tearing down here (the
    // shutdown handlers race this snapshot), so the stable assertion
    // is the retained per-client table: no entry is ever lost, even
    // after its connection ends.
    let snap = console::snapshot(&dist);
    assert_eq!(snap.progress.done, 1000);
    assert!(snap.clients <= 3);
    assert_eq!(dist.clients().len(), 3, "every worker appears in the table");
    assert!(console::render(&snap).contains("1000 total"));
    assert!(console::render_clients(&dist).contains("w1"));
}

/// Same project over real TCP sockets (multi-process shape).
#[test]
fn prime_project_over_tcp() {
    let fw = Framework::builder().build();
    let task = fw.create_task(Arc::new(IsPrimeTask));
    task.calculate((1..=200).map(|i| Value::obj(vec![("candidate", Value::num(i as f64))])).collect());
    let dist = Distributor::new(&fw);
    let mut listener = TcpListenerWrap::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr.clone();
    // accept exactly two workers on a plain thread
    let d2 = Arc::clone(&dist);
    let acceptor = std::thread::spawn(move || {
        for _ in 0..2 {
            let conn = listener.accept().unwrap();
            let d = Arc::clone(&d2);
            std::thread::spawn(move || {
                let _ = d.handle_conn(conn);
            });
        }
    });
    let stop = Arc::new(AtomicBool::new(false));
    let mut joins = Vec::new();
    for i in 0..2 {
        let registry = fw.registry_snapshot();
        let stop = Arc::clone(&stop);
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut w = Worker::new(&format!("tcp{i}"), DeviceProfile::native(), registry);
            w.run(|| Ok(Box::new(TcpConn::connect(&addr)?) as Box<dyn Conn>), &stop)
        }));
    }
    let results = task.block();
    stop.store(true, Ordering::SeqCst);
    for j in joins {
        let _ = j.join();
    }
    acceptor.join().unwrap();
    assert_eq!(results.len(), 200);
    let n_primes =
        results.iter().filter(|r| r.get("is_prime").unwrap().as_bool().unwrap()).count();
    assert_eq!(n_primes, 46); // π(200)
}

/// Table 2's workload end to end at small scale: distributed kNN with
/// the XLA artifact, folded across chunks, checked against exact brute
/// force on the server.
#[test]
fn knn_project_with_artifacts() {
    let Some(rt) = runtime::open_shared_or_skip() else { return };
    let n_train = 600;
    let n_query = 20;
    let chunk = 200;
    let train = data::mnist_train(n_train, 1);
    let queries = data::mnist_test(n_query, 2);

    // Paper-default windows on a virtual clock pinned at 0: store time
    // never moves, so no ticket can be redistributed out from under a
    // slow worker mid-test (the old way was oversized frozen windows).
    let fw = Framework::builder()
        .clock(Arc::new(sashimi::util::clock::VirtualClock::new()))
        .build();
    fw.datasets().register("q0", queries.rows_matrix(0, n_query));
    for (c, start) in (0..n_train).step_by(chunk).enumerate() {
        fw.datasets().register(&format!("chunk{c}"), train.rows_matrix(start, chunk));
    }
    let def = KnnChunkTask::small();
    let task = fw.create_task(Arc::new(KnnChunkTask::small()));
    let payloads: Vec<Value> = (0..n_train / chunk)
        .map(|c| def.ticket("q0", &format!("chunk{c}"), c * chunk))
        .collect();
    task.calculate(payloads);

    let dist = Distributor::new(&fw);
    let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
    dist.serve(Box::new(listener));
    let stop = Arc::new(AtomicBool::new(false));
    let workers = spawn_workers(&fw, &connector, 2, &stop, Some(rt));

    let results = task.block();
    stop.store(true, Ordering::SeqCst);
    for w in workers {
        let _ = w.join().unwrap();
    }

    // Fold (min, argmin) across chunk results.
    let mut acc = vec![(f32::INFINITY, 0usize); n_query];
    for r in &results {
        let offset = r.get("chunk_offset").unwrap().as_usize().unwrap();
        let mins = sashimi::tasks::tensor_from_json(r.get("min_dist2").unwrap()).unwrap();
        let argmins = sashimi::tasks::tensor_from_json(r.get("argmin").unwrap()).unwrap();
        sashimi::runtime::tensor::fold_min_argmin(&mut acc, mins.data(), argmins.data(), offset);
    }

    // Exact brute force on the server side.
    let mut correct_pred = 0;
    for qi in 0..n_query {
        let q = queries.row(qi);
        let (mut best, mut best_i) = (f32::INFINITY, 0usize);
        for ti in 0..n_train {
            let d: f32 = q.iter().zip(train.row(ti)).map(|(a, b)| (a - b) * (a - b)).sum();
            if d < best {
                best = d;
                best_i = ti;
            }
        }
        assert_eq!(acc[qi].1, best_i, "query {qi}: argmin mismatch");
        assert!((acc[qi].0 - best).abs() < 1e-2 * best.max(1.0), "query {qi}: distance");
        if train.labels[best_i] == queries.labels[qi] {
            correct_pred += 1;
        }
    }
    // The synthetic data is built to make kNN work: expect >80% accuracy.
    assert!(correct_pred as f64 / n_query as f64 > 0.8, "kNN accuracy {correct_pred}/{n_query}");
}

/// Workers cache datasets: repeated tickets against the same chunks must
/// not refetch them (the paper's browser-side cache + LRU GC).
#[test]
fn dataset_caching_across_tickets() {
    let Some(rt) = runtime::open_shared_or_skip() else { return };
    let train = data::mnist_train(400, 3);
    let queries = data::mnist_test(20, 4);
    let fw = Framework::builder().build();
    fw.datasets().register("q0", queries.rows_matrix(0, 20));
    fw.datasets().register("c0", train.rows_matrix(0, 200));
    fw.datasets().register("c1", train.rows_matrix(200, 200));
    let def = KnnChunkTask::small();
    let task = fw.create_task(Arc::new(KnnChunkTask::small()));
    // 4 tickets over 2 chunks: each chunk used twice.
    task.calculate(vec![
        def.ticket("q0", "c0", 0),
        def.ticket("q0", "c1", 200),
        def.ticket("q0", "c0", 0),
        def.ticket("q0", "c1", 200),
    ]);
    let dist = Distributor::new(&fw);
    let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
    dist.serve(Box::new(listener));
    let stop = Arc::new(AtomicBool::new(false));
    // Single worker so cache effects are deterministic.
    let workers = spawn_workers(&fw, &connector, 1, &stop, Some(rt));
    let _ = task.block();
    stop.store(true, Ordering::SeqCst);
    let report = workers.into_iter().next().unwrap().join().unwrap();
    assert_eq!(report.tickets_completed, 4);
    // 3 distinct datasets fetched once each; q0 cached across all 4.
    assert_eq!(report.data_fetches, 3, "datasets should be cached");
    assert_eq!(report.task_fetches, 1, "task code cached");
    use std::sync::atomic::Ordering as O;
    assert_eq!(dist.stats.data_requests.load(O::Relaxed), 3);
}

/// Tensor helper used by the kNN fold (module path sanity for docs).
#[test]
fn fold_helper_is_public() {
    let mut acc = vec![(f32::INFINITY, 0usize)];
    sashimi::runtime::tensor::fold_min_argmin(&mut acc, &[1.0], &[2.0], 10);
    assert_eq!(acc[0], (1.0, 12));
    let _ = Tensor::zeros(&[1]);
}
