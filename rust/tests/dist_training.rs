//! Distributed training (§4) end to end on real artifacts: the hybrid
//! algorithm and both baselines learn, agree across engines, and the
//! coordination layer holds up.

use sashimi::data;
use sashimi::dist::{self, Cluster, ClusterConfig};
use sashimi::nn::{metrics, NativeEngine, ParamSet, TrainEngine, XlaEngine};
use sashimi::runtime;
use sashimi::util::rng::SplitMix64;

/// Every test early-returns with a skip message when the AOT artifacts /
/// XLA bindings are unavailable; run `make artifacts` to enable them.
fn rt() -> Option<runtime::SharedRuntime> {
    runtime::open_shared_or_skip()
}

/// Both engines from the same init on the same batch: first-step loss
/// and parameter movement must agree (ConvNetJS vs Sukiyaki fidelity).
#[test]
fn engines_agree_on_first_steps() {
    let Some(rt) = rt() else { return };
    let spec = rt.net("mnist").unwrap().clone();
    let mut rng = SplitMix64::new(99);
    let init = ParamSet::init(&spec, &mut rng);
    let mut xla = XlaEngine::from_params(rt.clone(), "mnist", init.clone()).unwrap();
    let mut naive = NativeEngine::from_params(&spec, init);

    let dataset = data::mnist_train(200, 5);
    let mut loader = data::loader::BatchLoader::new(&dataset, spec.batch, 6);
    for step in 0..2 {
        let (x, y, _) = loader.next_batch();
        let lx = xla.train_batch(&x, &y).unwrap();
        let ln = naive.train_batch(&x, &y).unwrap();
        assert!(
            (lx - ln).abs() < 2e-2 * lx.abs().max(1.0),
            "step {step}: loss divergence xla={lx} naive={ln}"
        );
    }
    // Parameters stay close after two steps (f32 vs f64 accumulation).
    for name in ["conv1_w", "fc_w", "fc_b"] {
        let a = xla.params().get(name).unwrap();
        let b = naive.params().get(name).unwrap();
        let mut max_diff = 0.0f32;
        for (x, y) in a.data().iter().zip(b.data()) {
            max_diff = max_diff.max((x - y).abs());
        }
        assert!(max_diff < 5e-3, "{name}: max param diff {max_diff}");
    }
}

/// Both engines' forward probabilities agree on the same params.
#[test]
fn engine_forward_agreement() {
    let Some(rt) = rt() else { return };
    let spec = rt.net("mnist").unwrap().clone();
    let mut rng = SplitMix64::new(3);
    let init = ParamSet::init(&spec, &mut rng);
    let xla = XlaEngine::from_params(rt.clone(), "mnist", init.clone()).unwrap();
    let naive = NativeEngine::from_params(&spec, init);
    let dataset = data::mnist_train(100, 8);
    let x = dataset.batch_images(&(0..spec.batch).collect::<Vec<_>>());
    let pa = xla.forward(&x).unwrap();
    let pb = naive.forward(&x).unwrap();
    for (a, b) in pa.data().iter().zip(pb.data()) {
        assert!((a - b).abs() < 1e-3, "prob divergence {a} vs {b}");
    }
}

/// Hybrid training on a live cluster: loss falls, FC trains more often
/// than conv (the concurrency the paper claims), bytes are accounted.
#[test]
fn hybrid_trains_and_loss_falls() {
    let Some(rt) = rt() else { return };
    let dataset = data::mnist_train(600, 21);
    let cluster = Cluster::start(ClusterConfig::quick_test("mnist", 2), rt, &dataset).unwrap();
    let cfg = dist::hybrid::HybridConfig { rounds: 6, seed: 42, max_replay_per_round: 8, poll_ms: 2, ..Default::default() };
    let result = dist::hybrid::train(&cluster, &cfg).unwrap();
    let reports = cluster.shutdown();

    assert_eq!(result.conv_batches, 6 * 2);
    assert!(result.fc_steps >= result.conv_batches, "fc should train at least per-feature");
    let head = result.loss_curve.head_mean(2);
    let tail = result.loss_curve.tail_mean(2);
    assert!(tail < head, "loss did not fall: {head} -> {tail}");
    assert!(result.stats.bytes.0 > 0 && result.stats.bytes.1 > 0);
    let done: u64 = reports.iter().map(|r| r.tickets_completed).sum();
    assert_eq!(done, 6 * 2 * 2); // conv_fwd + conv_grad per shard per round
}

/// MLitB baseline trains too (correctness of the comparison target).
#[test]
fn mlitb_trains_and_loss_falls() {
    let Some(rt) = rt() else { return };
    let dataset = data::mnist_train(600, 22);
    let cluster = Cluster::start(ClusterConfig::quick_test("mnist", 2), rt, &dataset).unwrap();
    let cfg = dist::mlitb::MlitbConfig { rounds: 8, seed: 42 };
    let result = dist::mlitb::train(&cluster, &cfg).unwrap();
    cluster.shutdown();
    let head = result.loss_curve.head_mean(2);
    let tail = result.loss_curve.tail_mean(2);
    assert!(tail < head, "loss did not fall: {head} -> {tail}");
}

/// He-sync baseline: same work, strict barriers.
#[test]
fn he_sync_trains_and_loss_falls() {
    let Some(rt) = rt() else { return };
    let dataset = data::mnist_train(600, 23);
    let cluster = Cluster::start(ClusterConfig::quick_test("mnist", 2), rt, &dataset).unwrap();
    let cfg = dist::he_sync::HeSyncConfig { rounds: 6, seed: 42 };
    let result = dist::he_sync::train(&cluster, &cfg).unwrap();
    cluster.shutdown();
    let head = result.loss_curve.head_mean(2);
    let tail = result.loss_curve.tail_mean(2);
    assert!(tail < head, "loss did not fall: {head} -> {tail}");
    assert_eq!(result.stats.fc_steps_per_s > 0.0, true);
}

/// Measured wire traffic matches the analytic communication model
/// (dist::CommModel) for both algorithms.  On this MNIST net the
/// boundary (50×1568 floats) dominates, so MLitB actually moves fewer
/// bytes — the paper's byte advantage belongs to the FC-dominated
/// regime, which `CommModel::hybrid_wins` captures and the lib tests pin
/// for an AlexNet-scale model.  What this test verifies: the accounting
/// is real and the model predicts the measured ratio.
#[test]
fn measured_bytes_match_comm_model() {
    let Some(rt) = rt() else { return };
    let dataset = data::mnist_train(600, 24);
    let rounds = 3u64;

    let c1 = Cluster::start(ClusterConfig::quick_test("mnist", 2), rt.clone(), &dataset).unwrap();
    let model = dist::CommModel::of(&c1.spec);
    let h = dist::hybrid::train(
        &c1,
        &dist::hybrid::HybridConfig { rounds, seed: 7, max_replay_per_round: 0, poll_ms: 2, ..Default::default() },
    )
    .unwrap();
    c1.shutdown();

    let c2 = Cluster::start(ClusterConfig::quick_test("mnist", 2), rt, &dataset).unwrap();
    let m = dist::mlitb::train(&c2, &dist::mlitb::MlitbConfig { rounds, seed: 7 }).unwrap();
    c2.shutdown();

    let hybrid_bytes = (h.stats.bytes.0 + h.stats.bytes.1) as f64;
    let mlitb_bytes = (m.stats.bytes.0 + m.stats.bytes.1) as f64;
    // Analytic floats -> wire bytes: ~16/3 chars per f32 after base64.
    let per_float = 16.0 / 3.0;
    // Steady-state model plus the round-1 shard downloads (2 shards of
    // x[50,28,28,1] + y[50,10], fetched once per worker in the worst
    // case) as an upper-bound band.
    let shard_floats = 2.0 * (50.0 * 784.0 + 500.0) * 2.0;
    let h_pred = rounds as f64 * model.hybrid_floats(2, 2) as f64 * per_float;
    let m_pred = rounds as f64 * model.mlitb_floats(2, 2) as f64 * per_float;
    let slack = shard_floats * per_float + 200_000.0; // envelopes + tickets
    assert!(
        hybrid_bytes > h_pred * 0.8 && hybrid_bytes < h_pred + 2.0 * slack,
        "hybrid measured {hybrid_bytes} vs predicted {h_pred} (+{slack})"
    );
    assert!(
        mlitb_bytes > m_pred * 0.8 && mlitb_bytes < m_pred + 2.0 * slack,
        "mlitb measured {mlitb_bytes} vs predicted {m_pred} (+{slack})"
    );
    // Direction on this net: boundary-dominated -> MLitB moves less.
    assert!(!model.hybrid_wins(2, 2));
    assert!(hybrid_bytes > mlitb_bytes);
}

/// Trained hybrid model actually classifies better than chance: close
/// the loop with an error-rate evaluation through the forward artifact.
#[test]
fn hybrid_model_classifies_above_chance() {
    let Some(rt) = rt() else { return };
    let dataset = data::mnist_train(600, 25);
    let cluster = Cluster::start(ClusterConfig::quick_test("mnist", 2), rt, &dataset).unwrap();
    let cfg =
        dist::hybrid::HybridConfig { rounds: 10, seed: 5, max_replay_per_round: 4, poll_ms: 2, ..Default::default() };
    let result = dist::hybrid::train(&cluster, &cfg).unwrap();

    // Evaluate the hybrid-trained parameters themselves through the
    // forward artifact: the distributed pipeline (not a standalone
    // re-train) must produce a model that beats chance.
    let rt2 = cluster.rt.clone();
    let spec = cluster.spec.clone();
    cluster.shutdown();

    let engine = XlaEngine::from_params(rt2, "mnist", result.params).unwrap();
    let mut loader = data::loader::BatchLoader::new(&dataset, spec.batch, 9);
    let (x, _, labels) = loader.next_batch();
    let probs = engine.forward(&x).unwrap();
    let err = metrics::error_rate(&probs, &labels);
    assert!(err < 0.85, "error rate {err} not above chance (0.9)");
}
