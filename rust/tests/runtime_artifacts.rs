//! Integration: the PJRT runtime executes real AOT artifacts and matches
//! the Python-side golden checksums (cross-language numeric validation).
//!
//! Golden inputs are regenerated locally from the SplitMix64 seeds in
//! artifacts/golden.json — bit-identical to what aot.py fed the jitted
//! functions (see python/compile/prand.py).

use std::sync::OnceLock;

use sashimi::runtime::{self, default_artifacts_dir, Tensor};
use sashimi::util::json::Value;
use sashimi::util::rng::golden_input;

/// The shared runtime, or `None` (skip message printed once) when the
/// AOT artifacts / XLA bindings are unavailable; every test early-returns
/// on `None`.  Run `make artifacts` to enable the golden checks.
fn runtime() -> Option<&'static runtime::SharedRuntime> {
    static RT: OnceLock<Option<runtime::SharedRuntime>> = OnceLock::new();
    RT.get_or_init(runtime::open_shared_or_skip).as_ref()
}

#[test]
fn smoke_matmul_exact_values() {
    let Some(rt) = runtime() else { return };
    let a = Tensor::filled(&[8, 16], 1.0);
    let b = Tensor::filled(&[16, 4], 1.0);
    let out = rt.exec("smoke_matmul", &[a, b]).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape(), &[8, 4]);
    // ones(8,16) @ ones(16,4) + 2 == 18 everywhere
    assert!(out[0].data().iter().all(|&v| (v - 18.0).abs() < 1e-5));
}

#[test]
fn input_shape_mismatch_is_an_error() {
    let Some(rt) = runtime() else { return };
    let a = Tensor::filled(&[8, 15], 1.0);
    let b = Tensor::filled(&[16, 4], 1.0);
    assert!(rt.exec("smoke_matmul", &[a, b]).is_err());
}

#[test]
fn input_arity_mismatch_is_an_error() {
    let Some(rt) = runtime() else { return };
    let a = Tensor::filled(&[8, 16], 1.0);
    assert!(rt.exec("smoke_matmul", &[a]).is_err());
}

#[test]
fn executable_cache_reuses_compilation() {
    let Some(rt) = runtime() else { return };
    let a = Tensor::filled(&[8, 16], 1.0);
    let b = Tensor::filled(&[16, 4], 1.0);
    rt.exec("smoke_matmul", &[a.clone(), b.clone()]).unwrap();
    rt.exec("smoke_matmul", &[a, b]).unwrap();
    let stats = rt.stats();
    let row = stats.iter().find(|r| r.0 == "smoke_matmul").unwrap();
    assert!(row.1 >= 2);
}

fn golden() -> Value {
    let dir = default_artifacts_dir().unwrap();
    let text = std::fs::read_to_string(dir.join("golden.json")).unwrap();
    Value::parse(&text).unwrap()
}

/// Execute `name` on inputs regenerated from the golden seeds; compare
/// output checksums against the Python-recorded values.
fn check_golden(name: &str) {
    let Some(rt) = runtime() else { return };
    let g = golden();
    let entry = g.get(name).unwrap_or_else(|_| panic!("no golden for {name}"));
    let seeds = entry.get("input_seeds").unwrap().as_arr().unwrap();
    let sig = rt.manifest().artifact(name).unwrap().clone();
    assert_eq!(seeds.len(), sig.inputs.len(), "{name}: seed/arity mismatch");
    let inputs: Vec<Tensor> = seeds
        .iter()
        .zip(&sig.inputs)
        .map(|(s, i)| {
            Tensor::new(i.shape.clone(), golden_input(s.as_u64().unwrap(), i.numel())).unwrap()
        })
        .collect();
    let outs = rt.exec(name, &inputs).unwrap();
    let expected = entry.get("outputs").unwrap();
    for (t, out_name) in outs.iter().zip(&sig.outputs) {
        let e = expected.get(out_name).unwrap();
        let (sum, abs) = t.checksum();
        let esum = e.get("sum").unwrap().as_f64().unwrap();
        let eabs = e.get("abs_sum").unwrap().as_f64().unwrap();
        let elen = e.get("len").unwrap().as_usize().unwrap();
        assert_eq!(t.len(), elen, "{name}/{out_name}: length");
        let tol = 1e-3 * eabs.max(1.0);
        assert!(
            (sum - esum).abs() < tol,
            "{name}/{out_name}: sum {sum} vs golden {esum} (tol {tol})"
        );
        assert!(
            (abs - eabs).abs() < tol,
            "{name}/{out_name}: abs_sum {abs} vs golden {eabs} (tol {tol})"
        );
        // First elements pinned tighter than the aggregate.
        let first = e.get("first").unwrap().as_f32_vec().unwrap();
        for (i, (got, want)) in t.data().iter().zip(&first).enumerate() {
            assert!(
                (got - want).abs() < 1e-3 * want.abs().max(1.0),
                "{name}/{out_name}[{i}]: {got} vs {want}"
            );
        }
    }
}

#[test]
fn golden_adagrad_update() {
    check_golden("adagrad_update");
}

#[test]
fn golden_knn_chunk_small() {
    check_golden("knn_chunk_small");
}

#[test]
fn golden_mnist_forward() {
    check_golden("mnist_forward");
}

#[test]
fn golden_mnist_fc_step() {
    check_golden("mnist_fc_step");
}

#[test]
fn golden_cifar_fc_step() {
    check_golden("cifar_fc_step");
}

#[test]
fn golden_mnist_conv_fwd() {
    check_golden("mnist_conv_fwd");
}

/// The heavyweight artifacts; run with `SASHIMI_FULL_GOLDEN=1 cargo test`.
#[test]
fn golden_all_remaining() {
    if std::env::var("SASHIMI_FULL_GOLDEN").is_err() {
        return;
    }
    for name in [
        "smoke_matmul",
        "knn_chunk",
        "mnist_train_step",
        "mnist_grad",
        "mnist_conv_grad",
        "cifar_forward",
        "cifar_train_step",
        "cifar_train_step_jnp",
        "cifar_grad",
        "cifar_conv_fwd",
        "cifar_conv_grad",
    ] {
        check_golden(name);
    }
}
