//! Fault tolerance (§2.1.2's claims): killed clients, slow clients, and
//! error-reporting clients never lose tickets; redistribution recovers
//! throughput.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use sashimi::coordinator::{Distributor, Framework};
use sashimi::store::{Scheduler as _, StoreConfig};
use sashimi::tasks::is_prime::IsPrimeTask;
use sashimi::transport::local::{self, FaultPlan};
use sashimi::transport::{Conn, LinkModel};
use sashimi::util::json::Value;
use sashimi::worker::{DeviceProfile, Worker};

fn prime_framework(n: usize, cfg: StoreConfig) -> (Arc<Framework>, sashimi::store::TaskId) {
    let fw = Framework::builder().store_config(cfg).build();
    let task = fw.create_task(Arc::new(IsPrimeTask));
    task.calculate((1..=n).map(|i| Value::obj(vec![("candidate", Value::num(i as f64))])).collect());
    let id = task.id;
    (fw, id)
}

/// A worker whose connection dies mid-run: its in-flight ticket is
/// redistributed (after the scaled timeout) and a healthy worker
/// finishes the job. "If a web browser is terminated after it receives a
/// ticket ... another client can execute the task."
#[test]
fn killed_client_tickets_are_redistributed() {
    let cfg = StoreConfig { requeue_after_ms: 150, min_redistribute_ms: 50, requeue_on_error: true, ..StoreConfig::default() };
    let (fw, task_id) = prime_framework(30, cfg);
    let dist = Distributor::new(&fw);
    let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
    dist.serve(Box::new(listener));
    let stop = Arc::new(AtomicBool::new(false));

    // Flaky worker: every connection dies after 6 sends. It reconnects
    // (up to its budget) and keeps dying — some tickets it received are
    // stranded in flight each time.
    let flaky = {
        let connector = connector.clone();
        let registry = fw.registry_snapshot();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut w = Worker::new("flaky", DeviceProfile::native(), registry);
            w.run(
                || {
                    Ok(Box::new(
                        connector.connect_with_fault(FaultPlan { die_after_sends: Some(6) })?,
                    ) as Box<dyn Conn>)
                },
                &stop,
            )
        })
    };

    // Healthy worker finishes everything the flaky one drops.
    let healthy = {
        let connector = connector.clone();
        let registry = fw.registry_snapshot();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut w = Worker::new("healthy", DeviceProfile::native(), registry);
            w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop)
        })
    };

    let results = fw.store().wait_results_timeout(task_id, 30_000).expect("task must finish");
    stop.store(true, Ordering::SeqCst);
    let _ = flaky.join().unwrap();
    let h = healthy.join().unwrap();
    assert_eq!(results.len(), 30);
    assert!(h.tickets_completed > 0);
    // Every ticket produced a correct result despite the faults.
    let primes = results.iter().filter(|r| r.get("is_prime").unwrap().as_bool().unwrap()).count();
    assert_eq!(primes, 10); // π(30)
}

/// A deterministically-erroring ticket generates error reports but never
/// blocks the rest of the queue (it cycles error -> requeue).
struct AlwaysFails;
impl sashimi::tasks::TaskDef for AlwaysFails {
    fn name(&self) -> &str {
        "always_fails"
    }
    fn execute(
        &self,
        input: &Value,
        _: &mut dyn sashimi::tasks::TaskContext,
    ) -> anyhow::Result<sashimi::tasks::TaskOutput> {
        if input.get("bad")?.as_bool()? {
            anyhow::bail!("synthetic failure");
        }
        Ok(sashimi::tasks::TaskOutput::new(Value::Bool(true)))
    }
}

#[test]
fn poisoned_ticket_does_not_block_good_ones() {
    // requeue_on_error=false: the poisoned ticket waits out the timeout
    // instead of ping-ponging, so good tickets drain first.
    let cfg =
        StoreConfig { requeue_after_ms: 400, min_redistribute_ms: 400, requeue_on_error: false, ..StoreConfig::default() };
    let fw = Framework::builder().store_config(cfg).build();
    let task = fw.create_task(Arc::new(AlwaysFails));
    let mut payloads = vec![Value::obj(vec![("bad", Value::Bool(true))])];
    payloads.extend((0..10).map(|_| Value::obj(vec![("bad", Value::Bool(false))])));
    task.calculate(payloads);

    let dist = Distributor::new(&fw);
    let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
    dist.serve(Box::new(listener));
    let stop = Arc::new(AtomicBool::new(false));
    let registry = fw.registry_snapshot();
    let connector2 = connector.clone();
    let stop2 = Arc::clone(&stop);
    let worker = std::thread::spawn(move || {
        let mut w = Worker::new("w", DeviceProfile::native(), registry);
        w.run(|| Ok(Box::new(connector2.connect()?) as Box<dyn Conn>), &stop2)
    });

    // The 10 good tickets complete even though the first keeps failing.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
    loop {
        let p = task.progress();
        if p.done == 10 {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "good tickets stuck: {p:?}");
        sashimi::util::clock::sleep_ms(20);
    }
    stop.store(true, Ordering::SeqCst);
    let report = worker.join().unwrap();
    assert!(report.errors_reported >= 1);
    assert!(fw.store().error_count() >= 1);
    let p = task.progress();
    assert_eq!(p.done, 10);
    assert_eq!(p.total, 11);
}

/// A work unit with an explicit modelled cost, so device profiles bite
/// even though the actual computation is trivial.
struct FixedCostTask;
impl sashimi::tasks::TaskDef for FixedCostTask {
    fn name(&self) -> &str {
        "fixed_cost"
    }
    fn execute(
        &self,
        _input: &Value,
        _: &mut dyn sashimi::tasks::TaskContext,
    ) -> anyhow::Result<sashimi::tasks::TaskOutput> {
        Ok(sashimi::tasks::TaskOutput { value: Value::Bool(true), modelled_ms: Some(40.0) })
    }
}

/// Straggler redistribution improves completion time: a very slow client
/// holding the last tickets gets raced by a fast client via the
/// min-redistribute fallback, and first-result-wins dedups.
#[test]
fn straggler_is_raced_by_redistribution() {
    let cfg = StoreConfig { requeue_after_ms: 250, min_redistribute_ms: 30, requeue_on_error: true, ..StoreConfig::default() };
    let fw = Framework::builder().store_config(cfg).build();
    let task = fw.create_task(Arc::new(FixedCostTask));
    task.calculate((0..12).map(|i| Value::num(i as f64)).collect());
    let task_id = task.id;
    let dist = Distributor::new(&fw);
    let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
    dist.serve(Box::new(listener));
    let stop = Arc::new(AtomicBool::new(false));

    // Very slow device: modelled 40 ms at 1/10 speed -> 400 ms/ticket;
    // 12 tickets solo would take ~4.8 s.
    let slow = {
        let connector = connector.clone();
        let registry = fw.registry_snapshot();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut w = Worker::new("slow", DeviceProfile::with_speed("glacial", 0.1), registry);
            w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop)
        })
    };
    // Give the slow worker a head start so it grabs early tickets.
    sashimi::util::clock::sleep_ms(30);
    let fast = {
        let connector = connector.clone();
        let registry = fw.registry_snapshot();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut w = Worker::new("fast", DeviceProfile::native(), registry);
            w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop)
        })
    };

    let t0 = std::time::Instant::now();
    let results = fw.store().wait_results_timeout(task_id, 30_000).expect("finishes");
    let elapsed = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::SeqCst);
    let _ = slow.join().unwrap();
    let f = fast.join().unwrap();
    assert_eq!(results.len(), 12);
    // The fast client must have taken over redistributed tickets; without
    // redistribution the slow client alone would need ~4.8 s.
    assert!(f.tickets_completed >= 6, "fast did {}", f.tickets_completed);
    assert!(elapsed < 4.0, "took {elapsed}s — redistribution failed");
    let p = fw.store().progress(None);
    assert!(p.redistributions > 0, "expected redistributions, got {p:?}");
}
