//! Property-based tests (seeded harness, util::proptest) on the
//! coordinator's invariants and the substrate codecs.

use std::collections::HashMap;

use sashimi::prop_assert;
use sashimi::store::{
    IndexedStore, NaiveStore, Progress, Scheduler, StoreConfig, TaskId, Ticket, TicketId,
    TicketStatus, TicketStore,
};
use sashimi::util::json::Value;
use sashimi::util::lru::LruCache;
use sashimi::util::proptest::check;
use sashimi::util::rng::SplitMix64;
use sashimi::util::{base64, stats};

/// Random interleavings of distribute/complete/error/clock-advance never
/// lose a ticket, never double-complete, and always terminate with every
/// ticket done once every ticket has been completed exactly once.
#[test]
fn store_never_loses_or_duplicates_tickets() {
    check("store-invariants", 60, |rng| {
        let cfg = StoreConfig {
            requeue_after_ms: 50 + rng.gen_range(200),
            min_redistribute_ms: 1 + rng.gen_range(50),
            requeue_on_error: rng.gen_range(2) == 0,
            ..StoreConfig::default()
        };
        let store = TicketStore::new(cfg);
        let n = 1 + rng.gen_range(20) as usize;
        let ids = store.create_tickets(
            TaskId(1),
            "t",
            (0..n).map(|i| Value::num(i as f64)).collect(),
            0,
        );
        let mut now = 0u64;
        let mut completed = vec![false; n];
        let mut in_hand: Vec<sashimi::store::Ticket> = Vec::new();
        // Random walk of operations.
        for _ in 0..400 {
            if completed.iter().all(|&c| c) {
                break;
            }
            match rng.gen_range(4) {
                0 => {
                    // distribute
                    if let Some(t) = store.next_ticket("c", now) {
                        prop_assert!(
                            t.status == TicketStatus::InFlight,
                            "distributed ticket not in flight"
                        );
                        prop_assert!(!completed[t.index], "done ticket redistributed");
                        in_hand.push(t);
                    }
                }
                1 => {
                    // complete one held ticket
                    if !in_hand.is_empty() {
                        let k = rng.gen_range(in_hand.len() as u64) as usize;
                        let t = in_hand.remove(k);
                        let fresh = store
                            .complete(t.id, Value::num(t.index as f64))
                            .map_err(|e| e.to_string())?;
                        if fresh {
                            prop_assert!(!completed[t.index], "double completion accepted");
                            completed[t.index] = true;
                        } else {
                            prop_assert!(completed[t.index], "duplicate on incomplete ticket");
                        }
                    }
                }
                2 => {
                    // error-report one held ticket
                    if !in_hand.is_empty() {
                        let k = rng.gen_range(in_hand.len() as u64) as usize;
                        let t = in_hand.remove(k);
                        store.report_error(t.id, "e".into()).map_err(|e| e.to_string())?;
                    }
                }
                _ => {
                    now += rng.gen_range(120);
                }
            }
        }
        // Drain: keep distributing+completing until done (bounded).
        for _ in 0..10_000 {
            if completed.iter().all(|&c| c) {
                break;
            }
            now += 31;
            if let Some(t) = store.next_ticket("drain", now) {
                let fresh =
                    store.complete(t.id, Value::num(t.index as f64)).map_err(|e| e.to_string())?;
                if fresh {
                    completed[t.index] = true;
                }
            }
        }
        prop_assert!(completed.iter().all(|&c| c), "not all tickets completed");
        let p = store.progress(None);
        prop_assert!(p.done == n, "done {} != {}", p.done, n);
        // Results must be ordered by index and match what was stored.
        let results = store.wait_results(TaskId(1));
        for (i, r) in results.iter().enumerate() {
            prop_assert!(
                r == &Value::num(i as f64),
                "result {} corrupted: {:?}",
                i,
                r
            );
        }
        let _ = ids;
        Ok(())
    });
}

/// Differential test: the indexed, sharded scheduler and the naive
/// O(n)-scan reference must be observably identical — same dispatch
/// order and ticket contents, same progress counters, same duplicate
/// and error accounting — across random operation sequences (create /
/// next_ticket / next_tickets(k) / complete / complete_batch /
/// report_error / release / release_batch) at random clocks.  The
/// batched ops pit the indexed store's amortised native paths against
/// the naive store's loop-fallback reference, so "batch == k-fold
/// loop" (including k=1) is pinned alongside dispatch order, §2.1.2
/// redistribution, the release transition and duplicate accounting.
#[test]
fn indexed_scheduler_matches_naive_reference() {
    check("sched-differential", 256, |rng| {
        let cfg = StoreConfig {
            requeue_after_ms: 20 + rng.gen_range(300),
            min_redistribute_ms: rng.gen_range(80),
            requeue_on_error: rng.gen_range(2) == 0,
            ..StoreConfig::default()
        };
        let indexed = IndexedStore::with_shards(cfg.clone(), 1 + rng.gen_range(8) as usize);
        let naive = NaiveStore::new(cfg);
        let tasks = [TaskId(1), TaskId(2), TaskId(3)];
        let mut now = 0u64;
        let mut created: Vec<TicketId> = Vec::new();
        for step in 0..160u64 {
            match rng.gen_range(12) {
                10 => {
                    // Singular release of a random known (sometimes
                    // unknown) id: the tolerant-flag semantics and the
                    // pool-return transition must agree.
                    let id = if !created.is_empty() && rng.gen_range(8) != 0 {
                        created[rng.gen_range(created.len() as u64) as usize]
                    } else {
                        TicketId(created.len() as u64 + 1_000)
                    };
                    let a = indexed.release(id);
                    let b = naive.release(id);
                    prop_assert!(a == b, "release diverges on {id:?}: {a} vs {b}");
                }
                11 => {
                    // Batched release (repeats and unknowns included):
                    // the indexed store's one-mutex-pass override vs
                    // the trait's id-by-id loop on the naive store.
                    let n = 1 + rng.gen_range(4) as usize;
                    let ids: Vec<TicketId> = (0..n)
                        .map(|_| {
                            if !created.is_empty() && rng.gen_range(8) != 0 {
                                created[rng.gen_range(created.len() as u64) as usize]
                            } else {
                                TicketId(created.len() as u64 + 1_000)
                            }
                        })
                        .collect();
                    let a = indexed.release_batch(&ids);
                    let b = naive.release_batch(&ids);
                    prop_assert!(
                        a == b,
                        "release_batch flags diverge on {ids:?}: {a:?} vs {b:?}"
                    );
                }
                8 => {
                    // Batched dispatch, k = 1..=4 (k = 1 must be
                    // bit-for-bit the unbatched path).
                    let client = format!("c{}", rng.gen_range(4));
                    let k = 1 + rng.gen_range(4) as usize;
                    let a = indexed.next_tickets(&client, now, k);
                    let b = naive.next_tickets(&client, now, k);
                    prop_assert!(
                        a == b,
                        "batch dispatch (k={k}) diverges at t={now}: {a:?} vs {b:?}"
                    );
                }
                9 => {
                    // Batched completion over a random mix of known ids
                    // (occasionally an unknown one mid-batch: the
                    // applied-prefix error semantics must agree too).
                    let n = 1 + rng.gen_range(3) as usize;
                    let entries: Vec<(TicketId, Value)> = (0..n)
                        .map(|_| {
                            let id = if !created.is_empty() && rng.gen_range(8) != 0 {
                                created[rng.gen_range(created.len() as u64) as usize]
                            } else {
                                TicketId(created.len() as u64 + 1_000)
                            };
                            (id, Value::num(id.0 as f64))
                        })
                        .collect();
                    let a = indexed.complete_batch(entries.clone());
                    let b = naive.complete_batch(entries);
                    prop_assert!(
                        a.is_err() == b.is_err(),
                        "complete_batch error status diverges"
                    );
                    if let (Ok(x), Ok(y)) = (a, b) {
                        prop_assert!(x == y, "complete_batch accepted counts diverge");
                    }
                }
                0 | 1 => {
                    let task = tasks[rng.gen_range(3) as usize];
                    let n = 1 + rng.gen_range(3);
                    let args: Vec<Value> =
                        (0..n).map(|i| Value::num((step * 10 + i) as f64)).collect();
                    let a = indexed.create_tickets(task, "t", args.clone(), now);
                    let b = naive.create_tickets(task, "t", args, now);
                    prop_assert!(a == b, "created ids diverge: {a:?} vs {b:?}");
                    created.extend(a);
                }
                2 | 3 | 4 => {
                    let client = format!("c{}", rng.gen_range(4));
                    let a = indexed.next_ticket(&client, now);
                    let b = naive.next_ticket(&client, now);
                    prop_assert!(a == b, "dispatch diverges at t={now}: {a:?} vs {b:?}");
                }
                5 => {
                    // A random known ticket — or, now and then, an unknown id.
                    let id = if !created.is_empty() && rng.gen_range(8) != 0 {
                        created[rng.gen_range(created.len() as u64) as usize]
                    } else {
                        TicketId(created.len() as u64 + 1_000)
                    };
                    let v = Value::num(id.0 as f64);
                    let a = indexed.complete(id, v.clone());
                    let b = naive.complete(id, v);
                    prop_assert!(
                        a.is_err() == b.is_err(),
                        "complete() error status diverges on {id:?}"
                    );
                    if let (Ok(x), Ok(y)) = (a, b) {
                        prop_assert!(x == y, "first-result-wins diverges on {id:?}");
                    }
                }
                6 => {
                    let id = if created.is_empty() {
                        TicketId(7_777)
                    } else {
                        created[rng.gen_range(created.len() as u64) as usize]
                    };
                    indexed.report_error(id, "e".into()).map_err(|e| e.to_string())?;
                    naive.report_error(id, "e".into()).map_err(|e| e.to_string())?;
                }
                _ => now += rng.gen_range(150),
            }
            let (gp, gq) = (indexed.progress(None), naive.progress(None));
            prop_assert!(gp == gq, "global progress diverges at step {step}: {gp:?} vs {gq:?}");
            for task in tasks {
                let (tp, tq) = (indexed.progress(Some(task)), naive.progress(Some(task)));
                prop_assert!(tp == tq, "progress for {task:?} diverges: {tp:?} vs {tq:?}");
                prop_assert!(
                    indexed.is_task_done(task) == naive.is_task_done(task),
                    "is_task_done diverges for {task:?}"
                );
            }
        }
        // Drain both along an identical path; collected results and the
        // error ledgers must then agree per task.
        for _ in 0..20_000 {
            now += 17;
            let a = indexed.next_ticket("drain", now);
            let b = naive.next_ticket("drain", now);
            prop_assert!(a == b, "drain dispatch diverges at t={now}");
            match a {
                Some(t) => {
                    let x = indexed
                        .complete(t.id, Value::num(t.index as f64))
                        .map_err(|e| e.to_string())?;
                    let y = naive
                        .complete(t.id, Value::num(t.index as f64))
                        .map_err(|e| e.to_string())?;
                    prop_assert!(x == y, "drain completion accounting diverges on {:?}", t.id);
                }
                None => {
                    if tasks.iter().all(|&t| indexed.is_task_done(t)) {
                        break;
                    }
                }
            }
        }
        for task in tasks {
            prop_assert!(indexed.is_task_done(task), "drain left {task:?} unfinished");
            let a = indexed.wait_results_timeout(task, 0);
            let b = naive.wait_results_timeout(task, 0);
            prop_assert!(a == b, "collected results diverge for {task:?}");
        }
        prop_assert!(
            indexed.error_count() == naive.error_count(),
            "cumulative error counts diverge"
        );
        let (ea, eb) = (indexed.drain_errors(), naive.drain_errors());
        prop_assert!(ea == eb, "buffered error reports diverge");
        Ok(())
    });
}

/// Differential test for the result-verification layer (DESIGN.md
/// §2.8): the indexed scheduler and the naive reference must agree
/// vote-for-vote across random interleavings of dispatch, honest and
/// fabricated ballots, attributed errors and releases, and clock
/// advances, at R ∈ {1, 2, 3}.  Every observable is compared — vote
/// outcomes, progress counters, verify counters, per-client standing,
/// the quarantine ledger and the final result set.  The fabrications
/// all share one value (the worst case: a corroborable lie), so quorum
/// poisoning, flagging, escalation and quarantine all genuinely occur —
/// the property is that both stores do them *identically*.
#[test]
fn quorum_voting_matches_naive_reference() {
    check("verify-differential", 256, |rng| {
        let replication = 1 + rng.gen_range(3) as u32;
        let cfg = StoreConfig {
            requeue_after_ms: 20 + rng.gen_range(300),
            min_redistribute_ms: rng.gen_range(80),
            requeue_on_error: rng.gen_range(2) == 0,
            replication,
            quorum: if replication == 1 { 1 } else { 2 },
            ..StoreConfig::default()
        };
        let indexed = IndexedStore::with_shards(cfg.clone(), 1 + rng.gen_range(4) as usize);
        let naive = NaiveStore::new(cfg);
        let clients = ["c0", "c1", "c2", "c3", "c4"];
        let mut now = 0u64;
        let mut created: Vec<TicketId> = Vec::new();
        for step in 0..200u64 {
            match rng.gen_range(10) {
                0 | 1 => {
                    let n = 1 + rng.gen_range(3);
                    let args: Vec<Value> =
                        (0..n).map(|i| Value::num((step * 10 + i) as f64)).collect();
                    let a = indexed.create_tickets(TaskId(1), "t", args.clone(), now);
                    let b = naive.create_tickets(TaskId(1), "t", args, now);
                    prop_assert!(a == b, "created ids diverge: {a:?} vs {b:?}");
                    created.extend(a);
                }
                2 | 3 => {
                    let client = clients[rng.gen_range(5) as usize];
                    let a = indexed.next_ticket(client, now);
                    let b = naive.next_ticket(client, now);
                    prop_assert!(
                        a == b,
                        "dispatch diverges for {client} at t={now}: {a:?} vs {b:?}"
                    );
                }
                4 | 5 | 6 => {
                    // A ballot on a random known (sometimes unknown) id,
                    // honest three times out of four.
                    let id = if !created.is_empty() && rng.gen_range(8) != 0 {
                        created[rng.gen_range(created.len() as u64) as usize]
                    } else {
                        TicketId(created.len() as u64 + 1_000)
                    };
                    let client = clients[rng.gen_range(5) as usize];
                    let v = if rng.gen_range(4) == 0 {
                        Value::num(id.0 as f64 + 10_000.0)
                    } else {
                        Value::num(id.0 as f64)
                    };
                    let a = indexed.vote(client, id, v.clone(), now);
                    let b = naive.vote(client, id, v, now);
                    prop_assert!(a.is_err() == b.is_err(), "vote error status diverges on {id:?}");
                    if let (Ok(x), Ok(y)) = (a, b) {
                        prop_assert!(x == y, "vote outcome diverges on {id:?}: {x:?} vs {y:?}");
                    }
                    let sa = indexed.client_standing(client, now);
                    let sb = naive.client_standing(client, now);
                    prop_assert!(sa == sb, "standing diverges for {client}: {sa:?} vs {sb:?}");
                }
                7 => {
                    // Attributed error report or release from a random
                    // client — the quarantine-sweep primitives.
                    if !created.is_empty() {
                        let id = created[rng.gen_range(created.len() as u64) as usize];
                        let client = clients[rng.gen_range(5) as usize];
                        if rng.gen_range(2) == 0 {
                            let msg = format!("e{step}");
                            indexed
                                .report_error_from(client, id, msg.clone())
                                .map_err(|e| e.to_string())?;
                            naive.report_error_from(client, id, msg).map_err(|e| e.to_string())?;
                        } else {
                            let a = indexed.release_batch_from(client, &[id]);
                            let b = naive.release_batch_from(client, &[id]);
                            prop_assert!(
                                a == b,
                                "release_from diverges on {id:?}: {a:?} vs {b:?}"
                            );
                        }
                    }
                }
                _ => now += rng.gen_range(150),
            }
            let (gp, gq) = (indexed.progress(None), naive.progress(None));
            prop_assert!(gp == gq, "progress diverges at step {step}: {gp:?} vs {gq:?}");
            let (va, vb) = (indexed.verify_stats(), naive.verify_stats());
            prop_assert!(va == vb, "verify stats diverge at step {step}: {va:?} vs {vb:?}");
        }
        // Drain with a rotation of fresh honest clients — wider than the
        // quorum, so same-client exclusion can never wedge a ticket.
        let drainers = ["d0", "d1", "d2", "d3"];
        'drain: for round in 0..20_000usize {
            now += 31;
            if indexed.is_task_done(TaskId(1)) {
                break;
            }
            for k in 0..drainers.len() {
                let d = drainers[(round + k) % drainers.len()];
                let a = indexed.next_ticket(d, now);
                let b = naive.next_ticket(d, now);
                prop_assert!(a == b, "drain dispatch diverges for {d} at t={now}");
                if let Some(t) = a {
                    let v = Value::num(t.id.0 as f64);
                    let x = indexed.vote(d, t.id, v.clone(), now).map_err(|e| e.to_string())?;
                    let y = naive.vote(d, t.id, v, now).map_err(|e| e.to_string())?;
                    prop_assert!(x == y, "drain vote diverges on {:?}: {x:?} vs {y:?}", t.id);
                    continue 'drain;
                }
            }
        }
        prop_assert!(indexed.is_task_done(TaskId(1)), "drain left tickets unfinished");
        prop_assert!(naive.is_task_done(TaskId(1)), "naive drain out of sync");
        let a = indexed.wait_results_timeout(TaskId(1), 0);
        let b = naive.wait_results_timeout(TaskId(1), 0);
        prop_assert!(a == b, "collected results diverge (poisoning must be identical too)");
        let (va, vb) = (indexed.verify_stats(), naive.verify_stats());
        prop_assert!(va == vb, "final verify stats diverge: {va:?} vs {vb:?}");
        prop_assert!(
            indexed.quarantined_clients() == naive.quarantined_clients(),
            "quarantine ledgers diverge"
        );
        let (ea, eb) = (indexed.drain_errors(), naive.drain_errors());
        prop_assert!(ea == eb, "buffered error reports diverge");
        Ok(())
    });
}

/// Everything but the id/index (which live in per-store id spaces) must
/// agree between a sharded pick and its per-shard oracle's pick.
fn same_modulo_id(a: &Ticket, b: &Ticket) -> bool {
    a.task == b.task
        && a.task_name == b.task_name
        && a.payload == b.payload
        && a.created_ms == b.created_ms
        && a.status == b.status
        && a.last_distributed_ms == b.last_distributed_ms
        && a.distribution_count == b.distribution_count
        && a.result == b.result
        && a.assigned_to == b.assigned_to
}

/// Field-wise sum of the oracles' progress — every counter is additive
/// across disjoint ticket populations.
fn sum_progress(oracles: &[NaiveStore], task: Option<TaskId>) -> Progress {
    let mut s = Progress::default();
    for o in oracles {
        let p = o.progress(task);
        s.total += p.total;
        s.pending += p.pending;
        s.in_flight += p.in_flight;
        s.done += p.done;
        s.errors += p.errors;
        s.redistributions += p.redistributions;
        s.duplicate_results += p.duplicate_results;
    }
    s
}

/// Mirror one sharded `next_tickets` onto the per-shard oracles: the
/// return must split into contiguous single-visit shard groups, each
/// group must be exactly that shard oracle's VCT-ordered pick, and any
/// shard the scan moved past (or never filled `k` from) must have been
/// dry — the DESIGN.md §2.6 contract.
fn mirror_dispatch(
    indexed: &IndexedStore,
    oracles: &[NaiveStore],
    to_oracle: &HashMap<u64, (usize, TicketId)>,
    client: &str,
    now: u64,
    k: usize,
) -> Result<(), String> {
    let mask = (oracles.len() - 1) as u64;
    let got = indexed.next_tickets(client, now, k);
    prop_assert!(got.len() <= k, "over-dispatch: {} tickets for k={k}", got.len());
    let mut groups: Vec<(usize, Vec<&Ticket>)> = Vec::new();
    for t in &got {
        let sh = (t.id.0 & mask) as usize;
        match groups.last_mut() {
            Some((s, ts)) if *s == sh => ts.push(t),
            _ => {
                prop_assert!(
                    groups.iter().all(|(s, _)| *s != sh),
                    "shard {sh} recurs in one dispatch: the steal scan visits each shard once"
                );
                groups.push((sh, vec![t]));
            }
        }
    }
    let mut taken = 0usize;
    for (sh, ts) in &groups {
        let o = oracles[*sh].next_tickets(client, now, ts.len());
        prop_assert!(
            o.len() == ts.len(),
            "oracle shard {sh} dispatched {} tickets, sharded store took {}",
            o.len(),
            ts.len()
        );
        for (t, ot) in ts.iter().zip(&o) {
            prop_assert!(
                to_oracle.get(&t.id.0) == Some(&(*sh, ot.id)),
                "shard {sh} VCT order diverges: picked {:?}, oracle picked {:?}",
                t.id,
                ot.id
            );
            prop_assert!(same_modulo_id(t, ot), "ticket fields diverge on {:?}", t.id);
        }
        taken += ts.len();
        if taken < k {
            // The scan moved on (or stopped short) after this group, so
            // the shard must have had nothing further ready.
            let probe = oracles[*sh].next_ticket(client, now);
            prop_assert!(probe.is_none(), "shard {sh} left ready work behind: {probe:?}");
        }
    }
    if got.len() < k {
        // A short batch means the scan visited *every* shard.
        for (sh, oracle) in oracles.iter().enumerate() {
            if groups.iter().any(|(s, _)| *s == sh) {
                continue;
            }
            let probe = oracle.next_ticket(client, now);
            prop_assert!(probe.is_none(), "unvisited shard {sh} had ready work: {probe:?}");
        }
    }
    Ok(())
}

/// Differential test for the sharded dispatch core (DESIGN.md §2.6): an
/// `IndexedStore` with S dispatch shards against S independent
/// `NaiveStore` oracles holding the tickets routed to each shard
/// (`id & (S - 1)`).  S = 1 degenerates to the global-order reference
/// above; S ∈ {2, 8} pins the relaxed contract — per-shard VCT order,
/// single-visit steal scans, exhaustion before under-filling a batch,
/// progress as the field-wise sum over shards, and shard-major error
/// drains — across random interleaved batch ops at random clocks.
#[test]
fn sharded_dispatch_matches_per_shard_naive_oracles() {
    check("shard-differential", 256, |rng| {
        let shards = [1usize, 2, 8][rng.gen_range(3) as usize];
        let mask = (shards - 1) as u64;
        let cfg = StoreConfig {
            requeue_after_ms: 20 + rng.gen_range(300),
            min_redistribute_ms: rng.gen_range(80),
            requeue_on_error: rng.gen_range(2) == 0,
            ..StoreConfig::default()
        };
        let indexed = IndexedStore::with_layout(cfg.clone(), 1 + rng.gen_range(4) as usize, shards);
        let oracles: Vec<NaiveStore> = (0..shards).map(|_| NaiveStore::new(cfg.clone())).collect();
        // Sharded-store id -> (shard, oracle id), and the reverse.
        let mut to_oracle: HashMap<u64, (usize, TicketId)> = HashMap::new();
        let mut from_oracle: HashMap<(usize, u64), TicketId> = HashMap::new();
        let tasks = [TaskId(1), TaskId(2), TaskId(3)];
        let mut now = 0u64;
        let mut created: Vec<TicketId> = Vec::new();
        for step in 0..160u64 {
            match rng.gen_range(10) {
                0 | 1 => {
                    let task = tasks[rng.gen_range(3) as usize];
                    let n = 1 + rng.gen_range(3);
                    let args: Vec<Value> =
                        (0..n).map(|i| Value::num((step * 10 + i) as f64)).collect();
                    let ids = indexed.create_tickets(task, "t", args.clone(), now);
                    for (id, arg) in ids.iter().zip(args) {
                        let sh = (id.0 & mask) as usize;
                        let oid = oracles[sh].create_tickets(task, "t", vec![arg], now)[0];
                        to_oracle.insert(id.0, (sh, oid));
                        from_oracle.insert((sh, oid.0), *id);
                    }
                    created.extend(ids);
                }
                2 | 3 | 4 => {
                    let client = format!("c{}", rng.gen_range(4));
                    let k = 1 + rng.gen_range(5) as usize;
                    mirror_dispatch(&indexed, &oracles, &to_oracle, &client, now, k)?;
                }
                5 => {
                    if !created.is_empty() && rng.gen_range(8) != 0 {
                        let id = created[rng.gen_range(created.len() as u64) as usize];
                        let (sh, oid) = to_oracle[&id.0];
                        let v = Value::num(id.0 as f64);
                        let a = indexed.complete(id, v.clone());
                        let b = oracles[sh].complete(oid, v);
                        prop_assert!(
                            a.is_err() == b.is_err(),
                            "complete() error status diverges on {id:?}"
                        );
                        if let (Ok(x), Ok(y)) = (a, b) {
                            prop_assert!(x == y, "first-result-wins diverges on {id:?}");
                        }
                    } else {
                        let bogus = TicketId(created.len() as u64 + 1_000);
                        prop_assert!(
                            indexed.complete(bogus, Value::Null).is_err(),
                            "unknown-id complete must error"
                        );
                    }
                }
                6 => {
                    if !created.is_empty() {
                        let id = created[rng.gen_range(created.len() as u64) as usize];
                        let (sh, oid) = to_oracle[&id.0];
                        let msg = format!("e{step}");
                        indexed.report_error(id, msg.clone()).map_err(|e| e.to_string())?;
                        oracles[sh].report_error(oid, msg).map_err(|e| e.to_string())?;
                    }
                }
                7 => {
                    // Batched completion over known ids: the accepted
                    // count must equal item-wise oracle completions.
                    if !created.is_empty() {
                        let n = 1 + rng.gen_range(3) as usize;
                        let ids: Vec<TicketId> = (0..n)
                            .map(|_| created[rng.gen_range(created.len() as u64) as usize])
                            .collect();
                        let entries: Vec<(TicketId, Value)> =
                            ids.iter().map(|id| (*id, Value::num(id.0 as f64))).collect();
                        let a = indexed.complete_batch(entries).map_err(|e| e.to_string())?;
                        let mut want = 0usize;
                        for id in &ids {
                            let (sh, oid) = to_oracle[&id.0];
                            if oracles[sh]
                                .complete(oid, Value::num(id.0 as f64))
                                .map_err(|e| e.to_string())?
                            {
                                want += 1;
                            }
                        }
                        prop_assert!(a == want, "complete_batch accepted {a} != item-wise {want}");
                    }
                }
                8 => {
                    // Batched release, unknowns included: flag-for-flag
                    // against per-id oracle releases.
                    let n = 1 + rng.gen_range(4) as usize;
                    let ids: Vec<TicketId> = (0..n)
                        .map(|_| {
                            if !created.is_empty() && rng.gen_range(8) != 0 {
                                created[rng.gen_range(created.len() as u64) as usize]
                            } else {
                                TicketId(created.len() as u64 + 1_000)
                            }
                        })
                        .collect();
                    let a = indexed.release_batch(&ids);
                    let want: Vec<bool> = ids
                        .iter()
                        .map(|id| {
                            to_oracle
                                .get(&id.0)
                                .is_some_and(|&(sh, oid)| oracles[sh].release(oid))
                        })
                        .collect();
                    prop_assert!(
                        a == want,
                        "release_batch flags diverge on {ids:?}: {a:?} vs {want:?}"
                    );
                }
                _ => now += rng.gen_range(150),
            }
            let (gp, gq) = (indexed.progress(None), sum_progress(&oracles, None));
            prop_assert!(gp == gq, "progress != shard sum at step {step}: {gp:?} vs {gq:?}");
            for task in tasks {
                let (tp, tq) = (indexed.progress(Some(task)), sum_progress(&oracles, Some(task)));
                prop_assert!(tp == tq, "progress for {task:?} != shard sum: {tp:?} vs {tq:?}");
            }
        }
        let st = indexed.stats();
        prop_assert!(st.dispatch_shards == shards, "stats() shard count diverges");
        prop_assert!(st.shard_depths.len() == shards, "stats() depth vector length diverges");
        prop_assert!(st.dispatch_locks > 0, "dispatches must count lock acquisitions");
        // Drain to completion, mirroring whichever shard each pick came
        // from; when the sharded store idles, every oracle must too.
        for _ in 0..20_000 {
            now += 17;
            match indexed.next_ticket("drain", now) {
                Some(t) => {
                    let sh = (t.id.0 & mask) as usize;
                    let oid = match oracles[sh].next_ticket("drain", now) {
                        Some(o) => o.id,
                        None => return Err(format!("oracle shard {sh} dry at pick {:?}", t.id)),
                    };
                    prop_assert!(
                        to_oracle[&t.id.0] == (sh, oid),
                        "drain pick diverges on {:?}",
                        t.id
                    );
                    let v = Value::num(t.id.0 as f64);
                    let x = indexed.complete(t.id, v.clone()).map_err(|e| e.to_string())?;
                    let y = oracles[sh].complete(oid, v).map_err(|e| e.to_string())?;
                    prop_assert!(x == y, "drain completion accounting diverges on {:?}", t.id);
                }
                None => {
                    for (sh, oracle) in oracles.iter().enumerate() {
                        let probe = oracle.next_ticket("drain", now);
                        prop_assert!(
                            probe.is_none(),
                            "sharded store idle but shard {sh} ready: {probe:?}"
                        );
                    }
                    if tasks.iter().all(|&t| indexed.is_task_done(t)) {
                        break;
                    }
                }
            }
        }
        for task in tasks {
            prop_assert!(indexed.is_task_done(task), "drain left {task:?} unfinished");
        }
        let total_errs: usize = oracles.iter().map(|o| o.error_count()).sum();
        prop_assert!(indexed.error_count() == total_errs, "cumulative error counts diverge");
        let drained = indexed.drain_errors();
        let mut want: Vec<(TicketId, String)> = Vec::new();
        for (sh, oracle) in oracles.iter().enumerate() {
            want.extend(
                oracle.drain_errors().into_iter().map(|(oid, msg)| (from_oracle[&(sh, oid.0)], msg)),
            );
        }
        prop_assert!(drained == want, "error drains diverge from shard-major oracle order");
        Ok(())
    });
}

/// JSON writer/parser round-trips arbitrary machine-generated values.
#[test]
fn json_roundtrips_random_values() {
    fn gen_value(rng: &mut SplitMix64, depth: usize) -> Value {
        match if depth == 0 { rng.gen_range(4) } else { rng.gen_range(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.gen_range(2) == 0),
            2 => {
                // Mix of integers, fractions, negatives, big exponents.
                let raw = rng.uniform_f32(-1e6, 1e6) as f64;
                Value::Num(match rng.gen_range(3) {
                    0 => raw.trunc(),
                    1 => raw / 1024.0,
                    _ => raw * 1e-12,
                })
            }
            3 => {
                let len = rng.gen_range(12) as usize;
                let s: String = (0..len)
                    .map(|_| {
                        let c = rng.gen_range(96) as u8 + 32; // printable ASCII
                        if c == b'"' || c == b'\\' {
                            'x'
                        } else {
                            c as char
                        }
                    })
                    .collect();
                Value::Str(format!("{s}\"\\\n\té")) // plant escapes + UTF-8
            }
            4 => Value::Arr((0..rng.gen_range(5)).map(|_| gen_value(rng, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.gen_range(5) {
                    m.insert(format!("k{i}"), gen_value(rng, depth - 1));
                }
                Value::Obj(m)
            }
        }
    }
    check("json-roundtrip", 200, |rng| {
        let v = gen_value(rng, 3);
        let text = v.to_string();
        let back = Value::parse(&text).map_err(|e| format!("parse failed on {text:?}: {e}"))?;
        prop_assert!(back == v, "roundtrip mismatch:\n  {v:?}\n  {back:?}");
        Ok(())
    });
}

/// base64 round-trips arbitrary byte strings and f32 buffers bit-exactly.
#[test]
fn base64_roundtrips_random_buffers() {
    check("base64-roundtrip", 200, |rng| {
        let len = rng.gen_range(512) as usize;
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let back = base64::decode(&base64::encode(&bytes)).map_err(|e| e.to_string())?;
        prop_assert!(back == bytes, "byte roundtrip failed at len {len}");
        let floats: Vec<f32> = (0..len / 4).map(|_| f32::from_bits(rng.next_u64() as u32)).collect();
        let fback = base64::decode_f32(&base64::encode_f32(&floats)).map_err(|e| e.to_string())?;
        prop_assert!(fback.len() == floats.len(), "f32 length");
        for (a, b) in floats.iter().zip(&fback) {
            prop_assert!(a.to_bits() == b.to_bits(), "f32 bits changed");
        }
        Ok(())
    });
}

/// LRU cache: never exceeds budget by more than one entry, and a
/// just-inserted or just-touched key always survives the next insert.
#[test]
fn lru_budget_and_recency_properties() {
    check("lru-invariants", 100, |rng| {
        let budget = 64 + rng.gen_range(256) as usize;
        let mut cache: LruCache<u64, u64> = LruCache::new(budget);
        let mut last_touched: Option<u64> = None;
        for step in 0..200 {
            let key = rng.gen_range(32);
            match rng.gen_range(3) {
                0 => {
                    let size = 1 + rng.gen_range(48) as usize;
                    cache.put(key, step, size);
                    if size <= budget {
                        prop_assert!(cache.contains(&key), "fresh insert evicted itself");
                    }
                    if let Some(prev) = last_touched {
                        // The most recently *used* other key should only be
                        // gone if the budget truly forced it: weaker check —
                        // used_bytes respects budget modulo one oversize.
                        let _ = prev;
                    }
                    last_touched = Some(key);
                }
                1 => {
                    if cache.get(&key).is_some() {
                        last_touched = Some(key);
                    }
                }
                _ => {
                    let in_budget = cache.used_bytes() <= budget + 48;
                    prop_assert!(in_budget, "used {} exceeds budget {}", cache.used_bytes(), budget);
                }
            }
        }
        Ok(())
    });
}

/// stats::percentile is monotone in p and bounded by min/max.
#[test]
fn percentile_properties() {
    check("percentile-monotone", 100, |rng| {
        let n = 1 + rng.gen_range(50) as usize;
        let xs: Vec<f64> = (0..n).map(|_| rng.uniform_f32(-100.0, 100.0) as f64).collect();
        let mut last = f64::NEG_INFINITY;
        for p in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 100.0] {
            let v = stats::percentile(&xs, p);
            prop_assert!(v >= last - 1e-12, "percentile not monotone at p={p}");
            prop_assert!(
                v >= stats::min(&xs) - 1e-12 && v <= stats::max(&xs) + 1e-12,
                "percentile out of range"
            );
            last = v;
        }
        Ok(())
    });
}

/// dist::aggregate_gradients is a weighted mean: permutation-invariant,
/// scale-invariant in the weights, and equal to the plain mean under
/// equal weights.
#[test]
fn aggregate_gradients_weighted_mean_properties() {
    use sashimi::dist::aggregate_gradients;
    use sashimi::nn::ParamSet;
    use sashimi::runtime::Tensor;

    fn close(a: &ParamSet, b: &ParamSet, tol: f32) -> Result<(), String> {
        for name in a.names() {
            let (x, y) = (a.get(name).unwrap(), b.get(name).unwrap());
            for (i, (p, q)) in x.data().iter().zip(y.data()).enumerate() {
                if (p - q).abs() > tol {
                    return Err(format!("{name}[{i}]: {p} vs {q}"));
                }
            }
        }
        Ok(())
    }

    check("aggregate-weighted-mean", 60, |rng| {
        let n_tensors = 1 + rng.gen_range(3) as usize;
        let shapes: Vec<Vec<usize>> = (0..n_tensors)
            .map(|_| vec![1 + rng.gen_range(4) as usize, 1 + rng.gen_range(4) as usize])
            .collect();
        let n_parts = 1 + rng.gen_range(4) as usize;
        let mut parts: Vec<(f32, ParamSet)> = Vec::new();
        for _ in 0..n_parts {
            let pairs: Vec<(String, Tensor)> = shapes
                .iter()
                .enumerate()
                .map(|(i, s)| (format!("p{i}"), Tensor::uniform(s, rng, 2.0)))
                .collect();
            parts.push((0.25 + rng.uniform_f32(0.0, 4.0), ParamSet::from_pairs(pairs)));
        }
        let base = aggregate_gradients(&parts).map_err(|e| e.to_string())?;

        // Permutation invariance (rotation by a random offset).
        let mut rotated = parts.clone();
        rotated.rotate_left(rng.gen_range(n_parts as u64) as usize);
        close(&base, &aggregate_gradients(&rotated).map_err(|e| e.to_string())?, 1e-4)?;

        // Total-weight normalization: rescaling every weight is a no-op.
        let scaled: Vec<_> = parts.iter().map(|(w, g)| (w * 7.5, g.clone())).collect();
        close(&base, &aggregate_gradients(&scaled).map_err(|e| e.to_string())?, 1e-4)?;

        // Equal weights reduce to the plain mean.
        let equal: Vec<_> = parts.iter().map(|(_, g)| (1.0f32, g.clone())).collect();
        let mean = aggregate_gradients(&equal).map_err(|e| e.to_string())?;
        for i in 0..n_tensors {
            let name = format!("p{i}");
            let got = mean.get(&name).map_err(|e| e.to_string())?;
            for (j, v) in got.data().iter().enumerate() {
                let want = parts
                    .iter()
                    .map(|(_, g)| g.get(&name).unwrap().data()[j])
                    .sum::<f32>()
                    / n_parts as f32;
                prop_assert!((v - want).abs() < 1e-4, "plain mean {name}[{j}]: {v} vs {want}");
            }
        }
        Ok(())
    });
}

/// dist::CommModel: per-round floats are monotone in the fleet size and
/// in the model dimensions each algorithm actually ships; the hybrid
/// count is independent of the FC block (the paper's whole point).
#[test]
fn comm_model_monotonicity_properties() {
    use sashimi::dist::CommModel;

    check("comm-model-monotone", 100, |rng| {
        let m = CommModel {
            conv_params: 1 + rng.gen_range(1_000_000) as usize,
            fc_params: 1 + rng.gen_range(10_000_000) as usize,
            boundary: 1 + rng.gen_range(1_000_000) as usize,
        };
        let w = 1 + rng.gen_range(8) as usize;
        let s = 1 + rng.gen_range(8) as usize;
        let hybrid = m.hybrid_floats(w, s);
        let mlitb = m.mlitb_floats(w, s);
        prop_assert!(m.hybrid_floats(w + 1, s) > hybrid, "hybrid not monotone in workers");
        prop_assert!(m.hybrid_floats(w, s + 1) > hybrid, "hybrid not monotone in shards");
        prop_assert!(m.mlitb_floats(w + 1, s) > mlitb, "mlitb not monotone in workers");
        prop_assert!(m.mlitb_floats(w, s + 1) > mlitb, "mlitb not monotone in shards");
        prop_assert!(
            m.he_sync_floats(w, s) == m.mlitb_floats(w, s),
            "he_sync volume must equal mlitb's"
        );
        let bigger_fc = CommModel { fc_params: m.fc_params * 2, ..m };
        prop_assert!(
            bigger_fc.mlitb_floats(w, s) > m.mlitb_floats(w, s),
            "baselines must pay for FC growth"
        );
        prop_assert!(
            bigger_fc.hybrid_floats(w, s) == m.hybrid_floats(w, s),
            "hybrid bytes must not depend on the FC block"
        );
        let bigger_boundary = CommModel { boundary: m.boundary * 2, ..m };
        prop_assert!(
            bigger_boundary.hybrid_floats(w, s) > m.hybrid_floats(w, s),
            "hybrid must pay for the boundary"
        );
        Ok(())
    });
}

/// LinkModel::transfer_ms is monotone in payload bytes and in latency —
/// the ordering the communication model's byte counts rely on to imply
/// time.
#[test]
fn link_transfer_monotone_in_bytes_and_latency() {
    use sashimi::transport::LinkModel;

    check("link-monotone", 100, |rng| {
        let link = LinkModel {
            latency_ms: rng.uniform_f32(0.0, 100.0) as f64,
            bytes_per_ms: 1.0 + rng.uniform_f32(0.0, 100_000.0) as f64,
        };
        let a = rng.gen_range(1_000_000) as usize;
        let b = a + rng.gen_range(1_000_000) as usize;
        prop_assert!(
            link.transfer_ms(b) >= link.transfer_ms(a),
            "transfer not monotone in bytes: {a} vs {b}"
        );
        let slower = LinkModel { latency_ms: link.latency_ms + 5.0, ..link };
        prop_assert!(
            slower.transfer_ms(a) > link.transfer_ms(a),
            "transfer not monotone in latency"
        );
        Ok(())
    });
}

/// The churn soak is a pure function of its config: running the same
/// seeded soak twice yields byte-identical event traces and metrics
/// JSON — the property `sim` stakes its reproducibility claim on
/// (every dispatch decision, vanish, latency sample and histogram
/// bucket replays exactly).
#[test]
fn churn_soak_same_seed_same_bytes() {
    use sashimi::sim::{run_soak, SoakConfig};

    check("soak-determinism", 3, |rng| {
        let mut cfg = SoakConfig::new(32 + rng.gen_range(32) as usize, rng.next_u64());
        cfg.duration_ms = 60_000;
        cfg.mean_lifetime_ms = 5_000;
        // Half the reps soak the passive window-expiry baseline.
        cfg.release_on_disconnect = rng.gen_range(2) == 0;
        // A third of the reps also soak the §2.8 verification layer
        // with a random adversary mix — quorum voting, escalations and
        // quarantines must not cost reproducibility.
        if rng.gen_range(3) == 0 {
            cfg.release_on_disconnect = true;
            cfg.store_cfg.replication = 2 + rng.gen_range(2) as u32;
            cfg.store_cfg.quorum = 2;
            cfg.adversary_wrong_permille = rng.gen_range(250);
            cfg.adversary_corrupt_permille = rng.gen_range(150);
            cfg.adversary_collude_permille = rng.gen_range(150);
        }
        let a = run_soak(&cfg).map_err(|e| e.to_string())?;
        let b = run_soak(&cfg).map_err(|e| e.to_string())?;
        prop_assert!(
            a.metrics_json == b.metrics_json,
            "metrics diverge for {cfg:?}:\n  {}\n  {}",
            a.metrics_json,
            b.metrics_json
        );
        prop_assert!(a.trace == b.trace, "event traces diverge for {cfg:?}");
        prop_assert!(a.done == a.total, "soak lost tickets: {}/{}", a.done, a.total);
        prop_assert!(a.ghosts_after_close == 0, "soak leaked ghost clients");
        Ok(())
    });
}

/// Tensor wire format: LE bytes round-trip through the transport codec.
#[test]
fn tensor_json_wire_roundtrip() {
    check("tensor-wire", 60, |rng| {
        let rows = 1 + rng.gen_range(8) as usize;
        let cols = 1 + rng.gen_range(8) as usize;
        let t = sashimi::runtime::Tensor::uniform(&[rows, cols], rng, 3.0);
        let v = sashimi::tasks::tensor_to_json(&t);
        let back = sashimi::tasks::tensor_from_json(&v).map_err(|e| e.to_string())?;
        prop_assert!(back == t, "tensor wire roundtrip failed");
        Ok(())
    });
}
