//! Tier-1 churn soak: a 1k-browser fleet churning against one real
//! Distributor + WAL store coordinator for ten simulated minutes, on a
//! virtual clock, in well under a minute of wall time.
//!
//! The soak pins the operational invariants the paper's §2.1.2 design
//! claims under churn:
//!
//! * **zero lost tickets** — every ticket completes despite vanishes,
//!   reloads, injected task faults and permanent departures;
//! * **zero ghost workers** — the client table tracks the online fleet
//!   exactly and empties on shutdown;
//! * **bounded stranding** — no ticket is stranded longer than the
//!   redistribution window (plus poll slack) even in the passive
//!   baseline, and the active release path keeps stranding to seconds.

use sashimi::sim::{run_soak, SoakConfig};
use sashimi::store::StoreConfig;

/// The CI per-PR soak: `SoakConfig::quick()` — 1000 workers, seed 42,
/// ten simulated minutes of churn on the active failure path.
#[test]
fn quick_soak_1k_workers_loses_nothing() {
    let wall = std::time::Instant::now();
    let cfg = SoakConfig::quick();
    assert_eq!(cfg.workers, 1_000);
    let r = run_soak(&cfg).unwrap();

    // Ten simulated minutes, far less wall time.
    assert!(r.virtual_ms >= 600_000, "simulated only {} ms", r.virtual_ms);
    assert!(
        wall.elapsed().as_secs() < 60,
        "soak took {:?} wall — the virtual clock is not doing its job",
        wall.elapsed()
    );

    // Zero lost tickets: everything completes and the store is at rest.
    assert_eq!(r.done, r.total, "lost tickets: {}", r.total - r.done);
    assert_eq!((r.pending, r.in_flight), (0, 0), "store not at rest");
    assert!(r.dispatched as usize >= r.total);

    // Zero ghost workers.
    assert_eq!(r.ghost_entries, 0, "client table out of sync with the online fleet");
    assert_eq!(r.ghosts_after_close, 0, "ghost clients after shutdown");

    // Churn actually happened, and the active path kept stranding
    // windows to re-dispatch latency, not the 5-minute window.
    assert!(r.vanishes > 100, "only {} vanishes — not much of a churn soak", r.vanishes);
    assert!(r.reloads > 0);
    assert!(r.max_strand_ms <= 60_000.0, "active path stranded {} ms", r.max_strand_ms);

    // The sweep's coordinator-side argmin survived the churn.
    assert_eq!(r.sweep_best, Some((3e-3, 1e-2)));

    // All three Table 1 device classes contributed results.
    for class in ["desktop", "tablet", "firefox"] {
        assert!(
            !r.metrics_json.contains(&format!("\"{class}\":{{\"completed\":0")),
            "{class} completed nothing: {}",
            r.metrics_json
        );
    }
}

/// The same zero-loss/zero-ghost contract with the dispatch core split
/// into shards (DESIGN.md §2.6): a quick soak at 2 and 8 dispatch
/// shards, real worker threads stealing across real per-shard WAL
/// streams.  The sharded runs must also surface their contention
/// counters in the metrics JSON.
#[test]
fn sharded_soak_loses_nothing_at_every_shard_count() {
    for shards in [2usize, 8] {
        let mut cfg = SoakConfig::new(200, 7);
        cfg.dispatch_shards = shards;
        cfg.duration_ms = 120_000;
        let r = run_soak(&cfg).unwrap_or_else(|e| panic!("sharded soak ({shards}) failed: {e}"));
        assert_eq!(r.done, r.total, "lost tickets at {shards} shards: {}", r.total - r.done);
        assert_eq!((r.pending, r.in_flight), (0, 0), "store not at rest at {shards} shards");
        assert_eq!(r.ghosts_after_close, 0, "ghost clients at {shards} shards");
        assert!(r.vanishes > 0, "churn too gentle at {shards} shards");
        assert!(
            r.metrics_json.contains(&format!("\"dispatch_shards\":{shards}")),
            "metrics must report the shard layout: {}",
            r.metrics_json
        );
    }
}

/// The CI adversarial quorum soak: `SoakConfig::adversarial_quick()` —
/// the 1k-worker quick fleet with 20% wrong-result adversaries,
/// verified at R = 3, quorum = 2 (DESIGN.md §2.8).  Quorum voting must
/// let zero fabricated results reach the result set, quarantine every
/// worker that actually lied, and still converge the sweep to the exact
/// argmin — while keeping dispatch overhead within the 2.5x budget of
/// the unverified baseline.
#[test]
fn adversarial_quick_soak_poisons_nothing() {
    let baseline = run_soak(&SoakConfig::quick()).unwrap();

    let cfg = SoakConfig::adversarial_quick();
    assert_eq!(cfg.workers, 1_000);
    assert_eq!((cfg.store_cfg.replication, cfg.store_cfg.quorum), (3, 2));
    assert_eq!(cfg.adversary_wrong_permille, 200);
    let r = run_soak(&cfg).unwrap();

    // Zero lost tickets, even with a fifth of the fleet lying.
    assert_eq!(r.done, r.total, "lost tickets: {}", r.total - r.done);
    assert_eq!((r.pending, r.in_flight), (0, 0), "store not at rest");
    assert_eq!(r.ghosts_after_close, 0);

    // The adversaries showed up, lied, were outvoted, and none of their
    // fabrications reached a completed ticket.
    assert!(r.adversaries > 150, "only {} adversaries in a 20% mix", r.adversaries);
    assert!(r.adversaries_lied > 0, "no adversary ever got to lie");
    assert_eq!(r.poisoned_completions, 0, "fabricated results were accepted");
    assert_eq!(
        r.adversaries_quarantined, r.adversaries_lied,
        "every worker that lied must end the run quarantined"
    );
    assert!(r.verify.verdicts as usize >= r.total, "every ticket needs a verdict");
    assert!(r.verify.votes_flagged > 0, "outvoted ballots must be flagged");

    // The sweep argmin is exact — no poisoned grid point shifted it.
    assert_eq!(r.sweep_best, Some((3e-3, 1e-2)));

    // The metrics JSON carries the verify block CI uploads.
    assert!(r.metrics_json.contains("\"verify\":{\"replication\":3,\"quorum\":2"));
    assert!(r.metrics_json.contains("\"poisoned_completions\":0"));

    // Replication costs dispatches; the acceptance budget is 2.5x the
    // unverified baseline (EXPERIMENTS.md §Verify).
    let overhead = r.dispatched as f64 / baseline.dispatched as f64;
    assert!(overhead <= 2.5, "dispatch overhead {overhead:.2}x exceeds the 2.5x budget");
}

/// The passive §2.1.2 baseline at smaller scale: vanished browsers
/// strand tickets until window expiry, and stranding is bounded by the
/// window (plus poll slack) — the soak-metrics counterpart of the
/// scripted `failure_path.rs` tests.
#[test]
fn passive_soak_strands_are_window_bounded() {
    let mut cfg = SoakConfig::new(64, 23);
    cfg.release_on_disconnect = false;
    cfg.mean_lifetime_ms = 2_500; // everyone dies young, mid-batch
    cfg.duration_ms = 60_000;
    let r = run_soak(&cfg).unwrap();

    assert_eq!(r.done, r.total, "windows eventually recover every ticket");
    assert_eq!(r.ghosts_after_close, 0);
    assert!(r.strand_count > 0, "no stranding — churn too gentle to test the window");
    assert!(r.redistributions > 0, "no window expiries exercised");

    let window = StoreConfig::default().requeue_after_ms as f64;
    assert!(
        r.max_strand_ms >= 0.3 * window,
        "passive stranding should approach the window, got {} ms",
        r.max_strand_ms
    );
    assert!(
        r.max_strand_ms <= window + 60_000.0,
        "stranding exceeded the redistribution window: {} ms",
        r.max_strand_ms
    );
    assert!(r.virtual_ms >= 300_000, "the run must outlive the window to drain");
}
