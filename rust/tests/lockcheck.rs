//! The ranked-lock witness in anger (DESIGN.md §2.9).
//!
//! Tier-1 `cargo test` runs in debug, so every store/WAL lock in these
//! tests goes through the live `util::lockcheck` witness: a clean run
//! *is* the machine-checked proof that the exercised interleavings obey
//! the global rank order.  The negative tests prove the witness is
//! actually on: a seeded inversion against the real rank table must
//! panic, and the `try_lock` escape hatch must not.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;

use sashimi::store::{
    Scheduler, StoreConfig, SyncPolicy, TaskId, TicketStore, WalConfig, WalStore,
};
use sashimi::store::NaiveStore;
use sashimi::util::json::Value;
use sashimi::util::lockcheck::{held_count, CheckedMutex, Rank};

fn temp_dir(tag: &str) -> PathBuf {
    static N: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "sashimi-lockcheck-{tag}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn args(n: usize) -> Vec<Value> {
    (0..n).map(|i| Value::num(i as f64)).collect()
}

/// A blocking acquire that descends the *real* rank table (a dispatch
/// shard held, then the verify mutex wanted) is exactly the shape that
/// can deadlock against `vote()`'s verify→shard order; the witness must
/// refuse it before blocking.
#[test]
#[cfg_attr(not(debug_assertions), ignore = "lockcheck witness is debug-only")]
#[should_panic(expected = "lock rank inversion")]
fn seeded_rank_inversion_panics_in_debug() {
    let shard = CheckedMutex::new(Rank::dispatch_shard(0), ());
    let verify = CheckedMutex::new(Rank::verify_state(), ());
    let _held = shard.lock().unwrap();
    let _inverted = verify.lock().unwrap();
}

/// The work-stealing escape hatch: a lower-ranked `try_lock` probe
/// (stealing scans probe shards below the home shard) records but never
/// asserts, because a failed probe is dropped, not waited on.
#[test]
fn try_lock_steal_shape_never_panics() {
    let high = CheckedMutex::new(Rank::dispatch_shard(3), 0u32);
    let low = CheckedMutex::new(Rank::dispatch_shard(1), 7u32);
    let _home = high.lock().unwrap();
    let stolen = low.try_lock().unwrap();
    assert_eq!(*stolen, 7);
    drop(stolen);
    drop(_home);
    assert_eq!(held_count(), 0);
}

/// Drive a sharded, verifying `IndexedStore` from concurrent clients
/// through every lock-nesting path the store has: create (stripes +
/// ledger registry), dispatch + steal (shard mutexes), quorum votes
/// (verify held across a shard acquire), release/error, and the
/// condvar-backed result wait.  Zero rank panics = the discipline
/// holds under contention.
#[test]
fn wrapped_indexed_store_runs_clean_under_contention() {
    let cfg = StoreConfig { replication: 2, quorum: 2, ..StoreConfig::default() };
    let store = Arc::new(TicketStore::with_dispatch_shards(cfg, 4));
    let task = TaskId(1);
    let n = 24usize;
    store.create_tickets(task, "lockcheck", args(n), 0);

    let mut workers = Vec::new();
    for w in 0..4u64 {
        let store = Arc::clone(&store);
        workers.push(thread::spawn(move || {
            let client = format!("client-{w}");
            for round in 0..400u64 {
                let now = round * 50;
                let got = store.next_tickets(&client, now, 4);
                for (i, t) in got.iter().enumerate() {
                    match i % 3 {
                        // Matching votes: two clients voting num(id)
                        // reach quorum and complete the ticket.
                        0 | 1 => {
                            let v = Value::num(t.id.0 as f64);
                            let _ = store.vote(&client, t.id, v, now);
                        }
                        _ => {
                            if round % 2 == 0 {
                                store.release_batch_from(&client, &[t.id]);
                            } else {
                                let _ = store.report_error_from(&client, t.id, "flaky".into());
                            }
                        }
                    }
                }
                if store.progress(Some(task)).done == n {
                    break;
                }
            }
        }));
    }
    for w in workers {
        w.join().expect("no rank-inversion panic in any worker");
    }

    // Finish any stragglers single-threaded, then collect through the
    // ledger condvar path.
    let mut now = 1_000_000u64;
    while store.progress(Some(task)).done < n {
        now += 400_000;
        for t in store.next_tickets("finisher-a", now, n) {
            let _ = store.vote("finisher-a", t.id, Value::num(t.id.0 as f64), now);
        }
        for t in store.next_tickets("finisher-b", now + 1, n) {
            let _ = store.vote("finisher-b", t.id, Value::num(t.id.0 as f64), now + 1);
        }
    }
    let results = store.wait_results_timeout(task, 10_000).expect("task done");
    assert_eq!(results.len(), n);
    let _ = store.drain_errors();
    assert_eq!(held_count(), 0);
}

/// Same discipline proof for the durable store: per-shard WAL stream
/// locks are held *across* the inner store calls (the outermost store
/// rank), the group-commit flusher thread takes stream locks from its
/// own thread, and a sharded checkpoint takes all of them plus the
/// full snapshot nesting (stripes → ledger registry → ledgers under
/// stream locks).  Recovery then replays single-threaded through the
/// same wrappers.
#[test]
fn wrapped_wal_store_sharded_suite_runs_clean() {
    let dir = temp_dir("sharded");
    let cfg = StoreConfig::default();
    let wal_cfg = WalConfig {
        sync: SyncPolicy::GroupCommitMs(5),
        checkpoint_every: 64,
        dispatch_shards: 4,
        ..WalConfig::default()
    };
    let task = TaskId(7);
    let n = 32usize;
    let done_before = {
        let store = Arc::new(WalStore::open(&dir, cfg.clone(), wal_cfg.clone()).unwrap());
        store.create_tickets(task, "wal-lockcheck", args(n), 0);
        let mut workers = Vec::new();
        for w in 0..4u64 {
            let store = Arc::clone(&store);
            workers.push(thread::spawn(move || {
                let client = format!("wal-client-{w}");
                for round in 0..200u64 {
                    let now = round * 1_000;
                    let batch = store.next_tickets(&client, now, 4);
                    if batch.is_empty() && store.progress(Some(TaskId(7))).done == 32 {
                        break;
                    }
                    let votes: Vec<_> = batch
                        .iter()
                        .map(|t| (t.id, Value::num(t.id.0 as f64)))
                        .collect();
                    if round % 5 == 4 {
                        if let Some(first) = batch.first() {
                            store.release_batch_from(&client, &[first.id]);
                        }
                    }
                    let _ = store.vote_batch(&client, votes, now);
                }
            }));
        }
        for w in workers {
            w.join().expect("no rank-inversion panic in any WAL worker");
        }
        store.checkpoint_now().unwrap();
        store.sync_now().unwrap();
        store.progress(Some(task)).done
    };

    let reopened = WalStore::open(&dir, cfg, wal_cfg).unwrap();
    assert_eq!(reopened.progress(Some(task)).done, done_before);
    assert_eq!(held_count(), 0);
    drop(reopened);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The reference store's single mutex + condvar pair through the
/// checked wrappers: a consumer blocks in `next_completion` (the rank
/// is released for the wait, re-asserted on wake) while a producer
/// completes from another thread.
#[test]
fn naive_store_condvar_paths_run_clean() {
    let store = Arc::new(NaiveStore::new(StoreConfig::default()));
    let task = TaskId(3);
    let ids = store.create_tickets(task, "naive-lockcheck", args(2), 0);

    let producer = {
        let store = Arc::clone(&store);
        let ids = ids.clone();
        thread::spawn(move || {
            for id in ids {
                let t = store.next_ticket("naive-client", 0).expect("ticket available");
                assert_eq!(t.id, id);
                store.complete(t.id, Value::num(1.0)).unwrap();
            }
        })
    };
    for _ in 0..2 {
        let got = store.next_completion(task, 10_000);
        assert!(got.is_some(), "completion arrived before the deadline");
    }
    producer.join().unwrap();
    assert!(store.wait_results_timeout(task, 10_000).is_some());
    assert_eq!(held_count(), 0);
}
