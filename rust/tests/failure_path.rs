//! The active failure path, end to end (ISSUE 5 acceptance): a worker
//! holding a prefetched batch is killed — and, separately, errors and
//! reloads — and every undone ticket it held re-enters dispatch with
//! latency bounded by the release round trip, not by the store's
//! `min_redistribute_ms`/`requeue_after_ms` windows.  With disconnect
//! release disabled, the paper's passive §2.1.2 baseline (strand until
//! the window elapses) is preserved.
//!
//! Every test runs under the paper-default redistribution windows on an
//! injected [`VirtualClock`] pinned at t=0 — store time never advances,
//! so the §2.1.2 windows *cannot* elapse and any recovered ticket is
//! *proof* the active path ran.  The passive test then advances the
//! virtual clock by hand to watch the window expire at exactly
//! VCT + `requeue_after_ms`.

use std::io::{BufRead, BufReader, Read as _, Write as _};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use sashimi::coordinator::{Distributor, DistributorConfig, Framework, Gateway, GatewayConfig};
use sashimi::store::{Scheduler as _, StoreConfig, TaskId, TicketId};
use sashimi::tasks::is_prime::IsPrimeTask;
use sashimi::tasks::{TaskContext, TaskDef, TaskOutput};
use sashimi::transport::framing::{Framing as _, Inbound};
use sashimi::transport::ws::{self, WsFraming};
use sashimi::transport::{local, Conn, LinkModel, Message};
use sashimi::util::clock::VirtualClock;
use sashimi::util::json::Value;
use sashimi::util::rng::SplitMix64;
use sashimi::worker::{DeviceProfile, Worker};

/// A framework on the paper-default store windows whose clock is a
/// virtual one pinned at 0: tickets dispatch at VCT 0, and no
/// redistribution window can elapse unless a test advances the clock.
fn pinned_fw() -> (Arc<Framework>, Arc<VirtualClock>) {
    let vclock = Arc::new(VirtualClock::new());
    let fw = Framework::builder().clock(vclock.clone()).build();
    (fw, vclock)
}

fn prime_fw(n: usize) -> (Arc<Framework>, TaskId, Arc<VirtualClock>) {
    let (fw, vclock) = pinned_fw();
    let task = fw.create_task(Arc::new(IsPrimeTask));
    task.calculate(
        (0..n).map(|i| Value::obj(vec![("candidate", Value::num(i as f64 + 2.0))])).collect(),
    );
    let id = task.id;
    (fw, id, vclock)
}

/// A worker holding a prefetched batch is killed (connection dropped,
/// no shutdown, no reports): the whole batch is released on disconnect
/// and a healthy worker finishes the project with store time pinned at
/// 0 — the redistribution windows never get a chance to elapse.
#[test]
fn killed_workers_prefetched_batch_is_redispatched_immediately() {
    let (fw, task_id, _vclock) = prime_fw(8);
    let dist = Distributor::new(&fw);
    let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
    dist.serve(Box::new(listener));

    // The victim takes a 4-ticket batch over the raw protocol, then its
    // "browser" dies.
    let mut victim = connector.connect().unwrap();
    victim.send(&Message::Hello { client: "victim".into(), profile: "t".into() }).unwrap();
    assert!(matches!(victim.recv().unwrap(), Message::Ack));
    victim.send(&Message::TicketBatchRequest { max: 4 }).unwrap();
    match victim.recv().unwrap() {
        Message::Tickets { tickets } => assert_eq!(tickets.len(), 4),
        m => panic!("expected tickets, got {m:?}"),
    }
    assert_eq!(fw.store().progress(None).in_flight, 4);
    drop(victim);

    // A healthy worker must finish all 8 tickets within the test
    // horizon — impossible through windows that never elapse, trivial
    // through the release path.
    let stop = Arc::new(AtomicBool::new(false));
    let worker = {
        let connector = connector.clone();
        let registry = fw.registry_snapshot();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut w = Worker::new("healthy", DeviceProfile::native(), registry);
            w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop)
        })
    };
    let results =
        fw.store().wait_results_timeout(task_id, 20_000).expect("released tickets must finish");
    stop.store(true, Ordering::SeqCst);
    let report = worker.join().unwrap();
    assert_eq!(results.len(), 8);
    assert_eq!(report.tickets_completed, 8);
    let p = fw.store().progress(None);
    assert_eq!(p.done, 8);
    assert!(p.redistributions >= 4, "released tickets re-dispatch: {p:?}");
    assert_eq!(dist.stats.tickets_released.load(Ordering::Relaxed), 4);
    assert_eq!(p.errors, 0, "a kill is not an error report");
}

/// Fails the first execution of every ticket (a transient browser
/// fault), succeeds on the retry.
struct FailsOnceEach {
    failed: std::sync::Mutex<std::collections::HashSet<u64>>,
}

impl TaskDef for FailsOnceEach {
    fn name(&self) -> &str {
        "fails_once_each"
    }
    fn execute(&self, input: &Value, _: &mut dyn TaskContext) -> anyhow::Result<TaskOutput> {
        let n = input.get("n")?.as_u64()?;
        if self.failed.lock().unwrap().insert(n) {
            anyhow::bail!("transient failure on {n}");
        }
        Ok(TaskOutput::new(Value::num(n as f64)))
    }
}

/// The errors-and-reloads half of the acceptance case: every ticket
/// fails once, the worker flushes batched reports (one Reload round
/// trip per failing batch), every errored ticket requeues at its
/// creation-time VCT, and the project still completes with store time
/// pinned at 0 (error requeue does not wait on any window).
#[test]
fn erroring_worker_flushes_batched_reports_and_finishes() {
    let (fw, _vclock) = pinned_fw();
    let task = fw.create_task(Arc::new(FailsOnceEach { failed: Default::default() }));
    task.calculate((0..6).map(|i| Value::obj(vec![("n", Value::num(i as f64))])).collect());
    let task_id = task.id;
    let dist = Distributor::new(&fw);
    let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
    dist.serve(Box::new(listener));
    let stop = Arc::new(AtomicBool::new(false));
    let worker = {
        let connector = connector.clone();
        let registry = fw.registry_snapshot();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut w = Worker::new("flaky", DeviceProfile::native(), registry);
            w.max_tickets = Some(6);
            w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop)
        })
    };
    let results =
        fw.store().wait_results_timeout(task_id, 20_000).expect("errored tickets requeue at once");
    stop.store(true, Ordering::SeqCst);
    let report = worker.join().unwrap();
    assert_eq!(results.len(), 6);
    assert_eq!(report.errors_reported, 6, "every ticket failed exactly once");
    assert!(
        report.reloads >= 1 && report.reloads <= report.errors_reported,
        "one reload per failing batch, never per failure: {} reloads",
        report.reloads
    );
    assert_eq!(fw.store().error_count(), 6);
    assert_eq!(fw.store().progress(None).done, 6);
}

/// Disconnect release disabled: the passive paper baseline.  The killed
/// worker's batch stays stranded in flight, nothing is served while the
/// virtual clock sits inside the redistribution window — and the moment
/// it reaches VCT + `requeue_after_ms`, the stranded batch re-enters
/// dispatch (the §2.1.2 window expiry, end to end over a connection).
#[test]
fn disabled_disconnect_release_preserves_passive_stranding() {
    let (fw, _, vclock) = prime_fw(2);
    let dist = Distributor::new_with(
        &fw,
        DistributorConfig { release_on_disconnect: false, ..Default::default() },
    );
    let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
    dist.serve(Box::new(listener));

    let mut victim = connector.connect().unwrap();
    victim.send(&Message::Hello { client: "victim".into(), profile: "t".into() }).unwrap();
    assert!(matches!(victim.recv().unwrap(), Message::Ack));
    victim.send(&Message::TicketBatchRequest { max: 2 }).unwrap();
    match victim.recv().unwrap() {
        Message::Tickets { tickets } => assert_eq!(tickets.len(), 2),
        m => panic!("expected tickets, got {m:?}"),
    }
    drop(victim);
    // Wait until the handler has noticed the disconnect.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while dist.stats.clients_disconnected.load(Ordering::Relaxed) == 0 {
        assert!(std::time::Instant::now() < deadline, "handler never exited");
        sashimi::util::clock::sleep_ms(2);
    }
    assert_eq!(dist.stats.tickets_released.load(Ordering::Relaxed), 0);
    let p = fw.store().progress(None);
    assert_eq!((p.pending, p.in_flight), (0, 2), "passive baseline strands the batch");

    let mut probe = connector.connect().unwrap();
    probe.send(&Message::Hello { client: "probe".into(), profile: "t".into() }).unwrap();
    assert!(matches!(probe.recv().unwrap(), Message::Ack));
    probe.send(&Message::TicketRequest).unwrap();
    assert!(
        matches!(probe.recv().unwrap(), Message::NoTicket { .. }),
        "stranded tickets must wait out the window"
    );

    // One tick before the window: still stranded.
    let window = StoreConfig::default().requeue_after_ms;
    vclock.advance_to(window - 1);
    probe.send(&Message::TicketRequest).unwrap();
    assert!(
        matches!(probe.recv().unwrap(), Message::NoTicket { .. }),
        "the window must not expire a tick early"
    );

    // Exactly at VCT + requeue_after_ms the whole batch re-dispatches.
    vclock.advance_to(window);
    probe.send(&Message::TicketBatchRequest { max: 4 }).unwrap();
    match probe.recv().unwrap() {
        Message::Tickets { tickets } => {
            assert_eq!(tickets.len(), 2, "window expiry re-dispatches the stranded batch")
        }
        m => panic!("expected the stranded batch back, got {m:?}"),
    }
    let p = fw.store().progress(None);
    assert_eq!(p.redistributions, 2, "each stranded ticket redistributed once: {p:?}");
    probe.send(&Message::Shutdown).unwrap();
}

/// Ten-millisecond tickets so a stop lands mid-batch.
struct SlowTask;

impl TaskDef for SlowTask {
    fn name(&self) -> &str {
        "slow"
    }
    fn execute(&self, _input: &Value, _: &mut dyn TaskContext) -> anyhow::Result<TaskOutput> {
        sashimi::util::clock::sleep_ms(10);
        Ok(TaskOutput::new(Value::Bool(true)))
    }
}

/// A worker stopped mid-batch strands nothing: finished work is
/// flushed, the unexecuted queue is explicitly released (and whatever
/// the server still tracked is released on disconnect), so no ticket
/// is left in flight against windows that never elapse.
#[test]
fn stopped_worker_leaves_nothing_in_flight() {
    let (fw, _vclock) = pinned_fw();
    let task = fw.create_task(Arc::new(SlowTask));
    task.calculate((0..16).map(|i| Value::num(i as f64)).collect());
    let dist = Distributor::new(&fw);
    // A latency-priced link (really slept) so the adaptive batch grows
    // and the worker actually holds a multi-ticket queue when stopped.
    let (listener, connector) =
        local::endpoint(LinkModel { latency_ms: 20.0, bytes_per_ms: 100_000.0 }, true);
    dist.serve(Box::new(listener));
    let stop = Arc::new(AtomicBool::new(false));
    let worker = {
        let connector = connector.clone();
        let registry = fw.registry_snapshot();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut w = Worker::new("w", DeviceProfile::native(), registry)
                .with_prefetch_cap(8);
            w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop)
        })
    };
    sashimi::util::clock::sleep_ms(300);
    stop.store(true, Ordering::SeqCst);
    let report = worker.join().unwrap();
    let p = fw.store().progress(None);
    assert_eq!(p.in_flight, 0, "a stopping worker must strand nothing: {p:?}");
    assert_eq!(p.done as u64, report.tickets_completed, "acked flushes match the store");
    assert_eq!(p.done + p.pending, 16);
}

// ---------------------------------------------------------------------
// Gateway fault injection (ISSUE 8): misbehaving peers against the
// epoll gateway.  The store clock stays pinned at virtual 0 — its
// redistribution windows can never elapse — while the gateway's
// heartbeats run on the wall clock, so every recovered ticket below is
// proof of the dead-peer detection path, not of a window.

/// A pinned-store framework with `n` prime tickets behind a gateway
/// (one TCP or one WS listener) with the given heartbeat.
fn gateway_fixture(
    n: usize,
    heartbeat_ms: u64,
    ws: bool,
) -> (Arc<Framework>, Arc<Distributor>, Arc<Gateway>) {
    let (fw, _task, _vclock) = prime_fw(n);
    let dist = Distributor::new(&fw);
    let (tcp, wsl) = if ws { (None, Some("127.0.0.1:0")) } else { (Some("127.0.0.1:0"), None) };
    let gw = Gateway::bind(&dist, GatewayConfig { heartbeat_ms }, tcp, wsl).unwrap();
    (fw, dist, gw)
}

fn send_line(s: &mut TcpStream, m: &Message) {
    s.write_all(format!("{}\n", m.encode()).as_bytes()).unwrap();
}

fn recv_line(r: &mut BufReader<TcpStream>) -> Message {
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    Message::decode(line.trim_end()).unwrap()
}

/// Poll until `released()` on the distributor reaches `want`; returns
/// the elapsed wall time since `t0`.
fn await_release(dist: &Distributor, want: u64, t0: Instant, deadline_ms: u64) -> Duration {
    let deadline = t0 + Duration::from_millis(deadline_ms);
    loop {
        if dist.stats.tickets_released.load(Ordering::Relaxed) >= want {
            return t0.elapsed();
        }
        assert!(
            Instant::now() < deadline,
            "release never happened (released {} of {want})",
            dist.stats.tickets_released.load(Ordering::Relaxed)
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// A compliant peer that takes a batch and then falls silent — no FIN,
/// no frames, socket held open (the yanked-cable / suspended-laptop
/// shape).  Its held tickets must release within 2× the heartbeat
/// (plus sweep granularity and CI scheduling slack), and never before
/// the silence threshold — the acceptance pin for ISSUE 8.
#[test]
fn silent_tcp_peer_releases_within_two_heartbeats() {
    const HB: u64 = 500;
    let (fw, dist, gw) = gateway_fixture(8, HB, false);
    let mut s = TcpStream::connect(gw.tcp_addr().unwrap()).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    send_line(&mut s, &Message::Hello { client: "zombie".into(), profile: "t".into() });
    assert!(matches!(recv_line(&mut r), Message::Ack));
    send_line(&mut s, &Message::TicketBatchRequest { max: 4 });
    match recv_line(&mut r) {
        Message::Tickets { tickets } => assert_eq!(tickets.len(), 4),
        m => panic!("expected tickets, got {m:?}"),
    }
    assert_eq!(fw.store().progress(None).in_flight, 4);
    let t0 = Instant::now();
    // ... and now: nothing.  The socket stays open and silent.
    let elapsed = await_release(&dist, 4, t0, 15_000);
    assert!(
        elapsed.as_millis() as u64 >= 2 * HB - 150,
        "killed {}ms after last traffic — before the 2×{HB}ms silence threshold",
        elapsed.as_millis()
    );
    assert!(
        elapsed.as_millis() as u64 <= 2 * HB + 3_000,
        "released only after {}ms — outside the 2×heartbeat window (+CI slack)",
        elapsed.as_millis()
    );
    assert!(gw.stats.dead_peer_kills.load(Ordering::Relaxed) >= 1);
    let p = fw.store().progress(None);
    assert_eq!((p.in_flight, p.pending), (0, 8), "the whole batch re-entered dispatch");
    gw.shutdown();
}

/// Half-close: the peer shuts down its write side (FIN) while holding
/// tickets.  EOF detection — not the heartbeat timer — must release:
/// the heartbeat here is a minute, the release must land in seconds.
#[test]
fn half_closed_peer_releases_on_eof_not_heartbeat() {
    let (fw, dist, gw) = gateway_fixture(4, 60_000, false);
    let mut s = TcpStream::connect(gw.tcp_addr().unwrap()).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    send_line(&mut s, &Message::Hello { client: "half".into(), profile: "t".into() });
    assert!(matches!(recv_line(&mut r), Message::Ack));
    send_line(&mut s, &Message::TicketBatchRequest { max: 2 });
    match recv_line(&mut r) {
        Message::Tickets { tickets } => assert_eq!(tickets.len(), 2),
        m => panic!("expected tickets, got {m:?}"),
    }
    let t0 = Instant::now();
    s.shutdown(std::net::Shutdown::Write).unwrap();
    let elapsed = await_release(&dist, 2, t0, 10_000);
    assert!(
        elapsed.as_millis() < 5_000,
        "EOF release took {}ms — it must not wait for the 60s heartbeat",
        elapsed.as_millis()
    );
    assert_eq!(gw.stats.dead_peer_kills.load(Ordering::Relaxed), 0, "EOF is not a timeout kill");
    assert_eq!(fw.store().progress(None).in_flight, 0);
    gw.shutdown();
}

/// Garbage on the JSON-lines wire after taking a ticket: the gateway
/// must classify it as a protocol error, kill the connection, and
/// release the held ticket.
#[test]
fn garbage_tcp_line_kills_and_releases() {
    let (fw, dist, gw) = gateway_fixture(4, 60_000, false);
    let mut s = TcpStream::connect(gw.tcp_addr().unwrap()).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    send_line(&mut s, &Message::Hello { client: "garbler".into(), profile: "t".into() });
    assert!(matches!(recv_line(&mut r), Message::Ack));
    send_line(&mut s, &Message::TicketRequest);
    assert!(matches!(recv_line(&mut r), Message::Ticket { .. }));
    let t0 = Instant::now();
    s.write_all(b"!!!this is not a protocol document!!!\n").unwrap();
    await_release(&dist, 1, t0, 10_000);
    assert!(gw.stats.protocol_errors.load(Ordering::Relaxed) >= 1);
    assert_eq!(fw.store().progress(None).in_flight, 0);
    gw.shutdown();
}

/// A raw WebSocket client built from the `transport::ws` pieces, so
/// tests can misbehave below the `Conn` abstraction: send partial
/// frames, invalid frames, or nothing at all.
struct RawWs {
    stream: TcpStream,
    framing: WsFraming,
    inbuf: Vec<u8>,
}

impl RawWs {
    fn connect(hostport: &str) -> RawWs {
        let mut stream = TcpStream::connect(hostport).unwrap();
        let mut rng = SplitMix64::new(0xFA17);
        let (req, key) = ws::client_handshake_request(hostport, "/", &mut rng);
        stream.write_all(req.as_bytes()).unwrap();
        let mut buf = Vec::new();
        let end = loop {
            if let Some(end) = ws::find_header_end(&buf) {
                break end;
            }
            let mut tmp = [0u8; 4096];
            let n = stream.read(&mut tmp).unwrap();
            assert!(n > 0, "EOF during ws handshake");
            buf.extend_from_slice(&tmp[..n]);
        };
        let head = String::from_utf8_lossy(&buf[..end]).into_owned();
        assert!(head.lines().next().unwrap().contains(" 101"), "upgrade refused: {head}");
        assert!(head.contains(&ws::accept_key_for(&key)), "bad accept proof");
        let inbuf = buf[end..].to_vec();
        RawWs { stream, framing: WsFraming::client(0xFA17), inbuf }
    }

    fn send(&mut self, m: &Message) {
        let f = self.framing.frame_msg(&m.encode());
        self.stream.write_all(&f).unwrap();
    }

    fn send_raw(&mut self, bytes: &[u8]) {
        self.stream.write_all(bytes).unwrap();
    }

    fn recv(&mut self) -> Message {
        loop {
            match self.framing.extract(&mut self.inbuf).unwrap() {
                Some(Inbound::Msg(doc)) => return Message::decode(&doc).unwrap(),
                Some(Inbound::Ping(p)) => {
                    let f = self.framing.frame_pong(&p);
                    self.stream.write_all(&f).unwrap();
                }
                Some(Inbound::Pong) => {}
                Some(Inbound::Close) => panic!("server closed mid-script"),
                None => {
                    let mut tmp = [0u8; 4096];
                    let n = self.stream.read(&mut tmp).unwrap();
                    assert!(n > 0, "server EOF mid-script");
                    self.inbuf.extend_from_slice(&tmp[..n]);
                }
            }
        }
    }
}

/// A WebSocket peer that stalls mid-frame: it sends the first bytes of
/// a valid text frame and then nothing.  The gateway cannot complete
/// the frame; the heartbeat must kill it and release its tickets
/// within the 2× window.
#[test]
fn ws_peer_stalled_mid_frame_releases_within_two_heartbeats() {
    const HB: u64 = 500;
    let (fw, dist, gw) = gateway_fixture(8, HB, true);
    let mut c = RawWs::connect(&gw.ws_addr().unwrap());
    c.send(&Message::Hello { client: "staller".into(), profile: "t".into() });
    assert!(matches!(c.recv(), Message::Ack));
    c.send(&Message::TicketBatchRequest { max: 3 });
    match c.recv() {
        Message::Tickets { tickets } => assert_eq!(tickets.len(), 3),
        m => panic!("expected tickets, got {m:?}"),
    }
    let frame = c.framing.frame_msg(&Message::TicketRequest.encode());
    let t0 = Instant::now();
    c.send_raw(&frame[..frame.len() / 2]); // ...and the rest never comes
    let elapsed = await_release(&dist, 3, t0, 15_000);
    assert!(
        elapsed.as_millis() as u64 <= 2 * HB + 3_000,
        "stalled frame released only after {}ms",
        elapsed.as_millis()
    );
    assert!(gw.stats.dead_peer_kills.load(Ordering::Relaxed) >= 1);
    assert_eq!(fw.store().progress(None).in_flight, 0);
    gw.shutdown();
}

// ---------------------------------------------------------------------
// Byzantine workers (DESIGN.md §2.8): quorum result verification end to
// end over real connections.  The store clock stays pinned at virtual 0
// throughout — no redistribution window can elapse — so every decided
// ticket below is proof of the vote machinery, and every refused
// request proof of the quarantine gate.

/// A pinned-clock framework with `n` prime tickets verified at the
/// given replication/quorum, served by a distributor over a local
/// endpoint.
fn byzantine_fixture(
    n: usize,
    replication: u32,
    quorum: u32,
) -> (Arc<Framework>, TaskId, Arc<Distributor>, sashimi::transport::local::LocalConnector) {
    let vclock = Arc::new(VirtualClock::new());
    let fw = Framework::builder()
        .clock(vclock)
        .store_config(StoreConfig { replication, quorum, ..StoreConfig::default() })
        .build();
    let task = fw.create_task(Arc::new(IsPrimeTask));
    task.calculate(
        (0..n).map(|i| Value::obj(vec![("candidate", Value::num(i as f64 + 2.0))])).collect(),
    );
    let id = task.id;
    let dist = Distributor::new(&fw);
    let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
    dist.serve(Box::new(listener));
    (fw, id, dist, connector)
}

fn hello(connector: &sashimi::transport::local::LocalConnector, name: &str) -> local::LocalConn {
    let mut c = connector.connect().unwrap();
    c.send(&Message::Hello { client: name.into(), profile: "t".into() }).unwrap();
    assert!(matches!(c.recv().unwrap(), Message::Ack));
    c
}

fn take_one(c: &mut local::LocalConn) -> TicketId {
    c.send(&Message::TicketRequest).unwrap();
    match c.recv().unwrap() {
        Message::Ticket { ticket, .. } => ticket,
        m => panic!("expected a ticket, got {m:?}"),
    }
}

fn vote(c: &mut local::LocalConn, ticket: TicketId, result: Value) {
    c.send(&Message::TicketResult { ticket, result }).unwrap();
    assert!(matches!(c.recv().unwrap(), Message::Ack));
}

/// One liar against an honest quorum at R = 3, Q = 2: the divergence
/// recruits a fresh tie-breaker, the honest pair decides the ticket,
/// the fabrication never completes anything, and the outvoted liar is
/// flagged and quarantined — end to end over connections.
#[test]
fn byzantine_minority_is_outvoted_end_to_end() {
    let (fw, task_id, dist, connector) = byzantine_fixture(1, 3, 2);
    let mut liar = hello(&connector, "liar");
    let mut h1 = hello(&connector, "h1");
    let mut h2 = hello(&connector, "h2");

    let t = take_one(&mut liar);
    assert_eq!(take_one(&mut h1), t, "a verifying store recruits a second replica at once");

    vote(&mut liar, t, Value::Bool(false)); // the fabrication
    vote(&mut h1, t, Value::Bool(true));
    assert_eq!(fw.store().progress(None).done, 0, "a 1-1 split must not decide");

    // The divergence escalates: one fresh client is recruited.
    assert_eq!(take_one(&mut h2), t, "divergence recruits a tie-breaker");
    vote(&mut h2, t, Value::Bool(true));

    let results = fw.store().wait_results_timeout(task_id, 5_000).unwrap();
    assert_eq!(results, vec![Value::Bool(true)], "the honest quorum's value wins");
    let vs = fw.store().verify_stats();
    assert_eq!((vs.verdicts, vs.votes_flagged), (1, 1));
    assert_eq!((vs.escalations, vs.quarantines), (1, 1));
    assert_eq!(fw.store().quarantined_clients(), vec!["liar".to_string()]);

    // The liar is served NoTicket for the rest of its probation.
    liar.send(&Message::TicketRequest).unwrap();
    assert!(matches!(liar.recv().unwrap(), Message::NoTicket { .. }));
    assert_eq!(dist.stats.noticket_quarantined.load(Ordering::Relaxed), 1);
}

/// A client that prefetches a batch, answers one ticket wrongly enough
/// to be quarantined, and sits on the rest: its next request is refused
/// AND its held tickets are reclaimed in the same round trip, re-entering
/// dispatch immediately (the PR 5 release path, driven by quarantine).
#[test]
fn quarantined_clients_held_tickets_release_on_its_next_request() {
    let (fw, task_id, dist, connector) = byzantine_fixture(2, 3, 2);
    let mut sly = hello(&connector, "sly");
    let mut h1 = hello(&connector, "h1");
    let mut h2 = hello(&connector, "h2");

    // sly prefetches both tickets, lies on the second, holds the first.
    sly.send(&Message::TicketBatchRequest { max: 2 }).unwrap();
    let (t0, t1) = match sly.recv().unwrap() {
        Message::Tickets { tickets } => {
            assert_eq!(tickets.len(), 2);
            (tickets[0].ticket, tickets[1].ticket)
        }
        m => panic!("expected tickets, got {m:?}"),
    };
    vote(&mut sly, t1, Value::Bool(false));

    // The honest pair outvotes sly on t1; sly lands in quarantine.
    assert_eq!(take_one(&mut h1), t0, "both tickets are still recruiting; lowest id first");
    vote(&mut h1, t0, Value::Bool(true));
    assert_eq!(take_one(&mut h2), t1);
    vote(&mut h2, t1, Value::Bool(true));
    assert_eq!(take_one(&mut h1), t1, "the t1 divergence recruits h1 as tie-breaker");
    vote(&mut h1, t1, Value::Bool(true));
    assert_eq!(fw.store().quarantined_clients(), vec!["sly".to_string()]);

    // One request from quarantine: refused, and the held t0 reclaimed.
    let released_before = dist.stats.tickets_released.load(Ordering::Relaxed);
    sly.send(&Message::TicketRequest).unwrap();
    assert!(matches!(sly.recv().unwrap(), Message::NoTicket { .. }));
    assert_eq!(
        dist.stats.tickets_released.load(Ordering::Relaxed),
        released_before + 1,
        "the quarantined client's held ticket is reclaimed in the refusing round trip"
    );

    // The reclaimed ticket is immediately dispatchable to honest peers.
    assert_eq!(take_one(&mut h2), t0, "the reclaimed ticket re-enters dispatch at once");
    vote(&mut h2, t0, Value::Bool(true));
    let results = fw.store().wait_results_timeout(task_id, 5_000).unwrap();
    assert_eq!(results, vec![Value::Bool(true), Value::Bool(true)]);
}

/// A colluding pair voting one identical fabrication at R = 3 with
/// quorum 3: two matching lies stay below quorum forever, each full
/// undecided round recruits another fresh client, and the honest
/// majority eventually decides — both colluders flagged and
/// quarantined, their value never completing the ticket.
#[test]
fn colluding_pair_below_quorum_never_completes() {
    let (fw, task_id, dist, connector) = byzantine_fixture(1, 3, 3);
    let mut c1 = hello(&connector, "c1");
    let mut c2 = hello(&connector, "c2");
    let mut h1 = hello(&connector, "h1");
    let mut h2 = hello(&connector, "h2");
    let mut h3 = hello(&connector, "h3");

    let t = take_one(&mut c1);
    assert_eq!(take_one(&mut c2), t);
    assert_eq!(take_one(&mut h1), t);

    // The colluders agree with each other — and stay below quorum.
    vote(&mut c1, t, Value::Bool(false));
    vote(&mut c2, t, Value::Bool(false));
    assert_eq!(
        fw.store().progress(None).done,
        0,
        "two matching fabrications below quorum must not complete the ticket"
    );

    // Each full undecided round recruits one more fresh client until
    // the honest side reaches quorum.
    vote(&mut h1, t, Value::Bool(true));
    assert_eq!(take_one(&mut h2), t);
    vote(&mut h2, t, Value::Bool(true));
    assert_eq!(take_one(&mut h3), t);
    vote(&mut h3, t, Value::Bool(true));

    let results = fw.store().wait_results_timeout(task_id, 5_000).unwrap();
    assert_eq!(results, vec![Value::Bool(true)]);
    let vs = fw.store().verify_stats();
    assert_eq!(vs.verdicts, 1);
    assert_eq!(vs.votes_flagged, 2, "both colluders flagged by the verdict");
    assert_eq!(vs.escalations, 2, "two full undecided rounds each recruited a tie-breaker");
    assert_eq!(vs.quarantines, 2);
    assert_eq!(
        fw.store().quarantined_clients(),
        vec!["c1".to_string(), "c2".to_string()]
    );
    for c in [&mut c1, &mut c2] {
        c.send(&Message::TicketRequest).unwrap();
        assert!(matches!(c.recv().unwrap(), Message::NoTicket { .. }));
    }
    assert_eq!(dist.stats.noticket_quarantined.load(Ordering::Relaxed), 2);
}

/// A WebSocket frame with RSV bits set (no extension was negotiated)
/// is a protocol violation: immediate kill + release, no heartbeat
/// involved.
#[test]
fn ws_garbage_frame_kills_and_releases() {
    let (fw, dist, gw) = gateway_fixture(4, 60_000, true);
    let mut c = RawWs::connect(&gw.ws_addr().unwrap());
    c.send(&Message::Hello { client: "ws-garbler".into(), profile: "t".into() });
    assert!(matches!(c.recv(), Message::Ack));
    c.send(&Message::TicketRequest);
    assert!(matches!(c.recv(), Message::Ticket { .. }));
    let t0 = Instant::now();
    c.send_raw(&[0xF2, 0x00]); // FIN + RSV1..3 set, binary, empty
    let elapsed = await_release(&dist, 1, t0, 10_000);
    assert!(
        elapsed.as_millis() < 5_000,
        "protocol-error release took {}ms — it must not wait for the 60s heartbeat",
        elapsed.as_millis()
    );
    assert!(gw.stats.protocol_errors.load(Ordering::Relaxed) >= 1);
    assert_eq!(fw.store().progress(None).in_flight, 0);
    gw.shutdown();
}
