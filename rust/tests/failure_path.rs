//! The active failure path, end to end (ISSUE 5 acceptance): a worker
//! holding a prefetched batch is killed — and, separately, errors and
//! reloads — and every undone ticket it held re-enters dispatch with
//! latency bounded by the release round trip, not by the store's
//! `min_redistribute_ms`/`requeue_after_ms` windows.  With disconnect
//! release disabled, the paper's passive §2.1.2 baseline (strand until
//! the window elapses) is preserved.
//!
//! Every test runs under the paper-default redistribution windows on an
//! injected [`VirtualClock`] pinned at t=0 — store time never advances,
//! so the §2.1.2 windows *cannot* elapse and any recovered ticket is
//! *proof* the active path ran.  The passive test then advances the
//! virtual clock by hand to watch the window expire at exactly
//! VCT + `requeue_after_ms`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use sashimi::coordinator::{Distributor, DistributorConfig, Framework};
use sashimi::store::{Scheduler as _, StoreConfig, TaskId};
use sashimi::tasks::is_prime::IsPrimeTask;
use sashimi::tasks::{TaskContext, TaskDef, TaskOutput};
use sashimi::transport::{local, Conn, LinkModel, Message};
use sashimi::util::clock::VirtualClock;
use sashimi::util::json::Value;
use sashimi::worker::{DeviceProfile, Worker};

/// A framework on the paper-default store windows whose clock is a
/// virtual one pinned at 0: tickets dispatch at VCT 0, and no
/// redistribution window can elapse unless a test advances the clock.
fn pinned_fw() -> (Arc<Framework>, Arc<VirtualClock>) {
    let vclock = Arc::new(VirtualClock::new());
    let fw = Framework::builder().clock(vclock.clone()).build();
    (fw, vclock)
}

fn prime_fw(n: usize) -> (Arc<Framework>, TaskId, Arc<VirtualClock>) {
    let (fw, vclock) = pinned_fw();
    let task = fw.create_task(Arc::new(IsPrimeTask));
    task.calculate(
        (0..n).map(|i| Value::obj(vec![("candidate", Value::num(i as f64 + 2.0))])).collect(),
    );
    let id = task.id;
    (fw, id, vclock)
}

/// A worker holding a prefetched batch is killed (connection dropped,
/// no shutdown, no reports): the whole batch is released on disconnect
/// and a healthy worker finishes the project with store time pinned at
/// 0 — the redistribution windows never get a chance to elapse.
#[test]
fn killed_workers_prefetched_batch_is_redispatched_immediately() {
    let (fw, task_id, _vclock) = prime_fw(8);
    let dist = Distributor::new(&fw);
    let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
    dist.serve(Box::new(listener));

    // The victim takes a 4-ticket batch over the raw protocol, then its
    // "browser" dies.
    let mut victim = connector.connect().unwrap();
    victim.send(&Message::Hello { client: "victim".into(), profile: "t".into() }).unwrap();
    assert!(matches!(victim.recv().unwrap(), Message::Ack));
    victim.send(&Message::TicketBatchRequest { max: 4 }).unwrap();
    match victim.recv().unwrap() {
        Message::Tickets { tickets } => assert_eq!(tickets.len(), 4),
        m => panic!("expected tickets, got {m:?}"),
    }
    assert_eq!(fw.store().progress(None).in_flight, 4);
    drop(victim);

    // A healthy worker must finish all 8 tickets within the test
    // horizon — impossible through windows that never elapse, trivial
    // through the release path.
    let stop = Arc::new(AtomicBool::new(false));
    let worker = {
        let connector = connector.clone();
        let registry = fw.registry_snapshot();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut w = Worker::new("healthy", DeviceProfile::native(), registry);
            w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop)
        })
    };
    let results =
        fw.store().wait_results_timeout(task_id, 20_000).expect("released tickets must finish");
    stop.store(true, Ordering::SeqCst);
    let report = worker.join().unwrap();
    assert_eq!(results.len(), 8);
    assert_eq!(report.tickets_completed, 8);
    let p = fw.store().progress(None);
    assert_eq!(p.done, 8);
    assert!(p.redistributions >= 4, "released tickets re-dispatch: {p:?}");
    assert_eq!(dist.stats.tickets_released.load(Ordering::Relaxed), 4);
    assert_eq!(p.errors, 0, "a kill is not an error report");
}

/// Fails the first execution of every ticket (a transient browser
/// fault), succeeds on the retry.
struct FailsOnceEach {
    failed: std::sync::Mutex<std::collections::HashSet<u64>>,
}

impl TaskDef for FailsOnceEach {
    fn name(&self) -> &str {
        "fails_once_each"
    }
    fn execute(&self, input: &Value, _: &mut dyn TaskContext) -> anyhow::Result<TaskOutput> {
        let n = input.get("n")?.as_u64()?;
        if self.failed.lock().unwrap().insert(n) {
            anyhow::bail!("transient failure on {n}");
        }
        Ok(TaskOutput::new(Value::num(n as f64)))
    }
}

/// The errors-and-reloads half of the acceptance case: every ticket
/// fails once, the worker flushes batched reports (one Reload round
/// trip per failing batch), every errored ticket requeues at its
/// creation-time VCT, and the project still completes with store time
/// pinned at 0 (error requeue does not wait on any window).
#[test]
fn erroring_worker_flushes_batched_reports_and_finishes() {
    let (fw, _vclock) = pinned_fw();
    let task = fw.create_task(Arc::new(FailsOnceEach { failed: Default::default() }));
    task.calculate((0..6).map(|i| Value::obj(vec![("n", Value::num(i as f64))])).collect());
    let task_id = task.id;
    let dist = Distributor::new(&fw);
    let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
    dist.serve(Box::new(listener));
    let stop = Arc::new(AtomicBool::new(false));
    let worker = {
        let connector = connector.clone();
        let registry = fw.registry_snapshot();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut w = Worker::new("flaky", DeviceProfile::native(), registry);
            w.max_tickets = Some(6);
            w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop)
        })
    };
    let results =
        fw.store().wait_results_timeout(task_id, 20_000).expect("errored tickets requeue at once");
    stop.store(true, Ordering::SeqCst);
    let report = worker.join().unwrap();
    assert_eq!(results.len(), 6);
    assert_eq!(report.errors_reported, 6, "every ticket failed exactly once");
    assert!(
        report.reloads >= 1 && report.reloads <= report.errors_reported,
        "one reload per failing batch, never per failure: {} reloads",
        report.reloads
    );
    assert_eq!(fw.store().error_count(), 6);
    assert_eq!(fw.store().progress(None).done, 6);
}

/// Disconnect release disabled: the passive paper baseline.  The killed
/// worker's batch stays stranded in flight, nothing is served while the
/// virtual clock sits inside the redistribution window — and the moment
/// it reaches VCT + `requeue_after_ms`, the stranded batch re-enters
/// dispatch (the §2.1.2 window expiry, end to end over a connection).
#[test]
fn disabled_disconnect_release_preserves_passive_stranding() {
    let (fw, _, vclock) = prime_fw(2);
    let dist = Distributor::new_with(
        &fw,
        DistributorConfig { release_on_disconnect: false, ..Default::default() },
    );
    let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
    dist.serve(Box::new(listener));

    let mut victim = connector.connect().unwrap();
    victim.send(&Message::Hello { client: "victim".into(), profile: "t".into() }).unwrap();
    assert!(matches!(victim.recv().unwrap(), Message::Ack));
    victim.send(&Message::TicketBatchRequest { max: 2 }).unwrap();
    match victim.recv().unwrap() {
        Message::Tickets { tickets } => assert_eq!(tickets.len(), 2),
        m => panic!("expected tickets, got {m:?}"),
    }
    drop(victim);
    // Wait until the handler has noticed the disconnect.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while dist.stats.clients_disconnected.load(Ordering::Relaxed) == 0 {
        assert!(std::time::Instant::now() < deadline, "handler never exited");
        sashimi::util::clock::sleep_ms(2);
    }
    assert_eq!(dist.stats.tickets_released.load(Ordering::Relaxed), 0);
    let p = fw.store().progress(None);
    assert_eq!((p.pending, p.in_flight), (0, 2), "passive baseline strands the batch");

    let mut probe = connector.connect().unwrap();
    probe.send(&Message::Hello { client: "probe".into(), profile: "t".into() }).unwrap();
    assert!(matches!(probe.recv().unwrap(), Message::Ack));
    probe.send(&Message::TicketRequest).unwrap();
    assert!(
        matches!(probe.recv().unwrap(), Message::NoTicket { .. }),
        "stranded tickets must wait out the window"
    );

    // One tick before the window: still stranded.
    let window = StoreConfig::default().requeue_after_ms;
    vclock.advance_to(window - 1);
    probe.send(&Message::TicketRequest).unwrap();
    assert!(
        matches!(probe.recv().unwrap(), Message::NoTicket { .. }),
        "the window must not expire a tick early"
    );

    // Exactly at VCT + requeue_after_ms the whole batch re-dispatches.
    vclock.advance_to(window);
    probe.send(&Message::TicketBatchRequest { max: 4 }).unwrap();
    match probe.recv().unwrap() {
        Message::Tickets { tickets } => {
            assert_eq!(tickets.len(), 2, "window expiry re-dispatches the stranded batch")
        }
        m => panic!("expected the stranded batch back, got {m:?}"),
    }
    let p = fw.store().progress(None);
    assert_eq!(p.redistributions, 2, "each stranded ticket redistributed once: {p:?}");
    probe.send(&Message::Shutdown).unwrap();
}

/// Ten-millisecond tickets so a stop lands mid-batch.
struct SlowTask;

impl TaskDef for SlowTask {
    fn name(&self) -> &str {
        "slow"
    }
    fn execute(&self, _input: &Value, _: &mut dyn TaskContext) -> anyhow::Result<TaskOutput> {
        sashimi::util::clock::sleep_ms(10);
        Ok(TaskOutput::new(Value::Bool(true)))
    }
}

/// A worker stopped mid-batch strands nothing: finished work is
/// flushed, the unexecuted queue is explicitly released (and whatever
/// the server still tracked is released on disconnect), so no ticket
/// is left in flight against windows that never elapse.
#[test]
fn stopped_worker_leaves_nothing_in_flight() {
    let (fw, _vclock) = pinned_fw();
    let task = fw.create_task(Arc::new(SlowTask));
    task.calculate((0..16).map(|i| Value::num(i as f64)).collect());
    let dist = Distributor::new(&fw);
    // A latency-priced link (really slept) so the adaptive batch grows
    // and the worker actually holds a multi-ticket queue when stopped.
    let (listener, connector) =
        local::endpoint(LinkModel { latency_ms: 20.0, bytes_per_ms: 100_000.0 }, true);
    dist.serve(Box::new(listener));
    let stop = Arc::new(AtomicBool::new(false));
    let worker = {
        let connector = connector.clone();
        let registry = fw.registry_snapshot();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut w = Worker::new("w", DeviceProfile::native(), registry)
                .with_prefetch_cap(8);
            w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop)
        })
    };
    sashimi::util::clock::sleep_ms(300);
    stop.store(true, Ordering::SeqCst);
    let report = worker.join().unwrap();
    let p = fw.store().progress(None);
    assert_eq!(p.in_flight, 0, "a stopping worker must strand nothing: {p:?}");
    assert_eq!(p.done as u64, report.tickets_completed, "acked flushes match the store");
    assert_eq!(p.done + p.pending, 16);
}
