//! Transport conformance (ISSUE 8): the same scripted client session,
//! run over all three transports — in-process local channels, the
//! gateway's JSON-lines TCP listener, and the gateway's WebSocket
//! listener — must produce byte-identical reply transcripts.
//!
//! Each transport gets a *fresh* framework with the clock pinned at
//! virtual 0 and an identically-seeded ticket pool, so every reply —
//! ticket ids, payloads, retry hints, dataset bytes — is deterministic;
//! `Message::encode` is BTreeMap-ordered, so string equality of the
//! re-encoded replies is wire-semantics equality.  Any future transport
//! (or gateway refactor) that forks behaviour breaks the matrix
//! instead of shipping silently.
//!
//! The script walks the whole §2.1.2 surface: hello/ack, the legacy
//! singular ticket lifecycle (ticket_req / task_req / data_req /
//! result), batch dispatch with `max` clamping, singular + batched
//! error reports answered by Reload, explicit release + immediate
//! re-dispatch, NoTicket, and Shutdown (whose session close releases
//! everything still held).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use sashimi::coordinator::{Distributor, Framework, Gateway, GatewayConfig};
use sashimi::store::{Scheduler as _, TicketId};
use sashimi::tasks::is_prime::IsPrimeTask;
use sashimi::tasks::{TaskContext, TaskDef, TaskOutput};
use sashimi::transport::tcp::TcpConn;
use sashimi::transport::ws::WsConn;
use sashimi::transport::{local, Conn, LinkModel, Message, WireError};
use sashimi::util::clock::VirtualClock;
use sashimi::util::json::Value;
use sashimi::worker::{DeviceProfile, Worker};

/// One conformance server: a fresh pinned-clock framework with 8 prime
/// tickets and one registered dataset, plus whatever carries the bytes.
struct Server {
    fw: Arc<Framework>,
    dist: Arc<Distributor>,
    gw: Option<Arc<Gateway>>,
    connector: Option<local::LocalConnector>,
}

impl Server {
    fn fresh() -> (Arc<Framework>, Arc<Distributor>) {
        let vclock = Arc::new(VirtualClock::new());
        let fw = Framework::builder().clock(vclock).build();
        let task = fw.create_task(Arc::new(IsPrimeTask));
        task.calculate(
            (0..8).map(|i| Value::obj(vec![("candidate", Value::num(i as f64 + 2.0))])).collect(),
        );
        // A deterministic dataset for the data_req leg (seeded synth).
        let d = sashimi::data::mnist_train(100, 1);
        fw.datasets().register("conf_data", d.rows_matrix(0, 4));
        let dist = Distributor::new(&fw);
        (fw, dist)
    }

    fn local() -> Server {
        let (fw, dist) = Server::fresh();
        let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
        dist.serve(Box::new(listener));
        Server { fw, dist, gw: None, connector: Some(connector) }
    }

    fn gateway_tcp() -> Server {
        let (fw, dist) = Server::fresh();
        let gw =
            Gateway::bind(&dist, GatewayConfig::default(), Some("127.0.0.1:0"), None).unwrap();
        Server { fw, dist, gw: Some(gw), connector: None }
    }

    fn gateway_ws() -> Server {
        let (fw, dist) = Server::fresh();
        let gw =
            Gateway::bind(&dist, GatewayConfig::default(), None, Some("127.0.0.1:0")).unwrap();
        Server { fw, dist, gw: Some(gw), connector: None }
    }

    fn connect(&self) -> Box<dyn Conn> {
        if let Some(c) = &self.connector {
            return Box::new(c.connect().unwrap());
        }
        let gw = self.gw.as_ref().unwrap();
        if let Some(addr) = gw.tcp_addr() {
            Box::new(TcpConn::connect(&addr).unwrap())
        } else {
            Box::new(WsConn::connect(&format!("ws://{}/", gw.ws_addr().unwrap())).unwrap())
        }
    }
}

fn ask(conn: &mut dyn Conn, log: &mut Vec<String>, m: &Message) -> Message {
    conn.send(m).unwrap();
    let reply = conn.recv().unwrap();
    log.push(reply.encode());
    reply
}

fn ok_result() -> Value {
    Value::obj(vec![("is_prime", Value::Bool(true))])
}

/// The scripted session; returns the encoded reply transcript.
fn run_script(conn: &mut dyn Conn) -> Vec<String> {
    let mut log = Vec::new();

    // Hello / Ack.
    let r = ask(conn, &mut log, &Message::Hello { client: "conf".into(), profile: "test".into() });
    assert_eq!(r, Message::Ack);

    // Legacy singular lifecycle: ticket, code, data, result.
    let t1 = match ask(conn, &mut log, &Message::TicketRequest) {
        Message::Ticket { ticket, task_name, .. } => {
            assert_eq!(task_name, "is_prime");
            ticket
        }
        m => panic!("expected Ticket, got {m:?}"),
    };
    ask(conn, &mut log, &Message::TaskRequest { task_name: "is_prime".into() });
    match ask(conn, &mut log, &Message::DataRequest { key: "conf_data".into() }) {
        Message::Data { shape, .. } => assert_eq!(shape[0], 4),
        m => panic!("expected Data, got {m:?}"),
    }
    let r = ask(conn, &mut log, &Message::TicketResult { ticket: t1, result: ok_result() });
    assert_eq!(r, Message::Ack);

    // Batch dispatch + batched results + a singular error report.
    let batch = match ask(conn, &mut log, &Message::TicketBatchRequest { max: 3 }) {
        Message::Tickets { tickets } => tickets,
        m => panic!("expected Tickets, got {m:?}"),
    };
    assert_eq!(batch.len(), 3);
    let r = ask(
        conn,
        &mut log,
        &Message::TicketResults {
            results: vec![(batch[0].ticket, ok_result()), (batch[1].ticket, ok_result())],
        },
    );
    assert_eq!(r, Message::Ack);
    let r = ask(
        conn,
        &mut log,
        &Message::ErrorReport {
            ticket: batch[2].ticket,
            message: "boom".into(),
            stack: "conformance stack".into(),
        },
    );
    assert_eq!(r, Message::Reload, "singular error reports answer Reload");

    // `max: 0` must clamp to 1, not error and not return empty.
    let b2 = match ask(conn, &mut log, &Message::TicketBatchRequest { max: 0 }) {
        Message::Tickets { tickets } => tickets,
        m => panic!("expected Tickets, got {m:?}"),
    };
    assert_eq!(b2.len(), 1, "max=0 clamps to a single ticket");
    let b3 = match ask(conn, &mut log, &Message::TicketBatchRequest { max: 2 }) {
        Message::Tickets { tickets } => tickets,
        m => panic!("expected Tickets, got {m:?}"),
    };
    assert_eq!(b3.len(), 2);

    // Explicit release: one Ack, and the tickets re-dispatch at once
    // (no redistribution window — the clock is frozen, so re-dispatch
    // is proof of the release path).
    let held: Vec<TicketId> = b2.iter().chain(b3.iter()).map(|t| t.ticket).collect();
    let r = ask(conn, &mut log, &Message::ReleaseTickets { tickets: held });
    assert_eq!(r, Message::Ack);
    let t5 = match ask(conn, &mut log, &Message::TicketRequest) {
        Message::Ticket { ticket, .. } => ticket,
        m => panic!("released tickets must re-dispatch immediately, got {m:?}"),
    };

    // Batched error reports: one Reload for the whole batch.
    let r = ask(
        conn,
        &mut log,
        &Message::ErrorReports {
            reports: vec![WireError {
                ticket: t5,
                message: "boom2".into(),
                stack: "conformance stack".into(),
            }],
        },
    );
    assert_eq!(r, Message::Reload, "batched error reports answer one Reload");

    // Drain the rest (3 done so far, so 5 remain), then an empty pool
    // answers NoTicket with the configured hint.
    let rest = match ask(conn, &mut log, &Message::TicketBatchRequest { max: 64 }) {
        Message::Tickets { tickets } => tickets,
        m => panic!("expected Tickets, got {m:?}"),
    };
    assert_eq!(rest.len(), 5);
    let r = ask(conn, &mut log, &Message::TicketRequest);
    assert!(matches!(r, Message::NoTicket { .. }), "empty pool answers NoTicket, got {r:?}");

    // Orderly shutdown; the 5 tickets still held release on close.
    conn.send(&Message::Shutdown).unwrap();
    log
}

fn released(server: &Server) -> u64 {
    server.dist.stats.tickets_released.load(Ordering::Relaxed)
}

/// Core matrix: identical transcripts on local, gateway-TCP and
/// gateway-WS, and identical release accounting (3 explicit + 5 on
/// session close).
#[test]
fn scripted_session_is_byte_identical_across_transports() {
    let cases: Vec<(&str, Server)> = vec![
        ("local", Server::local()),
        ("gateway-tcp", Server::gateway_tcp()),
        ("gateway-ws", Server::gateway_ws()),
    ];
    let mut transcripts: Vec<(&str, Vec<String>)> = Vec::new();
    for (name, server) in &cases {
        let mut conn = server.connect();
        let log = run_script(&mut *conn);
        drop(conn);
        // The server notices the shutdown asynchronously (gateway
        // reactor); wait for the close-release to land.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while released(server) < 8 {
            assert!(
                std::time::Instant::now() < deadline,
                "{name}: close-release never completed (released {})",
                released(server)
            );
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(released(server), 8, "{name}: 3 explicit + 5 close releases");
        assert_eq!(server.fw.store().progress(None).done, 3, "{name}: 3 results applied");
        transcripts.push((name, log));
    }
    let (ref_name, reference) = &transcripts[0];
    for (name, log) in &transcripts[1..] {
        assert_eq!(
            log.len(),
            reference.len(),
            "{name} transcript length differs from {ref_name}"
        );
        for (i, (a, b)) in reference.iter().zip(log.iter()).enumerate() {
            assert_eq!(a, b, "{name} reply {i} differs from {ref_name}");
        }
    }
    for (_, server) in cases {
        if let Some(gw) = &server.gw {
            gw.shutdown();
        }
    }
}

/// Fails the first execution of every ticket, succeeds on retry — so
/// both workers exercise error reports + Reload mid-run.
struct FailsOnceEach {
    failed: std::sync::Mutex<std::collections::HashSet<u64>>,
}

impl TaskDef for FailsOnceEach {
    fn name(&self) -> &str {
        "fails_once_each"
    }
    fn execute(&self, input: &Value, _: &mut dyn TaskContext) -> anyhow::Result<TaskOutput> {
        let n = input.get("n")?.as_u64()?;
        if self.failed.lock().unwrap().insert(n) {
            anyhow::bail!("transient failure on {n}");
        }
        Ok(TaskOutput::new(Value::num(n as f64)))
    }
}

/// The ISSUE 8 acceptance case: a real WebSocket worker and a legacy
/// TCP JSON worker complete one task set *together* against a single
/// distributor behind one gateway — full lifecycle including errors and
/// reloads on both wires.
#[test]
fn ws_and_tcp_workers_share_one_distributor() {
    let fw = Framework::builder().build();
    let task = fw.create_task(Arc::new(FailsOnceEach { failed: Default::default() }));
    task.calculate((0..24).map(|i| Value::obj(vec![("n", Value::num(i as f64))])).collect());
    let task_id = task.id;
    let dist = Distributor::new(&fw);
    let gw = Gateway::bind(
        &dist,
        GatewayConfig::default(),
        Some("127.0.0.1:0"),
        Some("127.0.0.1:0"),
    )
    .unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    let tcp_addr = gw.tcp_addr().unwrap();
    let ws_addr = format!("ws://{}/", gw.ws_addr().unwrap());
    let tcp_worker = {
        let registry = fw.registry_snapshot();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut w = Worker::new("legacy-tcp", DeviceProfile::native(), registry);
            w.run(|| Ok(Box::new(TcpConn::connect(&tcp_addr)?) as Box<dyn Conn>), &stop)
        })
    };
    let ws_worker = {
        let registry = fw.registry_snapshot();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut w = Worker::new("browser-ws", DeviceProfile::native(), registry);
            w.run(|| Ok(Box::new(WsConn::connect(&ws_addr)?) as Box<dyn Conn>), &stop)
        })
    };

    let results = fw
        .store()
        .wait_results_timeout(task_id, 60_000)
        .expect("both transports must finish the shared task");
    stop.store(true, Ordering::SeqCst);
    let tcp_report = tcp_worker.join().unwrap();
    let ws_report = ws_worker.join().unwrap();

    assert_eq!(results.len(), 24);
    assert_eq!(fw.store().progress(None).done, 24);
    assert_eq!(
        tcp_report.tickets_completed + ws_report.tickets_completed,
        24,
        "the two transports split the pool: tcp={} ws={}",
        tcp_report.tickets_completed,
        ws_report.tickets_completed
    );
    assert_eq!(
        tcp_report.errors_reported + ws_report.errors_reported,
        24,
        "every ticket failed exactly once across both wires"
    );
    assert!(
        ws_report.tickets_completed > 0,
        "the WebSocket worker must have done real work"
    );
    assert!(
        tcp_report.tickets_completed > 0,
        "the legacy TCP worker must have done real work"
    );
    gw.shutdown();
}
