//! Per-PR contention smoke for the sharded dispatch core (ISSUE 7).
//!
//! Two client threads hammer a store with more dispatch shards than
//! clients, so draining the pool *requires* the work-stealing scan:
//! each thread empties its home shard, then must pull every remaining
//! shard's tickets through try-lock steals while the sibling thread
//! does the same.  The smoke asserts the two properties the sharding
//! must never trade away:
//!
//! * **No deadlock** — every thread finishes inside a hard deadline
//!   (the steal scan only ever try-locks siblings, and multi-shard ops
//!   lock shards in ascending order, so no cycle can form).
//! * **No lost or duplicated tickets** — with redistribution windows
//!   far beyond the test horizon, every ticket is dispatched exactly
//!   once per hand-out (one more time than it was released), accepted
//!   exactly once, and the final progress shows the whole pool done.
//!
//! Kept deliberately small (a few thousand tickets, ~a second) so CI
//! can afford it on every PR; the nightly shard sweep in
//! `benches/store_throughput.rs` covers throughput at 1M live.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use sashimi::store::{
    IndexedStore, Scheduler, StoreConfig, SyncPolicy, TaskId, WalConfig, WalStore,
};
use sashimi::util::json::Value;

/// Redistribution windows far beyond the test horizon: any second
/// hand-out of a ticket that was not explicitly released is a bug.
fn quiet_cfg() -> StoreConfig {
    StoreConfig {
        requeue_after_ms: 1_000_000_000_000,
        min_redistribute_ms: 1_000_000_000_000,
        requeue_on_error: true,
        ..StoreConfig::default()
    }
}

const DEADLINE: Duration = Duration::from_secs(60);

/// Drive `clients` threads of next_tickets(16) → release-some /
/// complete-rest cycles until the pool drains, then check conservation.
fn drain_under_contention(store: Arc<dyn Scheduler>, clients: usize, n: usize) {
    let ids = store.create_tickets(
        TaskId(1),
        "smoke",
        (0..n).map(|i| Value::num(i as f64)).collect(),
        0,
    );
    assert_eq!(ids.len(), n);
    let started = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|w| {
            let store = Arc::clone(&store);
            std::thread::spawn(move || {
                let client = format!("smoke-{w}");
                // (dispatches, releases) seen by this thread, per id.
                let mut seen: HashMap<u64, (u32, u32)> = HashMap::new();
                let mut accepted = 0usize;
                let mut batches = 0u64;
                loop {
                    assert!(
                        started.elapsed() < DEADLINE,
                        "{client} still dispatching after {DEADLINE:?}: deadlock or livelock"
                    );
                    let now = 1 + batches; // virtual clock, monotone
                    let got = store.next_tickets(&client, now, 16);
                    if got.is_empty() {
                        if store.progress(None).pending == 0 {
                            break; // pool drained (in-flight is the sibling's)
                        }
                        std::thread::yield_now();
                        continue;
                    }
                    batches += 1;
                    for t in &got {
                        seen.entry(t.id.0).or_insert((0, 0)).0 += 1;
                    }
                    // Hand every 7th pick back through the active
                    // failure path; it must come around again (possibly
                    // via the sibling's steal scan).
                    let (dropped, kept): (Vec<_>, Vec<_>) =
                        got.iter().enumerate().partition(|(i, _)| i % 7 == 6);
                    let release_ids: Vec<_> = dropped.iter().map(|(_, t)| t.id).collect();
                    let flags = store.release_batch(&release_ids);
                    assert!(flags.iter().all(|&f| f), "released an in-flight ticket we hold");
                    for id in &release_ids {
                        seen.get_mut(&id.0).unwrap().1 += 1;
                    }
                    accepted += store
                        .complete_batch(
                            kept.iter().map(|(_, t)| (t.id, Value::num(t.index as f64))).collect(),
                        )
                        .expect("complete_batch on held tickets");
                }
                (seen, accepted)
            })
        })
        .collect();
    let mut dispatched: HashMap<u64, (u32, u32)> = HashMap::new();
    let mut accepted_total = 0usize;
    for h in handles {
        let (seen, accepted) = h.join().expect("smoke thread panicked");
        for (id, (d, r)) in seen {
            let e = dispatched.entry(id).or_insert((0, 0));
            e.0 += d;
            e.1 += r;
        }
        accepted_total += accepted;
    }
    // Conservation: every created ticket went out, exactly once per
    // hand-out, and was accepted exactly once across both threads.
    assert_eq!(dispatched.len(), n, "some tickets were never dispatched");
    for (id, (d, r)) in &dispatched {
        assert_eq!(*d, r + 1, "ticket {id} dispatched {d}× for {r} releases");
    }
    assert_eq!(accepted_total, n, "accepted completions != pool size");
    let p = store.progress(None);
    assert_eq!((p.total, p.done, p.pending, p.in_flight), (n, n, 0, 0), "final progress {p:?}");
    let st = store.stats();
    assert!(st.dispatch_locks > 0, "dispatches must count lock acquisitions");
    assert!(
        st.steal_successes > 0,
        "2 clients × {} shards cannot drain without stealing: {st:?}",
        st.dispatch_shards
    );
}

/// The in-memory sharded core: 2 threads, 8 shards — six shards' worth
/// of tickets are reachable only through steals.
#[test]
fn two_threads_eight_shards_no_deadlock_no_lost_tickets() {
    let store: Arc<dyn Scheduler> = Arc::new(IndexedStore::with_dispatch_shards(quiet_cfg(), 8));
    drain_under_contention(store, 2, 4_000);
}

/// The same contract through the per-shard WAL segment streams, where
/// a steal appends to a sibling's stream and completion batches lock
/// several streams at once (ascending order — the deadlock-freedom
/// discipline this smoke exists to catch regressions in).
#[test]
fn two_threads_sharded_wal_no_deadlock_no_lost_tickets() {
    let dir = std::env::temp_dir()
        .join(format!("sashimi-contention-smoke-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let wal_cfg = WalConfig {
        sync: SyncPolicy::OsOnly,
        segment_max_bytes: 1 << 20,
        checkpoint_every: 128, // several checkpoints mid-contention
        dispatch_shards: 4,
    };
    let store: Arc<dyn Scheduler> =
        Arc::new(WalStore::open(&dir, quiet_cfg(), wal_cfg).expect("open sharded WAL"));
    drain_under_contention(Arc::clone(&store), 2, 1_500);
    drop(store);
    let _ = std::fs::remove_dir_all(&dir);
}
