//! Deterministic fleet-scale churn simulator (the "soak rig").
//!
//! The paper's strongest claim is operational, not algorithmic: a
//! coordinator that keeps making progress while *browsers come and go*
//! (§2.1.2's redistribution windows, the error-report/reload loop, tab
//! closes mid-ticket).  The unit tests exercise each failure path with
//! two or three scripted connections; this module exercises all of them
//! at once, at fleet scale, without wall-clock cost:
//!
//! * **One real coordinator** — a [`Distributor`] over a [`WalStore`],
//!   the same code production uses.  Nothing server-side is mocked.
//! * **O(10k) lightweight workers** — each a protocol-level state
//!   machine driving a real [`Session`] (the transport-free handler the
//!   distributor exposes), not a thread.  Per-worker behaviour (connect
//!   delay, compute speed, vanish/reload hazard, link RTT) is sampled
//!   from seeded distributions anchored to the Table 1 device profiles
//!   in [`crate::worker::profile`].
//! * **A discrete-event loop on a [`VirtualClock`]** — events are
//!   ordered by `(virtual time, sequence)`, and the shared clock is
//!   advanced to each event's timestamp, so redistribution windows,
//!   backoff and VCT timestamps all elapse in simulated milliseconds.
//!   Ten minutes of fleet time replays in seconds of wall time, and the
//!   entire run — traces, metrics JSON, every dispatch decision — is a
//!   pure function of [`SoakConfig`] (same seed, byte-identical output).
//!
//! The rig reports soak metrics (dispatch throughput, ticket-latency
//! percentiles, stranding-window durations, churn counters, per-class
//! completion shares) via [`crate::util::stats::Histogram`], as a JSON
//! document and a console table.  `examples/churn_soak.rs` is the CLI
//! driver; `rust/tests/churn_soak.rs` pins the invariants (zero lost
//! tickets, zero ghost workers, bounded stranding).

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::{Distributor, DistributorConfig, Session};
use crate::runtime::{SharedRuntime, Tensor};
use crate::store::{
    Scheduler, StoreConfig, SyncPolicy, TaskId, TicketId, VerifyStats, WalConfig, WalStore,
};
use crate::tasks::is_prime::IsPrimeTask;
use crate::tasks::sweep::{self, SweepTask};
use crate::tasks::{DatasetStore, Registry, TaskContext};
use crate::transport::{Message, WireError, WireTicket};
use crate::util::clock::{Clock, VirtualClock};
use crate::util::json::Value;
use crate::util::rng::SplitMix64;
use crate::util::stats::Histogram;
use crate::worker::profile::DeviceProfile;
use crate::worker::PrefetchController;

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// Everything a soak run depends on.  Two runs with equal configs
/// produce byte-identical traces and metrics JSON.
#[derive(Debug, Clone)]
pub struct SoakConfig {
    /// Fleet size (simulated browsers).
    pub workers: usize,
    /// Master seed; every worker forks its own stream from it.
    pub seed: u64,
    /// Churn horizon in virtual ms: vanish/reload hazards apply inside
    /// `[0, duration_ms)`.  The run itself continues until every ticket
    /// is done, then the clock is advanced to at least the horizon.
    pub duration_ms: u64,
    /// `is_prime` fan-out size (the bulk workload).
    pub prime_tickets: usize,
    /// Include the 8x8 hyperparameter [`sweep`] grid (64 more tickets)
    /// so the soak runs two task types concurrently.
    pub sweep_grid: bool,
    /// `true` = the active failure path (release on disconnect);
    /// `false` = the paper's passive §2.1.2 window-expiry baseline.
    pub release_on_disconnect: bool,
    /// Per-worker adaptive prefetch ceiling (1 = paper's protocol).
    pub prefetch_cap: usize,
    /// Mean worker lifetime in virtual ms; lifetimes are sampled
    /// uniformly from `[mean/4, 2.25*mean)`.  `0` disables churn.
    pub mean_lifetime_ms: u64,
    /// Percent of vanishes followed by a reload (reconnect after a
    /// 1-15 s delay); the rest leave for good.
    pub reload_percent: u64,
    /// Per-ticket task-fault injection rate (per thousand) — exercises
    /// the ErrorReports/Reload/requeue loop.
    pub error_permille: u64,
    /// Ticket-store redistribution policy (paper defaults: 5 min
    /// window, 10 s minimum interval).
    pub store_cfg: StoreConfig,
    /// Dispatch shards of the coordinator's store (each with its own
    /// WAL stream).  `1` — the default, and what every preset uses —
    /// keeps the soak's store byte-identical to the pre-sharding rig.
    pub dispatch_shards: usize,
    /// Per-mille of workers that *always* fabricate results.  Each liar
    /// fabricates a value unique to itself, so two of them can never
    /// corroborate each other (the BOINC wrong-result model).
    pub adversary_wrong_permille: u64,
    /// Per-mille of workers that fabricate roughly a quarter of their
    /// results and answer honestly otherwise (intermittent corruptor).
    pub adversary_corrupt_permille: u64,
    /// Per-mille of workers in one colluding ring: their fabrications
    /// are *identical* — the only class that can corroborate itself,
    /// and therefore the only one that can poison a quorum.
    pub adversary_collude_permille: u64,
}

impl SoakConfig {
    /// A soak sized to `workers`, with the paper-default store policy
    /// and the active failure path.
    pub fn new(workers: usize, seed: u64) -> SoakConfig {
        SoakConfig {
            workers,
            seed,
            duration_ms: 600_000, // ten simulated minutes
            prime_tickets: workers.saturating_mul(3).max(64),
            sweep_grid: true,
            release_on_disconnect: true,
            prefetch_cap: 8,
            mean_lifetime_ms: 30_000,
            reload_percent: 85,
            error_permille: 5,
            store_cfg: StoreConfig::default(),
            dispatch_shards: 1,
            adversary_wrong_permille: 0,
            adversary_corrupt_permille: 0,
            adversary_collude_permille: 0,
        }
    }

    /// The CI per-PR preset: 1k workers, ten simulated minutes.
    pub fn quick() -> SoakConfig {
        SoakConfig::new(1_000, 42)
    }

    /// The adversarial CI preset: the quick soak with 20 % wrong-result
    /// workers under R = 3 / Q = 2 quorum verification.
    pub fn adversarial_quick() -> SoakConfig {
        let mut cfg = SoakConfig::quick();
        cfg.store_cfg.replication = 3;
        cfg.store_cfg.quorum = 2;
        cfg.adversary_wrong_permille = 200;
        cfg
    }
}

// ---------------------------------------------------------------------------
// Device classes (Table 1 anchors)
// ---------------------------------------------------------------------------

/// A fleet slice: Table 1 device profile + a modelled link.
struct DeviceClass {
    name: &'static str,
    /// Modelled-ms multiplier relative to the desktop (1.0).
    mult: f64,
    /// Link round trip: `rtt_base + U[0, rtt_jitter)` per worker.
    rtt_base: u64,
    rtt_jitter: u64,
    /// Fleet share, percent; shares must sum to 100.
    share_pct: u64,
}

/// Half the fleet is the Table 1 desktop, a third the Nexus 7 tablet
/// (desktop/7.2, on a slow link), the rest a desktop throttled by the
/// Table 4 Firefox/ConvNetJS engine factor.
fn device_classes() -> [DeviceClass; 3] {
    let desktop = DeviceProfile::desktop().speed;
    [
        DeviceClass { name: "desktop", mult: 1.0, rtt_base: 4, rtt_jitter: 4, share_pct: 50 },
        DeviceClass {
            name: "tablet",
            mult: desktop / DeviceProfile::tablet().speed,
            rtt_base: 60,
            rtt_jitter: 60,
            share_pct: 30,
        },
        DeviceClass {
            name: "firefox",
            mult: DeviceProfile::firefox_convnetjs_factor(),
            rtt_base: 12,
            rtt_jitter: 8,
            share_pct: 20,
        },
    ]
}

/// Modelled per-ticket compute on the *desktop* (multiplied by the
/// class factor).  Task results are computed for real; only the virtual
/// duration is modelled, so the soak stays deterministic.
fn modelled_cost_ms(task_name: &str) -> f64 {
    match task_name {
        "sweep" => 8.0,
        "is_prime" => 150.0,
        _ => 25.0,
    }
}

/// The sweep grid soaked alongside the primes: log-spaced learning
/// rates and a reg ladder that both contain the known optimum
/// `(3e-3, 1e-2)`, so the end-to-end argmin is assertable.
fn sweep_grid_inputs() -> Vec<Value> {
    let lrs = [1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1];
    let regs = [0.0, 0.0025, 0.005, 0.0075, 0.01, 0.025, 0.05, 0.1];
    sweep::grid(&lrs, &regs)
}

// ---------------------------------------------------------------------------
// The event loop
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Kind {
    /// (Re)connect: open a session, Hello, start fetching.
    Connect,
    /// Poll the coordinator for up to `prefetch.size()` tickets.
    Fetch,
    /// A batch's compute is over: flush results/errors, fetch again.
    Finish,
    /// The tab closes mid-whatever.  Maybe schedules a reload.
    Vanish,
}

/// Min-heap entry: `(virtual ms, sequence, worker, epoch, kind)`.  The
/// sequence number makes same-instant ordering total, so runs are
/// reproducible; the epoch invalidates events scheduled before a
/// vanish (a dead tab's Finish must not fire).
type Ev = (u64, u64, usize, u32, Kind);

/// Worker honesty class, assigned per worker from its forked stream (so
/// the assignment is independent of event order, like every other
/// per-worker trait).
#[derive(Clone, Copy, PartialEq)]
enum Adversary {
    Honest,
    /// Fabricates every result, uniquely to itself.
    WrongResult,
    /// Fabricates ~25 % of results, uniquely to itself.
    Corruptor,
    /// Fabricates every result, identically to every other colluder.
    Colluder,
}

/// The shared tag of the colluding ring's fabrications.
const COLLUDER_TAG: u64 = 999_999;

/// The fabricated result an adversary submits instead of the honest
/// value: structurally valid JSON, trivially recognisable after the run
/// (no honest soak task emits a "poisoned" key).
fn poisoned_value(tag: u64) -> Value {
    Value::obj(vec![("poisoned", Value::Bool(true)), ("tag", Value::num(tag as f64))])
}

fn is_poisoned(v: &Value) -> bool {
    v.get("poisoned").is_ok()
}

struct SimWorker {
    class: usize,
    mult: f64,
    rtt: u64,
    rng: SplitMix64,
    epoch: u32,
    online: bool,
    prefetch: PrefetchController,
    idle_streak: u32,
    batch: Vec<WireTicket>,
    batch_exec_ms: u64,
    adversary: Adversary,
}

/// Task context for simulated execution: soak tasks are pure
/// compute, so dataset/runtime access is a bug, not a feature.
struct SimContext;

impl TaskContext for SimContext {
    fn dataset(&mut self, key: &str) -> Result<Arc<Tensor>> {
        anyhow::bail!("churn-soak tasks are dataset-free (asked for {key:?})")
    }

    fn runtime(&self) -> Result<&SharedRuntime> {
        anyhow::bail!("no runtime in the churn soak")
    }
}

/// Trace lines are capped so a 10k-worker soak's report stays small;
/// the drop count is part of the (deterministic) output.
const TRACE_CAP: usize = 512;

/// Runaway backstop: no sane soak comes near this many events.
const EVENT_BUDGET: u64 = 50_000_000;

fn push_ev(heap: &mut BinaryHeap<Reverse<Ev>>, seq: &mut u64, at: u64, wi: usize, epoch: u32, kind: Kind) {
    *seq += 1;
    heap.push(Reverse((at, *seq, wi, epoch, kind)));
}

fn trace_line(trace: &mut Vec<String>, dropped: &mut u64, line: String) {
    if trace.len() < TRACE_CAP {
        trace.push(line);
    } else {
        *dropped += 1;
    }
}

// ---------------------------------------------------------------------------
// Report
// ---------------------------------------------------------------------------

/// Everything a soak run measured.  `metrics_json` and `trace` are
/// deterministic (virtual-time only — no wall timestamps, no paths).
pub struct SoakReport {
    /// The metrics document (one line of canonical JSON).
    pub metrics_json: String,
    /// Human-readable summary table.
    pub table: String,
    /// Deterministic event trace (connects/vanishes/milestones), capped
    /// at [`TRACE_CAP`] lines plus a final summary line.
    pub trace: Vec<String>,
    /// Final virtual clock (ms); at least the churn horizon.
    pub virtual_ms: u64,
    pub total: usize,
    pub done: usize,
    pub pending: usize,
    pub in_flight: usize,
    /// Store-side redistribution count (window expiries re-dispatched).
    pub redistributions: u64,
    pub dispatched: u64,
    pub released: u64,
    pub duplicates: u64,
    pub errors_reported: usize,
    pub connections: u64,
    pub vanishes: u64,
    pub reloads: u64,
    /// All-offline recoveries (the rig reconnects worker 0 so a fully
    /// churned-out fleet cannot deadlock the run).
    pub rescues: u64,
    pub idle_polls: u64,
    /// Connected-client-table entries minus actually-online workers,
    /// sampled just before the final close: nonzero means the client
    /// table leaked a ghost.
    pub ghost_entries: i64,
    /// Client-table entries still marked connected after every session
    /// closed (must be 0).
    pub ghosts_after_close: usize,
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
    pub latency_max_ms: f64,
    /// Stranding windows: vanish-with-held-tickets until re-dispatch.
    pub strand_count: u64,
    pub strand_p50_ms: f64,
    pub max_strand_ms: f64,
    pub throughput_per_s: f64,
    /// The sweep argmin `(lr, reg)` recovered from ticket results, when
    /// the sweep grid ran.  `None` when the grid's accepted results
    /// contain a fabrication (no trustworthy argmin exists).
    pub sweep_best: Option<(f64, f64)>,
    /// Accepted results carrying an adversary's fabrication marker.
    /// Zero for every mix that cannot corroborate itself (only the
    /// colluding ring can poison a quorum).
    pub poisoned_completions: usize,
    /// Workers assigned a dishonest class by the mix fractions.
    pub adversaries: usize,
    /// Adversaries that actually submitted at least one fabrication.
    pub adversaries_lied: usize,
    /// Adversaries the reputation layer ever quarantined.
    pub adversaries_quarantined: usize,
    /// Verification-layer counters (all zero at R = 1).
    pub verify: VerifyStats,
}

fn round3(x: f64) -> f64 {
    (x * 1000.0).round() / 1000.0
}

fn hist_json(h: &Histogram) -> Value {
    Value::obj(vec![
        ("count", Value::num(h.count() as f64)),
        ("mean", Value::num(round3(h.mean()))),
        ("p50", Value::num(round3(h.percentile(50.0)))),
        ("p99", Value::num(round3(h.percentile(99.0)))),
        ("max", Value::num(round3(h.max()))),
    ])
}

// ---------------------------------------------------------------------------
// The run
// ---------------------------------------------------------------------------

static SOAK_DIR_SEQ: AtomicU64 = AtomicU64::new(0);

/// Run one soak.  The WAL lives in a per-run temp directory that is
/// removed afterwards (kept on error for post-mortems).
pub fn run_soak(cfg: &SoakConfig) -> Result<SoakReport> {
    let dir = std::env::temp_dir().join(format!(
        "sashimi-soak-{}-{}-{}",
        std::process::id(),
        cfg.seed,
        SOAK_DIR_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let result = run_soak_in(cfg, &dir);
    if result.is_ok() {
        let _ = std::fs::remove_dir_all(&dir);
    }
    result
}

fn run_soak_in(cfg: &SoakConfig, wal_dir: &std::path::Path) -> Result<SoakReport> {
    anyhow::ensure!(cfg.workers > 0, "soak needs at least one worker");

    // -- Coordinator: real store, real registry, real distributor, all
    //    on one shared virtual clock.
    let vclock = Arc::new(VirtualClock::new());
    let wal_cfg = WalConfig {
        sync: SyncPolicy::OsOnly,
        dispatch_shards: cfg.dispatch_shards,
        ..WalConfig::default()
    };
    let store: Arc<WalStore> = Arc::new(WalStore::open(wal_dir, cfg.store_cfg.clone(), wal_cfg)?);
    let store_dyn: Arc<dyn Scheduler> = Arc::clone(&store);

    let mut registry = Registry::new();
    registry.register(Arc::new(IsPrimeTask));
    registry.register(Arc::new(SweepTask));

    let dist = Distributor::from_parts_clocked(
        Arc::clone(&store_dyn),
        registry.clone(),
        Arc::new(DatasetStore::new()),
        DistributorConfig { release_on_disconnect: cfg.release_on_disconnect, ..Default::default() },
        vclock.clone(),
    );

    // -- Workload: a prime fan-out (odd candidates around 1e6) plus the
    //    sweep grid; both created at t=0.
    let prime_args: Vec<Value> = (0..cfg.prime_tickets)
        .map(|i| Value::obj(vec![("candidate", Value::num((1_000_003 + 2 * i) as f64))]))
        .collect();
    let prime_task = TaskId(1);
    store_dyn.create_tickets(prime_task, "is_prime", prime_args, 0);
    let sweep_task = TaskId(2);
    if cfg.sweep_grid {
        store_dyn.create_tickets(sweep_task, "sweep", sweep_grid_inputs(), 0);
    }
    let total = store_dyn.progress(None).total;

    // -- Fleet: per-worker streams forked from the master seed in index
    //    order, so worker behaviour is independent of event order.
    let classes = device_classes();
    let mut master = SplitMix64::new(cfg.seed);
    let mut fleet: Vec<SimWorker> = (0..cfg.workers)
        .map(|_| {
            let mut rng = master.fork();
            let r = rng.gen_range(100);
            let mut acc = 0u64;
            let mut class = classes.len() - 1;
            for (i, c) in classes.iter().enumerate() {
                acc += c.share_pct;
                if r < acc {
                    class = i;
                    break;
                }
            }
            let c = &classes[class];
            let rtt = c.rtt_base + rng.gen_range(c.rtt_jitter.max(1));
            // The honesty draw happens unconditionally so the rest of
            // the worker's stream is unaffected by the mix fractions.
            let a = rng.gen_range(1_000);
            let adversary = if a < cfg.adversary_wrong_permille {
                Adversary::WrongResult
            } else if a < cfg.adversary_wrong_permille + cfg.adversary_corrupt_permille {
                Adversary::Corruptor
            } else if a
                < cfg.adversary_wrong_permille
                    + cfg.adversary_corrupt_permille
                    + cfg.adversary_collude_permille
            {
                Adversary::Colluder
            } else {
                Adversary::Honest
            };
            SimWorker {
                class,
                mult: c.mult,
                rtt,
                rng,
                epoch: 0,
                online: false,
                prefetch: PrefetchController::new(cfg.prefetch_cap),
                idle_streak: 0,
                batch: Vec::new(),
                batch_exec_ms: 0,
                adversary,
            }
        })
        .collect();

    let mut sessions: Vec<Option<Session>> = (0..cfg.workers).map(|_| None).collect();
    let mut heap: BinaryHeap<Reverse<Ev>> = BinaryHeap::new();
    let mut seq = 0u64;
    for (wi, w) in fleet.iter_mut().enumerate() {
        let delay = w.rng.gen_range(5_000);
        push_ev(&mut heap, &mut seq, delay, wi, 0, Kind::Connect);
    }

    // -- Bookkeeping.
    let mut latency = Histogram::new();
    let mut stranding = Histogram::new();
    let mut dispatch_at: BTreeMap<TicketId, u64> = BTreeMap::new();
    let mut strand_start: BTreeMap<TicketId, u64> = BTreeMap::new();
    let mut completed_by_class = vec![0u64; classes.len()];
    let mut workers_by_class = vec![0u64; classes.len()];
    for w in &fleet {
        workers_by_class[w.class] += 1;
    }
    let adversaries = fleet.iter().filter(|w| w.adversary != Adversary::Honest).count();
    // Which adversaries actually submitted at least one fabrication —
    // the set the reputation layer must end up quarantining.
    let mut adversary_lied = vec![false; cfg.workers];
    let (mut vanishes, mut reloads, mut rescues, mut idle_polls) = (0u64, 0u64, 0u64, 0u64);
    let mut errors_injected = 0u64;
    let mut trace: Vec<String> = Vec::new();
    let mut trace_dropped = 0u64;
    let mut done_logged = false;
    let mut events = 0u64;

    loop {
        if heap.is_empty() {
            if store_dyn.progress(None).done >= total {
                break;
            }
            // Every worker churned out with work still undone: bring
            // worker 0 back so the run cannot deadlock.  Under quorum
            // verification one client can never decide a ticket alone
            // (same-client exclusion), so a rotating window of quorum+1
            // workers reconnects instead — rotation guarantees honest
            // workers eventually return even if the first window was
            // all adversaries.
            let now = vclock.now_ms();
            if cfg.store_cfg.verifying() {
                let k = (cfg.store_cfg.quorum as usize + 1).min(cfg.workers);
                for j in 0..k {
                    let ri = ((rescues as usize).wrapping_mul(k) + j) % cfg.workers;
                    fleet[ri].epoch += 1;
                    fleet[ri].online = false;
                    let ep = fleet[ri].epoch;
                    push_ev(&mut heap, &mut seq, now + 1_000, ri, ep, Kind::Connect);
                    trace_line(&mut trace, &mut trace_dropped, format!("t={now} rescue w{ri}"));
                }
                rescues += 1;
            } else {
                fleet[0].epoch += 1;
                fleet[0].online = false;
                rescues += 1;
                let ep = fleet[0].epoch;
                push_ev(&mut heap, &mut seq, now + 1_000, 0, ep, Kind::Connect);
                trace_line(&mut trace, &mut trace_dropped, format!("t={now} rescue w0"));
            }
        }
        let Reverse((at, _s, wi, epoch, kind)) = heap.pop().unwrap();
        events += 1;
        anyhow::ensure!(events <= EVENT_BUDGET, "soak exceeded the {EVENT_BUDGET}-event budget");
        vclock.advance_to(at);
        let now = at;
        if fleet[wi].epoch != epoch {
            continue; // scheduled before a vanish: the tab is gone
        }

        match kind {
            Kind::Connect => {
                let w = &mut fleet[wi];
                w.online = true;
                w.idle_streak = 0;
                let mut s = dist.open_session();
                let hello = Message::Hello {
                    client: format!("w{wi}"),
                    profile: classes[w.class].name.to_string(),
                };
                s.handle(hello)?;
                sessions[wi] = Some(s);
                push_ev(&mut heap, &mut seq, now + w.rtt, wi, w.epoch, Kind::Fetch);
                if cfg.mean_lifetime_ms > 0 {
                    let life =
                        cfg.mean_lifetime_ms / 4 + w.rng.gen_range(cfg.mean_lifetime_ms * 2);
                    let vanish_at = now + life;
                    if vanish_at < cfg.duration_ms {
                        push_ev(&mut heap, &mut seq, vanish_at, wi, w.epoch, Kind::Vanish);
                    }
                }
                trace_line(&mut trace, &mut trace_dropped, format!("t={now} w{wi} connect"));
            }

            Kind::Fetch => {
                let drained = store_dyn.progress(None).done >= total;
                let w = &mut fleet[wi];
                if !w.online {
                    continue;
                }
                let Some(sess) = sessions[wi].as_mut() else { continue };
                let want = w.prefetch.size();
                let reply = sess
                    .handle(Message::TicketBatchRequest { max: want })?
                    .expect("batch request always gets a reply");
                match reply {
                    Message::Tickets { tickets } => {
                        w.idle_streak = 0;
                        let mut exec_total = 0u64;
                        for t in &tickets {
                            if let Some(s0) = strand_start.remove(&t.ticket) {
                                stranding.record((now - s0) as f64);
                            }
                            dispatch_at.insert(t.ticket, now);
                            let cost = modelled_cost_ms(&t.task_name) * w.mult;
                            exec_total += (cost.ceil() as u64).max(1);
                        }
                        w.batch = tickets;
                        w.batch_exec_ms = exec_total;
                        push_ev(&mut heap, &mut seq, now + exec_total, wi, w.epoch, Kind::Finish);
                    }
                    Message::NoTicket { .. } => {
                        w.prefetch.on_no_ticket();
                        idle_polls += 1;
                        if !drained {
                            // The worker's jittered exponential idle
                            // backoff, in virtual time.
                            let ceiling = 20u64
                                .saturating_mul(1u64 << w.idle_streak.min(8))
                                .min(5_000);
                            let nap = ceiling / 2 + w.rng.gen_range(ceiling / 2 + 1);
                            w.idle_streak += 1;
                            push_ev(&mut heap, &mut seq, now + nap, wi, w.epoch, Kind::Fetch);
                        }
                    }
                    other => anyhow::bail!("unexpected batch reply: {other:?}"),
                }
            }

            Kind::Finish => {
                let w = &mut fleet[wi];
                if !w.online {
                    continue;
                }
                let Some(sess) = sessions[wi].as_mut() else { continue };
                let batch = std::mem::take(&mut w.batch);
                let mut results: Vec<(TicketId, Value)> = Vec::new();
                let mut errs: Vec<WireError> = Vec::new();
                let mut ctx = SimContext;
                for t in batch {
                    let fault =
                        cfg.error_permille > 0 && w.rng.gen_range(1_000) < cfg.error_permille;
                    if fault {
                        errs.push(WireError {
                            ticket: t.ticket,
                            message: "injected churn-soak fault".into(),
                            stack: "sim::worker".into(),
                        });
                        continue;
                    }
                    match registry.get(&t.task_name)?.execute(&t.payload, &mut ctx) {
                        Ok(out) => {
                            let value = match w.adversary {
                                Adversary::Honest => out.value,
                                Adversary::WrongResult => poisoned_value(wi as u64),
                                Adversary::Corruptor => {
                                    if w.rng.gen_range(4) == 0 {
                                        poisoned_value(wi as u64)
                                    } else {
                                        out.value
                                    }
                                }
                                Adversary::Colluder => poisoned_value(COLLUDER_TAG),
                            };
                            if is_poisoned(&value) {
                                adversary_lied[wi] = true;
                            }
                            results.push((t.ticket, value));
                        }
                        Err(e) => errs.push(WireError {
                            ticket: t.ticket,
                            message: format!("{e:#}"),
                            stack: String::new(),
                        }),
                    }
                }
                let had_errs = !errs.is_empty();
                if !results.is_empty() {
                    let ids: Vec<TicketId> = results.iter().map(|r| r.0).collect();
                    sess.handle(Message::TicketResults { results })?;
                    for id in &ids {
                        if let Some(d) = dispatch_at.remove(id) {
                            latency.record((now - d + w.rtt) as f64);
                        }
                    }
                    completed_by_class[w.class] += ids.len() as u64;
                }
                if had_errs {
                    errors_injected += errs.len() as u64;
                    for e in &errs {
                        dispatch_at.remove(&e.ticket);
                    }
                    sess.handle(Message::ErrorReports { reports: errs })?;
                    w.prefetch.on_error();
                } else {
                    w.prefetch.on_batch_done(w.batch_exec_ms as f64, w.rtt as f64);
                }
                push_ev(&mut heap, &mut seq, now + w.rtt, wi, w.epoch, Kind::Fetch);
                if !done_logged && store_dyn.progress(None).done >= total {
                    done_logged = true;
                    trace_line(
                        &mut trace,
                        &mut trace_dropped,
                        format!("t={now} all {total} tickets done"),
                    );
                }
            }

            Kind::Vanish => {
                let w = &mut fleet[wi];
                if !w.online {
                    continue;
                }
                w.online = false;
                w.epoch += 1;
                vanishes += 1;
                let mut held = 0usize;
                if let Some(mut s) = sessions[wi].take() {
                    for id in s.held_tickets() {
                        strand_start.entry(id).or_insert(now);
                        held += 1;
                    }
                    s.close();
                }
                w.batch.clear();
                if w.rng.gen_range(100) < cfg.reload_percent {
                    let delay = 1_000 + w.rng.gen_range(14_000);
                    reloads += 1;
                    let ep = w.epoch;
                    push_ev(&mut heap, &mut seq, now + delay, wi, ep, Kind::Connect);
                }
                trace_line(
                    &mut trace,
                    &mut trace_dropped,
                    format!("t={now} w{wi} vanish held={held}"),
                );
            }
        }
    }

    // The fleet sat out the rest of the horizon (if the workload
    // drained early): the run always covers `duration_ms`.
    vclock.advance_to(cfg.duration_ms);
    let virtual_ms = vclock.now_ms();

    // -- Ghost-worker audit, then an orderly fleet shutdown.
    let online_now = fleet.iter().filter(|w| w.online).count();
    let ghost_entries = dist.client_count() as i64 - online_now as i64;
    for s in sessions.iter_mut().flatten() {
        s.close();
    }
    let ghosts_after_close = dist.client_count();

    let p = store_dyn.progress(None);
    let sched = store_dyn.stats();
    // Poisoned-completion audit: count accepted results that carry an
    // adversary's fabrication marker.  Any mix that cannot corroborate
    // itself (everything but colluders) must score zero here.
    let mut poisoned_completions =
        store_dyn.wait_results(prime_task).iter().filter(|v| is_poisoned(v)).count();
    let sweep_best = if cfg.sweep_grid {
        let results = store_dyn.wait_results(sweep_task);
        let poisoned = results.iter().filter(|v| is_poisoned(v)).count();
        poisoned_completions += poisoned;
        if poisoned > 0 {
            None // a poisoned grid cell has no trustworthy argmin
        } else {
            let (lr, reg, _loss) = sweep::best(&results)?;
            Some((lr, reg))
        }
    } else {
        None
    };

    let throughput = if virtual_ms > 0 {
        p.done as f64 / (virtual_ms as f64 / 1000.0)
    } else {
        0.0
    };
    let stats = &dist.stats;
    let dispatched = stats.tickets_served.load(Ordering::Relaxed);
    let released = stats.tickets_released.load(Ordering::Relaxed);
    let duplicates = stats.results_duplicate.load(Ordering::Relaxed);
    let connections = stats.connections.load(Ordering::Relaxed);
    let duplicates_cross = stats.results_duplicate_cross.load(Ordering::Relaxed);
    let pending_quorum = stats.results_pending_quorum.load(Ordering::Relaxed);
    let refused_quarantine = stats.noticket_quarantined.load(Ordering::Relaxed);
    let vs = store_dyn.verify_stats();
    let quarantined: std::collections::HashSet<String> =
        store_dyn.quarantined_clients().into_iter().collect();
    let adversaries_lied = adversary_lied.iter().filter(|&&l| l).count();
    let adversaries_quarantined = (0..cfg.workers)
        .filter(|&i| {
            fleet[i].adversary != Adversary::Honest && quarantined.contains(&format!("w{i}"))
        })
        .count();

    // The summary line rides above the cap so it is always present.
    trace.push(format!(
        "t={virtual_ms} end done={}/{} vanishes={vanishes} reloads={reloads} trace_dropped={trace_dropped}",
        p.done, p.total
    ));

    let class_json = Value::Obj(
        classes
            .iter()
            .enumerate()
            .map(|(i, c)| {
                let share = if p.done > 0 {
                    completed_by_class[i] as f64 / p.done as f64
                } else {
                    0.0
                };
                (
                    c.name.to_string(),
                    Value::obj(vec![
                        ("workers", Value::num(workers_by_class[i] as f64)),
                        ("completed", Value::num(completed_by_class[i] as f64)),
                        ("share", Value::num(round3(share))),
                    ]),
                )
            })
            .collect(),
    );

    let metrics = Value::obj(vec![
        (
            "config",
            Value::obj(vec![
                ("workers", Value::num(cfg.workers as f64)),
                ("seed", Value::num(cfg.seed as f64)),
                ("duration_ms", Value::num(cfg.duration_ms as f64)),
                ("release_on_disconnect", Value::Bool(cfg.release_on_disconnect)),
                ("prefetch_cap", Value::num(cfg.prefetch_cap as f64)),
                ("mean_lifetime_ms", Value::num(cfg.mean_lifetime_ms as f64)),
            ]),
        ),
        ("virtual_ms", Value::num(virtual_ms as f64)),
        ("throughput_per_s", Value::num(round3(throughput))),
        (
            "tickets",
            Value::obj(vec![
                ("total", Value::num(p.total as f64)),
                ("done", Value::num(p.done as f64)),
                ("pending", Value::num(p.pending as f64)),
                ("in_flight", Value::num(p.in_flight as f64)),
                ("dispatched", Value::num(dispatched as f64)),
                ("released", Value::num(released as f64)),
                ("duplicates", Value::num(duplicates as f64)),
                ("errors", Value::num(p.errors as f64)),
                ("redistributions", Value::num(p.redistributions as f64)),
            ]),
        ),
        (
            "churn",
            Value::obj(vec![
                ("connections", Value::num(connections as f64)),
                ("vanishes", Value::num(vanishes as f64)),
                ("reloads", Value::num(reloads as f64)),
                ("rescues", Value::num(rescues as f64)),
                ("idle_polls", Value::num(idle_polls as f64)),
                ("faults_injected", Value::num(errors_injected as f64)),
            ]),
        ),
        (
            "sched",
            Value::obj(vec![
                ("dispatch_shards", Value::num(sched.dispatch_shards as f64)),
                ("dispatch_locks", Value::num(sched.dispatch_locks as f64)),
                ("steal_attempts", Value::num(sched.steal_attempts as f64)),
                ("steal_successes", Value::num(sched.steal_successes as f64)),
                ("ready_depth", Value::num(sched.shard_depths.iter().sum::<usize>() as f64)),
            ]),
        ),
        (
            "verify",
            Value::obj(vec![
                ("replication", Value::num(vs.replication as f64)),
                ("quorum", Value::num(vs.quorum as f64)),
                ("votes", Value::num(vs.votes_recorded as f64)),
                ("verdicts", Value::num(vs.verdicts as f64)),
                ("flagged", Value::num(vs.votes_flagged as f64)),
                ("escalations", Value::num(vs.escalations as f64)),
                ("quarantines", Value::num(vs.quarantines as f64)),
                ("pending_quorum", Value::num(pending_quorum as f64)),
                ("cross_duplicates", Value::num(duplicates_cross as f64)),
                ("refused_requests", Value::num(refused_quarantine as f64)),
                ("adversaries", Value::num(adversaries as f64)),
                ("adversaries_lied", Value::num(adversaries_lied as f64)),
                ("adversaries_quarantined", Value::num(adversaries_quarantined as f64)),
                ("poisoned_completions", Value::num(poisoned_completions as f64)),
            ]),
        ),
        ("latency_ms", hist_json(&latency)),
        ("stranding_ms", hist_json(&stranding)),
        ("classes", class_json),
    ]);
    let metrics_json = metrics.to_string();

    let mut table = String::new();
    use std::fmt::Write as _;
    let _ = writeln!(
        table,
        "churn soak — {} workers, seed {}, {} ({} path)",
        cfg.workers,
        cfg.seed,
        if cfg.mean_lifetime_ms > 0 { "churning" } else { "stable" },
        if cfg.release_on_disconnect { "active" } else { "passive" },
    );
    let _ = writeln!(table, "  virtual time   {:.1} s", virtual_ms as f64 / 1000.0);
    let _ = writeln!(
        table,
        "  tickets        {}/{} done  ({} pending, {} in flight)",
        p.done, p.total, p.pending, p.in_flight
    );
    let _ = writeln!(
        table,
        "  dispatch       {} served, {} released, {} redistributed, {} duplicates, {} faults",
        dispatched, released, p.redistributions, duplicates, errors_injected
    );
    if cfg.store_cfg.verifying() {
        let _ = writeln!(
            table,
            "  verify         R={} Q={}: {} verdicts, {} flagged, {} escalations, {} quarantines, {}/{} adversaries caught, {} poisoned",
            vs.replication,
            vs.quorum,
            vs.verdicts,
            vs.votes_flagged,
            vs.escalations,
            vs.quarantines,
            adversaries_quarantined,
            adversaries_lied,
            poisoned_completions,
        );
    }
    let _ = writeln!(table, "  throughput     {:.2} tickets/s (virtual)", throughput);
    let _ = writeln!(
        table,
        "  latency ms     p50 {:.0}  p99 {:.0}  max {:.0}",
        latency.percentile(50.0),
        latency.percentile(99.0),
        latency.max()
    );
    let _ = writeln!(
        table,
        "  stranding ms   n {}  p50 {:.0}  max {:.0}",
        stranding.count(),
        stranding.percentile(50.0),
        stranding.max()
    );
    let _ = writeln!(
        table,
        "  churn          {} connections, {} vanishes, {} reloads, {} rescues, {} idle polls",
        connections, vanishes, reloads, rescues, idle_polls
    );
    for (i, c) in classes.iter().enumerate() {
        let share = if p.done > 0 {
            100.0 * completed_by_class[i] as f64 / p.done as f64
        } else {
            0.0
        };
        let _ = writeln!(
            table,
            "  class          {:<8} {:>6} workers  {:>8} done  {:>5.1}% of results",
            c.name, workers_by_class[i], completed_by_class[i], share
        );
    }
    if let Some((lr, reg)) = sweep_best {
        let _ = writeln!(table, "  sweep argmin   lr {lr}  reg {reg}");
    }

    Ok(SoakReport {
        metrics_json,
        table,
        trace,
        virtual_ms,
        total: p.total,
        done: p.done,
        pending: p.pending,
        in_flight: p.in_flight,
        redistributions: p.redistributions,
        dispatched,
        released,
        duplicates,
        errors_reported: p.errors,
        connections,
        vanishes,
        reloads,
        rescues,
        idle_polls,
        ghost_entries,
        ghosts_after_close,
        latency_p50_ms: latency.percentile(50.0),
        latency_p99_ms: latency.percentile(99.0),
        latency_max_ms: latency.max(),
        strand_count: stranding.count(),
        strand_p50_ms: stranding.percentile(50.0),
        max_strand_ms: stranding.max(),
        throughput_per_s: throughput,
        sweep_best,
        poisoned_completions,
        adversaries,
        adversaries_lied,
        adversaries_quarantined,
        verify: vs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(workers: usize, seed: u64) -> SoakConfig {
        let mut cfg = SoakConfig::new(workers, seed);
        cfg.duration_ms = 120_000;
        cfg.mean_lifetime_ms = 10_000;
        cfg
    }

    #[test]
    fn tiny_soak_completes_with_no_losses() {
        let r = run_soak(&tiny(24, 7)).unwrap();
        assert_eq!(r.done, r.total, "every ticket completes");
        assert_eq!((r.pending, r.in_flight), (0, 0), "conservation at rest");
        assert_eq!(r.ghost_entries, 0, "client table tracks the online fleet");
        assert_eq!(r.ghosts_after_close, 0, "no ghosts after shutdown");
        assert!(r.virtual_ms >= 120_000, "run covers the horizon");
        assert!(r.dispatched as usize >= r.total);
        assert!(r.vanishes > 0, "churn actually happened");
        assert_eq!(r.sweep_best, Some((sweep::OPT_LR, sweep::OPT_REG)));
        assert!(r.metrics_json.contains("\"workers\":24"));
        assert!(r.trace.last().unwrap().starts_with(&format!("t={}", r.virtual_ms)));
    }

    #[test]
    fn same_seed_is_byte_identical() {
        let a = run_soak(&tiny(16, 9)).unwrap();
        let b = run_soak(&tiny(16, 9)).unwrap();
        assert_eq!(a.metrics_json, b.metrics_json);
        assert_eq!(a.trace, b.trace);
        let c = run_soak(&tiny(16, 10)).unwrap();
        assert_ne!(a.trace, c.trace, "a different seed drives a different run");
    }

    #[test]
    fn adversaries_are_outvoted_and_quarantined() {
        let mut cfg = tiny(48, 13);
        cfg.store_cfg.replication = 3;
        cfg.store_cfg.quorum = 2;
        cfg.adversary_wrong_permille = 400;
        let r = run_soak(&cfg).unwrap();
        assert_eq!(r.done, r.total, "quorum verification still drains the pool");
        assert!(r.adversaries > 0, "the mix actually sampled adversaries");
        assert_eq!(r.poisoned_completions, 0, "lone liars can never reach quorum");
        assert_eq!(
            r.adversaries_quarantined, r.adversaries_lied,
            "every adversary that cast a fabricated ballot ends up quarantined"
        );
        assert_eq!(r.sweep_best, Some((sweep::OPT_LR, sweep::OPT_REG)));
        assert!(r.verify.verdicts as usize >= r.total);
        assert!(r.metrics_json.contains("\"poisoned_completions\":0"));
    }

    #[test]
    fn adversarial_same_seed_is_byte_identical() {
        for &(wrong, corrupt, collude) in &[(300u64, 0u64, 0u64), (150, 150, 0), (100, 50, 100)] {
            let mut cfg = tiny(16, 21);
            cfg.store_cfg.replication = 3;
            cfg.store_cfg.quorum = 2;
            cfg.adversary_wrong_permille = wrong;
            cfg.adversary_corrupt_permille = corrupt;
            cfg.adversary_collude_permille = collude;
            let a = run_soak(&cfg).unwrap();
            let b = run_soak(&cfg).unwrap();
            assert_eq!(a.metrics_json, b.metrics_json, "mix {wrong}/{corrupt}/{collude}");
            assert_eq!(a.trace, b.trace, "mix {wrong}/{corrupt}/{collude}");
        }
    }

    #[test]
    fn passive_mode_strands_into_the_redistribution_window() {
        let mut cfg = tiny(24, 11);
        cfg.release_on_disconnect = false;
        cfg.mean_lifetime_ms = 2_000; // everyone dies mid-batch
        cfg.duration_ms = 60_000;
        let r = run_soak(&cfg).unwrap();
        assert_eq!(r.done, r.total, "windows eventually recover everything");
        assert!(r.strand_count > 0, "passive churn strands tickets");
        let window = StoreConfig::default().requeue_after_ms as f64;
        assert!(
            r.max_strand_ms >= 100_000.0,
            "stranded tickets wait out a large part of the 5-min window, got {}",
            r.max_strand_ms
        );
        assert!(r.max_strand_ms <= window + 60_000.0);
        assert!(r.redistributions > 0);
        assert!(r.virtual_ms >= 300_000, "the run pushes past the window");
    }
}
