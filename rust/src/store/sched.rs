//! Indexed, sharded scheduling core — the production [`Scheduler`].
//!
//! The naive reference store answers every `TicketRequest` with a full
//! scan over *all* tickets (done ones included) under one global mutex.
//! This module keeps the paper's §2.1.2 policy bit-for-bit but replaces
//! the scan with indexes, and splits the state three ways so the hot
//! paths stop contending:
//!
//! * **Dispatch shards** (S small mutexes, [`ShardState`], S a power of
//!   two, default 1): each shard owns a VCT-ordered ready set
//!   `BTreeSet<(vct, id)>` whose first element is the
//!   `SELECT ... ORDER BY vct LIMIT 1` answer in O(log n), a
//!   last-distributed fallback set `BTreeSet<(last_dist, id)>` for the
//!   paper's min-redistribute rule, the per-ticket scheduling metadata
//!   (status/clock fields only — no payloads) for the tickets hashed to
//!   it (`id & (S-1)`), and its slice of the global counters plus the
//!   buffered error reports.  Done tickets are evicted from both sets,
//!   so dispatch cost tracks the *live* ticket count.  A dispatching
//!   client locks its *home* shard (hashed from the client name) and
//!   **work-steals** from sibling shards under `try_lock` when the home
//!   shard drains — one shard mutex held at a time, so stealing can
//!   never deadlock (see DESIGN.md §2.6 for the ordering relaxation
//!   this buys and what stays exact).
//! * **Ticket bodies** (N lock stripes keyed by `TicketId`): task name,
//!   payload, creation time.  Payload clones for the wire happen under a
//!   stripe read lock, never under a dispatch mutex.  Stripes and
//!   dispatch shards are independent dimensions: stripes spread *memory*
//!   traffic, shards spread the *decision* serialisation.
//! * **Per-task ledgers** (one mutex + condvar per task): incrementally
//!   maintained total/pending/in-flight/done counters (`progress` and
//!   `is_task_done` are O(1)), the accepted results, and the streaming
//!   completion FIFO.  Completion waits block on the task's own condvar,
//!   so finishing one task no longer wakes every waiter in the process.
//!   Every ticket body carries an `Arc` to its task's ledger, so the
//!   hot paths never consult the ledger registry (an `RwLock` map that
//!   only creation and first-time stream subscription write to);
//!   read-only polls of never-created tasks allocate nothing.
//!
//! With a single dispatch shard (the [`IndexedStore::new`] default) the
//! behaviour is bit-for-bit the pre-sharding store: one mutex, global
//! VCT order, and the differential suites against [`NaiveStore`] assert
//! exact equality.  With S > 1 ([`IndexedStore::sharded`] /
//! [`IndexedStore::with_dispatch_shards`]) the §2.1.2 policy holds
//! *per shard* — the global dispatch sequence is an interleaving of S
//! exact per-shard sequences (pinned by the shard-oracle differential
//! in `rust/tests/properties.rs`), while per-ticket guarantees
//! (at-least-once, no concurrent duplicate dispatch, redistribution
//! windows, first-result-wins) are unchanged because every ticket lives
//! in exactly one shard.
//!
//! Lock discipline: every lock here is a ranked
//! [`lockcheck`](crate::util::lockcheck) wrapper — verify state, then
//! dispatch shards, then body stripes, then the ledger registry, then
//! per-task ledgers, and blocking acquisition must ascend (full table
//! in `util::lockcheck`; debug builds panic on inversion).  Mostly the
//! code holds one lock at a time: no two dispatch-shard mutexes are
//! ever held at once (batch paths drop the current shard's guard
//! before locking the next; stealing uses `try_lock`, the witness's
//! escape hatch).  Consequence: per-task ledger counters may lag a
//! dispatch decision by a few instructions; counters are kept as
//! signed ints and clamped at the reporting edge, and every quiescent
//! value is exact (asserted by the differential property suite against
//! [`NaiveStore`]).
//!
//! [`NaiveStore`]: super::NaiveStore

use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::store::ticket::{canonical_hash, Rep, TicketVerify, VoteAction, TRUST_SCORE};
use crate::store::{
    deadline_after, wait_deadline, Progress, SchedStats, Scheduler, Standing, StoreConfig, TaskId,
    Ticket, TicketId, TicketStatus, Verdict, VerifyStats, VoteOutcome, ERROR_QUEUE_CAP,
};
use crate::util::json::Value;
use crate::util::lockcheck::{
    CheckedCondvar, CheckedMutex, CheckedMutexGuard, CheckedRwLock, Rank,
};

/// Default number of lock stripes for the ticket-body map.
pub const DEFAULT_SHARDS: usize = 16;

/// Ceiling for the auto-sized dispatch-shard count
/// ([`IndexedStore::sharded`]): beyond this the per-shard ready sets
/// get too shallow to amortise the steal scans.
const MAX_DISPATCH_SHARDS: usize = 64;

/// Scheduling metadata — everything `next_ticket` ordering needs,
/// deliberately payload-free so the dispatch mutex guards only small
/// state.
struct Meta {
    task: TaskId,
    created_ms: u64,
    status: TicketStatus,
    last_distributed_ms: Option<u64>,
    distribution_count: u32,
    /// Replication/vote state — `None` on every ticket at R = 1 (the
    /// legacy store pays one null pointer per ticket for the feature).
    verify: Option<Box<TicketVerify>>,
    /// Which client's vote completed the ticket at R = 1 — the
    /// same-client/cross-client duplicate split.  Best-effort, in-memory
    /// only (not WAL-logged or snapshotted: after recovery duplicates
    /// classify as cross-client).
    completed_by: Option<Box<str>>,
}

impl Meta {
    fn fresh(task: TaskId, created_ms: u64) -> Self {
        Self {
            task,
            created_ms,
            status: TicketStatus::Pending,
            last_distributed_ms: None,
            distribution_count: 0,
            verify: None,
            completed_by: None,
        }
    }
}

/// One dispatch shard: the §2.1.2 indexes and counters for the tickets
/// whose `id & (S-1)` hashes here, plus the shard's error-report queue
/// (per-shard so error reports never contend store-wide — ISSUE 7).
#[derive(Default)]
struct ShardState {
    meta: HashMap<u64, Meta>,
    /// (virtual created time, id) for every non-done ticket of the
    /// shard; the first element whose VCT has arrived is the dispatch
    /// pick.
    ready: BTreeSet<(u64, u64)>,
    /// (last distribution time or 0, id) for every non-done ticket; the
    /// min-redistribute fallback ordering.
    fallback: BTreeSet<(u64, u64)>,
    // Per-shard counters, maintained with the status transitions;
    // `progress(None)` sums them across shards.
    total: usize,
    pending: usize,
    in_flight: usize,
    done: usize,
    redistributions: u64,
    duplicate_results: u64,
    /// Buffered error reports for this shard's tickets, oldest first;
    /// drained shard-major by [`Scheduler::drain_errors`], capped at
    /// [`ERROR_QUEUE_CAP`].
    errors: Vec<(TicketId, String)>,
    /// Reports dropped because the buffer was at its cap.
    errors_dropped: u64,
}

impl ShardState {
    /// Buffer an error report, dropping the overflow beyond
    /// [`ERROR_QUEUE_CAP`] (the cumulative store-wide count still sees
    /// every report).
    fn push_error(&mut self, id: TicketId, report: String) {
        if self.errors.len() < ERROR_QUEUE_CAP {
            self.errors.push((id, report));
        } else {
            self.errors_dropped += 1;
        }
    }
}

/// Store-wide verification state: per-client reputation plus the
/// verification counters.  Guarded by its own mutex, which — when a
/// path needs both — is always taken *before* any dispatch-shard mutex
/// (and never the other way around), extending the module's lock
/// discipline by one outermost level.  `BTreeMap` so stats and
/// quarantine listings iterate deterministically.
#[derive(Default)]
struct VerifyState {
    reps: BTreeMap<String, Rep>,
    votes_recorded: u64,
    verdicts: u64,
    votes_flagged: u64,
    escalations: u64,
    quarantines: u64,
}

impl VerifyState {
    fn standing_of(&mut self, client: &str, now_ms: u64) -> Standing {
        match self.reps.get_mut(client) {
            Some(r) => r.standing(now_ms),
            None => Standing::Normal,
        }
    }

    /// Apply a verdict's reputation consequences.
    fn apply_verdict_reps(&mut self, verdict: &Verdict, now_ms: u64) {
        for w in &verdict.winners {
            self.reps.entry(w.clone()).or_default().win();
        }
        for l in &verdict.losers {
            self.votes_flagged += 1;
            if self.reps.entry(l.clone()).or_default().lose(now_ms) {
                self.quarantines += 1;
            }
        }
    }

    fn apply_late_rep(&mut self, client: &str, won: bool, now_ms: u64) {
        if won {
            self.reps.entry(client.to_string()).or_default().win();
        } else {
            self.votes_flagged += 1;
            if self.reps.entry(client.to_string()).or_default().lose(now_ms) {
                self.quarantines += 1;
            }
        }
    }
}

/// Immutable ticket body; mutable scheduling state lives in [`Meta`],
/// results in the task ledger.
struct StoredTicket {
    task: TaskId,
    task_name: Arc<str>,
    index: usize,
    payload: Value,
    created_ms: u64,
    /// The owning task's ledger, cached at creation so the hot paths
    /// (dispatch/complete/requeue) never touch the ledger registry.
    ledger: Arc<TaskLedger>,
}

#[derive(Default)]
struct LedgerState {
    // Signed: a dispatch may decrement `pending` here before the racing
    // create's increment lands (see module doc); clamped when reported.
    total: i64,
    pending: i64,
    in_flight: i64,
    done: i64,
    /// Accepted (index, ticket id, result) triples; sorted by
    /// (index, id) at collection — id as tie-break so repeated indexes
    /// (one task fed by several `create_tickets` batches) collect in
    /// the same order the reference store's id-ordered scan yields.
    results: Vec<(usize, u64, Value)>,
    /// Streaming FIFO consumed by `next_completion`.
    completions: VecDeque<(usize, Value)>,
}

struct TaskLedger {
    state: CheckedMutex<LedgerState>,
    cv: CheckedCondvar,
}

impl Default for TaskLedger {
    fn default() -> Self {
        TaskLedger {
            state: CheckedMutex::new(Rank::task_ledger(), LedgerState::default()),
            cv: CheckedCondvar::new(),
        }
    }
}

/// Virtual created time of a ticket (the paper's ordering key).  At
/// R > 1 an undecided ticket still recruiting replicas keys at its
/// creation time — it must reach additional distinct clients now, not
/// after the redistribution window.  Every verify mutation that can
/// change `needs_recruits` re-keys the ready index accordingly.
fn vct_of(cfg: &StoreConfig, m: &Meta) -> u64 {
    if let Some(v) = &m.verify {
        if v.needs_recruits() {
            return m.created_ms;
        }
    }
    match m.last_distributed_ms {
        None => m.created_ms,
        Some(d) => d + cfg.requeue_after_ms,
    }
}

/// One ticket's full durable state: scheduling metadata ([`Meta`]) plus
/// the stored body, flattened for [`StoreSnapshot`].
pub(crate) struct TicketSnapshot {
    pub(crate) id: u64,
    pub(crate) task: TaskId,
    pub(crate) task_name: String,
    pub(crate) index: usize,
    pub(crate) payload: Value,
    pub(crate) created_ms: u64,
    pub(crate) status: TicketStatus,
    pub(crate) last_distributed_ms: Option<u64>,
    pub(crate) distribution_count: u32,
    /// Replication/vote state; `None` on every ticket at R = 1 (legacy
    /// snapshots are unchanged).
    pub(crate) verify: Option<TicketVerify>,
}

/// One task ledger's durable state.  Counters are *not* snapshotted —
/// [`IndexedStore::restore`] recomputes them from the tickets, so a
/// snapshot can never smuggle in a counter/ticket mismatch.
pub(crate) struct LedgerSnapshot {
    pub(crate) task: TaskId,
    /// Accepted (index, ticket id, result) triples, in completion order.
    pub(crate) results: Vec<(usize, u64, Value)>,
    /// The unconsumed streaming FIFO, front first.
    pub(crate) completions: Vec<(usize, Value)>,
}

/// Everything needed to rebuild an [`IndexedStore`] bit-for-bit: the WAL
/// checkpoint payload (`store::wal`).
pub(crate) struct StoreSnapshot {
    pub(crate) cfg: StoreConfig,
    pub(crate) next_id: u64,
    pub(crate) redistributions: u64,
    pub(crate) duplicate_results: u64,
    pub(crate) errors_reported: u64,
    /// Dispatch-shard count of the snapshotted store; restore rebuilds
    /// with the same count so the per-shard VCT sequences (and the
    /// shard-major error-buffer order) continue exactly.
    pub(crate) dispatch_shards: usize,
    /// Sorted by id, so snapshots of identical stores are byte-identical.
    pub(crate) tickets: Vec<TicketSnapshot>,
    /// Sorted by task id.
    pub(crate) ledgers: Vec<LedgerSnapshot>,
    /// The buffered (undrained) error reports, shard-major (shard 0's
    /// queue first), oldest first within a shard — the exact
    /// [`Scheduler::drain_errors`] order.
    pub(crate) errors: Vec<(TicketId, String)>,
    /// Per-client reputation, sorted by client name; empty at R = 1.
    pub(crate) reps: Vec<(String, Rep)>,
    /// Verification counters: (votes_recorded, verdicts, votes_flagged,
    /// escalations, quarantines); all zero at R = 1.
    pub(crate) verify_counters: [u64; 5],
}

/// The indexed, sharded ticket store (aliased as
/// [`TicketStore`](super::TicketStore)).
pub struct IndexedStore {
    cfg: StoreConfig,
    next_id: AtomicU64,
    /// The dispatch shards; length is a power of two, ticket `id` maps
    /// to shard `id & shard_mask`.
    dispatch: Vec<CheckedMutex<ShardState>>,
    shard_mask: u64,
    shards: Vec<CheckedRwLock<HashMap<u64, StoredTicket>>>,
    ledgers: CheckedRwLock<HashMap<TaskId, Arc<TaskLedger>>>,
    /// Cumulative reports ever recorded (drain-proof, shown on console).
    errors_reported: AtomicUsize,
    /// Reputation + verification counters (R > 1; untouched at R = 1).
    /// Lock order: this mutex is outermost among the in-store locks —
    /// taken before any dispatch shard mutex, never after one (rank
    /// `verify_state`, enforced by the lockcheck witness).
    verify: CheckedMutex<VerifyState>,
    // Contention observability (ISSUE 7): surfaced by `stats()`.
    dispatch_locks: AtomicU64,
    steal_attempts: AtomicU64,
    steal_successes: AtomicU64,
}

impl IndexedStore {
    /// Store with the default [`DEFAULT_SHARDS`] ticket-body stripes and
    /// a **single** dispatch shard — the exact single-queue §2.1.2
    /// semantics every existing consumer and differential suite pins.
    pub fn new(cfg: StoreConfig) -> Self {
        Self::with_layout(cfg, DEFAULT_SHARDS, 1)
    }

    /// Store with an explicit body-stripe count (property tests sweep
    /// 1..8 to prove striping never changes observable behaviour) and a
    /// single dispatch shard.
    pub fn with_shards(cfg: StoreConfig, n_shards: usize) -> Self {
        Self::with_layout(cfg, n_shards, 1)
    }

    /// Sharded-dispatch store: default stripes, explicit dispatch-shard
    /// count (rounded up to a power of two, min 1).
    pub fn with_dispatch_shards(cfg: StoreConfig, dispatch_shards: usize) -> Self {
        Self::with_layout(cfg, DEFAULT_SHARDS, dispatch_shards)
    }

    /// Sharded-dispatch store auto-sized to the host: dispatch-shard
    /// count = available parallelism rounded up to a power of two,
    /// capped at [`MAX_DISPATCH_SHARDS`].
    pub fn sharded(cfg: StoreConfig) -> Self {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8);
        Self::with_layout(cfg, DEFAULT_SHARDS, cores.min(MAX_DISPATCH_SHARDS))
    }

    /// The fully explicit constructor: `n_shards` body stripes (min 1)
    /// × `dispatch_shards` dispatch shards (rounded up to a power of
    /// two so the id→shard map is a mask, min 1).
    pub fn with_layout(cfg: StoreConfig, n_shards: usize, dispatch_shards: usize) -> Self {
        let n = n_shards.max(1);
        let d = dispatch_shards.max(1).next_power_of_two();
        Self {
            cfg,
            next_id: AtomicU64::new(0),
            dispatch: (0..d)
                .map(|i| CheckedMutex::new(Rank::dispatch_shard(i), ShardState::default()))
                .collect(),
            shard_mask: (d - 1) as u64,
            shards: (0..n)
                .map(|i| CheckedRwLock::new(Rank::body_stripe(i), HashMap::new()))
                .collect(),
            ledgers: CheckedRwLock::new(Rank::ledger_registry(), HashMap::new()),
            errors_reported: AtomicUsize::new(0),
            verify: CheckedMutex::new(Rank::verify_state(), VerifyState::default()),
            dispatch_locks: AtomicU64::new(0),
            steal_attempts: AtomicU64::new(0),
            steal_successes: AtomicU64::new(0),
        }
    }

    /// Number of dispatch shards (a power of two).
    pub fn dispatch_shard_count(&self) -> usize {
        self.dispatch.len()
    }

    /// Dispatch shard owning ticket `id`.
    pub(crate) fn dshard(&self, id: u64) -> usize {
        (id & self.shard_mask) as usize
    }

    /// Reserve `n` consecutive ticket ids without creating anything.
    /// The sharded WAL allocates first (so it knows which per-shard log
    /// streams a create touches and can lock them before mutating),
    /// then materialises via
    /// [`create_tickets_exact`](Self::create_tickets_exact).
    pub(crate) fn allocate_ids(&self, n: u64) -> u64 {
        self.next_id.fetch_add(n, Ordering::SeqCst)
    }

    /// Count a work-steal probe of a non-home shard (the sharded WAL
    /// runs its own steal scan over the log streams, so it reports
    /// through these instead of the in-store scan counters).
    pub(crate) fn note_steal_attempt(&self) {
        self.steal_attempts.fetch_add(1, Ordering::Relaxed);
    }

    /// Count a steal probe that actually yielded work.
    pub(crate) fn note_steal_success(&self) {
        self.steal_successes.fetch_add(1, Ordering::Relaxed);
    }

    /// A client's home shard (FNV-1a over the client name): the shard
    /// its dispatch scan starts from, so distinct clients spread their
    /// lock pressure instead of convoying on shard 0.
    pub(crate) fn home_shard(&self, client: &str) -> usize {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &b in client.as_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        (h & self.shard_mask) as usize
    }

    fn shard(&self, id: u64) -> &CheckedRwLock<HashMap<u64, StoredTicket>> {
        &self.shards[id as usize % self.shards.len()]
    }

    /// Get-or-create a task's ledger (read-lock fast path).  Only the
    /// paths that legitimately materialise a task use this: creation,
    /// and the streaming consumer that may subscribe before the first
    /// ticket exists.
    fn ledger(&self, task: TaskId) -> Arc<TaskLedger> {
        if let Some(ledger) = self.ledgers.read().unwrap().get(&task) {
            return Arc::clone(ledger);
        }
        Arc::clone(self.ledgers.write().unwrap().entry(task).or_default())
    }

    /// Read-only ledger lookup: polls for never-created tasks allocate
    /// nothing (absence means the empty, vacuously-done task).
    fn ledger_if_exists(&self, task: TaskId) -> Option<Arc<TaskLedger>> {
        self.ledgers.read().unwrap().get(&task).cloned()
    }

    /// The dispatch decision (under one shard's mutex): same pick as the
    /// naive scan, from the shard's index tops instead.  At R > 1 a
    /// client is excluded from tickets it already holds or has voted on
    /// (`verify` is `None` on every ticket at R = 1, so the exclusion
    /// check is a null test on the legacy path).
    fn pick(&self, s: &ShardState, now_ms: u64, client: &str) -> Option<u64> {
        let excluded = |id: u64| -> bool {
            s.meta[&id].verify.as_ref().map(|v| v.involves(client)).unwrap_or(false)
        };
        // Primary: the shard's first (vct, id) whose VCT has arrived and
        // that the client is not excluded from.
        for &(vct, id) in s.ready.iter() {
            if vct > now_ms {
                break;
            }
            if !excluded(id) {
                return Some(id);
            }
        }
        // Fallback: ascending (last_distributed, id).  Never-distributed
        // tickets key at 0 and are always eligible; distributed ones need
        // the min-redistribute window elapsed.  Eligibility is monotone
        // against the key, so the scan stops at the first keyed entry
        // that fails the window — only same-key (0) entries after an
        // ineligible one can still qualify.  Excluded entries are merely
        // skipped (exclusion is per client, not monotone in the key).
        for &(key, id) in s.fallback.iter() {
            let eligible = match s.meta[&id].last_distributed_ms {
                None => true,
                Some(d) => now_ms.saturating_sub(d) >= self.cfg.min_redistribute_ms,
            };
            if eligible {
                if !excluded(id) {
                    return Some(id);
                }
            } else if key > 0 {
                break;
            }
        }
        None
    }

    /// One dispatch decision + index/counter transition under the
    /// already-held shard guard: the shared core of
    /// [`Scheduler::next_ticket`] and the batched
    /// [`Scheduler::next_tickets`].  Returns `(id, distribution_count,
    /// was_pending)`.  `trusted` is the client's standing at call time
    /// (only consulted at R > 1, where a trusted first dispatchee fixes
    /// the recruitment target at 1 — the BOINC-style fast path).
    fn dispatch_one(
        &self,
        s: &mut ShardState,
        now_ms: u64,
        client: &str,
        trusted: bool,
    ) -> Option<(u64, u32, bool)> {
        let id = self.pick(s, now_ms, client)?;
        let m = s.meta.get_mut(&id).expect("picked ticket has meta");
        let old_vct = vct_of(&self.cfg, m);
        let old_fkey = m.last_distributed_ms.unwrap_or(0);
        let redistribution = m.distribution_count > 0;
        let was_pending = m.status == TicketStatus::Pending;
        m.status = TicketStatus::InFlight;
        m.last_distributed_ms = Some(now_ms);
        m.distribution_count += 1;
        let count = m.distribution_count;
        if self.cfg.verifying() {
            let quorum = self.cfg.quorum;
            let v = m
                .verify
                .get_or_insert_with(|| Box::new(TicketVerify::new(if trusted { 1 } else { quorum })));
            v.note_dispatch(client, self.cfg.replication);
        }
        // The new ready key is computed *after* every mutation: at R = 1
        // it is exactly the legacy `now + requeue_after`; at R > 1 a
        // still-recruiting ticket keys at its creation time instead.
        let new_vct = vct_of(&self.cfg, m);
        s.ready.remove(&(old_vct, id));
        s.ready.insert((new_vct, id));
        s.fallback.remove(&(old_fkey, id));
        s.fallback.insert((now_ms, id));
        if redistribution {
            s.redistributions += 1;
        }
        if was_pending {
            s.pending -= 1;
            s.in_flight += 1;
        }
        Some((id, count, was_pending))
    }

    /// Standing gate shared by every dispatch entry point: `None` when
    /// the client is quarantined (served `NoTicket`), otherwise whether
    /// it is currently trusted.  A no-op `Some(false)` at R = 1.
    fn dispatch_gate(&self, client: &str, now_ms: u64) -> Option<bool> {
        if !self.cfg.verifying() {
            return Some(false);
        }
        match self.verify.lock().unwrap().standing_of(client, now_ms) {
            Standing::Quarantined { .. } => None,
            s => Some(s == Standing::Trusted),
        }
    }

    /// The pool-return transition shared by the error requeue and the
    /// explicit release — DESIGN.md §2.4 declares them identical, so
    /// they run the same code: if `id` is in flight, flip it to
    /// pending, reset its VCT to the creation time, re-arm both
    /// indexes and move the shard counters.  Caller holds the owning
    /// shard's mutex; returns whether the ticket moved.
    fn requeue_one(&self, s: &mut ShardState, id: u64) -> bool {
        let info = match s.meta.get_mut(&id) {
            Some(m) if m.status == TicketStatus::InFlight => {
                let old_vct = vct_of(&self.cfg, m);
                let old_fkey = m.last_distributed_ms.unwrap_or(0);
                m.status = TicketStatus::Pending;
                m.last_distributed_ms = None; // VCT back to creation time
                Some((old_vct, old_fkey, m.created_ms))
            }
            _ => None,
        };
        match info {
            Some((old_vct, old_fkey, created_ms)) => {
                s.ready.remove(&(old_vct, id));
                s.ready.insert((created_ms, id));
                s.fallback.remove(&(old_fkey, id));
                s.fallback.insert((0, id));
                s.in_flight -= 1;
                s.pending += 1;
                true
            }
            None => false,
        }
    }

    /// The *clientless* pool-return at R > 1 (release / unattributed
    /// error): every holder is cleared (no attribution to keep), and the
    /// ticket returns to the pool only when no ballots are pending on
    /// it.  `allow_requeue` gates the status flip (false for error
    /// reports with `requeue_on_error` off — holders still clear).
    /// Both index keys are re-computed after the verify mutation, which
    /// can change `needs_recruits` and therefore the ready key even
    /// when the status does not move.  Delegates to the bit-exact
    /// legacy [`requeue_one`](Self::requeue_one) at R = 1.
    fn requeue_clientless(&self, s: &mut ShardState, id: u64, allow_requeue: bool) -> bool {
        if !self.cfg.verifying() {
            return if allow_requeue { self.requeue_one(s, id) } else { false };
        }
        let m = match s.meta.get_mut(&id) {
            Some(m) => m,
            None => return false,
        };
        let old_vct = vct_of(&self.cfg, m);
        let old_fkey = m.last_distributed_ms.unwrap_or(0);
        let has_votes = match m.verify.as_deref_mut() {
            Some(v) => {
                v.holders.clear();
                !v.votes.is_empty()
            }
            None => false,
        };
        if m.status == TicketStatus::Done {
            return false; // done tickets are not indexed: nothing to re-key
        }
        let moved = allow_requeue && m.status == TicketStatus::InFlight && !has_votes;
        if moved {
            m.status = TicketStatus::Pending;
            m.last_distributed_ms = None; // VCT back to creation time
        }
        let new_vct = vct_of(&self.cfg, m);
        let new_fkey = m.last_distributed_ms.unwrap_or(0);
        if new_vct != old_vct {
            s.ready.remove(&(old_vct, id));
            s.ready.insert((new_vct, id));
        }
        if new_fkey != old_fkey {
            s.fallback.remove(&(old_fkey, id));
            s.fallback.insert((new_fkey, id));
        }
        if moved {
            s.in_flight -= 1;
            s.pending += 1;
        }
        moved
    }

    /// The *attributed* holder removal at R > 1: only `client` leaves
    /// the holder set; the ticket returns to the pool only when that
    /// removal left no participants at all (other replicas keep
    /// working).  Returns `(released, moved)` — `released` is the
    /// [`Scheduler::release_batch_from`] flag, `moved` drives the
    /// ledger counters.  `require_released` distinguishes the release
    /// path (a no-op release cannot requeue) from the error path (an
    /// error from a non-holder may still return an otherwise-empty
    /// ticket).  Caller holds the owning shard's mutex; R > 1 only.
    fn release_from_one(
        &self,
        s: &mut ShardState,
        id: u64,
        client: &str,
        allow_requeue: bool,
        require_released: bool,
    ) -> (bool, bool) {
        let m = match s.meta.get_mut(&id) {
            Some(m) => m,
            None => return (false, false),
        };
        let old_vct = vct_of(&self.cfg, m);
        let old_fkey = m.last_distributed_ms.unwrap_or(0);
        let (released, empty) = match m.verify.as_deref_mut() {
            Some(v) => (v.release_from(client), v.holders.is_empty() && v.votes.is_empty()),
            None => (false, true),
        };
        if m.status == TicketStatus::Done {
            return (released, false);
        }
        let moved = allow_requeue
            && m.status == TicketStatus::InFlight
            && empty
            && (released || !require_released);
        if moved {
            m.status = TicketStatus::Pending;
            m.last_distributed_ms = None; // VCT back to creation time
        }
        let new_vct = vct_of(&self.cfg, m);
        let new_fkey = m.last_distributed_ms.unwrap_or(0);
        if new_vct != old_vct {
            s.ready.remove(&(old_vct, id));
            s.ready.insert((new_vct, id));
        }
        if new_fkey != old_fkey {
            s.fallback.remove(&(old_fkey, id));
            s.fallback.insert((new_fkey, id));
        }
        if moved {
            s.in_flight -= 1;
            s.pending += 1;
        }
        (released, moved)
    }

    /// In-flight→pending ledger counter move for `id` (the tail of
    /// every requeue path), via the body's cached ledger `Arc`.
    fn ledger_requeue(&self, id: u64) {
        let ledger = {
            let shard = self.shard(id).read().unwrap();
            let body = shard.get(&id).expect("requeued ticket has a stored body");
            Arc::clone(&body.ledger)
        };
        let mut st = ledger.state.lock().unwrap();
        st.in_flight -= 1;
        st.pending += 1;
    }

    /// Phases 2–3 of a batched dispatch, shared by
    /// [`Scheduler::next_tickets`] and the per-shard
    /// [`next_tickets_from_shard`](Self::next_tickets_from_shard):
    /// clone the picked bodies (each stripe read-locked once) and move
    /// the pending→in-flight ledger counters (one lock per task).  The
    /// same id may appear twice (zero min-redistribute window re-issues
    /// within the batch); each occurrence gets its own clone.
    fn clone_dispatched(
        &self,
        picks: &[(u64, u32, bool)],
        client: &str,
        now_ms: u64,
    ) -> Vec<Ticket> {
        let n_stripes = self.shards.len();
        let mut by_stripe: Vec<Vec<usize>> = vec![Vec::new(); n_stripes];
        for (pos, &(id, _, _)) in picks.iter().enumerate() {
            by_stripe[id as usize % n_stripes].push(pos);
        }
        let mut out: Vec<Option<Ticket>> = (0..picks.len()).map(|_| None).collect();
        // Pending→in-flight ledger moves, grouped per task.
        let mut moves: Vec<(TaskId, Arc<TaskLedger>, i64)> = Vec::new();
        for (stripe, positions) in by_stripe.into_iter().enumerate() {
            if positions.is_empty() {
                continue;
            }
            let shard = self.shards[stripe].read().unwrap();
            for pos in positions {
                let (id, count, was_pending) = picks[pos];
                let body = shard.get(&id).expect("indexed ticket has a stored body");
                out[pos] = Some(Ticket {
                    id: TicketId(id),
                    task: body.task,
                    task_name: body.task_name.to_string(),
                    index: body.index,
                    payload: body.payload.clone(),
                    created_ms: body.created_ms,
                    status: TicketStatus::InFlight,
                    last_distributed_ms: Some(now_ms),
                    distribution_count: count,
                    result: None,
                    assigned_to: Some(client.to_string()),
                });
                if was_pending {
                    match moves.iter_mut().find(|(t, _, _)| *t == body.task) {
                        Some((_, _, n)) => *n += 1,
                        None => moves.push((body.task, Arc::clone(&body.ledger), 1)),
                    }
                }
            }
        }
        for (_, ledger, n) in moves {
            let mut st = ledger.state.lock().unwrap();
            st.pending -= n;
            st.in_flight += n;
        }
        out.into_iter().map(|t| t.expect("every pick got its body")).collect()
    }

    /// Batched dispatch restricted to one shard (blocking lock, no
    /// stealing): up to `k` [`dispatch_one`](Self::dispatch_one)
    /// decisions under that shard's mutex, then the shared body/ledger
    /// phases.  `store::wal`'s sharded mode dispatches through this so
    /// each decision run is logged to exactly one per-shard stream (and
    /// replays it with the same call, cross-checking the picks).
    pub(crate) fn next_tickets_from_shard(
        &self,
        shard: usize,
        client: &str,
        now_ms: u64,
        k: usize,
    ) -> Vec<Ticket> {
        if k == 0 {
            return Vec::new();
        }
        let trusted = match self.dispatch_gate(client, now_ms) {
            Some(t) => t,
            None => return Vec::new(), // quarantined: served nothing
        };
        let picks: Vec<(u64, u32, bool)> = {
            let mut s = self.dispatch[shard].lock().unwrap();
            self.dispatch_locks.fetch_add(1, Ordering::Relaxed);
            let mut picks = Vec::with_capacity(k.min(64));
            while picks.len() < k {
                match self.dispatch_one(&mut s, now_ms, client, trusted) {
                    Some(p) => picks.push(p),
                    None => break,
                }
            }
            picks
        };
        if picks.is_empty() {
            return Vec::new();
        }
        self.clone_dispatched(&picks, client, now_ms)
    }

    /// Apply a batch of completions in order with per-entry
    /// [`Scheduler::complete`] semantics.  Consecutive same-shard
    /// entries share one shard-mutex acquisition — with a single
    /// dispatch shard that is one acquisition for the whole batch (the
    /// PR 4 amortisation, unchanged); the held guard is dropped before
    /// the next shard's mutex is taken, so no two shard locks are ever
    /// held at once.  Returns the accepted/duplicate flag for every
    /// entry actually applied, plus the error (if any) that stopped the
    /// batch — entries before it stay applied, exactly like a
    /// hand-written `complete` loop.  Shared by the trait impl and by
    /// [`wal`](super::wal)'s `CompleteBatch` record, which needs the
    /// per-entry flags for its replay cross-check.
    ///
    /// `voter` attributes the completion (the R = 1 [`Scheduler::vote`]
    /// path): an accepted entry records the completer so a later
    /// duplicate can be split into same-client retry vs. cross-client
    /// duplicate — the second flag of each returned pair.  `None` (the
    /// legacy clientless paths) records nothing and classifies every
    /// duplicate as cross-client.  At R > 1 a clientless completion
    /// stays authoritative: it seals an undecided verify entry so late
    /// ballots are judged against the accepted hash.
    pub(crate) fn complete_batch_flags(
        &self,
        results: Vec<(TicketId, Value)>,
        voter: Option<&str>,
    ) -> (Vec<(bool, bool)>, Option<anyhow::Error>) {
        // Phase 1: stripe lookups (never under a dispatch mutex).
        let mut entries: Vec<(TicketId, Value, usize, TaskId, Arc<TaskLedger>)> =
            Vec::with_capacity(results.len());
        let mut stopped: Option<anyhow::Error> = None;
        for (id, value) in results {
            let found = {
                let shard = self.shard(id.0).read().unwrap();
                shard.get(&id.0).map(|t| (t.index, t.task, Arc::clone(&t.ledger)))
            };
            match found {
                Some((index, task, ledger)) => entries.push((id, value, index, task, ledger)),
                None => {
                    stopped = Some(anyhow!("unknown ticket {id:?}"));
                    break;
                }
            }
        }
        // Phase 2: status transitions, batched per dispatch shard run.
        let mut flags: Vec<(bool, bool)> = Vec::with_capacity(entries.len());
        let mut pendings: Vec<bool> = Vec::with_capacity(entries.len());
        {
            let mut cur_shard = usize::MAX;
            let mut guard: Option<CheckedMutexGuard<'_, ShardState>> = None;
            for (id, value, _, _, _) in &entries {
                let sh = self.dshard(id.0);
                if sh != cur_shard {
                    // Drop the held guard *before* locking the next
                    // shard: one shard mutex at a time, no deadlock.
                    guard = None;
                    guard = Some(self.dispatch[sh].lock().unwrap());
                    cur_shard = sh;
                }
                let s = guard.as_mut().expect("guard set for current shard");
                let status = match s.meta.get(&id.0) {
                    Some(m) => m.status,
                    None => {
                        // Body present but meta not yet published (a
                        // racing create): stop here, prefix applied.
                        stopped = Some(anyhow!("unknown ticket {id:?}"));
                        break;
                    }
                };
                if status == TicketStatus::Done {
                    s.duplicate_results += 1;
                    let m = s.meta.get_mut(&id.0).expect("checked above");
                    let same_client = match voter {
                        Some(c) => m.completed_by.as_deref() == Some(c),
                        None => false,
                    };
                    flags.push((false, same_client));
                    pendings.push(false);
                    continue;
                }
                let m = s.meta.get_mut(&id.0).expect("checked above");
                let was_pending = m.status == TicketStatus::Pending;
                let old_vct = vct_of(&self.cfg, m);
                let old_fkey = m.last_distributed_ms.unwrap_or(0);
                m.status = TicketStatus::Done;
                if let Some(c) = voter {
                    m.completed_by = Some(c.into());
                }
                // Clientless completion at R > 1 stays authoritative (it
                // bypasses quorum); seal the verify entry so late ballots
                // are judged against the accepted hash.
                if self.cfg.verifying() {
                    if let Some(v) = m.verify.as_deref_mut() {
                        if v.decided.is_none() {
                            v.holders.clear();
                            v.decided = Some(Verdict {
                                ticket: *id,
                                hash: canonical_hash(value),
                                winners: Vec::new(),
                                losers: Vec::new(),
                            });
                        }
                    }
                }
                s.ready.remove(&(old_vct, id.0));
                s.fallback.remove(&(old_fkey, id.0));
                if was_pending {
                    s.pending -= 1;
                } else {
                    s.in_flight -= 1;
                }
                s.done += 1;
                flags.push((true, false));
                pendings.push(was_pending);
            }
        }
        entries.truncate(flags.len());
        // Phase 3: ledger results + counters; consecutive same-task
        // entries share one lock acquisition and one wakeup (the common
        // whole-batch-one-task case).
        let mut i = 0usize;
        while i < entries.len() {
            let task = entries[i].3;
            let ledger = Arc::clone(&entries[i].4);
            let mut any = false;
            {
                let mut st = ledger.state.lock().unwrap();
                while i < entries.len() && entries[i].3 == task {
                    if flags[i].0 {
                        let index = entries[i].2;
                        let id = (entries[i].0).0;
                        let value = std::mem::replace(&mut entries[i].1, Value::Null);
                        if pendings[i] {
                            st.pending -= 1;
                        } else {
                            st.in_flight -= 1;
                        }
                        st.done += 1;
                        st.results.push((index, id, value.clone()));
                        st.completions.push_back((index, value));
                        any = true;
                    }
                    i += 1;
                }
            }
            if any {
                ledger.cv.notify_all();
            }
        }
        (flags, stopped)
    }

    /// Create tickets with caller-chosen ids — the WAL's sharded replay
    /// path, where `Create` records are split per shard stream and must
    /// re-insert exactly the original ids (re-running the id allocator
    /// in merge order could renumber).  `next_id` is bumped past the
    /// highest id so post-recovery creates never collide.  Same
    /// publication order as [`Scheduler::create_tickets`]: ledger,
    /// bodies, then dispatch indexes.
    pub(crate) fn create_tickets_exact(
        &self,
        task: TaskId,
        task_name: &str,
        items: Vec<(u64, usize, Value)>,
        now_ms: u64,
    ) {
        let n = items.len();
        if n == 0 {
            return;
        }
        let max_id = items.iter().map(|&(id, _, _)| id).max().expect("non-empty");
        self.next_id.fetch_max(max_id + 1, Ordering::SeqCst);
        // Ledger first: by the time a ticket is dispatchable (indexed
        // below), its task totals are already counted.
        let ledger = self.ledger(task);
        {
            let mut st = ledger.state.lock().unwrap();
            st.total += n as i64;
            st.pending += n as i64;
        }
        // Bodies next, so a dispatch pick always finds its payload;
        // grouped so each stripe lock is taken once, the name shared.
        let task_name: Arc<str> = Arc::from(task_name);
        let n_stripes = self.shards.len();
        let mut ids: Vec<u64> = Vec::with_capacity(n);
        let mut by_stripe: Vec<Vec<(u64, usize, Value)>> = vec![Vec::new(); n_stripes];
        for (id, index, payload) in items {
            ids.push(id);
            by_stripe[id as usize % n_stripes].push((id, index, payload));
        }
        for (stripe, stripe_items) in by_stripe.into_iter().enumerate() {
            if stripe_items.is_empty() {
                continue;
            }
            let mut shard = self.shards[stripe].write().unwrap();
            for (id, index, payload) in stripe_items {
                shard.insert(
                    id,
                    StoredTicket {
                        task,
                        task_name: Arc::clone(&task_name),
                        index,
                        payload,
                        created_ms: now_ms,
                        ledger: Arc::clone(&ledger),
                    },
                );
            }
        }
        // Publish to the dispatch indexes last, one shard mutex at a
        // time in ascending shard order.
        let nshards = self.dispatch.len();
        let mut by_dshard: Vec<Vec<u64>> = vec![Vec::new(); nshards];
        for id in ids {
            by_dshard[self.dshard(id)].push(id);
        }
        for (sh, shard_ids) in by_dshard.into_iter().enumerate() {
            if shard_ids.is_empty() {
                continue;
            }
            let count = shard_ids.len();
            let mut s = self.dispatch[sh].lock().unwrap();
            for id in shard_ids {
                s.meta.insert(id, Meta::fresh(task, now_ms));
                s.ready.insert((now_ms, id));
                s.fallback.insert((0, id));
            }
            s.total += count;
            s.pending += count;
        }
    }

    /// Drain one shard's error-report buffer.  `store::wal`'s sharded
    /// mode drains shard by shard under all its stream locks (one
    /// `DrainErrors` record covers the lot), producing exactly the
    /// shard-major order of [`Scheduler::drain_errors`].
    pub(crate) fn drain_errors_shard(&self, shard: usize) -> Vec<(TicketId, String)> {
        std::mem::take(&mut self.dispatch[shard].lock().unwrap().errors)
    }

    /// Capture the full durable state (the WAL checkpoint payload).
    ///
    /// Callers must guarantee no concurrent *mutation* of tickets or
    /// errors (`store::wal` holds its log mutex(es), which serialise
    /// every mutating op); concurrent reads and completion-FIFO
    /// consumption are harmless — consumption is not logged state (see
    /// [`wal`](super::wal) on at-least-once completion delivery).  The
    /// locks are taken one at a time, respecting the module's lock
    /// discipline.
    pub(crate) fn snapshot(&self) -> StoreSnapshot {
        // Verify state first (its mutex is outermost in the lock order;
        // here every lock is taken one at a time anyway).
        let (reps, verify_counters) = {
            let vs = self.verify.lock().unwrap();
            (
                vs.reps.iter().map(|(c, r)| (c.clone(), r.clone())).collect::<Vec<_>>(),
                [vs.votes_recorded, vs.verdicts, vs.votes_flagged, vs.escalations, vs.quarantines],
            )
        };
        let mut metas: Vec<(u64, TaskId, u64, TicketStatus, Option<u64>, u32, Option<TicketVerify>)> =
            Vec::new();
        let mut redistributions = 0u64;
        let mut duplicate_results = 0u64;
        let mut errors: Vec<(TicketId, String)> = Vec::new();
        for shard in &self.dispatch {
            let s = shard.lock().unwrap();
            for (&id, m) in s.meta.iter() {
                metas.push((
                    id,
                    m.task,
                    m.created_ms,
                    m.status,
                    m.last_distributed_ms,
                    m.distribution_count,
                    m.verify.as_deref().cloned(),
                ));
            }
            redistributions += s.redistributions;
            duplicate_results += s.duplicate_results;
            errors.extend(s.errors.iter().cloned());
        }
        metas.sort_by_key(|&(id, ..)| id);
        let tickets = metas
            .into_iter()
            .map(|(id, task, created_ms, status, last_distributed_ms, distribution_count, verify)| {
                let shard = self.shard(id).read().unwrap();
                let body = shard.get(&id).expect("every meta entry has a stored body");
                TicketSnapshot {
                    id,
                    task,
                    task_name: body.task_name.to_string(),
                    index: body.index,
                    payload: body.payload.clone(),
                    created_ms,
                    status,
                    last_distributed_ms,
                    distribution_count,
                    verify,
                }
            })
            .collect();
        let mut ledgers: Vec<LedgerSnapshot> = {
            let map = self.ledgers.read().unwrap();
            map.iter()
                .map(|(&task, ledger)| {
                    let st = ledger.state.lock().unwrap();
                    LedgerSnapshot {
                        task,
                        results: st.results.clone(),
                        completions: st.completions.iter().cloned().collect(),
                    }
                })
                .collect()
        };
        ledgers.sort_by_key(|l| l.task);
        StoreSnapshot {
            cfg: self.cfg.clone(),
            next_id: self.next_id.load(Ordering::SeqCst),
            redistributions,
            duplicate_results,
            errors_reported: self.errors_reported.load(Ordering::Relaxed) as u64,
            dispatch_shards: self.dispatch.len(),
            tickets,
            ledgers,
            errors,
            reps,
            verify_counters,
        }
    }

    /// Rebuild a store from a [`snapshot`](Self::snapshot): same dispatch
    /// shards, indexes, ledgers, counters and error buffers, so every
    /// subsequent operation behaves exactly as it would have on the
    /// original.
    pub(crate) fn restore(snap: StoreSnapshot) -> IndexedStore {
        let store = IndexedStore::with_layout(snap.cfg, DEFAULT_SHARDS, snap.dispatch_shards);
        store.next_id.store(snap.next_id, Ordering::SeqCst);
        store.errors_reported.store(snap.errors_reported as usize, Ordering::Relaxed);
        {
            let mut vs = store.verify.lock().unwrap();
            vs.reps = snap.reps.into_iter().collect();
            let [votes_recorded, verdicts, votes_flagged, escalations, quarantines] =
                snap.verify_counters;
            vs.votes_recorded = votes_recorded;
            vs.verdicts = verdicts;
            vs.votes_flagged = votes_flagged;
            vs.escalations = escalations;
            vs.quarantines = quarantines;
        }
        // The snapshot's error order is shard-major, so pushing by shard
        // of id reconstructs each per-shard queue in its original FIFO
        // order (the shard count is pinned by the snapshot).
        for (id, msg) in snap.errors {
            store.dispatch[store.dshard(id.0)].lock().unwrap().errors.push((id, msg));
        }
        // Ledgers first (results + FIFO), so ticket bodies can cache the
        // Arc exactly like create_tickets does.
        for l in snap.ledgers {
            let ledger = store.ledger(l.task);
            let mut st = ledger.state.lock().unwrap();
            st.results = l.results;
            st.completions = l.completions.into_iter().collect();
        }
        // Bodies + ledger counters first (recomputed from the tickets),
        // dispatch indexes last — the same publication order as
        // `create_tickets`, one lock at a time.
        let mut metas: Vec<(u64, Meta)> = Vec::with_capacity(snap.tickets.len());
        for t in snap.tickets {
            let ledger = store.ledger(t.task);
            {
                let mut st = ledger.state.lock().unwrap();
                st.total += 1;
                match t.status {
                    TicketStatus::Pending => st.pending += 1,
                    TicketStatus::InFlight => st.in_flight += 1,
                    TicketStatus::Done => st.done += 1,
                }
            }
            store.shard(t.id).write().unwrap().insert(
                t.id,
                StoredTicket {
                    task: t.task,
                    task_name: Arc::from(t.task_name.as_str()),
                    index: t.index,
                    payload: t.payload,
                    created_ms: t.created_ms,
                    ledger,
                },
            );
            metas.push((
                t.id,
                Meta {
                    task: t.task,
                    created_ms: t.created_ms,
                    status: t.status,
                    last_distributed_ms: t.last_distributed_ms,
                    distribution_count: t.distribution_count,
                    verify: t.verify.map(Box::new),
                    completed_by: None, // best-effort, not snapshotted
                },
            ));
        }
        let nshards = store.dispatch.len();
        let mut by_dshard: Vec<Vec<(u64, Meta)>> = (0..nshards).map(|_| Vec::new()).collect();
        for (id, meta) in metas {
            by_dshard[store.dshard(id)].push((id, meta));
        }
        for (sh, shard_metas) in by_dshard.into_iter().enumerate() {
            let mut s = store.dispatch[sh].lock().unwrap();
            // The global counters are not per-shard attributable from a
            // snapshot; they live on shard 0 and `progress` sums shards.
            if sh == 0 {
                s.redistributions = snap.redistributions;
                s.duplicate_results = snap.duplicate_results;
            }
            for (id, meta) in shard_metas {
                s.total += 1;
                match meta.status {
                    TicketStatus::Pending => s.pending += 1,
                    TicketStatus::InFlight => s.in_flight += 1,
                    TicketStatus::Done => s.done += 1,
                }
                if meta.status != TicketStatus::Done {
                    s.ready.insert((vct_of(&store.cfg, &meta), id));
                    s.fallback.insert((meta.last_distributed_ms.unwrap_or(0), id));
                }
                s.meta.insert(id, meta);
            }
        }
        store
    }
}

impl Scheduler for IndexedStore {
    fn config(&self) -> &StoreConfig {
        &self.cfg
    }

    fn create_tickets(
        &self,
        task: TaskId,
        task_name: &str,
        args: Vec<Value>,
        now_ms: u64,
    ) -> Vec<TicketId> {
        let n = args.len();
        let base = self.allocate_ids(n as u64);
        let items: Vec<(u64, usize, Value)> = args
            .into_iter()
            .enumerate()
            .map(|(index, payload)| (base + index as u64, index, payload))
            .collect();
        self.create_tickets_exact(task, task_name, items, now_ms);
        (base..base + n as u64).map(TicketId).collect()
    }

    fn next_ticket(&self, client: &str, now_ms: u64) -> Option<Ticket> {
        // Standing gate first (verify mutex, outermost, released before
        // any shard lock): quarantined clients are served nothing.
        let trusted = self.dispatch_gate(client, now_ms)?;
        // Home shard first (blocking), then steal from siblings under
        // try_lock — one shard mutex at a time, so no deadlock.
        let nshards = self.dispatch.len();
        let home = self.home_shard(client);
        let mut picked: Option<(u64, u32, bool)> = None;
        for i in 0..nshards {
            let sh = (home + i) % nshards;
            let mut guard = if i == 0 {
                self.dispatch[sh].lock().unwrap()
            } else {
                self.steal_attempts.fetch_add(1, Ordering::Relaxed);
                match self.dispatch[sh].try_lock() {
                    Ok(g) => g,
                    Err(_) => continue, // a sibling owns it: skip, never wait
                }
            };
            self.dispatch_locks.fetch_add(1, Ordering::Relaxed);
            if let Some(p) = self.dispatch_one(&mut guard, now_ms, client, trusted) {
                if i > 0 {
                    self.steal_successes.fetch_add(1, Ordering::Relaxed);
                }
                picked = Some(p);
                break;
            }
        }
        let (id, count, was_pending) = picked?;
        let (ticket, ledger) = {
            let shard = self.shard(id).read().unwrap();
            let body = shard.get(&id).expect("indexed ticket has a stored body");
            (
                Ticket {
                    id: TicketId(id),
                    task: body.task,
                    task_name: body.task_name.to_string(),
                    index: body.index,
                    payload: body.payload.clone(),
                    created_ms: body.created_ms,
                    status: TicketStatus::InFlight,
                    last_distributed_ms: Some(now_ms),
                    distribution_count: count,
                    result: None,
                    assigned_to: Some(client.to_string()),
                },
                Arc::clone(&body.ledger),
            )
        };
        if was_pending {
            let mut st = ledger.state.lock().unwrap();
            st.pending -= 1;
            st.in_flight += 1;
        }
        Some(ticket)
    }

    /// The batched dispatch pick: drain the home shard first (blocking
    /// lock, up to `k` [`dispatch_one`] decisions under one
    /// acquisition — with one dispatch shard that is exactly the PR 4
    /// single-mutex batch), then work-steal the remainder from sibling
    /// shards under `try_lock`.  Body clones are grouped so each
    /// stripe's read lock is taken once, ledger counter moves grouped
    /// per task.
    ///
    /// [`dispatch_one`]: IndexedStore::dispatch_one
    fn next_tickets(&self, client: &str, now_ms: u64, k: usize) -> Vec<Ticket> {
        if k == 0 {
            return Vec::new();
        }
        if k == 1 {
            return self.next_ticket(client, now_ms).into_iter().collect();
        }
        let trusted = match self.dispatch_gate(client, now_ms) {
            Some(t) => t,
            None => return Vec::new(), // quarantined: served nothing
        };
        // Phase 1: dispatch decisions, home shard then steal scan.
        let nshards = self.dispatch.len();
        let home = self.home_shard(client);
        let mut picks: Vec<(u64, u32, bool)> = Vec::with_capacity(k.min(64));
        for i in 0..nshards {
            if picks.len() >= k {
                break;
            }
            let sh = (home + i) % nshards;
            let mut guard = if i == 0 {
                self.dispatch[sh].lock().unwrap()
            } else {
                self.steal_attempts.fetch_add(1, Ordering::Relaxed);
                match self.dispatch[sh].try_lock() {
                    Ok(g) => g,
                    Err(_) => continue, // a sibling owns it: skip, never wait
                }
            };
            self.dispatch_locks.fetch_add(1, Ordering::Relaxed);
            let before = picks.len();
            while picks.len() < k {
                match self.dispatch_one(&mut guard, now_ms, client, trusted) {
                    Some(p) => picks.push(p),
                    None => break,
                }
            }
            if i > 0 && picks.len() > before {
                self.steal_successes.fetch_add(1, Ordering::Relaxed);
            }
        }
        if picks.is_empty() {
            return Vec::new();
        }
        // Phases 2–3: body clones + ledger moves (shared helper).
        self.clone_dispatched(&picks, client, now_ms)
    }

    fn complete_batch(&self, results: Vec<(TicketId, Value)>) -> Result<usize> {
        let (flags, stopped) = self.complete_batch_flags(results, None);
        match stopped {
            Some(e) => Err(e),
            None => Ok(flags.iter().filter(|&&(f, _)| f).count()),
        }
    }

    fn complete(&self, id: TicketId, result: Value) -> Result<bool> {
        // One completion state machine: the singular path is a
        // one-entry batch, so the differential suites pin a single
        // implementation instead of two hand-synchronised copies.
        let (flags, stopped) = self.complete_batch_flags(vec![(id, result)], None);
        match stopped {
            Some(e) => Err(e),
            None => Ok(flags[0].0),
        }
    }

    fn vote(&self, client: &str, id: TicketId, result: Value, now_ms: u64) -> Result<VoteOutcome> {
        if !self.cfg.verifying() {
            // R = 1: bit-exact legacy complete, attributed so a later
            // duplicate splits into same-client retry vs. cross-client.
            let (flags, stopped) =
                self.complete_batch_flags(vec![(id, result)], Some(client));
            return match stopped {
                Some(e) => Err(e),
                None => Ok(match flags[0] {
                    (true, _) => VoteOutcome::Accepted { verdict: None },
                    (false, same_client) => VoteOutcome::Duplicate { same_client },
                }),
            };
        }
        // R > 1: the quorum state machine.  The verify mutex (outermost)
        // is held across the shard transition so standing reads, ballot
        // recording and reputation consequences are one atomic step.
        let mut vs = self.verify.lock().unwrap();
        let trusted = vs.standing_of(client, now_ms) == Standing::Trusted;
        let hash = canonical_hash(&result);
        let found = {
            let shard = self.shard(id.0).read().unwrap();
            shard.get(&id.0).map(|t| (t.index, Arc::clone(&t.ledger)))
        };
        let (index, ledger) = match found {
            Some(f) => f,
            None => return Err(anyhow!("unknown ticket {id:?}")),
        };
        // Decide(verdict, winning value, was_pending) escapes the shard
        // guard; the ledger phase runs after it drops.
        let decided: Option<(Verdict, Value, bool)> = {
            let mut s = self.dispatch[self.dshard(id.0)].lock().unwrap();
            let s = &mut *s;
            let status = match s.meta.get(&id.0) {
                Some(m) => m.status,
                None => return Err(anyhow!("unknown ticket {id:?}")),
            };
            if status == TicketStatus::Done {
                // Legacy duplicate accounting, now attributed — and a
                // late ballot still moves the straggler's reputation.
                s.duplicate_results += 1;
                let m = s.meta.get_mut(&id.0).expect("checked above");
                return Ok(match m.verify.as_deref_mut() {
                    Some(v) if v.has_voted(client) => VoteOutcome::Duplicate { same_client: true },
                    Some(v) => {
                        if let Some(won) = v.record_late_vote(client, hash) {
                            vs.apply_late_rep(client, won, now_ms);
                        }
                        VoteOutcome::Duplicate { same_client: false }
                    }
                    None => VoteOutcome::Duplicate { same_client: false },
                });
            }
            let quorum = self.cfg.quorum;
            let m = s.meta.get_mut(&id.0).expect("checked above");
            // Old index keys *before* the verify mutation: recording a
            // ballot can change `needs_recruits` and thus the ready key.
            let old_vct = vct_of(&self.cfg, m);
            let old_fkey = m.last_distributed_ms.unwrap_or(0);
            let action = m
                .verify
                .get_or_insert_with(|| Box::new(TicketVerify::new(quorum)))
                .record_vote(id, client, hash, &result, trusted, quorum);
            match action {
                VoteAction::Repeat => return Ok(VoteOutcome::Repeat),
                VoteAction::Pending { escalated } => {
                    vs.votes_recorded += 1;
                    if escalated {
                        vs.escalations += 1;
                    }
                    let new_vct = vct_of(&self.cfg, m);
                    if new_vct != old_vct {
                        s.ready.remove(&(old_vct, id.0));
                        s.ready.insert((new_vct, id.0));
                    }
                    return Ok(VoteOutcome::Pending);
                }
                VoteAction::Decide(verdict) => {
                    vs.votes_recorded += 1;
                    vs.verdicts += 1;
                    let winning = m.verify.as_deref().expect("just voted").winning_value();
                    let was_pending = m.status == TicketStatus::Pending;
                    m.status = TicketStatus::Done;
                    s.ready.remove(&(old_vct, id.0));
                    s.fallback.remove(&(old_fkey, id.0));
                    if was_pending {
                        s.pending -= 1;
                    } else {
                        s.in_flight -= 1;
                    }
                    s.done += 1;
                    vs.apply_verdict_reps(&verdict, now_ms);
                    Some((verdict, winning, was_pending))
                }
            }
        };
        drop(vs);
        let (verdict, winning, was_pending) = decided.expect("non-decide paths returned above");
        {
            let mut st = ledger.state.lock().unwrap();
            if was_pending {
                st.pending -= 1;
            } else {
                st.in_flight -= 1;
            }
            st.done += 1;
            st.results.push((index, id.0, winning.clone()));
            st.completions.push_back((index, winning));
        }
        ledger.cv.notify_all();
        Ok(VoteOutcome::Accepted { verdict: Some(verdict) })
    }

    fn report_error(&self, id: TicketId, report: String) -> Result<()> {
        self.errors_reported.fetch_add(1, Ordering::Relaxed);
        // The error buffer is per shard (drained shard-major), so
        // reports on different shards never contend; push and requeue
        // share the one shard acquisition.
        let requeued = {
            let mut s = self.dispatch[self.dshard(id.0)].lock().unwrap();
            s.push_error(id, report);
            self.requeue_clientless(&mut s, id.0, self.cfg.requeue_on_error)
        };
        if requeued {
            self.ledger_requeue(id.0);
        }
        Ok(())
    }

    fn report_error_from(&self, client: &str, id: TicketId, report: String) -> Result<()> {
        if !self.cfg.verifying() {
            return self.report_error(id, report);
        }
        self.errors_reported.fetch_add(1, Ordering::Relaxed);
        let requeued = {
            let mut s = self.dispatch[self.dshard(id.0)].lock().unwrap();
            s.push_error(id, report);
            // Only when the erroring client was the last participant
            // does the ticket return to the undistributed pool; other
            // replicas keep working and the freed slot re-recruits.
            self.release_from_one(&mut s, id.0, client, self.cfg.requeue_on_error, false).1
        };
        if requeued {
            self.ledger_requeue(id.0);
        }
        Ok(())
    }

    fn release(&self, id: TicketId) -> bool {
        // One release state machine: the singular path is a one-entry
        // batch (same pattern as `complete` → `complete_batch_flags`).
        self.release_batch(std::slice::from_ref(&id))[0]
    }

    /// The batched release: every status transition and index re-arm
    /// applied in order, consecutive same-shard entries sharing one
    /// shard-mutex acquisition (the whole batch, with one dispatch
    /// shard), then ledger counter moves grouped one lock per task —
    /// same observable result as the trait's id-by-id loop.
    fn release_batch(&self, ids: &[TicketId]) -> Vec<bool> {
        if ids.is_empty() {
            return Vec::new();
        }
        // Phase 1: pool-return transitions (shared with the error
        // requeue, [`requeue_one`](Self::requeue_one)) + index
        // re-arming, batched per shard run; the held guard drops
        // before the next shard's lock is taken.
        let flags: Vec<bool> = {
            let mut cur_shard = usize::MAX;
            let mut guard: Option<CheckedMutexGuard<'_, ShardState>> = None;
            ids.iter()
                .map(|&id| {
                    let sh = self.dshard(id.0);
                    if sh != cur_shard {
                        guard = None;
                        guard = Some(self.dispatch[sh].lock().unwrap());
                        cur_shard = sh;
                    }
                    let s = guard.as_mut().expect("guard set for current shard");
                    self.requeue_clientless(s, id.0, true)
                })
                .collect()
        };
        // Phase 2: ledger counters for the released entries — lookups
        // grouped so each stripe's read lock is taken once (as in the
        // batched dispatch), moves grouped one lock per task.  A
        // repeated id cannot be flagged twice (the second occurrence
        // found it already pending), so the counts stay exact.
        let n_stripes = self.shards.len();
        let mut by_stripe: Vec<Vec<u64>> = vec![Vec::new(); n_stripes];
        for (i, &id) in ids.iter().enumerate() {
            if flags[i] {
                by_stripe[id.0 as usize % n_stripes].push(id.0);
            }
        }
        let mut moves: Vec<(TaskId, Arc<TaskLedger>, i64)> = Vec::new();
        for (stripe, stripe_ids) in by_stripe.into_iter().enumerate() {
            if stripe_ids.is_empty() {
                continue;
            }
            let shard = self.shards[stripe].read().unwrap();
            for id in stripe_ids {
                let body = shard.get(&id).expect("released ticket has a stored body");
                match moves.iter_mut().find(|(t, _, _)| *t == body.task) {
                    Some((_, _, n)) => *n += 1,
                    None => moves.push((body.task, Arc::clone(&body.ledger), 1)),
                }
            }
        }
        for (_, ledger, n) in moves {
            let mut st = ledger.state.lock().unwrap();
            st.in_flight -= n;
            st.pending += n;
        }
        flags
    }

    /// The attributed batched release (R > 1): each entry removes only
    /// `client` from its ticket's holder set; the ticket requeues only
    /// when that removal emptied it.  Same shard-run batching and
    /// ledger grouping as [`release_batch`](Self::release_batch), which
    /// it delegates to outright at R = 1 (one holder per ticket).
    fn release_batch_from(&self, client: &str, ids: &[TicketId]) -> Vec<bool> {
        if !self.cfg.verifying() {
            return self.release_batch(ids);
        }
        if ids.is_empty() {
            return Vec::new();
        }
        // Phase 1: holder removal + (maybe) pool return, per shard run.
        let mut moved: Vec<bool> = Vec::with_capacity(ids.len());
        let released: Vec<bool> = {
            let mut cur_shard = usize::MAX;
            let mut guard: Option<CheckedMutexGuard<'_, ShardState>> = None;
            ids.iter()
                .map(|&id| {
                    let sh = self.dshard(id.0);
                    if sh != cur_shard {
                        guard = None;
                        guard = Some(self.dispatch[sh].lock().unwrap());
                        cur_shard = sh;
                    }
                    let s = guard.as_mut().expect("guard set for current shard");
                    let (rel, mv) = self.release_from_one(s, id.0, client, true, true);
                    moved.push(mv);
                    rel
                })
                .collect()
        };
        // Phase 2: ledger counters for the entries that actually moved.
        for (i, &id) in ids.iter().enumerate() {
            if moved[i] {
                self.ledger_requeue(id.0);
            }
        }
        released
    }

    fn client_standing(&self, client: &str, now_ms: u64) -> Standing {
        self.verify.lock().unwrap().standing_of(client, now_ms)
    }

    fn verify_stats(&self) -> VerifyStats {
        let vs = self.verify.lock().unwrap();
        VerifyStats {
            replication: self.cfg.replication,
            quorum: self.cfg.quorum,
            votes_recorded: vs.votes_recorded,
            verdicts: vs.verdicts,
            votes_flagged: vs.votes_flagged,
            escalations: vs.escalations,
            quarantines: vs.quarantines,
            quarantined_now: vs.reps.values().filter(|r| r.quarantined_until.is_some()).count(),
            trusted_now: vs
                .reps
                .values()
                .filter(|r| r.quarantined_until.is_none() && r.score >= TRUST_SCORE)
                .count(),
        }
    }

    fn quarantined_clients(&self) -> Vec<String> {
        let vs = self.verify.lock().unwrap();
        vs.reps.iter().filter(|(_, r)| r.ever_quarantined).map(|(c, _)| c.clone()).collect()
    }

    fn next_completion(&self, task: TaskId, timeout_ms: u64) -> Option<(usize, Value)> {
        let deadline = deadline_after(timeout_ms);
        let ledger = self.ledger(task);
        let mut st = ledger.state.lock().unwrap();
        loop {
            if let Some(front) = st.completions.pop_front() {
                return Some(front);
            }
            st = wait_deadline(&ledger.cv, st, deadline)?;
        }
    }

    fn progress(&self, task: Option<TaskId>) -> Progress {
        let errors = self.errors_reported.load(Ordering::Relaxed);
        // Sum the per-shard slices (one lock at a time); with one
        // dispatch shard this is the old single-mutex read.
        let mut g = Progress { errors, ..Default::default() };
        for shard in &self.dispatch {
            let s = shard.lock().unwrap();
            g.total += s.total;
            g.pending += s.pending;
            g.in_flight += s.in_flight;
            g.done += s.done;
            g.redistributions += s.redistributions;
            g.duplicate_results += s.duplicate_results;
        }
        let task = match task {
            None => return g,
            Some(t) => t,
        };
        // Per-task progress still reports the store-wide
        // redistribution/duplicate counters (console parity with the
        // reference store).
        let mut p = Progress {
            errors,
            redistributions: g.redistributions,
            duplicate_results: g.duplicate_results,
            ..Default::default()
        };
        if let Some(ledger) = self.ledger_if_exists(task) {
            let st = ledger.state.lock().unwrap();
            let clamp = |v: i64| v.max(0) as usize;
            p.total = clamp(st.total);
            p.pending = clamp(st.pending);
            p.in_flight = clamp(st.in_flight);
            p.done = clamp(st.done);
        }
        p
    }

    fn max_task_id(&self) -> Option<TaskId> {
        // Ledgers subscribed via `next_completion` but never given
        // tickets are excluded (total == 0), matching the reference
        // store's ticket-derived answer.
        self.ledgers
            .read()
            .unwrap()
            .iter()
            .filter(|(_, ledger)| ledger.state.lock().unwrap().total > 0)
            .map(|(&task, _)| task)
            .max()
    }

    fn is_task_done(&self, task: TaskId) -> bool {
        match self.ledger_if_exists(task) {
            Some(ledger) => {
                let st = ledger.state.lock().unwrap();
                st.done == st.total
            }
            // Never-created task: vacuously done (reference-store parity).
            None => true,
        }
    }

    fn wait_results_deadline(
        &self,
        task: TaskId,
        deadline: Option<Instant>,
    ) -> Option<Vec<Value>> {
        let ledger = match self.ledger_if_exists(task) {
            Some(ledger) => ledger,
            // Zero tickets: immediately complete with no results, like
            // the reference store's vacuous all-done scan.
            None => return Some(Vec::new()),
        };
        let mut st = ledger.state.lock().unwrap();
        loop {
            if st.done == st.total {
                let mut rows = st.results.clone();
                rows.sort_by_key(|&(index, id, _)| (index, id));
                return Some(rows.into_iter().map(|(_, _, v)| v).collect());
            }
            st = wait_deadline(&ledger.cv, st, deadline)?;
        }
    }

    fn error_count(&self) -> usize {
        self.errors_reported.load(Ordering::Relaxed)
    }

    fn drain_errors(&self) -> Vec<(TicketId, String)> {
        // Shard-major, one pass, one lock at a time: the documented
        // S > 1 ordering (exactly the old order with one shard).
        let mut out = Vec::new();
        for shard in &self.dispatch {
            out.append(&mut shard.lock().unwrap().errors);
        }
        out
    }

    fn stats(&self) -> SchedStats {
        let mut shard_depths = Vec::with_capacity(self.dispatch.len());
        let mut errors_dropped = 0u64;
        for shard in &self.dispatch {
            let s = shard.lock().unwrap();
            shard_depths.push(s.ready.len());
            errors_dropped += s.errors_dropped;
        }
        SchedStats {
            dispatch_shards: self.dispatch.len(),
            dispatch_locks: self.dispatch_locks.load(Ordering::Relaxed),
            steal_attempts: self.steal_attempts.load(Ordering::Relaxed),
            steal_successes: self.steal_successes.load(Ordering::Relaxed),
            shard_depths,
            errors_dropped,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> StoreConfig {
        StoreConfig {
            requeue_after_ms: 1000,
            min_redistribute_ms: 100,
            requeue_on_error: true,
            ..StoreConfig::default()
        }
    }

    /// The index tops must track every transition: dispatch, timeout
    /// redistribution, error requeue, completion eviction.
    #[test]
    fn indexes_follow_ticket_lifecycle() {
        let s = IndexedStore::with_shards(cfg(), 4);
        let ids =
            s.create_tickets(TaskId(1), "t", (0..3).map(|i| Value::num(i as f64)).collect(), 0);
        {
            let st = s.dispatch[0].lock().unwrap();
            assert_eq!(st.ready.len(), 3);
            assert_eq!(st.fallback.len(), 3);
            assert_eq!(st.ready.iter().next(), Some(&(0, ids[0].0)));
        }
        let t = s.next_ticket("c", 5).unwrap();
        assert_eq!(t.id, ids[0]);
        {
            let st = s.dispatch[0].lock().unwrap();
            // Dispatched ticket re-keyed to now + requeue window.
            assert!(st.ready.contains(&(1005, ids[0].0)));
            assert!(st.fallback.contains(&(5, ids[0].0)));
        }
        // Error requeue: VCT back to creation time, fallback key to 0.
        s.report_error(ids[0], "boom".into()).unwrap();
        {
            let st = s.dispatch[0].lock().unwrap();
            assert!(st.ready.contains(&(0, ids[0].0)));
            assert!(st.fallback.contains(&(0, ids[0].0)));
        }
        // Completion evicts from both indexes.
        let t = s.next_ticket("c", 6).unwrap();
        assert_eq!(t.id, ids[0]);
        s.complete(ids[0], Value::Null).unwrap();
        {
            let st = s.dispatch[0].lock().unwrap();
            assert_eq!(st.ready.len(), 2);
            assert_eq!(st.fallback.len(), 2);
            assert!(!st.ready.iter().any(|&(_, id)| id == ids[0].0));
        }
    }

    /// A batched release re-arms both dispatch indexes under one
    /// dispatch-mutex pass and keeps the O(1) ledgers exact.
    #[test]
    fn release_batch_rearms_indexes() {
        let s = IndexedStore::with_shards(cfg(), 4);
        let ids =
            s.create_tickets(TaskId(1), "t", (0..3).map(|i| Value::num(i as f64)).collect(), 0);
        let a = s.next_ticket("c", 5).unwrap();
        let b = s.next_ticket("c", 6).unwrap();
        let flags = s.release_batch(&[a.id, b.id, a.id, TicketId(99)]);
        assert_eq!(flags, vec![true, true, false, false]);
        {
            let st = s.dispatch[0].lock().unwrap();
            assert!(st.ready.contains(&(0, a.id.0)), "VCT re-armed to creation time");
            assert!(st.fallback.contains(&(0, a.id.0)), "fallback key re-armed to 0");
            assert!(st.ready.contains(&(0, b.id.0)));
        }
        let p = s.progress(None);
        assert_eq!((p.pending, p.in_flight), (3, 0));
        let p1 = s.progress(Some(TaskId(1)));
        assert_eq!((p1.pending, p1.in_flight), (3, 0));
        // Released tickets dispatch again immediately, oldest id first.
        assert_eq!(s.next_ticket("d", 7).unwrap().id, ids[0]);
        assert_eq!(s.progress(None).redistributions, 1);
    }

    /// Ticket ids spread across stripes, and bodies are found regardless
    /// of the stripe count.
    #[test]
    fn striping_covers_all_tickets() {
        for shards in [1, 3, 16] {
            let s = IndexedStore::with_shards(cfg(), shards);
            let ids = s.create_tickets(
                TaskId(1),
                "t",
                (0..20).map(|i| Value::num(i as f64)).collect(),
                0,
            );
            for (i, id) in ids.iter().enumerate() {
                let t = s.next_ticket("c", i as u64).unwrap();
                assert_eq!(t.id, *id);
                assert_eq!(t.index, i);
            }
        }
    }

    /// A single client drains a sharded store completely: the home
    /// shard empties, then the steal scan covers every sibling, so no
    /// ticket is stranded in an unvisited shard.
    #[test]
    fn sharded_dispatch_steals_across_all_shards() {
        for dshards in [2usize, 4, 8] {
            let s = IndexedStore::with_layout(cfg(), 4, dshards);
            let n = 40usize;
            s.create_tickets(TaskId(1), "t", (0..n).map(|i| Value::num(i as f64)).collect(), 0);
            let mut seen = std::collections::HashSet::new();
            while let Some(t) = s.next_ticket("c", 1) {
                assert!(seen.insert(t.id), "no duplicate dispatch in one pass");
                s.complete(t.id, Value::Null).unwrap();
            }
            assert_eq!(seen.len(), n, "steal scan reaches every shard");
            let p = s.progress(None);
            assert_eq!((p.done, p.pending, p.in_flight), (n, 0, 0));
            let st = s.stats();
            assert_eq!(st.dispatch_shards, dshards);
            assert_eq!(st.shard_depths.len(), dshards);
            assert!(st.steal_attempts > 0, "draining visits sibling shards");
            assert!(st.steal_successes > 0, "siblings actually yielded work");
            assert!(st.steal_successes <= st.steal_attempts);
        }
    }

    /// Within one shard the §2.1.2 policy is exact: tickets of the same
    /// shard dispatch in global VCT order even at S > 1.
    #[test]
    fn per_shard_vct_order_is_exact() {
        let s = IndexedStore::with_layout(cfg(), 4, 4);
        let ids =
            s.create_tickets(TaskId(1), "t", (0..16).map(|i| Value::num(i as f64)).collect(), 0);
        let mut order: Vec<u64> = Vec::new();
        while let Some(t) = s.next_ticket("c", 5) {
            order.push(t.id.0);
            s.complete(t.id, Value::Null).unwrap();
        }
        assert_eq!(order.len(), ids.len());
        // Restricted to any one shard, ids come out ascending (equal
        // creation time → (vct, id) order per shard).
        for sh in 0..4u64 {
            let shard_seq: Vec<u64> = order.iter().copied().filter(|id| id % 4 == sh).collect();
            let mut sorted = shard_seq.clone();
            sorted.sort_unstable();
            assert_eq!(shard_seq, sorted, "shard {sh} preserves VCT order");
        }
    }

    /// Batched dispatch at S > 1 drains the home shard then steals; the
    /// batch covers the whole pool when k is large enough.
    #[test]
    fn sharded_batch_dispatch_covers_pool() {
        let s = IndexedStore::with_layout(cfg(), 4, 4);
        let n = 32usize;
        s.create_tickets(TaskId(1), "t", (0..n).map(|i| Value::num(i as f64)).collect(), 0);
        let batch = s.next_tickets("c", 0, n);
        assert_eq!(batch.len(), n, "one batch drains every shard");
        let mut ids: Vec<u64> = batch.iter().map(|t| t.id.0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "no duplicate dispatch across shards");
        assert_eq!(s.progress(None).in_flight, n);
    }

    /// Concurrent clients hammering dispatch/complete across stripes
    /// neither lose nor double-complete tickets.
    #[test]
    fn concurrent_dispatch_is_exact() {
        let s = Arc::new(IndexedStore::new(StoreConfig {
            requeue_after_ms: 600_000,
            min_redistribute_ms: 600_000,
            requeue_on_error: true,
            ..StoreConfig::default()
        }));
        let n = 800usize;
        s.create_tickets(TaskId(1), "t", (0..n).map(|i| Value::num(i as f64)).collect(), 0);
        let handles: Vec<_> = (0..8)
            .map(|w| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || {
                    let client = format!("c{w}");
                    let mut served = 0u64;
                    while let Some(t) = s.next_ticket(&client, 1) {
                        assert!(s.complete(t.id, Value::num(t.index as f64)).unwrap());
                        served += 1;
                    }
                    served
                })
            })
            .collect();
        let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, n as u64);
        let p = s.progress(None);
        assert_eq!(p.done, n);
        assert_eq!(p.duplicate_results, 0);
        assert_eq!(s.wait_results(TaskId(1)).len(), n);
    }

    /// Concurrent clients draining the pool in batches neither lose nor
    /// double-complete tickets (the batched analogue of
    /// `concurrent_dispatch_is_exact`).
    #[test]
    fn concurrent_batched_dispatch_is_exact() {
        let s = Arc::new(IndexedStore::new(StoreConfig {
            requeue_after_ms: 600_000,
            min_redistribute_ms: 600_000,
            requeue_on_error: true,
            ..StoreConfig::default()
        }));
        let n = 960usize;
        s.create_tickets(TaskId(1), "t", (0..n).map(|i| Value::num(i as f64)).collect(), 0);
        let handles: Vec<_> = (0..6)
            .map(|w| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || {
                    let client = format!("c{w}");
                    let mut served = 0u64;
                    loop {
                        let batch = s.next_tickets(&client, 1, 16);
                        if batch.is_empty() {
                            break;
                        }
                        let results: Vec<_> =
                            batch.iter().map(|t| (t.id, Value::num(t.index as f64))).collect();
                        served += s.complete_batch(results).unwrap() as u64;
                    }
                    served
                })
            })
            .collect();
        let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, n as u64);
        let p = s.progress(None);
        assert_eq!(p.done, n);
        assert_eq!(p.duplicate_results, 0);
        assert_eq!(s.wait_results(TaskId(1)).len(), n);
    }

    /// The sharded analogue: many clients, many shards, batched
    /// dispatch + complete + release under steal pressure — conservation
    /// and no-duplicate-dispatch must hold exactly.
    #[test]
    fn concurrent_sharded_dispatch_is_exact() {
        let s = Arc::new(IndexedStore::with_layout(
            StoreConfig {
                requeue_after_ms: 600_000,
                min_redistribute_ms: 600_000,
                requeue_on_error: true,
                ..StoreConfig::default()
            },
            DEFAULT_SHARDS,
            8,
        ));
        let n = 1024usize;
        s.create_tickets(TaskId(1), "t", (0..n).map(|i| Value::num(i as f64)).collect(), 0);
        let handles: Vec<_> = (0..8)
            .map(|w| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || {
                    let client = format!("c{w}");
                    let mut served = 0u64;
                    loop {
                        let batch = s.next_tickets(&client, 1, 16);
                        if batch.is_empty() {
                            break;
                        }
                        // Release every third batch (steal-pressure on
                        // the re-armed tickets), complete the rest.
                        if served % 3 == 2 {
                            let ids: Vec<_> = batch.iter().map(|t| t.id).collect();
                            s.release_batch(&ids);
                        } else {
                            let results: Vec<_> = batch
                                .iter()
                                .map(|t| (t.id, Value::num(t.index as f64)))
                                .collect();
                            s.complete_batch(results).unwrap();
                        }
                        served += 1;
                    }
                    served
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // Released tickets are pending again: a final single-threaded
        // drain must finish the job with nothing lost or duplicated.
        while let Some(t) = s.next_ticket("sweeper", 2) {
            let _ = s.complete(t.id, Value::num(t.index as f64)).unwrap();
        }
        let p = s.progress(None);
        assert_eq!((p.done, p.pending, p.in_flight), (n, 0, 0), "conservation under steal");
        assert_eq!(s.wait_results(TaskId(1)).len(), n);
    }

    /// O(1) progress counters match a recount after a mixed workload.
    #[test]
    fn ledger_counters_match_recount() {
        let s = IndexedStore::new(cfg());
        let a = s.create_tickets(TaskId(1), "a", (0..4).map(|_| Value::Null).collect(), 0);
        let _b = s.create_tickets(TaskId(2), "b", (0..2).map(|_| Value::Null).collect(), 0);
        let _ = s.next_ticket("c", 0);
        let _ = s.next_ticket("c", 1);
        s.complete(a[0], Value::Null).unwrap();
        let p1 = s.progress(Some(TaskId(1)));
        assert_eq!((p1.total, p1.pending, p1.in_flight, p1.done), (4, 2, 1, 1));
        let p2 = s.progress(Some(TaskId(2)));
        assert_eq!((p2.total, p2.pending, p2.in_flight, p2.done), (2, 2, 0, 0));
        let g = s.progress(None);
        assert_eq!((g.total, g.pending, g.in_flight, g.done), (6, 4, 1, 1));
        assert!(s.is_task_done(TaskId(3)), "empty task is vacuously done");
        assert!(!s.is_task_done(TaskId(1)));
    }

    /// Per-shard error queues: reports land on the owning shard, drain
    /// in one shard-major pass, and the cumulative count survives.
    #[test]
    fn per_shard_error_queues_drain_shard_major() {
        let s = IndexedStore::with_layout(cfg(), 4, 4);
        let ids =
            s.create_tickets(TaskId(1), "t", (0..8).map(|i| Value::num(i as f64)).collect(), 0);
        // Dispatch everything so the error requeues have in-flight work.
        let _ = s.next_tickets("c", 0, 8);
        // Report in descending-id order: the drain must come back
        // shard-major (shard 0's queue first), not report order.
        for id in ids.iter().rev() {
            s.report_error(*id, format!("e{}", id.0)).unwrap();
        }
        assert_eq!(s.error_count(), 8);
        let drained = s.drain_errors();
        assert_eq!(drained.len(), 8);
        let shards: Vec<u64> = drained.iter().map(|(id, _)| id.0 % 4).collect();
        let mut sorted = shards.clone();
        sorted.sort_unstable();
        assert_eq!(shards, sorted, "drain order is shard-major");
        assert!(s.drain_errors().is_empty());
        assert_eq!(s.error_count(), 8, "cumulative count unaffected by drain");
    }

    /// snapshot→restore rebuilds an observably identical store: same
    /// progress, same dispatch order, same error buffers, same results.
    #[test]
    fn snapshot_restore_roundtrip_is_identical() {
        let s = IndexedStore::with_shards(cfg(), 4);
        let a = s.create_tickets(TaskId(1), "a", (0..4).map(|i| Value::num(i as f64)).collect(), 0);
        let _b = s.create_tickets(TaskId(2), "b", (0..2).map(|_| Value::Null).collect(), 5);
        let _ = s.next_ticket("c1", 10).unwrap();
        let _ = s.next_ticket("c2", 11).unwrap();
        s.complete(a[0], Value::num(42.0)).unwrap();
        assert!(!s.complete(a[0], Value::num(43.0)).unwrap(), "duplicate counted");
        s.report_error(a[1], "boom".into()).unwrap();

        let r = IndexedStore::restore(s.snapshot());
        assert_eq!(r.progress(None), s.progress(None));
        for t in [TaskId(1), TaskId(2), TaskId(3)] {
            assert_eq!(r.progress(Some(t)), s.progress(Some(t)));
            assert_eq!(r.is_task_done(t), s.is_task_done(t));
        }
        assert_eq!(r.error_count(), s.error_count());
        // Identical future dispatch decisions, clock by clock.
        let mut now = 12;
        loop {
            let (x, y) = (s.next_ticket("d", now), r.next_ticket("d", now));
            assert_eq!(x, y, "dispatch diverges at t={now}");
            match x {
                Some(t) => {
                    assert_eq!(
                        s.complete(t.id, Value::num(now as f64)).unwrap(),
                        r.complete(t.id, Value::num(now as f64)).unwrap()
                    );
                }
                None if s.is_task_done(TaskId(1)) && s.is_task_done(TaskId(2)) => break,
                None => {}
            }
            now += 37;
        }
        assert_eq!(s.wait_results(TaskId(1)), r.wait_results(TaskId(1)));
        assert_eq!(s.wait_results(TaskId(2)), r.wait_results(TaskId(2)));
        assert_eq!(s.drain_errors(), r.drain_errors());
    }

    /// The sharded snapshot pins the shard count and per-shard error
    /// queues: restore continues the same per-shard sequences.
    #[test]
    fn sharded_snapshot_restore_roundtrip_is_identical() {
        let s = IndexedStore::with_layout(cfg(), 4, 4);
        let ids =
            s.create_tickets(TaskId(1), "t", (0..12).map(|i| Value::num(i as f64)).collect(), 0);
        let _ = s.next_tickets("c1", 10, 5);
        s.complete(ids[0], Value::num(1.0)).unwrap();
        s.report_error(ids[1], "boom".into()).unwrap();
        s.report_error(ids[2], "bam".into()).unwrap();

        let r = IndexedStore::restore(s.snapshot());
        assert_eq!(r.dispatch_shard_count(), 4, "shard count restored from the snapshot");
        assert_eq!(r.progress(None), s.progress(None));
        let mut now = 11;
        for _ in 0..40 {
            let (x, y) = (s.next_ticket("d", now), r.next_ticket("d", now));
            assert_eq!(x, y, "sharded dispatch diverges at t={now}");
            if let Some(t) = x {
                assert_eq!(
                    s.complete(t.id, Value::num(now as f64)).unwrap(),
                    r.complete(t.id, Value::num(now as f64)).unwrap()
                );
            }
            now += 37;
        }
        assert_eq!(s.drain_errors(), r.drain_errors());
        assert_eq!(s.wait_results_timeout(TaskId(1), 10), r.wait_results_timeout(TaskId(1), 10));
    }

    /// `create_tickets_exact` (the sharded-WAL replay path) reproduces
    /// a normal create bit-for-bit and advances the id allocator.
    #[test]
    fn create_tickets_exact_matches_create() {
        let a = IndexedStore::with_layout(cfg(), 4, 2);
        let b = IndexedStore::with_layout(cfg(), 4, 2);
        let ids = a.create_tickets(TaskId(1), "t", (0..6).map(|i| Value::num(i as f64)).collect(), 3);
        let items: Vec<(u64, usize, Value)> =
            ids.iter().enumerate().map(|(i, id)| (id.0, i, Value::num(i as f64))).collect();
        b.create_tickets_exact(TaskId(1), "t", items, 3);
        assert_eq!(a.progress(None), b.progress(None));
        for _ in 0..6 {
            assert_eq!(a.next_ticket("c", 5), b.next_ticket("c", 5));
        }
        // The allocator moved past the explicit ids: a fresh create
        // cannot collide.
        let fresh = b.create_tickets(TaskId(2), "u", vec![Value::Null], 4);
        assert!(fresh[0].0 > ids[5].0);
    }
}
