//! Write-ahead-logged ticket store: durability for the [`Scheduler`].
//!
//! The paper kept tickets in MySQL, so a Sashimi coordinator restart
//! never lost work (§2.1); the in-memory [`IndexedStore`] loses every
//! ticket on a crash.  [`WalStore`] closes that gap without giving up
//! the indexed dispatch path: it wraps an `IndexedStore` and appends one
//! compact binary record per *mutating* operation (ticket creation,
//! dispatch, result, error report, explicit release, error drain) to a
//! segmented log
//! before returning, so the log replays to exactly the in-memory state.
//!
//! ## On-disk layout
//!
//! A state directory holds numbered segments and checkpoints:
//!
//! ```text
//! state/
//!   wal-00000000.log         segment: 8-byte header, then frames
//!   wal-00000001.log
//!   checkpoint-00000001.snap full-store snapshot; replay resumes at
//!                            segment 00000001
//! ```
//!
//! With [`WalConfig::dispatch_shards`] > 1 the log is split into one
//! *stream* per dispatch shard of the inner [`IndexedStore`], so the
//! log append stops being the one lock every dispatch funnels through
//! (the store's own shards already spread the decision, ISSUE 7):
//!
//! ```text
//! state/
//!   wal-s000-00000000.log    stream 0, segment 0
//!   wal-s001-00000000.log    stream 1, segment 0
//!   checkpoint-00000003.snap all streams rotate to segment 00000003
//! ```
//!
//! Framing is unchanged.  Every record is wrapped in an `OP_SEQ` header
//! carrying a global log sequence number (LSN, from one atomic
//! counter), and each stream segment carries an `OP_SHARDS` header
//! pinning the shard count.  An operation locks the streams of every
//! shard it touches (ascending, so multi-stream ops cannot deadlock;
//! dispatch locks one stream at a time, `try_lock`-stealing like the
//! store itself) and allocates its LSN while holding them — so for any
//! two records touching a common shard, LSN order equals apply order,
//! and recovery merges all stream tails by LSN into a replay sequence
//! equivalent to the original execution, with the same outcome
//! cross-checks as the single-stream path.
//!
//! Every frame is `[len: u32 LE][crc32: u32 LE][payload]` with the CRC
//! over the payload, so torn tails and bit rot are detected, never
//! replayed.  Each segment starts with a `Config` record pinning the
//! [`StoreConfig`] that produced it — replay *re-runs* the §2.1.2
//! dispatch policy, so recovering under a different `requeue_after_ms`
//! would change history; the persisted config always wins.
//!
//! ## Durability policy ([`SyncPolicy`])
//!
//! Appends always reach the OS (one `write` per record); *fsync* is the
//! knob.  `EveryRecord` survives power loss at fsync-per-dispatch cost;
//! `GroupCommitMs(t)` bounds loss to the last `t` ms (a background
//! flusher fsyncs the tail) — with one carve-out: *completions* are
//! fsynced before [`Scheduler::complete`] / [`Scheduler::complete_batch`]
//! returns, so an acknowledged result is never inside the loss window
//! (batching amortises that fsync across the whole batch); `OsOnly`
//! never fsyncs — it survives process crashes (the bar for coordinator
//! restarts) but not kernel panics.  `benches/store_throughput.rs`
//! measures all three against the raw store (EXPERIMENTS.md §WAL).
//!
//! Batched operations ([`Scheduler::next_tickets`] /
//! [`Scheduler::complete_batch`]) log one framed `DispatchBatch` /
//! `CompleteBatch` record per batch instead of one frame per ticket, so
//! frame and fsync overheads amortise with the batch size
//! (EXPERIMENTS.md §Batch).
//!
//! ## Checkpoints
//!
//! Every [`WalConfig::checkpoint_every`] records the store serialises a
//! full [`IndexedStore`] snapshot to `checkpoint-<seq>.snap` (written to
//! a temp file, fsynced, renamed), then deletes all older segments and
//! checkpoints — the log stays bounded by checkpoint cadence, not by
//! history.  Recovery loads the newest intact checkpoint and replays the
//! surviving segment tail; [`WalStore::recover`] then continues on a
//! fresh segment, never appending to a possibly-torn file.
//!
//! ## Recovery invariant
//!
//! Post-recovery state is *differential-test identical* to the pre-crash
//! store: dispatch order, progress counters, duplicate/error accounting
//! and collected results all match an uninterrupted run
//! (`rust/tests/wal_recovery.rs` asserts this over the same 256-case
//! random-op suite that pins `IndexedStore` to [`NaiveStore`]).  Two
//! deliberate exceptions, both consumer-side: completion-FIFO pops
//! ([`Scheduler::next_completion`]) are not logged, so an unconsumed (or
//! consumed-but-unacknowledged) completion is redelivered after recovery
//! — at-least-once, like the paper's browsers re-answering a
//! redistributed ticket — and durability of the last few records is
//! bounded by the [`SyncPolicy`], not by the append itself.
//!
//! [`NaiveStore`]: super::NaiveStore

use std::collections::BTreeMap;
use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::store::sched::{LedgerSnapshot, StoreSnapshot, TicketSnapshot};
use crate::store::ticket::{Rep, TicketVerify};
use crate::store::{
    IndexedStore, Progress, SchedStats, Scheduler, Standing, StoreConfig, TaskId, Ticket,
    TicketId, TicketStatus, Verdict, VerifyStats, VoteOutcome,
};
use crate::util::json::Value;
use crate::util::lockcheck::{CheckedMutex, CheckedMutexGuard, Rank};

/// Segment header: magic + format version.
const SEGMENT_MAGIC: [u8; 8] = *b"SWAL\x01\0\0\0";
/// Checkpoint header: magic + format version.
const CHECKPOINT_MAGIC: [u8; 8] = *b"SCKP\x01\0\0\0";
/// Upper bound on one frame's payload; larger lengths are treated as
/// corruption instead of attempted as an allocation.
const MAX_FRAME: u32 = 1 << 30;

// Record opcodes (first payload byte).
const OP_CONFIG: u8 = 1;
const OP_CREATE: u8 = 2;
const OP_DISPATCH: u8 = 3;
const OP_COMPLETE: u8 = 4;
const OP_ERROR: u8 = 5;
const OP_DRAIN_ERRORS: u8 = 6;
/// One batched dispatch (`next_tickets`): the whole batch in one frame.
const OP_DISPATCH_BATCH: u8 = 7;
/// One batched completion (`complete_batch`): the applied prefix, with
/// its per-entry accepted flags, in one frame.
const OP_COMPLETE_BATCH: u8 = 8;
/// One batched release (`release`/`release_batch`): every id with its
/// released flag, in one frame (the active failure path: a
/// disconnecting client's whole prefetched batch re-enters dispatch as
/// one record).
const OP_RELEASE_BATCH: u8 = 9;
/// Stream-segment header (after the config record): `[shard_count u32]
/// [stream_index u32]`, pinning the sharded layout a stream belongs to.
const OP_SHARDS: u8 = 10;
/// LSN wrapper heading every sharded-stream record: `[lsn u64]` then
/// the inner record payload verbatim.  Recovery merges all stream
/// tails by LSN before replaying.
const OP_SEQ: u8 = 11;
/// A create with explicit ticket ids: `[task][now][name][n]` then
/// `(id, index, payload)` per ticket.  The sharded path logs creates
/// this way because replay order across streams is LSN order, not id-
/// allocation order — re-running the allocator could renumber.
const OP_CREATE_EXACT: u8 = 12;
/// One per-shard dispatch run (`IndexedStore::next_tickets_from_shard`):
/// `[shard u32][now][client][n][ids...]`.  Replay re-runs the same
/// per-shard pick (deterministic given the shard's state) and
/// cross-checks the ids.
const OP_DISPATCH_SHARD: u8 = 13;
/// One verification-layer vote (R > 1 only): `[now][client][ticket]
/// [outcome u8][result json]`.  Replay re-runs the deterministic vote
/// state machine and cross-checks the logged outcome discriminant.
const OP_VOTE: u8 = 14;
/// An attributed release (R > 1 only): `[client][n]` then `(id,
/// released u8)` per entry.  R = 1 logs the legacy [`OP_RELEASE_BATCH`].
const OP_RELEASE_FROM: u8 = 15;
/// An attributed error report (R > 1 only): `[client][ticket][report]`.
/// R = 1 logs the legacy [`OP_ERROR`].
const OP_ERROR_FROM: u8 = 16;

/// When the log is fsynced (appends always reach the OS immediately).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncPolicy {
    /// fsync after every record: survives power loss, slowest.
    EveryRecord,
    /// A background flusher fsyncs every `t` ms: loss window ≤ `t` ms
    /// for unacknowledged work.  Completions are excluded from the
    /// window: `complete`/`complete_batch` fsync the tail before
    /// returning, so an Acked result is always durable (batch
    /// completion amortises that fsync across its entries).  A window
    /// of 0 degenerates to per-record fsync ([`EveryRecord`]).
    ///
    /// [`EveryRecord`]: SyncPolicy::EveryRecord
    GroupCommitMs(u64),
    /// Never fsync: survives process crashes (OS page cache persists),
    /// not power loss.  The fast default for coordinator restarts.
    OsOnly,
}

/// Tuning knobs of the [`WalStore`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WalConfig {
    /// fsync batching policy.
    pub sync: SyncPolicy,
    /// Rotate to a fresh segment once the current one exceeds this.
    pub segment_max_bytes: u64,
    /// Write a checkpoint (and truncate older segments) every this many
    /// records; `0` disables checkpointing (the log grows unboundedly).
    pub checkpoint_every: u64,
    /// Dispatch shards of the inner store, each with its own log
    /// stream (rounded up to a power of two).  `1` (the default) is
    /// the legacy single-stream layout, bit-for-bit.  When recovering
    /// an existing state directory the persisted shard count wins.
    pub dispatch_shards: usize,
}

impl Default for WalConfig {
    fn default() -> Self {
        WalConfig {
            sync: SyncPolicy::GroupCommitMs(50),
            segment_max_bytes: 8 << 20,
            checkpoint_every: 100_000,
            dispatch_shards: 1,
        }
    }
}

// ---------------------------------------------------------------------------
// Frame codec: length-prefixed CRC-checked payloads of LE primitives.
// ---------------------------------------------------------------------------

/// IEEE CRC-32 table (polynomial 0xEDB88320), built at compile time.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Payload encoder: LE integers, length-prefixed UTF-8, JSON values
/// through the fuzz-tested [`Value`] codec.
struct Enc(Vec<u8>);

impl Enc {
    fn new(op: u8) -> Enc {
        Enc(vec![op])
    }

    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.0.extend_from_slice(s.as_bytes());
    }

    fn value(&mut self, v: &Value) {
        self.str(&v.to_string());
    }

    /// Append pre-encoded payload bytes verbatim (the `OP_SEQ` wrapper
    /// embeds a whole inner record).
    fn raw(&mut self, bytes: &[u8]) {
        self.0.extend_from_slice(bytes);
    }

    /// The framed bytes: `[len][crc][payload]`.
    fn frame(self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.0.len() + 8);
        out.extend_from_slice(&(self.0.len() as u32).to_le_bytes());
        out.extend_from_slice(&crc32(&self.0).to_le_bytes());
        out.extend_from_slice(&self.0);
        out
    }
}

/// Payload decoder over a borrowed frame.
struct Dec<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Dec<'a> {
    fn new(b: &'a [u8]) -> Dec<'a> {
        Dec { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.i + n <= self.b.len(), "record truncated at byte {}", self.i);
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(std::str::from_utf8(self.take(n)?)?.to_string())
    }

    fn value(&mut self) -> Result<Value> {
        let s = self.str()?;
        Value::parse(&s).context("corrupt JSON payload in WAL record")
    }

    fn done(&self) -> Result<()> {
        ensure!(self.i == self.b.len(), "{} trailing bytes in record", self.b.len() - self.i);
        Ok(())
    }

    /// Bytes not yet decoded — optional trailing sections (verification
    /// state) are present exactly when this is non-zero after the fixed
    /// legacy layout has been consumed.
    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    /// Everything not yet decoded — the [`OP_SEQ`] envelope carries a
    /// whole inner record verbatim after its LSN.
    fn rest(&mut self) -> &'a [u8] {
        let s = &self.b[self.i..];
        self.i = self.b.len();
        s
    }
}

fn encode_config(cfg: &StoreConfig) -> Enc {
    let mut e = Enc::new(OP_CONFIG);
    e.u64(cfg.requeue_after_ms);
    e.u64(cfg.min_redistribute_ms);
    e.u8(cfg.requeue_on_error as u8);
    // The verification knobs appear only when the layer is active, so
    // R = 1 config records stay byte-identical to the legacy layout.
    if cfg.verifying() {
        e.u32(cfg.replication);
        e.u32(cfg.quorum);
    }
    e
}

/// Decode the fixed legacy config fields; the verification knobs
/// default to off.  Snapshot bodies use this form (more fields follow
/// the config lead there, so trailing-presence is ambiguous — the
/// snapshot carries its verify section at the very end instead).
fn decode_config(d: &mut Dec) -> Result<StoreConfig> {
    Ok(StoreConfig {
        requeue_after_ms: d.u64()?,
        min_redistribute_ms: d.u64()?,
        requeue_on_error: d.u8()? != 0,
        ..StoreConfig::default()
    })
}

/// Decode a standalone config *record*, whose payload is the config and
/// nothing else: trailing bytes (written only at R > 1) carry the
/// verification knobs.
fn decode_config_record(d: &mut Dec) -> Result<StoreConfig> {
    let mut cfg = decode_config(d)?;
    if d.remaining() > 0 {
        cfg.replication = d.u32()?;
        cfg.quorum = d.u32()?;
    }
    Ok(cfg)
}

fn encode_option_u64(e: &mut Enc, v: Option<u64>) {
    // u64::MAX is an unreachable clock value; it encodes None compactly.
    e.u64(v.unwrap_or(u64::MAX));
}

fn decode_option_u64(d: &mut Dec) -> Result<Option<u64>> {
    let v = d.u64()?;
    Ok(if v == u64::MAX { None } else { Some(v) })
}

fn encode_verify(e: &mut Enc, v: &TicketVerify) {
    e.u32(v.target);
    e.u32(v.holders.len() as u32);
    for h in &v.holders {
        e.str(h);
    }
    e.u32(v.votes.len() as u32);
    for (c, h) in &v.votes {
        e.str(c);
        e.u64(*h);
    }
    e.u32(v.values.len() as u32);
    for (h, val) in &v.values {
        e.u64(*h);
        e.value(val);
    }
    match &v.decided {
        None => e.u8(0),
        Some(vd) => {
            e.u8(1);
            e.u64(vd.ticket.0);
            e.u64(vd.hash);
            e.u32(vd.winners.len() as u32);
            for w in &vd.winners {
                e.str(w);
            }
            e.u32(vd.losers.len() as u32);
            for l in &vd.losers {
                e.str(l);
            }
        }
    }
}

fn decode_verify(d: &mut Dec) -> Result<TicketVerify> {
    let target = d.u32()?;
    let n = d.u32()? as usize;
    let mut holders = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        holders.push(d.str()?);
    }
    let n = d.u32()? as usize;
    let mut votes = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        let c = d.str()?;
        votes.push((c, d.u64()?));
    }
    let n = d.u32()? as usize;
    let mut values = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        let h = d.u64()?;
        values.push((h, d.value()?));
    }
    let decided = match d.u8()? {
        0 => None,
        _ => {
            let ticket = TicketId(d.u64()?);
            let hash = d.u64()?;
            let n = d.u32()? as usize;
            let mut winners = Vec::with_capacity(n.min(1 << 16));
            for _ in 0..n {
                winners.push(d.str()?);
            }
            let n = d.u32()? as usize;
            let mut losers = Vec::with_capacity(n.min(1 << 16));
            for _ in 0..n {
                losers.push(d.str()?);
            }
            Some(Verdict { ticket, hash, winners, losers })
        }
    };
    Ok(TicketVerify { target, holders, votes, values, decided })
}

fn encode_snapshot(snap: &StoreSnapshot) -> Vec<u8> {
    let mut e = Enc::new(OP_CONFIG); // snapshot body reuses the config lead
    e.u64(snap.cfg.requeue_after_ms);
    e.u64(snap.cfg.min_redistribute_ms);
    e.u8(snap.cfg.requeue_on_error as u8);
    e.u64(snap.next_id);
    e.u64(snap.redistributions);
    e.u64(snap.duplicate_results);
    e.u64(snap.errors_reported);
    e.u64(snap.dispatch_shards as u64);
    e.u64(snap.tickets.len() as u64);
    for t in &snap.tickets {
        e.u64(t.id);
        e.u64(t.task.0);
        e.u64(t.index as u64);
        e.u64(t.created_ms);
        e.u8(match t.status {
            TicketStatus::Pending => 0,
            TicketStatus::InFlight => 1,
            TicketStatus::Done => 2,
        });
        encode_option_u64(&mut e, t.last_distributed_ms);
        e.u32(t.distribution_count);
        e.str(&t.task_name);
        e.value(&t.payload);
    }
    e.u64(snap.ledgers.len() as u64);
    for l in &snap.ledgers {
        e.u64(l.task.0);
        e.u64(l.results.len() as u64);
        for (index, id, v) in &l.results {
            e.u64(*index as u64);
            e.u64(*id);
            e.value(v);
        }
        e.u64(l.completions.len() as u64);
        for (index, v) in &l.completions {
            e.u64(*index as u64);
            e.value(v);
        }
    }
    e.u64(snap.errors.len() as u64);
    for (id, report) in &snap.errors {
        e.u64(id.0);
        e.str(report);
    }
    // Verification layer: a trailing section present only at R > 1.
    // The legacy layout consumes the payload exactly, so the section's
    // absence is unambiguous and R = 1 checkpoints stay byte-identical.
    if snap.cfg.verifying() {
        e.u32(snap.cfg.replication);
        e.u32(snap.cfg.quorum);
        let with_verify: Vec<&TicketSnapshot> =
            snap.tickets.iter().filter(|t| t.verify.is_some()).collect();
        e.u64(with_verify.len() as u64);
        for t in with_verify {
            e.u64(t.id);
            encode_verify(&mut e, t.verify.as_ref().expect("filtered on Some"));
        }
        e.u64(snap.reps.len() as u64);
        for (client, rep) in &snap.reps {
            e.str(client);
            e.u64(rep.score as u64);
            encode_option_u64(&mut e, rep.quarantined_until);
            e.u8(rep.ever_quarantined as u8);
            e.u64(rep.votes_won);
            e.u64(rep.votes_lost);
        }
        for c in snap.verify_counters {
            e.u64(c);
        }
    }
    e.frame()
}

fn decode_snapshot(payload: &[u8]) -> Result<StoreSnapshot> {
    let mut d = Dec::new(payload);
    ensure!(d.u8()? == OP_CONFIG, "checkpoint payload must start with a config record");
    let mut cfg = decode_config(&mut d)?;
    let next_id = d.u64()?;
    let redistributions = d.u64()?;
    let duplicate_results = d.u64()?;
    let errors_reported = d.u64()?;
    let dispatch_shards = d.u64()? as usize;
    ensure!(
        dispatch_shards >= 1 && dispatch_shards.is_power_of_two() && dispatch_shards <= 1 << 16,
        "bad dispatch shard count {dispatch_shards} in checkpoint"
    );
    let n_tickets = d.u64()?;
    let mut tickets = Vec::with_capacity(n_tickets.min(1 << 20) as usize);
    for _ in 0..n_tickets {
        let id = d.u64()?;
        let task = TaskId(d.u64()?);
        let index = d.u64()? as usize;
        let created_ms = d.u64()?;
        let status = match d.u8()? {
            0 => TicketStatus::Pending,
            1 => TicketStatus::InFlight,
            2 => TicketStatus::Done,
            s => bail!("bad ticket status {s} in checkpoint"),
        };
        let last_distributed_ms = decode_option_u64(&mut d)?;
        let distribution_count = d.u32()?;
        let task_name = d.str()?;
        let payload = d.value()?;
        tickets.push(TicketSnapshot {
            id,
            task,
            task_name,
            index,
            payload,
            created_ms,
            status,
            last_distributed_ms,
            distribution_count,
            verify: None,
        });
    }
    let n_ledgers = d.u64()?;
    let mut ledgers = Vec::with_capacity(n_ledgers.min(1 << 20) as usize);
    for _ in 0..n_ledgers {
        let task = TaskId(d.u64()?);
        let n_results = d.u64()?;
        let mut results = Vec::with_capacity(n_results.min(1 << 20) as usize);
        for _ in 0..n_results {
            let index = d.u64()? as usize;
            let id = d.u64()?;
            results.push((index, id, d.value()?));
        }
        let n_completions = d.u64()?;
        let mut completions = Vec::with_capacity(n_completions.min(1 << 20) as usize);
        for _ in 0..n_completions {
            let index = d.u64()? as usize;
            completions.push((index, d.value()?));
        }
        ledgers.push(LedgerSnapshot { task, results, completions });
    }
    let n_errors = d.u64()?;
    let mut errors = Vec::with_capacity(n_errors.min(1 << 20) as usize);
    for _ in 0..n_errors {
        let id = TicketId(d.u64()?);
        errors.push((id, d.str()?));
    }
    // Trailing verify section (R > 1 checkpoints only).
    let mut reps: Vec<(String, Rep)> = Vec::new();
    let mut verify_counters = [0u64; 5];
    if d.remaining() > 0 {
        cfg.replication = d.u32()?;
        cfg.quorum = d.u32()?;
        let n_verify = d.u64()?;
        let mut by_id: BTreeMap<u64, TicketVerify> = BTreeMap::new();
        for _ in 0..n_verify {
            let id = d.u64()?;
            by_id.insert(id, decode_verify(&mut d)?);
        }
        for t in &mut tickets {
            if let Some(v) = by_id.remove(&t.id) {
                t.verify = Some(v);
            }
        }
        ensure!(
            by_id.is_empty(),
            "checkpoint carries verify state for {} unknown ticket(s)",
            by_id.len()
        );
        let n_reps = d.u64()?;
        for _ in 0..n_reps {
            let client = d.str()?;
            let score = d.u64()? as i64;
            let quarantined_until = decode_option_u64(&mut d)?;
            let ever_quarantined = d.u8()? != 0;
            let votes_won = d.u64()?;
            let votes_lost = d.u64()?;
            reps.push((
                client,
                Rep { score, quarantined_until, ever_quarantined, votes_won, votes_lost },
            ));
        }
        for c in &mut verify_counters {
            *c = d.u64()?;
        }
    }
    d.done()?;
    Ok(StoreSnapshot {
        cfg,
        next_id,
        redistributions,
        duplicate_results,
        errors_reported,
        dispatch_shards,
        tickets,
        ledgers,
        errors,
        reps,
        verify_counters,
    })
}

// ---------------------------------------------------------------------------
// Segment files
// ---------------------------------------------------------------------------

fn segment_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("wal-{seq:08}.log"))
}

/// Per-shard stream segment (the sharded layout).
fn stream_segment_path(dir: &Path, stream: usize, seq: u64) -> PathBuf {
    dir.join(format!("wal-s{stream:03}-{seq:08}.log"))
}

fn checkpoint_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("checkpoint-{seq:08}.snap"))
}

/// Parse `wal-<seq>.log` / `checkpoint-<seq>.snap` file names.
fn parse_seq(name: &str, prefix: &str, suffix: &str) -> Option<u64> {
    name.strip_prefix(prefix)?.strip_suffix(suffix)?.parse().ok()
}

/// Parse `wal-s<stream>-<seq>.log` file names.
fn parse_stream_name(name: &str) -> Option<(usize, u64)> {
    let rest = name.strip_prefix("wal-s")?.strip_suffix(".log")?;
    let (stream, seq) = rest.split_once('-')?;
    Some((stream.parse().ok()?, seq.parse().ok()?))
}

/// Read every intact frame of a segment after the header.  `strict`
/// errors on a torn/corrupt tail (non-final segments must be whole);
/// lenient mode stops there instead — the defining property of a
/// crash-interrupted final segment.
fn read_segment(path: &Path, strict: bool) -> Result<Vec<Vec<u8>>> {
    let mut bytes = Vec::new();
    File::open(path)
        .and_then(|mut f| f.read_to_end(&mut bytes))
        .with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() < SEGMENT_MAGIC.len() || bytes[..SEGMENT_MAGIC.len()] != SEGMENT_MAGIC {
        // A final segment can be torn *inside its header* — a crash
        // mid-rotation leaves a short or garbage file.  Nothing was
        // ever acknowledged from it, so lenient mode treats it as
        // empty; anywhere else a bad header is corruption.
        ensure!(!strict, "{} is not a WAL segment (bad header)", path.display());
        crate::log_warn!(
            "wal",
            "{}: short or corrupt segment header (crash mid-rotation): treating as empty",
            path.display()
        );
        return Ok(Vec::new());
    }
    let mut frames = Vec::new();
    let mut i = SEGMENT_MAGIC.len();
    while i < bytes.len() {
        let whole = (|| -> Option<Vec<u8>> {
            let len = u32::from_le_bytes(bytes.get(i..i + 4)?.try_into().unwrap());
            if len > MAX_FRAME {
                return None;
            }
            let crc = u32::from_le_bytes(bytes.get(i + 4..i + 8)?.try_into().unwrap());
            let payload = bytes.get(i + 8..i + 8 + len as usize)?;
            if crc32(payload) != crc {
                return None;
            }
            Some(payload.to_vec())
        })();
        match whole {
            Some(payload) => {
                i += 8 + payload.len();
                frames.push(payload);
            }
            None => {
                ensure!(
                    !strict,
                    "corrupt frame at byte {i} of non-final segment {}",
                    path.display()
                );
                crate::log_warn!(
                    "wal",
                    "torn tail at byte {i} of {}: dropping unsynced records",
                    path.display()
                );
                break;
            }
        }
    }
    Ok(frames)
}

// ---------------------------------------------------------------------------
// The writer
// ---------------------------------------------------------------------------

struct LogWriter {
    dir: PathBuf,
    /// `Some((stream_index, shard_count))` for a per-shard stream
    /// writer (sharded layout: `wal-s<stream>-<seq>.log` files with an
    /// `OP_SHARDS` header record); `None` is the legacy single log.
    stream: Option<(usize, usize)>,
    file: BufWriter<File>,
    seq: u64,
    bytes_in_segment: u64,
    records_since_checkpoint: u64,
    /// Unsynced bytes pending an fsync (group commit).
    dirty: bool,
}

impl LogWriter {
    /// Open a fresh legacy segment `seq`, writing header + config record.
    fn open_segment(dir: &Path, seq: u64, cfg: &StoreConfig) -> Result<LogWriter> {
        Self::open_at(dir, None, seq, cfg)
    }

    /// Open a fresh segment of per-shard stream `stream` (of
    /// `shard_count`), writing header + config + shards records.
    fn open_stream_segment(
        dir: &Path,
        stream: usize,
        shard_count: usize,
        seq: u64,
        cfg: &StoreConfig,
    ) -> Result<LogWriter> {
        Self::open_at(dir, Some((stream, shard_count)), seq, cfg)
    }

    fn open_at(
        dir: &Path,
        stream: Option<(usize, usize)>,
        seq: u64,
        cfg: &StoreConfig,
    ) -> Result<LogWriter> {
        let path = match stream {
            None => segment_path(dir, seq),
            Some((s, _)) => stream_segment_path(dir, s, seq),
        };
        let file = OpenOptions::new()
            .create_new(true)
            .write(true)
            .open(&path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut w = LogWriter {
            dir: dir.to_path_buf(),
            stream,
            file: BufWriter::new(file),
            seq,
            bytes_in_segment: 0,
            records_since_checkpoint: 0,
            dirty: false,
        };
        w.file.write_all(&SEGMENT_MAGIC)?;
        w.write_frame(&encode_config(cfg).frame())?;
        if let Some((s, count)) = stream {
            let mut e = Enc::new(OP_SHARDS);
            e.u32(count as u32);
            e.u32(s as u32);
            w.write_frame(&e.frame())?;
        }
        w.sync()?;
        Ok(w)
    }

    /// Append one frame and push it to the OS (flush, no fsync).
    fn write_frame(&mut self, frame: &[u8]) -> Result<()> {
        self.file.write_all(frame)?;
        self.file.flush()?;
        self.bytes_in_segment += frame.len() as u64;
        self.dirty = true;
        Ok(())
    }

    /// Flush + fsync the current segment.
    fn sync(&mut self) -> Result<()> {
        if self.dirty {
            self.file.flush()?;
            self.file.get_ref().sync_data()?;
            self.dirty = false;
        }
        Ok(())
    }

    /// fsync the directory itself so renames/creates are durable.
    fn sync_dir(&self) -> Result<()> {
        File::open(&self.dir)?.sync_all()?;
        Ok(())
    }

    /// Append one record; rotate / checkpoint per `wal_cfg`.  `store` is
    /// only consulted when a checkpoint is due.
    fn append(&mut self, record: Enc, wal_cfg: &WalConfig, store: &IndexedStore) -> Result<()> {
        self.write_frame(&record.frame())?;
        self.records_since_checkpoint += 1;
        if matches!(wal_cfg.sync, SyncPolicy::EveryRecord | SyncPolicy::GroupCommitMs(0)) {
            self.sync()?;
        }
        if wal_cfg.checkpoint_every > 0 && self.records_since_checkpoint >= wal_cfg.checkpoint_every
        {
            self.checkpoint(store, store.config())?;
        } else if self.bytes_in_segment >= wal_cfg.segment_max_bytes {
            self.rotate(store.config())?;
        }
        Ok(())
    }

    /// Start segment `seq + 1` without checkpointing (size rotation).
    fn rotate(&mut self, cfg: &StoreConfig) -> Result<()> {
        self.sync()?;
        let records = self.records_since_checkpoint;
        *self = LogWriter::open_at(&self.dir, self.stream, self.seq + 1, cfg)?;
        self.records_since_checkpoint = records;
        self.sync_dir()?;
        Ok(())
    }

    /// Serialise a full snapshot as `checkpoint-<seq+1>.snap`, move the
    /// log to segment `seq + 1`, and delete everything older.
    fn checkpoint(&mut self, store: &IndexedStore, cfg: &StoreConfig) -> Result<()> {
        let new_seq = self.seq + 1;
        let tmp = self.dir.join(format!("checkpoint-{new_seq:08}.tmp"));
        {
            let mut f = File::create(&tmp)?;
            f.write_all(&CHECKPOINT_MAGIC)?;
            f.write_all(&encode_snapshot(&store.snapshot()))?;
            f.sync_all()?;
        }
        fs::rename(&tmp, checkpoint_path(&self.dir, new_seq))?;
        *self = LogWriter::open_segment(&self.dir, new_seq, cfg)?;
        self.sync_dir()?;
        // Truncate: state before `new_seq` now lives in the checkpoint.
        for (kind, seq) in list_state_files(&self.dir)? {
            if seq < new_seq {
                let _ = fs::remove_file(state_file_path(&self.dir, kind, seq));
            }
        }
        Ok(())
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum StateFile {
    Segment,
    Checkpoint,
    /// A per-shard stream segment (sharded layout); the payload is the
    /// stream index.
    Stream(usize),
}

fn state_file_path(dir: &Path, kind: StateFile, seq: u64) -> PathBuf {
    match kind {
        StateFile::Segment => segment_path(dir, seq),
        StateFile::Checkpoint => checkpoint_path(dir, seq),
        StateFile::Stream(s) => stream_segment_path(dir, s, seq),
    }
}

/// Enumerate `(kind, seq)` for every recognised file in a state dir;
/// stray `.tmp` checkpoints are ignored (an interrupted checkpoint).
fn list_state_files(dir: &Path) -> Result<Vec<(StateFile, u64)>> {
    let mut out = Vec::new();
    for entry in fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))? {
        let name = entry?.file_name();
        let name = name.to_string_lossy();
        if let Some(seq) = parse_seq(&name, "wal-", ".log") {
            out.push((StateFile::Segment, seq));
        } else if let Some((stream, seq)) = parse_stream_name(&name) {
            out.push((StateFile::Stream(stream), seq));
        } else if let Some(seq) = parse_seq(&name, "checkpoint-", ".snap") {
            out.push((StateFile::Checkpoint, seq));
        }
    }
    out.sort();
    Ok(out)
}

// ---------------------------------------------------------------------------
// The store
// ---------------------------------------------------------------------------

/// The durable [`Scheduler`]: an [`IndexedStore`] whose every mutation
/// is first serialised into a CRC-framed, checkpointed, group-committed
/// log (see the module docs).  Inject via
/// [`FrameworkBuilder::scheduler`](crate::coordinator::Framework) or the
/// coordinator's `serve --state-dir <dir>` flag.
///
/// All mutating operations are serialised by the log mutex, so log order
/// always equals apply order — the property replay correctness rests on.
/// Read paths (`progress`, waits, streaming consumption) bypass the log
/// entirely and keep the inner store's lock granularity.
pub struct WalStore {
    inner: IndexedStore,
    /// One log stream per dispatch shard; `logs.len() == 1` is the
    /// legacy single-log layout, byte-for-byte.  Stream `i` serialises
    /// every mutation touching dispatch shard `i`; an op spanning
    /// several shards locks every touched stream in ascending index
    /// order (the global ordering that makes multi-stream ops
    /// deadlock-free) and appends one record to the lowest one.
    logs: Vec<Arc<CheckedMutex<LogWriter>>>,
    /// Global log-sequence-number allocator (sharded layout only).
    /// Every sharded record carries its LSN in an [`OP_SEQ`] envelope;
    /// recovery merges the stream tails in LSN order, which equals the
    /// original apply order because any two records touching a common
    /// shard allocated their LSNs under that shard's held stream lock
    /// (and records with no common shard commute).
    lsn: AtomicU64,
    /// Records appended since the last sharded checkpoint.  Sharded
    /// checkpoints are deferred: an append holds one stream lock, a
    /// checkpoint needs all of them, so the due-check runs only after
    /// an op has dropped its guards.
    sharded_records: AtomicU64,
    /// Single-flight guard so concurrent ops don't stack checkpoints.
    ckpt_in_progress: AtomicBool,
    wal_cfg: WalConfig,
    dir: PathBuf,
    stop_flusher: Arc<AtomicBool>,
    flusher: CheckedMutex<Option<JoinHandle<()>>>,
    /// Set by the group-commit flusher when an fsync fails; mutating
    /// ops refuse to proceed once durability is gone.
    sync_failed: Arc<AtomicBool>,
    /// Test-only hygiene: remove the state dir when dropped.
    remove_dir_on_drop: bool,
}

impl WalStore {
    /// Open a state directory: recover from it if it already holds WAL
    /// state (the *persisted* [`StoreConfig`] wins — replay re-runs the
    /// dispatch policy, so the config that wrote the log is the only
    /// correct one), otherwise start a fresh log under `store_cfg`.
    pub fn open(
        dir: impl AsRef<Path>,
        store_cfg: StoreConfig,
        wal_cfg: WalConfig,
    ) -> Result<WalStore> {
        let dir = dir.as_ref();
        fs::create_dir_all(dir)?;
        if !list_state_files(dir)?.is_empty() {
            let recovered = Self::recover_with(dir, wal_cfg)?;
            if *recovered.inner.config() != store_cfg {
                crate::log_warn!(
                    "wal",
                    "{}: recovered persisted StoreConfig {:?} (requested {:?} ignored)",
                    dir.display(),
                    recovered.inner.config(),
                    store_cfg
                );
            }
            let want = wal_cfg.dispatch_shards.max(1).next_power_of_two();
            if recovered.logs.len() != want {
                crate::log_warn!(
                    "wal",
                    "{}: recovered persisted layout with {} dispatch shard(s) (requested {} \
                     ignored)",
                    dir.display(),
                    recovered.logs.len(),
                    want
                );
            }
            return Ok(recovered);
        }
        if wal_cfg.dispatch_shards > 1 {
            let inner = IndexedStore::with_dispatch_shards(store_cfg, wal_cfg.dispatch_shards);
            let count = inner.dispatch_shard_count();
            let mut writers = Vec::with_capacity(count);
            for s in 0..count {
                writers.push(LogWriter::open_stream_segment(dir, s, count, 0, inner.config())?);
            }
            // The first generation's directory entries must be durable
            // too, or a power loss could lose the whole log at once.
            writers[0].sync_dir()?;
            return Ok(Self::assemble(inner, writers, wal_cfg, dir, 0));
        }
        let writer = LogWriter::open_segment(dir, 0, &store_cfg)?;
        // The first segment's directory entry must be durable too, or a
        // power loss could lose the whole (record-fsynced) log at once.
        writer.sync_dir()?;
        Ok(Self::assemble(IndexedStore::new(store_cfg), vec![writer], wal_cfg, dir, 0))
    }

    /// Recover a coordinator's store from its state directory with the
    /// default [`WalConfig`]: load the newest intact checkpoint, replay
    /// the segment tail, and continue logging on a fresh segment.
    /// Errors if `dir` holds no WAL state.
    pub fn recover(dir: impl AsRef<Path>) -> Result<WalStore> {
        Self::recover_with(dir, WalConfig::default())
    }

    /// [`recover`](Self::recover) with explicit WAL tuning.
    pub fn recover_with(dir: impl AsRef<Path>, wal_cfg: WalConfig) -> Result<WalStore> {
        let dir = dir.as_ref();
        let files = list_state_files(dir)?;
        ensure!(
            !files.is_empty(),
            "{}: no WAL segments or checkpoints to recover",
            dir.display()
        );
        // Per-shard stream segments mean the directory was written by a
        // sharded-layout store; the persisted layout wins, whatever
        // `wal_cfg.dispatch_shards` asks for.
        if files.iter().any(|(k, _)| matches!(k, StateFile::Stream(_))) {
            return Self::recover_sharded(dir, wal_cfg, &files);
        }

        // Newest checkpoint that decodes intact wins.  Falling back to an
        // older one is only sound while the intermediate segments still
        // exist (a crash *during* the newer checkpoint's truncation);
        // once they are gone, the continuity check below fails recovery
        // loudly instead of resurrecting a stale store.
        let mut checkpoints: Vec<u64> = files
            .iter()
            .filter(|(k, _)| *k == StateFile::Checkpoint)
            .map(|&(_, seq)| seq)
            .collect();
        checkpoints.sort_unstable();
        let mut base: Option<(u64, StoreSnapshot)> = None;
        for &seq in checkpoints.iter().rev() {
            match read_checkpoint(&checkpoint_path(dir, seq)) {
                Ok(snap) => {
                    base = Some((seq, snap));
                    break;
                }
                Err(e) => {
                    crate::log_warn!("wal", "checkpoint {seq} unreadable ({e:#}); falling back")
                }
            }
        }

        let mut segments: Vec<u64> = files
            .iter()
            .filter(|(k, _)| *k == StateFile::Segment)
            .map(|&(_, seq)| seq)
            .collect();
        segments.sort_unstable();
        let (base_seq, store) = match base {
            Some((seq, snap)) => (seq, IndexedStore::restore(snap)),
            None => {
                // Segments preceded by a checkpoint cannot stand alone:
                // with every checkpoint unreadable, the state is gone.
                ensure!(
                    checkpoints.is_empty(),
                    "{}: all checkpoints corrupt; segments alone cannot reconstruct the store",
                    dir.display()
                );
                // No checkpoint ever existed: the config record heading
                // the oldest segment tells us how to build the empty store.
                let first =
                    *segments.first().context("no readable checkpoint and no segments")?;
                let frames = read_segment(&segment_path(dir, first), false)?;
                let head = frames.first().context("empty first segment: nothing to recover")?;
                let mut d = Dec::new(head);
                ensure!(d.u8()? == OP_CONFIG, "first WAL record must be a config record");
                (first, IndexedStore::new(decode_config_record(&mut d)?))
            }
        };

        // Continuity: the replay tail must start at the checkpoint's seq
        // and have no holes — segment numbers are consecutive by
        // construction, so any gap means deleted history.
        let tail: Vec<u64> = segments.iter().copied().filter(|&s| s >= base_seq).collect();
        if let Some(&first_tail) = tail.first() {
            ensure!(
                first_tail == base_seq,
                "replay tail starts at segment {first_tail}, not at checkpoint {base_seq}: \
                 intermediate history was truncated"
            );
            for pair in tail.windows(2) {
                ensure!(
                    pair[1] == pair[0] + 1,
                    "segment gap between {} and {}: log history incomplete",
                    pair[0],
                    pair[1]
                );
            }
        }

        let last_seq = *segments.last().unwrap_or(&base_seq);
        let mut replayed = 0u64;
        for &seq in &tail {
            let strict = seq != last_seq;
            for frame in read_segment(&segment_path(dir, seq), strict)? {
                replayed += replay_record(&store, &frame)
                    .with_context(|| format!("replaying segment {seq}"))?;
            }
        }
        crate::log_info!(
            "wal",
            "{}: recovered {} tickets ({} replayed records on top of checkpoint {})",
            dir.display(),
            store.progress(None).total,
            replayed,
            base_seq
        );

        // Never append to a possibly-torn file: continue on a new segment.
        let mut writer = LogWriter::open_segment(dir, last_seq + 1, store.config())?;
        writer.sync_dir()?;
        writer.records_since_checkpoint = replayed;
        Ok(Self::assemble(store, vec![writer], wal_cfg, dir, replayed))
    }

    /// Recover a sharded-layout state directory: the newest intact
    /// checkpoint (if any) plus every stream's replay tail, merged in
    /// LSN order so the single-threaded replay re-applies mutations in
    /// exactly their original apply order (see the `lsn` field docs for
    /// why LSN order == apply order).
    fn recover_sharded(
        dir: &Path,
        wal_cfg: WalConfig,
        files: &[(StateFile, u64)],
    ) -> Result<WalStore> {
        // Newest checkpoint that decodes intact wins — same fallback
        // rationale as the legacy path.
        let mut checkpoints: Vec<u64> = files
            .iter()
            .filter(|(k, _)| *k == StateFile::Checkpoint)
            .map(|&(_, seq)| seq)
            .collect();
        checkpoints.sort_unstable();
        let mut base: Option<(u64, StoreSnapshot)> = None;
        for &seq in checkpoints.iter().rev() {
            match read_checkpoint(&checkpoint_path(dir, seq)) {
                Ok(snap) => {
                    base = Some((seq, snap));
                    break;
                }
                Err(e) => {
                    crate::log_warn!("wal", "checkpoint {seq} unreadable ({e:#}); falling back")
                }
            }
        }

        let mut streams: BTreeMap<usize, Vec<u64>> = BTreeMap::new();
        for &(kind, seq) in files {
            if let StateFile::Stream(s) = kind {
                streams.entry(s).or_default().push(seq);
            }
        }
        let shard_count = streams.keys().next_back().map(|&m| m + 1).unwrap_or(0);
        ensure!(
            streams.len() == shard_count,
            "sharded WAL stream set has holes: found streams {:?}",
            streams.keys().collect::<Vec<_>>()
        );
        for seqs in streams.values_mut() {
            seqs.sort_unstable();
        }

        let (base_seq, store) = match base {
            Some((seq, snap)) => {
                ensure!(
                    snap.dispatch_shards == shard_count,
                    "checkpoint says {} dispatch shards, directory has {shard_count} streams",
                    snap.dispatch_shards
                );
                (seq, IndexedStore::restore(snap))
            }
            None => {
                ensure!(
                    checkpoints.is_empty(),
                    "{}: all checkpoints corrupt; segments alone cannot reconstruct the store",
                    dir.display()
                );
                // No checkpoint ever existed: every stream starts at
                // generation 0, and stream 0's header records say how
                // to build the empty store.
                let first = *streams[&0].first().expect("listed stream has a segment");
                let frames = read_segment(&stream_segment_path(dir, 0, first), false)?;
                ensure!(
                    frames.len() >= 2,
                    "first stream segment lacks its config + shards header"
                );
                let mut d = Dec::new(&frames[0]);
                ensure!(d.u8()? == OP_CONFIG, "first WAL record must be a config record");
                let cfg = decode_config_record(&mut d)?;
                let mut d = Dec::new(&frames[1]);
                ensure!(
                    d.u8()? == OP_SHARDS,
                    "second record of a stream segment must be a shards record"
                );
                let logged = d.u32()? as usize;
                ensure!(
                    logged == shard_count,
                    "shards record says {logged} streams, directory has {shard_count}"
                );
                (first, IndexedStore::with_dispatch_shards(cfg, shard_count))
            }
        };
        ensure!(
            store.dispatch_shard_count() == shard_count,
            "recovered store has {} dispatch shards, directory has {shard_count} streams",
            store.dispatch_shard_count()
        );

        // Per-stream continuity (segment seqs advance independently per
        // stream; an empty tail is a stream the crash caught before its
        // rotation inside a partially-applied checkpoint), then harvest
        // each stream's `(lsn, inner record)` pairs.
        let mut pending: Vec<(u64, Vec<u8>)> = Vec::new();
        let mut next_seqs = vec![base_seq; shard_count];
        for (&stream, seqs) in &streams {
            let tail: Vec<u64> = seqs.iter().copied().filter(|&s| s >= base_seq).collect();
            if let Some(&first_tail) = tail.first() {
                ensure!(
                    first_tail == base_seq,
                    "stream {stream}: replay tail starts at segment {first_tail}, not at \
                     checkpoint {base_seq}: intermediate history was truncated"
                );
                for pair in tail.windows(2) {
                    ensure!(
                        pair[1] == pair[0] + 1,
                        "stream {stream}: segment gap between {} and {}: log history incomplete",
                        pair[0],
                        pair[1]
                    );
                }
            }
            let stream_last = tail.last().copied().unwrap_or(base_seq);
            next_seqs[stream] = if tail.is_empty() { base_seq } else { stream_last + 1 };
            for &seq in &tail {
                let strict = seq != stream_last;
                for frame in read_segment(&stream_segment_path(dir, stream, seq), strict)? {
                    let mut d = Dec::new(&frame);
                    match d.u8()? {
                        OP_SEQ => {
                            let lsn = d.u64()?;
                            pending.push((lsn, d.rest().to_vec()));
                        }
                        _ => {
                            // Per-segment header records (config +
                            // shards): cross-checked right here; they
                            // apply no mutation, so order is moot.
                            let applied = replay_record(&store, &frame).with_context(|| {
                                format!("stream {stream} segment {seq} header record")
                            })?;
                            ensure!(
                                applied == 0,
                                "stream {stream} segment {seq}: mutating record outside an \
                                 OP_SEQ envelope"
                            );
                        }
                    }
                }
            }
        }

        pending.sort_by_key(|&(lsn, _)| lsn);
        for pair in pending.windows(2) {
            ensure!(pair[0].0 != pair[1].0, "duplicate WAL LSN {}", pair[0].0);
        }
        // The next generation's LSNs must sort after everything replayed
        // here, or a later recovery would merge the two out of order.
        let next_lsn = pending.last().map(|&(lsn, _)| lsn + 1).unwrap_or(0);
        let mut replayed = 0u64;
        for (lsn, payload) in &pending {
            replayed += replay_record(&store, payload)
                .with_context(|| format!("replaying sharded record lsn {lsn}"))?;
        }
        crate::log_info!(
            "wal",
            "{}: recovered {} tickets ({} replayed records across {} streams on top of \
             checkpoint {})",
            dir.display(),
            store.progress(None).total,
            replayed,
            shard_count,
            base_seq
        );

        // Never append to a possibly-torn file: every stream continues
        // on a fresh segment.
        let mut writers = Vec::with_capacity(shard_count);
        for s in 0..shard_count {
            writers.push(LogWriter::open_stream_segment(
                dir,
                s,
                shard_count,
                next_seqs[s],
                store.config(),
            )?);
        }
        writers[0].sync_dir()?;
        let ws = Self::assemble(store, writers, wal_cfg, dir, replayed);
        ws.lsn.store(next_lsn, Ordering::SeqCst);
        Ok(ws)
    }

    fn assemble(
        inner: IndexedStore,
        writers: Vec<LogWriter>,
        wal_cfg: WalConfig,
        dir: &Path,
        records_since_ckpt: u64,
    ) -> WalStore {
        let logs: Vec<Arc<CheckedMutex<LogWriter>>> = writers
            .into_iter()
            .enumerate()
            .map(|(i, w)| Arc::new(CheckedMutex::new(Rank::wal_stream(i), w)))
            .collect();
        let stop_flusher = Arc::new(AtomicBool::new(false));
        let sync_failed = Arc::new(AtomicBool::new(false));
        let flusher = match wal_cfg.sync {
            SyncPolicy::GroupCommitMs(interval_ms) if interval_ms > 0 => {
                let logs = logs.clone();
                let stop = Arc::clone(&stop_flusher);
                let failed = Arc::clone(&sync_failed);
                Some(std::thread::spawn(move || {
                    // Wall clock on purpose (pallas-lint allow-listed):
                    // fsync pacing batches real disk I/O and never
                    // orders records — log order is fixed under the
                    // stream locks, so transcripts stay seed-pure.
                    let mut last = Instant::now();
                    while !stop.load(Ordering::Relaxed) {
                        // Sleep in short slices so Drop joins promptly.
                        std::thread::sleep(std::time::Duration::from_millis(interval_ms.min(20)));
                        if last.elapsed().as_millis() as u64 >= interval_ms {
                            for log in &logs {
                                if let Err(e) = log.lock().unwrap().sync() {
                                    // Poison the store: the next
                                    // mutating op dies instead of
                                    // acknowledging work the disk can
                                    // no longer persist.
                                    crate::log_error!("wal", "group-commit fsync failed: {e:#}");
                                    failed.store(true, Ordering::SeqCst);
                                    return;
                                }
                            }
                            last = Instant::now();
                        }
                    }
                }))
            }
            _ => None,
        };
        WalStore {
            inner,
            logs,
            lsn: AtomicU64::new(0),
            sharded_records: AtomicU64::new(records_since_ckpt),
            ckpt_in_progress: AtomicBool::new(false),
            wal_cfg,
            dir: dir.to_path_buf(),
            stop_flusher,
            flusher: CheckedMutex::new(Rank::wal_flusher(), flusher),
            sync_failed,
            remove_dir_on_drop: false,
        }
    }

    /// The state directory this store logs into.
    pub fn state_dir(&self) -> &Path {
        &self.dir
    }

    /// Force a checkpoint + log truncation now (graceful shutdowns make
    /// the next recovery O(checkpoint) instead of O(log)).
    pub fn checkpoint_now(&self) -> Result<()> {
        if self.logs.len() > 1 {
            return self.checkpoint_sharded();
        }
        let mut log = self.logs[0].lock().unwrap();
        log.checkpoint(&self.inner, self.inner.config())
    }

    /// Flush and fsync everything appended so far, regardless of policy.
    pub fn sync_now(&self) -> Result<()> {
        for log in &self.logs {
            log.lock().unwrap().sync()?;
        }
        Ok(())
    }

    /// Whether any appended record is still waiting for an fsync.  Test
    /// hook for the group-commit acknowledgement contract
    /// (`rust/tests/wal_recovery.rs`): after `complete`/`complete_batch`
    /// returns under [`SyncPolicy::GroupCommitMs`], this must be false.
    pub fn has_unsynced_appends(&self) -> bool {
        self.logs.iter().any(|l| l.lock().unwrap().dirty)
    }

    /// The group-commit acknowledgement fix: under `GroupCommitMs` a
    /// completion record is fsynced *before* the call returns (and the
    /// distributor Acks), so acknowledged results are never in the loss
    /// window.  `EveryRecord`/`GroupCommitMs(0)` already synced in
    /// `append`; `OsOnly`'s contract is process-crash durability, which
    /// the write+flush in `append` provides.
    fn sync_completions(&self, log: &mut LogWriter) -> Result<()> {
        if matches!(self.wal_cfg.sync, SyncPolicy::GroupCommitMs(t) if t > 0) {
            log.sync().context("fsync before acknowledging completion")?;
        }
        Ok(())
    }

    /// Append one record after its operation has been applied, keeping
    /// log order == apply order under the already-held log guard.  An
    /// append failure is fatal by design: a coordinator that cannot
    /// persist must stop taking work, exactly like the paper's
    /// coordinator losing MySQL.
    fn append(&self, log: &mut LogWriter, record: Enc) {
        assert!(
            !self.sync_failed.load(Ordering::SeqCst),
            "WAL group-commit fsync failed earlier: refusing to accept work without durability"
        );
        log.append(record, &self.wal_cfg, &self.inner)
            .expect("WAL append failed: refusing to continue without durability");
    }

    /// Lock the stream mutexes for `touched` (ascending, deduped) — the
    /// global ordering that keeps multi-stream ops deadlock-free.
    fn lock_streams(&self, touched: &[usize]) -> Vec<CheckedMutexGuard<'_, LogWriter>> {
        touched.iter().map(|&s| self.logs[s].lock().unwrap()).collect()
    }

    /// Sharded-mode append: allocate the next LSN, wrap `record` in an
    /// [`OP_SEQ`] envelope, and frame it into the already-locked stream
    /// `log`.  Callers holding several stream guards append to the
    /// lowest touched one — the LSN is allocated while every touched
    /// guard is held, which is what makes LSN order equal apply order.
    /// Only size rotation happens inline; checkpointing needs *all*
    /// stream locks and is deferred to
    /// [`maybe_checkpoint_sharded`](Self::maybe_checkpoint_sharded).
    fn append_stream(&self, log: &mut LogWriter, record: Enc) {
        assert!(
            !self.sync_failed.load(Ordering::SeqCst),
            "WAL group-commit fsync failed earlier: refusing to accept work without durability"
        );
        let lsn = self.lsn.fetch_add(1, Ordering::SeqCst);
        let mut e = Enc::new(OP_SEQ);
        e.u64(lsn);
        e.raw(&record.0);
        (|| -> Result<()> {
            log.write_frame(&e.frame())?;
            if matches!(self.wal_cfg.sync, SyncPolicy::EveryRecord | SyncPolicy::GroupCommitMs(0))
            {
                log.sync()?;
            }
            if log.bytes_in_segment >= self.wal_cfg.segment_max_bytes {
                log.rotate(self.inner.config())?;
            }
            Ok(())
        })()
        .expect("WAL append failed: refusing to continue without durability");
        self.sharded_records.fetch_add(1, Ordering::Relaxed);
    }

    /// Run a sharded checkpoint if one is due and nobody else is mid
    /// checkpoint.  Called after a mutating op has dropped its stream
    /// guards (the checkpoint takes all of them).
    fn maybe_checkpoint_sharded(&self) {
        let every = self.wal_cfg.checkpoint_every;
        if every == 0 || self.sharded_records.load(Ordering::Relaxed) < every {
            return;
        }
        if self
            .ckpt_in_progress
            .compare_exchange(false, true, Ordering::SeqCst, Ordering::SeqCst)
            .is_err()
        {
            return;
        }
        let r = self.checkpoint_sharded();
        self.ckpt_in_progress.store(false, Ordering::SeqCst);
        r.expect("WAL checkpoint failed: refusing to continue without durability");
    }

    /// Sharded checkpoint: freeze every stream (ascending lock order),
    /// snapshot the store, rotate all streams to one common generation
    /// `new_seq`, then delete everything older.  Recovery tolerates a
    /// crash anywhere in this sequence: an unrenamed `.tmp` falls back
    /// to the previous checkpoint, an unrotated stream shows up as an
    /// empty replay tail, and an interrupted deletion just leaves stale
    /// files below `new_seq` that the tail filter ignores.
    fn checkpoint_sharded(&self) -> Result<()> {
        let mut guards = self.lock_streams(&(0..self.logs.len()).collect::<Vec<_>>());
        let new_seq = guards.iter().map(|g| g.seq).max().unwrap_or(0) + 1;
        let tmp = self.dir.join(format!("checkpoint-{new_seq:08}.tmp"));
        {
            let mut f = File::create(&tmp)?;
            f.write_all(&CHECKPOINT_MAGIC)?;
            f.write_all(&encode_snapshot(&self.inner.snapshot()))?;
            f.sync_all()?;
        }
        fs::rename(&tmp, checkpoint_path(&self.dir, new_seq))?;
        let count = guards.len();
        for (s, g) in guards.iter_mut().enumerate() {
            g.sync()?;
            **g = LogWriter::open_stream_segment(
                &self.dir,
                s,
                count,
                new_seq,
                self.inner.config(),
            )?;
        }
        guards[0].sync_dir()?;
        for (kind, seq) in list_state_files(&self.dir)? {
            if seq < new_seq {
                let _ = fs::remove_file(state_file_path(&self.dir, kind, seq));
            }
        }
        self.sharded_records.store(0, Ordering::SeqCst);
        Ok(())
    }

    /// Sharded `create_tickets`.  Ids are allocated *before* the stream
    /// locks — the tickets stay unreachable until `create_tickets_exact`
    /// publishes them under the locks, and the explicit ids in the
    /// record make replay immune to allocator interleaving.
    fn sharded_create(
        &self,
        task: TaskId,
        task_name: &str,
        args: Vec<Value>,
        now_ms: u64,
        payload_json: &[String],
    ) -> Vec<TicketId> {
        let n = args.len() as u64;
        if n == 0 {
            // Nothing is created (see `create_tickets_exact`), so
            // nothing needs logging.
            return Vec::new();
        }
        let base = self.inner.allocate_ids(n);
        let items: Vec<(u64, usize, Value)> = args
            .into_iter()
            .enumerate()
            .map(|(index, payload)| (base + index as u64, index, payload))
            .collect();
        let mut touched: Vec<usize> =
            items.iter().map(|&(id, _, _)| self.inner.dshard(id)).collect();
        touched.sort_unstable();
        touched.dedup();
        let mut guards = self.lock_streams(&touched);
        self.inner.create_tickets_exact(task, task_name, items, now_ms);
        let mut e = Enc::new(OP_CREATE_EXACT);
        e.u64(task.0);
        e.u64(now_ms);
        e.str(task_name);
        e.u32(n as u32);
        for (i, json) in payload_json.iter().enumerate() {
            e.u64(base + i as u64);
            e.u64(i as u64);
            e.str(json);
        }
        self.append_stream(&mut guards[0], e);
        drop(guards);
        self.maybe_checkpoint_sharded();
        (base..base + n).map(TicketId).collect()
    }

    /// Sharded `next_tickets`: the same home-then-steal scan as the
    /// in-memory store, but over *stream* locks (home blocking, sibling
    /// streams under try-lock), with each non-empty per-shard decision
    /// run logged as one [`OP_DISPATCH_SHARD`] record on that shard's
    /// own stream — dispatch never serialises on a global log.
    fn sharded_next_tickets(&self, client: &str, now_ms: u64, k: usize) -> Vec<Ticket> {
        if k == 0 {
            return Vec::new();
        }
        let nshards = self.logs.len();
        let home = self.inner.home_shard(client);
        let mut out: Vec<Ticket> = Vec::new();
        for i in 0..nshards {
            if out.len() >= k {
                break;
            }
            let sh = (home + i) % nshards;
            let mut guard = if i == 0 {
                self.logs[sh].lock().unwrap()
            } else {
                self.inner.note_steal_attempt();
                match self.logs[sh].try_lock() {
                    Ok(g) => g,
                    Err(_) => continue,
                }
            };
            let got = self.inner.next_tickets_from_shard(sh, client, now_ms, k - out.len());
            if !got.is_empty() {
                if i > 0 {
                    self.inner.note_steal_success();
                }
                let mut e = Enc::new(OP_DISPATCH_SHARD);
                e.u32(sh as u32);
                e.u64(now_ms);
                e.str(client);
                e.u32(got.len() as u32);
                for t in &got {
                    e.u64(t.id.0);
                }
                self.append_stream(&mut guard, e);
                out.extend(got);
            }
            drop(guard);
        }
        self.maybe_checkpoint_sharded();
        out
    }

    fn sharded_complete(&self, id: TicketId, result: Value, result_json: &str) -> Result<bool> {
        let mut log = self.logs[self.inner.dshard(id.0)].lock().unwrap();
        let fresh = self.inner.complete(id, result)?;
        let mut e = Enc::new(OP_COMPLETE);
        e.u64(id.0);
        e.u8(fresh as u8);
        e.str(result_json);
        self.append_stream(&mut log, e);
        self.sync_completions(&mut log)?;
        drop(log);
        self.maybe_checkpoint_sharded();
        Ok(fresh)
    }

    fn sharded_complete_batch(
        &self,
        results: Vec<(TicketId, Value)>,
        jsons: &[(u64, String)],
    ) -> Result<usize> {
        if results.is_empty() {
            return Ok(0); // nothing to apply, log, or lock
        }
        let mut touched: Vec<usize> =
            results.iter().map(|(id, _)| self.inner.dshard(id.0)).collect();
        touched.sort_unstable();
        touched.dedup();
        let mut guards = self.lock_streams(&touched);
        let (flags, stopped) = self.inner.complete_batch_flags(results, None);
        // Log the applied prefix with its per-entry accepted flags; an
        // erroring entry was not applied and is not logged.
        if !flags.is_empty() {
            let mut e = Enc::new(OP_COMPLETE_BATCH);
            e.u32(flags.len() as u32);
            for (i, (accepted, _)) in flags.iter().enumerate() {
                e.u64(jsons[i].0);
                e.u8(*accepted as u8);
                e.str(&jsons[i].1);
            }
            self.append_stream(&mut guards[0], e);
        }
        self.sync_completions(&mut guards[0])?;
        drop(guards);
        self.maybe_checkpoint_sharded();
        match stopped {
            Some(err) => Err(err),
            None => Ok(flags.iter().filter(|f| f.0).count()),
        }
    }

    fn sharded_report_error(&self, id: TicketId, report: String) -> Result<()> {
        let mut log = self.logs[self.inner.dshard(id.0)].lock().unwrap();
        let mut e = Enc::new(OP_ERROR);
        e.u64(id.0);
        e.str(&report);
        self.inner.report_error(id, report)?;
        self.append_stream(&mut log, e);
        drop(log);
        self.maybe_checkpoint_sharded();
        Ok(())
    }

    fn sharded_release_batch(&self, ids: &[TicketId]) -> Vec<bool> {
        if ids.is_empty() {
            return Vec::new(); // nothing to apply, log, or lock
        }
        let mut touched: Vec<usize> = ids.iter().map(|id| self.inner.dshard(id.0)).collect();
        touched.sort_unstable();
        touched.dedup();
        let mut guards = self.lock_streams(&touched);
        let flags = self.inner.release_batch(ids);
        let mut e = Enc::new(OP_RELEASE_BATCH);
        e.u32(ids.len() as u32);
        for (i, id) in ids.iter().enumerate() {
            e.u64(id.0);
            e.u8(flags[i] as u8);
        }
        self.append_stream(&mut guards[0], e);
        drop(guards);
        self.maybe_checkpoint_sharded();
        flags
    }

    fn sharded_drain_errors(&self) -> Vec<(TicketId, String)> {
        // The drain empties every shard's queue, so its record must
        // order against every stream's traffic: all streams locked,
        // ascending.
        let mut guards = self.lock_streams(&(0..self.logs.len()).collect::<Vec<_>>());
        let mut drained = Vec::new();
        for shard in 0..self.logs.len() {
            drained.extend(self.inner.drain_errors_shard(shard));
        }
        if !drained.is_empty() {
            self.append_stream(&mut guards[0], Enc::new(OP_DRAIN_ERRORS));
        }
        drop(guards);
        self.maybe_checkpoint_sharded();
        drained
    }

    /// Fresh store in a unique throwaway directory, removed on drop.
    #[cfg(test)]
    pub(crate) fn open_temp_for_tests(cfg: StoreConfig) -> WalStore {
        Self::open_temp_with(cfg, WalConfig::default())
    }

    /// [`open_temp_for_tests`](Self::open_temp_for_tests) with explicit
    /// WAL tuning (e.g. a sharded layout).
    #[cfg(test)]
    pub(crate) fn open_temp_with(cfg: StoreConfig, wal_cfg: WalConfig) -> WalStore {
        static N: AtomicU64 = AtomicU64::new(0);
        let dir = std::env::temp_dir().join(format!(
            "sashimi-wal-suite-{}-{}",
            std::process::id(),
            N.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = fs::remove_dir_all(&dir);
        let mut s = WalStore::open(&dir, cfg, wal_cfg).expect("temp WAL store");
        s.remove_dir_on_drop = true;
        s
    }
}

impl Drop for WalStore {
    fn drop(&mut self) {
        self.stop_flusher.store(true, Ordering::Relaxed);
        if let Some(h) = self.flusher.lock().unwrap().take() {
            let _ = h.join();
        }
        for log in &self.logs {
            if let Ok(mut log) = log.lock() {
                let _ = log.sync();
            }
        }
        if self.remove_dir_on_drop {
            let _ = fs::remove_dir_all(&self.dir);
        }
    }
}

/// Replay one record payload onto `store`.  Returns how many *logical*
/// records were applied (1, or 0 for config frames), and cross-checks
/// the logged outcome against the deterministic re-execution: any
/// divergence means the log and the policy disagree, and recovery must
/// fail loudly rather than resurrect a different history.
fn replay_record(store: &IndexedStore, payload: &[u8]) -> Result<u64> {
    let mut d = Dec::new(payload);
    match d.u8()? {
        OP_CONFIG => {
            let cfg = decode_config_record(&mut d)?;
            d.done()?;
            ensure!(
                cfg == *store.config(),
                "config record {cfg:?} contradicts recovering store {:?}",
                store.config()
            );
            Ok(0)
        }
        OP_CREATE => {
            let task = TaskId(d.u64()?);
            let now_ms = d.u64()?;
            let base_id = d.u64()?;
            let task_name = d.str()?;
            let n = d.u32()? as usize;
            let mut args = Vec::with_capacity(n);
            for _ in 0..n {
                args.push(d.value()?);
            }
            d.done()?;
            let ids = store.create_tickets(task, &task_name, args, now_ms);
            ensure!(
                ids.first().map(|i| i.0).unwrap_or(base_id) == base_id,
                "replayed create assigned id {:?}, log says {base_id}",
                ids.first()
            );
            Ok(1)
        }
        OP_DISPATCH => {
            let now_ms = d.u64()?;
            let ticket = d.u64()?;
            let client = d.str()?;
            d.done()?;
            let t = store
                .next_ticket(&client, now_ms)
                .with_context(|| format!("replayed dispatch at t={now_ms} found no ticket"))?;
            ensure!(
                t.id.0 == ticket,
                "replayed dispatch picked {:?}, log says {ticket}",
                t.id
            );
            Ok(1)
        }
        OP_COMPLETE => {
            let ticket = TicketId(d.u64()?);
            let accepted = d.u8()? != 0;
            let result = d.value()?;
            d.done()?;
            let fresh = store.complete(ticket, result)?;
            ensure!(
                fresh == accepted,
                "replayed completion of {ticket:?} accepted={fresh}, log says {accepted}"
            );
            Ok(1)
        }
        OP_ERROR => {
            let ticket = TicketId(d.u64()?);
            let report = d.str()?;
            d.done()?;
            store.report_error(ticket, report)?;
            Ok(1)
        }
        OP_DRAIN_ERRORS => {
            d.done()?;
            let _ = store.drain_errors();
            Ok(1)
        }
        OP_DISPATCH_BATCH => {
            let now_ms = d.u64()?;
            let client = d.str()?;
            let n = d.u32()? as usize;
            let mut ids = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                ids.push(d.u64()?);
            }
            d.done()?;
            // A batch is a prefix of the k-fold dispatch sequence, so
            // replaying with k = n deterministically re-picks exactly
            // the logged tickets (whatever k was originally requested).
            let tickets = store.next_tickets(&client, now_ms, ids.len());
            let picked: Vec<u64> = tickets.iter().map(|t| t.id.0).collect();
            ensure!(
                picked == ids,
                "replayed batch dispatch picked {picked:?}, log says {ids:?}"
            );
            Ok(1)
        }
        OP_COMPLETE_BATCH => {
            let n = d.u32()? as usize;
            let mut entries = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                let id = TicketId(d.u64()?);
                let accepted = d.u8()? != 0;
                let result = d.value()?;
                entries.push((id, accepted, result));
            }
            d.done()?;
            for (id, accepted, result) in entries {
                let fresh = store.complete(id, result)?;
                ensure!(
                    fresh == accepted,
                    "replayed batch completion of {id:?} accepted={fresh}, log says {accepted}"
                );
            }
            Ok(1)
        }
        OP_RELEASE_BATCH => {
            let n = d.u32()? as usize;
            let mut entries = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                let id = TicketId(d.u64()?);
                let released = d.u8()? != 0;
                entries.push((id, released));
            }
            d.done()?;
            let ids: Vec<TicketId> = entries.iter().map(|&(id, _)| id).collect();
            let flags = store.release_batch(&ids);
            for (i, &(id, logged)) in entries.iter().enumerate() {
                ensure!(
                    flags[i] == logged,
                    "replayed release of {id:?} released={}, log says {logged}",
                    flags[i]
                );
            }
            Ok(1)
        }
        OP_SHARDS => {
            let count = d.u32()? as usize;
            let _stream = d.u32()?;
            d.done()?;
            ensure!(
                count == store.dispatch_shard_count(),
                "shards record says {count} dispatch shards, recovering store has {}",
                store.dispatch_shard_count()
            );
            Ok(0)
        }
        OP_CREATE_EXACT => {
            let task = TaskId(d.u64()?);
            let now_ms = d.u64()?;
            let task_name = d.str()?;
            let n = d.u32()? as usize;
            let mut items = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                let id = d.u64()?;
                let index = d.u64()? as usize;
                let payload = d.value()?;
                items.push((id, index, payload));
            }
            d.done()?;
            // The record carries explicit ids, so replay re-inserts the
            // exact originals regardless of merge interleaving.
            store.create_tickets_exact(task, &task_name, items, now_ms);
            Ok(1)
        }
        OP_DISPATCH_SHARD => {
            let shard = d.u32()? as usize;
            let now_ms = d.u64()?;
            let client = d.str()?;
            let n = d.u32()? as usize;
            let mut ids = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                ids.push(d.u64()?);
            }
            d.done()?;
            ensure!(
                shard < store.dispatch_shard_count(),
                "dispatch record for shard {shard}, store has {}",
                store.dispatch_shard_count()
            );
            // One shard's decision run is a prefix of that shard's
            // k-fold VCT sequence, so replaying with k = n re-picks
            // exactly the logged tickets.
            let tickets = store.next_tickets_from_shard(shard, &client, now_ms, ids.len());
            let picked: Vec<u64> = tickets.iter().map(|t| t.id.0).collect();
            ensure!(
                picked == ids,
                "replayed shard-{shard} dispatch picked {picked:?}, log says {ids:?}"
            );
            Ok(1)
        }
        OP_VOTE => {
            let now_ms = d.u64()?;
            let client = d.str()?;
            let ticket = TicketId(d.u64()?);
            let logged = d.u8()?;
            let result = d.value()?;
            d.done()?;
            let out = store.vote(&client, ticket, result, now_ms)?;
            let code = vote_code(&out);
            ensure!(
                code == logged,
                "replayed vote on {ticket:?} by {client} gave outcome {code}, log says {logged}"
            );
            Ok(1)
        }
        OP_RELEASE_FROM => {
            let client = d.str()?;
            let n = d.u32()? as usize;
            let mut entries = Vec::with_capacity(n.min(1 << 20));
            for _ in 0..n {
                let id = TicketId(d.u64()?);
                let released = d.u8()? != 0;
                entries.push((id, released));
            }
            d.done()?;
            let ids: Vec<TicketId> = entries.iter().map(|&(id, _)| id).collect();
            let flags = store.release_batch_from(&client, &ids);
            for (i, &(id, logged)) in entries.iter().enumerate() {
                ensure!(
                    flags[i] == logged,
                    "replayed release of {id:?} from {client} released={}, log says {logged}",
                    flags[i]
                );
            }
            Ok(1)
        }
        OP_ERROR_FROM => {
            let client = d.str()?;
            let ticket = TicketId(d.u64()?);
            let report = d.str()?;
            d.done()?;
            store.report_error_from(&client, ticket, report)?;
            Ok(1)
        }
        op => bail!("unknown WAL opcode {op}"),
    }
}

/// Stable wire discriminant of a [`VoteOutcome`] for the replay
/// cross-check (the verdict payload is re-derived, not logged).
fn vote_code(o: &VoteOutcome) -> u8 {
    match o {
        VoteOutcome::Accepted { .. } => 0,
        VoteOutcome::Pending => 1,
        VoteOutcome::Duplicate { same_client: false } => 2,
        VoteOutcome::Duplicate { same_client: true } => 3,
        VoteOutcome::Repeat => 4,
    }
}

fn read_checkpoint(path: &Path) -> Result<StoreSnapshot> {
    let mut bytes = Vec::new();
    File::open(path)?.read_to_end(&mut bytes)?;
    ensure!(
        bytes.len() >= CHECKPOINT_MAGIC.len() + 8
            && bytes[..CHECKPOINT_MAGIC.len()] == CHECKPOINT_MAGIC,
        "bad checkpoint header"
    );
    let i = CHECKPOINT_MAGIC.len();
    let len = u32::from_le_bytes(bytes[i..i + 4].try_into().unwrap());
    ensure!(len <= MAX_FRAME, "absurd checkpoint frame length {len}");
    let crc = u32::from_le_bytes(bytes[i + 4..i + 8].try_into().unwrap());
    let payload = bytes
        .get(i + 8..i + 8 + len as usize)
        .context("truncated checkpoint frame")?;
    ensure!(crc32(payload) == crc, "checkpoint CRC mismatch");
    decode_snapshot(payload)
}

impl Scheduler for WalStore {
    fn config(&self) -> &StoreConfig {
        self.inner.config()
    }

    fn create_tickets(
        &self,
        task: TaskId,
        task_name: &str,
        args: Vec<Value>,
        now_ms: u64,
    ) -> Vec<TicketId> {
        // Serialise payloads before `args` moves into the inner store.
        let payload_json: Vec<String> = args.iter().map(|v| v.to_string()).collect();
        if self.logs.len() > 1 {
            return self.sharded_create(task, task_name, args, now_ms, &payload_json);
        }
        let mut log = self.logs[0].lock().unwrap();
        let ids = self.inner.create_tickets(task, task_name, args, now_ms);
        let mut e = Enc::new(OP_CREATE);
        e.u64(task.0);
        e.u64(now_ms);
        e.u64(ids.first().map(|i| i.0).unwrap_or(0));
        e.str(task_name);
        e.u32(payload_json.len() as u32);
        for s in &payload_json {
            e.str(s);
        }
        self.append(&mut log, e);
        ids
    }

    fn next_ticket(&self, client: &str, now_ms: u64) -> Option<Ticket> {
        if self.logs.len() > 1 {
            return self.sharded_next_tickets(client, now_ms, 1).pop();
        }
        let mut log = self.logs[0].lock().unwrap();
        let t = self.inner.next_ticket(client, now_ms)?;
        let mut e = Enc::new(OP_DISPATCH);
        e.u64(now_ms);
        e.u64(t.id.0);
        e.str(client);
        self.append(&mut log, e);
        Some(t)
    }

    fn next_tickets(&self, client: &str, now_ms: u64, k: usize) -> Vec<Ticket> {
        if self.logs.len() > 1 {
            return self.sharded_next_tickets(client, now_ms, k);
        }
        let mut log = self.logs[0].lock().unwrap();
        let tickets = self.inner.next_tickets(client, now_ms, k);
        if tickets.is_empty() {
            // Nothing mutated, nothing to log.
            return tickets;
        }
        let mut e = Enc::new(OP_DISPATCH_BATCH);
        e.u64(now_ms);
        e.str(client);
        e.u32(tickets.len() as u32);
        for t in &tickets {
            e.u64(t.id.0);
        }
        self.append(&mut log, e);
        tickets
    }

    fn complete(&self, id: TicketId, result: Value) -> Result<bool> {
        let result_json = result.to_string();
        if self.logs.len() > 1 {
            return self.sharded_complete(id, result, &result_json);
        }
        let mut log = self.logs[0].lock().unwrap();
        let fresh = self.inner.complete(id, result)?;
        let mut e = Enc::new(OP_COMPLETE);
        e.u64(id.0);
        e.u8(fresh as u8);
        e.str(&result_json);
        self.append(&mut log, e);
        self.sync_completions(&mut log)?;
        Ok(fresh)
    }

    fn complete_batch(&self, results: Vec<(TicketId, Value)>) -> Result<usize> {
        if results.is_empty() {
            return Ok(0);
        }
        // Serialise payloads before `results` moves into the inner store.
        let jsons: Vec<(u64, String)> =
            results.iter().map(|(id, v)| (id.0, v.to_string())).collect();
        if self.logs.len() > 1 {
            return self.sharded_complete_batch(results, &jsons);
        }
        let mut log = self.logs[0].lock().unwrap();
        let (flags, stopped) = self.inner.complete_batch_flags(results, None);
        // Log the applied prefix with its per-entry accepted flags; an
        // erroring entry was not applied and is not logged.
        if !flags.is_empty() {
            let mut e = Enc::new(OP_COMPLETE_BATCH);
            e.u32(flags.len() as u32);
            for (i, (accepted, _)) in flags.iter().enumerate() {
                e.u64(jsons[i].0);
                e.u8(*accepted as u8);
                e.str(&jsons[i].1);
            }
            self.append(&mut log, e);
        }
        self.sync_completions(&mut log)?;
        match stopped {
            Some(err) => Err(err),
            None => Ok(flags.iter().filter(|f| f.0).count()),
        }
    }

    fn report_error(&self, id: TicketId, report: String) -> Result<()> {
        if self.logs.len() > 1 {
            return self.sharded_report_error(id, report);
        }
        let mut log = self.logs[0].lock().unwrap();
        let mut e = Enc::new(OP_ERROR);
        e.u64(id.0);
        e.str(&report);
        self.inner.report_error(id, report)?;
        self.append(&mut log, e);
        Ok(())
    }

    fn vote(&self, client: &str, id: TicketId, result: Value, now_ms: u64) -> Result<VoteOutcome> {
        let result_json = result.to_string();
        if !self.inner.config().verifying() {
            // R = 1: the vote *is* the legacy completion.  Log the exact
            // OP_COMPLETE record the unattributed path writes, so R = 1
            // transcripts stay byte-identical to pre-verification logs.
            if self.logs.len() > 1 {
                let mut log = self.logs[self.inner.dshard(id.0)].lock().unwrap();
                let out = self.inner.vote(client, id, result, now_ms)?;
                let mut e = Enc::new(OP_COMPLETE);
                e.u64(id.0);
                e.u8(matches!(out, VoteOutcome::Accepted { .. }) as u8);
                e.str(&result_json);
                self.append_stream(&mut log, e);
                self.sync_completions(&mut log)?;
                drop(log);
                self.maybe_checkpoint_sharded();
                return Ok(out);
            }
            let mut log = self.logs[0].lock().unwrap();
            let out = self.inner.vote(client, id, result, now_ms)?;
            let mut e = Enc::new(OP_COMPLETE);
            e.u64(id.0);
            e.u8(matches!(out, VoteOutcome::Accepted { .. }) as u8);
            e.str(&result_json);
            self.append(&mut log, e);
            self.sync_completions(&mut log)?;
            return Ok(out);
        }
        // R > 1: a vote can move cross-shard reputation state, so in
        // the sharded layout its record must order against *every*
        // stream (all locks held while the LSN is allocated), exactly
        // like the drain-errors record.
        let all: Vec<usize> = (0..self.logs.len()).collect();
        let mut guards = self.lock_streams(&all);
        let out = self.inner.vote(client, id, result, now_ms)?;
        let mut e = Enc::new(OP_VOTE);
        e.u64(now_ms);
        e.str(client);
        e.u64(id.0);
        e.u8(vote_code(&out));
        e.str(&result_json);
        if self.logs.len() > 1 {
            self.append_stream(&mut guards[0], e);
            self.sync_completions(&mut guards[0])?;
            drop(guards);
            self.maybe_checkpoint_sharded();
        } else {
            self.append(&mut guards[0], e);
            self.sync_completions(&mut guards[0])?;
        }
        Ok(out)
    }

    fn vote_batch(
        &self,
        client: &str,
        results: Vec<(TicketId, Value)>,
        now_ms: u64,
    ) -> Result<Vec<VoteOutcome>> {
        if self.inner.config().verifying() {
            // R > 1: every ballot is its own replayable OP_VOTE record.
            return results.into_iter().map(|(id, v)| self.vote(client, id, v, now_ms)).collect();
        }
        if results.is_empty() {
            return Ok(Vec::new());
        }
        // R = 1: one OP_COMPLETE_BATCH record, byte-identical to the
        // unattributed batch path (the logged flags do not depend on
        // the voter; attribution lives only in memory).
        let jsons: Vec<(u64, String)> =
            results.iter().map(|(id, v)| (id.0, v.to_string())).collect();
        let (flags, stopped) = if self.logs.len() > 1 {
            let mut touched: Vec<usize> =
                results.iter().map(|(id, _)| self.inner.dshard(id.0)).collect();
            touched.sort_unstable();
            touched.dedup();
            let mut guards = self.lock_streams(&touched);
            let (flags, stopped) = self.inner.complete_batch_flags(results, Some(client));
            if !flags.is_empty() {
                let mut e = Enc::new(OP_COMPLETE_BATCH);
                e.u32(flags.len() as u32);
                for (i, (accepted, _)) in flags.iter().enumerate() {
                    e.u64(jsons[i].0);
                    e.u8(*accepted as u8);
                    e.str(&jsons[i].1);
                }
                self.append_stream(&mut guards[0], e);
            }
            self.sync_completions(&mut guards[0])?;
            drop(guards);
            self.maybe_checkpoint_sharded();
            (flags, stopped)
        } else {
            let mut log = self.logs[0].lock().unwrap();
            let (flags, stopped) = self.inner.complete_batch_flags(results, Some(client));
            if !flags.is_empty() {
                let mut e = Enc::new(OP_COMPLETE_BATCH);
                e.u32(flags.len() as u32);
                for (i, (accepted, _)) in flags.iter().enumerate() {
                    e.u64(jsons[i].0);
                    e.u8(*accepted as u8);
                    e.str(&jsons[i].1);
                }
                self.append(&mut log, e);
            }
            self.sync_completions(&mut log)?;
            (flags, stopped)
        };
        match stopped {
            Some(err) => Err(err),
            None => Ok(flags
                .into_iter()
                .map(|(accepted, same_client)| {
                    if accepted {
                        VoteOutcome::Accepted { verdict: None }
                    } else {
                        VoteOutcome::Duplicate { same_client }
                    }
                })
                .collect()),
        }
    }

    fn release_batch_from(&self, client: &str, ids: &[TicketId]) -> Vec<bool> {
        if !self.inner.config().verifying() {
            // R = 1: holder attribution is vacuous; log the legacy
            // release record byte-identically.
            return self.release_batch(ids);
        }
        if ids.is_empty() {
            return Vec::new();
        }
        let all: Vec<usize> = (0..self.logs.len()).collect();
        let mut guards = self.lock_streams(&all);
        let flags = self.inner.release_batch_from(client, ids);
        let mut e = Enc::new(OP_RELEASE_FROM);
        e.str(client);
        e.u32(ids.len() as u32);
        for (i, id) in ids.iter().enumerate() {
            e.u64(id.0);
            e.u8(flags[i] as u8);
        }
        if self.logs.len() > 1 {
            self.append_stream(&mut guards[0], e);
            drop(guards);
            self.maybe_checkpoint_sharded();
        } else {
            self.append(&mut guards[0], e);
        }
        flags
    }

    fn report_error_from(&self, client: &str, id: TicketId, report: String) -> Result<()> {
        if !self.inner.config().verifying() {
            return self.report_error(id, report);
        }
        let all: Vec<usize> = (0..self.logs.len()).collect();
        let mut guards = self.lock_streams(&all);
        let mut e = Enc::new(OP_ERROR_FROM);
        e.str(client);
        e.u64(id.0);
        e.str(&report);
        self.inner.report_error_from(client, id, report)?;
        if self.logs.len() > 1 {
            self.append_stream(&mut guards[0], e);
            drop(guards);
            self.maybe_checkpoint_sharded();
        } else {
            self.append(&mut guards[0], e);
        }
        Ok(())
    }

    fn client_standing(&self, client: &str, now_ms: u64) -> Standing {
        // Read-only surface (the lazy probation-expiry it may trigger is
        // recomputed identically from `now_ms` after replay): not logged.
        self.inner.client_standing(client, now_ms)
    }

    fn verify_stats(&self) -> VerifyStats {
        self.inner.verify_stats()
    }

    fn quarantined_clients(&self) -> Vec<String> {
        self.inner.quarantined_clients()
    }

    fn release(&self, id: TicketId) -> bool {
        self.release_batch(std::slice::from_ref(&id))[0]
    }

    fn release_batch(&self, ids: &[TicketId]) -> Vec<bool> {
        if ids.is_empty() {
            return Vec::new();
        }
        if self.logs.len() > 1 {
            return self.sharded_release_batch(ids);
        }
        let mut log = self.logs[0].lock().unwrap();
        let flags = self.inner.release_batch(ids);
        // One framed record per batch, with the per-entry released
        // flags for the replay cross-check (a no-op flag changes no
        // state, but replay must still agree it was a no-op).
        let mut e = Enc::new(OP_RELEASE_BATCH);
        e.u32(ids.len() as u32);
        for (i, id) in ids.iter().enumerate() {
            e.u64(id.0);
            e.u8(flags[i] as u8);
        }
        self.append(&mut log, e);
        flags
    }

    fn next_completion(&self, task: TaskId, timeout_ms: u64) -> Option<(usize, Value)> {
        // Consumption is not logged (module docs: at-least-once delivery
        // after recovery), so this stays block-on-condvar, log-free.
        self.inner.next_completion(task, timeout_ms)
    }

    fn progress(&self, task: Option<TaskId>) -> Progress {
        self.inner.progress(task)
    }

    fn is_task_done(&self, task: TaskId) -> bool {
        self.inner.is_task_done(task)
    }

    fn max_task_id(&self) -> Option<TaskId> {
        self.inner.max_task_id()
    }

    fn wait_results_deadline(
        &self,
        task: TaskId,
        deadline: Option<Instant>,
    ) -> Option<Vec<Value>> {
        self.inner.wait_results_deadline(task, deadline)
    }

    fn error_count(&self) -> usize {
        self.inner.error_count()
    }

    fn drain_errors(&self) -> Vec<(TicketId, String)> {
        if self.logs.len() > 1 {
            return self.sharded_drain_errors();
        }
        let mut log = self.logs[0].lock().unwrap();
        let drained = self.inner.drain_errors();
        if !drained.is_empty() {
            self.append(&mut log, Enc::new(OP_DRAIN_ERRORS));
        }
        drained
    }

    fn stats(&self) -> SchedStats {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> StoreConfig {
        StoreConfig {
            requeue_after_ms: 1000,
            min_redistribute_ms: 100,
            requeue_on_error: true,
            ..StoreConfig::default()
        }
    }

    fn temp_dir(tag: &str) -> PathBuf {
        use std::sync::atomic::AtomicU64;
        static N: AtomicU64 = AtomicU64::new(0);
        let dir = std::env::temp_dir().join(format!(
            "sashimi-wal-{tag}-{}-{}",
            std::process::id(),
            N.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn frame_codec_roundtrips() {
        let mut e = Enc::new(OP_CREATE);
        e.u64(7);
        e.u32(42);
        e.u8(1);
        e.str("héllo \"quoted\"");
        e.value(&Value::obj(vec![("k", Value::num(1.5))]));
        let frame = e.frame();
        let len = u32::from_le_bytes(frame[0..4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(frame[4..8].try_into().unwrap());
        let payload = &frame[8..];
        assert_eq!(payload.len(), len);
        assert_eq!(crc32(payload), crc);
        let mut d = Dec::new(payload);
        assert_eq!(d.u8().unwrap(), OP_CREATE);
        assert_eq!(d.u64().unwrap(), 7);
        assert_eq!(d.u32().unwrap(), 42);
        assert_eq!(d.u8().unwrap(), 1);
        assert_eq!(d.str().unwrap(), "héllo \"quoted\"");
        assert_eq!(d.value().unwrap(), Value::obj(vec![("k", Value::num(1.5))]));
        d.done().unwrap();
    }

    #[test]
    fn crc_detects_corruption() {
        let mut e = Enc::new(OP_ERROR);
        e.u64(3);
        e.str("boom");
        let mut frame = e.frame();
        let last = frame.len() - 1;
        frame[last] ^= 0x40;
        let payload = &frame[8..];
        let crc = u32::from_le_bytes(frame[4..8].try_into().unwrap());
        assert_ne!(crc32(payload), crc);
    }

    #[test]
    fn crc32_matches_known_vector() {
        // The standard IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }

    #[test]
    fn fresh_open_then_recover_roundtrips_state() {
        let dir = temp_dir("roundtrip");
        let ids = {
            let s = WalStore::open(&dir, cfg(), WalConfig::default()).unwrap();
            let ids = s.create_tickets(
                TaskId(1),
                "t",
                (0..3).map(|i| Value::num(i as f64)).collect(),
                0,
            );
            let t = s.next_ticket("c1", 5).unwrap();
            assert_eq!(t.id, ids[0]);
            s.complete(ids[0], Value::num(42.0)).unwrap();
            s.report_error(ids[1], "boom".into()).unwrap();
            ids
        }; // graceful drop: flush + sync
        let r = WalStore::recover(&dir).unwrap();
        let p = r.progress(None);
        assert_eq!((p.total, p.pending, p.in_flight, p.done, p.errors), (3, 2, 0, 1, 1));
        assert_eq!(r.config().requeue_after_ms, 1000, "persisted config wins");
        // The oldest pending ticket dispatches first (VCT = creation).
        let t = r.next_ticket("c2", 6).unwrap();
        assert_eq!(t.id, ids[1]);
        assert_eq!(t.distribution_count, 1, "first-ever dispatch of this ticket");
        let drained = r.drain_errors();
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].0, ids[1]);
        drop(r);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn recover_on_empty_dir_errors() {
        let dir = temp_dir("empty");
        fs::create_dir_all(&dir).unwrap();
        assert!(WalStore::recover(&dir).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_tail_is_dropped_not_fatal() {
        let dir = temp_dir("torn");
        {
            let s = WalStore::open(&dir, cfg(), WalConfig::default()).unwrap();
            s.create_tickets(TaskId(1), "t", vec![Value::num(1.0), Value::num(2.0)], 0);
        }
        // Simulate a crash mid-append: garbage on the newest segment.
        let (_, seq) = *list_state_files(&dir)
            .unwrap()
            .iter()
            .filter(|(k, _)| *k == StateFile::Segment)
            .last()
            .unwrap();
        let mut f = OpenOptions::new().append(true).open(segment_path(&dir, seq)).unwrap();
        f.write_all(&[0xDE, 0xAD, 0xBE, 0xEF, 0x01]).unwrap();
        drop(f);
        let r = WalStore::recover(&dir).unwrap();
        assert_eq!(r.progress(None).total, 2, "intact prefix replayed");
        drop(r);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn checkpoint_truncates_old_segments() {
        let dir = temp_dir("ckpt");
        let wal_cfg = WalConfig {
            sync: SyncPolicy::OsOnly,
            segment_max_bytes: 1 << 20,
            checkpoint_every: 10,
            dispatch_shards: 1,
        };
        {
            let s = WalStore::open(&dir, cfg(), wal_cfg).unwrap();
            for batch in 0..7u64 {
                s.create_tickets(
                    TaskId(1),
                    "t",
                    (0..3).map(|i| Value::num(i as f64)).collect(),
                    batch,
                );
                // VCT order: picks the oldest pending ticket, whichever
                // batch it came from.
                let t = s.next_ticket("c", batch).unwrap();
                s.complete(t.id, Value::Null).unwrap();
            }
        }
        let files = list_state_files(&dir).unwrap();
        let checkpoints: Vec<u64> =
            files.iter().filter(|(k, _)| *k == StateFile::Checkpoint).map(|f| f.1).collect();
        assert!(!checkpoints.is_empty(), "cadence of 10 over 21 records checkpoints");
        assert_eq!(checkpoints.len(), 1, "older checkpoints deleted");
        let min_segment = files
            .iter()
            .filter(|(k, _)| *k == StateFile::Segment)
            .map(|f| f.1)
            .min()
            .unwrap();
        assert!(min_segment >= checkpoints[0], "segments before the checkpoint deleted");
        let r = WalStore::recover(&dir).unwrap();
        let p = r.progress(None);
        assert_eq!((p.total, p.done), (21, 7));
        drop(r);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn size_rotation_splits_segments_and_recovers() {
        let dir = temp_dir("rotate");
        let wal_cfg = WalConfig {
            sync: SyncPolicy::OsOnly,
            segment_max_bytes: 256,
            checkpoint_every: 0,
            dispatch_shards: 1,
        };
        {
            let s = WalStore::open(&dir, cfg(), wal_cfg).unwrap();
            for i in 0..20u64 {
                s.create_tickets(TaskId(1), "t", vec![Value::num(i as f64)], i);
            }
        }
        let segments = list_state_files(&dir)
            .unwrap()
            .iter()
            .filter(|(k, _)| *k == StateFile::Segment)
            .count();
        assert!(segments > 1, "256-byte cap must rotate ({segments} segments)");
        let r = WalStore::recover(&dir).unwrap();
        assert_eq!(r.progress(None).total, 20);
        drop(r);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn crashed_store_recovers_without_graceful_drop() {
        let dir = temp_dir("crash");
        let s = WalStore::open(
            &dir,
            cfg(),
            WalConfig { sync: SyncPolicy::OsOnly, ..WalConfig::default() },
        )
        .unwrap();
        let ids =
            s.create_tickets(TaskId(1), "t", (0..4).map(|i| Value::num(i as f64)).collect(), 0);
        let _ = s.next_ticket("c", 1).unwrap();
        s.complete(ids[0], Value::Bool(true)).unwrap();
        let before = s.progress(None);
        std::mem::forget(s); // crash: no flush-on-drop, fd leaks until exit
        let r = WalStore::recover(&dir).unwrap();
        assert_eq!(r.progress(None), before);
        drop(r);
        fs::remove_dir_all(&dir).unwrap();
    }

    /// Batched dispatch/completion write one frame per batch, replay
    /// deterministically, and leave the recovered store in lockstep
    /// with an unlogged control store.
    #[test]
    fn batched_ops_recover_exactly() {
        let dir = temp_dir("batch");
        let control = IndexedStore::new(cfg());
        {
            let s = WalStore::open(
                &dir,
                cfg(),
                WalConfig { sync: SyncPolicy::OsOnly, ..WalConfig::default() },
            )
            .unwrap();
            let drive = |a: &dyn Scheduler| {
                a.create_tickets(
                    TaskId(1),
                    "t",
                    (0..5).map(|i| Value::num(i as f64)).collect(),
                    0,
                );
                let batch = a.next_tickets("c", 1, 3);
                assert_eq!(batch.len(), 3);
                let accepted = a
                    .complete_batch(vec![
                        (batch[0].id, Value::num(0.0)),
                        (batch[1].id, Value::num(1.0)),
                        (batch[0].id, Value::num(9.0)), // duplicate inside the batch
                    ])
                    .unwrap();
                assert_eq!(accepted, 2);
            };
            drive(&s);
            drive(&control);
            std::mem::forget(s); // crash: no flush-on-drop
        }
        let r = WalStore::recover(&dir).unwrap();
        assert_eq!(r.progress(None), control.progress(None));
        // Post-recovery batched dispatch continues in lockstep.
        assert_eq!(r.next_tickets("d", 2, 4), control.next_tickets("d", 2, 4));
        drop(r);
        fs::remove_dir_all(&dir).unwrap();
    }

    /// Release batches write one frame, replay with their logged flags
    /// cross-checked, and leave the recovered store in lockstep with an
    /// unlogged control store.
    #[test]
    fn release_records_recover_exactly() {
        let dir = temp_dir("release");
        let control = IndexedStore::new(cfg());
        {
            let s = WalStore::open(
                &dir,
                cfg(),
                WalConfig { sync: SyncPolicy::OsOnly, ..WalConfig::default() },
            )
            .unwrap();
            let drive = |a: &dyn Scheduler| {
                let ids = a.create_tickets(
                    TaskId(1),
                    "t",
                    (0..3).map(|i| Value::num(i as f64)).collect(),
                    0,
                );
                let t = a.next_ticket("c", 1).unwrap();
                // One real release and one no-op (pending id) share a frame.
                let flags = a.release_batch(&[t.id, ids[2]]);
                assert_eq!(flags, vec![true, false]);
            };
            drive(&s);
            drive(&control);
            std::mem::forget(s); // crash: no flush-on-drop
        }
        let r = WalStore::recover(&dir).unwrap();
        assert_eq!(r.progress(None), control.progress(None));
        // The released ticket dispatches again immediately on both.
        assert_eq!(r.next_ticket("d", 2), control.next_ticket("d", 2));
        drop(r);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn checkpoint_now_roundtrips_through_snapshot_only() {
        let dir = temp_dir("manual");
        {
            let s = WalStore::open(&dir, cfg(), WalConfig::default()).unwrap();
            let ids = s.create_tickets(TaskId(3), "t", vec![Value::num(9.0)], 0);
            let _ = s.next_ticket("c", 0).unwrap();
            s.complete(ids[0], Value::num(81.0)).unwrap();
            s.checkpoint_now().unwrap();
        }
        // All segments before the checkpoint are gone: recovery exercises
        // the snapshot decode path, not record replay.
        let r = WalStore::recover(&dir).unwrap();
        assert!(r.is_task_done(TaskId(3)));
        assert_eq!(r.wait_results(TaskId(3)), vec![Value::num(81.0)]);
        drop(r);
        fs::remove_dir_all(&dir).unwrap();
    }

    // --- sharded layout ---------------------------------------------------

    fn wal4() -> WalConfig {
        WalConfig { sync: SyncPolicy::OsOnly, dispatch_shards: 4, ..WalConfig::default() }
    }

    /// A representative op mix touching several shards, clients, and
    /// outcome kinds (21 tickets per call).
    fn drive_sharded(s: &dyn Scheduler) {
        let ids = s.create_tickets(
            TaskId(1),
            "t",
            (0..16).map(|i| Value::num(i as f64)).collect(),
            0,
        );
        let more =
            s.create_tickets(TaskId(2), "u", (0..5).map(|i| Value::num(i as f64)).collect(), 1);
        let a = s.next_tickets("alice", 2, 6);
        let b = s.next_tickets("bob", 3, 4);
        assert_eq!((a.len(), b.len()), (6, 4));
        s.complete_batch(a.iter().take(3).map(|t| (t.id, Value::num(1.0))).collect()).unwrap();
        s.report_error(b[0].id, "boom".into()).unwrap();
        let _ = s.release_batch(&[b[1].id, ids[15], more[4]]);
        let _ = s.drain_errors();
        let c = s.next_tickets("carol", 10, 3);
        assert_eq!(c.len(), 3);
        s.complete(c[0].id, Value::num(2.0)).unwrap();
    }

    #[test]
    fn sharded_open_creates_stream_layout() {
        let dir = temp_dir("shard-open");
        let s = WalStore::open(&dir, cfg(), wal4()).unwrap();
        assert_eq!(s.logs.len(), 4);
        assert_eq!(s.stats().dispatch_shards, 4);
        let files = list_state_files(&dir).unwrap();
        assert!(files.iter().all(|(k, _)| matches!(k, StateFile::Stream(_))));
        let streams: Vec<usize> = files
            .iter()
            .filter_map(|(k, seq)| match k {
                StateFile::Stream(i) if *seq == 0 => Some(*i),
                _ => None,
            })
            .collect();
        assert_eq!(streams, vec![0, 1, 2, 3]);
        drop(s);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sharded_crash_recovery_is_bit_exact() {
        let dir = temp_dir("shard-recover");
        let before = {
            let s = WalStore::open(&dir, cfg(), wal4()).unwrap();
            drive_sharded(&s);
            let snap = encode_snapshot(&s.inner.snapshot());
            std::mem::forget(s); // crash: no flush-on-drop
            snap
        };
        let r = WalStore::recover(&dir).unwrap();
        assert_eq!(r.logs.len(), 4);
        assert_eq!(encode_snapshot(&r.inner.snapshot()), before);
        drop(r);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sharded_checkpoint_rotates_and_truncates_all_streams() {
        let dir = temp_dir("shard-ckpt");
        let before;
        {
            let s = WalStore::open(&dir, cfg(), wal4()).unwrap();
            drive_sharded(&s);
            s.checkpoint_now().unwrap();
            // Post-checkpoint traffic replays on top of the snapshot.
            drive_sharded(&s);
            before = encode_snapshot(&s.inner.snapshot());
        }
        let files = list_state_files(&dir).unwrap();
        let ckpts: Vec<u64> =
            files.iter().filter(|(k, _)| *k == StateFile::Checkpoint).map(|f| f.1).collect();
        assert_eq!(ckpts.len(), 1, "older state truncated by the checkpoint");
        for stream in 0..4 {
            let min = files
                .iter()
                .filter_map(|&(k, seq)| (k == StateFile::Stream(stream)).then_some(seq))
                .min()
                .unwrap();
            assert_eq!(min, ckpts[0], "stream {stream} rotated to the checkpoint generation");
        }
        let r = WalStore::recover(&dir).unwrap();
        assert_eq!(encode_snapshot(&r.inner.snapshot()), before);
        drop(r);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sharded_torn_stream_header_is_tolerated() {
        let dir = temp_dir("shard-torn");
        let before;
        {
            let s = WalStore::open(&dir, cfg(), wal4()).unwrap();
            drive_sharded(&s);
            before = encode_snapshot(&s.inner.snapshot());
        }
        // A crash mid size-rotation can leave one stream's next segment
        // as a torn header-only file; recovery treats it as empty.
        fs::write(stream_segment_path(&dir, 1, 1), b"SW").unwrap();
        let r = WalStore::recover(&dir).unwrap();
        assert_eq!(encode_snapshot(&r.inner.snapshot()), before);
        drop(r);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sharded_recovery_spans_generations_in_lsn_order() {
        let dir = temp_dir("shard-gen");
        let control = IndexedStore::with_dispatch_shards(cfg(), 4);
        {
            let s = WalStore::open(&dir, cfg(), wal4()).unwrap();
            drive_sharded(&s);
        }
        {
            // Second generation: fresh segments, LSNs resume after the
            // replayed maximum so the next merge stays in apply order.
            let s = WalStore::recover(&dir).unwrap();
            drive_sharded(&s);
        }
        drive_sharded(&control);
        drive_sharded(&control);
        let r = WalStore::recover(&dir).unwrap();
        assert_eq!(encode_snapshot(&r.inner.snapshot()), encode_snapshot(&control.snapshot()));
        drop(r);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sharded_request_on_legacy_dir_keeps_legacy_layout() {
        let dir = temp_dir("shard-legacy");
        {
            let s = WalStore::open(&dir, cfg(), WalConfig::default()).unwrap();
            s.create_tickets(TaskId(1), "t", vec![Value::num(1.0)], 0);
        }
        // The persisted layout wins over the requested shard count.
        let s = WalStore::open(&dir, cfg(), wal4()).unwrap();
        assert_eq!(s.logs.len(), 1);
        assert_eq!(s.progress(None).total, 1);
        drop(s);
        fs::remove_dir_all(&dir).unwrap();
    }
}
