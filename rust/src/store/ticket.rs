//! Ticket: one divided argument of a task, plus its distribution state.
//!
//! A "ticket" in the paper is a row in the MySQL table carrying the task
//! reference, one slice of the divided arguments, and the bookkeeping
//! the Distributor uses for redistribution.

use crate::store::TaskId;
use crate::util::json::Value;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TicketId(pub u64);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TicketStatus {
    /// Never distributed, or returned to the pool by an error report.
    Pending,
    /// Handed to at least one client; may be redistributed on timeout.
    InFlight,
    /// A result has been accepted (first result wins).
    Done,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Ticket {
    pub id: TicketId,
    pub task: TaskId,
    /// Task name: what the worker asks the registry for when its cache
    /// misses (the paper's browser downloads the task's JS code).
    pub task_name: String,
    /// Position within the task's divided argument list; results are
    /// collected back in this order.
    pub index: usize,
    /// The divided argument (JSON, as in the paper's Node.js framework).
    pub payload: Value,
    pub created_ms: u64,
    pub status: TicketStatus,
    pub last_distributed_ms: Option<u64>,
    pub distribution_count: u32,
    pub result: Option<Value>,
    pub assigned_to: Option<String>,
}

impl Ticket {
    /// Approximate wire size of the ticket payload (bandwidth modelling).
    pub fn payload_bytes(&self) -> usize {
        self.payload.to_string().len()
    }
}

// ---------------------------------------------------------------------------
// Result verification (quorum replication + per-client reputation).
//
// With `StoreConfig { replication: R > 1, quorum: Q }` a ticket no longer
// completes on the first result: results are canonicalised and hashed, and
// the ticket completes when Q matching *votes* have arrived from distinct
// clients (or one vote from a long-trusted client — the BOINC-style
// adaptive fast path).  The pure state machine lives here so the naive
// reference store, the indexed production store, and WAL replay all run
// the *same* code — the differential suites then only have to pin the
// backends' dispatch plumbing, not two hand-synchronised vote machines.
// At R = 1 none of this is instantiated and every path is bit-for-bit the
// legacy first-result-wins store.
// ---------------------------------------------------------------------------

/// Canonical hash of a result value: FNV-1a over the canonical JSON
/// serialisation.  Two clients "agree" iff their results hash equal.
pub fn canonical_hash(v: &Value) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in v.to_string().as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// What a [`vote`](crate::store::Scheduler::vote) did.
#[derive(Debug, Clone, PartialEq)]
pub enum VoteOutcome {
    /// This vote completed the ticket.  At R = 1 (the legacy path) the
    /// verdict is `None`; at R > 1 it names the winning hash and the
    /// flagged minority voters.
    Accepted { verdict: Option<Verdict> },
    /// Recorded; the ticket is still short of quorum (R > 1 only).
    Pending,
    /// The ticket was already done when the vote arrived — the legacy
    /// duplicate, now attributed: a same-client retry vs. a slower
    /// *different* client answering a replicated/redistributed ticket.
    Duplicate { same_client: bool },
    /// The same client re-voting on a still-undecided ticket: ignored
    /// (one client, one vote).
    Repeat,
}

/// The outcome of a decided ticket at R > 1.
#[derive(Debug, Clone, PartialEq)]
pub struct Verdict {
    pub ticket: TicketId,
    /// Canonical hash of the accepted result.
    pub hash: u64,
    /// Clients whose vote matched the winning hash.
    pub winners: Vec<String>,
    /// Minority voters — flagged for reputation loss.
    pub losers: Vec<String>,
}

/// A client's scheduling standing, derived from its reputation score.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Standing {
    /// Long history of winning votes: earns the R = 1 fast path (its
    /// single vote decides a ticket it was first to receive).
    Trusted,
    Normal,
    /// Lost its way into quarantine: served `NoTicket`, held tickets
    /// released, until the probation timer expires.
    Quarantined { until_ms: u64 },
}

/// Reputation score at (and above) which a client is [`Standing::Trusted`].
pub(crate) const TRUST_SCORE: i64 = 8;
/// Score at (or below) which a lost vote tips a client into quarantine.
pub(crate) const QUARANTINE_SCORE: i64 = -8;
/// Quarantine probation: how long a quarantined client is served
/// `NoTicket` before being allowed back (score restarts from 0).
pub(crate) const PROBATION_MS: u64 = 120_000;

/// One client's reputation record (BOINC-style adaptive replication).
#[derive(Debug, Clone, Default, PartialEq)]
pub(crate) struct Rep {
    /// +1 per vote won; halved-and-docked per vote lost.
    pub(crate) score: i64,
    pub(crate) quarantined_until: Option<u64>,
    /// Sticky: set on the first quarantine, never cleared (surfaced by
    /// [`quarantined_clients`](crate::store::Scheduler::quarantined_clients)).
    pub(crate) ever_quarantined: bool,
    pub(crate) votes_won: u64,
    pub(crate) votes_lost: u64,
}

impl Rep {
    pub(crate) fn win(&mut self) {
        self.score += 1;
        self.votes_won += 1;
    }

    /// A lost vote: halve the accumulated trust and dock a penalty, so
    /// repeat offenders decay geometrically toward quarantine while one
    /// bad vote cannot erase a long history linearly.  Returns `true`
    /// when this loss tipped the client into quarantine.
    pub(crate) fn lose(&mut self, now_ms: u64) -> bool {
        self.votes_lost += 1;
        self.score = self.score / 2 + QUARANTINE_SCORE;
        if self.score <= QUARANTINE_SCORE {
            self.score = 0; // probation restarts the ladder from scratch
            self.quarantined_until = Some(now_ms + PROBATION_MS);
            self.ever_quarantined = true;
            true
        } else {
            false
        }
    }

    /// Current standing; lazily clears an expired quarantine.
    pub(crate) fn standing(&mut self, now_ms: u64) -> Standing {
        if let Some(until) = self.quarantined_until {
            if now_ms < until {
                return Standing::Quarantined { until_ms: until };
            }
            self.quarantined_until = None;
        }
        if self.score >= TRUST_SCORE {
            Standing::Trusted
        } else {
            Standing::Normal
        }
    }
}

/// What [`TicketVerify::record_vote`] decided — interpreted identically
/// by every backend.
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum VoteAction {
    /// Quorum (or a trusted voter) reached: complete the ticket with
    /// the first value recorded under the verdict's hash.
    Decide(Verdict),
    /// Recorded, still short of quorum; `escalated` when this vote
    /// exposed a divergence and bumped the recruitment target (the
    /// fresh-client tie-breaker).
    Pending { escalated: bool },
    /// Same client re-voting on the undecided ticket: ignored.
    Repeat,
}

/// Per-ticket replication state (R > 1 only; `None` on every ticket at
/// R = 1).  `holders` are clients the ticket is currently dispatched to
/// that have not voted; `votes` are the ballots cast.  A client appears
/// in at most one of the two, and `enlisted = holders + votes` is the
/// recruitment level measured against `target`.
#[derive(Debug, Clone, Default, PartialEq)]
pub(crate) struct TicketVerify {
    /// How many distinct clients to recruit before waiting on the
    /// redistribution window: starts at `quorum` (or 1 for a trusted
    /// first client), +1 per exposed divergence.
    pub(crate) target: u32,
    pub(crate) holders: Vec<String>,
    /// Ballots in arrival order: (client, canonical result hash).
    pub(crate) votes: Vec<(String, u64)>,
    /// First value seen per distinct hash — the deterministic result a
    /// verdict for that hash completes with.
    pub(crate) values: Vec<(u64, Value)>,
    pub(crate) decided: Option<Verdict>,
}

impl TicketVerify {
    pub(crate) fn new(target: u32) -> Self {
        Self { target: target.max(1), ..Default::default() }
    }

    pub(crate) fn enlisted(&self) -> usize {
        self.holders.len() + self.votes.len()
    }

    /// Still recruiting: an undecided ticket below its target is
    /// immediately dispatchable (VCT = creation time) to new clients.
    pub(crate) fn needs_recruits(&self) -> bool {
        self.decided.is_none() && self.enlisted() < self.target as usize
    }

    /// Same-client exclusion: a client never sees a ticket it already
    /// holds or has voted on.
    pub(crate) fn involves(&self, client: &str) -> bool {
        self.holders.iter().any(|c| c == client) || self.votes.iter().any(|(c, _)| c == client)
    }

    /// Record a dispatch to `client`, evicting the oldest holder when
    /// the concurrent-holder cap (`replication`) is full — that holder
    /// is presumed dead (its window expired, which is why we are
    /// re-dispatching); a late vote from it is still counted.
    pub(crate) fn note_dispatch(&mut self, client: &str, replication: u32) {
        if self.holders.len() >= replication.max(1) as usize {
            self.holders.remove(0);
        }
        self.holders.push(client.to_string());
    }

    /// Remove `client` from the holder set (release / error / vanish).
    /// Returns whether it actually held the ticket.
    pub(crate) fn release_from(&mut self, client: &str) -> bool {
        match self.holders.iter().position(|c| c == client) {
            Some(i) => {
                self.holders.remove(i);
                true
            }
            None => false,
        }
    }

    /// The quorum state machine (undecided tickets only).  A vote
    /// decides when its voter is trusted *at vote time*, or when
    /// `quorum` ballots carry the same hash; a full undecided round
    /// bumps `target` so a fresh client is recruited as tie-breaker.
    pub(crate) fn record_vote(
        &mut self,
        ticket: TicketId,
        client: &str,
        hash: u64,
        value: &Value,
        voter_trusted: bool,
        quorum: u32,
    ) -> VoteAction {
        if self.votes.iter().any(|(c, _)| c == client) {
            return VoteAction::Repeat;
        }
        self.release_from(client);
        self.votes.push((client.to_string(), hash));
        if !self.values.iter().any(|(h, _)| *h == hash) {
            self.values.push((hash, value.clone()));
        }
        let matching = self.votes.iter().filter(|(_, h)| *h == hash).count();
        if voter_trusted || matching >= quorum.max(1) as usize {
            let (winners, losers) = self
                .votes
                .iter()
                .map(|(c, h)| (c.clone(), *h))
                .partition::<Vec<_>, _>(|(_, h)| *h == hash);
            let verdict = Verdict {
                ticket,
                hash,
                winners: winners.into_iter().map(|(c, _)| c).collect(),
                losers: losers.into_iter().map(|(c, _)| c).collect(),
            };
            self.decided = Some(verdict.clone());
            return VoteAction::Decide(verdict);
        }
        let escalated = self.votes.len() >= self.target as usize;
        if escalated {
            self.target += 1; // divergence: recruit a fresh tie-breaker
        }
        VoteAction::Pending { escalated }
    }

    /// The value a [`VoteAction::Decide`] completes the ticket with:
    /// the first value recorded under the decided hash (deterministic
    /// regardless of which matching vote tipped the quorum).
    pub(crate) fn winning_value(&self) -> Value {
        let hash = self.decided.as_ref().expect("winning_value on decided ticket").hash;
        self.values
            .iter()
            .find(|(h, _)| *h == hash)
            .map(|(_, v)| v.clone())
            .expect("decided hash has a recorded value")
    }

    /// A vote arriving after the ticket is done: a repeat from a client
    /// that already voted is `None` (no reputation effect); otherwise
    /// the ballot is recorded and judged against the verdict —
    /// `Some(true)` won, `Some(false)` lost.  Tickets completed through
    /// the clientless infrastructure path carry no verdict; late votes
    /// on them are recorded but unjudged (`None`).
    pub(crate) fn record_late_vote(&mut self, client: &str, hash: u64) -> Option<bool> {
        if self.votes.iter().any(|(c, _)| c == client) {
            return None;
        }
        self.release_from(client);
        self.votes.push((client.to_string(), hash));
        self.decided.as_ref().map(|v| v.hash == hash)
    }

    /// Whether a vote on this *done* ticket is a same-client retry.
    pub(crate) fn has_voted(&self, client: &str) -> bool {
        self.votes.iter().any(|(c, _)| c == client)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_size_tracks_json() {
        let t = Ticket {
            id: TicketId(0),
            task: TaskId(0),
            task_name: "t".into(),
            index: 0,
            payload: Value::obj(vec![("candidate", Value::num(17.0))]),
            created_ms: 0,
            status: TicketStatus::Pending,
            last_distributed_ms: None,
            distribution_count: 0,
            result: None,
            assigned_to: None,
        };
        assert_eq!(t.payload_bytes(), t.payload.to_string().len());
        assert!(t.payload_bytes() > 10);
    }

    #[test]
    fn canonical_hash_is_serialisation_stable() {
        let a = Value::obj(vec![("x", Value::num(1.0)), ("y", Value::str("z"))]);
        let b = Value::obj(vec![("x", Value::num(1.0)), ("y", Value::str("z"))]);
        assert_eq!(canonical_hash(&a), canonical_hash(&b));
        assert_ne!(canonical_hash(&a), canonical_hash(&Value::num(1.0)));
    }

    #[test]
    fn quorum_of_two_decides_on_second_matching_vote() {
        let mut v = TicketVerify::new(2);
        v.note_dispatch("a", 3);
        v.note_dispatch("b", 3);
        let h = canonical_hash(&Value::num(7.0));
        let act = v.record_vote(TicketId(1), "a", h, &Value::num(7.0), false, 2);
        assert_eq!(act, VoteAction::Pending { escalated: false });
        assert!(v.has_voted("a") && !v.involves("c"));
        match v.record_vote(TicketId(1), "b", h, &Value::num(7.0), false, 2) {
            VoteAction::Decide(verdict) => {
                assert_eq!(verdict.hash, h);
                assert_eq!(verdict.winners, vec!["a".to_string(), "b".to_string()]);
                assert!(verdict.losers.is_empty());
            }
            other => panic!("expected decide, got {other:?}"),
        }
        assert_eq!(v.winning_value(), Value::num(7.0));
    }

    #[test]
    fn divergence_escalates_then_tiebreaker_outvotes_minority() {
        let mut v = TicketVerify::new(2);
        let good = canonical_hash(&Value::num(1.0));
        let bad = canonical_hash(&Value::num(666.0));
        assert_eq!(
            v.record_vote(TicketId(9), "honest1", good, &Value::num(1.0), false, 2),
            VoteAction::Pending { escalated: false }
        );
        // Divergent second vote: full round, undecided -> target bumps.
        assert_eq!(
            v.record_vote(TicketId(9), "evil", bad, &Value::num(666.0), false, 2),
            VoteAction::Pending { escalated: true }
        );
        assert_eq!(v.target, 3);
        assert!(v.needs_recruits(), "tie-breaker must be recruitable immediately");
        match v.record_vote(TicketId(9), "honest2", good, &Value::num(1.0), false, 2) {
            VoteAction::Decide(verdict) => {
                assert_eq!(verdict.losers, vec!["evil".to_string()]);
                assert_eq!(v.winning_value(), Value::num(1.0));
            }
            other => panic!("expected decide, got {other:?}"),
        }
    }

    #[test]
    fn trusted_vote_decides_alone_and_repeats_are_ignored() {
        let mut v = TicketVerify::new(1);
        let h = canonical_hash(&Value::Bool(true));
        assert_eq!(
            v.record_vote(TicketId(2), "vet", h, &Value::Bool(true), false, 2),
            VoteAction::Pending { escalated: true },
            "an untrusted voter alone cannot decide even at target 1"
        );
        assert_eq!(
            v.record_vote(TicketId(2), "vet", h, &Value::Bool(true), false, 2),
            VoteAction::Repeat
        );
        match v.record_vote(TicketId(2), "trusted", h, &Value::Bool(true), true, 2) {
            VoteAction::Decide(verdict) => assert_eq!(verdict.hash, h),
            other => panic!("expected decide, got {other:?}"),
        }
    }

    #[test]
    fn holder_cap_evicts_oldest() {
        let mut v = TicketVerify::new(2);
        v.note_dispatch("a", 2);
        v.note_dispatch("b", 2);
        v.note_dispatch("c", 2); // cap 2: evicts "a"
        assert!(!v.involves("a"));
        assert!(v.involves("b") && v.involves("c"));
        assert!(v.release_from("b"));
        assert!(!v.release_from("b"));
    }

    #[test]
    fn reputation_ladder_trust_quarantine_probation() {
        let mut r = Rep::default();
        assert_eq!(r.standing(0), Standing::Normal);
        for _ in 0..TRUST_SCORE {
            r.win();
        }
        assert_eq!(r.standing(0), Standing::Trusted);
        // One lost vote knocks trust off but does not quarantine...
        assert!(!r.lose(1_000));
        assert_eq!(r.standing(1_000), Standing::Normal);
        // ...the next does (geometric decay toward the floor).
        assert!(r.lose(2_000));
        assert_eq!(r.standing(2_000), Standing::Quarantined { until_ms: 2_000 + PROBATION_MS });
        assert!(r.ever_quarantined);
        // Probation expiry is lazy; the ladder restarts from zero.
        assert_eq!(r.standing(2_000 + PROBATION_MS), Standing::Normal);
        assert_eq!(r.score, 0);
        // A fresh (score 0) client quarantines on its first lost vote.
        let mut fresh = Rep::default();
        assert!(fresh.lose(5));
    }

    #[test]
    fn late_votes_are_judged_against_the_verdict() {
        let mut v = TicketVerify::new(2);
        let h = canonical_hash(&Value::num(3.0));
        v.record_vote(TicketId(4), "a", h, &Value::num(3.0), false, 2);
        v.record_vote(TicketId(4), "b", h, &Value::num(3.0), false, 2);
        assert!(v.decided.is_some());
        assert_eq!(v.record_late_vote("straggler", h), Some(true));
        assert_eq!(v.record_late_vote("liar", canonical_hash(&Value::Null)), Some(false));
        assert_eq!(v.record_late_vote("a", h), None, "repeat voter is not judged twice");
    }
}
