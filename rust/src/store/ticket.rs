//! Ticket: one divided argument of a task, plus its distribution state.
//!
//! A "ticket" in the paper is a row in the MySQL table carrying the task
//! reference, one slice of the divided arguments, and the bookkeeping
//! the Distributor uses for redistribution.

use crate::store::TaskId;
use crate::util::json::Value;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TicketId(pub u64);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TicketStatus {
    /// Never distributed, or returned to the pool by an error report.
    Pending,
    /// Handed to at least one client; may be redistributed on timeout.
    InFlight,
    /// A result has been accepted (first result wins).
    Done,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Ticket {
    pub id: TicketId,
    pub task: TaskId,
    /// Task name: what the worker asks the registry for when its cache
    /// misses (the paper's browser downloads the task's JS code).
    pub task_name: String,
    /// Position within the task's divided argument list; results are
    /// collected back in this order.
    pub index: usize,
    /// The divided argument (JSON, as in the paper's Node.js framework).
    pub payload: Value,
    pub created_ms: u64,
    pub status: TicketStatus,
    pub last_distributed_ms: Option<u64>,
    pub distribution_count: u32,
    pub result: Option<Value>,
    pub assigned_to: Option<String>,
}

impl Ticket {
    /// Approximate wire size of the ticket payload (bandwidth modelling).
    pub fn payload_bytes(&self) -> usize {
        self.payload.to_string().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_size_tracks_json() {
        let t = Ticket {
            id: TicketId(0),
            task: TaskId(0),
            task_name: "t".into(),
            index: 0,
            payload: Value::obj(vec![("candidate", Value::num(17.0))]),
            created_ms: 0,
            status: TicketStatus::Pending,
            last_distributed_ms: None,
            distribution_count: 0,
            result: None,
            assigned_to: None,
        };
        assert_eq!(t.payload_bytes(), t.payload.to_string().len());
        assert!(t.payload_bytes() > 10);
    }
}
