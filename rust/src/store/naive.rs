//! The original O(n)-scan ticket store, kept as the reference
//! implementation of [`Scheduler`].
//!
//! One global mutex over a `BTreeMap<TicketId, Ticket>`; every
//! `next_ticket` walks all live *and done* tickets to find the minimum
//! virtual created time, and every `progress`/`wait_results` call walks
//! the table again.  That is exactly what the paper's MySQL
//! `SELECT ... ORDER BY vct LIMIT 1` costs without an index, and it is
//! deliberately preserved: the differential property test
//! (`rust/tests/properties.rs`) replays random operation sequences
//! through this store and [`sched::IndexedStore`](super::IndexedStore)
//! and asserts identical dispatch order, progress counters, and
//! duplicate accounting.  `benches/store_throughput.rs` measures the
//! gap.
//!
//! The batched entry points ([`Scheduler::next_tickets`] /
//! [`Scheduler::complete_batch`] / [`Scheduler::release_batch`]) are
//! deliberately *not* overridden here: this store runs the trait's
//! loop fallback, which is the reference semantics the indexed store's
//! amortised batch paths are differential-tested against
//! (`rust/tests/properties.rs`).

use std::collections::BTreeMap;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::store::{
    deadline_after, wait_deadline, Progress, Scheduler, StoreConfig, TaskId, Ticket, TicketId,
    TicketStatus,
};
use crate::util::json::Value;

#[derive(Debug, Default)]
struct Inner {
    tickets: BTreeMap<TicketId, Ticket>,
    next_ticket: u64,
    errors: Vec<(TicketId, String)>,
    /// Cumulative count of reports ever recorded (drain-proof).
    errors_reported: usize,
    redistributions: u64,
    duplicate_results: u64,
    /// FIFO of accepted results, consumed by streaming drivers (the
    /// hybrid trainer reacts to each client's features as they arrive,
    /// §4 "learned concurrently").
    completions: std::collections::VecDeque<(TaskId, usize, Value)>,
}

/// Thread-safe ticket store with one global lock and linear scans.
pub struct NaiveStore {
    cfg: StoreConfig,
    inner: Mutex<Inner>,
    /// Signalled on completions so waits can block without polling.
    done_cv: Condvar,
}

impl NaiveStore {
    pub fn new(cfg: StoreConfig) -> Self {
        Self { cfg, inner: Mutex::new(Inner::default()), done_cv: Condvar::new() }
    }

    /// Virtual created time of a ticket (the paper's ordering key).
    fn vct(&self, t: &Ticket) -> u64 {
        match t.last_distributed_ms {
            None => t.created_ms,
            Some(d) => d + self.cfg.requeue_after_ms,
        }
    }
}

impl Scheduler for NaiveStore {
    fn config(&self) -> &StoreConfig {
        &self.cfg
    }

    fn create_tickets(
        &self,
        task: TaskId,
        task_name: &str,
        args: Vec<Value>,
        now_ms: u64,
    ) -> Vec<TicketId> {
        let mut inner = self.inner.lock().unwrap();
        let mut ids = Vec::with_capacity(args.len());
        for (index, payload) in args.into_iter().enumerate() {
            let id = TicketId(inner.next_ticket);
            inner.next_ticket += 1;
            inner.tickets.insert(
                id,
                Ticket {
                    id,
                    task,
                    task_name: task_name.to_string(),
                    index,
                    payload,
                    created_ms: now_ms,
                    status: TicketStatus::Pending,
                    last_distributed_ms: None,
                    distribution_count: 0,
                    result: None,
                    assigned_to: None,
                },
            );
            ids.push(id);
        }
        ids
    }

    fn next_ticket(&self, client: &str, now_ms: u64) -> Option<Ticket> {
        let mut inner = self.inner.lock().unwrap();
        // Primary: minimum VCT among candidates whose VCT has arrived.
        let pick = inner
            .tickets
            .values()
            .filter(|t| t.status != TicketStatus::Done)
            .filter(|t| self.vct(t) <= now_ms)
            .min_by_key(|t| (self.vct(t), t.id.0))
            .map(|t| t.id);
        // Fallback: nothing due -> redistribute the longest-in-flight
        // ticket, provided it was not distributed in the last
        // min_redistribute window (the paper's 10 s rule).
        let pick = pick.or_else(|| {
            inner
                .tickets
                .values()
                .filter(|t| t.status != TicketStatus::Done)
                .filter(|t| {
                    t.last_distributed_ms
                        .map(|d| now_ms.saturating_sub(d) >= self.cfg.min_redistribute_ms)
                        .unwrap_or(true)
                })
                .min_by_key(|t| (t.last_distributed_ms.unwrap_or(0), t.id.0))
                .map(|t| t.id)
        });
        let id = pick?;
        let redistribution = {
            let t = inner.tickets.get(&id).unwrap();
            t.distribution_count > 0
        };
        if redistribution {
            inner.redistributions += 1;
        }
        let t = inner.tickets.get_mut(&id).unwrap();
        t.status = TicketStatus::InFlight;
        t.last_distributed_ms = Some(now_ms);
        t.distribution_count += 1;
        t.assigned_to = Some(client.to_string());
        Some(t.clone())
    }

    fn complete(&self, id: TicketId, result: Value) -> Result<bool> {
        let mut inner = self.inner.lock().unwrap();
        let t = match inner.tickets.get_mut(&id) {
            Some(t) => t,
            None => bail!("unknown ticket {id:?}"),
        };
        if t.status == TicketStatus::Done {
            inner.duplicate_results += 1;
            return Ok(false);
        }
        t.status = TicketStatus::Done;
        t.result = Some(result.clone());
        let (task, index) = (t.task, t.index);
        inner.completions.push_back((task, index, result));
        self.done_cv.notify_all();
        Ok(true)
    }

    fn next_completion(&self, task: TaskId, timeout_ms: u64) -> Option<(usize, Value)> {
        let deadline = deadline_after(timeout_ms);
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(pos) = inner.completions.iter().position(|(t, _, _)| *t == task) {
                let (_, index, value) = inner.completions.remove(pos).unwrap();
                return Some((index, value));
            }
            inner = wait_deadline(&self.done_cv, inner, deadline)?;
        }
    }

    fn report_error(&self, id: TicketId, report: String) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        inner.errors.push((id, report));
        inner.errors_reported += 1;
        let requeue = self.cfg.requeue_on_error;
        if let Some(t) = inner.tickets.get_mut(&id) {
            if t.status == TicketStatus::InFlight && requeue {
                t.status = TicketStatus::Pending;
                t.last_distributed_ms = None; // VCT back to creation time
            }
        }
        Ok(())
    }

    fn release(&self, id: TicketId) -> bool {
        let mut inner = self.inner.lock().unwrap();
        match inner.tickets.get_mut(&id) {
            Some(t) if t.status == TicketStatus::InFlight => {
                t.status = TicketStatus::Pending;
                t.last_distributed_ms = None; // VCT back to creation time
                true
            }
            _ => false,
        }
    }

    // `release_batch` is deliberately not overridden: this store runs
    // the trait's id-by-id loop, which is the reference semantics the
    // indexed store's amortised batch release is differential-tested
    // against (`rust/tests/properties.rs`).

    fn progress(&self, task: Option<TaskId>) -> Progress {
        let inner = self.inner.lock().unwrap();
        let mut p = Progress {
            redistributions: inner.redistributions,
            duplicate_results: inner.duplicate_results,
            errors: inner.errors_reported,
            ..Default::default()
        };
        for t in inner.tickets.values() {
            if task.map(|id| t.task == id).unwrap_or(true) {
                p.total += 1;
                match t.status {
                    TicketStatus::Pending => p.pending += 1,
                    TicketStatus::InFlight => p.in_flight += 1,
                    TicketStatus::Done => p.done += 1,
                }
            }
        }
        p
    }

    fn is_task_done(&self, task: TaskId) -> bool {
        let inner = self.inner.lock().unwrap();
        inner
            .tickets
            .values()
            .filter(|t| t.task == task)
            .all(|t| t.status == TicketStatus::Done)
    }

    fn max_task_id(&self) -> Option<TaskId> {
        self.inner.lock().unwrap().tickets.values().map(|t| t.task).max()
    }

    fn wait_results_deadline(
        &self,
        task: TaskId,
        deadline: Option<Instant>,
    ) -> Option<Vec<Value>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            let all_done = inner
                .tickets
                .values()
                .filter(|t| t.task == task)
                .all(|t| t.status == TicketStatus::Done);
            if all_done {
                let mut rows: Vec<(usize, Value)> = inner
                    .tickets
                    .values()
                    .filter(|t| t.task == task)
                    .map(|t| (t.index, t.result.clone().unwrap()))
                    .collect();
                rows.sort_by_key(|(i, _)| *i);
                return Some(rows.into_iter().map(|(_, v)| v).collect());
            }
            inner = wait_deadline(&self.done_cv, inner, deadline)?;
        }
    }

    fn error_count(&self) -> usize {
        self.inner.lock().unwrap().errors_reported
    }

    fn drain_errors(&self) -> Vec<(TicketId, String)> {
        std::mem::take(&mut self.inner.lock().unwrap().errors)
    }
}
