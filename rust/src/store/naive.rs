//! The original O(n)-scan ticket store, kept as the reference
//! implementation of [`Scheduler`].
//!
//! One global mutex over a `BTreeMap<TicketId, Ticket>`; every
//! `next_ticket` walks all live *and done* tickets to find the minimum
//! virtual created time, and every `progress`/`wait_results` call walks
//! the table again.  That is exactly what the paper's MySQL
//! `SELECT ... ORDER BY vct LIMIT 1` costs without an index, and it is
//! deliberately preserved: the differential property test
//! (`rust/tests/properties.rs`) replays random operation sequences
//! through this store and [`sched::IndexedStore`](super::IndexedStore)
//! and asserts identical dispatch order, progress counters, and
//! duplicate accounting.  `benches/store_throughput.rs` measures the
//! gap.
//!
//! The batched entry points ([`Scheduler::next_tickets`] /
//! [`Scheduler::complete_batch`] / [`Scheduler::release_batch`]) are
//! deliberately *not* overridden here: this store runs the trait's
//! loop fallback, which is the reference semantics the indexed store's
//! amortised batch paths are differential-tested against
//! (`rust/tests/properties.rs`).

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::store::ticket::{canonical_hash, Rep, TicketVerify, VoteAction};
use crate::store::{
    deadline_after, wait_deadline, Progress, Scheduler, Standing, StoreConfig, TaskId, Ticket,
    TicketId, TicketStatus, Verdict, VerifyStats, VoteOutcome, ERROR_QUEUE_CAP,
};
use crate::util::json::Value;
use crate::util::lockcheck::{CheckedCondvar, CheckedMutex, Rank};

#[derive(Debug, Default)]
struct Inner {
    tickets: BTreeMap<TicketId, Ticket>,
    next_ticket: u64,
    errors: Vec<(TicketId, String)>,
    /// Cumulative count of reports ever recorded (drain-proof, and
    /// unaffected by the [`ERROR_QUEUE_CAP`] overflow drop).
    errors_reported: usize,
    /// Reports dropped because the buffer was at [`ERROR_QUEUE_CAP`].
    errors_dropped: u64,
    redistributions: u64,
    duplicate_results: u64,
    /// FIFO of accepted results, consumed by streaming drivers (the
    /// hybrid trainer reacts to each client's features as they arrive,
    /// §4 "learned concurrently").
    completions: std::collections::VecDeque<(TaskId, usize, Value)>,
    /// Per-ticket replication state; populated only at `replication > 1`
    /// (empty ⇒ every path below is the bit-exact legacy store).
    verify: BTreeMap<u64, TicketVerify>,
    /// Per-client reputation (R > 1 only); BTreeMap for deterministic
    /// iteration in `verify_stats`/`quarantined_clients`.
    reps: BTreeMap<String, Rep>,
    /// Which client's vote completed each ticket at R = 1 — the
    /// same-client/cross-client duplicate split.  Best-effort, in-memory
    /// only (not part of the durable legacy state).
    completed_by: BTreeMap<u64, String>,
    // Verification counters (VerifyStats).
    votes_recorded: u64,
    verdicts: u64,
    votes_flagged: u64,
    escalations: u64,
    quarantines: u64,
}

impl Inner {
    /// Buffer an error report, dropping the overflow beyond
    /// [`ERROR_QUEUE_CAP`]; the cumulative count sees every report.
    fn push_error(&mut self, id: TicketId, report: String) {
        self.errors_reported += 1;
        if self.errors.len() < ERROR_QUEUE_CAP {
            self.errors.push((id, report));
        } else {
            self.errors_dropped += 1;
        }
    }

    fn standing_of(&mut self, client: &str, now_ms: u64) -> Standing {
        match self.reps.get_mut(client) {
            Some(r) => r.standing(now_ms),
            None => Standing::Normal,
        }
    }

    /// Apply a verdict's reputation consequences (winners credited,
    /// losers flagged and possibly quarantined).
    fn apply_verdict_reps(&mut self, verdict: &Verdict, now_ms: u64) {
        for w in &verdict.winners {
            self.reps.entry(w.clone()).or_default().win();
        }
        for l in &verdict.losers {
            self.votes_flagged += 1;
            if self.reps.entry(l.clone()).or_default().lose(now_ms) {
                self.quarantines += 1;
            }
        }
    }

    /// Judge one late ballot (`Some(won)`) against the verdict.
    fn apply_late_rep(&mut self, client: &str, won: bool, now_ms: u64) {
        if won {
            self.reps.entry(client.to_string()).or_default().win();
        } else {
            self.votes_flagged += 1;
            if self.reps.entry(client.to_string()).or_default().lose(now_ms) {
                self.quarantines += 1;
            }
        }
    }
}

/// Thread-safe ticket store with one global lock and linear scans.
pub struct NaiveStore {
    cfg: StoreConfig,
    inner: CheckedMutex<Inner>,
    /// Signalled on completions so waits can block without polling.
    done_cv: CheckedCondvar,
}

impl NaiveStore {
    pub fn new(cfg: StoreConfig) -> Self {
        Self {
            cfg,
            inner: CheckedMutex::new(Rank::naive_inner(), Inner::default()),
            done_cv: CheckedCondvar::new(),
        }
    }

    /// Virtual created time of a ticket (the paper's ordering key).
    /// At R > 1 an undecided ticket still recruiting replicas
    /// (`enlisted < target`) keys at its creation time — it must reach
    /// additional distinct clients immediately, not after the window.
    fn vct(&self, t: &Ticket, verify: Option<&TicketVerify>) -> u64 {
        if let Some(v) = verify {
            if v.needs_recruits() {
                return t.created_ms;
            }
        }
        match t.last_distributed_ms {
            None => t.created_ms,
            Some(d) => d + self.cfg.requeue_after_ms,
        }
    }
}

impl Scheduler for NaiveStore {
    fn config(&self) -> &StoreConfig {
        &self.cfg
    }

    fn create_tickets(
        &self,
        task: TaskId,
        task_name: &str,
        args: Vec<Value>,
        now_ms: u64,
    ) -> Vec<TicketId> {
        let mut inner = self.inner.lock().unwrap();
        let mut ids = Vec::with_capacity(args.len());
        for (index, payload) in args.into_iter().enumerate() {
            let id = TicketId(inner.next_ticket);
            inner.next_ticket += 1;
            inner.tickets.insert(
                id,
                Ticket {
                    id,
                    task,
                    task_name: task_name.to_string(),
                    index,
                    payload,
                    created_ms: now_ms,
                    status: TicketStatus::Pending,
                    last_distributed_ms: None,
                    distribution_count: 0,
                    result: None,
                    assigned_to: None,
                },
            );
            ids.push(id);
        }
        ids
    }

    fn next_ticket(&self, client: &str, now_ms: u64) -> Option<Ticket> {
        let mut inner = self.inner.lock().unwrap();
        let verifying = self.cfg.verifying();
        // Quarantined clients are served nothing until probation ends.
        if verifying {
            if let Standing::Quarantined { .. } = inner.standing_of(client, now_ms) {
                return None;
            }
        }
        let inner = &mut *inner;
        // At R > 1 a client never sees a ticket it already holds or has
        // voted on (same-client exclusion).
        let excluded = |verify: &BTreeMap<u64, TicketVerify>, id: u64| -> bool {
            verifying && verify.get(&id).map(|v| v.involves(client)).unwrap_or(false)
        };
        // Primary: minimum VCT among candidates whose VCT has arrived.
        let pick = {
            let verify = &inner.verify;
            inner
                .tickets
                .values()
                .filter(|t| t.status != TicketStatus::Done && !excluded(verify, t.id.0))
                .filter(|t| self.vct(t, verify.get(&t.id.0)) <= now_ms)
                .min_by_key(|t| (self.vct(t, verify.get(&t.id.0)), t.id.0))
                .map(|t| t.id)
        };
        // Fallback: nothing due -> redistribute the longest-in-flight
        // ticket, provided it was not distributed in the last
        // min_redistribute window (the paper's 10 s rule).
        let pick = pick.or_else(|| {
            let verify = &inner.verify;
            inner
                .tickets
                .values()
                .filter(|t| t.status != TicketStatus::Done && !excluded(verify, t.id.0))
                .filter(|t| {
                    t.last_distributed_ms
                        .map(|d| now_ms.saturating_sub(d) >= self.cfg.min_redistribute_ms)
                        .unwrap_or(true)
                })
                .min_by_key(|t| (t.last_distributed_ms.unwrap_or(0), t.id.0))
                .map(|t| t.id)
        });
        let id = pick?;
        let redistribution = {
            let t = inner.tickets.get(&id).unwrap();
            t.distribution_count > 0
        };
        if redistribution {
            inner.redistributions += 1;
        }
        if verifying {
            // First dispatch fixes the recruitment target: a trusted
            // client earns the R = 1 fast path, everyone else recruits
            // `quorum` replicas.
            let trusted = matches!(
                inner.reps.get_mut(client).map(|r| r.standing(now_ms)),
                Some(Standing::Trusted)
            );
            let quorum = self.cfg.quorum;
            let v = inner
                .verify
                .entry(id.0)
                .or_insert_with(|| TicketVerify::new(if trusted { 1 } else { quorum }));
            v.note_dispatch(client, self.cfg.replication);
        }
        let t = inner.tickets.get_mut(&id).unwrap();
        t.status = TicketStatus::InFlight;
        t.last_distributed_ms = Some(now_ms);
        t.distribution_count += 1;
        t.assigned_to = Some(client.to_string());
        Some(t.clone())
    }

    fn complete(&self, id: TicketId, result: Value) -> Result<bool> {
        let mut inner = self.inner.lock().unwrap();
        let t = match inner.tickets.get_mut(&id) {
            Some(t) => t,
            None => bail!("unknown ticket {id:?}"),
        };
        if t.status == TicketStatus::Done {
            inner.duplicate_results += 1;
            return Ok(false);
        }
        t.status = TicketStatus::Done;
        t.result = Some(result.clone());
        let (task, index) = (t.task, t.index);
        // The clientless infrastructure path stays authoritative at
        // R > 1 (it bypasses quorum); it seals the verify entry so late
        // ballots are judged against the accepted hash.
        if self.cfg.verifying() {
            if let Some(v) = inner.verify.get_mut(&id.0) {
                if v.decided.is_none() {
                    v.holders.clear();
                    v.decided = Some(Verdict {
                        ticket: id,
                        hash: canonical_hash(&result),
                        winners: Vec::new(),
                        losers: Vec::new(),
                    });
                }
            }
        }
        inner.completions.push_back((task, index, result));
        self.done_cv.notify_all();
        Ok(true)
    }

    fn vote(&self, client: &str, id: TicketId, result: Value, now_ms: u64) -> Result<VoteOutcome> {
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        let t = match inner.tickets.get_mut(&id) {
            Some(t) => t,
            None => bail!("unknown ticket {id:?}"),
        };
        if !self.cfg.verifying() {
            // R = 1: bit-exact legacy complete, plus the in-memory
            // completer record that splits same-client retries from
            // cross-client duplicates.
            if t.status == TicketStatus::Done {
                inner.duplicate_results += 1;
                let same_client =
                    inner.completed_by.get(&id.0).map(|c| c == client).unwrap_or(false);
                return Ok(VoteOutcome::Duplicate { same_client });
            }
            t.status = TicketStatus::Done;
            t.result = Some(result.clone());
            let (task, index) = (t.task, t.index);
            inner.completed_by.insert(id.0, client.to_string());
            inner.completions.push_back((task, index, result));
            self.done_cv.notify_all();
            return Ok(VoteOutcome::Accepted { verdict: None });
        }
        let hash = canonical_hash(&result);
        if t.status == TicketStatus::Done {
            // Legacy duplicate accounting, now attributed — and a late
            // ballot still moves the straggler's reputation.
            inner.duplicate_results += 1;
            return Ok(match inner.verify.get_mut(&id.0) {
                Some(v) if v.has_voted(client) => VoteOutcome::Duplicate { same_client: true },
                Some(v) => {
                    let judged = v.record_late_vote(client, hash);
                    if let Some(won) = judged {
                        inner.apply_late_rep(client, won, now_ms);
                    }
                    VoteOutcome::Duplicate { same_client: false }
                }
                None => VoteOutcome::Duplicate { same_client: false },
            });
        }
        let trusted = matches!(
            inner.reps.get_mut(client).map(|r| r.standing(now_ms)),
            Some(Standing::Trusted)
        );
        let quorum = self.cfg.quorum;
        let v = inner.verify.entry(id.0).or_insert_with(|| TicketVerify::new(quorum));
        match v.record_vote(id, client, hash, &result, trusted, quorum) {
            VoteAction::Repeat => Ok(VoteOutcome::Repeat),
            VoteAction::Pending { escalated } => {
                inner.votes_recorded += 1;
                if escalated {
                    inner.escalations += 1;
                }
                Ok(VoteOutcome::Pending)
            }
            VoteAction::Decide(verdict) => {
                inner.votes_recorded += 1;
                inner.verdicts += 1;
                let winning = v.winning_value();
                let t = inner.tickets.get_mut(&id).unwrap();
                t.status = TicketStatus::Done;
                t.result = Some(winning.clone());
                let (task, index) = (t.task, t.index);
                inner.apply_verdict_reps(&verdict, now_ms);
                inner.completions.push_back((task, index, winning));
                self.done_cv.notify_all();
                Ok(VoteOutcome::Accepted { verdict: Some(verdict) })
            }
        }
    }

    fn next_completion(&self, task: TaskId, timeout_ms: u64) -> Option<(usize, Value)> {
        let deadline = deadline_after(timeout_ms);
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(pos) = inner.completions.iter().position(|(t, _, _)| *t == task) {
                let (_, index, value) = inner.completions.remove(pos).unwrap();
                return Some((index, value));
            }
            inner = wait_deadline(&self.done_cv, inner, deadline)?;
        }
    }

    fn report_error(&self, id: TicketId, report: String) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        inner.push_error(id, report);
        let requeue = self.cfg.requeue_on_error;
        // The clientless form clears every holder at R > 1 (no
        // attribution to keep) before the legacy requeue.
        if self.cfg.verifying() {
            if let Some(v) = inner.verify.get_mut(&id.0) {
                v.holders.clear();
            }
        }
        let has_votes = inner.verify.get(&id.0).map(|v| !v.votes.is_empty()).unwrap_or(false);
        if let Some(t) = inner.tickets.get_mut(&id) {
            if t.status == TicketStatus::InFlight && requeue && !has_votes {
                t.status = TicketStatus::Pending;
                t.last_distributed_ms = None; // VCT back to creation time
            }
        }
        Ok(())
    }

    fn report_error_from(&self, client: &str, id: TicketId, report: String) -> Result<()> {
        if !self.cfg.verifying() {
            return self.report_error(id, report);
        }
        let mut inner = self.inner.lock().unwrap();
        inner.push_error(id, report);
        let (released, empty) = match inner.verify.get_mut(&id.0) {
            Some(v) => (v.release_from(client), v.holders.is_empty() && v.votes.is_empty()),
            None => (false, true),
        };
        let _ = released;
        if let Some(t) = inner.tickets.get_mut(&id) {
            // Only when the erroring client was the last participant
            // does the ticket return to the undistributed pool; other
            // replicas keep working and the freed slot re-recruits.
            if t.status == TicketStatus::InFlight && self.cfg.requeue_on_error && empty {
                t.status = TicketStatus::Pending;
                t.last_distributed_ms = None; // VCT back to creation time
            }
        }
        Ok(())
    }

    fn release(&self, id: TicketId) -> bool {
        let mut inner = self.inner.lock().unwrap();
        // Clientless release at R > 1: clear every holder; the ticket
        // returns to the pool only if no ballots are pending on it.
        let has_votes = if self.cfg.verifying() {
            match inner.verify.get_mut(&id.0) {
                Some(v) => {
                    v.holders.clear();
                    !v.votes.is_empty()
                }
                None => false,
            }
        } else {
            false
        };
        match inner.tickets.get_mut(&id) {
            Some(t) if t.status == TicketStatus::InFlight && !has_votes => {
                t.status = TicketStatus::Pending;
                t.last_distributed_ms = None; // VCT back to creation time
                true
            }
            _ => false,
        }
    }

    fn release_batch_from(&self, client: &str, ids: &[TicketId]) -> Vec<bool> {
        if !self.cfg.verifying() {
            return self.release_batch(ids);
        }
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        ids.iter()
            .map(|&id| {
                let (released, empty) = match inner.verify.get_mut(&id.0) {
                    Some(v) => {
                        (v.release_from(client), v.holders.is_empty() && v.votes.is_empty())
                    }
                    None => (false, true),
                };
                if let Some(t) = inner.tickets.get_mut(&id) {
                    if t.status == TicketStatus::InFlight && empty && released {
                        t.status = TicketStatus::Pending;
                        t.last_distributed_ms = None; // VCT back to creation time
                    }
                }
                released
            })
            .collect()
    }

    fn client_standing(&self, client: &str, now_ms: u64) -> Standing {
        self.inner.lock().unwrap().standing_of(client, now_ms)
    }

    fn verify_stats(&self) -> VerifyStats {
        let inner = self.inner.lock().unwrap();
        VerifyStats {
            replication: self.cfg.replication,
            quorum: self.cfg.quorum,
            votes_recorded: inner.votes_recorded,
            verdicts: inner.verdicts,
            votes_flagged: inner.votes_flagged,
            escalations: inner.escalations,
            quarantines: inner.quarantines,
            quarantined_now: inner.reps.values().filter(|r| r.quarantined_until.is_some()).count(),
            trusted_now: inner
                .reps
                .values()
                .filter(|r| r.quarantined_until.is_none() && r.score >= super::ticket::TRUST_SCORE)
                .count(),
        }
    }

    fn quarantined_clients(&self) -> Vec<String> {
        let inner = self.inner.lock().unwrap();
        inner
            .reps
            .iter()
            .filter(|(_, r)| r.ever_quarantined)
            .map(|(c, _)| c.clone())
            .collect()
    }

    // `release_batch` is deliberately not overridden: this store runs
    // the trait's id-by-id loop, which is the reference semantics the
    // indexed store's amortised batch release is differential-tested
    // against (`rust/tests/properties.rs`).

    fn progress(&self, task: Option<TaskId>) -> Progress {
        let inner = self.inner.lock().unwrap();
        let mut p = Progress {
            redistributions: inner.redistributions,
            duplicate_results: inner.duplicate_results,
            errors: inner.errors_reported,
            ..Default::default()
        };
        for t in inner.tickets.values() {
            if task.map(|id| t.task == id).unwrap_or(true) {
                p.total += 1;
                match t.status {
                    TicketStatus::Pending => p.pending += 1,
                    TicketStatus::InFlight => p.in_flight += 1,
                    TicketStatus::Done => p.done += 1,
                }
            }
        }
        p
    }

    fn is_task_done(&self, task: TaskId) -> bool {
        let inner = self.inner.lock().unwrap();
        inner
            .tickets
            .values()
            .filter(|t| t.task == task)
            .all(|t| t.status == TicketStatus::Done)
    }

    fn max_task_id(&self) -> Option<TaskId> {
        self.inner.lock().unwrap().tickets.values().map(|t| t.task).max()
    }

    fn wait_results_deadline(
        &self,
        task: TaskId,
        deadline: Option<Instant>,
    ) -> Option<Vec<Value>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            let all_done = inner
                .tickets
                .values()
                .filter(|t| t.task == task)
                .all(|t| t.status == TicketStatus::Done);
            if all_done {
                let mut rows: Vec<(usize, Value)> = inner
                    .tickets
                    .values()
                    .filter(|t| t.task == task)
                    .map(|t| (t.index, t.result.clone().unwrap()))
                    .collect();
                rows.sort_by_key(|(i, _)| *i);
                return Some(rows.into_iter().map(|(_, v)| v).collect());
            }
            inner = wait_deadline(&self.done_cv, inner, deadline)?;
        }
    }

    fn error_count(&self) -> usize {
        self.inner.lock().unwrap().errors_reported
    }

    fn drain_errors(&self) -> Vec<(TicketId, String)> {
        std::mem::take(&mut self.inner.lock().unwrap().errors)
    }
}
