//! Ticket store — the MySQL substitute, with the paper's exact
//! redistribution semantics (§2.1.2).
//!
//! The paper keeps tickets in a MySQL table and selects them in
//! ascending order of **virtual created time** (VCT):
//!
//! * undistributed ticket → VCT = creation time;
//! * distributed ticket   → VCT = distribution time + 5 minutes;
//! * redistributed ticket → VCT = *last* distribution time + 5 minutes.
//!
//! So a ticket whose result has not returned within 5 minutes looks
//! freshly created again and gets re-issued.  Two extra rules: when no
//! ticket's VCT has arrived yet (everything is recently in flight),
//! tickets are redistributed in ascending order of distribution time —
//! but each at least `min_redistribute` apart, so the *last* ticket of a
//! task is not blasted to every idle client at once.
//!
//! Both timeouts are configurable ([`StoreConfig`]); the defaults are the
//! paper's 5 min / 10 s, and benches scale them down with the modelled
//! clock.
//!
//! The scheduling policy is pinned by the [`Scheduler`] trait and has two
//! implementations:
//!
//! * [`sched::IndexedStore`] (the default, aliased as [`TicketStore`]) —
//!   the production path: a VCT-ordered ready index plus a
//!   last-distributed fallback index make `next_ticket` O(log n), done
//!   tickets are evicted from the scan path into per-task result
//!   ledgers, and the ticket bodies live in N lock stripes so
//!   distributor connection threads do not serialise on one mutex.
//! * [`naive::NaiveStore`] — the original O(n)-scan reference
//!   implementation, kept for differential testing: the property suite
//!   drives random operation sequences through both and asserts
//!   identical dispatch order and accounting
//!   (`rust/tests/properties.rs`).
//!
//! A third backend adds durability rather than a third policy:
//! [`wal::WalStore`] wraps an [`IndexedStore`] behind a write-ahead log
//! with CRC-checked frames, group-commit fsync and checkpoint
//! truncation, so a coordinator restart recovers every ticket — the
//! paper got this from MySQL for free (`serve --state-dir` wires it up;
//! crash/recovery is differential-tested in `rust/tests/wal_recovery.rs`).
//!
//! The invariants (no lost tickets, first result wins, ordered
//! collection) are property-tested in `rust/tests/properties.rs`.

pub mod naive;
pub mod sched;
pub mod ticket;
pub mod wal;

pub use naive::NaiveStore;
pub use sched::IndexedStore;
pub use ticket::{canonical_hash, Standing, Ticket, TicketId, TicketStatus, Verdict, VoteOutcome};
pub use wal::{SyncPolicy, WalConfig, WalStore};

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::util::json::Value;
use crate::util::lockcheck::{CheckedCondvar, CheckedMutexGuard};

/// A millisecond timeout as a deadline; `None` when it overflows the
/// platform clock — callers treat that as "wait forever".
pub(crate) fn deadline_after(timeout_ms: u64) -> Option<Instant> {
    Instant::now().checked_add(Duration::from_millis(timeout_ms))
}

/// One condvar wait bounded by an optional deadline: `None` when the
/// deadline has passed (caller times out), otherwise the reacquired
/// guard after a (possibly spurious) wakeup.  Shared by both backends'
/// `next_completion` / `wait_results_deadline` loops.
pub(crate) fn wait_deadline<'a, T>(
    cv: &CheckedCondvar,
    guard: CheckedMutexGuard<'a, T>,
    deadline: Option<Instant>,
) -> Option<CheckedMutexGuard<'a, T>> {
    match deadline {
        None => Some(cv.wait(guard).unwrap()),
        Some(d) => {
            let now = Instant::now();
            if now >= d {
                return None;
            }
            Some(cv.wait_timeout(guard, d - now).unwrap().0)
        }
    }
}

/// The default store implementation served to every consumer.
pub type TicketStore = IndexedStore;

/// Task identifier within a running framework.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TaskId(pub u64);

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StoreConfig {
    /// Re-issue a ticket if no result within this window (paper: 5 min).
    pub requeue_after_ms: u64,
    /// Minimum interval between redistributions of one ticket (paper: 10 s).
    pub min_redistribute_ms: u64,
    /// On worker error reports, immediately return the ticket to the
    /// undistributed pool instead of waiting out the timeout.
    pub requeue_on_error: bool,
    /// Maximum number of *distinct* clients a ticket is concurrently
    /// dispatched to for result verification.  1 (the default) is the
    /// bit-exact legacy first-result-wins store; R > 1 replicates each
    /// ticket and completes it by quorum vote (`ticket::TicketVerify`).
    pub replication: u32,
    /// Matching votes required to accept a result at `replication > 1`
    /// (ignored at R = 1).  A trusted client's single vote also decides
    /// — the BOINC-style adaptive fast path.
    pub quorum: u32,
}

impl Default for StoreConfig {
    fn default() -> Self {
        Self {
            requeue_after_ms: 300_000,
            min_redistribute_ms: 10_000,
            requeue_on_error: true,
            replication: 1,
            quorum: 1,
        }
    }
}

impl StoreConfig {
    /// Whether the quorum verification layer is active.
    pub fn verifying(&self) -> bool {
        self.replication > 1
    }
}

/// Counters surfaced on the control console (paper: project name, #tasks,
/// #waiting tickets, #executed tickets, #error reports, client info).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Progress {
    /// Tickets ever created (in scope: one task, or the whole store).
    pub total: usize,
    /// Undistributed tickets waiting for a client.
    pub pending: usize,
    /// Distributed tickets whose result has not been accepted yet.
    pub in_flight: usize,
    /// Tickets with an accepted result (first result wins).
    pub done: usize,
    /// Cumulative error reports ever recorded (store-wide; not reduced
    /// by [`Scheduler::drain_errors`]).
    pub errors: usize,
    /// Times a ticket was handed out *again* (timeout, fallback, or
    /// post-error re-dispatch); store-wide.
    pub redistributions: u64,
    /// Results dropped because the ticket was already done (a slow
    /// client answering a redistributed ticket); store-wide.
    pub duplicate_results: u64,
}

/// Contention observability for the dispatch core
/// ([`Scheduler::stats`]): how hard the dispatch mutex(es) are being
/// hit and how often work-stealing fires.  Surfaced on the console
/// snapshot and in the churn-soak metrics JSON.  Backends without a
/// sharded dispatch core return the default (all zeros,
/// `dispatch_shards == 0` meaning "not instrumented").
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SchedStats {
    /// Number of dispatch shards (0 = backend not instrumented).
    pub dispatch_shards: usize,
    /// Cumulative dispatch-mutex acquisitions on the dispatch paths.
    pub dispatch_locks: u64,
    /// Cumulative `try_lock` probes on non-home shards.
    pub steal_attempts: u64,
    /// Steal probes that actually yielded at least one ticket.
    pub steal_successes: u64,
    /// Current ready-index depth per shard (live, non-done tickets).
    pub shard_depths: Vec<usize>,
    /// Error reports dropped from the drain buffer because a shard's
    /// queue hit its cap (an adversarial error flood); the cumulative
    /// [`Scheduler::error_count`] still counts them.
    pub errors_dropped: u64,
}

/// Per-shard (and, for the unsharded reference store, global) cap on
/// the buffered-but-undrained error reports: an adversarial error flood
/// stops growing the queue here and counts
/// [`SchedStats::errors_dropped`] instead.
pub const ERROR_QUEUE_CAP: usize = 1024;

/// Counters for the result-verification layer ([`Scheduler::verify_stats`]).
/// All zeros at `replication == 1` (the layer is inactive).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct VerifyStats {
    pub replication: u32,
    pub quorum: u32,
    /// Ballots recorded on undecided tickets (accepted + pending).
    pub votes_recorded: u64,
    /// Tickets decided by quorum (or trusted fast-path) vote.
    pub verdicts: u64,
    /// Votes judged wrong: minority ballots at verdict time plus late
    /// mismatching ballots.
    pub votes_flagged: u64,
    /// Divergence escalations (recruitment-target bumps — each recruits
    /// one fresh tie-breaker client).
    pub escalations: u64,
    /// Cumulative quarantine events.
    pub quarantines: u64,
    /// Clients currently under an unexpired (or not-yet-cleared)
    /// quarantine.
    pub quarantined_now: usize,
    /// Clients currently at [`Standing::Trusted`].
    pub trusted_now: usize,
}

/// The scheduling-core boundary consumed by the coordinator
/// (`distributor`/`framework`/`console`), the §4 trainers (`dist`), and
/// the worker tests: everything the paper's MySQL table plus its SELECT
/// policy provided.
///
/// # Invariants
///
/// Every implementation must preserve these bit-for-bit — the
/// differential property suites (`rust/tests/properties.rs`,
/// `rust/tests/wal_recovery.rs`) replay random operation sequences
/// through two backends and assert observable equality, so "almost the
/// same policy" fails loudly:
///
/// * **VCT dispatch ordering** — [`next_ticket`](Self::next_ticket)
///   picks the minimum `(vct, id)` among non-done tickets whose virtual
///   created time has arrived, where `vct` = creation time for
///   undistributed tickets and last-distribution time +
///   [`StoreConfig::requeue_after_ms`] otherwise.  The `id` tie-break
///   makes same-clock dispatch deterministic.
/// * **Min-redistribute fallback** — when no VCT has arrived, the
///   longest-undistributed in-flight ticket is re-issued, but never
///   within [`StoreConfig::min_redistribute_ms`] of its last
///   distribution (the paper's 10 s rule: the last ticket of a task is
///   not blasted to every idle client at once).
/// * **First result wins, duplicates accounted** — the first
///   [`complete`](Self::complete) for a ticket is accepted; later ones
///   return `Ok(false)` and increment
///   [`Progress::duplicate_results`], never overwriting the stored
///   result.
/// * **Batch = k-fold loop** — [`next_tickets`](Self::next_tickets)
///   returns exactly the sequence that `k` successive
///   [`next_ticket`](Self::next_ticket) calls at the same `now_ms`
///   would, stopping early at the first `None` (so a batch is always a
///   prefix of the k-fold dispatch sequence, VCT ordering preserved
///   within and across batches), and
///   [`complete_batch`](Self::complete_batch) applies its entries in
///   order with per-entry first-result-wins accounting, stopping at the
///   first error with the preceding prefix applied.  `k = 1` is
///   bit-for-bit the unbatched path.
/// * **Error requeue at creation time** — an error report on an
///   in-flight ticket (with `requeue_on_error`) returns it to the pool
///   with its VCT reset to the *original* creation time, keeping its
///   distribution history; reports are buffered until
///   [`drain_errors`](Self::drain_errors) and counted forever in
///   [`error_count`](Self::error_count).
/// * **Explicit release** — [`release`](Self::release) performs the
///   same pool-return transition as an error requeue (VCT back to the
///   original creation time, history kept, the next dispatch counted
///   as a redistribution) but records no error and ignores
///   `requeue_on_error`: it is the *active* failure path, bypassing
///   both `requeue_after_ms` and `min_redistribute_ms`.  Pending, done
///   and unknown ids are tolerated no-ops returning `false` (a
///   released ticket may have been completed by a racing client, or
///   released twice).  [`release_batch`](Self::release_batch) equals
///   the id-by-id loop, per-entry flags and all.
/// * **Ordered collection** — [`wait_results`](Self::wait_results)
///   returns accepted results sorted by ticket index (id-tie-broken),
///   regardless of completion order.
///
/// # Sharded-dispatch relaxation
///
/// A backend may partition its dispatch core into S shards
/// ([`IndexedStore::with_dispatch_shards`]).  With S = 1 (every
/// default constructor) all of the above holds globally, bit-for-bit.
/// With S > 1 the *ordering* invariants (VCT dispatch order, the
/// min-redistribute fallback, batch-is-a-prefix) hold **per shard**:
/// the global dispatch sequence is an interleaving of S sequences,
/// each individually exact.  Every *per-ticket* invariant
/// (at-least-once, no concurrent duplicate dispatch, first result
/// wins, error/release requeue semantics, conservation of counts) is
/// unchanged, because each ticket lives in exactly one shard and all
/// its transitions happen under that shard's mutex.
/// [`drain_errors`](Self::drain_errors) order becomes shard-major.
/// The shard-oracle differential suite (`rust/tests/properties.rs`)
/// pins exactly this relaxation; DESIGN.md §2.6 derives it.
pub trait Scheduler: Send + Sync {
    fn config(&self) -> &StoreConfig;

    /// Create tickets for a task's divided arguments; returns their ids.
    fn create_tickets(
        &self,
        task: TaskId,
        task_name: &str,
        args: Vec<Value>,
        now_ms: u64,
    ) -> Vec<TicketId>;

    /// The SQL `SELECT ... ORDER BY vct LIMIT 1` equivalent: pick the
    /// next ticket for `client` at `now_ms`, marking it distributed.
    fn next_ticket(&self, client: &str, now_ms: u64) -> Option<Ticket>;

    /// Record a result.  First result wins; duplicates (a slow client
    /// returning a redistributed ticket) are counted and dropped.
    fn complete(&self, id: TicketId, result: Value) -> Result<bool>;

    /// Batched dispatch: up to `k` tickets for `client` at `now_ms`, in
    /// dispatch order — observably identical to calling
    /// [`next_ticket`](Self::next_ticket) `k` times and stopping at the
    /// first `None` (the same ticket may appear more than once when the
    /// min-redistribute window is zero, exactly as the loop would
    /// re-issue it).  This default *is* the loop; indexed backends
    /// override it to amortise lock acquisitions across the batch.
    fn next_tickets(&self, client: &str, now_ms: u64, k: usize) -> Vec<Ticket> {
        let mut out = Vec::with_capacity(k.min(64));
        for _ in 0..k {
            match self.next_ticket(client, now_ms) {
                Some(t) => out.push(t),
                None => break,
            }
        }
        out
    }

    /// Batched completion: apply `(ticket, result)` pairs in order with
    /// [`complete`](Self::complete) semantics per entry; returns how
    /// many were freshly accepted (the rest were duplicates).  On an
    /// unknown ticket the entries *before* it stay applied and the
    /// error is returned — identical to looping `complete` by hand.
    fn complete_batch(&self, results: Vec<(TicketId, Value)>) -> Result<usize> {
        let mut accepted = 0usize;
        for (id, result) in results {
            if self.complete(id, result)? {
                accepted += 1;
            }
        }
        Ok(accepted)
    }

    /// Record a worker error report; optionally requeue immediately.
    fn report_error(&self, id: TicketId, report: String) -> Result<()>;

    /// Hand a dispatched ticket back to the pool as immediately
    /// re-dispatchable: status → `Pending`, VCT reset to the *original*
    /// creation time, distribution history kept — the transition an
    /// error requeue performs (§2.1.2) minus the error record, and
    /// unconditional (not gated on [`StoreConfig::requeue_on_error`]).
    /// Both redistribution windows are bypassed, so the very next
    /// [`next_ticket`](Self::next_ticket) may re-issue it.  Returns
    /// whether the ticket actually moved; pending, done and unknown
    /// ids return `false` (releases are tolerant — the ticket may have
    /// been completed by a racing client, or released twice).  The
    /// caller is trusted on ownership: releasing a ticket that §2.1.2
    /// redistribution has meanwhile handed to a *live* client yanks it
    /// back to the pool early — bounded duplicate work that
    /// first-result-wins absorbs, exactly as for timeout
    /// redistribution itself (DESIGN.md §2.4).
    fn release(&self, id: TicketId) -> bool;

    /// Batched release with per-entry [`release`](Self::release)
    /// semantics, applied in order; returns the per-entry released
    /// flags (a repeated id releases only once, exactly like the
    /// loop).  This default *is* the loop — the reference semantics
    /// [`NaiveStore`] runs; indexed backends override it to amortise
    /// lock acquisitions across the batch and durable backends log one
    /// framed record per batch.
    fn release_batch(&self, ids: &[TicketId]) -> Vec<bool> {
        ids.iter().map(|&id| self.release(id)).collect()
    }

    /// Pop the next accepted result for `task` (FIFO in completion
    /// order), waiting up to `timeout_ms`.  Streaming counterpart of
    /// [`Scheduler::wait_results`].
    fn next_completion(&self, task: TaskId, timeout_ms: u64) -> Option<(usize, Value)>;

    fn progress(&self, task: Option<TaskId>) -> Progress;

    fn is_task_done(&self, task: TaskId) -> bool;

    /// Highest task id that owns at least one ticket, if any — what a
    /// coordinator seeds its task-id allocator from after recovering a
    /// durable store, so fresh tasks never collide with recovered
    /// ledgers ([`crate::coordinator::Framework`]).
    fn max_task_id(&self) -> Option<TaskId>;

    /// Wait until every ticket of `task` is done, then return results
    /// ordered by ticket index.  `deadline` of `None` blocks forever;
    /// `Some(instant)` returns `None` on timeout.  The single
    /// deadline-parameterised implementation behind both
    /// [`Scheduler::wait_results`] and
    /// [`Scheduler::wait_results_timeout`].
    fn wait_results_deadline(&self, task: TaskId, deadline: Option<Instant>)
        -> Option<Vec<Value>>;

    /// Cumulative number of error reports ever recorded (monotone; not
    /// reduced by [`Scheduler::drain_errors`]).
    fn error_count(&self) -> usize;

    /// Take the buffered error reports, leaving the buffer empty.  The
    /// cumulative [`Scheduler::error_count`] is unaffected.
    fn drain_errors(&self) -> Vec<(TicketId, String)>;

    /// Dispatch-contention counters ([`SchedStats`]).  The default is
    /// the uninstrumented answer (`dispatch_shards == 0`); sharded
    /// backends override.
    fn stats(&self) -> SchedStats {
        SchedStats::default()
    }

    /// Record a result as a *vote* from `client`.  At `replication == 1`
    /// this is exactly [`complete`](Self::complete) (the default shown
    /// here), with the outcome mapped onto [`VoteOutcome`]; at R > 1 a
    /// verifying backend runs the quorum state machine instead: the
    /// ticket completes only when `quorum` matching ballots (or one
    /// from a trusted client) have arrived, minority voters are flagged
    /// and divergent tickets recruit a fresh tie-breaker client.
    /// Legacy wire clients vote without knowing it — the distributor
    /// routes every `TicketResult` through here.
    fn vote(&self, client: &str, id: TicketId, result: Value, now_ms: u64) -> Result<VoteOutcome> {
        let _ = (client, now_ms);
        Ok(match self.complete(id, result)? {
            true => VoteOutcome::Accepted { verdict: None },
            false => VoteOutcome::Duplicate { same_client: false },
        })
    }

    /// Batched [`vote`](Self::vote): entries applied in order, stopping
    /// at the first error with the prefix applied (the
    /// [`complete_batch`](Self::complete_batch) contract).
    fn vote_batch(
        &self,
        client: &str,
        results: Vec<(TicketId, Value)>,
        now_ms: u64,
    ) -> Result<Vec<VoteOutcome>> {
        results.into_iter().map(|(id, v)| self.vote(client, id, v, now_ms)).collect()
    }

    /// [`release_batch`](Self::release_batch) attributed to the client
    /// handing the tickets back.  At R > 1 a verifying backend removes
    /// only *that client* from each ticket's holder set (other replicas
    /// keep working); the unattributed default releases outright —
    /// correct at R = 1 where a ticket has one holder.
    fn release_batch_from(&self, client: &str, ids: &[TicketId]) -> Vec<bool> {
        let _ = client;
        self.release_batch(ids)
    }

    /// [`report_error`](Self::report_error) attributed to the reporting
    /// client — same relationship to the unattributed form as
    /// [`release_batch_from`](Self::release_batch_from).
    fn report_error_from(&self, client: &str, id: TicketId, report: String) -> Result<()> {
        let _ = client;
        self.report_error(id, report)
    }

    /// The client's current reputation standing.  Non-verifying
    /// backends know nothing and answer [`Standing::Normal`].
    fn client_standing(&self, client: &str, now_ms: u64) -> Standing {
        let _ = (client, now_ms);
        Standing::Normal
    }

    /// Verification-layer counters; all zeros when inactive.
    fn verify_stats(&self) -> VerifyStats {
        VerifyStats::default()
    }

    /// Every client ever quarantined, sorted by name.
    fn quarantined_clients(&self) -> Vec<String> {
        Vec::new()
    }

    /// Block until every ticket of `task` is done (condvar, no polling),
    /// then return results ordered by ticket index — the framework's
    /// `task.block(callback)` from the appendix sample.
    fn wait_results(&self, task: TaskId) -> Vec<Value> {
        self.wait_results_deadline(task, None).expect("unbounded wait cannot time out")
    }

    /// Non-blocking variant with timeout; None on timeout.
    fn wait_results_timeout(&self, task: TaskId, timeout_ms: u64) -> Option<Vec<Value>> {
        self.wait_results_deadline(task, deadline_after(timeout_ms))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(n: usize) -> Vec<Value> {
        (0..n).map(|i| Value::num(i as f64)).collect()
    }

    /// The behavioural suite every [`Scheduler`] implementation must
    /// pass; instantiated below for both backends.
    macro_rules! scheduler_suite {
        ($backend:ident, $make:expr) => {
            mod $backend {
                use super::args;
                // Each expansion constructs only one of the two backends.
                #[allow(unused_imports)]
                use crate::store::{
                    IndexedStore, NaiveStore, Scheduler, StoreConfig, TaskId, TicketId,
                };
                use crate::util::json::Value;

                #[allow(clippy::redundant_closure_call)]
                fn store(requeue_ms: u64, min_redist: u64) -> Box<dyn Scheduler> {
                    let cfg = StoreConfig {
                        requeue_after_ms: requeue_ms,
                        min_redistribute_ms: min_redist,
                        ..StoreConfig::default()
                    };
                    ($make)(cfg)
                }

                #[test]
                fn fifo_by_creation_time() {
                    let s = store(1000, 100);
                    s.create_tickets(TaskId(1), "t", args(3), 10);
                    let a = s.next_ticket("c1", 20).unwrap();
                    let b = s.next_ticket("c1", 21).unwrap();
                    assert_eq!(a.index, 0);
                    assert_eq!(b.index, 1);
                }

                #[test]
                fn inflight_ticket_not_reissued_before_timeout() {
                    let s = store(1000, 100);
                    s.create_tickets(TaskId(1), "t", args(1), 0);
                    let t = s.next_ticket("c1", 0).unwrap();
                    // Before timeout and within min_redistribute: nothing for c2.
                    assert!(s.next_ticket("c2", 50).is_none());
                    // After min_redistribute (fallback path): redistribute.
                    let again = s.next_ticket("c2", 150).unwrap();
                    assert_eq!(again.id, t.id);
                    assert_eq!(again.distribution_count, 2);
                }

                #[test]
                fn timeout_reissues_via_vct() {
                    let s = store(1000, 10_000); // min_redistribute large: only VCT path
                    s.create_tickets(TaskId(1), "t", args(1), 0);
                    let t = s.next_ticket("c1", 0).unwrap();
                    assert!(s.next_ticket("c2", 999).is_none());
                    let again = s.next_ticket("c2", 1001).unwrap();
                    assert_eq!(again.id, t.id);
                }

                #[test]
                fn first_result_wins_duplicates_counted() {
                    let s = store(100, 10);
                    let ids = s.create_tickets(TaskId(1), "t", args(1), 0);
                    let _ = s.next_ticket("c1", 0).unwrap();
                    let _ = s.next_ticket("c2", 200).unwrap(); // redistributed
                    assert!(s.complete(ids[0], Value::num(1.0)).unwrap());
                    assert!(!s.complete(ids[0], Value::num(2.0)).unwrap());
                    let p = s.progress(None);
                    assert_eq!(p.done, 1);
                    assert_eq!(p.duplicate_results, 1);
                    // First result is what block() sees.
                    assert_eq!(s.wait_results(TaskId(1)), vec![Value::num(1.0)]);
                }

                #[test]
                fn error_requeues_immediately() {
                    let s = store(1_000_000, 1_000_000);
                    let ids = s.create_tickets(TaskId(1), "t", args(1), 0);
                    let _ = s.next_ticket("c1", 0).unwrap();
                    s.report_error(ids[0], "boom".into()).unwrap();
                    // Eligible right away despite huge timeouts.
                    let t = s.next_ticket("c2", 1).unwrap();
                    assert_eq!(t.id, ids[0]);
                    assert_eq!(s.progress(None).errors, 1);
                }

                #[test]
                fn results_ordered_by_index() {
                    let s = store(1000, 100);
                    let ids = s.create_tickets(TaskId(7), "t", args(3), 0);
                    // Complete out of order.
                    for i in [2usize, 0, 1] {
                        let _ = s.next_ticket("c", i as u64);
                        s.complete(ids[i], Value::num(i as f64 * 10.0)).unwrap();
                    }
                    let r = s.wait_results(TaskId(7));
                    assert_eq!(r, vec![Value::num(0.0), Value::num(10.0), Value::num(20.0)]);
                }

                #[test]
                fn min_redistribute_rate_limits_last_ticket() {
                    // The 10 s rule: an in-flight last ticket is not handed to
                    // every idle client at once.
                    let s = store(100_000, 50);
                    s.create_tickets(TaskId(1), "t", args(1), 0);
                    let _ = s.next_ticket("c1", 0).unwrap();
                    assert!(s.next_ticket("c2", 10).is_none());
                    assert!(s.next_ticket("c3", 49).is_none());
                    assert!(s.next_ticket("c4", 50).is_some());
                    // Fresh redistribution resets the window.
                    assert!(s.next_ticket("c5", 60).is_none());
                }

                #[test]
                fn progress_by_task() {
                    let s = store(1000, 100);
                    s.create_tickets(TaskId(1), "a", args(2), 0);
                    let ids = s.create_tickets(TaskId(2), "b", args(1), 0);
                    s.next_ticket("c", 0);
                    let _ = s.complete(ids[0], Value::Null).unwrap();
                    let p1 = s.progress(Some(TaskId(1)));
                    assert_eq!(p1.total, 2);
                    let p2 = s.progress(Some(TaskId(2)));
                    assert_eq!(p2.done, 1);
                }

                #[test]
                fn wait_with_timeout_returns_none_if_incomplete() {
                    let s = store(1000, 100);
                    s.create_tickets(TaskId(1), "t", args(1), 0);
                    assert!(s.wait_results_timeout(TaskId(1), 30).is_none());
                }

                #[test]
                fn completions_stream_in_fifo_order() {
                    let s = store(1000, 100);
                    let ids = s.create_tickets(TaskId(1), "t", args(3), 0);
                    let _ = s.next_ticket("c", 0);
                    s.complete(ids[1], Value::num(1.0)).unwrap();
                    s.complete(ids[0], Value::num(0.0)).unwrap();
                    assert_eq!(s.next_completion(TaskId(1), 10), Some((1, Value::num(1.0))));
                    assert_eq!(s.next_completion(TaskId(1), 10), Some((0, Value::num(0.0))));
                    assert_eq!(s.next_completion(TaskId(1), 10), None); // third not done
                    // Completions are task-scoped.
                    let other = s.create_tickets(TaskId(2), "u", args(1), 0);
                    s.complete(other[0], Value::Bool(true)).unwrap();
                    s.complete(ids[2], Value::num(2.0)).unwrap();
                    assert_eq!(s.next_completion(TaskId(2), 10), Some((0, Value::Bool(true))));
                    assert_eq!(s.next_completion(TaskId(1), 10), Some((2, Value::num(2.0))));
                }

                #[test]
                fn unknown_ticket_completion_is_error() {
                    let s = store(1000, 100);
                    assert!(s.complete(TicketId(99), Value::Null).is_err());
                }

                #[test]
                fn max_task_id_tracks_ticketed_tasks() {
                    let s = store(1000, 100);
                    assert_eq!(s.max_task_id(), None);
                    s.create_tickets(TaskId(3), "t", args(1), 0);
                    s.create_tickets(TaskId(1), "t", args(1), 0);
                    assert_eq!(s.max_task_id(), Some(TaskId(3)));
                }

                /// Batched dispatch must equal the k-fold `next_ticket`
                /// loop on an identical store — including re-issuing the
                /// same ticket when the min-redistribute window is zero.
                #[test]
                fn batch_dispatch_is_prefix_of_loop() {
                    let a = store(1000, 0);
                    let b = store(1000, 0);
                    a.create_tickets(TaskId(1), "t", args(3), 0);
                    b.create_tickets(TaskId(1), "t", args(3), 0);
                    let batch = a.next_tickets("c", 5, 5);
                    let mut looped = Vec::new();
                    for _ in 0..5 {
                        match b.next_ticket("c", 5) {
                            Some(t) => looped.push(t),
                            None => break,
                        }
                    }
                    assert_eq!(batch, looped);
                    assert_eq!(batch.len(), 5, "zero window keeps re-issuing");
                    assert_eq!(batch[3].id, batch[0].id, "fallback re-issue inside the batch");
                    assert_eq!(a.progress(None), b.progress(None));
                }

                #[test]
                fn complete_batch_counts_and_stops_at_unknown() {
                    let s = store(1000, 100);
                    let ids = s.create_tickets(TaskId(1), "t", args(3), 0);
                    let _ = s.next_ticket("c", 0);
                    // A duplicate inside one batch is counted, not applied.
                    let accepted = s
                        .complete_batch(vec![
                            (ids[0], Value::num(1.0)),
                            (ids[0], Value::num(2.0)),
                            (ids[1], Value::num(3.0)),
                        ])
                        .unwrap();
                    assert_eq!(accepted, 2);
                    let p = s.progress(None);
                    assert_eq!(p.done, 2);
                    assert_eq!(p.duplicate_results, 1);
                    // Unknown id mid-batch: the prefix stays applied.
                    let err = s.complete_batch(vec![
                        (ids[2], Value::num(4.0)),
                        (TicketId(99), Value::Null),
                    ]);
                    assert!(err.is_err());
                    assert_eq!(s.progress(None).done, 3);
                    assert_eq!(
                        s.wait_results(TaskId(1)),
                        vec![Value::num(1.0), Value::num(3.0), Value::num(4.0)]
                    );
                }

                #[test]
                fn empty_and_oversized_batches() {
                    let s = store(1000, 100);
                    assert!(s.next_tickets("c", 0, 0).is_empty());
                    assert_eq!(s.complete_batch(Vec::new()).unwrap(), 0);
                    s.create_tickets(TaskId(1), "t", args(2), 0);
                    // k beyond the pool stops where the loop would: the
                    // min-redistribute window blocks a re-issue.
                    let got = s.next_tickets("c", 5, 8);
                    assert_eq!(got.len(), 2);
                    assert_eq!(s.progress(None).in_flight, 2);
                }

                /// Release is the active failure path: an in-flight
                /// ticket returns to the pool at once, both
                /// redistribution windows bypassed, history intact.
                #[test]
                fn release_returns_ticket_immediately() {
                    let s = store(1_000_000, 1_000_000);
                    let ids = s.create_tickets(TaskId(1), "t", args(1), 0);
                    let t = s.next_ticket("c1", 0).unwrap();
                    assert!(s.next_ticket("c2", 1).is_none(), "windows block redistribution");
                    assert!(s.release(t.id), "in-flight ticket releases");
                    let p = s.progress(None);
                    assert_eq!((p.pending, p.in_flight), (1, 0));
                    assert_eq!(p.errors, 0, "release records no error");
                    let again = s.next_ticket("c2", 2).unwrap();
                    assert_eq!(again.id, ids[0]);
                    assert_eq!(again.distribution_count, 2, "history preserved");
                    assert_eq!(
                        s.progress(None).redistributions,
                        1,
                        "re-dispatch after release is a redistribution"
                    );
                    s.complete(ids[0], Value::Null).unwrap();
                    assert!(!s.release(ids[0]), "done ticket does not release");
                    assert!(!s.release(TicketId(999)), "unknown id is a tolerated no-op");
                }

                /// A release batch equals the id-by-id loop: per-entry
                /// flags, repeated ids releasing once, unknown and
                /// pending ids flagged false with the rest applied.
                #[test]
                fn release_batch_flags_match_loop() {
                    let s = store(1_000_000, 1_000_000);
                    let ids = s.create_tickets(TaskId(1), "t", args(3), 0);
                    let a = s.next_ticket("c", 0).unwrap();
                    let b = s.next_ticket("c", 1).unwrap();
                    // ids[2] stays pending; a repeated and an unknown id
                    // exercise the tolerant flags.
                    let flags = s.release_batch(&[a.id, b.id, a.id, ids[2], TicketId(99)]);
                    assert_eq!(flags, vec![true, true, false, false, false]);
                    let p = s.progress(None);
                    assert_eq!((p.pending, p.in_flight), (3, 0));
                    // Released tickets dispatch again in creation (VCT)
                    // order, oldest id first.
                    assert_eq!(s.next_ticket("d", 2).unwrap().id, ids[0]);
                    assert!(s.release_batch(&[]).is_empty());
                }

                /// Weak cross-backend contract for [`Scheduler::stats`]:
                /// instrumented backends report one depth per shard and
                /// never more steal successes than attempts;
                /// uninstrumented ones report the zero default.
                #[test]
                fn stats_are_internally_consistent() {
                    let s = store(1000, 100);
                    s.create_tickets(TaskId(1), "t", args(4), 0);
                    let _ = s.next_tickets("c", 1, 4);
                    let st = s.stats();
                    assert!(st.steal_successes <= st.steal_attempts);
                    if st.dispatch_shards > 0 {
                        assert_eq!(st.shard_depths.len(), st.dispatch_shards);
                        assert!(st.dispatch_locks > 0, "dispatch acquired a shard lock");
                    } else {
                        assert_eq!(st, Default::default());
                    }
                }

                /// A verifying store (R = 3, quorum = 2) completes only
                /// on agreement: replicas go to distinct clients, a
                /// lone vote pends, the second matching vote decides,
                /// and stragglers are attributed duplicates.
                #[test]
                fn quorum_store_completes_on_agreement() {
                    let cfg = StoreConfig {
                        requeue_after_ms: 1000,
                        min_redistribute_ms: 100,
                        replication: 3,
                        quorum: 2,
                        ..StoreConfig::default()
                    };
                    let s = ($make)(cfg);
                    let ids = s.create_tickets(TaskId(1), "t", args(1), 0);
                    let t1 = s.next_ticket("c1", 0).unwrap();
                    assert_eq!(t1.id, ids[0]);
                    // Same-client exclusion: c1 cannot take a replica.
                    assert!(s.next_ticket("c1", 1).is_none());
                    // A second client can, immediately (recruiting).
                    let t2 = s.next_ticket("c2", 1).unwrap();
                    assert_eq!(t2.id, ids[0]);
                    // Recruitment target reached: c3 must wait.
                    assert!(s.next_ticket("c3", 2).is_none());
                    let v = Value::num(42.0);
                    assert_eq!(
                        s.vote("c1", ids[0], v.clone(), 3).unwrap(),
                        crate::store::VoteOutcome::Pending
                    );
                    let p = s.progress(None);
                    assert_eq!((p.done, p.in_flight), (0, 1), "one vote is not a completion");
                    match s.vote("c2", ids[0], v.clone(), 4).unwrap() {
                        crate::store::VoteOutcome::Accepted { verdict: Some(verd) } => {
                            assert_eq!(verd.winners.len(), 2);
                            assert!(verd.losers.is_empty());
                        }
                        other => panic!("expected verdict, got {other:?}"),
                    }
                    assert_eq!(s.progress(None).done, 1);
                    assert_eq!(s.wait_results(TaskId(1)), vec![v.clone()]);
                    // Straggler votes are attributed duplicates.
                    assert_eq!(
                        s.vote("c3", ids[0], v.clone(), 5).unwrap(),
                        crate::store::VoteOutcome::Duplicate { same_client: false }
                    );
                    assert_eq!(
                        s.vote("c1", ids[0], v, 6).unwrap(),
                        crate::store::VoteOutcome::Duplicate { same_client: true }
                    );
                    assert_eq!(s.progress(None).duplicate_results, 2);
                    let vs = s.verify_stats();
                    assert_eq!((vs.replication, vs.quorum), (3, 2));
                    assert_eq!(vs.verdicts, 1);
                    assert_eq!(vs.votes_recorded, 2);
                }

                /// A wrong minority vote is outvoted, flagged, and (for
                /// a fresh client) quarantined: it is then served
                /// nothing until probation expires.
                #[test]
                fn minority_voter_is_flagged_and_quarantined() {
                    let cfg = StoreConfig {
                        requeue_after_ms: 100_000,
                        min_redistribute_ms: 10,
                        replication: 3,
                        quorum: 2,
                        ..StoreConfig::default()
                    };
                    let s = ($make)(cfg);
                    let ids = s.create_tickets(TaskId(1), "t", args(2), 0);
                    let _ = s.next_ticket("evil", 0).unwrap();
                    let _ = s.next_ticket("good1", 1).unwrap();
                    assert_eq!(
                        s.vote("evil", ids[0], Value::num(666.0), 2).unwrap(),
                        crate::store::VoteOutcome::Pending
                    );
                    assert_eq!(
                        s.vote("good1", ids[0], Value::num(1.0), 3).unwrap(),
                        crate::store::VoteOutcome::Pending,
                        "divergence cannot decide"
                    );
                    // The divergence recruited a tie-breaker slot.
                    let t = s.next_ticket("good2", 4).unwrap();
                    assert_eq!(t.id, ids[0]);
                    match s.vote("good2", ids[0], Value::num(1.0), 5).unwrap() {
                        crate::store::VoteOutcome::Accepted { verdict: Some(verd) } => {
                            assert_eq!(verd.losers, vec!["evil".to_string()]);
                        }
                        other => panic!("expected verdict, got {other:?}"),
                    }
                    assert_eq!(s.wait_results(TaskId(1)), vec![Value::num(1.0)]);
                    // The fresh loser is quarantined and served nothing.
                    match s.client_standing("evil", 6) {
                        crate::store::Standing::Quarantined { .. } => {}
                        other => panic!("expected quarantine, got {other:?}"),
                    }
                    assert!(s.next_ticket("evil", 7).is_none(), "quarantined client gets NoTicket");
                    assert_eq!(s.quarantined_clients(), vec!["evil".to_string()]);
                    let vs = s.verify_stats();
                    assert_eq!(vs.votes_flagged, 1);
                    assert_eq!(vs.escalations, 1);
                    assert_eq!(vs.quarantines, 1);
                    // Probation expires: served again.
                    let far = 6 + crate::store::ticket::PROBATION_MS + 1;
                    assert_eq!(
                        s.client_standing("evil", far),
                        crate::store::Standing::Normal
                    );
                    assert!(s.next_ticket("evil", far).is_some());
                }

                /// Attributed release removes one holder without
                /// disturbing the other replica's in-flight work.
                #[test]
                fn release_from_keeps_other_replicas_in_flight() {
                    let cfg = StoreConfig {
                        requeue_after_ms: 100_000,
                        min_redistribute_ms: 100_000,
                        replication: 2,
                        quorum: 2,
                        ..StoreConfig::default()
                    };
                    let s = ($make)(cfg);
                    let ids = s.create_tickets(TaskId(1), "t", args(1), 0);
                    let _ = s.next_ticket("c1", 0).unwrap();
                    let _ = s.next_ticket("c2", 1).unwrap();
                    assert_eq!(s.release_batch_from("c1", &ids), vec![true]);
                    // Still in flight for c2, and the freed slot is
                    // immediately re-recruitable — but never by c2.
                    assert_eq!(s.progress(None).in_flight, 1);
                    assert!(s.next_ticket("c2", 2).is_none(), "exclusion survives release");
                    let t = s.next_ticket("c3", 2).unwrap();
                    assert_eq!(t.id, ids[0]);
                    // Releasing a client that holds nothing is a no-op.
                    assert_eq!(s.release_batch_from("c1", &ids), vec![false]);
                }

                /// The drained-error queue is capped: an error flood
                /// stops growing the buffer at ERROR_QUEUE_CAP, while
                /// the cumulative count and the requeue side-effect
                /// still apply to every report.
                #[test]
                fn error_queue_is_capped_under_flood() {
                    let s = store(1_000_000, 1_000_000);
                    let ids = s.create_tickets(TaskId(1), "t", args(1), 0);
                    let n = crate::store::ERROR_QUEUE_CAP + 50;
                    for i in 0..n {
                        s.report_error(ids[0], format!("e{i}")).unwrap();
                    }
                    assert_eq!(s.error_count(), n, "cumulative count sees every report");
                    let drained = s.drain_errors();
                    assert_eq!(drained.len(), crate::store::ERROR_QUEUE_CAP);
                    assert_eq!(drained[0].1, "e0", "oldest reports are kept, overflow dropped");
                    let st = s.stats();
                    if st.dispatch_shards > 0 {
                        assert_eq!(st.errors_dropped, 50);
                    }
                    // Drain freed the buffer: new reports are kept again.
                    s.report_error(ids[0], "fresh".into()).unwrap();
                    assert_eq!(s.drain_errors().len(), 1);
                }

                #[test]
                fn drain_errors_empties_buffer_not_count() {
                    let s = store(1000, 100);
                    let ids = s.create_tickets(TaskId(1), "t", args(2), 0);
                    let _ = s.next_ticket("c", 0);
                    let _ = s.next_ticket("c", 1);
                    s.report_error(ids[0], "a".into()).unwrap();
                    s.report_error(ids[1], "b".into()).unwrap();
                    assert_eq!(s.error_count(), 2);
                    let drained = s.drain_errors();
                    assert_eq!(drained.len(), 2);
                    assert_eq!(drained[0].0, ids[0]);
                    assert!(s.drain_errors().is_empty());
                    // The console's cumulative counter is unaffected.
                    assert_eq!(s.error_count(), 2);
                    assert_eq!(s.progress(None).errors, 2);
                }
            }
        };
    }

    scheduler_suite!(indexed, |cfg| Box::new(IndexedStore::new(cfg)) as Box<dyn Scheduler>);
    scheduler_suite!(naive_reference, |cfg| Box::new(NaiveStore::new(cfg)) as Box<dyn Scheduler>);
    // The durable backend must preserve the exact §2.1.2 semantics while
    // logging every mutation (each case writes to a throwaway state dir).
    scheduler_suite!(wal_logged, |cfg| {
        Box::new(crate::store::wal::WalStore::open_temp_for_tests(cfg)) as Box<dyn Scheduler>
    });
}
