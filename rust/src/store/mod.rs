//! Ticket store — the MySQL substitute, with the paper's exact
//! redistribution semantics (§2.1.2).
//!
//! The paper keeps tickets in a MySQL table and selects them in
//! ascending order of **virtual created time** (VCT):
//!
//! * undistributed ticket → VCT = creation time;
//! * distributed ticket   → VCT = distribution time + 5 minutes;
//! * redistributed ticket → VCT = *last* distribution time + 5 minutes.
//!
//! So a ticket whose result has not returned within 5 minutes looks
//! freshly created again and gets re-issued.  Two extra rules: when no
//! ticket's VCT has arrived yet (everything is recently in flight),
//! tickets are redistributed in ascending order of distribution time —
//! but each at least `min_redistribute` apart, so the *last* ticket of a
//! task is not blasted to every idle client at once.
//!
//! Both timeouts are configurable ([`StoreConfig`]); the defaults are the
//! paper's 5 min / 10 s, and benches scale them down with the modelled
//! clock.  The invariants (no lost tickets, first result wins, ordered
//! collection) are property-tested in `rust/tests/properties.rs`.

pub mod ticket;

pub use ticket::{Ticket, TicketId, TicketStatus};

use std::collections::BTreeMap;
use std::sync::{Condvar, Mutex};

use anyhow::{bail, Result};

use crate::util::json::Value;

/// Task identifier within a running framework.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TaskId(pub u64);

#[derive(Debug, Clone)]
pub struct StoreConfig {
    /// Re-issue a ticket if no result within this window (paper: 5 min).
    pub requeue_after_ms: u64,
    /// Minimum interval between redistributions of one ticket (paper: 10 s).
    pub min_redistribute_ms: u64,
    /// On worker error reports, immediately return the ticket to the
    /// undistributed pool instead of waiting out the timeout.
    pub requeue_on_error: bool,
}

impl Default for StoreConfig {
    fn default() -> Self {
        Self { requeue_after_ms: 300_000, min_redistribute_ms: 10_000, requeue_on_error: true }
    }
}

/// Counters surfaced on the control console (paper: project name, #tasks,
/// #waiting tickets, #executed tickets, #error reports, client info).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Progress {
    pub total: usize,
    pub pending: usize,
    pub in_flight: usize,
    pub done: usize,
    pub errors: usize,
    pub redistributions: u64,
    pub duplicate_results: u64,
}

#[derive(Debug, Default)]
struct Inner {
    tickets: BTreeMap<TicketId, Ticket>,
    next_ticket: u64,
    errors: Vec<(TicketId, String)>,
    redistributions: u64,
    duplicate_results: u64,
    /// FIFO of accepted results, consumed by streaming drivers (the
    /// hybrid trainer reacts to each client's features as they arrive,
    /// §4 "learned concurrently").
    completions: std::collections::VecDeque<(TaskId, usize, Value)>,
}

/// Thread-safe ticket store shared by the distributor and the framework.
pub struct TicketStore {
    cfg: StoreConfig,
    inner: Mutex<Inner>,
    /// Signalled on completions so `block()` can wait without polling.
    done_cv: Condvar,
}

impl TicketStore {
    pub fn new(cfg: StoreConfig) -> Self {
        Self { cfg, inner: Mutex::new(Inner::default()), done_cv: Condvar::new() }
    }

    pub fn config(&self) -> &StoreConfig {
        &self.cfg
    }

    /// Create tickets for a task's divided arguments; returns their ids.
    pub fn create_tickets(&self, task: TaskId, task_name: &str, args: Vec<Value>, now_ms: u64) -> Vec<TicketId> {
        let mut inner = self.inner.lock().unwrap();
        let mut ids = Vec::with_capacity(args.len());
        for (index, payload) in args.into_iter().enumerate() {
            let id = TicketId(inner.next_ticket);
            inner.next_ticket += 1;
            inner.tickets.insert(
                id,
                Ticket {
                    id,
                    task,
                    task_name: task_name.to_string(),
                    index,
                    payload,
                    created_ms: now_ms,
                    status: TicketStatus::Pending,
                    last_distributed_ms: None,
                    distribution_count: 0,
                    result: None,
                    assigned_to: None,
                },
            );
            ids.push(id);
        }
        ids
    }

    /// Virtual created time of a ticket (the paper's ordering key).
    fn vct(&self, t: &Ticket) -> u64 {
        match t.last_distributed_ms {
            None => t.created_ms,
            Some(d) => d + self.cfg.requeue_after_ms,
        }
    }

    /// The SQL `SELECT ... ORDER BY vct LIMIT 1` equivalent: pick the
    /// next ticket for `client` at `now_ms`, marking it distributed.
    pub fn next_ticket(&self, client: &str, now_ms: u64) -> Option<Ticket> {
        let mut inner = self.inner.lock().unwrap();
        // Primary: minimum VCT among candidates whose VCT has arrived.
        let pick = inner
            .tickets
            .values()
            .filter(|t| t.status != TicketStatus::Done)
            .filter(|t| self.vct(t) <= now_ms)
            .min_by_key(|t| (self.vct(t), t.id.0))
            .map(|t| t.id);
        // Fallback: nothing due -> redistribute the longest-in-flight
        // ticket, provided it was not distributed in the last
        // min_redistribute window (the paper's 10 s rule).
        let pick = pick.or_else(|| {
            inner
                .tickets
                .values()
                .filter(|t| t.status != TicketStatus::Done)
                .filter(|t| {
                    t.last_distributed_ms
                        .map(|d| now_ms.saturating_sub(d) >= self.cfg.min_redistribute_ms)
                        .unwrap_or(true)
                })
                .min_by_key(|t| (t.last_distributed_ms.unwrap_or(0), t.id.0))
                .map(|t| t.id)
        });
        let id = pick?;
        let redistribution = {
            let t = inner.tickets.get(&id).unwrap();
            t.distribution_count > 0
        };
        if redistribution {
            inner.redistributions += 1;
        }
        let t = inner.tickets.get_mut(&id).unwrap();
        t.status = TicketStatus::InFlight;
        t.last_distributed_ms = Some(now_ms);
        t.distribution_count += 1;
        t.assigned_to = Some(client.to_string());
        Some(t.clone())
    }

    /// Record a result.  First result wins; duplicates (a slow client
    /// returning a redistributed ticket) are counted and dropped.
    pub fn complete(&self, id: TicketId, result: Value) -> Result<bool> {
        let mut inner = self.inner.lock().unwrap();
        let t = match inner.tickets.get_mut(&id) {
            Some(t) => t,
            None => bail!("unknown ticket {id:?}"),
        };
        if t.status == TicketStatus::Done {
            inner.duplicate_results += 1;
            return Ok(false);
        }
        t.status = TicketStatus::Done;
        t.result = Some(result.clone());
        let (task, index) = (t.task, t.index);
        inner.completions.push_back((task, index, result));
        self.done_cv.notify_all();
        Ok(true)
    }

    /// Pop the next accepted result for `task` (FIFO in completion
    /// order), waiting up to `timeout_ms`.  Streaming counterpart of
    /// [`wait_results`].
    pub fn next_completion(&self, task: TaskId, timeout_ms: u64) -> Option<(usize, Value)> {
        let deadline = std::time::Instant::now() + std::time::Duration::from_millis(timeout_ms);
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(pos) = inner.completions.iter().position(|(t, _, _)| *t == task) {
                let (_, index, value) = inner.completions.remove(pos).unwrap();
                return Some((index, value));
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self.done_cv.wait_timeout(inner, deadline - now).unwrap();
            inner = guard;
        }
    }

    /// Record a worker error report; optionally requeue immediately.
    pub fn report_error(&self, id: TicketId, report: String) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        inner.errors.push((id, report));
        let requeue = self.cfg.requeue_on_error;
        if let Some(t) = inner.tickets.get_mut(&id) {
            if t.status == TicketStatus::InFlight && requeue {
                t.status = TicketStatus::Pending;
                t.last_distributed_ms = None; // VCT back to creation time
            }
        }
        Ok(())
    }

    pub fn progress(&self, task: Option<TaskId>) -> Progress {
        let inner = self.inner.lock().unwrap();
        let mut p = Progress {
            redistributions: inner.redistributions,
            duplicate_results: inner.duplicate_results,
            errors: inner.errors.len(),
            ..Default::default()
        };
        for t in inner.tickets.values() {
            if task.map(|id| t.task == id).unwrap_or(true) {
                p.total += 1;
                match t.status {
                    TicketStatus::Pending => p.pending += 1,
                    TicketStatus::InFlight => p.in_flight += 1,
                    TicketStatus::Done => p.done += 1,
                }
            }
        }
        p
    }

    pub fn is_task_done(&self, task: TaskId) -> bool {
        let inner = self.inner.lock().unwrap();
        inner
            .tickets
            .values()
            .filter(|t| t.task == task)
            .all(|t| t.status == TicketStatus::Done)
    }

    /// Block until every ticket of `task` is done (condvar, no polling),
    /// then return results ordered by ticket index — the framework's
    /// `task.block(callback)` from the appendix sample.
    pub fn wait_results(&self, task: TaskId) -> Vec<Value> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            let all_done = inner
                .tickets
                .values()
                .filter(|t| t.task == task)
                .all(|t| t.status == TicketStatus::Done);
            if all_done {
                let mut rows: Vec<(usize, Value)> = inner
                    .tickets
                    .values()
                    .filter(|t| t.task == task)
                    .map(|t| (t.index, t.result.clone().unwrap()))
                    .collect();
                rows.sort_by_key(|(i, _)| *i);
                return rows.into_iter().map(|(_, v)| v).collect();
            }
            inner = self.done_cv.wait(inner).unwrap();
        }
    }

    /// Non-blocking variant with timeout; None on timeout.
    pub fn wait_results_timeout(&self, task: TaskId, timeout_ms: u64) -> Option<Vec<Value>> {
        let deadline = std::time::Instant::now() + std::time::Duration::from_millis(timeout_ms);
        let mut inner = self.inner.lock().unwrap();
        loop {
            let all_done = inner
                .tickets
                .values()
                .filter(|t| t.task == task)
                .all(|t| t.status == TicketStatus::Done);
            if all_done {
                let mut rows: Vec<(usize, Value)> = inner
                    .tickets
                    .values()
                    .filter(|t| t.task == task)
                    .map(|t| (t.index, t.result.clone().unwrap()))
                    .collect();
                rows.sort_by_key(|(i, _)| *i);
                return Some(rows.into_iter().map(|(_, v)| v).collect());
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self.done_cv.wait_timeout(inner, deadline - now).unwrap();
            inner = guard;
        }
    }

    pub fn errors(&self) -> Vec<(TicketId, String)> {
        self.inner.lock().unwrap().errors.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(requeue_ms: u64, min_redist: u64) -> TicketStore {
        TicketStore::new(StoreConfig {
            requeue_after_ms: requeue_ms,
            min_redistribute_ms: min_redist,
            requeue_on_error: true,
        })
    }

    fn args(n: usize) -> Vec<Value> {
        (0..n).map(|i| Value::num(i as f64)).collect()
    }

    #[test]
    fn fifo_by_creation_time() {
        let s = store(1000, 100);
        s.create_tickets(TaskId(1), "t", args(3), 10);
        let a = s.next_ticket("c1", 20).unwrap();
        let b = s.next_ticket("c1", 21).unwrap();
        assert_eq!(a.index, 0);
        assert_eq!(b.index, 1);
    }

    #[test]
    fn inflight_ticket_not_reissued_before_timeout() {
        let s = store(1000, 100);
        s.create_tickets(TaskId(1), "t", args(1), 0);
        let t = s.next_ticket("c1", 0).unwrap();
        // Before timeout and within min_redistribute: nothing for c2.
        assert!(s.next_ticket("c2", 50).is_none());
        // After min_redistribute (fallback path): redistribute.
        let again = s.next_ticket("c2", 150).unwrap();
        assert_eq!(again.id, t.id);
        assert_eq!(again.distribution_count, 2);
    }

    #[test]
    fn timeout_reissues_via_vct() {
        let s = store(1000, 10_000); // min_redistribute large: only VCT path
        s.create_tickets(TaskId(1), "t", args(1), 0);
        let t = s.next_ticket("c1", 0).unwrap();
        assert!(s.next_ticket("c2", 999).is_none());
        let again = s.next_ticket("c2", 1001).unwrap();
        assert_eq!(again.id, t.id);
    }

    #[test]
    fn first_result_wins_duplicates_counted() {
        let s = store(100, 10);
        let ids = s.create_tickets(TaskId(1), "t", args(1), 0);
        let _ = s.next_ticket("c1", 0).unwrap();
        let _ = s.next_ticket("c2", 200).unwrap(); // redistributed
        assert!(s.complete(ids[0], Value::num(1.0)).unwrap());
        assert!(!s.complete(ids[0], Value::num(2.0)).unwrap());
        let p = s.progress(None);
        assert_eq!(p.done, 1);
        assert_eq!(p.duplicate_results, 1);
        // First result is what block() sees.
        assert_eq!(s.wait_results(TaskId(1)), vec![Value::num(1.0)]);
    }

    #[test]
    fn error_requeues_immediately() {
        let s = store(1_000_000, 1_000_000);
        let ids = s.create_tickets(TaskId(1), "t", args(1), 0);
        let _ = s.next_ticket("c1", 0).unwrap();
        s.report_error(ids[0], "boom".into()).unwrap();
        // Eligible right away despite huge timeouts.
        let t = s.next_ticket("c2", 1).unwrap();
        assert_eq!(t.id, ids[0]);
        assert_eq!(s.progress(None).errors, 1);
    }

    #[test]
    fn results_ordered_by_index() {
        let s = store(1000, 100);
        let ids = s.create_tickets(TaskId(7), "t", args(3), 0);
        // Complete out of order.
        for i in [2usize, 0, 1] {
            let _ = s.next_ticket("c", i as u64);
            s.complete(ids[i], Value::num(i as f64 * 10.0)).unwrap();
        }
        let r = s.wait_results(TaskId(7));
        assert_eq!(r, vec![Value::num(0.0), Value::num(10.0), Value::num(20.0)]);
    }

    #[test]
    fn min_redistribute_rate_limits_last_ticket() {
        // The 10 s rule: an in-flight last ticket is not handed to every
        // idle client at once.
        let s = store(100_000, 50);
        s.create_tickets(TaskId(1), "t", args(1), 0);
        let _ = s.next_ticket("c1", 0).unwrap();
        assert!(s.next_ticket("c2", 10).is_none());
        assert!(s.next_ticket("c3", 49).is_none());
        assert!(s.next_ticket("c4", 50).is_some());
        // Fresh redistribution resets the window.
        assert!(s.next_ticket("c5", 60).is_none());
    }

    #[test]
    fn progress_by_task() {
        let s = store(1000, 100);
        s.create_tickets(TaskId(1), "a", args(2), 0);
        let ids = s.create_tickets(TaskId(2), "b", args(1), 0);
        s.next_ticket("c", 0);
        let _ = s.complete(ids[0], Value::Null).unwrap();
        let p1 = s.progress(Some(TaskId(1)));
        assert_eq!(p1.total, 2);
        let p2 = s.progress(Some(TaskId(2)));
        assert_eq!(p2.done, 1);
    }

    #[test]
    fn wait_with_timeout_returns_none_if_incomplete() {
        let s = store(1000, 100);
        s.create_tickets(TaskId(1), "t", args(1), 0);
        assert!(s.wait_results_timeout(TaskId(1), 30).is_none());
    }

    #[test]
    fn completions_stream_in_fifo_order() {
        let s = store(1000, 100);
        let ids = s.create_tickets(TaskId(1), "t", args(3), 0);
        let _ = s.next_ticket("c", 0);
        s.complete(ids[1], Value::num(1.0)).unwrap();
        s.complete(ids[0], Value::num(0.0)).unwrap();
        assert_eq!(s.next_completion(TaskId(1), 10), Some((1, Value::num(1.0))));
        assert_eq!(s.next_completion(TaskId(1), 10), Some((0, Value::num(0.0))));
        assert_eq!(s.next_completion(TaskId(1), 10), None); // third not done
        // Completions are task-scoped.
        let other = s.create_tickets(TaskId(2), "u", args(1), 0);
        s.complete(other[0], Value::Bool(true)).unwrap();
        s.complete(ids[2], Value::num(2.0)).unwrap();
        assert_eq!(s.next_completion(TaskId(2), 10), Some((0, Value::Bool(true))));
        assert_eq!(s.next_completion(TaskId(1), 10), Some((2, Value::num(2.0))));
    }

    #[test]
    fn unknown_ticket_completion_is_error() {
        let s = store(1000, 100);
        assert!(s.complete(TicketId(99), Value::Null).is_err());
    }
}
