//! # Sashimi / Sukiyaki — volunteer-grid distributed deep learning
//!
//! A reproduction of *"Implementation of a Practical Distributed
//! Calculation System with Browsers and JavaScript, and Application to
//! Distributed Deep Learning"* (Miura & Harada, 2015) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the Sashimi coordination system: a
//!   [`coordinator`] running projects/tasks/tickets, a [`store`] with the
//!   paper's virtual-created-time redistribution policy (durable behind
//!   a write-ahead log, [`store::wal`], as the paper's MySQL was), a
//!   [`transport`] layer (JSON-lines TCP and in-process), and [`worker`]
//!   nodes that replay the browser loop of §2.1.2.  The distributed
//!   deep-learning algorithms of §4 live in [`dist`]; [`sim`] soaks the
//!   whole coordinator under deterministic fleet-scale churn on a
//!   virtual clock.
//! * **L2/L1 (build time)** — `python/compile` lowers the Sukiyaki CNNs
//!   (whose hot paths are Pallas kernels) to HLO text; the [`runtime`]
//!   module loads and executes those artifacts through PJRT.  Python is
//!   never on the request path.
//!
//! See `DESIGN.md` for the paper → module map and `EXPERIMENTS.md` for
//! the reproduced tables and figures.

pub mod coordinator;
pub mod data;
pub mod dist;
pub mod nn;
pub mod runtime;
pub mod sim;
pub mod store;
pub mod tasks;
pub mod transport;
pub mod util;
pub mod worker;

pub use anyhow::{anyhow, bail, Context, Result};
