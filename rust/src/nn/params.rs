//! Named parameter sets in the canonical cross-language ordering.
//!
//! Ordering and shapes come from the manifest's `NetSpec` (which mirrors
//! `model.py`), so a `ParamSet` can be flattened straight into an
//! artifact's input list and rebuilt from its output list without any
//! permutation logic anywhere else.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::runtime::{NetSpec, Tensor};
use crate::util::rng::SplitMix64;

/// An ordered, named set of tensors (parameters, accumulators or
/// gradients — same structure for all three).
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSet {
    names: Vec<String>,
    tensors: BTreeMap<String, Tensor>,
}

impl ParamSet {
    /// Zero-initialised set with the net's parameter shapes (used for
    /// AdaGrad accumulators and gradient accumulation buffers).
    pub fn zeros(net: &NetSpec) -> ParamSet {
        let mut tensors = BTreeMap::new();
        for n in &net.param_names {
            tensors.insert(n.clone(), Tensor::zeros(&net.param_shapes[n]));
        }
        ParamSet { names: net.param_names.clone(), tensors }
    }

    /// LeCun-style uniform init: w ~ U[-1/sqrt(fan_in), 1/sqrt(fan_in)],
    /// biases zero.  Both engines (XLA and ConvNetJS-naive) initialise
    /// through this so Table 4 / Fig 3 start from identical weights.
    pub fn init(net: &NetSpec, rng: &mut SplitMix64) -> ParamSet {
        let mut tensors = BTreeMap::new();
        for n in &net.param_names {
            let shape = &net.param_shapes[n];
            let t = if n.ends_with("_b") {
                Tensor::zeros(shape)
            } else {
                let fan_in = shape[0] as f32;
                Tensor::uniform(shape, rng, 1.0 / fan_in.sqrt())
            };
            tensors.insert(n.clone(), t);
        }
        ParamSet { names: net.param_names.clone(), tensors }
    }

    /// Build from explicit (name, tensor) pairs in the given order.
    pub fn from_pairs(pairs: Vec<(String, Tensor)>) -> ParamSet {
        let names = pairs.iter().map(|(n, _)| n.clone()).collect();
        ParamSet { names, tensors: pairs.into_iter().collect() }
    }

    /// Restrict to the conv-stack parameters (the hybrid client's share).
    pub fn conv_subset(&self, net: &NetSpec) -> ParamSet {
        let names: Vec<String> = net.conv_param_names().to_vec();
        let tensors = names.iter().map(|n| (n.clone(), self.tensors[n].clone())).collect();
        ParamSet { names, tensors }
    }

    /// Restrict to the FC parameters (the hybrid server's share).
    pub fn fc_subset(&self) -> ParamSet {
        let names: Vec<String> = self.names.iter().filter(|n| n.starts_with("fc_")).cloned().collect();
        let tensors = names.iter().map(|n| (n.clone(), self.tensors[n].clone())).collect();
        ParamSet { names, tensors }
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).ok_or_else(|| anyhow::anyhow!("no parameter {name:?}"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        self.tensors.get_mut(name).ok_or_else(|| anyhow::anyhow!("no parameter {name:?}"))
    }

    pub fn set(&mut self, name: &str, t: Tensor) -> Result<()> {
        if !self.tensors.contains_key(name) {
            bail!("no parameter {name:?}");
        }
        self.tensors.insert(name.to_string(), t);
        Ok(())
    }

    /// Tensors in canonical order — exactly the artifact argument order.
    pub fn ordered(&self) -> Vec<Tensor> {
        self.names.iter().map(|n| self.tensors[n].clone()).collect()
    }

    /// Replace all tensors from an artifact's output slice (same order).
    pub fn update_from(&mut self, outputs: &[Tensor]) -> Result<()> {
        if outputs.len() != self.names.len() {
            bail!("expected {} tensors, got {}", self.names.len(), outputs.len());
        }
        for (n, t) in self.names.iter().zip(outputs) {
            let cur = &self.tensors[n];
            if cur.shape() != t.shape() {
                bail!("{n}: shape {:?} -> {:?} mismatch", cur.shape(), t.shape());
            }
            self.tensors.insert(n.clone(), t.clone());
        }
        Ok(())
    }

    /// Merge another set's tensors for the names it has (hybrid: fold the
    /// server-trained FC params back into the full set).
    pub fn merge(&mut self, other: &ParamSet) -> Result<()> {
        for n in &other.names {
            if !self.tensors.contains_key(n) {
                bail!("merge: unknown parameter {n:?}");
            }
            self.tensors.insert(n.clone(), other.tensors[n].clone());
        }
        Ok(())
    }

    /// In-place axpy over the whole set: self += alpha * other.
    pub fn axpy(&mut self, alpha: f32, other: &ParamSet) -> Result<()> {
        if self.names != other.names {
            bail!("axpy over mismatched param sets");
        }
        for n in &self.names {
            let o = other.tensors[n].clone();
            self.tensors.get_mut(n).unwrap().axpy(alpha, &o)?;
        }
        Ok(())
    }

    pub fn scale(&mut self, s: f32) {
        for t in self.tensors.values_mut() {
            t.scale(s);
        }
    }

    pub fn total_elements(&self) -> usize {
        self.tensors.values().map(|t| t.len()).sum()
    }

    pub fn size_bytes(&self) -> usize {
        self.total_elements() * 4
    }

    /// Global L2 norm across all tensors.
    pub fn norm(&self) -> f32 {
        self.tensors.values().map(|t| {
            let n = t.norm();
            n * n
        }).sum::<f32>().sqrt()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Tensor)> {
        self.names.iter().map(move |n| (n, &self.tensors[n]))
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::runtime::artifact::ConvLayerSpec;

    /// A miniature NetSpec for unit tests that don't need artifacts.
    pub fn tiny_net() -> NetSpec {
        let mut param_shapes = BTreeMap::new();
        param_shapes.insert("conv1_w".into(), vec![25, 4]);
        param_shapes.insert("conv1_b".into(), vec![4]);
        param_shapes.insert("fc_w".into(), vec![64, 3]);
        param_shapes.insert("fc_b".into(), vec![3]);
        NetSpec {
            name: "tiny".into(),
            input_hw: 8,
            input_c: 1,
            batch: 2,
            n_classes: 3,
            fc_in: 64,
            convs: vec![ConvLayerSpec { kh: 5, kw: 5, cin: 1, cout: 4, pad: 2 }],
            param_names: vec!["conv1_w".into(), "conv1_b".into(), "fc_w".into(), "fc_b".into()],
            param_shapes,
            lr: 0.01,
            beta: 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::tiny_net;
    use super::*;

    #[test]
    fn init_shapes_and_bias_zero() {
        let net = tiny_net();
        let p = ParamSet::init(&net, &mut SplitMix64::new(1));
        assert_eq!(p.get("conv1_w").unwrap().shape(), &[25, 4]);
        assert!(p.get("conv1_b").unwrap().data().iter().all(|&v| v == 0.0));
        let w = p.get("fc_w").unwrap();
        let bound = 1.0 / (64f32).sqrt() + 1e-6;
        assert!(w.data().iter().all(|&v| v.abs() <= bound));
    }

    #[test]
    fn ordered_matches_canonical_order() {
        let net = tiny_net();
        let p = ParamSet::init(&net, &mut SplitMix64::new(2));
        let v = p.ordered();
        assert_eq!(v.len(), 4);
        assert_eq!(v[0].shape(), &[25, 4]); // conv1_w first, not BTreeMap order
        assert_eq!(v[3].shape(), &[3]);
    }

    #[test]
    fn update_from_roundtrip() {
        let net = tiny_net();
        let mut p = ParamSet::init(&net, &mut SplitMix64::new(3));
        let mut outs = p.ordered();
        outs[0].data_mut()[0] = 42.0;
        p.update_from(&outs).unwrap();
        assert_eq!(p.get("conv1_w").unwrap().data()[0], 42.0);
        outs.pop();
        assert!(p.update_from(&outs).is_err());
    }

    #[test]
    fn subsets_and_merge() {
        let net = tiny_net();
        let mut p = ParamSet::init(&net, &mut SplitMix64::new(4));
        let conv = p.conv_subset(&net);
        assert_eq!(conv.names(), &["conv1_w", "conv1_b"]);
        let mut fc = p.fc_subset();
        assert_eq!(fc.names(), &["fc_w", "fc_b"]);
        fc.get_mut("fc_b").unwrap().data_mut()[0] = 9.0;
        p.merge(&fc).unwrap();
        assert_eq!(p.get("fc_b").unwrap().data()[0], 9.0);
    }

    #[test]
    fn axpy_accumulates_gradients() {
        let net = tiny_net();
        let mut acc = ParamSet::zeros(&net);
        let mut g = ParamSet::zeros(&net);
        g.get_mut("fc_b").unwrap().data_mut()[1] = 2.0;
        acc.axpy(0.5, &g).unwrap();
        assert_eq!(acc.get("fc_b").unwrap().data()[1], 1.0);
        acc.scale(2.0);
        assert_eq!(acc.get("fc_b").unwrap().data()[1], 2.0);
    }

    #[test]
    fn init_is_deterministic() {
        let net = tiny_net();
        let a = ParamSet::init(&net, &mut SplitMix64::new(7));
        let b = ParamSet::init(&net, &mut SplitMix64::new(7));
        assert_eq!(a, b);
    }
}
