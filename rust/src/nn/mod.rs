//! Sukiyaki (L3 side): parameter state, AdaGrad-β, model files, the two
//! training engines, and metrics.
//!
//! The heavy math lives in the AOT artifacts (L2/L1); this module owns
//! everything the coordinator touches directly:
//!
//! * [`params`] — named parameter/accumulator sets in the canonical
//!   ordering shared with `python/compile/model.py`;
//! * [`model_file`] — the paper's JSON + base64 model interchange format
//!   (§3.1: platform-independent, no rounding errors);
//! * [`adagrad`] — a native AdaGrad-β used by the hybrid server to apply
//!   *aggregated* conv gradients (everything else updates inside the
//!   artifacts);
//! * [`convnetjs`] — the faithful single-threaded scalar baseline
//!   standing in for ConvNetJS in Table 4 / Fig 3;
//! * [`engine`] — one `TrainEngine` interface over the XLA artifact
//!   engine (Sukiyaki) and the naive engine (ConvNetJS);
//! * [`metrics`] — error rate, loss curves.

pub mod adagrad;
pub mod convnetjs;
pub mod engine;
pub mod metrics;
pub mod model_file;
pub mod params;

pub use engine::{NativeEngine, TrainEngine, XlaEngine};
pub use params::ParamSet;
