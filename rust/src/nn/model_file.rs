//! The paper's model interchange format (§3.1): JSON with base64-encoded
//! parameters — "a platform independent string format ... exchanged among
//! machines without rounding errors".
//!
//! Layout:
//! ```json
//! {
//!   "format": 1,
//!   "net": "cifar",
//!   "step": 1200,
//!   "params":  { "conv1_w": {"shape": [75,16], "data": "<base64 LE f32>"}, ... },
//!   "accums":  { ... same structure, optional ... }
//! }
//! ```
//! Tensor bytes are little-endian f32, so round-trips are bit-exact
//! (tested below with NaN payloads and ±0).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::nn::params::ParamSet;
use crate::runtime::Tensor;
use crate::util::base64;
use crate::util::json::Value;

#[derive(Debug, Clone)]
pub struct ModelFile {
    pub net: String,
    pub step: u64,
    pub params: ParamSet,
    pub accums: Option<ParamSet>,
}

fn set_to_json(set: &ParamSet) -> Value {
    let mut obj = BTreeMap::new();
    for (name, t) in set.iter() {
        obj.insert(
            name.clone(),
            Value::obj(vec![
                ("shape", Value::arr(t.shape().iter().map(|&d| Value::num(d as f64)))),
                ("data", Value::str(base64::encode_f32(t.data()))),
            ]),
        );
    }
    Value::Obj(obj)
}

fn set_from_json(v: &Value, order_hint: &[String]) -> Result<ParamSet> {
    let obj = v.as_obj()?;
    // Preserve canonical order if the hint covers the keys, else sorted.
    let names: Vec<String> = if !order_hint.is_empty()
        && order_hint.iter().all(|n| obj.contains_key(n))
        && obj.len() == order_hint.len()
    {
        order_hint.to_vec()
    } else {
        obj.keys().cloned().collect()
    };
    let mut pairs = Vec::new();
    for n in names {
        let e = &obj[&n];
        let shape = e.get("shape")?.as_usize_vec()?;
        let data = base64::decode_f32(e.get("data")?.as_str()?)
            .with_context(|| format!("decoding parameter {n:?}"))?;
        pairs.push((n, Tensor::new(shape, data)?));
    }
    Ok(ParamSet::from_pairs(pairs))
}

impl ModelFile {
    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("format", Value::num(1.0)),
            ("net", Value::str(self.net.clone())),
            ("step", Value::num(self.step as f64)),
            ("params", set_to_json(&self.params)),
        ];
        if let Some(a) = &self.accums {
            fields.push(("accums", set_to_json(a)));
        }
        Value::obj(fields)
    }

    pub fn to_string(&self) -> String {
        self.to_json().to_string()
    }

    pub fn parse(text: &str, order_hint: &[String]) -> Result<ModelFile> {
        let v = Value::parse(text)?;
        let format = v.get("format")?.as_usize()?;
        if format != 1 {
            bail!("unsupported model file format {format}");
        }
        Ok(ModelFile {
            net: v.get("net")?.as_str()?.to_string(),
            step: v.get("step")?.as_u64()?,
            params: set_from_json(v.get("params")?, order_hint)?,
            accums: match v.opt("accums") {
                Some(a) => Some(set_from_json(a, order_hint)?),
                None => None,
            },
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_string()).with_context(|| format!("writing {path:?}"))
    }

    pub fn load(path: &Path, order_hint: &[String]) -> Result<ModelFile> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        Self::parse(&text, order_hint)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::params::test_support::tiny_net;
    use crate::util::rng::SplitMix64;

    #[test]
    fn roundtrip_is_bit_exact() {
        let net = tiny_net();
        let mut params = ParamSet::init(&net, &mut SplitMix64::new(1));
        // Plant exact-bit hazards.
        params.get_mut("fc_b").unwrap().data_mut()[0] = f32::NAN;
        params.get_mut("fc_b").unwrap().data_mut()[1] = -0.0;
        let mf = ModelFile { net: "tiny".into(), step: 42, params: params.clone(), accums: None };
        let back = ModelFile::parse(&mf.to_string(), &net.param_names).unwrap();
        assert_eq!(back.net, "tiny");
        assert_eq!(back.step, 42);
        for (n, t) in params.iter() {
            let b = back.params.get(n).unwrap();
            assert_eq!(t.shape(), b.shape());
            for (x, y) in t.data().iter().zip(b.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{n}");
            }
        }
    }

    #[test]
    fn accums_roundtrip() {
        let net = tiny_net();
        let params = ParamSet::init(&net, &mut SplitMix64::new(2));
        let mut accums = ParamSet::zeros(&net);
        accums.get_mut("conv1_w").unwrap().data_mut()[3] = 0.5;
        let mf = ModelFile { net: "tiny".into(), step: 0, params, accums: Some(accums.clone()) };
        let back = ModelFile::parse(&mf.to_string(), &net.param_names).unwrap();
        assert_eq!(back.accums.unwrap().get("conv1_w").unwrap().data()[3], 0.5);
    }

    #[test]
    fn canonical_order_preserved() {
        let net = tiny_net();
        let params = ParamSet::init(&net, &mut SplitMix64::new(3));
        let mf = ModelFile { net: "tiny".into(), step: 0, params, accums: None };
        let back = ModelFile::parse(&mf.to_string(), &net.param_names).unwrap();
        assert_eq!(back.params.names(), net.param_names.as_slice());
    }

    #[test]
    fn rejects_future_format() {
        let text = r#"{"format": 2, "net": "x", "step": 0, "params": {}}"#;
        assert!(ModelFile::parse(text, &[]).is_err());
    }

    #[test]
    fn save_and_load_file() {
        let net = tiny_net();
        let params = ParamSet::init(&net, &mut SplitMix64::new(4));
        let mf = ModelFile { net: "tiny".into(), step: 7, params, accums: None };
        let dir = std::env::temp_dir().join("sashimi_model_file_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.json");
        mf.save(&path).unwrap();
        let back = ModelFile::load(&path, &net.param_names).unwrap();
        assert_eq!(back.step, 7);
        std::fs::remove_dir_all(&dir).ok();
    }
}
