//! Native AdaGrad-β (the paper's §3.1 update rule), used where the
//! gradient was produced *outside* an artifact — i.e. the hybrid server
//! applying aggregated conv gradients, and the ConvNetJS-naive engine.
//!
//! Must agree numerically with the Pallas kernel
//! (`python/compile/kernels/adagrad.py`); the golden artifact
//! `adagrad_update` pins both against the same checksums, and a unit
//! test here checks the closed form directly.

use anyhow::Result;

use crate::nn::params::ParamSet;
use crate::runtime::Tensor;

/// θ' = θ - lr * g / sqrt(β + G + g²);  G' = G + g².
pub fn update_tensor(theta: &mut Tensor, accum: &mut Tensor, grad: &Tensor, lr: f32, beta: f32) -> Result<()> {
    anyhow::ensure!(
        theta.shape() == accum.shape() && theta.shape() == grad.shape(),
        "adagrad shape mismatch: {:?} / {:?} / {:?}",
        theta.shape(),
        accum.shape(),
        grad.shape()
    );
    let t = theta.data_mut();
    let a = accum.data_mut();
    let g = grad.data();
    for i in 0..t.len() {
        let gi = g[i];
        let acc = a[i] + gi * gi;
        a[i] = acc;
        t[i] -= lr * gi / (beta + acc).sqrt();
    }
    Ok(())
}

/// Apply one step across a whole parameter set.
pub fn update_set(params: &mut ParamSet, accums: &mut ParamSet, grads: &ParamSet, lr: f32, beta: f32) -> Result<()> {
    let names: Vec<String> = params.names().to_vec();
    for n in &names {
        let g = grads.get(n)?.clone();
        let mut t = params.get(n)?.clone();
        let mut a = accums.get(n)?.clone();
        update_tensor(&mut t, &mut a, &g, lr, beta)?;
        params.set(n, t)?;
        accums.set(n, a)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::params::test_support::tiny_net;
    use crate::util::rng::SplitMix64;

    #[test]
    fn closed_form_single_element() {
        let mut theta = Tensor::scalar(1.0);
        let mut accum = Tensor::scalar(0.25);
        let grad = Tensor::scalar(0.5);
        update_tensor(&mut theta, &mut accum, &grad, 0.1, 1.0).unwrap();
        // G' = 0.25 + 0.25 = 0.5; θ' = 1 - 0.1*0.5/sqrt(1.5)
        assert!((accum.item().unwrap() - 0.5).abs() < 1e-7);
        let expect = 1.0 - 0.1 * 0.5 / 1.5f32.sqrt();
        assert!((theta.item().unwrap() - expect).abs() < 1e-7);
    }

    #[test]
    fn beta_bounds_first_step() {
        // The paper's motivation: tiny first gradients must not blow up.
        let mut theta = Tensor::zeros(&[8]);
        let mut accum = Tensor::zeros(&[8]);
        let grad = Tensor::filled(&[8], 1e-6);
        update_tensor(&mut theta, &mut accum, &grad, 0.01, 1.0).unwrap();
        assert!(theta.data().iter().all(|v| v.abs() < 1e-6));
    }

    #[test]
    fn without_beta_first_step_is_full_lr() {
        let mut theta = Tensor::scalar(0.0);
        let mut accum = Tensor::scalar(0.0);
        let grad = Tensor::scalar(1e-6);
        update_tensor(&mut theta, &mut accum, &grad, 0.01, 0.0).unwrap();
        // g/sqrt(g²) = 1 -> step = lr regardless of gradient magnitude.
        assert!((theta.item().unwrap().abs() - 0.01).abs() < 1e-6);
    }

    #[test]
    fn set_update_touches_every_tensor() {
        let net = tiny_net();
        let mut rng = SplitMix64::new(5);
        let mut params = ParamSet::init(&net, &mut rng);
        let before = params.clone();
        let mut accums = ParamSet::zeros(&net);
        let mut grads = ParamSet::zeros(&net);
        for n in ["conv1_w", "conv1_b", "fc_w", "fc_b"] {
            for v in grads.get_mut(n).unwrap().data_mut() {
                *v = 0.1;
            }
        }
        update_set(&mut params, &mut accums, &grads, 0.01, 1.0).unwrap();
        for n in ["conv1_w", "conv1_b", "fc_w", "fc_b"] {
            assert_ne!(params.get(n).unwrap(), before.get(n).unwrap(), "{n} unchanged");
            assert!(accums.get(n).unwrap().data().iter().all(|&v| v > 0.0));
        }
    }

    #[test]
    fn accumulator_is_monotone_over_steps() {
        let mut theta = Tensor::scalar(0.0);
        let mut accum = Tensor::scalar(0.0);
        let mut last = 0.0;
        for i in 0..10 {
            let grad = Tensor::scalar(0.1 * (i as f32 + 1.0));
            update_tensor(&mut theta, &mut accum, &grad, 0.01, 1.0).unwrap();
            let a = accum.item().unwrap();
            assert!(a >= last);
            last = a;
        }
    }
}
