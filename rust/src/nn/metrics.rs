//! Training metrics: error rate, loss curves (the Fig 3 data series).

use crate::runtime::Tensor;

/// Top-1 error rate of probability rows vs integer labels.
pub fn error_rate(probs: &Tensor, labels: &[usize]) -> f32 {
    let nc = *probs.shape().last().unwrap();
    let b = probs.len() / nc;
    assert_eq!(b, labels.len(), "batch/labels mismatch");
    let mut wrong = 0usize;
    for n in 0..b {
        let row = &probs.data()[n * nc..(n + 1) * nc];
        let mut best = 0usize;
        for k in 1..nc {
            if row[k] > row[best] {
                best = k;
            }
        }
        if best != labels[n] {
            wrong += 1;
        }
    }
    wrong as f32 / b as f32
}

/// A recorded training curve: (step, wall-clock ms, value).
#[derive(Debug, Default, Clone)]
pub struct Curve {
    pub points: Vec<(u64, f64, f64)>,
}

impl Curve {
    pub fn push(&mut self, step: u64, wall_ms: f64, value: f64) {
        self.points.push((step, wall_ms, value));
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|p| p.2)
    }

    /// Mean of the first/last `k` recorded values (trend check).
    pub fn head_mean(&self, k: usize) -> f64 {
        let k = k.min(self.points.len());
        self.points[..k].iter().map(|p| p.2).sum::<f64>() / k.max(1) as f64
    }

    pub fn tail_mean(&self, k: usize) -> f64 {
        let n = self.points.len();
        let k = k.min(n);
        self.points[n - k..].iter().map(|p| p.2).sum::<f64>() / k.max(1) as f64
    }

    /// Render as "x y" rows for EXPERIMENTS.md / gnuplot.
    pub fn dump(&self, label: &str) -> String {
        let mut s = format!("# {label}: step wall_ms value\n");
        for (step, ms, v) in &self.points {
            s.push_str(&format!("{step} {ms:.1} {v:.6}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_rate_counts_mismatches() {
        let probs = Tensor::new(
            vec![3, 2],
            vec![
                0.9, 0.1, // -> 0
                0.2, 0.8, // -> 1
                0.6, 0.4, // -> 0
            ],
        )
        .unwrap();
        assert_eq!(error_rate(&probs, &[0, 1, 1]), 1.0 / 3.0);
        assert_eq!(error_rate(&probs, &[0, 1, 0]), 0.0);
        assert_eq!(error_rate(&probs, &[1, 0, 1]), 1.0);
    }

    #[test]
    fn curve_trend_helpers() {
        let mut c = Curve::default();
        for i in 0..10u64 {
            c.push(i, i as f64, 10.0 - i as f64);
        }
        assert!(c.head_mean(3) > c.tail_mean(3));
        assert_eq!(c.last(), Some(1.0));
        assert!(c.dump("loss").lines().count() == 11);
    }
}
