//! The two training engines behind one interface.
//!
//! * [`XlaEngine`] — "Sukiyaki": one fused AOT train-step artifact per
//!   batch; the whole fwd/bwd/update runs inside XLA, parameters round-
//!   trip as tensors.
//! * [`NativeEngine`] — "ConvNetJS": the scalar baseline.
//!
//! Table 4 / Fig 3 drive both through this trait from identical inits
//! and identical batch streams, so the comparison isolates the engine.

use anyhow::Result;

use crate::nn::convnetjs::NaiveNet;
use crate::nn::params::ParamSet;
use crate::runtime::{NetSpec, SharedRuntime, Tensor};
use crate::util::rng::SplitMix64;

pub trait TrainEngine {
    fn name(&self) -> &str;
    /// One mini-batch train step; returns the batch loss.
    fn train_batch(&mut self, x: &Tensor, y1h: &Tensor) -> Result<f32>;
    /// Class probabilities for a batch.
    fn forward(&self, x: &Tensor) -> Result<Tensor>;
    fn params(&self) -> &ParamSet;
    fn step(&self) -> u64;
}

/// Sukiyaki: the AOT/XLA engine.
pub struct XlaEngine {
    rt: SharedRuntime,
    spec: NetSpec,
    params: ParamSet,
    accums: ParamSet,
    step: u64,
    train_artifact: String,
    forward_artifact: String,
    label: String,
}

impl XlaEngine {
    pub fn new(rt: SharedRuntime, net: &str, rng: &mut SplitMix64) -> Result<XlaEngine> {
        let spec = rt.net(net)?.clone();
        let params = ParamSet::init(&spec, rng);
        Self::from_params(rt, net, params)
    }

    pub fn from_params(rt: SharedRuntime, net: &str, params: ParamSet) -> Result<XlaEngine> {
        let spec = rt.net(net)?.clone();
        let accums = ParamSet::zeros(&spec);
        Ok(XlaEngine {
            rt,
            params,
            accums,
            step: 0,
            train_artifact: format!("{net}_train_step"),
            forward_artifact: format!("{net}_forward"),
            label: format!("sukiyaki-xla[{net}]"),
            spec,
        })
    }

    /// Swap the train-step artifact (e.g. `cifar_train_step_jnp` for the
    /// pure-jnp ablation engine).
    pub fn with_train_artifact(mut self, artifact: &str) -> XlaEngine {
        self.train_artifact = artifact.to_string();
        self.label = format!("sukiyaki-xla[{artifact}]");
        self
    }

    pub fn spec(&self) -> &NetSpec {
        &self.spec
    }

    pub fn accums(&self) -> &ParamSet {
        &self.accums
    }

    /// Pre-compile the artifacts so the first measured batch is not a
    /// compilation sample.
    pub fn warm(&self) -> Result<()> {
        self.rt.load(&self.train_artifact)?;
        self.rt.load(&self.forward_artifact)?;
        Ok(())
    }
}

impl TrainEngine for XlaEngine {
    fn name(&self) -> &str {
        &self.label
    }

    fn train_batch(&mut self, x: &Tensor, y1h: &Tensor) -> Result<f32> {
        let mut inputs = self.params.ordered();
        inputs.extend(self.accums.ordered());
        inputs.push(x.clone());
        inputs.push(y1h.clone());
        let outs = self.rt.exec(&self.train_artifact, &inputs)?;
        let n = self.params.names().len();
        anyhow::ensure!(outs.len() == 2 * n + 1, "train step returned {} outputs", outs.len());
        self.params.update_from(&outs[..n])?;
        self.accums.update_from(&outs[n..2 * n])?;
        self.step += 1;
        outs[2 * n].item()
    }

    fn forward(&self, x: &Tensor) -> Result<Tensor> {
        let mut inputs = self.params.ordered();
        inputs.push(x.clone());
        let outs = self.rt.exec(&self.forward_artifact, &inputs)?;
        Ok(outs.into_iter().next().unwrap())
    }

    fn params(&self) -> &ParamSet {
        &self.params
    }

    fn step(&self) -> u64 {
        self.step
    }
}

/// ConvNetJS: the scalar baseline engine.
pub struct NativeEngine {
    nn: NaiveNet,
    label: String,
}

impl NativeEngine {
    pub fn new(spec: &NetSpec, rng: &mut SplitMix64) -> NativeEngine {
        NativeEngine { nn: NaiveNet::new(spec, rng), label: format!("convnetjs-naive[{}]", spec.name) }
    }

    pub fn from_params(spec: &NetSpec, params: ParamSet) -> NativeEngine {
        NativeEngine { nn: NaiveNet::from_params(spec, params), label: format!("convnetjs-naive[{}]", spec.name) }
    }
}

impl TrainEngine for NativeEngine {
    fn name(&self) -> &str {
        &self.label
    }

    fn train_batch(&mut self, x: &Tensor, y1h: &Tensor) -> Result<f32> {
        self.nn.train_batch(x, y1h)
    }

    fn forward(&self, x: &Tensor) -> Result<Tensor> {
        self.nn.forward_probs(x)
    }

    fn params(&self) -> &ParamSet {
        &self.nn.params
    }

    fn step(&self) -> u64 {
        self.nn.step
    }
}
