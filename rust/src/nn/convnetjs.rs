//! The ConvNetJS stand-in: a faithful single-threaded scalar CNN.
//!
//! Table 4 / Fig 3 compare Sukiyaki against ConvNetJS (Karpathy's
//! JavaScript library).  We cannot run a browser, so this module
//! re-implements ConvNetJS's algorithmic profile in Rust with the same
//! characteristics the JS engine executes:
//!
//! * direct (non-im2col) convolution with per-output-pixel scalar loops;
//! * f64 arithmetic throughout (JS numbers are doubles);
//! * a single thread, no blocking, no SIMD intrinsics;
//! * max-pool "switches" remembered for the backward pass, like
//!   ConvNetJS's `Vol`-based pooling layer;
//! * the same AdaGrad-β update as the rest of the system.
//!
//! Parameters interchange with the XLA engine via [`ParamSet`] (same
//! im2col weight layout `[kh*kw*cin, cout]`, (dy,dx,c) row-major), so
//! both engines can start from identical weights — Fig 3 plots both
//! error curves from the same init.

use anyhow::{ensure, Result};

use crate::nn::adagrad;
use crate::nn::params::ParamSet;
use crate::runtime::{NetSpec, Tensor};
use crate::util::rng::SplitMix64;

/// Per-layer forward cache for one batch.
struct ConvCache {
    input: Vec<f64>,          // [B, h, w, cin] layer input
    relu_mask: Vec<bool>,     // [B, h, w, cout] post-conv activation sign
    switches: Vec<usize>,     // [B, h/2, w/2, cout] pooled argmax (flat idx into conv out)
    pooled: Vec<f64>,         // [B, h/2, w/2, cout]
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
}

pub struct NaiveNet {
    spec: NetSpec,
    pub params: ParamSet,
    pub accums: ParamSet,
    pub step: u64,
}

impl NaiveNet {
    pub fn new(spec: &NetSpec, rng: &mut SplitMix64) -> NaiveNet {
        NaiveNet {
            spec: spec.clone(),
            params: ParamSet::init(spec, rng),
            accums: ParamSet::zeros(spec),
            step: 0,
        }
    }

    pub fn from_params(spec: &NetSpec, params: ParamSet) -> NaiveNet {
        NaiveNet { spec: spec.clone(), params, accums: ParamSet::zeros(spec), step: 0 }
    }

    fn conv_forward_layer(
        &self,
        li: usize,
        input: &[f64],
        b: usize,
        h: usize,
        w: usize,
    ) -> ConvCache {
        let l = &self.spec.convs[li];
        let (kh, kw, cin, cout, pad) = (l.kh, l.kw, l.cin, l.cout, l.pad);
        let wname = format!("conv{}_w", li + 1);
        let bname = format!("conv{}_b", li + 1);
        let wt = self.params.get(&wname).unwrap();
        let bt = self.params.get(&bname).unwrap();
        let wd: Vec<f64> = wt.data().iter().map(|&v| v as f64).collect();
        let bd: Vec<f64> = bt.data().iter().map(|&v| v as f64).collect();

        let mut conv_out = vec![0.0f64; b * h * w * cout];
        let mut relu_mask = vec![false; b * h * w * cout];
        // ConvNetJS ConvLayer.forward: per output pixel, scan the filter
        // window with scalar multiply-adds and bounds checks.
        for n in 0..b {
            for oy in 0..h {
                for ox in 0..w {
                    for oc in 0..cout {
                        let mut acc = bd[oc];
                        for dy in 0..kh {
                            let iy = oy as isize + dy as isize - pad as isize;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            for dx in 0..kw {
                                let ix = ox as isize + dx as isize - pad as isize;
                                if ix < 0 || ix >= w as isize {
                                    continue;
                                }
                                let in_base = ((n * h + iy as usize) * w + ix as usize) * cin;
                                let w_base = (dy * kw + dx) * cin;
                                for c in 0..cin {
                                    acc += input[in_base + c] * wd[(w_base + c) * cout + oc];
                                }
                            }
                        }
                        let idx = ((n * h + oy) * w + ox) * cout + oc;
                        // relu fused, remembering the mask (activation layer)
                        if acc > 0.0 {
                            conv_out[idx] = acc;
                            relu_mask[idx] = true;
                        }
                    }
                }
            }
        }

        // 2x2/2 max pool with switches (PoolLayer.forward).
        let (ph, pw) = (h / 2, w / 2);
        let mut pooled = vec![0.0f64; b * ph * pw * cout];
        let mut switches = vec![0usize; b * ph * pw * cout];
        for n in 0..b {
            for py in 0..ph {
                for px in 0..pw {
                    for c in 0..cout {
                        let mut best = f64::NEG_INFINITY;
                        let mut best_idx = 0usize;
                        for dy in 0..2 {
                            for dx in 0..2 {
                                let idx = ((n * h + 2 * py + dy) * w + 2 * px + dx) * cout + c;
                                if conv_out[idx] > best {
                                    best = conv_out[idx];
                                    best_idx = idx;
                                }
                            }
                        }
                        let pidx = ((n * ph + py) * pw + px) * cout + c;
                        pooled[pidx] = best;
                        switches[pidx] = best_idx;
                    }
                }
            }
        }

        ConvCache { input: input.to_vec(), relu_mask, switches, pooled, h, w, cin, cout }
    }

    /// Full forward pass; returns (per-layer caches, features, probs).
    fn forward_full(&self, x: &Tensor) -> (Vec<ConvCache>, Vec<f64>, Vec<f64>) {
        let b = self.spec.batch;
        let mut cur: Vec<f64> = x.data().iter().map(|&v| v as f64).collect();
        let mut h = self.spec.input_hw;
        let mut w = self.spec.input_hw;
        let mut caches = Vec::new();
        for li in 0..self.spec.convs.len() {
            let cache = self.conv_forward_layer(li, &cur, b, h, w);
            cur = cache.pooled.clone();
            h /= 2;
            w /= 2;
            caches.push(cache);
        }
        // cur is now [B, fc_in]
        let fc_w = self.params.get("fc_w").unwrap();
        let fc_b = self.params.get("fc_b").unwrap();
        let (fin, nc) = (self.spec.fc_in, self.spec.n_classes);
        let mut logits = vec![0.0f64; b * nc];
        for n in 0..b {
            for k in 0..nc {
                let mut acc = fc_b.data()[k] as f64;
                for j in 0..fin {
                    acc += cur[n * fin + j] * fc_w.data()[j * nc + k] as f64;
                }
                logits[n * nc + k] = acc;
            }
        }
        // softmax
        let mut probs = vec![0.0f64; b * nc];
        for n in 0..b {
            let row = &logits[n * nc..(n + 1) * nc];
            let m = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let mut z = 0.0;
            for k in 0..nc {
                let e = (row[k] - m).exp();
                probs[n * nc + k] = e;
                z += e;
            }
            for k in 0..nc {
                probs[n * nc + k] /= z;
            }
        }
        (caches, cur, probs)
    }

    /// Inference: class probabilities [B, n_classes].
    pub fn forward_probs(&self, x: &Tensor) -> Result<Tensor> {
        ensure!(x.shape() == self.spec.x_shape().as_slice(), "bad input shape {:?}", x.shape());
        let (_, _, probs) = self.forward_full(x);
        Tensor::new(
            vec![self.spec.batch, self.spec.n_classes],
            probs.iter().map(|&v| v as f32).collect(),
        )
    }

    /// Gradients + loss without applying an update (for tests/aggregation).
    pub fn gradients(&self, x: &Tensor, y1h: &Tensor) -> Result<(ParamSet, f64)> {
        ensure!(x.shape() == self.spec.x_shape().as_slice(), "bad x shape {:?}", x.shape());
        ensure!(y1h.shape() == self.spec.y_shape().as_slice(), "bad y shape {:?}", y1h.shape());
        let b = self.spec.batch;
        let nc = self.spec.n_classes;
        let fin = self.spec.fc_in;
        let (caches, feat, probs) = self.forward_full(x);

        // loss + dlogits
        let mut loss = 0.0f64;
        let mut dlogits = vec![0.0f64; b * nc];
        for n in 0..b {
            for k in 0..nc {
                let yv = y1h.data()[n * nc + k] as f64;
                if yv > 0.0 {
                    loss -= yv * probs[n * nc + k].max(1e-300).ln();
                }
                dlogits[n * nc + k] = (probs[n * nc + k] - yv) / b as f64;
            }
        }
        loss /= b as f64;

        let mut grads = ParamSet::zeros(&self.spec);
        // FC grads + dfeat
        let fc_w = self.params.get("fc_w").unwrap();
        {
            let gw = grads.get_mut("fc_w").unwrap();
            let gwd = gw.data_mut();
            for n in 0..b {
                for j in 0..fin {
                    let f = feat[n * fin + j];
                    for k in 0..nc {
                        gwd[j * nc + k] += (f * dlogits[n * nc + k]) as f32;
                    }
                }
            }
        }
        {
            let gb = grads.get_mut("fc_b").unwrap().data_mut();
            for n in 0..b {
                for k in 0..nc {
                    gb[k] += dlogits[n * nc + k] as f32;
                }
            }
        }
        let mut dcur = vec![0.0f64; b * fin];
        for n in 0..b {
            for j in 0..fin {
                let mut acc = 0.0;
                for k in 0..nc {
                    acc += dlogits[n * nc + k] * fc_w.data()[j * nc + k] as f64;
                }
                dcur[n * fin + j] = acc;
            }
        }

        // conv stack backward, last layer first
        for li in (0..self.spec.convs.len()).rev() {
            let l = &self.spec.convs[li];
            let cache = &caches[li];
            let (h, w, cin, cout) = (cache.h, cache.w, cache.cin, cache.cout);
            let (ph, pw) = (h / 2, w / 2);
            let (kh, kw, pad) = (l.kh, l.kw, l.pad);

            // pool backward: route cotangent to the switch position
            let mut dconv = vec![0.0f64; b * h * w * cout];
            for i in 0..b * ph * pw * cout {
                dconv[cache.switches[i]] += dcur[i];
            }
            // relu backward
            for i in 0..dconv.len() {
                if !cache.relu_mask[i] {
                    dconv[i] = 0.0;
                }
            }
            // conv backward: dW, db, dinput
            let wname = format!("conv{}_w", li + 1);
            let bname = format!("conv{}_b", li + 1);
            let wt: Vec<f64> = self.params.get(&wname).unwrap().data().iter().map(|&v| v as f64).collect();
            let mut dw = vec![0.0f64; kh * kw * cin * cout];
            let mut db = vec![0.0f64; cout];
            let mut dinput = vec![0.0f64; b * h * w * cin];
            for n in 0..b {
                for oy in 0..h {
                    for ox in 0..w {
                        let out_base = ((n * h + oy) * w + ox) * cout;
                        for oc in 0..cout {
                            let d = dconv[out_base + oc];
                            if d == 0.0 {
                                continue;
                            }
                            db[oc] += d;
                            for dy in 0..kh {
                                let iy = oy as isize + dy as isize - pad as isize;
                                if iy < 0 || iy >= h as isize {
                                    continue;
                                }
                                for dx in 0..kw {
                                    let ix = ox as isize + dx as isize - pad as isize;
                                    if ix < 0 || ix >= w as isize {
                                        continue;
                                    }
                                    let in_base = ((n * h + iy as usize) * w + ix as usize) * cin;
                                    let w_base = (dy * kw + dx) * cin;
                                    for c in 0..cin {
                                        dw[(w_base + c) * cout + oc] += input_at(&cache.input, in_base + c) * d;
                                        dinput[in_base + c] += wt[(w_base + c) * cout + oc] * d;
                                    }
                                }
                            }
                        }
                    }
                }
            }
            {
                let g = grads.get_mut(&wname).unwrap().data_mut();
                for i in 0..g.len() {
                    g[i] = dw[i] as f32;
                }
            }
            {
                let g = grads.get_mut(&bname).unwrap().data_mut();
                for i in 0..g.len() {
                    g[i] = db[i] as f32;
                }
            }
            dcur = dinput;
        }

        Ok((grads, loss))
    }

    /// One training step: forward, backward, AdaGrad-β update.
    pub fn train_batch(&mut self, x: &Tensor, y1h: &Tensor) -> Result<f32> {
        let (grads, loss) = self.gradients(x, y1h)?;
        adagrad::update_set(&mut self.params, &mut self.accums, &grads, self.spec.lr, self.spec.beta)?;
        self.step += 1;
        Ok(loss as f32)
    }

    pub fn spec(&self) -> &NetSpec {
        &self.spec
    }
}

#[inline]
fn input_at(input: &[f64], idx: usize) -> f64 {
    input[idx]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::params::test_support::tiny_net;

    fn tiny_batch(net: &NetSpec, seed: u64) -> (Tensor, Tensor) {
        let mut rng = SplitMix64::new(seed);
        let x = Tensor::uniform(&net.x_shape(), &mut rng, 1.0);
        let mut y = Tensor::zeros(&net.y_shape());
        for n in 0..net.batch {
            let k = rng.gen_range(net.n_classes as u64) as usize;
            y.data_mut()[n * net.n_classes + k] = 1.0;
        }
        (x, y)
    }

    #[test]
    fn forward_is_distribution() {
        let net = tiny_net();
        let nn = NaiveNet::new(&net, &mut SplitMix64::new(1));
        let (x, _) = tiny_batch(&net, 2);
        let probs = nn.forward_probs(&x).unwrap();
        for n in 0..net.batch {
            let row = &probs.data()[n * net.n_classes..(n + 1) * net.n_classes];
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(row.iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn gradient_check_against_finite_differences() {
        let net = tiny_net();
        let mut rng = SplitMix64::new(3);
        let nn = NaiveNet::new(&net, &mut rng);
        let (x, y) = tiny_batch(&net, 4);
        let (grads, loss0) = nn.gradients(&x, &y).unwrap();
        assert!(loss0 > 0.0);

        let eps = 1e-3f32;
        // Sample a few coordinates from every tensor and compare to the
        // symmetric difference quotient.
        for name in ["conv1_w", "conv1_b", "fc_w", "fc_b"] {
            let len = nn.params.get(name).unwrap().len();
            for probe in 0..3.min(len) {
                let idx = (probe * 7919) % len;
                let mut plus = NaiveNet::from_params(&net, nn.params.clone());
                plus.params.get_mut(name).unwrap().data_mut()[idx] += eps;
                let (_, lp) = plus.gradients(&x, &y).unwrap();
                let mut minus = NaiveNet::from_params(&net, nn.params.clone());
                minus.params.get_mut(name).unwrap().data_mut()[idx] -= eps;
                let (_, lm) = minus.gradients(&x, &y).unwrap();
                let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
                let an = grads.get(name).unwrap().data()[idx];
                assert!(
                    (fd - an).abs() < 2e-2 * fd.abs().max(an.abs()).max(0.05),
                    "{name}[{idx}]: analytic {an} vs fd {fd}"
                );
            }
        }
    }

    #[test]
    fn training_reduces_loss_on_learnable_batch() {
        let net = tiny_net();
        let mut rng = SplitMix64::new(5);
        let mut nn = NaiveNet::new(&net, &mut rng);
        // class-dependent constant images: trivially separable
        let mut x = Tensor::zeros(&net.x_shape());
        let mut y = Tensor::zeros(&net.y_shape());
        let hw = net.input_hw * net.input_hw * net.input_c;
        for n in 0..net.batch {
            let k = n % net.n_classes;
            for i in 0..hw {
                x.data_mut()[n * hw + i] = k as f32 / net.n_classes as f32 + 0.1;
            }
            y.data_mut()[n * net.n_classes + k] = 1.0;
        }
        let first = nn.train_batch(&x, &y).unwrap();
        let mut last = first;
        for _ in 0..120 {
            last = nn.train_batch(&x, &y).unwrap();
        }
        assert!(last < first * 0.6, "loss {first} -> {last}");
        assert_eq!(nn.step, 121);
    }

    #[test]
    fn rejects_wrong_shapes() {
        let net = tiny_net();
        let nn = NaiveNet::new(&net, &mut SplitMix64::new(6));
        let bad = Tensor::zeros(&[1, 8, 8, 1]);
        assert!(nn.forward_probs(&bad).is_err());
    }
}
