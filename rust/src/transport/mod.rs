//! Wire protocol + transports — the WebSocket/HTTP substitute.
//!
//! The paper's browsers speak WebSocket to the TicketDistributor and
//! HTTP to the HTTPServer (static program, dataset APIs).  Here both
//! roles share one JSON-lines protocol ([`Message`]) over two
//! interchangeable transports:
//!
//! * [`tcp`] — real sockets (std::net), one JSON document per line, for
//!   multi-process deployments (`sashimi serve` / `sashimi worker`);
//! * [`local`] — in-process channel pairs with an explicit [`LinkModel`]
//!   (RTT + bandwidth) and fault injection, used by benches and tests to
//!   emulate Internet-grade links deterministically;
//! * [`ws`] — RFC 6455 WebSocket, text frames carrying the same JSON
//!   documents, so an actual browser can join the fleet (the paper's
//!   deployment story made literal).
//!
//! The byte-level cut between documents lives in [`framing`], shared by
//! the blocking transports here and the async epoll gateway
//! ([`crate::coordinator::gateway`]) that multiplexes thousands of
//! connections onto one thread.
//!
//! Every message carries its encoded size through the link model, so
//! communication costs scale with real payload bytes (the quantity the
//! paper's §4 algorithm is designed to minimise).

pub mod framing;
pub mod local;
pub mod tcp;
pub mod ws;

use anyhow::{bail, Context, Result};

use crate::store::{TaskId, TicketId};
use crate::util::json::Value;

/// One ticket as it rides the wire inside a [`Message::Tickets`] batch:
/// the same fields as the singular [`Message::Ticket`].
#[derive(Debug, Clone, PartialEq)]
pub struct WireTicket {
    pub ticket: TicketId,
    pub task: TaskId,
    pub task_name: String,
    pub index: usize,
    pub payload: Value,
}

impl WireTicket {
    fn to_value(&self) -> Value {
        Value::obj(vec![
            ("ticket", Value::num(self.ticket.0 as f64)),
            ("task", Value::num(self.task.0 as f64)),
            ("task_name", Value::str(self.task_name.clone())),
            ("index", Value::num(self.index as f64)),
            ("payload", self.payload.clone()),
        ])
    }

    fn from_value(v: &Value) -> Result<WireTicket> {
        Ok(WireTicket {
            ticket: TicketId(v.get("ticket")?.as_u64()?),
            task: TaskId(v.get("task")?.as_u64()?),
            task_name: v.get("task_name")?.as_str()?.to_string(),
            index: v.get("index")?.as_usize()?,
            payload: v.get("payload")?.clone(),
        })
    }
}

/// One error report as it rides the wire inside a
/// [`Message::ErrorReports`] batch: the same fields as the singular
/// [`Message::ErrorReport`].
#[derive(Debug, Clone, PartialEq)]
pub struct WireError {
    pub ticket: TicketId,
    pub message: String,
    pub stack: String,
}

impl WireError {
    fn to_value(&self) -> Value {
        Value::obj(vec![
            ("ticket", Value::num(self.ticket.0 as f64)),
            ("message", Value::str(self.message.clone())),
            ("stack", Value::str(self.stack.clone())),
        ])
    }

    fn from_value(v: &Value) -> Result<WireError> {
        Ok(WireError {
            ticket: TicketId(v.get("ticket")?.as_u64()?),
            message: v.get("message")?.as_str()?.to_string(),
            stack: v.get("stack")?.as_str()?.to_string(),
        })
    }
}

/// Protocol messages (both directions).  Mirrors the browser loop in
/// §2.1.2 of the paper step by step.  The batched variants
/// (`TicketBatchRequest`/`Tickets`/`TicketResults`, and on the failure
/// path `ErrorReports`/`ReleaseTickets`) amortise one round-trip over
/// many tickets; the singular forms stay served for legacy clients.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Worker -> server: join with a client id and device profile name.
    Hello { client: String, profile: String },
    /// Worker -> server: step 2, "a ticket request is sent".
    TicketRequest,
    /// Worker -> server: batched step 2 — up to `max` tickets in one
    /// round trip (the worker's adaptive prefetch size).
    TicketBatchRequest { max: usize },
    /// Server -> worker: a ticket to execute.
    Ticket { ticket: TicketId, task: TaskId, task_name: String, index: usize, payload: Value },
    /// Server -> worker: a batch of tickets, in dispatch order (the
    /// reply to [`Message::TicketBatchRequest`]; an empty pool is
    /// answered with [`Message::NoTicket`] instead).
    Tickets { tickets: Vec<WireTicket> },
    /// Server -> worker: nothing available; retry after the hint.
    NoTicket { retry_after_ms: u64 },
    /// Worker -> server: step 3, fetch task code it has not cached.
    TaskRequest { task_name: String },
    /// Server -> worker: task code metadata (code itself is resolved
    /// through the worker's registry — see DESIGN.md §2 on eval()).
    TaskCode { task_name: String, code_bytes: usize, dataset_refs: Vec<String> },
    /// Worker -> server: step 4, fetch an external dataset/file.
    DataRequest { key: String },
    /// Server -> worker: dataset payload (base64 of little-endian f32s).
    Data { key: String, shape: Vec<usize>, b64: String },
    /// Worker -> server: step 6, the calculated result.
    TicketResult { ticket: TicketId, result: Value },
    /// Worker -> server: batched step 6 — a flush of several results in
    /// one round trip, in completion order (answered with one Ack).
    TicketResults { results: Vec<(TicketId, Value)> },
    /// Worker -> server: error report with stack trace; the worker
    /// reloads itself afterwards (paper behaviour).
    ErrorReport { ticket: TicketId, message: String, stack: String },
    /// Worker -> server: batched error reports — every failure of one
    /// prefetched batch in a single round trip, answered by one
    /// [`Message::Reload`] (the worker reloads itself once per batch,
    /// not once per failure).
    ErrorReports { reports: Vec<WireError> },
    /// Worker -> server: explicitly hand undone tickets back (an
    /// orderly shutdown, or an abandoned prefetch queue).  Released
    /// tickets are immediately re-dispatchable — no redistribution
    /// window — and the server answers with one [`Message::Ack`].
    ReleaseTickets { tickets: Vec<TicketId> },
    /// Server -> worker: acknowledge (keeps the protocol strictly
    /// request/response so links can be modelled per round trip).
    Ack,
    /// Server -> worker: console-initiated reload/redirect (§2.1.2).
    Reload,
    /// Either direction: orderly shutdown.
    Shutdown,
}

impl Message {
    pub fn encode(&self) -> String {
        let v = match self {
            Message::Hello { client, profile } => Value::obj(vec![
                ("t", Value::str("hello")),
                ("client", Value::str(client.clone())),
                ("profile", Value::str(profile.clone())),
            ]),
            Message::TicketRequest => Value::obj(vec![("t", Value::str("ticket_req"))]),
            Message::TicketBatchRequest { max } => Value::obj(vec![
                ("t", Value::str("ticket_batch_req")),
                ("max", Value::num(*max as f64)),
            ]),
            Message::Tickets { tickets } => Value::obj(vec![
                ("t", Value::str("tickets")),
                ("tickets", Value::arr(tickets.iter().map(|t| t.to_value()))),
            ]),
            Message::TicketResults { results } => Value::obj(vec![
                ("t", Value::str("results")),
                (
                    "results",
                    Value::arr(results.iter().map(|(id, r)| {
                        Value::obj(vec![
                            ("ticket", Value::num(id.0 as f64)),
                            ("result", r.clone()),
                        ])
                    })),
                ),
            ]),
            Message::Ticket { ticket, task, task_name, index, payload } => Value::obj(vec![
                ("t", Value::str("ticket")),
                ("ticket", Value::num(ticket.0 as f64)),
                ("task", Value::num(task.0 as f64)),
                ("task_name", Value::str(task_name.clone())),
                ("index", Value::num(*index as f64)),
                ("payload", payload.clone()),
            ]),
            Message::NoTicket { retry_after_ms } => Value::obj(vec![
                ("t", Value::str("no_ticket")),
                ("retry_after_ms", Value::num(*retry_after_ms as f64)),
            ]),
            Message::TaskRequest { task_name } => Value::obj(vec![
                ("t", Value::str("task_req")),
                ("task_name", Value::str(task_name.clone())),
            ]),
            Message::TaskCode { task_name, code_bytes, dataset_refs } => Value::obj(vec![
                ("t", Value::str("task_code")),
                ("task_name", Value::str(task_name.clone())),
                ("code_bytes", Value::num(*code_bytes as f64)),
                ("dataset_refs", Value::arr(dataset_refs.iter().map(|s| Value::str(s.clone())))),
            ]),
            Message::DataRequest { key } => Value::obj(vec![
                ("t", Value::str("data_req")),
                ("key", Value::str(key.clone())),
            ]),
            Message::Data { key, shape, b64 } => Value::obj(vec![
                ("t", Value::str("data")),
                ("key", Value::str(key.clone())),
                ("shape", Value::arr(shape.iter().map(|&d| Value::num(d as f64)))),
                ("b64", Value::str(b64.clone())),
            ]),
            Message::TicketResult { ticket, result } => Value::obj(vec![
                ("t", Value::str("result")),
                ("ticket", Value::num(ticket.0 as f64)),
                ("result", result.clone()),
            ]),
            Message::ErrorReport { ticket, message, stack } => Value::obj(vec![
                ("t", Value::str("error")),
                ("ticket", Value::num(ticket.0 as f64)),
                ("message", Value::str(message.clone())),
                ("stack", Value::str(stack.clone())),
            ]),
            Message::ErrorReports { reports } => Value::obj(vec![
                ("t", Value::str("errors")),
                ("reports", Value::arr(reports.iter().map(|r| r.to_value()))),
            ]),
            Message::ReleaseTickets { tickets } => Value::obj(vec![
                ("t", Value::str("release")),
                ("tickets", Value::arr(tickets.iter().map(|id| Value::num(id.0 as f64)))),
            ]),
            Message::Ack => Value::obj(vec![("t", Value::str("ack"))]),
            Message::Reload => Value::obj(vec![("t", Value::str("reload"))]),
            Message::Shutdown => Value::obj(vec![("t", Value::str("shutdown"))]),
        };
        v.to_string()
    }

    pub fn decode(line: &str) -> Result<Message> {
        let v = Value::parse(line).context("decoding message")?;
        let t = v.get("t")?.as_str()?;
        Ok(match t {
            "hello" => Message::Hello {
                client: v.get("client")?.as_str()?.to_string(),
                profile: v.get("profile")?.as_str()?.to_string(),
            },
            "ticket_req" => Message::TicketRequest,
            "ticket_batch_req" => {
                Message::TicketBatchRequest { max: v.get("max")?.as_usize()? }
            }
            "tickets" => Message::Tickets {
                tickets: v
                    .get("tickets")?
                    .as_arr()?
                    .iter()
                    .map(WireTicket::from_value)
                    .collect::<Result<Vec<_>>>()?,
            },
            "results" => Message::TicketResults {
                results: v
                    .get("results")?
                    .as_arr()?
                    .iter()
                    .map(|e| {
                        Ok((TicketId(e.get("ticket")?.as_u64()?), e.get("result")?.clone()))
                    })
                    .collect::<Result<Vec<_>>>()?,
            },
            "ticket" => Message::Ticket {
                ticket: TicketId(v.get("ticket")?.as_u64()?),
                task: TaskId(v.get("task")?.as_u64()?),
                task_name: v.get("task_name")?.as_str()?.to_string(),
                index: v.get("index")?.as_usize()?,
                payload: v.get("payload")?.clone(),
            },
            "no_ticket" => Message::NoTicket { retry_after_ms: v.get("retry_after_ms")?.as_u64()? },
            "task_req" => Message::TaskRequest { task_name: v.get("task_name")?.as_str()?.to_string() },
            "task_code" => Message::TaskCode {
                task_name: v.get("task_name")?.as_str()?.to_string(),
                code_bytes: v.get("code_bytes")?.as_usize()?,
                dataset_refs: v
                    .get("dataset_refs")?
                    .as_arr()?
                    .iter()
                    .map(|s| Ok(s.as_str()?.to_string()))
                    .collect::<Result<Vec<_>>>()?,
            },
            "data_req" => Message::DataRequest { key: v.get("key")?.as_str()?.to_string() },
            "data" => Message::Data {
                key: v.get("key")?.as_str()?.to_string(),
                shape: v.get("shape")?.as_usize_vec()?,
                b64: v.get("b64")?.as_str()?.to_string(),
            },
            "result" => Message::TicketResult {
                ticket: TicketId(v.get("ticket")?.as_u64()?),
                result: v.get("result")?.clone(),
            },
            "error" => Message::ErrorReport {
                ticket: TicketId(v.get("ticket")?.as_u64()?),
                message: v.get("message")?.as_str()?.to_string(),
                stack: v.get("stack")?.as_str()?.to_string(),
            },
            "errors" => Message::ErrorReports {
                reports: v
                    .get("reports")?
                    .as_arr()?
                    .iter()
                    .map(WireError::from_value)
                    .collect::<Result<Vec<_>>>()?,
            },
            "release" => Message::ReleaseTickets {
                tickets: v
                    .get("tickets")?
                    .as_arr()?
                    .iter()
                    .map(|e| Ok(TicketId(e.as_u64()?)))
                    .collect::<Result<Vec<_>>>()?,
            },
            "ack" => Message::Ack,
            "reload" => Message::Reload,
            "shutdown" => Message::Shutdown,
            other => bail!("unknown message type {other:?}"),
        })
    }
}

/// Bidirectional, blocking, message-oriented connection.
pub trait Conn: Send {
    fn send(&mut self, m: &Message) -> Result<()>;
    fn recv(&mut self) -> Result<Message>;
    /// Bytes moved so far (sent, received) — for the communication-cost
    /// accounting in the Fig 5 / ablation benches.
    fn bytes(&self) -> (u64, u64);
}

/// Server side: accept worker connections.
pub trait Listener: Send {
    fn accept(&mut self) -> Result<Box<dyn Conn>>;
}

/// Internet-link model applied by the local transport (and available to
/// the benches for calibration): per-message RTT share + bandwidth.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// One-way latency added per message, ms.
    pub latency_ms: f64,
    /// Link throughput in bytes/ms (e.g. 1 MB/s = 1000.0).
    pub bytes_per_ms: f64,
}

impl LinkModel {
    pub const FAST_LAN: LinkModel = LinkModel { latency_ms: 0.1, bytes_per_ms: 100_000.0 };
    /// Campus/office LAN (the paper's testbed): 5 ms one-way, ~50 MB/s.
    pub const CAMPUS: LinkModel = LinkModel { latency_ms: 5.0, bytes_per_ms: 50_000.0 };
    /// Home-broadband-ish: 20 ms one-way, ~2 MB/s.
    pub const INTERNET: LinkModel = LinkModel { latency_ms: 20.0, bytes_per_ms: 2_000.0 };
    /// 3G-tablet-ish: 50 ms one-way, ~250 KB/s.
    pub const MOBILE: LinkModel = LinkModel { latency_ms: 50.0, bytes_per_ms: 250.0 };

    pub fn transfer_ms(&self, bytes: usize) -> f64 {
        self.latency_ms + bytes as f64 / self.bytes_per_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(m: Message) {
        let enc = m.encode();
        let dec = Message::decode(&enc).unwrap();
        assert_eq!(m, dec, "encoded: {enc}");
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(Message::Hello { client: "w1".into(), profile: "desktop".into() });
        roundtrip(Message::TicketRequest);
        roundtrip(Message::Ticket {
            ticket: TicketId(3),
            task: TaskId(1),
            task_name: "is_prime".into(),
            index: 7,
            payload: Value::obj(vec![("candidate", Value::num(97.0))]),
        });
        roundtrip(Message::NoTicket { retry_after_ms: 250 });
        roundtrip(Message::TicketBatchRequest { max: 16 });
        roundtrip(Message::Tickets {
            tickets: vec![
                WireTicket {
                    ticket: TicketId(3),
                    task: TaskId(1),
                    task_name: "is_prime".into(),
                    index: 7,
                    payload: Value::obj(vec![("candidate", Value::num(97.0))]),
                },
                WireTicket {
                    ticket: TicketId(4),
                    task: TaskId(1),
                    task_name: "is_prime".into(),
                    index: 8,
                    payload: Value::obj(vec![("candidate", Value::num(98.0))]),
                },
            ],
        });
        roundtrip(Message::Tickets { tickets: Vec::new() });
        roundtrip(Message::TicketResults {
            results: vec![
                (TicketId(3), Value::Bool(true)),
                (TicketId(4), Value::obj(vec![("x", Value::num(1.5))])),
            ],
        });
        roundtrip(Message::TicketResults { results: Vec::new() });
        roundtrip(Message::TaskRequest { task_name: "knn".into() });
        roundtrip(Message::TaskCode {
            task_name: "knn".into(),
            code_bytes: 4096,
            dataset_refs: vec!["mnist_train_0".into(), "mnist_train_1".into()],
        });
        roundtrip(Message::DataRequest { key: "mnist_train_0".into() });
        roundtrip(Message::Data { key: "d".into(), shape: vec![2, 3], b64: "AAAA".into() });
        roundtrip(Message::TicketResult { ticket: TicketId(9), result: Value::Bool(true) });
        roundtrip(Message::ErrorReport {
            ticket: TicketId(2),
            message: "panic: index out of bounds".into(),
            stack: "worker::execute\ncoordinator::...".into(),
        });
        roundtrip(Message::ErrorReports {
            reports: vec![
                WireError {
                    ticket: TicketId(2),
                    message: "panic: index out of bounds".into(),
                    stack: "worker::execute".into(),
                },
                WireError { ticket: TicketId(5), message: "boom".into(), stack: String::new() },
            ],
        });
        roundtrip(Message::ErrorReports { reports: Vec::new() });
        roundtrip(Message::ReleaseTickets { tickets: vec![TicketId(7), TicketId(8), TicketId(7)] });
        roundtrip(Message::ReleaseTickets { tickets: Vec::new() });
        roundtrip(Message::Ack);
        roundtrip(Message::Reload);
        roundtrip(Message::Shutdown);
    }

    #[test]
    fn decode_rejects_unknown() {
        assert!(Message::decode(r#"{"t":"warp"}"#).is_err());
        assert!(Message::decode("not json").is_err());
    }

    #[test]
    fn link_model_costs() {
        let m = LinkModel::INTERNET;
        assert!((m.transfer_ms(0) - 20.0).abs() < 1e-9);
        assert!((m.transfer_ms(2_000_000) - (20.0 + 1000.0)).abs() < 1e-6);
        assert!(LinkModel::MOBILE.transfer_ms(1000) > LinkModel::FAST_LAN.transfer_ms(1000));
    }
}
