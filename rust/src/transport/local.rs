//! In-process transport: channel pairs with a link model and fault
//! injection.  Deterministic stand-in for Internet WebSocket links in
//! tests and benches.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::{bail, Result};

use super::{Conn, LinkModel, Listener, Message};

/// Fault plan for one endpoint: cut the connection after N sends.
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultPlan {
    /// Drop the connection (subsequent send/recv error) after this many
    /// successful sends from this endpoint.  None = healthy.
    pub die_after_sends: Option<u64>,
}

struct Shared {
    sent_bytes: AtomicU64,
    recv_bytes: AtomicU64,
}

pub struct LocalConn {
    tx: Sender<String>,
    rx: Receiver<String>,
    link: LinkModel,
    fault: FaultPlan,
    sends: u64,
    dead: bool,
    shared: Arc<Shared>,
    /// When false (bench mode measuring pure dispatch), the link model
    /// cost is accounted but not slept.
    sleep_on_link: bool,
}

impl LocalConn {
    fn apply_link(&self, bytes: usize) {
        if self.sleep_on_link {
            let ms = self.link.transfer_ms(bytes);
            if ms > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(ms / 1e3));
            }
        }
    }
}

impl Conn for LocalConn {
    fn send(&mut self, m: &Message) -> Result<()> {
        if self.dead {
            bail!("connection dead (fault injection)");
        }
        if let Some(n) = self.fault.die_after_sends {
            if self.sends >= n {
                self.dead = true;
                bail!("connection dropped after {n} sends (fault injection)");
            }
        }
        let line = m.encode();
        self.apply_link(line.len());
        self.shared.sent_bytes.fetch_add(line.len() as u64, Ordering::Relaxed);
        self.sends += 1;
        self.tx.send(line).map_err(|_| anyhow::anyhow!("peer closed"))
    }

    fn recv(&mut self) -> Result<Message> {
        if self.dead {
            bail!("connection dead (fault injection)");
        }
        let line = self.rx.recv().map_err(|_| anyhow::anyhow!("peer closed"))?;
        // Downloads pay the link too: dataset payloads (the paper's
        // per-browser MNIST download) dominate a worker's fixed cost.
        self.apply_link(line.len());
        self.shared.recv_bytes.fetch_add(line.len() as u64, Ordering::Relaxed);
        Message::decode(&line)
    }

    fn bytes(&self) -> (u64, u64) {
        (self.shared.sent_bytes.load(Ordering::Relaxed), self.shared.recv_bytes.load(Ordering::Relaxed))
    }
}

/// Create a connected (client, server) pair over `link`.
pub fn pair(link: LinkModel, sleep_on_link: bool) -> (LocalConn, LocalConn) {
    pair_with_fault(link, sleep_on_link, FaultPlan::default())
}

/// Like [`pair`] but the *client* endpoint carries a fault plan.
pub fn pair_with_fault(link: LinkModel, sleep_on_link: bool, client_fault: FaultPlan) -> (LocalConn, LocalConn) {
    let (tx_a, rx_b) = channel();
    let (tx_b, rx_a) = channel();
    let mk_shared = || Arc::new(Shared { sent_bytes: AtomicU64::new(0), recv_bytes: AtomicU64::new(0) });
    let client = LocalConn {
        tx: tx_a,
        rx: rx_a,
        link,
        fault: client_fault,
        sends: 0,
        dead: false,
        shared: mk_shared(),
        sleep_on_link,
    };
    let server = LocalConn {
        tx: tx_b,
        rx: rx_b,
        link,
        fault: FaultPlan::default(),
        sends: 0,
        dead: false,
        shared: mk_shared(),
        sleep_on_link: false, // model the link once, on the client side
    };
    (client, server)
}

/// Listener over an mpsc of pre-built server endpoints: the distributor
/// accepts them exactly like TCP connections.
pub struct LocalListener {
    rx: Receiver<LocalConn>,
}

pub struct LocalConnector {
    tx: Sender<LocalConn>,
    link: LinkModel,
    sleep_on_link: bool,
}

impl LocalConnector {
    /// Create a new client connection to the listener.
    pub fn connect(&self) -> Result<LocalConn> {
        self.connect_with_fault(FaultPlan::default())
    }

    pub fn connect_with_fault(&self, fault: FaultPlan) -> Result<LocalConn> {
        let (client, server) = pair_with_fault(self.link, self.sleep_on_link, fault);
        self.tx.send(server).map_err(|_| anyhow::anyhow!("listener closed"))?;
        Ok(client)
    }
}

impl Clone for LocalConnector {
    fn clone(&self) -> Self {
        Self { tx: self.tx.clone(), link: self.link, sleep_on_link: self.sleep_on_link }
    }
}

/// An in-process "endpoint": (listener for the server, connector for
/// clients).
pub fn endpoint(link: LinkModel, sleep_on_link: bool) -> (LocalListener, LocalConnector) {
    let (tx, rx) = channel();
    (LocalListener { rx }, LocalConnector { tx, link, sleep_on_link })
}

impl Listener for LocalListener {
    fn accept(&mut self) -> Result<Box<dyn Conn>> {
        let conn = self.rx.recv().map_err(|_| anyhow::anyhow!("all connectors dropped"))?;
        Ok(Box::new(conn))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_flow_both_ways() {
        let (mut c, mut s) = pair(LinkModel::FAST_LAN, false);
        c.send(&Message::TicketRequest).unwrap();
        assert_eq!(s.recv().unwrap(), Message::TicketRequest);
        s.send(&Message::NoTicket { retry_after_ms: 5 }).unwrap();
        assert_eq!(c.recv().unwrap(), Message::NoTicket { retry_after_ms: 5 });
        assert!(c.bytes().0 > 0);
    }

    #[test]
    fn fault_kills_after_n_sends() {
        let (mut c, mut s) =
            pair_with_fault(LinkModel::FAST_LAN, false, FaultPlan { die_after_sends: Some(2) });
        c.send(&Message::TicketRequest).unwrap();
        c.send(&Message::TicketRequest).unwrap();
        assert!(c.send(&Message::TicketRequest).is_err());
        assert!(c.recv().is_err()); // dead both ways
        // Server sees the two delivered messages then closed channel.
        assert!(s.recv().is_ok());
        assert!(s.recv().is_ok());
    }

    #[test]
    fn listener_accepts_connections() {
        let (mut listener, connector) = endpoint(LinkModel::FAST_LAN, false);
        let h = std::thread::spawn(move || {
            let mut server = listener.accept().unwrap();
            let m = server.recv().unwrap();
            server.send(&m).unwrap(); // echo
        });
        let mut client = connector.connect().unwrap();
        client.send(&Message::Ack).unwrap();
        assert_eq!(client.recv().unwrap(), Message::Ack);
        h.join().unwrap();
    }

    #[test]
    fn link_sleep_adds_latency() {
        let (mut c, mut s) = pair(LinkModel { latency_ms: 10.0, bytes_per_ms: 1e9 }, true);
        let t = std::time::Instant::now();
        c.send(&Message::Ack).unwrap();
        let _ = s.recv().unwrap();
        assert!(t.elapsed().as_secs_f64() * 1e3 >= 9.0);
    }
}
