//! WebSocket transport (RFC 6455), std-only.
//!
//! The paper's browsers speak WebSocket to the TicketDistributor; this
//! module makes that literal.  The same JSON documents as the
//! JSON-lines wire ride text frames one-per-message, so the protocol
//! layer ([`super::Message`], [`crate::coordinator::Session`]) is
//! untouched — a browser `new WebSocket("ws://host:port/")` +
//! `JSON.stringify`/`JSON.parse` is a complete client.
//!
//! Scope (deliberately the subset the protocol needs, hand-rolled so
//! the crate stays dependency-free):
//! * HTTP/1.1 upgrade handshake, both sides, with the RFC 6455
//!   `Sec-WebSocket-Accept` SHA-1/base64 proof;
//! * text frames (fragmentation supported on receive, never produced on
//!   send), ping/pong/close control frames;
//! * client→server masking (required by the RFC; servers send unmasked);
//! * RSV bits and unknown opcodes are protocol errors — the gateway's
//!   garbage-frame fault-injection relies on that.
//!
//! Two consumers: the blocking [`WsConn`] (a [`Conn`] for workers and
//! tests, mirroring [`super::tcp::TcpConn`]) and the non-blocking
//! [`WsFraming`] driven by the epoll gateway.

use std::io::{Read, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

use super::framing::{Framing, Inbound};
use super::{Conn, Message};
use crate::util::base64;
use crate::util::rng::SplitMix64;

/// The RFC 6455 handshake GUID.
const WS_GUID: &str = "258EAFA5-E914-47DA-95CA-C5AB0DC85B11";

/// Largest accepted frame payload (coalesced over fragments): generous
/// for dataset messages, small enough that a hostile length header
/// cannot balloon memory.
pub const MAX_FRAME_BYTES: usize = 16 << 20;

const OP_CONT: u8 = 0x0;
const OP_TEXT: u8 = 0x1;
const OP_BIN: u8 = 0x2;
const OP_CLOSE: u8 = 0x8;
const OP_PING: u8 = 0x9;
const OP_PONG: u8 = 0xA;

// ---------------------------------------------------------------------
// SHA-1 (handshake only — not a general-purpose hash).

/// SHA-1 of `data` (RFC 3174).  Used solely for the
/// `Sec-WebSocket-Accept` proof, which RFC 6455 pins to SHA-1; this is
/// an integrity token against misrouted proxies, not a security
/// boundary.
pub fn sha1(data: &[u8]) -> [u8; 20] {
    let mut h: [u32; 5] = [0x67452301, 0xEFCDAB89, 0x98BADCFE, 0x10325476, 0xC3D2E1F0];
    let ml = (data.len() as u64).wrapping_mul(8);
    let mut msg = data.to_vec();
    msg.push(0x80);
    while msg.len() % 64 != 56 {
        msg.push(0);
    }
    msg.extend_from_slice(&ml.to_be_bytes());
    let mut w = [0u32; 80];
    for chunk in msg.chunks_exact(64) {
        for (i, word) in chunk.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes([word[0], word[1], word[2], word[3]]);
        }
        for i in 16..80 {
            w[i] = (w[i - 3] ^ w[i - 8] ^ w[i - 14] ^ w[i - 16]).rotate_left(1);
        }
        let (mut a, mut b, mut c, mut d, mut e) = (h[0], h[1], h[2], h[3], h[4]);
        for (i, &wi) in w.iter().enumerate() {
            let (f, k) = match i {
                0..=19 => ((b & c) | ((!b) & d), 0x5A827999u32),
                20..=39 => (b ^ c ^ d, 0x6ED9EBA1),
                40..=59 => ((b & c) | (b & d) | (c & d), 0x8F1BBCDC),
                _ => (b ^ c ^ d, 0xCA62C1D6),
            };
            let tmp = a
                .rotate_left(5)
                .wrapping_add(f)
                .wrapping_add(e)
                .wrapping_add(k)
                .wrapping_add(wi);
            e = d;
            d = c;
            c = b.rotate_left(30);
            b = a;
            a = tmp;
        }
        h[0] = h[0].wrapping_add(a);
        h[1] = h[1].wrapping_add(b);
        h[2] = h[2].wrapping_add(c);
        h[3] = h[3].wrapping_add(d);
        h[4] = h[4].wrapping_add(e);
    }
    let mut out = [0u8; 20];
    for (i, word) in h.iter().enumerate() {
        out[i * 4..i * 4 + 4].copy_from_slice(&word.to_be_bytes());
    }
    out
}

/// The `Sec-WebSocket-Accept` value for a client's `Sec-WebSocket-Key`.
pub fn accept_key_for(key: &str) -> String {
    let mut buf = key.trim().as_bytes().to_vec();
    buf.extend_from_slice(WS_GUID.as_bytes());
    base64::encode(&sha1(&buf))
}

// ---------------------------------------------------------------------
// HTTP upgrade handshake.

/// Position *after* the `\r\n\r\n` header terminator, if complete.
pub fn find_header_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n").map(|p| p + 4)
}

fn header_value<'a>(head: &'a str, name: &str) -> Option<&'a str> {
    for line in head.lines().skip(1) {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case(name) {
                return Some(v.trim());
            }
        }
    }
    None
}

/// Validate a client's upgrade request head (everything before the
/// blank line) and build the `101 Switching Protocols` response.
pub fn server_handshake_response(head: &str) -> Result<String> {
    let first = head.lines().next().unwrap_or("");
    if !first.starts_with("GET ") {
        bail!("not an HTTP GET: {first:?}");
    }
    let upgrade = header_value(head, "Upgrade").unwrap_or("");
    if !upgrade.eq_ignore_ascii_case("websocket") {
        bail!("missing Upgrade: websocket header");
    }
    let key = header_value(head, "Sec-WebSocket-Key")
        .context("missing Sec-WebSocket-Key header")?;
    Ok(format!(
        "HTTP/1.1 101 Switching Protocols\r\n\
         Upgrade: websocket\r\n\
         Connection: Upgrade\r\n\
         Sec-WebSocket-Accept: {}\r\n\r\n",
        accept_key_for(key)
    ))
}

/// Build a client upgrade request for `path` on `hostport`; returns
/// (request, key) — the key validates the server's accept proof.
pub fn client_handshake_request(hostport: &str, path: &str, rng: &mut SplitMix64) -> (String, String) {
    let mut nonce = [0u8; 16];
    for chunk in nonce.chunks_mut(8) {
        let v = rng.next_u64().to_le_bytes();
        chunk.copy_from_slice(&v[..chunk.len()]);
    }
    let key = base64::encode(&nonce);
    let req = format!(
        "GET {path} HTTP/1.1\r\n\
         Host: {hostport}\r\n\
         Upgrade: websocket\r\n\
         Connection: Upgrade\r\n\
         Sec-WebSocket-Key: {key}\r\n\
         Sec-WebSocket-Version: 13\r\n\r\n"
    );
    (req, key)
}

// ---------------------------------------------------------------------
// Frame codec.

/// RFC 6455 framing as a [`Framing`]: text frames carry the JSON
/// documents; ping/pong/close surface as control [`Inbound`]s.  The
/// client side masks outbound frames (RFC requirement), the server
/// side sends unmasked; both sides accept either on receive.
pub struct WsFraming {
    mask_outbound: bool,
    mask_rng: SplitMix64,
    /// An in-progress fragmented message: (first-frame opcode, bytes).
    partial: Option<(u8, Vec<u8>)>,
    max_payload: usize,
}

impl WsFraming {
    /// Server side: unmasked outbound frames.
    pub fn server() -> WsFraming {
        WsFraming {
            mask_outbound: false,
            mask_rng: SplitMix64::new(0),
            partial: None,
            max_payload: MAX_FRAME_BYTES,
        }
    }

    /// Client side: masked outbound frames (mask bytes from `seed` —
    /// masking defeats proxy cache poisoning, not eavesdroppers, so a
    /// deterministic stream is fine and keeps tests reproducible).
    pub fn client(seed: u64) -> WsFraming {
        WsFraming {
            mask_outbound: true,
            mask_rng: SplitMix64::new(seed),
            partial: None,
            max_payload: MAX_FRAME_BYTES,
        }
    }

    fn frame(&mut self, opcode: u8, payload: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(payload.len() + 14);
        out.push(0x80 | opcode); // FIN, no RSV
        let mask_bit = if self.mask_outbound { 0x80u8 } else { 0 };
        if payload.len() < 126 {
            out.push(mask_bit | payload.len() as u8);
        } else if payload.len() <= u16::MAX as usize {
            out.push(mask_bit | 126);
            out.extend_from_slice(&(payload.len() as u16).to_be_bytes());
        } else {
            out.push(mask_bit | 127);
            out.extend_from_slice(&(payload.len() as u64).to_be_bytes());
        }
        if self.mask_outbound {
            let mask = self.mask_rng.next_u64().to_le_bytes();
            let mask = [mask[0], mask[1], mask[2], mask[3]];
            out.extend_from_slice(&mask);
            out.extend(payload.iter().enumerate().map(|(i, &b)| b ^ mask[i % 4]));
        } else {
            out.extend_from_slice(payload);
        }
        out
    }

    fn complete(&mut self, opcode: u8, payload: Vec<u8>) -> Result<Inbound> {
        match opcode {
            OP_TEXT | OP_BIN => match String::from_utf8(payload) {
                // Binary frames are accepted as documents too: the
                // payload is JSON text either way.
                Ok(s) => Ok(Inbound::Msg(s)),
                Err(_) => bail!("non-UTF-8 websocket message payload"),
            },
            other => bail!("unexpected completed opcode {other:#x}"),
        }
    }
}

impl Framing for WsFraming {
    fn extract(&mut self, buf: &mut Vec<u8>) -> Result<Option<Inbound>> {
        loop {
            if buf.len() < 2 {
                return Ok(None);
            }
            let b0 = buf[0];
            let b1 = buf[1];
            if b0 & 0x70 != 0 {
                bail!("websocket RSV bits set (no extension negotiated)");
            }
            let fin = b0 & 0x80 != 0;
            let opcode = b0 & 0x0F;
            let masked = b1 & 0x80 != 0;
            let mut idx = 2usize;
            let len7 = (b1 & 0x7F) as usize;
            let len = match len7 {
                126 => {
                    if buf.len() < idx + 2 {
                        return Ok(None);
                    }
                    let n = u16::from_be_bytes([buf[idx], buf[idx + 1]]) as usize;
                    idx += 2;
                    n
                }
                127 => {
                    if buf.len() < idx + 8 {
                        return Ok(None);
                    }
                    let mut b8 = [0u8; 8];
                    b8.copy_from_slice(&buf[idx..idx + 8]);
                    let n = u64::from_be_bytes(b8);
                    idx += 8;
                    if n > self.max_payload as u64 {
                        bail!("websocket frame of {n} bytes exceeds the {} cap", self.max_payload);
                    }
                    n as usize
                }
                n => n,
            };
            if len > self.max_payload {
                bail!("websocket frame of {len} bytes exceeds the {} cap", self.max_payload);
            }
            let mask = if masked {
                if buf.len() < idx + 4 {
                    return Ok(None);
                }
                let m = [buf[idx], buf[idx + 1], buf[idx + 2], buf[idx + 3]];
                idx += 4;
                Some(m)
            } else {
                None
            };
            if buf.len() < idx + len {
                return Ok(None);
            }
            let mut payload: Vec<u8> = buf[idx..idx + len].to_vec();
            buf.drain(..idx + len);
            if let Some(m) = mask {
                for (i, b) in payload.iter_mut().enumerate() {
                    *b ^= m[i % 4];
                }
            }
            if opcode >= OP_CLOSE {
                // Control frames: never fragmented, small.
                if !fin {
                    bail!("fragmented websocket control frame");
                }
                if len > 125 {
                    bail!("oversized websocket control frame ({len} bytes)");
                }
                match opcode {
                    OP_CLOSE => return Ok(Some(Inbound::Close)),
                    OP_PING => return Ok(Some(Inbound::Ping(payload))),
                    OP_PONG => return Ok(Some(Inbound::Pong)),
                    other => bail!("unknown websocket control opcode {other:#x}"),
                }
            }
            match opcode {
                OP_CONT => {
                    let Some((first_op, mut acc)) = self.partial.take() else {
                        bail!("websocket continuation frame with nothing to continue");
                    };
                    if acc.len() + payload.len() > self.max_payload {
                        bail!("fragmented websocket message exceeds the {} cap", self.max_payload);
                    }
                    acc.extend_from_slice(&payload);
                    if fin {
                        return self.complete(first_op, acc).map(Some);
                    }
                    self.partial = Some((first_op, acc));
                }
                OP_TEXT | OP_BIN => {
                    if self.partial.is_some() {
                        bail!("new websocket data frame inside a fragmented message");
                    }
                    if fin {
                        return self.complete(opcode, payload).map(Some);
                    }
                    self.partial = Some((opcode, payload));
                }
                other => bail!("unknown websocket opcode {other:#x}"),
            }
        }
    }

    fn frame_msg(&mut self, json: &str) -> Vec<u8> {
        self.frame(OP_TEXT, json.as_bytes())
    }

    fn frame_ping(&mut self) -> Vec<u8> {
        self.frame(OP_PING, b"hb")
    }

    fn frame_pong(&mut self, payload: &[u8]) -> Vec<u8> {
        self.frame(OP_PONG, payload)
    }

    fn frame_close(&mut self) -> Vec<u8> {
        self.frame(OP_CLOSE, &[])
    }
}

// ---------------------------------------------------------------------
// Blocking Conn.

/// Read from `stream` until the HTTP header terminator, appending to
/// `buf`; returns the index after `\r\n\r\n`.
fn read_header(stream: &mut TcpStream, buf: &mut Vec<u8>) -> Result<usize> {
    loop {
        if let Some(end) = find_header_end(buf) {
            return Ok(end);
        }
        if buf.len() > 64 << 10 {
            bail!("oversized handshake header");
        }
        let mut tmp = [0u8; 4096];
        let n = stream.read(&mut tmp).context("ws handshake read")?;
        if n == 0 {
            bail!("connection closed during websocket handshake");
        }
        buf.extend_from_slice(&tmp[..n]);
    }
}

/// A blocking WebSocket [`Conn`] — the worker-side mirror of
/// [`super::tcp::TcpConn`].  Transport-level pings are answered inline
/// inside [`recv`](Conn::recv), invisible to the protocol.
pub struct WsConn {
    stream: TcpStream,
    framing: WsFraming,
    inbuf: Vec<u8>,
    sent: u64,
    received: u64,
}

impl WsConn {
    /// Connect and upgrade.  Accepts `ws://host:port/path` or a bare
    /// `host:port`.
    pub fn connect(addr: &str) -> Result<WsConn> {
        let rest = addr.strip_prefix("ws://").unwrap_or(addr);
        let (hostport, path) = match rest.find('/') {
            Some(i) => (&rest[..i], &rest[i..]),
            None => (rest, "/"),
        };
        let mut stream =
            TcpStream::connect(hostport).with_context(|| format!("connecting to {hostport}"))?;
        stream.set_nodelay(true).ok();
        let mut rng = SplitMix64::new(
            crate::util::clock::now_us() ^ (std::process::id() as u64) << 32 ^ 0x5157_7357,
        );
        let (req, key) = client_handshake_request(hostport, path, &mut rng);
        stream.write_all(req.as_bytes()).context("ws handshake send")?;
        let mut buf = Vec::new();
        let end = read_header(&mut stream, &mut buf)?;
        let head = String::from_utf8_lossy(&buf[..end]).into_owned();
        let status = head.lines().next().unwrap_or("");
        if !status.contains(" 101") {
            bail!("websocket upgrade refused: {status:?}");
        }
        let accept = header_value(&head, "Sec-WebSocket-Accept").unwrap_or("");
        if accept != accept_key_for(&key) {
            bail!("bad Sec-WebSocket-Accept (got {accept:?})");
        }
        let inbuf = buf[end..].to_vec();
        Ok(WsConn {
            stream,
            framing: WsFraming::client(rng.next_u64()),
            inbuf,
            sent: 0,
            received: 0,
        })
    }

    /// Server-side upgrade of an accepted socket (the blocking
    /// counterpart of the gateway's reactor path; used by tests).
    pub fn accept(mut stream: TcpStream) -> Result<WsConn> {
        stream.set_nodelay(true).ok();
        let mut buf = Vec::new();
        let end = read_header(&mut stream, &mut buf)?;
        let head = String::from_utf8_lossy(&buf[..end]).into_owned();
        let resp = server_handshake_response(&head)?;
        stream.write_all(resp.as_bytes()).context("ws handshake reply")?;
        let inbuf = buf[end..].to_vec();
        Ok(WsConn { stream, framing: WsFraming::server(), inbuf, sent: 0, received: 0 })
    }
}

impl Conn for WsConn {
    fn send(&mut self, m: &Message) -> Result<()> {
        let frame = self.framing.frame_msg(&m.encode());
        self.stream.write_all(&frame).context("ws send")?;
        self.sent += frame.len() as u64;
        Ok(())
    }

    fn recv(&mut self) -> Result<Message> {
        loop {
            match self.framing.extract(&mut self.inbuf)? {
                Some(Inbound::Msg(doc)) => return Message::decode(&doc),
                Some(Inbound::Ping(payload)) => {
                    let pong = self.framing.frame_pong(&payload);
                    self.stream.write_all(&pong).context("ws pong")?;
                    self.sent += pong.len() as u64;
                }
                Some(Inbound::Pong) => {}
                Some(Inbound::Close) => bail!("connection closed by peer (websocket close)"),
                None => {
                    let mut tmp = [0u8; 16384];
                    let n = self.stream.read(&mut tmp).context("ws recv")?;
                    if n == 0 {
                        bail!("connection closed by peer");
                    }
                    self.received += n as u64;
                    self.inbuf.extend_from_slice(&tmp[..n]);
                }
            }
        }
    }

    fn bytes(&self) -> (u64, u64) {
        (self.sent, self.received)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::{TaskId, TicketId};
    use crate::util::json::Value;

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    /// RFC 3174 test vectors.
    #[test]
    fn sha1_known_answers() {
        assert_eq!(hex(&sha1(b"abc")), "a9993e364706816aba3e25717850c26c9cd0d89d");
        assert_eq!(hex(&sha1(b"")), "da39a3ee5e6b4b0d3255bfef95601890afd80709");
        assert_eq!(
            hex(&sha1(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq")),
            "84983e441c3bd26ebaae4aa1f95129e5e54670f1"
        );
        // A two-block message (>64 bytes).
        assert_eq!(
            hex(&sha1(b"The quick brown fox jumps over the lazy dog")),
            "2fd4e1c67a2d28fced849ee1bb76e7391b93eb12"
        );
    }

    /// The RFC 6455 §1.3 handshake example.
    #[test]
    fn accept_key_matches_rfc_example() {
        assert_eq!(accept_key_for("dGhlIHNhbXBsZSBub25jZQ=="), "s3pPLMBiTxaQ9kYGzzhZRbK+xOo=");
    }

    #[test]
    fn handshake_request_response_pair() {
        let mut rng = SplitMix64::new(7);
        let (req, key) = client_handshake_request("127.0.0.1:9", "/", &mut rng);
        assert!(req.ends_with("\r\n\r\n"));
        let resp = server_handshake_response(&req).unwrap();
        assert!(resp.starts_with("HTTP/1.1 101"));
        assert!(resp.contains(&accept_key_for(&key)));
        // A plain HTTP request is refused.
        assert!(server_handshake_response("GET / HTTP/1.1\r\nHost: x\r\n").is_err());
        assert!(server_handshake_response("POST / HTTP/1.1\r\n").is_err());
    }

    fn roundtrip_via(tx: &mut WsFraming, rx: &mut WsFraming, doc: &str) {
        let mut buf = tx.frame_msg(doc);
        assert_eq!(rx.extract(&mut buf).unwrap(), Some(Inbound::Msg(doc.to_string())));
        assert!(buf.is_empty());
    }

    #[test]
    fn frames_roundtrip_both_directions() {
        let mut client = WsFraming::client(42);
        let mut server = WsFraming::server();
        roundtrip_via(&mut client, &mut server, r#"{"t":"ack"}"#);
        roundtrip_via(&mut server, &mut client, r#"{"t":"reload"}"#);
        // Payload sizes straddling the 126 and 65536 length encodings.
        for n in [0usize, 125, 126, 127, 65_535, 65_536, 70_001] {
            let doc: String = "x".repeat(n);
            roundtrip_via(&mut client, &mut server, &doc);
            roundtrip_via(&mut server, &mut client, &doc);
        }
    }

    #[test]
    fn extract_handles_partial_frames() {
        let mut client = WsFraming::client(1);
        let mut server = WsFraming::server();
        let frame = client.frame_msg(r#"{"t":"ack"}"#);
        let mut buf = Vec::new();
        for (i, &b) in frame.iter().enumerate() {
            buf.push(b);
            let got = server.extract(&mut buf).unwrap();
            if i + 1 < frame.len() {
                assert_eq!(got, None, "complete message before byte {}", i + 1);
            } else {
                assert_eq!(got, Some(Inbound::Msg(r#"{"t":"ack"}"#.into())));
            }
        }
    }

    #[test]
    fn fragmented_text_reassembles() {
        let mut server = WsFraming::server();
        // Hand-built: "he" (text, no FIN) + "llo" (continuation, FIN).
        let mut buf = vec![OP_TEXT, 2, b'h', b'e', 0x80 | OP_CONT, 3, b'l', b'l', b'o'];
        assert_eq!(server.extract(&mut buf).unwrap(), Some(Inbound::Msg("hello".into())));
        // A control frame interleaved mid-fragmentation is legal.
        let mut buf = vec![OP_TEXT, 1, b'a', 0x80 | OP_PING, 1, b'p', 0x80 | OP_CONT, 1, b'b'];
        assert_eq!(server.extract(&mut buf).unwrap(), Some(Inbound::Ping(vec![b'p'])));
        assert_eq!(server.extract(&mut buf).unwrap(), Some(Inbound::Msg("ab".into())));
    }

    #[test]
    fn control_frames_surface_as_events() {
        let mut client = WsFraming::client(3);
        let mut server = WsFraming::server();
        let mut buf = client.frame_ping();
        match server.extract(&mut buf).unwrap() {
            Some(Inbound::Ping(p)) => {
                let mut pong = server.frame_pong(&p);
                assert_eq!(client.extract(&mut pong).unwrap(), Some(Inbound::Pong));
            }
            other => panic!("{other:?}"),
        }
        let mut close = client.frame_close();
        assert_eq!(server.extract(&mut close).unwrap(), Some(Inbound::Close));
    }

    #[test]
    fn garbage_frames_are_protocol_errors() {
        // RSV bits set.
        let mut f = WsFraming::server();
        assert!(f.extract(&mut vec![0xF2, 0x00]).is_err());
        // Unknown data opcode.
        let mut f = WsFraming::server();
        assert!(f.extract(&mut vec![0x83, 0x00]).is_err());
        // Continuation with nothing to continue.
        let mut f = WsFraming::server();
        assert!(f.extract(&mut vec![0x80, 0x01, b'x']).is_err());
        // Fragmented control frame (PING without FIN).
        let mut f = WsFraming::server();
        assert!(f.extract(&mut vec![OP_PING, 0x00]).is_err());
        // 64-bit length over the cap.
        let mut f = WsFraming::server();
        let mut buf = vec![0x80 | OP_TEXT, 127];
        buf.extend_from_slice(&(u64::MAX).to_be_bytes());
        assert!(f.extract(&mut buf).is_err());
    }

    /// Blocking loopback: WsConn client against a WsConn::accept server
    /// thread, real sockets, full upgrade.
    #[test]
    fn ws_conn_roundtrip_on_loopback() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut server = WsConn::accept(stream).unwrap();
            loop {
                match server.recv() {
                    Ok(Message::Shutdown) | Err(_) => break,
                    Ok(m) => server.send(&m).unwrap(),
                }
            }
        });
        let mut client = WsConn::connect(&format!("ws://{addr}/")).unwrap();
        let msg = Message::Ticket {
            ticket: TicketId(1),
            task: TaskId(2),
            task_name: "echo".into(),
            index: 0,
            payload: Value::obj(vec![("x", Value::num(1.5))]),
        };
        client.send(&msg).unwrap();
        assert_eq!(client.recv().unwrap(), msg);
        client.send(&Message::Shutdown).unwrap();
        h.join().unwrap();
        let (sent, recv) = client.bytes();
        assert!(sent > 0 && recv > 0);
    }
}
