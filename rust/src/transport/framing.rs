//! Byte-level framing, factored out of the sockets.
//!
//! The blocking transports ([`tcp`](super::tcp), [`ws`](super::ws))
//! and the async gateway ([`crate::coordinator::gateway`]) all move the
//! same JSON [`Message`](super::Message)s; what differs per transport
//! is only how a byte stream is cut into documents.  A [`Framing`]
//! turns an append-only inbound byte buffer into [`Inbound`] events and
//! outbound strings into wire bytes, with no I/O of its own — so the
//! epoll reactor can drive any framing from non-blocking reads, and the
//! conformance suite can byte-compare transports against each other.
//!
//! Two implementations:
//! * [`LineFraming`] — one JSON document per `\n` (the legacy TCP wire);
//! * [`ws::WsFraming`](super::ws::WsFraming) — RFC 6455 frames, text
//!   opcode carrying the same JSON documents, plus ping/pong/close
//!   control frames (which surface as their own [`Inbound`] variants so
//!   heartbeats never touch the JSON protocol).

use anyhow::{bail, Result};

/// One event extracted from the inbound byte stream.
#[derive(Debug, Clone, PartialEq)]
pub enum Inbound {
    /// A complete protocol document (JSON text, undecoded).
    Msg(String),
    /// Transport-level ping (WS control frame); answer with
    /// [`Framing::frame_pong`] echoing the payload.
    Ping(Vec<u8>),
    /// Transport-level pong — liveness evidence, no reply.
    Pong,
    /// Orderly transport-level close.
    Close,
}

/// A stateful byte-stream codec: cut inbound bytes into [`Inbound`]
/// events, wrap outbound documents into wire bytes.
pub trait Framing: Send {
    /// Try to extract one event from the front of `buf` (consuming its
    /// bytes).  `Ok(None)` = need more bytes; `Err` = the stream is not
    /// valid for this framing (protocol violation — close the
    /// connection).  Call in a loop until `None` to drain a read.
    fn extract(&mut self, buf: &mut Vec<u8>) -> Result<Option<Inbound>>;

    /// Wrap one encoded protocol document for the wire.
    fn frame_msg(&mut self, json: &str) -> Vec<u8>;

    /// A transport-level ping, empty if the framing has none (line
    /// framing: heartbeats are read-timeout-only, because an
    /// unsolicited line would desync the strict request/response JSON
    /// protocol).
    fn frame_ping(&mut self) -> Vec<u8>;

    /// A pong echoing `payload` (empty if the framing has none).
    fn frame_pong(&mut self, payload: &[u8]) -> Vec<u8>;

    /// An orderly transport-level close (empty if the framing has none).
    fn frame_close(&mut self) -> Vec<u8>;
}

/// The legacy wire: one JSON document per `\n`-terminated line
/// (trailing `\r` tolerated, empty lines skipped).  No control frames —
/// liveness on this framing is inferred from read silence alone.
#[derive(Debug, Default)]
pub struct LineFraming;

impl LineFraming {
    pub fn new() -> LineFraming {
        LineFraming
    }
}

impl Framing for LineFraming {
    fn extract(&mut self, buf: &mut Vec<u8>) -> Result<Option<Inbound>> {
        loop {
            let Some(pos) = buf.iter().position(|&b| b == b'\n') else {
                return Ok(None);
            };
            let mut line: Vec<u8> = buf.drain(..=pos).collect();
            line.pop(); // the '\n'
            if line.last() == Some(&b'\r') {
                line.pop();
            }
            if line.is_empty() {
                continue; // blank keepalive line, skip
            }
            match String::from_utf8(line) {
                Ok(s) => return Ok(Some(Inbound::Msg(s))),
                Err(_) => bail!("non-UTF-8 line on the JSON-lines wire"),
            }
        }
    }

    fn frame_msg(&mut self, json: &str) -> Vec<u8> {
        let mut out = Vec::with_capacity(json.len() + 1);
        out.extend_from_slice(json.as_bytes());
        out.push(b'\n');
        out
    }

    fn frame_ping(&mut self) -> Vec<u8> {
        Vec::new()
    }

    fn frame_pong(&mut self, _payload: &[u8]) -> Vec<u8> {
        Vec::new()
    }

    fn frame_close(&mut self) -> Vec<u8> {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_framing_roundtrip() {
        let mut f = LineFraming::new();
        let mut buf = f.frame_msg(r#"{"t":"ack"}"#);
        buf.extend_from_slice(f.frame_msg(r#"{"t":"reload"}"#).as_slice());
        assert_eq!(f.extract(&mut buf).unwrap(), Some(Inbound::Msg(r#"{"t":"ack"}"#.into())));
        assert_eq!(f.extract(&mut buf).unwrap(), Some(Inbound::Msg(r#"{"t":"reload"}"#.into())));
        assert_eq!(f.extract(&mut buf).unwrap(), None);
        assert!(buf.is_empty());
    }

    #[test]
    fn line_framing_partial_then_complete() {
        let mut f = LineFraming::new();
        let mut buf = b"{\"t\":\"ac".to_vec();
        assert_eq!(f.extract(&mut buf).unwrap(), None);
        buf.extend_from_slice(b"k\"}\n");
        assert_eq!(f.extract(&mut buf).unwrap(), Some(Inbound::Msg(r#"{"t":"ack"}"#.into())));
    }

    #[test]
    fn line_framing_tolerates_crlf_and_blank_lines() {
        let mut f = LineFraming::new();
        let mut buf = b"\r\n\n{\"t\":\"ack\"}\r\n".to_vec();
        assert_eq!(f.extract(&mut buf).unwrap(), Some(Inbound::Msg(r#"{"t":"ack"}"#.into())));
        assert_eq!(f.extract(&mut buf).unwrap(), None);
    }

    #[test]
    fn line_framing_rejects_non_utf8() {
        let mut f = LineFraming::new();
        let mut buf = vec![0xFF, 0xFE, b'\n'];
        assert!(f.extract(&mut buf).is_err());
    }

    #[test]
    fn line_framing_has_no_control_frames() {
        let mut f = LineFraming::new();
        assert!(f.frame_ping().is_empty());
        assert!(f.frame_pong(b"x").is_empty());
        assert!(f.frame_close().is_empty());
    }
}
