//! TCP transport: one JSON document per line over std::net sockets.
//!
//! This is the deployment transport (`sashimi serve` / `sashimi worker
//! --connect host:port`); the protocol is identical to the in-process
//! transport, so the distributor and worker are transport-agnostic.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use super::{Conn, Listener, Message};

pub struct TcpConn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    sent: Arc<AtomicU64>,
    received: Arc<AtomicU64>,
}

impl TcpConn {
    pub fn connect(addr: &str) -> Result<TcpConn> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        Self::from_stream(stream)
    }

    pub fn from_stream(stream: TcpStream) -> Result<TcpConn> {
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone().context("cloning stream")?);
        Ok(TcpConn {
            reader,
            writer: stream,
            sent: Arc::new(AtomicU64::new(0)),
            received: Arc::new(AtomicU64::new(0)),
        })
    }
}

impl Conn for TcpConn {
    fn send(&mut self, m: &Message) -> Result<()> {
        let mut line = m.encode();
        line.push('\n');
        self.writer.write_all(line.as_bytes()).context("tcp send")?;
        self.sent.fetch_add(line.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    fn recv(&mut self) -> Result<Message> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).context("tcp recv")?;
        if n == 0 {
            anyhow::bail!("connection closed by peer");
        }
        self.received.fetch_add(n as u64, Ordering::Relaxed);
        Message::decode(line.trim_end())
    }

    fn bytes(&self) -> (u64, u64) {
        (self.sent.load(Ordering::Relaxed), self.received.load(Ordering::Relaxed))
    }
}

pub struct TcpListenerWrap {
    listener: TcpListener,
    pub local_addr: String,
}

impl TcpListenerWrap {
    /// Bind; use port 0 for an ephemeral port (tests).
    pub fn bind(addr: &str) -> Result<TcpListenerWrap> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local_addr = listener.local_addr()?.to_string();
        Ok(TcpListenerWrap { listener, local_addr })
    }
}

impl Listener for TcpListenerWrap {
    fn accept(&mut self) -> Result<Box<dyn Conn>> {
        let (stream, _) = self.listener.accept().context("tcp accept")?;
        Ok(Box::new(TcpConn::from_stream(stream)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::{TaskId, TicketId};
    use crate::util::json::Value;

    #[test]
    fn tcp_roundtrip_on_loopback() {
        let mut listener = TcpListenerWrap::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr.clone();
        let h = std::thread::spawn(move || {
            let mut server = listener.accept().unwrap();
            loop {
                match server.recv() {
                    Ok(Message::Shutdown) | Err(_) => break,
                    Ok(m) => server.send(&m).unwrap(),
                }
            }
        });
        let mut client = TcpConn::connect(&addr).unwrap();
        let msg = Message::Ticket {
            ticket: TicketId(1),
            task: TaskId(2),
            task_name: "echo".into(),
            index: 0,
            payload: Value::obj(vec![("x", Value::num(1.5))]),
        };
        client.send(&msg).unwrap();
        assert_eq!(client.recv().unwrap(), msg);
        client.send(&Message::Shutdown).unwrap();
        h.join().unwrap();
        let (sent, recv) = client.bytes();
        assert!(sent > 0 && recv > 0);
    }

    #[test]
    fn closed_peer_is_an_error() {
        let mut listener = TcpListenerWrap::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr.clone();
        let h = std::thread::spawn(move || {
            let _ = listener.accept().unwrap(); // drop immediately
        });
        let mut client = TcpConn::connect(&addr).unwrap();
        h.join().unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(client.recv().is_err());
    }

    /// Newline framing must survive arbitrary TCP segmentation: two
    /// messages written in 3-byte chunks (chunks straddle the frame
    /// boundary, so this also covers coalesced frames) arrive as exactly
    /// two intact messages.
    #[test]
    fn framing_survives_partial_writes() {
        use std::io::Write as _;

        let mut listener = TcpListenerWrap::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr.clone();
        let h = std::thread::spawn(move || {
            let mut server = listener.accept().unwrap();
            (server.recv().unwrap(), server.recv().unwrap())
        });

        let first = Message::Data { key: "k".into(), shape: vec![2, 2], b64: "QUJDRA==".into() };
        let mut bytes = first.encode().into_bytes();
        bytes.push(b'\n');
        bytes.extend_from_slice(Message::TicketRequest.encode().as_bytes());
        bytes.push(b'\n');

        let mut raw = std::net::TcpStream::connect(&addr).unwrap();
        raw.set_nodelay(true).unwrap();
        for chunk in bytes.chunks(3) {
            raw.write_all(chunk).unwrap();
            raw.flush().unwrap();
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        let (a, b) = h.join().unwrap();
        assert_eq!(a, first);
        assert_eq!(b, Message::TicketRequest);
    }

    /// A connection dropped mid-frame yields an error, never a truncated
    /// message; a non-protocol line is a decode error, not a hang.
    #[test]
    fn connection_drop_mid_frame_is_error() {
        use std::io::Write as _;

        let mut listener = TcpListenerWrap::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr.clone();
        let h = std::thread::spawn(move || {
            let mut server = listener.accept().unwrap();
            (server.recv(), server.recv())
        });
        let mut raw = std::net::TcpStream::connect(&addr).unwrap();
        raw.write_all(b"not a protocol line\n").unwrap();
        raw.write_all(br#"{"t":"ack""#).unwrap(); // no terminating newline
        raw.flush().unwrap();
        drop(raw);
        let (garbage, truncated) = h.join().unwrap();
        assert!(garbage.is_err(), "garbage line must not decode");
        assert!(truncated.is_err(), "half frame must not be delivered");
    }
}
