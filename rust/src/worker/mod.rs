//! Worker: the browser node, faithfully replaying §2.1.2's basic-program
//! loop.
//!
//! ```text
//! 1. connect (Hello)                      -> WebSocket open
//! 2. TicketRequest                        -> step 2
//! 3. TaskRequest if task not cached       -> step 3
//! 4. DataRequest per missing dataset      -> step 4
//! 5. execute the task                     -> step 5
//! 6. TicketResult                         -> step 6
//! 7. goto 2                               -> step 7
//! ```
//!
//! Extras the paper specifies and this module implements:
//! * task code and datasets cached under an LRU byte budget (browser GC);
//! * on execution error: ErrorReport with a stack trace, then the worker
//!   *reloads itself* (cache cleared, reconnect) and continues;
//! * device heterogeneity via [`DeviceProfile`]: the real compute runs,
//!   then the ticket is padded to `elapsed / speed` (DESIGN.md §7).
//!
//! Two departures from the one-ticket-per-round-trip basic program, both
//! aimed at the coordinator RTT that bounds fast-link throughput
//! (DESIGN.md §2.3):
//! * **Prefetch queue** — step 2 sends `TicketBatchRequest { max }` and
//!   queues the returned batch locally; results are flushed back as one
//!   `TicketResults` per batch.  The batch size adapts: it starts at 1,
//!   doubles toward [`Worker::prefetch_cap`] while a whole batch
//!   executes faster than the round trip that fetched it (link-bound),
//!   and halves on `NoTicket` or errors.  `prefetch_cap = 1` restores
//!   the paper's exact single-ticket wire protocol.
//! * **Idle backoff** — `NoTicket` sleeps grow exponentially with the
//!   idle streak (jittered, capped at [`Worker::idle_backoff_cap_ms`]),
//!   so an idle fleet does not hammer the coordinator in lockstep at
//!   the retry hint.
//!
//! The failure path is *active* (DESIGN.md §2.4), not the paper's
//! passive wait-out-the-window story:
//! * a failing ticket does not interrupt its batch — the report is
//!   queued, the rest of the queue executes, and every failure flushes
//!   as one `ErrorReports` round trip answered by one Reload, after
//!   which the worker reloads itself once (cache cleared, fresh
//!   connection);
//! * on stop/shutdown the worker flushes finished results, flushes
//!   queued reports, and hands the unexecuted queue back in one
//!   `ReleaseTickets` round trip, so nothing it holds strands;
//! * if the transport dies mid-batch the queue is dropped — the
//!   coordinator's disconnect release (or, with it disabled, §2.1.2
//!   redistribution) re-arms those tickets, and re-executing them
//!   locally would only race the re-dispatch.
//!
//! Unacknowledged *result* flushes are retried on the next connection —
//! at-least-once, with the store's first-result-wins dedup absorbing
//! any repeat.  Completed tickets are only counted once a flush is
//! acknowledged, so a `max_tickets`-bounded worker's ledger is exact.

pub mod profile;

pub use profile::DeviceProfile;

use std::collections::VecDeque;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context as _, Result};

use crate::runtime::{SharedRuntime, Tensor};
use crate::store::TicketId;
use crate::tasks::{Registry, TaskContext, TaskDef};
use crate::transport::{Conn, Message, WireError, WireTicket};
use crate::util::base64;
use crate::util::clock::{Clock, PaddedTimer, WallClock};
use crate::util::json::Value;
use crate::util::lru::LruCache;
use crate::util::rng::SplitMix64;

/// What a worker did during `run` (asserted by tests/benches).
#[derive(Debug, Default, Clone)]
pub struct WorkerReport {
    pub tickets_completed: u64,
    pub errors_reported: u64,
    pub reloads: u64,
    pub reconnects: u64,
    pub busy_ms: f64,
    pub idle_polls: u64,
    pub task_fetches: u64,
    pub data_fetches: u64,
    /// `Tickets` batches received (batch protocol only).
    pub prefetch_batches: u64,
    /// Largest batch the adaptive sizing actually received.
    pub peak_batch: u64,
    /// Tickets handed back via `ReleaseTickets` (stop/shutdown with a
    /// non-empty prefetch queue).
    pub tickets_released: u64,
}

enum CacheEntry {
    TaskCode,
    Data(Arc<Tensor>),
}

/// The per-connection task context: datasets resolve through the LRU
/// cache, falling back to DataRequest messages on the wire.
struct WireContext<'a> {
    conn: &'a mut dyn Conn,
    cache: &'a mut LruCache<String, CacheEntry>,
    runtime: Option<&'a SharedRuntime>,
    data_fetches: &'a mut u64,
    /// Set when a transport op failed (or desynced) inside `dataset`:
    /// the worker then reconnects instead of misreporting a dead link
    /// as a task error (see [`ExecError`]).
    conn_failed: &'a mut bool,
}

impl TaskContext for WireContext<'_> {
    fn dataset(&mut self, key: &str) -> Result<Arc<Tensor>> {
        if let Some(CacheEntry::Data(t)) = self.cache.get(&key.to_string()) {
            return Ok(Arc::clone(t));
        }
        *self.data_fetches += 1;
        if let Err(e) = self.conn.send(&Message::DataRequest { key: key.to_string() }) {
            *self.conn_failed = true;
            return Err(e);
        }
        let reply = match self.conn.recv() {
            Ok(m) => m,
            Err(e) => {
                *self.conn_failed = true;
                return Err(e);
            }
        };
        match reply {
            Message::Data { key: k, shape, b64 } => {
                if k != key {
                    // A reply for a different key is a desynced stream,
                    // same as a non-Data reply: reconnect, don't report.
                    *self.conn_failed = true;
                    anyhow::bail!("dataset key mismatch: {k} != {key}");
                }
                let data = base64::decode_f32(&b64)?;
                let t = Arc::new(Tensor::new(shape, data)?);
                let bytes = t.size_bytes();
                self.cache.put(key.to_string(), CacheEntry::Data(Arc::clone(&t)), bytes);
                Ok(t)
            }
            m => {
                // Desynced stream: poison the connection, don't guess.
                *self.conn_failed = true;
                anyhow::bail!("expected Data, got {m:?}")
            }
        }
    }

    fn runtime(&self) -> Result<&SharedRuntime> {
        self.runtime.context("worker has no XLA runtime configured")
    }
}

/// Why one ticket's execution failed: a dead or desynced transport
/// (reconnect — the coordinator's disconnect release or §2.1.2
/// redistribution re-arms the work, there is nothing to report) versus
/// a genuine task failure (queue an error report for the batch flush).
enum ExecError {
    Conn(anyhow::Error),
    Task(anyhow::Error),
}

pub struct Worker {
    pub id: String,
    pub profile: DeviceProfile,
    registry: Registry,
    runtime: Option<SharedRuntime>,
    cache: LruCache<String, CacheEntry>,
    /// Cap on tickets to execute (None = until Shutdown/stop).
    pub max_tickets: Option<u64>,
    /// Upper bound on the adaptive prefetch batch.  `1` disables
    /// batching entirely and speaks the paper's exact single-ticket
    /// protocol (`TicketRequest`/`TicketResult`).
    pub prefetch_cap: usize,
    /// Cap on the exponential `NoTicket` backoff sleep (ms).
    pub idle_backoff_cap_ms: u64,
    /// Time source for backoff/reconnect sleeps (DESIGN.md §2.5).
    /// Wall clock by default; tests inject a virtual clock so idle
    /// workers yield instead of really sleeping.  RTT and padding keep
    /// reading real monotonic time — they measure this host, not
    /// simulated time.
    clock: Arc<dyn Clock>,
}

/// Default [`Worker::prefetch_cap`]: modest enough that compute-bound
/// tickets stay effectively unbatched (the batch only grows while a
/// whole batch runs faster than one round trip).
pub const DEFAULT_PREFETCH_CAP: usize = 8;

/// The adaptive prefetch state machine (DESIGN.md §2.3), extracted so
/// its transitions are unit-testable and the churn simulator can run
/// the *same* sizing policy as the threaded worker.
///
/// * starts at 1 ticket per fetch;
/// * [`on_batch_done`]: a whole error-free batch that executed faster
///   than the round trip that fetched it is link-bound — double the
///   batch, clamped to the cap;
/// * [`on_no_ticket`] / [`on_error`]: halve (never below 1) — an empty
///   pool wants small probes, a failing batch wants less speculation;
/// * `cap = 1` pins the size at 1: the paper's single-ticket protocol.
///
/// [`on_batch_done`]: PrefetchController::on_batch_done
/// [`on_no_ticket`]: PrefetchController::on_no_ticket
/// [`on_error`]: PrefetchController::on_error
#[derive(Debug, Clone)]
pub struct PrefetchController {
    size: usize,
    cap: usize,
}

impl PrefetchController {
    pub fn new(cap: usize) -> PrefetchController {
        PrefetchController { size: 1, cap: cap.max(1) }
    }

    /// Tickets to ask for in the next `TicketBatchRequest`.
    pub fn size(&self) -> usize {
        self.size
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// An error-free batch finished: grow iff it was link-bound (total
    /// execution beat the fetch round trip) and the cap allows.
    pub fn on_batch_done(&mut self, batch_exec_ms: f64, fetch_rtt_ms: f64) {
        if batch_exec_ms < fetch_rtt_ms && self.size < self.cap {
            self.size = (self.size * 2).min(self.cap);
        }
    }

    /// The pool answered `NoTicket`: probe smaller next time.
    pub fn on_no_ticket(&mut self) {
        self.size = (self.size / 2).max(1);
    }

    /// A ticket in the batch failed: speculate less.
    pub fn on_error(&mut self) {
        self.size = (self.size / 2).max(1);
    }
}

/// Reconnect pacing: capped exponential backoff with multiplicative
/// jitter, the same shape as the `NoTicket` idle backoff.
///
/// A fixed 10 ms retry turns a briefly-down coordinator into a
/// re-resolve + re-handshake hammer — and a fleet that lost the
/// coordinator together retries in lockstep forever.  Instead the nth
/// consecutive failure sleeps a jittered value in `[c/2, c]` where
/// `c = min(base × 2ⁿ, cap)`; any success resets the streak.
#[derive(Debug, Clone)]
pub struct ReconnectBackoff {
    base_ms: u64,
    cap_ms: u64,
    streak: u32,
}

impl ReconnectBackoff {
    pub fn new(base_ms: u64, cap_ms: u64) -> ReconnectBackoff {
        let base_ms = base_ms.max(1);
        ReconnectBackoff { base_ms, cap_ms: cap_ms.max(base_ms), streak: 0 }
    }

    /// Consecutive failures since the last success.
    pub fn streak(&self) -> u32 {
        self.streak
    }

    /// Record a failure and pick the sleep for it, in ms.  The shift is
    /// clamped far above where `cap_ms` saturates, so the cap — not the
    /// clamp — is what bounds the ceiling.
    pub fn on_failure(&mut self, rng: &mut SplitMix64) -> u64 {
        let ceiling =
            self.base_ms.saturating_mul(1u64 << self.streak.min(20)).min(self.cap_ms);
        self.streak = self.streak.saturating_add(1);
        ceiling / 2 + rng.gen_range(ceiling / 2 + 1)
    }

    /// A connection made it through Hello/Ack: forget the streak.
    pub fn reset(&mut self) {
        self.streak = 0;
    }
}

impl Worker {
    pub fn new(id: &str, profile: DeviceProfile, registry: Registry) -> Worker {
        Worker {
            id: id.to_string(),
            profile,
            registry,
            runtime: None,
            cache: LruCache::new(256 << 20), // 256 MiB, a browser-ish budget
            max_tickets: None,
            prefetch_cap: DEFAULT_PREFETCH_CAP,
            idle_backoff_cap_ms: 200,
            clock: Arc::new(WallClock),
        }
    }

    /// Inject a time source for backoff sleeps (virtual under tests).
    pub fn with_clock(mut self, clock: Arc<dyn Clock>) -> Worker {
        self.clock = clock;
        self
    }

    pub fn with_runtime(mut self, rt: SharedRuntime) -> Worker {
        self.runtime = Some(rt);
        self
    }

    pub fn with_cache_bytes(mut self, bytes: usize) -> Worker {
        self.cache = LruCache::new(bytes);
        self
    }

    /// Set the prefetch ceiling (`1` = legacy single-ticket protocol).
    pub fn with_prefetch_cap(mut self, cap: usize) -> Worker {
        self.prefetch_cap = cap.max(1);
        self
    }

    /// Run the browser loop until Shutdown, `stop`, connection failure
    /// with no reconnect budget, or `max_tickets`.
    ///
    /// `connect` reopens the transport (used both at start and on
    /// reload); a worker tolerates `max_reconnects` consecutive failures.
    pub fn run<F>(&mut self, connect: F, stop: &AtomicBool) -> WorkerReport
    where
        F: Fn() -> Result<Box<dyn Conn>>,
    {
        let mut report = WorkerReport::default();
        let max_reconnects = 5u32;
        let mut consecutive_failures = 0u32;
        // Adaptive prefetch sizing (survives reconnects: link quality,
        // not connection identity, is what it tracks).
        let cap = self.prefetch_cap.max(1);
        let mut prefetch = PrefetchController::new(cap);
        let mut idle_streak: u32 = 0;
        let mut jitter = SplitMix64::new(
            self.id.bytes().fold(0x5EEDu64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64)),
        );
        // The result flush buffer and queued error reports survive
        // reloads and reconnects; the prefetch queue itself is dropped
        // when the transport dies (module docs: the coordinator's
        // disconnect release re-arms it) and explicitly released on
        // stop/shutdown.
        let mut queue: VecDeque<WireTicket> = VecDeque::new();
        let mut pending: Vec<(TicketId, Value)> = Vec::new();
        let mut errors: Vec<WireError> = Vec::new();
        let mut reconnect = ReconnectBackoff::new(10, 2000);
        'outer: while !stop.load(Ordering::SeqCst) {
            let mut conn = match connect() {
                Ok(c) => c,
                Err(_) => {
                    consecutive_failures += 1;
                    if consecutive_failures > max_reconnects {
                        break;
                    }
                    let nap = reconnect.on_failure(&mut jitter);
                    self.clock.sleep_ms(nap);
                    continue;
                }
            };
            report.reconnects += 1;
            if conn
                .send(&Message::Hello { client: self.id.clone(), profile: self.profile.name.clone() })
                .is_err()
                || !matches!(conn.recv(), Ok(Message::Ack))
            {
                consecutive_failures += 1;
                if consecutive_failures > max_reconnects {
                    break;
                }
                // Same backoff as a failed connect: a half-up
                // coordinator (socket open, Hello unanswered) must not
                // be spin-looped against.
                let nap = reconnect.on_failure(&mut jitter);
                self.clock.sleep_ms(nap);
                continue;
            }
            consecutive_failures = 0;
            reconnect.reset();

            // Compute time spent on the current batch vs the round trip
            // that fetched it: the adaptive-growth signal, reset per
            // connection.
            let mut batch_exec_ms = 0.0f64;
            let mut fetch_rtt_ms = 0.0f64;

            loop {
                if stop.load(Ordering::SeqCst) {
                    // Orderly exit: salvage finished work, report
                    // queued failures, and hand the unexecuted queue
                    // back so nothing strands for the redistribution
                    // window.
                    let _ = self.flush_results(&mut *conn, &mut pending, &mut report);
                    let _ = self.flush_errors(&mut *conn, &mut errors);
                    let _ = self.release_queue(&mut *conn, &mut queue, &mut report);
                    let _ = conn.send(&Message::Shutdown);
                    break 'outer;
                }
                // Execute from the prefetch queue first.
                if let Some(t) = queue.pop_front() {
                    let t0 = Instant::now();
                    match self.execute_ticket(&mut *conn, &t.task_name, &t.payload, &mut report) {
                        Ok(result) => {
                            batch_exec_ms += t0.elapsed().as_secs_f64() * 1e3;
                            pending.push((t.ticket, result));
                            // Grow only off an error-free batch that
                            // ran faster than the round trip it cost.
                            if queue.is_empty() && errors.is_empty() {
                                prefetch.on_batch_done(batch_exec_ms, fetch_rtt_ms);
                            }
                        }
                        Err(ExecError::Conn(e)) => {
                            // Transport died mid-ticket: reconnect.
                            // Nothing to report, and the queue is
                            // dropped — with disconnect release on
                            // (the default) the coordinator re-arms
                            // everything this connection held, so
                            // executing it locally would only race the
                            // re-dispatch.  Under the passive baseline
                            // (release disabled) the dropped tickets
                            // wait out the §2.1.2 window instead —
                            // that *is* the paper's recovery story,
                            // which that configuration exists to
                            // reproduce.
                            crate::log_debug!(
                                "worker",
                                "{}: transport failed mid-ticket: {e:#}",
                                self.id
                            );
                            queue.clear();
                            continue 'outer;
                        }
                        Err(ExecError::Task(e)) => {
                            // Queue the report; the batch keeps
                            // executing and every failure flushes as
                            // one ErrorReports round trip below.
                            report.errors_reported += 1;
                            prefetch.on_error();
                            errors.push(WireError {
                                ticket: t.ticket,
                                message: format!("{e:#}"),
                                stack: stack_trace_of(&e),
                            });
                        }
                    }
                    continue;
                }
                // Queue empty: everything executed is flushed...
                if self.flush_results(&mut *conn, &mut pending, &mut report).is_err() {
                    continue 'outer;
                }
                // ...and a batch that had failures reports all of them
                // in one round trip, then the worker reloads itself
                // once (§2.1.2: "the browser reloads itself"), not once
                // per failure.  Reports survive a failed flush and are
                // retried on the next connection.
                if !errors.is_empty() {
                    match self.flush_errors(&mut *conn, &mut errors) {
                        Ok(()) => {
                            // One reload per failing batch, counted when
                            // the flush actually lands.
                            self.cache.clear();
                            report.reloads += 1;
                        }
                        Err(_) => {
                            // Dead/desynced connection: reconnect and
                            // retry the still-queued reports; the
                            // reload is counted on the pass where the
                            // flush succeeds, so retries never inflate
                            // the churn accounting.
                            crate::log_debug!(
                                "worker",
                                "{}: error flush failed; retrying after reconnect",
                                self.id
                            );
                        }
                    }
                    continue 'outer;
                }
                if let Some(max) = self.max_tickets {
                    if report.tickets_completed >= max {
                        let _ = conn.send(&Message::Shutdown);
                        break 'outer;
                    }
                }
                // ...and the next batch is fetched, clamped so a bounded
                // worker never prefetches work it will not complete.
                let want = match self.max_tickets {
                    Some(max) => prefetch.size().min((max - report.tickets_completed) as usize),
                    None => prefetch.size(),
                };
                let t0 = Instant::now();
                let fetch = if cap == 1 {
                    conn.send(&Message::TicketRequest)
                } else {
                    conn.send(&Message::TicketBatchRequest { max: want })
                };
                if fetch.is_err() {
                    continue 'outer; // reconnect
                }
                match conn.recv() {
                    Ok(Message::Ticket { ticket, task, task_name, index, payload }) => {
                        fetch_rtt_ms = t0.elapsed().as_secs_f64() * 1e3;
                        batch_exec_ms = 0.0;
                        idle_streak = 0;
                        queue.push_back(WireTicket { ticket, task, task_name, index, payload });
                    }
                    Ok(Message::Tickets { tickets }) => {
                        fetch_rtt_ms = t0.elapsed().as_secs_f64() * 1e3;
                        batch_exec_ms = 0.0;
                        idle_streak = 0;
                        report.prefetch_batches += 1;
                        report.peak_batch = report.peak_batch.max(tickets.len() as u64);
                        queue.extend(tickets);
                    }
                    Ok(Message::NoTicket { retry_after_ms }) => {
                        report.idle_polls += 1;
                        prefetch.on_no_ticket();
                        self.idle_backoff(&mut jitter, retry_after_ms, idle_streak);
                        idle_streak = idle_streak.saturating_add(1);
                    }
                    Ok(Message::Reload) => {
                        self.cache.clear();
                        report.reloads += 1;
                        continue 'outer;
                    }
                    Ok(Message::Shutdown) => break 'outer,
                    Ok(m) => {
                        crate::log_warn!("worker", "{}: unexpected message {m:?}", self.id);
                        continue 'outer;
                    }
                    Err(_) => continue 'outer,
                }
            }
        }
        report
    }

    /// Flush buffered results: one `TicketResults` round trip, or the
    /// legacy per-ticket `TicketResult` when batching is disabled.
    /// Tickets are counted completed only once the coordinator's Ack
    /// arrives, so a `max_tickets` ledger is exact; on a send/Ack
    /// failure the unacknowledged results are put back in `pending`
    /// and retried on the next connection (at-least-once — the store
    /// counts any repeat as a duplicate, never double-applies it).
    fn flush_results(
        &self,
        conn: &mut dyn Conn,
        pending: &mut Vec<(TicketId, Value)>,
        report: &mut WorkerReport,
    ) -> Result<()> {
        if self.prefetch_cap <= 1 {
            while !pending.is_empty() {
                let (ticket, result) = pending.remove(0);
                let msg = Message::TicketResult { ticket, result };
                let acked = conn.send(&msg).and_then(|_| conn.recv().map(|_| ()));
                if let Err(e) = acked {
                    if let Message::TicketResult { ticket, result } = msg {
                        pending.insert(0, (ticket, result));
                    }
                    return Err(e);
                }
                report.tickets_completed += 1;
            }
            return Ok(());
        }
        if pending.is_empty() {
            return Ok(());
        }
        let n = pending.len() as u64;
        let msg = Message::TicketResults { results: std::mem::take(pending) };
        let acked = conn.send(&msg).and_then(|_| conn.recv().map(|_| ()));
        match acked {
            Ok(()) => {
                report.tickets_completed += n;
                Ok(())
            }
            Err(e) => {
                if let Message::TicketResults { results } = msg {
                    *pending = results;
                }
                Err(e)
            }
        }
    }

    /// Flush queued error reports: one `ErrorReports` round trip for
    /// the whole batch (or the legacy per-ticket `ErrorReport` when
    /// batching is disabled), answered by a Reload.  The reply is
    /// matched *explicitly*: anything other than Reload — or a recv
    /// failure — is a desynced stream and errors out so the caller
    /// reconnects; unacknowledged reports stay queued and retry on the
    /// next connection (at-least-once; a repeated report only inflates
    /// the error ledger, never double-applies a requeue).
    fn flush_errors(&self, conn: &mut dyn Conn, errors: &mut Vec<WireError>) -> Result<()> {
        fn expect_reload(conn: &mut dyn Conn) -> Result<()> {
            match conn.recv() {
                Ok(Message::Reload) => Ok(()),
                Ok(m) => anyhow::bail!("expected Reload after error report, got {m:?}"),
                Err(e) => Err(e),
            }
        }
        if errors.is_empty() {
            return Ok(());
        }
        if self.prefetch_cap <= 1 {
            while let Some(r) = errors.first().cloned() {
                conn.send(&Message::ErrorReport {
                    ticket: r.ticket,
                    message: r.message,
                    stack: r.stack,
                })?;
                expect_reload(conn)?;
                errors.remove(0);
            }
            return Ok(());
        }
        conn.send(&Message::ErrorReports { reports: errors.clone() })?;
        expect_reload(conn)?;
        errors.clear();
        Ok(())
    }

    /// Hand the unexecuted prefetch queue back in one `ReleaseTickets`
    /// round trip, so a stopping worker's tickets re-enter dispatch
    /// immediately instead of waiting out the redistribution window.
    /// With batching disabled (`prefetch_cap <= 1`) the legacy wire has
    /// no release message; the queue (at most one ticket) is dropped
    /// and §2.1.2 redistribution covers it — the paper's exact story.
    fn release_queue(
        &self,
        conn: &mut dyn Conn,
        queue: &mut VecDeque<WireTicket>,
        report: &mut WorkerReport,
    ) -> Result<()> {
        if queue.is_empty() {
            return Ok(());
        }
        let tickets: Vec<TicketId> = queue.drain(..).map(|t| t.ticket).collect();
        if self.prefetch_cap <= 1 {
            return Ok(());
        }
        let n = tickets.len() as u64;
        conn.send(&Message::ReleaseTickets { tickets })?;
        match conn.recv() {
            Ok(Message::Ack) => {
                report.tickets_released += n;
                Ok(())
            }
            Ok(m) => anyhow::bail!("expected Ack after release, got {m:?}"),
            Err(e) => Err(e),
        }
    }

    /// `NoTicket` backoff: exponential in the idle streak with
    /// multiplicative jitter, capped at [`Self::idle_backoff_cap_ms`].
    /// Replaces the fixed retry-hint sleep so an idle fleet spreads its
    /// polls instead of re-asking in lockstep.
    fn idle_backoff(&self, rng: &mut SplitMix64, retry_hint_ms: u64, streak: u32) {
        let base = retry_hint_ms.max(1);
        let ceiling =
            base.saturating_mul(1u64 << streak.min(6)).min(self.idle_backoff_cap_ms.max(base));
        // Sleep in [ceiling/2, ceiling]: two workers idling from the
        // same instant drift apart within a few polls.
        let jittered = ceiling / 2 + rng.gen_range(ceiling / 2 + 1);
        self.clock.sleep_ms(jittered);
    }

    /// Steps 3–5 for one ticket: ensure code, prefetch datasets, execute
    /// with panic isolation, pad to the device profile.  Failures are
    /// classified ([`ExecError`]): transport deaths reconnect, task
    /// failures become queued error reports.
    fn execute_ticket(
        &mut self,
        conn: &mut dyn Conn,
        task_name: &str,
        payload: &crate::util::json::Value,
        report: &mut WorkerReport,
    ) -> std::result::Result<crate::util::json::Value, ExecError> {
        // Step 3: task code, if not cached.
        let code_key = format!("task:{task_name}");
        if self.cache.get(&code_key).is_none() {
            report.task_fetches += 1;
            conn.send(&Message::TaskRequest { task_name: task_name.to_string() })
                .map_err(ExecError::Conn)?;
            match conn.recv() {
                Ok(Message::TaskCode { code_bytes, .. }) => {
                    self.cache.put(code_key, CacheEntry::TaskCode, code_bytes);
                }
                Ok(m) => {
                    // Desynced stream: reconnect, don't misreport.
                    return Err(ExecError::Conn(anyhow::anyhow!("expected TaskCode, got {m:?}")));
                }
                Err(e) => return Err(ExecError::Conn(e)),
            }
        }
        let def: Arc<dyn TaskDef> = self.registry.get(task_name).map_err(ExecError::Task)?;

        let timer = PaddedTimer::start();
        // Steps 4–5 under panic isolation (a panicking task produces an
        // error report + reload, not a dead worker thread).
        let mut conn_failed = false;
        let outcome = {
            let mut ctx = WireContext {
                conn,
                cache: &mut self.cache,
                runtime: self.runtime.as_ref(),
                data_fetches: &mut report.data_fetches,
                conn_failed: &mut conn_failed,
            };
            // Step 4: explicit prefetch of declared refs (mirrors the
            // basic program requesting files before running the task).
            let mut prefetch_err = None;
            for key in def.dataset_refs(payload) {
                if let Err(e) = ctx.dataset(&key) {
                    prefetch_err = Some(e);
                    break;
                }
            }
            match prefetch_err {
                Some(e) => Err(e),
                None => std::panic::catch_unwind(AssertUnwindSafe(|| {
                    def.execute(payload, &mut ctx)
                }))
                .unwrap_or_else(|p| {
                    Err(anyhow::anyhow!("task panicked: {}", panic_message(&p)))
                }),
            }
        };
        let output = match outcome {
            Ok(output) => output,
            Err(e) if conn_failed => return Err(ExecError::Conn(e)),
            Err(e) => return Err(ExecError::Task(e)),
        };

        // Device-speed padding (DESIGN.md §7).
        let modelled = output.modelled_ms.unwrap_or_else(|| timer.elapsed_ms());
        let total = timer.pad_to(modelled, self.profile.speed);
        report.busy_ms += total;
        Ok(output.value)
    }
}

fn panic_message(p: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn stack_trace_of(e: &anyhow::Error) -> String {
    // anyhow captures a backtrace when RUST_BACKTRACE is set; the chain
    // of causes is the useful part either way.
    e.chain().map(|c| c.to_string()).collect::<Vec<_>>().join("\n  caused by: ")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Distributor, Framework};
    use crate::store::Scheduler as _;
    use crate::tasks::is_prime::IsPrimeTask;
    use crate::tasks::{TaskOutput};
    use crate::transport::{local, LinkModel};
    use crate::util::clock;
    use crate::util::json::Value;

    fn prime_setup(n: usize) -> (Arc<Framework>, Arc<Distributor>, local::LocalConnector) {
        let fw = Framework::builder().build();
        let task = fw.create_task(Arc::new(IsPrimeTask));
        task.calculate(
            (0..n).map(|i| Value::obj(vec![("candidate", Value::num(i as f64 + 2.0))])).collect(),
        );
        let dist = Distributor::new(&fw);
        let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
        dist.serve(Box::new(listener));
        (fw, dist, connector)
    }

    #[test]
    fn worker_drains_all_tickets() {
        let (fw, _dist, connector) = prime_setup(20);
        let registry = fw.registry_snapshot();
        let mut w = Worker::new("w0", DeviceProfile::native(), registry);
        w.max_tickets = Some(20);
        let stop = AtomicBool::new(false);
        let report = w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop);
        assert_eq!(report.tickets_completed, 20);
        assert_eq!(report.task_fetches, 1, "task code cached after first fetch");
        assert_eq!(fw.store().progress(None).done, 20);
    }

    /// Tiny tickets over a latency-priced link: the adaptive batch
    /// grows toward the cap, round trips amortise, and every ticket
    /// still completes exactly once.
    #[test]
    fn prefetch_batches_tiny_tickets() {
        let fw = Framework::builder().build();
        let task = fw.create_task(Arc::new(IsPrimeTask));
        task.calculate(
            (0..64).map(|i| Value::obj(vec![("candidate", Value::num(i as f64 + 2.0))])).collect(),
        );
        let dist = Distributor::new(&fw);
        // 5 ms one-way latency, actually slept: execution (µs) is far
        // cheaper than a round trip, so growth must kick in.
        let (listener, connector) =
            local::endpoint(LinkModel { latency_ms: 5.0, bytes_per_ms: 100_000.0 }, true);
        dist.serve(Box::new(listener));
        let mut w = Worker::new("w0", DeviceProfile::native(), fw.registry_snapshot());
        w.prefetch_cap = 16;
        w.max_tickets = Some(64);
        let stop = AtomicBool::new(false);
        let report = w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop);
        assert_eq!(report.tickets_completed, 64);
        assert!(report.peak_batch >= 4, "batch never grew: peak {}", report.peak_batch);
        assert!(
            report.prefetch_batches < 64,
            "batching should need fewer fetches than tickets ({})",
            report.prefetch_batches
        );
        let p = fw.store().progress(None);
        assert_eq!(p.done, 64);
        assert_eq!(p.duplicate_results, 0);
    }

    /// `prefetch_cap = 1` speaks the paper's exact single-ticket
    /// protocol — no batch messages at all.
    #[test]
    fn legacy_cap_uses_single_ticket_protocol() {
        let (fw, _dist, connector) = prime_setup(5);
        let mut w = Worker::new("w0", DeviceProfile::native(), fw.registry_snapshot())
            .with_prefetch_cap(1);
        w.max_tickets = Some(5);
        let stop = AtomicBool::new(false);
        let report = w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop);
        assert_eq!(report.tickets_completed, 5);
        assert_eq!(report.prefetch_batches, 0, "no batch messages on the legacy path");
        assert_eq!(fw.store().progress(None).done, 5);
    }

    /// Panics on the first execution of ticket n=1, succeeds afterwards —
    /// a *transient* browser failure.  (A deterministically-failing
    /// ticket would loop forever in the paper's design too: the ticket
    /// is requeued, has the oldest virtual created time, and is re-served
    /// first.  That behaviour is exercised in rust/tests/fault_tolerance.)
    struct PanicOnceTask {
        fired: std::sync::atomic::AtomicBool,
    }
    impl TaskDef for PanicOnceTask {
        fn name(&self) -> &str {
            "panics_once"
        }
        fn execute(&self, input: &Value, _: &mut dyn TaskContext) -> Result<TaskOutput> {
            if input.get("n")?.as_u64()? == 1 && !self.fired.swap(true, Ordering::SeqCst) {
                panic!("injected transient panic");
            }
            Ok(TaskOutput::new(Value::Bool(true)))
        }
    }

    #[test]
    fn panicking_task_reports_and_worker_survives() {
        let fw = Framework::builder().build();
        let task = fw.create_task(Arc::new(PanicOnceTask { fired: AtomicBool::new(false) }));
        task.calculate(vec![
            Value::obj(vec![("n", Value::num(1.0))]), // panics once...
            Value::obj(vec![("n", Value::num(0.0))]),
        ]);
        let dist = Distributor::new(&fw);
        let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
        dist.serve(Box::new(listener));
        let mut w = Worker::new("w0", DeviceProfile::native(), fw.registry_snapshot());
        w.max_tickets = Some(2);
        let stop = AtomicBool::new(false);
        let report = w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop);
        // One error report + reload, then both tickets complete.
        assert_eq!(report.errors_reported, 1);
        assert_eq!(report.reloads, 1);
        assert_eq!(report.tickets_completed, 2);
        assert_eq!(fw.store().error_count(), 1);
        assert_eq!(fw.store().progress(None).done, 2);
    }

    #[test]
    fn tablet_profile_pads_time() {
        let (fw, _dist, connector) = prime_setup(2);
        let mut w = Worker::new(
            "slow",
            DeviceProfile { name: "tablet".into(), speed: 0.05 },
            fw.registry_snapshot(),
        );
        w.max_tickets = Some(2);
        let stop = AtomicBool::new(false);
        let t0 = std::time::Instant::now();
        let report = w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop);
        assert_eq!(report.tickets_completed, 2);
        // Each prime check is sub-millisecond real, padded by 1/0.05 = 20x.
        assert!(report.busy_ms >= t0.elapsed().as_secs_f64() * 1e3 * 0.2);
    }

    #[test]
    fn stop_flag_halts_worker() {
        let (fw, _dist, connector) = prime_setup(1);
        let mut w = Worker::new("w", DeviceProfile::native(), fw.registry_snapshot());
        let stop = Arc::new(AtomicBool::new(false));
        let s2 = Arc::clone(&stop);
        let h = std::thread::spawn(move || {
            clock::sleep_ms(50);
            s2.store(true, Ordering::SeqCst);
        });
        let report = w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop);
        h.join().unwrap();
        assert_eq!(report.tickets_completed, 1); // drained, then idled until stop
    }

    /// The adaptive prefetch state machine across a scripted RTT
    /// sequence: geometric growth while every batch is link-bound
    /// (execution beats the fetch round trip), clamped at the cap.
    #[test]
    fn prefetch_doubles_on_fast_batches_and_clamps_at_cap() {
        let mut p = PrefetchController::new(8);
        assert_eq!(p.size(), 1);
        // Scripted (exec_ms, rtt_ms) per finished batch: always fast.
        for (expected, (exec, rtt)) in
            [2usize, 4, 8, 8].iter().zip([(0.5, 10.0), (1.2, 10.0), (3.0, 9.5), (6.0, 9.0)])
        {
            p.on_batch_done(exec, rtt);
            assert_eq!(p.size(), *expected, "after batch exec={exec} rtt={rtt}");
        }
        // A non-power-of-two cap clamps mid-double: 4 -> 6, not 8.
        let mut odd = PrefetchController::new(6);
        for _ in 0..5 {
            odd.on_batch_done(1.0, 10.0);
        }
        assert_eq!(odd.size(), 6);
    }

    /// Compute-bound batches (execution slower than the round trip)
    /// never grow the batch — the whole point of the growth gate.
    #[test]
    fn prefetch_slow_batches_do_not_grow() {
        let mut p = PrefetchController::new(8);
        for _ in 0..4 {
            p.on_batch_done(50.0, 3.0);
        }
        assert_eq!(p.size(), 1, "compute-bound stays unbatched");
        // Equal exec and RTT is not strictly faster: no growth either.
        p.on_batch_done(3.0, 3.0);
        assert_eq!(p.size(), 1);
    }

    /// NoTicket and task errors halve toward 1 and never below it; the
    /// sequence grow-halve-grow behaves like the inline logic it
    /// replaced.
    #[test]
    fn prefetch_halves_on_no_ticket_and_error() {
        let mut p = PrefetchController::new(8);
        for _ in 0..3 {
            p.on_batch_done(1.0, 10.0); // 1 -> 2 -> 4 -> 8
        }
        assert_eq!(p.size(), 8);
        p.on_no_ticket();
        assert_eq!(p.size(), 4);
        p.on_error();
        assert_eq!(p.size(), 2);
        p.on_no_ticket();
        p.on_no_ticket();
        assert_eq!(p.size(), 1, "floor at 1");
        p.on_batch_done(1.0, 10.0);
        assert_eq!(p.size(), 2, "recovers after the pool refills");
    }

    /// `cap = 1` (and the degenerate `cap = 0`) pin the size at one
    /// ticket forever: the paper's exact single-ticket protocol.
    #[test]
    fn prefetch_cap_one_never_grows() {
        for cap in [0, 1] {
            let mut p = PrefetchController::new(cap);
            assert_eq!(p.cap(), 1);
            for _ in 0..6 {
                p.on_batch_done(0.1, 100.0);
                assert_eq!(p.size(), 1);
            }
        }
    }

    /// The nth failure sleeps in `[c/2, c]`, `c = min(10·2ⁿ, 2000)`:
    /// exponential until the cap, jittered, and reset by success.
    #[test]
    fn reconnect_backoff_grows_jitters_and_caps() {
        let mut rng = SplitMix64::new(7);
        let mut b = ReconnectBackoff::new(10, 2000);
        for n in 0..16u32 {
            let ceiling = 10u64.saturating_mul(1 << n).min(2000);
            let nap = b.on_failure(&mut rng);
            assert!(
                nap >= ceiling / 2 && nap <= ceiling,
                "failure {n}: nap {nap} outside [{}, {ceiling}]",
                ceiling / 2
            );
        }
        // Deep streaks stay pinned at the cap (the shift clamp cannot
        // undercut it).
        for _ in 0..64 {
            let nap = b.on_failure(&mut rng);
            assert!(nap >= 1000 && nap <= 2000, "capped nap out of range: {nap}");
        }
        assert_eq!(b.streak(), 80);
        b.reset();
        assert_eq!(b.streak(), 0);
        let nap = b.on_failure(&mut rng);
        assert!(nap <= 10, "post-reset nap should be back at base, got {nap}");
    }

    /// Different worker seeds take different naps on the same streak —
    /// the fleet-wide lockstep-retry guard.
    #[test]
    fn reconnect_backoff_desynchronises_workers() {
        let naps: Vec<u64> = (0..8u64)
            .map(|seed| {
                let mut rng = SplitMix64::new(seed);
                let mut b = ReconnectBackoff::new(10, 2000);
                for _ in 0..8 {
                    b.on_failure(&mut rng);
                }
                b.on_failure(&mut rng)
            })
            .collect();
        let distinct: std::collections::HashSet<u64> = naps.iter().copied().collect();
        assert!(distinct.len() > 1, "all workers chose the same nap: {naps:?}");
    }
}
