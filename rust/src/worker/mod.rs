//! Worker: the browser node, faithfully replaying §2.1.2's basic-program
//! loop.
//!
//! ```text
//! 1. connect (Hello)                      -> WebSocket open
//! 2. TicketRequest                        -> step 2
//! 3. TaskRequest if task not cached       -> step 3
//! 4. DataRequest per missing dataset      -> step 4
//! 5. execute the task                     -> step 5
//! 6. TicketResult                         -> step 6
//! 7. goto 2                               -> step 7
//! ```
//!
//! Extras the paper specifies and this module implements:
//! * task code and datasets cached under an LRU byte budget (browser GC);
//! * on execution error: ErrorReport with a stack trace, then the worker
//!   *reloads itself* (cache cleared, reconnect) and continues;
//! * device heterogeneity via [`DeviceProfile`]: the real compute runs,
//!   then the ticket is padded to `elapsed / speed` (DESIGN.md §7).

pub mod profile;

pub use profile::DeviceProfile;

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context as _, Result};

use crate::runtime::{SharedRuntime, Tensor};
use crate::tasks::{Registry, TaskContext, TaskDef};
use crate::transport::{Conn, Message};
use crate::util::base64;
use crate::util::clock::{self, PaddedTimer};
use crate::util::lru::LruCache;

/// What a worker did during `run` (asserted by tests/benches).
#[derive(Debug, Default, Clone)]
pub struct WorkerReport {
    pub tickets_completed: u64,
    pub errors_reported: u64,
    pub reloads: u64,
    pub reconnects: u64,
    pub busy_ms: f64,
    pub idle_polls: u64,
    pub task_fetches: u64,
    pub data_fetches: u64,
}

enum CacheEntry {
    TaskCode,
    Data(Arc<Tensor>),
}

/// The per-connection task context: datasets resolve through the LRU
/// cache, falling back to DataRequest messages on the wire.
struct WireContext<'a> {
    conn: &'a mut dyn Conn,
    cache: &'a mut LruCache<String, CacheEntry>,
    runtime: Option<&'a SharedRuntime>,
    data_fetches: &'a mut u64,
}

impl TaskContext for WireContext<'_> {
    fn dataset(&mut self, key: &str) -> Result<Arc<Tensor>> {
        if let Some(CacheEntry::Data(t)) = self.cache.get(&key.to_string()) {
            return Ok(Arc::clone(t));
        }
        *self.data_fetches += 1;
        self.conn.send(&Message::DataRequest { key: key.to_string() })?;
        match self.conn.recv()? {
            Message::Data { key: k, shape, b64 } => {
                anyhow::ensure!(k == key, "dataset key mismatch: {k} != {key}");
                let data = base64::decode_f32(&b64)?;
                let t = Arc::new(Tensor::new(shape, data)?);
                let bytes = t.size_bytes();
                self.cache.put(key.to_string(), CacheEntry::Data(Arc::clone(&t)), bytes);
                Ok(t)
            }
            m => anyhow::bail!("expected Data, got {m:?}"),
        }
    }

    fn runtime(&self) -> Result<&SharedRuntime> {
        self.runtime.context("worker has no XLA runtime configured")
    }
}

pub struct Worker {
    pub id: String,
    pub profile: DeviceProfile,
    registry: Registry,
    runtime: Option<SharedRuntime>,
    cache: LruCache<String, CacheEntry>,
    /// Cap on tickets to execute (None = until Shutdown/stop).
    pub max_tickets: Option<u64>,
}

impl Worker {
    pub fn new(id: &str, profile: DeviceProfile, registry: Registry) -> Worker {
        Worker {
            id: id.to_string(),
            profile,
            registry,
            runtime: None,
            cache: LruCache::new(256 << 20), // 256 MiB, a browser-ish budget
            max_tickets: None,
        }
    }

    pub fn with_runtime(mut self, rt: SharedRuntime) -> Worker {
        self.runtime = Some(rt);
        self
    }

    pub fn with_cache_bytes(mut self, bytes: usize) -> Worker {
        self.cache = LruCache::new(bytes);
        self
    }

    /// Run the browser loop until Shutdown, `stop`, connection failure
    /// with no reconnect budget, or `max_tickets`.
    ///
    /// `connect` reopens the transport (used both at start and on
    /// reload); a worker tolerates `max_reconnects` consecutive failures.
    pub fn run<F>(&mut self, connect: F, stop: &AtomicBool) -> WorkerReport
    where
        F: Fn() -> Result<Box<dyn Conn>>,
    {
        let mut report = WorkerReport::default();
        let max_reconnects = 5u32;
        let mut consecutive_failures = 0u32;
        'outer: while !stop.load(Ordering::SeqCst) {
            let mut conn = match connect() {
                Ok(c) => c,
                Err(_) => {
                    consecutive_failures += 1;
                    if consecutive_failures > max_reconnects {
                        break;
                    }
                    clock::sleep_ms(10);
                    continue;
                }
            };
            report.reconnects += 1;
            if conn
                .send(&Message::Hello { client: self.id.clone(), profile: self.profile.name.clone() })
                .is_err()
                || !matches!(conn.recv(), Ok(Message::Ack))
            {
                consecutive_failures += 1;
                if consecutive_failures > max_reconnects {
                    break;
                }
                continue;
            }
            consecutive_failures = 0;

            loop {
                if stop.load(Ordering::SeqCst) {
                    let _ = conn.send(&Message::Shutdown);
                    break 'outer;
                }
                if let Some(max) = self.max_tickets {
                    if report.tickets_completed >= max {
                        let _ = conn.send(&Message::Shutdown);
                        break 'outer;
                    }
                }
                if conn.send(&Message::TicketRequest).is_err() {
                    continue 'outer; // reconnect
                }
                match conn.recv() {
                    Ok(Message::Ticket { ticket, task_name, payload, .. }) => {
                        match self.execute_ticket(&mut *conn, &task_name, &payload, &mut report) {
                            Ok(result) => {
                                if conn.send(&Message::TicketResult { ticket, result }).is_err() {
                                    continue 'outer;
                                }
                                let _ = conn.recv(); // Ack
                                report.tickets_completed += 1;
                            }
                            Err(e) => {
                                report.errors_reported += 1;
                                let _ = conn.send(&Message::ErrorReport {
                                    ticket,
                                    message: format!("{e:#}"),
                                    stack: stack_trace_of(&e),
                                });
                                let _ = conn.recv(); // Reload
                                // The paper: "the browser reloads itself".
                                self.cache.clear();
                                report.reloads += 1;
                                continue 'outer;
                            }
                        }
                    }
                    Ok(Message::NoTicket { retry_after_ms }) => {
                        report.idle_polls += 1;
                        clock::sleep_ms(retry_after_ms.min(200));
                    }
                    Ok(Message::Reload) => {
                        self.cache.clear();
                        report.reloads += 1;
                        continue 'outer;
                    }
                    Ok(Message::Shutdown) => break 'outer,
                    Ok(m) => {
                        crate::log_warn!("worker", "{}: unexpected message {m:?}", self.id);
                        continue 'outer;
                    }
                    Err(_) => continue 'outer,
                }
            }
        }
        report
    }

    /// Steps 3–5 for one ticket: ensure code, prefetch datasets, execute
    /// with panic isolation, pad to the device profile.
    fn execute_ticket(
        &mut self,
        conn: &mut dyn Conn,
        task_name: &str,
        payload: &crate::util::json::Value,
        report: &mut WorkerReport,
    ) -> Result<crate::util::json::Value> {
        // Step 3: task code, if not cached.
        let code_key = format!("task:{task_name}");
        if self.cache.get(&code_key).is_none() {
            report.task_fetches += 1;
            conn.send(&Message::TaskRequest { task_name: task_name.to_string() })?;
            match conn.recv()? {
                Message::TaskCode { code_bytes, .. } => {
                    self.cache.put(code_key, CacheEntry::TaskCode, code_bytes);
                }
                m => anyhow::bail!("expected TaskCode, got {m:?}"),
            }
        }
        let def: Arc<dyn TaskDef> = self.registry.get(task_name)?;

        let timer = PaddedTimer::start();
        // Steps 4–5 under panic isolation (a panicking task produces an
        // error report + reload, not a dead worker thread).
        let result = {
            let mut ctx = WireContext {
                conn,
                cache: &mut self.cache,
                runtime: self.runtime.as_ref(),
                data_fetches: &mut report.data_fetches,
            };
            // Step 4: explicit prefetch of declared refs (mirrors the
            // basic program requesting files before running the task).
            for key in def.dataset_refs(payload) {
                ctx.dataset(&key)?;
            }
            std::panic::catch_unwind(AssertUnwindSafe(|| def.execute(payload, &mut ctx)))
                .map_err(|p| anyhow::anyhow!("task panicked: {}", panic_message(&p)))?
        }?;

        // Device-speed padding (DESIGN.md §7).
        let modelled = result.modelled_ms.unwrap_or_else(|| timer.elapsed_ms());
        let total = timer.pad_to(modelled, self.profile.speed);
        report.busy_ms += total;
        Ok(result.value)
    }
}

fn panic_message(p: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn stack_trace_of(e: &anyhow::Error) -> String {
    // anyhow captures a backtrace when RUST_BACKTRACE is set; the chain
    // of causes is the useful part either way.
    e.chain().map(|c| c.to_string()).collect::<Vec<_>>().join("\n  caused by: ")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Distributor, Framework};
    use crate::store::Scheduler as _;
    use crate::tasks::is_prime::IsPrimeTask;
    use crate::tasks::{TaskOutput};
    use crate::transport::{local, LinkModel};
    use crate::util::json::Value;

    fn prime_setup(n: usize) -> (Arc<Framework>, Arc<Distributor>, local::LocalConnector) {
        let fw = Framework::builder().build();
        let task = fw.create_task(Arc::new(IsPrimeTask));
        task.calculate(
            (0..n).map(|i| Value::obj(vec![("candidate", Value::num(i as f64 + 2.0))])).collect(),
        );
        let dist = Distributor::new(&fw);
        let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
        dist.serve(Box::new(listener));
        (fw, dist, connector)
    }

    #[test]
    fn worker_drains_all_tickets() {
        let (fw, _dist, connector) = prime_setup(20);
        let registry = fw.registry_snapshot();
        let mut w = Worker::new("w0", DeviceProfile::native(), registry);
        w.max_tickets = Some(20);
        let stop = AtomicBool::new(false);
        let report = w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop);
        assert_eq!(report.tickets_completed, 20);
        assert_eq!(report.task_fetches, 1, "task code cached after first fetch");
        assert_eq!(fw.store().progress(None).done, 20);
    }

    /// Panics on the first execution of ticket n=1, succeeds afterwards —
    /// a *transient* browser failure.  (A deterministically-failing
    /// ticket would loop forever in the paper's design too: the ticket
    /// is requeued, has the oldest virtual created time, and is re-served
    /// first.  That behaviour is exercised in rust/tests/fault_tolerance.)
    struct PanicOnceTask {
        fired: std::sync::atomic::AtomicBool,
    }
    impl TaskDef for PanicOnceTask {
        fn name(&self) -> &str {
            "panics_once"
        }
        fn execute(&self, input: &Value, _: &mut dyn TaskContext) -> Result<TaskOutput> {
            if input.get("n")?.as_u64()? == 1 && !self.fired.swap(true, Ordering::SeqCst) {
                panic!("injected transient panic");
            }
            Ok(TaskOutput::new(Value::Bool(true)))
        }
    }

    #[test]
    fn panicking_task_reports_and_worker_survives() {
        let fw = Framework::builder().build();
        let task = fw.create_task(Arc::new(PanicOnceTask { fired: AtomicBool::new(false) }));
        task.calculate(vec![
            Value::obj(vec![("n", Value::num(1.0))]), // panics once...
            Value::obj(vec![("n", Value::num(0.0))]),
        ]);
        let dist = Distributor::new(&fw);
        let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
        dist.serve(Box::new(listener));
        let mut w = Worker::new("w0", DeviceProfile::native(), fw.registry_snapshot());
        w.max_tickets = Some(2);
        let stop = AtomicBool::new(false);
        let report = w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop);
        // One error report + reload, then both tickets complete.
        assert_eq!(report.errors_reported, 1);
        assert_eq!(report.reloads, 1);
        assert_eq!(report.tickets_completed, 2);
        assert_eq!(fw.store().error_count(), 1);
        assert_eq!(fw.store().progress(None).done, 2);
    }

    #[test]
    fn tablet_profile_pads_time() {
        let (fw, _dist, connector) = prime_setup(2);
        let mut w = Worker::new(
            "slow",
            DeviceProfile { name: "tablet".into(), speed: 0.05 },
            fw.registry_snapshot(),
        );
        w.max_tickets = Some(2);
        let stop = AtomicBool::new(false);
        let t0 = std::time::Instant::now();
        let report = w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop);
        assert_eq!(report.tickets_completed, 2);
        // Each prime check is sub-millisecond real, padded by 1/0.05 = 20x.
        assert!(report.busy_ms >= t0.elapsed().as_secs_f64() * 1e3 * 0.2);
    }

    #[test]
    fn stop_flag_halts_worker() {
        let (fw, _dist, connector) = prime_setup(1);
        let mut w = Worker::new("w", DeviceProfile::native(), fw.registry_snapshot());
        let stop = Arc::new(AtomicBool::new(false));
        let s2 = Arc::clone(&stop);
        let h = std::thread::spawn(move || {
            clock::sleep_ms(50);
            s2.store(true, Ordering::SeqCst);
        });
        let report = w.run(|| Ok(Box::new(connector.connect()?) as Box<dyn Conn>), &stop);
        h.join().unwrap();
        assert_eq!(report.tickets_completed, 1); // drained, then idled until stop
    }
}
