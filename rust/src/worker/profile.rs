//! Device profiles: the paper's heterogeneous clients as speed ratios.
//!
//! `speed` is the device's modelled throughput relative to this host's
//! CPU: a ticket whose real compute took `t` ms is padded to `t/speed`.
//! Emulation is faithful while the sum of active speeds stays ≤ 1 (the
//! host can keep up with the modelled fleet) — the constants below keep
//! 4 concurrent desktops at 0.8 (DESIGN.md §7).
//!
//! Ratios are calibrated to the paper's measurements:
//! * Table 2: Nexus 7 took 768 s where the OPTIPLEX took 107 s for the
//!   same single-client workload → tablet ≈ desktop / 7.2;
//! * Table 4: Firefox ran ConvNetJS 7.2× and Sukiyaki 17.4× slower than
//!   Node.js on identical hardware → the browser-engine throttles.

#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: String,
    /// Modelled device throughput relative to this host (0 < speed ≤ ∞).
    pub speed: f64,
}

impl DeviceProfile {
    /// No padding at all: run at host speed (engine benches).
    pub fn native() -> DeviceProfile {
        DeviceProfile { name: "native".into(), speed: f64::INFINITY }
    }

    /// The DELL OPTIPLEX 8010 desktop of Table 1, scaled so four fit on
    /// one host core.
    pub fn desktop() -> DeviceProfile {
        DeviceProfile { name: "desktop".into(), speed: 0.2 }
    }

    /// The Nexus 7 (2013) tablet of Table 1: desktop / 7.2.
    pub fn tablet() -> DeviceProfile {
        DeviceProfile { name: "tablet".into(), speed: 0.2 / 7.2 }
    }

    /// Browser-engine throttles (Table 4's Node.js vs Firefox columns).
    pub fn firefox_convnetjs_factor() -> f64 {
        17.55 / 2.44 // ≈ 7.2
    }

    pub fn firefox_sukiyaki_factor() -> f64 {
        545.39 / 31.39 // ≈ 17.4
    }

    pub fn with_speed(name: &str, speed: f64) -> DeviceProfile {
        DeviceProfile { name: name.into(), speed }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_ratios() {
        let d = DeviceProfile::desktop();
        let t = DeviceProfile::tablet();
        assert!((d.speed / t.speed - 7.2).abs() < 1e-9);
        assert!((DeviceProfile::firefox_convnetjs_factor() - 7.19).abs() < 0.1);
        assert!((DeviceProfile::firefox_sukiyaki_factor() - 17.37).abs() < 0.1);
    }

    #[test]
    fn fleet_fits_host() {
        // 4 desktops must not oversubscribe the single host core.
        assert!(4.0 * DeviceProfile::desktop().speed <= 1.0);
    }
}
