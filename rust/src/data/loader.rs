//! Mini-batch streams over a [`Dataset`] — deterministic, shuffled per
//! epoch, shared by both engines so comparisons see identical batches.

use super::Dataset;
use crate::runtime::Tensor;
use crate::util::rng::SplitMix64;

pub struct BatchLoader<'a> {
    data: &'a Dataset,
    batch: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: SplitMix64,
    pub epoch: u64,
}

impl<'a> BatchLoader<'a> {
    pub fn new(data: &'a Dataset, batch: usize, seed: u64) -> Self {
        assert!(batch <= data.len(), "batch {batch} > dataset {}", data.len());
        let mut rng = SplitMix64::new(seed);
        let mut order: Vec<usize> = (0..data.len()).collect();
        rng.shuffle(&mut order);
        Self { data, batch, order, cursor: 0, rng, epoch: 0 }
    }

    /// Next (images, one-hot labels, integer labels); reshuffles at epoch
    /// boundaries (drop-last semantics, like the paper's 50-image
    /// mini-batches over 50k train images).
    pub fn next_batch(&mut self) -> (Tensor, Tensor, Vec<usize>) {
        if self.cursor + self.batch > self.order.len() {
            self.rng.shuffle(&mut self.order);
            self.cursor = 0;
            self.epoch += 1;
        }
        let idx = &self.order[self.cursor..self.cursor + self.batch];
        self.cursor += self.batch;
        let x = self.data.batch_images(idx);
        let y = self.data.batch_onehot(idx);
        let labels = idx.iter().map(|&i| self.data.labels[i]).collect();
        (x, y, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::mnist_train;

    #[test]
    fn batches_have_right_shapes() {
        let d = mnist_train(120, 1);
        let mut l = BatchLoader::new(&d, 50, 2);
        let (x, y, labels) = l.next_batch();
        assert_eq!(x.shape(), &[50, 28, 28, 1]);
        assert_eq!(y.shape(), &[50, 10]);
        assert_eq!(labels.len(), 50);
    }

    #[test]
    fn epoch_reshuffles_and_counts() {
        let d = mnist_train(100, 1);
        let mut l = BatchLoader::new(&d, 50, 3);
        let (a, _, _) = l.next_batch();
        let _ = l.next_batch();
        assert_eq!(l.epoch, 0);
        let (c, _, _) = l.next_batch(); // triggers epoch 1
        assert_eq!(l.epoch, 1);
        assert_ne!(a.data(), c.data());
    }

    #[test]
    fn deterministic_given_seed() {
        let d = mnist_train(100, 1);
        let mut l1 = BatchLoader::new(&d, 20, 9);
        let mut l2 = BatchLoader::new(&d, 20, 9);
        for _ in 0..7 {
            let (a, _, la) = l1.next_batch();
            let (b, _, lb) = l2.next_batch();
            assert_eq!(a, b);
            assert_eq!(la, lb);
        }
    }

    #[test]
    #[should_panic]
    fn oversized_batch_panics() {
        let d = mnist_train(10, 1);
        let _ = BatchLoader::new(&d, 11, 0);
    }
}
