//! Synthetic datasets standing in for MNIST and CIFAR-10 downloads.
//!
//! The image has no network access, so the paper's datasets are replaced
//! by deterministic generators with class-dependent structure
//! (DESIGN.md §2).  Two properties matter for the experiments:
//!
//! 1. *learnability* — a CNN's error rate must actually fall (Fig 3) and
//!    nearest-neighbour must beat chance (Table 2's workload is "classify
//!    1,000 of the 10,000 test images against 60,000 training images");
//! 2. *shape fidelity* — same tensor shapes and set sizes as the real
//!    datasets so all throughput/communication numbers are comparable.
//!
//! Each class k gets a smooth prototype image built from k-seeded
//! sinusoid bumps; samples are `prototype + uniform pixel noise`, so
//! intra-class distances are smaller than inter-class distances (kNN
//! works) while noise keeps the problem non-trivial for the CNN.

pub mod loader;

use crate::runtime::Tensor;
use crate::util::rng::SplitMix64;

/// An in-memory labelled image dataset, NHWC f32 in [0, 1].
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub hw: usize,
    pub channels: usize,
    pub n_classes: usize,
    /// [N, hw, hw, channels] flattened.
    pub images: Vec<f32>,
    pub labels: Vec<usize>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn image_elems(&self) -> usize {
        self.hw * self.hw * self.channels
    }

    /// One image as a flat row (for kNN distance workloads).
    pub fn row(&self, i: usize) -> &[f32] {
        let d = self.image_elems();
        &self.images[i * d..(i + 1) * d]
    }

    /// Pack `indices` into an NHWC batch tensor.
    pub fn batch_images(&self, indices: &[usize]) -> Tensor {
        let d = self.image_elems();
        let mut out = Vec::with_capacity(indices.len() * d);
        for &i in indices {
            out.extend_from_slice(self.row(i));
        }
        Tensor::new(vec![indices.len(), self.hw, self.hw, self.channels], out).unwrap()
    }

    /// Pack `indices` into a one-hot label tensor.
    pub fn batch_onehot(&self, indices: &[usize]) -> Tensor {
        let mut out = vec![0.0f32; indices.len() * self.n_classes];
        for (row, &i) in indices.iter().enumerate() {
            out[row * self.n_classes + self.labels[i]] = 1.0;
        }
        Tensor::new(vec![indices.len(), self.n_classes], out).unwrap()
    }

    /// Pack rows `[start, start+count)` as a [count, D] matrix (kNN chunks).
    pub fn rows_matrix(&self, start: usize, count: usize) -> Tensor {
        let d = self.image_elems();
        let mut out = Vec::with_capacity(count * d);
        for i in start..start + count {
            out.extend_from_slice(self.row(i));
        }
        Tensor::new(vec![count, d], out).unwrap()
    }

    pub fn size_bytes(&self) -> usize {
        self.images.len() * 4
    }
}

/// Class prototype pixel: a smooth function of (x, y) with k-dependent
/// frequencies/phases per channel — distinct, smooth, bounded.
fn prototype_pixel(class: usize, c: usize, x: f64, y: f64) -> f64 {
    let k = class as f64 + 1.0;
    let ch = c as f64 + 1.0;
    let v = 0.5
        + 0.25 * ((k * 1.3 + ch) * x * std::f64::consts::PI).sin()
        + 0.25 * ((k * 0.7 + 2.0 * ch) * y * std::f64::consts::PI + k).cos();
    v.clamp(0.0, 1.0)
}

/// Generate a synthetic dataset: `n` samples, `hw`x`hw`x`channels`,
/// `n_classes` classes, balanced labels in round-robin order then
/// shuffled; noise amplitude 0.25 keeps kNN accuracy high but not 100%.
pub fn synthetic(
    name: &str,
    n: usize,
    hw: usize,
    channels: usize,
    n_classes: usize,
    seed: u64,
) -> Dataset {
    let mut rng = SplitMix64::new(seed);
    let d = hw * hw * channels;
    // Precompute prototypes.
    let mut protos = vec![0.0f32; n_classes * d];
    for k in 0..n_classes {
        for y in 0..hw {
            for x in 0..hw {
                for c in 0..channels {
                    protos[k * d + (y * hw + x) * channels + c] =
                        prototype_pixel(k, c, x as f64 / hw as f64, y as f64 / hw as f64) as f32;
                }
            }
        }
    }
    let mut order: Vec<usize> = (0..n).map(|i| i % n_classes).collect();
    rng.shuffle(&mut order);
    let mut images = Vec::with_capacity(n * d);
    for &k in &order {
        for j in 0..d {
            let noise = rng.uniform_f32(-0.25, 0.25);
            images.push((protos[k * d + j] + noise).clamp(0.0, 1.0));
        }
    }
    Dataset { name: name.to_string(), hw, channels, n_classes, images, labels: order }
}

/// MNIST-shaped: 28x28x1, 10 classes.
pub fn mnist_train(n: usize, seed: u64) -> Dataset {
    synthetic("mnist-train", n, 28, 1, 10, seed)
}

pub fn mnist_test(n: usize, seed: u64) -> Dataset {
    synthetic("mnist-test", n, 28, 1, 10, seed ^ 0x5EED_7E57)
}

/// CIFAR-shaped: 32x32x3, 10 classes.
pub fn cifar_train(n: usize, seed: u64) -> Dataset {
    synthetic("cifar-train", n, 32, 3, 10, seed)
}

pub fn cifar_test(n: usize, seed: u64) -> Dataset {
    synthetic("cifar-test", n, 32, 3, 10, seed ^ 0x5EED_7E57)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let a = mnist_train(100, 1);
        assert_eq!(a.len(), 100);
        assert_eq!(a.image_elems(), 784);
        let b = mnist_train(100, 1);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        let c = mnist_train(100, 2);
        assert_ne!(a.images, c.images);
    }

    #[test]
    fn pixels_in_unit_range_and_balanced_labels() {
        let d = cifar_train(200, 3);
        assert!(d.images.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let mut counts = [0usize; 10];
        for &l in &d.labels {
            counts[l] += 1;
        }
        assert!(counts.iter().all(|&c| c == 20), "{counts:?}");
    }

    #[test]
    fn nearest_prototype_structure_holds() {
        // Intra-class distance must be systematically below inter-class:
        // the property that makes kNN and the CNN work on this data.
        let d = mnist_train(60, 4);
        let (mut intra, mut inter) = (0.0f64, 0.0f64);
        let (mut ni, mut nx) = (0, 0);
        for i in 0..30 {
            for j in 30..60 {
                let dist: f64 = d
                    .row(i)
                    .iter()
                    .zip(d.row(j))
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum();
                if d.labels[i] == d.labels[j] {
                    intra += dist;
                    ni += 1;
                } else {
                    inter += dist;
                    nx += 1;
                }
            }
        }
        let (intra, inter) = (intra / ni as f64, inter / nx as f64);
        assert!(intra * 1.5 < inter, "intra {intra} vs inter {inter}");
    }

    #[test]
    fn batch_packing() {
        let d = mnist_train(20, 5);
        let x = d.batch_images(&[0, 3, 7]);
        assert_eq!(x.shape(), &[3, 28, 28, 1]);
        assert_eq!(&x.data()[..784], d.row(0));
        let y = d.batch_onehot(&[0, 3]);
        assert_eq!(y.shape(), &[2, 10]);
        assert_eq!(y.data().iter().sum::<f32>(), 2.0);
        assert_eq!(y.data()[d.labels[0]], 1.0);
    }

    #[test]
    fn rows_matrix_slices() {
        let d = mnist_train(10, 6);
        let m = d.rows_matrix(2, 3);
        assert_eq!(m.shape(), &[3, 784]);
        assert_eq!(&m.data()[784..1568], d.row(3));
    }
}
