//! The TicketDistributor: serves the browser protocol.
//!
//! One thread per connection (the paper's TicketDistributor is a single
//! Node.js process multiplexing WebSockets; with blocking sockets the
//! thread-per-conn layout is the idiomatic equivalent, and the shared
//! state is the same ticket store the SQL server held).
//!
//! Handles, per §2.1.2:
//! * `TicketRequest` → next ticket by virtual created time (or NoTicket
//!   with a retry hint);
//! * `TicketBatchRequest { max }` → up to `min(max, max_batch)` tickets
//!   in one round trip via `Scheduler::next_tickets` (empty pool →
//!   NoTicket), amortising the coordinator RTT that bounds fast-link
//!   throughput;
//! * `TaskRequest` → task code metadata (code bytes accounted);
//! * `DataRequest` → dataset payloads (the HTTPServer API);
//! * `TicketResult` → store completion (first result wins);
//! * `TicketResults` → batched completion through
//!   `Scheduler::complete_batch` (one Ack; per-entry first-result-wins
//!   accounting);
//! * `ErrorReport` → recorded, ticket requeued, client told to reload.
//!
//! The singular forms stay served unchanged, so a legacy client that
//! speaks only `TicketRequest`/`TicketResult` interoperates with
//! batching clients on the same store.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::coordinator::framework::Framework;
use crate::store::Scheduler;
use crate::tasks::{DatasetStore, Registry};
use crate::transport::{Conn, Listener, Message, WireTicket};
use crate::util::clock;

/// Per-client info shown on the console.
#[derive(Debug, Clone, Default)]
pub struct ClientInfo {
    pub client: String,
    pub profile: String,
    pub tickets_served: u64,
    pub results: u64,
    pub errors: u64,
    pub connected_ms: u64,
}

#[derive(Default)]
pub struct DistributorStats {
    pub connections: AtomicU64,
    pub tickets_served: AtomicU64,
    pub results_accepted: AtomicU64,
    pub results_duplicate: AtomicU64,
    pub errors_reported: AtomicU64,
    pub data_requests: AtomicU64,
    pub task_requests: AtomicU64,
    /// Bytes moved over all finished connections (server side).
    pub bytes_sent: AtomicU64,
    pub bytes_received: AtomicU64,
}

pub struct Distributor {
    store: Arc<dyn Scheduler>,
    registry: Registry,
    datasets: Arc<DatasetStore>,
    pub stats: DistributorStats,
    clients: Mutex<HashMap<String, ClientInfo>>,
    stop: AtomicBool,
    /// Retry hint handed to idle workers.
    pub idle_retry_ms: u64,
    /// Server-side cap on one `TicketBatchRequest` (protects the store
    /// from a single client draining the pool in one call).
    pub max_batch: usize,
}

/// Default server-side cap on one dispatched batch.
pub const DEFAULT_MAX_BATCH: usize = 64;

impl Distributor {
    pub fn new(fw: &Arc<Framework>) -> Arc<Distributor> {
        Arc::new(Distributor {
            store: Arc::clone(fw.store()),
            registry: fw.registry_snapshot(),
            datasets: fw.datasets().clone(),
            stats: DistributorStats::default(),
            clients: Mutex::new(HashMap::new()),
            stop: AtomicBool::new(false),
            idle_retry_ms: 20,
            max_batch: DEFAULT_MAX_BATCH,
        })
    }

    /// Build from raw parts (dist drivers that bypass Framework).
    pub fn from_parts(
        store: Arc<dyn Scheduler>,
        registry: Registry,
        datasets: Arc<DatasetStore>,
    ) -> Arc<Distributor> {
        Arc::new(Distributor {
            store,
            registry,
            datasets,
            stats: DistributorStats::default(),
            clients: Mutex::new(HashMap::new()),
            stop: AtomicBool::new(false),
            idle_retry_ms: 20,
            max_batch: DEFAULT_MAX_BATCH,
        })
    }

    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Clone the per-client table.  On-demand reporting only
    /// ([`crate::coordinator::console::render_clients`]); per-render
    /// paths use [`Self::client_count`] and the stats atomics instead.
    pub fn clients(&self) -> Vec<ClientInfo> {
        self.clients.lock().unwrap().values().cloned().collect()
    }

    /// Number of clients that have sent Hello (O(1), no cloning).
    pub fn client_count(&self) -> usize {
        self.clients.lock().unwrap().len()
    }

    pub fn store(&self) -> &Arc<dyn Scheduler> {
        &self.store
    }

    pub fn datasets(&self) -> &Arc<DatasetStore> {
        &self.datasets
    }

    /// Accept-loop: spawn a handler thread per connection.  Returns the
    /// acceptor handle; stop by making `listener.accept()` fail (drop
    /// all connectors / close the socket) after calling [`stop`].
    pub fn serve(self: &Arc<Self>, mut listener: Box<dyn Listener>) -> JoinHandle<()> {
        let this = Arc::clone(self);
        std::thread::spawn(move || {
            let mut handlers = Vec::new();
            while !this.stopped() {
                match listener.accept() {
                    Ok(conn) => {
                        this.stats.connections.fetch_add(1, Ordering::Relaxed);
                        let d = Arc::clone(&this);
                        handlers.push(std::thread::spawn(move || {
                            if let Err(e) = d.handle_conn(conn) {
                                crate::log_debug!("distributor", "connection ended: {e:#}");
                            }
                        }));
                    }
                    Err(_) => break,
                }
            }
            for h in handlers {
                let _ = h.join();
            }
        })
    }

    /// Serve one connection until Shutdown/EOF, accounting its bytes
    /// incrementally (so live benches see traffic as it happens).
    pub fn handle_conn(&self, mut conn: Box<dyn Conn>) -> Result<()> {
        self.handle_conn_inner(&mut *conn)
    }

    fn handle_conn_inner(&self, conn: &mut dyn Conn) -> Result<()> {
        let mut client = String::from("unknown");
        let (mut acc_sent, mut acc_recv) = (0u64, 0u64);
        let mut account = |conn: &mut dyn Conn, stats: &DistributorStats| {
            let (s, r) = conn.bytes();
            stats.bytes_sent.fetch_add(s - acc_sent, Ordering::Relaxed);
            stats.bytes_received.fetch_add(r - acc_recv, Ordering::Relaxed);
            acc_sent = s;
            acc_recv = r;
        };
        loop {
            if self.stopped() {
                let _ = conn.send(&Message::Shutdown);
                account(conn, &self.stats);
                return Ok(());
            }
            let msg = match conn.recv() {
                Ok(m) => m,
                Err(e) => {
                    account(conn, &self.stats);
                    return Err(e);
                }
            };
            account(conn, &self.stats);
            match msg {
                Message::Hello { client: c, profile } => {
                    client = c.clone();
                    self.clients.lock().unwrap().insert(
                        c.clone(),
                        ClientInfo {
                            client: c,
                            profile,
                            connected_ms: clock::now_ms(),
                            ..Default::default()
                        },
                    );
                    conn.send(&Message::Ack)?;
                }
                Message::TicketRequest => {
                    if self.stopped() {
                        conn.send(&Message::Shutdown)?;
                        return Ok(());
                    }
                    match self.store.next_ticket(&client, clock::now_ms()) {
                        Some(t) => {
                            self.stats.tickets_served.fetch_add(1, Ordering::Relaxed);
                            if let Some(ci) = self.clients.lock().unwrap().get_mut(&client) {
                                ci.tickets_served += 1;
                            }
                            conn.send(&Message::Ticket {
                                ticket: t.id,
                                task: t.task,
                                task_name: t.task_name.clone(),
                                index: t.index,
                                payload: t.payload.clone(),
                            })?;
                        }
                        None => conn.send(&Message::NoTicket { retry_after_ms: self.idle_retry_ms })?,
                    }
                }
                Message::TicketBatchRequest { max } => {
                    if self.stopped() {
                        conn.send(&Message::Shutdown)?;
                        return Ok(());
                    }
                    let k = max.clamp(1, self.max_batch.max(1));
                    let batch = self.store.next_tickets(&client, clock::now_ms(), k);
                    if batch.is_empty() {
                        conn.send(&Message::NoTicket { retry_after_ms: self.idle_retry_ms })?;
                    } else {
                        self.stats.tickets_served.fetch_add(batch.len() as u64, Ordering::Relaxed);
                        if let Some(ci) = self.clients.lock().unwrap().get_mut(&client) {
                            ci.tickets_served += batch.len() as u64;
                        }
                        let tickets: Vec<WireTicket> = batch
                            .into_iter()
                            .map(|t| WireTicket {
                                ticket: t.id,
                                task: t.task,
                                task_name: t.task_name,
                                index: t.index,
                                payload: t.payload,
                            })
                            .collect();
                        conn.send(&Message::Tickets { tickets })?;
                    }
                }
                Message::TaskRequest { task_name } => {
                    self.stats.task_requests.fetch_add(1, Ordering::Relaxed);
                    let def = self.registry.get(&task_name)?;
                    // dataset_refs are per-ticket; the static advertisement
                    // is empty (workers resolve refs from each payload).
                    conn.send(&Message::TaskCode {
                        task_name,
                        code_bytes: def.code_bytes(),
                        dataset_refs: Vec::new(),
                    })?;
                }
                Message::DataRequest { key } => {
                    self.stats.data_requests.fetch_add(1, Ordering::Relaxed);
                    let enc = self.datasets.encoded(&key)?;
                    conn.send(&Message::Data { key, shape: enc.0.clone(), b64: enc.1.clone() })?;
                }
                Message::TicketResult { ticket, result } => {
                    let fresh = self.store.complete(ticket, result)?;
                    if fresh {
                        self.stats.results_accepted.fetch_add(1, Ordering::Relaxed);
                    } else {
                        self.stats.results_duplicate.fetch_add(1, Ordering::Relaxed);
                    }
                    if let Some(ci) = self.clients.lock().unwrap().get_mut(&client) {
                        ci.results += 1;
                    }
                    conn.send(&Message::Ack)?;
                }
                Message::TicketResults { results } => {
                    let n = results.len() as u64;
                    // A mid-batch unknown ticket (a protocol-violating
                    // client) applies the prefix, then `?` kills the
                    // connection; the stats counters below are skipped
                    // for that prefix.  The store's progress counters —
                    // the source of truth — stay exact either way.
                    let accepted = self.store.complete_batch(results)? as u64;
                    self.stats.results_accepted.fetch_add(accepted, Ordering::Relaxed);
                    self.stats.results_duplicate.fetch_add(n - accepted, Ordering::Relaxed);
                    if let Some(ci) = self.clients.lock().unwrap().get_mut(&client) {
                        ci.results += n;
                    }
                    conn.send(&Message::Ack)?;
                }
                Message::ErrorReport { ticket, message, stack } => {
                    self.stats.errors_reported.fetch_add(1, Ordering::Relaxed);
                    if let Some(ci) = self.clients.lock().unwrap().get_mut(&client) {
                        ci.errors += 1;
                    }
                    crate::log_warn!("distributor", "error report from {client}: {message}");
                    self.store.report_error(ticket, format!("{message}\n{stack}"))?;
                    // The paper: the browser reloads itself after reporting.
                    conn.send(&Message::Reload)?;
                }
                Message::Shutdown => {
                    return Ok(());
                }
                other => {
                    anyhow::bail!("unexpected message from {client}: {other:?}");
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::TaskId;
    use crate::tasks::is_prime::IsPrimeTask;
    use crate::transport::local;
    use crate::transport::LinkModel;
    use crate::util::json::Value;

    fn framework_with_tickets(n: usize) -> (Arc<Framework>, TaskId) {
        let fw = Framework::builder().build();
        let task = fw.create_task(Arc::new(IsPrimeTask));
        task.calculate(
            (0..n).map(|i| Value::obj(vec![("candidate", Value::num(i as f64 + 2.0))])).collect(),
        );
        let id = task.id;
        (fw, id)
    }

    #[test]
    fn protocol_happy_path() {
        let (fw, _task) = framework_with_tickets(1);
        let dist = Distributor::new(&fw);
        let (mut client, server) = local::pair(LinkModel::FAST_LAN, false);
        let d = Arc::clone(&dist);
        let h = std::thread::spawn(move || d.handle_conn(Box::new(server)).unwrap());

        client.send(&Message::Hello { client: "w0".into(), profile: "desktop".into() }).unwrap();
        assert_eq!(client.recv().unwrap(), Message::Ack);

        client.send(&Message::TicketRequest).unwrap();
        let (ticket, payload) = match client.recv().unwrap() {
            Message::Ticket { ticket, payload, task_name, .. } => {
                assert_eq!(task_name, "is_prime");
                (ticket, payload)
            }
            m => panic!("expected ticket, got {m:?}"),
        };
        assert_eq!(payload.get("candidate").unwrap().as_u64().unwrap(), 2);

        client.send(&Message::TaskRequest { task_name: "is_prime".into() }).unwrap();
        match client.recv().unwrap() {
            Message::TaskCode { code_bytes, .. } => assert!(code_bytes > 0),
            m => panic!("expected task code, got {m:?}"),
        }

        client
            .send(&Message::TicketResult {
                ticket,
                result: Value::obj(vec![("is_prime", Value::Bool(true))]),
            })
            .unwrap();
        assert_eq!(client.recv().unwrap(), Message::Ack);

        // No tickets left.
        client.send(&Message::TicketRequest).unwrap();
        assert!(matches!(client.recv().unwrap(), Message::NoTicket { .. }));

        client.send(&Message::Shutdown).unwrap();
        h.join().unwrap();
        assert_eq!(dist.stats.results_accepted.load(Ordering::Relaxed), 1);
        assert_eq!(dist.clients()[0].results, 1);
    }

    /// The batched protocol end to end, plus the compat requirement: a
    /// legacy client speaking only `TicketRequest`/`TicketResult`
    /// finishes the same task against the same distributor.
    #[test]
    fn batch_and_legacy_clients_interoperate() {
        let (fw, task) = framework_with_tickets(6);
        let dist = Distributor::new(&fw);
        let (mut batcher, server) = local::pair(LinkModel::FAST_LAN, false);
        let d = Arc::clone(&dist);
        let h = std::thread::spawn(move || d.handle_conn(Box::new(server)).unwrap());

        batcher.send(&Message::Hello { client: "b0".into(), profile: "desktop".into() }).unwrap();
        assert_eq!(batcher.recv().unwrap(), Message::Ack);
        batcher.send(&Message::TicketBatchRequest { max: 4 }).unwrap();
        let tickets = match batcher.recv().unwrap() {
            Message::Tickets { tickets } => tickets,
            m => panic!("expected tickets, got {m:?}"),
        };
        assert_eq!(tickets.len(), 4);
        // Dispatch order == VCT order: indexes 0..4 in sequence.
        assert_eq!(tickets.iter().map(|t| t.index).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        let results: Vec<_> = tickets.iter().map(|t| (t.ticket, Value::Bool(true))).collect();
        batcher.send(&Message::TicketResults { results }).unwrap();
        assert_eq!(batcher.recv().unwrap(), Message::Ack);
        batcher.send(&Message::Shutdown).unwrap();
        h.join().unwrap();

        // A legacy client drains the remaining two tickets one by one.
        let (mut legacy, server) = local::pair(LinkModel::FAST_LAN, false);
        let d = Arc::clone(&dist);
        let h = std::thread::spawn(move || d.handle_conn(Box::new(server)).unwrap());
        legacy.send(&Message::Hello { client: "l0".into(), profile: "tablet".into() }).unwrap();
        legacy.recv().unwrap();
        for _ in 0..2 {
            legacy.send(&Message::TicketRequest).unwrap();
            let ticket = match legacy.recv().unwrap() {
                Message::Ticket { ticket, .. } => ticket,
                m => panic!("expected ticket, got {m:?}"),
            };
            legacy.send(&Message::TicketResult { ticket, result: Value::Bool(false) }).unwrap();
            assert_eq!(legacy.recv().unwrap(), Message::Ack);
        }
        // Pool empty: a batch request is answered with NoTicket.
        legacy.send(&Message::TicketBatchRequest { max: 8 }).unwrap();
        assert!(matches!(legacy.recv().unwrap(), Message::NoTicket { .. }));
        legacy.send(&Message::Shutdown).unwrap();
        h.join().unwrap();

        assert_eq!(fw.store().progress(Some(task)).done, 6);
        assert_eq!(dist.stats.tickets_served.load(Ordering::Relaxed), 6);
        assert_eq!(dist.stats.results_accepted.load(Ordering::Relaxed), 6);
        assert_eq!(dist.stats.results_duplicate.load(Ordering::Relaxed), 0);
    }

    /// The server cap bounds one batch even when the client asks for
    /// more, and `max: 0` is clamped up to 1 rather than ignored.
    #[test]
    fn batch_request_clamped_to_server_cap() {
        let (fw, _task) = framework_with_tickets(DEFAULT_MAX_BATCH + 8);
        let dist = Distributor::new(&fw);
        let (mut client, server) = local::pair(LinkModel::FAST_LAN, false);
        let d = Arc::clone(&dist);
        let h = std::thread::spawn(move || d.handle_conn(Box::new(server)).unwrap());
        client.send(&Message::Hello { client: "w".into(), profile: "t".into() }).unwrap();
        client.recv().unwrap();
        client.send(&Message::TicketBatchRequest { max: DEFAULT_MAX_BATCH + 8 }).unwrap();
        match client.recv().unwrap() {
            Message::Tickets { tickets } => assert_eq!(tickets.len(), DEFAULT_MAX_BATCH),
            m => panic!("{m:?}"),
        }
        client.send(&Message::TicketBatchRequest { max: 0 }).unwrap();
        match client.recv().unwrap() {
            Message::Tickets { tickets } => assert_eq!(tickets.len(), 1),
            m => panic!("{m:?}"),
        }
        client.send(&Message::Shutdown).unwrap();
        h.join().unwrap();
        assert_eq!(fw.store().progress(None).in_flight, DEFAULT_MAX_BATCH + 1);
    }

    #[test]
    fn error_report_triggers_reload_and_requeue() {
        let (fw, _) = framework_with_tickets(1);
        let dist = Distributor::new(&fw);
        let (mut client, server) = local::pair(LinkModel::FAST_LAN, false);
        let d = Arc::clone(&dist);
        let h = std::thread::spawn(move || d.handle_conn(Box::new(server)).unwrap());
        client.send(&Message::Hello { client: "w0".into(), profile: "tablet".into() }).unwrap();
        client.recv().unwrap();
        client.send(&Message::TicketRequest).unwrap();
        let ticket = match client.recv().unwrap() {
            Message::Ticket { ticket, .. } => ticket,
            m => panic!("{m:?}"),
        };
        client
            .send(&Message::ErrorReport {
                ticket,
                message: "TypeError: x is undefined".into(),
                stack: "at task.run".into(),
            })
            .unwrap();
        assert_eq!(client.recv().unwrap(), Message::Reload);
        // Ticket is immediately available again.
        client.send(&Message::TicketRequest).unwrap();
        assert!(matches!(client.recv().unwrap(), Message::Ticket { .. }));
        client.send(&Message::Shutdown).unwrap();
        h.join().unwrap();
        assert_eq!(fw.store().error_count(), 1);
        let drained = fw.store().drain_errors();
        assert_eq!(drained.len(), 1);
        assert_eq!(fw.store().error_count(), 1, "drain keeps the cumulative count");
    }

    #[test]
    fn dataset_requests_served() {
        let (fw, _) = framework_with_tickets(1);
        fw.datasets().register("d1", crate::runtime::Tensor::new(vec![2], vec![1.0, 2.0]).unwrap());
        let dist = Distributor::new(&fw);
        let (mut client, server) = local::pair(LinkModel::FAST_LAN, false);
        let d = Arc::clone(&dist);
        let h = std::thread::spawn(move || {
            let _ = d.handle_conn(Box::new(server));
        });
        client.send(&Message::DataRequest { key: "d1".into() }).unwrap();
        match client.recv().unwrap() {
            Message::Data { key, shape, b64 } => {
                assert_eq!(key, "d1");
                assert_eq!(shape, vec![2]);
                assert_eq!(crate::util::base64::decode_f32(&b64).unwrap(), vec![1.0, 2.0]);
            }
            m => panic!("{m:?}"),
        }
        // Unknown dataset kills the connection (worker will reconnect).
        client.send(&Message::DataRequest { key: "nope".into() }).unwrap();
        assert!(client.recv().is_err());
        h.join().unwrap();
    }

    #[test]
    fn serve_accepts_multiple_connections() {
        let (fw, task) = framework_with_tickets(4);
        let dist = Distributor::new(&fw);
        let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
        let acceptor = dist.serve(Box::new(listener));
        let mut joins = Vec::new();
        for w in 0..2 {
            let connector = connector.clone();
            joins.push(std::thread::spawn(move || {
                let mut c = connector.connect().unwrap();
                c.send(&Message::Hello { client: format!("w{w}"), profile: "t".into() }).unwrap();
                c.recv().unwrap();
                loop {
                    c.send(&Message::TicketRequest).unwrap();
                    match c.recv().unwrap() {
                        Message::Ticket { ticket, .. } => {
                            c.send(&Message::TicketResult { ticket, result: Value::Null }).unwrap();
                            c.recv().unwrap();
                        }
                        Message::NoTicket { .. } => break,
                        m => panic!("{m:?}"),
                    }
                }
                c.send(&Message::Shutdown).unwrap();
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(fw.store().progress(Some(task)).done, 4);
        dist.stop();
        drop(connector);
        acceptor.join().unwrap();
        assert_eq!(dist.stats.connections.load(Ordering::Relaxed), 2);
    }

    /// First result wins exactly once: a redistributed ticket answered by
    /// two clients keeps the first value, counts the second as a
    /// duplicate, and still Acks the slow client (it must not reload).
    #[test]
    fn duplicate_result_wins_once() {
        let fw = Framework::builder()
            .store_config(crate::store::StoreConfig {
                requeue_after_ms: 0, // every in-flight ticket is immediately redistributable
                min_redistribute_ms: 0,
                requeue_on_error: true,
            })
            .build();
        let task = fw.create_task(Arc::new(IsPrimeTask));
        task.calculate(vec![Value::obj(vec![("candidate", Value::num(7.0))])]);
        let task_id = task.id;
        let dist = Distributor::new(&fw);

        let mut clients = Vec::new();
        let mut handlers = Vec::new();
        for i in 0..2 {
            let (mut c, s) = local::pair(LinkModel::FAST_LAN, false);
            let d = Arc::clone(&dist);
            handlers.push(std::thread::spawn(move || {
                let _ = d.handle_conn(Box::new(s));
            }));
            c.send(&Message::Hello { client: format!("w{i}"), profile: "t".into() }).unwrap();
            assert_eq!(c.recv().unwrap(), Message::Ack);
            clients.push(c);
        }
        let mut tickets = Vec::new();
        for c in clients.iter_mut() {
            c.send(&Message::TicketRequest).unwrap();
            match c.recv().unwrap() {
                Message::Ticket { ticket, .. } => tickets.push(ticket),
                m => panic!("expected ticket, got {m:?}"),
            }
        }
        assert_eq!(tickets[0], tickets[1], "both clients race the same ticket");

        clients[0]
            .send(&Message::TicketResult { ticket: tickets[0], result: Value::num(1.0) })
            .unwrap();
        assert_eq!(clients[0].recv().unwrap(), Message::Ack);
        clients[1]
            .send(&Message::TicketResult { ticket: tickets[1], result: Value::num(2.0) })
            .unwrap();
        assert_eq!(clients[1].recv().unwrap(), Message::Ack, "duplicate still acked");

        assert_eq!(dist.stats.results_accepted.load(Ordering::Relaxed), 1);
        assert_eq!(dist.stats.results_duplicate.load(Ordering::Relaxed), 1);
        let p = fw.store().progress(None);
        assert_eq!(p.done, 1);
        assert_eq!(p.duplicate_results, 1);
        assert_eq!(p.redistributions, 1);
        assert_eq!(fw.store().wait_results(task_id), vec![Value::num(1.0)]);
        for mut c in clients {
            c.send(&Message::Shutdown).unwrap();
        }
        for h in handlers {
            h.join().unwrap();
        }
    }

    /// Error-report accounting: the stat and store error counters move,
    /// the ticket returns to the pending pool exactly once, and the
    /// re-issued ticket carries the incremented distribution count.
    #[test]
    fn error_requeue_accounting() {
        let (fw, _) = framework_with_tickets(1);
        let dist = Distributor::new(&fw);
        let (mut client, server) = local::pair(LinkModel::FAST_LAN, false);
        let d = Arc::clone(&dist);
        let h = std::thread::spawn(move || d.handle_conn(Box::new(server)).unwrap());
        client.send(&Message::Hello { client: "w0".into(), profile: "t".into() }).unwrap();
        client.recv().unwrap();
        client.send(&Message::TicketRequest).unwrap();
        let ticket = match client.recv().unwrap() {
            Message::Ticket { ticket, .. } => ticket,
            m => panic!("{m:?}"),
        };
        let before = fw.store().progress(None);
        assert_eq!((before.pending, before.in_flight), (0, 1));

        client
            .send(&Message::ErrorReport { ticket, message: "boom".into(), stack: "s".into() })
            .unwrap();
        assert_eq!(client.recv().unwrap(), Message::Reload);
        assert_eq!(dist.stats.errors_reported.load(Ordering::Relaxed), 1);
        let after = fw.store().progress(None);
        assert_eq!((after.pending, after.in_flight, after.errors), (1, 0, 1));
        assert_eq!(dist.clients()[0].errors, 1);

        // The requeued ticket is served again with its history intact.
        client.send(&Message::TicketRequest).unwrap();
        match client.recv().unwrap() {
            Message::Ticket { ticket: t2, .. } => assert_eq!(t2, ticket),
            m => panic!("{m:?}"),
        }
        let p = fw.store().progress(None);
        assert_eq!((p.pending, p.in_flight), (0, 1));
        assert_eq!(p.redistributions, 1, "re-serving an errored ticket is a redistribution");
        client.send(&Message::Shutdown).unwrap();
        h.join().unwrap();
    }
}
