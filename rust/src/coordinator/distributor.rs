//! The TicketDistributor: serves the browser protocol.
//!
//! One thread per connection (the paper's TicketDistributor is a single
//! Node.js process multiplexing WebSockets; with blocking sockets the
//! thread-per-conn layout is the idiomatic equivalent, and the shared
//! state is the same ticket store the SQL server held).
//!
//! Handles, per §2.1.2:
//! * `TicketRequest` → next ticket by virtual created time (or NoTicket
//!   with a retry hint);
//! * `TicketBatchRequest { max }` → up to `min(max, max_batch)` tickets
//!   in one round trip via `Scheduler::next_tickets` (empty pool →
//!   NoTicket), amortising the coordinator RTT that bounds fast-link
//!   throughput;
//! * `TaskRequest` → task code metadata (code bytes accounted);
//! * `DataRequest` → dataset payloads (the HTTPServer API);
//! * `TicketResult` → store completion (first result wins);
//! * `TicketResults` → batched completion through
//!   `Scheduler::complete_batch` (one Ack; per-entry first-result-wins
//!   accounting);
//! * `ErrorReport` → recorded, ticket requeued, client told to reload;
//! * `ErrorReports` → a whole batch's failures recorded and requeued in
//!   one round trip, answered by a single Reload;
//! * `ReleaseTickets` → the client's undone tickets handed back through
//!   `Scheduler::release_batch`, immediately re-dispatchable.
//!
//! The *active failure path* (DESIGN.md §2.4): every ticket dispatched
//! over a connection is tracked until it is answered (result, error
//! report, or explicit release), and when the handler exits — orderly
//! shutdown, protocol violation, or a vanished socket — the leftovers
//! are released at once instead of stranding for the store's
//! redistribution window ([`DistributorConfig::release_on_disconnect`]
//! turns this off to reproduce the paper's passive §2.1.2 baseline).
//!
//! The singular forms stay served unchanged, so a legacy client that
//! speaks only `TicketRequest`/`TicketResult` interoperates with
//! batching clients on the same store.
//!
//! The protocol itself — strictly one reply per request — lives in
//! [`Session`], a transport-free state machine: the thread-per-conn
//! path pumps `recv -> Session::handle -> send`, and the churn
//! simulator ([`crate::sim`]) drives thousands of sessions directly at
//! virtual event times, no sockets or threads involved.  Both paths
//! run the *same* dispatch, accounting and disconnect-release code.
//!
//! Timestamps (Hello connect times, dispatch `now_ms` for the store's
//! VCT windows) read the distributor's injected
//! [`Clock`](crate::util::clock::Clock) — wall time by default,
//! virtual time under the simulator.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::coordinator::framework::Framework;
use crate::store::{Scheduler, Standing, TicketId, VoteOutcome};
use crate::tasks::{DatasetStore, Registry};
use crate::transport::{Conn, Listener, Message, WireTicket};
use crate::util::clock::{Clock, WallClock};
use crate::util::lockcheck::{CheckedMutex, Rank};

/// Per-client info shown on the console.
#[derive(Debug, Clone, Default)]
pub struct ClientInfo {
    pub client: String,
    pub profile: String,
    pub tickets_served: u64,
    pub results: u64,
    pub errors: u64,
    pub connected_ms: u64,
    /// The connection that wrote this entry has ended; kept (marked,
    /// not erased) so end-of-run summaries still show the client.
    pub disconnected: bool,
    /// Which connection's Hello owns this entry — a reloading worker's
    /// fresh connection may re-insert it before the old handler
    /// notices EOF, and only the owning handler may mark it.
    pub(crate) conn_seq: u64,
}

#[derive(Default)]
pub struct DistributorStats {
    pub connections: AtomicU64,
    pub tickets_served: AtomicU64,
    pub results_accepted: AtomicU64,
    /// Same-client retries of an already-done ticket (a reloading
    /// worker re-sending its answer).  Cross-client duplicates land in
    /// [`results_duplicate_cross`](Self::results_duplicate_cross) —
    /// conflating the two would mask vote fraud at R > 1.
    pub results_duplicate: AtomicU64,
    /// A *different* client answering an already-done ticket (a slower
    /// replica or a redistribution race) — the legitimate-looking shape
    /// a vote-fraud attempt also takes, so it is counted separately.
    pub results_duplicate_cross: AtomicU64,
    /// Votes recorded on tickets still short of quorum (R > 1 only).
    pub results_pending_quorum: AtomicU64,
    /// Ticket requests refused because the client is quarantined.
    pub noticket_quarantined: AtomicU64,
    pub errors_reported: AtomicU64,
    pub data_requests: AtomicU64,
    pub task_requests: AtomicU64,
    /// Tickets handed back through the active failure path: explicit
    /// `ReleaseTickets` messages plus disconnect releases.
    pub tickets_released: AtomicU64,
    /// Hello'd connections whose handler has since ended.  This is
    /// *connection* churn, not distinct clients: a worker reload (one
    /// per failing batch, by design) ends one connection and re-Hellos
    /// on the next, and a reload whose fresh Hello lands before the
    /// old handler exits is not counted at all (the entry's `conn_seq`
    /// has moved on).
    pub clients_disconnected: AtomicU64,
    /// Bytes moved over all finished connections (server side).
    pub bytes_sent: AtomicU64,
    pub bytes_received: AtomicU64,
}

/// Tuning knobs of a [`Distributor`] — plumbed from
/// [`ClusterConfig`](crate::dist::ClusterConfig) by the in-process
/// cluster, defaulted everywhere else.
#[derive(Debug, Clone)]
pub struct DistributorConfig {
    /// Retry hint handed to idle workers.
    pub idle_retry_ms: u64,
    /// Server-side cap on one `TicketBatchRequest` (protects the store
    /// from a single client draining the pool in one call).
    pub max_batch: usize,
    /// Release a connection's unanswered tickets the moment its
    /// handler exits (the active failure path).  `false` reproduces
    /// the paper's passive baseline: a vanished browser's tickets wait
    /// out the §2.1.2 redistribution windows.
    pub release_on_disconnect: bool,
}

impl Default for DistributorConfig {
    fn default() -> Self {
        DistributorConfig {
            idle_retry_ms: 20,
            max_batch: DEFAULT_MAX_BATCH,
            release_on_disconnect: true,
        }
    }
}

pub struct Distributor {
    store: Arc<dyn Scheduler>,
    registry: Registry,
    datasets: Arc<DatasetStore>,
    pub stats: DistributorStats,
    clients: CheckedMutex<HashMap<String, ClientInfo>>,
    stop: AtomicBool,
    /// Hands out one [`ClientInfo::conn_seq`] per handled connection.
    next_conn_seq: AtomicU64,
    pub cfg: DistributorConfig,
    /// Time source for connect stamps and dispatch `now_ms` (the VCT
    /// window decisions).  Wall clock in production; the churn
    /// simulator injects a virtual clock.
    clock: Arc<dyn Clock>,
}

/// Default server-side cap on one dispatched batch.
pub const DEFAULT_MAX_BATCH: usize = 64;

impl Distributor {
    pub fn new(fw: &Arc<Framework>) -> Arc<Distributor> {
        Self::new_with(fw, DistributorConfig::default())
    }

    /// [`new`](Self::new) with explicit tuning.  Inherits the
    /// framework's injected clock, so a virtual-clocked framework gets
    /// a virtual-clocked distributor with no extra plumbing.
    pub fn new_with(fw: &Arc<Framework>, cfg: DistributorConfig) -> Arc<Distributor> {
        Self::from_parts_clocked(
            Arc::clone(fw.store()),
            fw.registry_snapshot(),
            fw.datasets().clone(),
            cfg,
            Arc::clone(fw.clock()),
        )
    }

    /// Build from raw parts (dist drivers that bypass Framework).
    pub fn from_parts(
        store: Arc<dyn Scheduler>,
        registry: Registry,
        datasets: Arc<DatasetStore>,
    ) -> Arc<Distributor> {
        Self::from_parts_with(store, registry, datasets, DistributorConfig::default())
    }

    /// [`from_parts`](Self::from_parts) with explicit tuning (wall
    /// clock).
    pub fn from_parts_with(
        store: Arc<dyn Scheduler>,
        registry: Registry,
        datasets: Arc<DatasetStore>,
        cfg: DistributorConfig,
    ) -> Arc<Distributor> {
        Self::from_parts_clocked(store, registry, datasets, cfg, Arc::new(WallClock))
    }

    /// [`from_parts_with`](Self::from_parts_with) plus an explicit time
    /// source (the churn simulator's entry point).
    pub fn from_parts_clocked(
        store: Arc<dyn Scheduler>,
        registry: Registry,
        datasets: Arc<DatasetStore>,
        cfg: DistributorConfig,
        clock: Arc<dyn Clock>,
    ) -> Arc<Distributor> {
        Arc::new(Distributor {
            store,
            registry,
            datasets,
            stats: DistributorStats::default(),
            clients: CheckedMutex::new(Rank::distributor_clients(), HashMap::new()),
            stop: AtomicBool::new(false),
            next_conn_seq: AtomicU64::new(0),
            cfg,
            clock,
        })
    }

    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Clone the per-client table (disconnected clients included,
    /// marked).  On-demand reporting only
    /// ([`crate::coordinator::console::render_clients`]); per-render
    /// paths use [`Self::client_count`] and the stats atomics instead.
    pub fn clients(&self) -> Vec<ClientInfo> {
        self.clients.lock().unwrap().values().cloned().collect()
    }

    /// Number of *currently connected* clients (Hello'd, handler still
    /// running) — disconnected entries are excluded, so console totals
    /// never count ghost workers.
    pub fn client_count(&self) -> usize {
        self.clients.lock().unwrap().values().filter(|c| !c.disconnected).count()
    }

    pub fn store(&self) -> &Arc<dyn Scheduler> {
        &self.store
    }

    pub fn datasets(&self) -> &Arc<DatasetStore> {
        &self.datasets
    }

    /// Accept-loop: spawn a handler thread per connection.  Returns the
    /// acceptor handle; stop by making `listener.accept()` fail (drop
    /// all connectors / close the socket) after calling [`stop`].
    pub fn serve(self: &Arc<Self>, mut listener: Box<dyn Listener>) -> JoinHandle<()> {
        let this = Arc::clone(self);
        std::thread::spawn(move || {
            let mut handlers = Vec::new();
            while !this.stopped() {
                match listener.accept() {
                    Ok(conn) => {
                        let d = Arc::clone(&this);
                        handlers.push(std::thread::spawn(move || {
                            if let Err(e) = d.handle_conn(conn) {
                                crate::log_debug!("distributor", "connection ended: {e:#}");
                            }
                        }));
                    }
                    Err(_) => break,
                }
            }
            for h in handlers {
                let _ = h.join();
            }
        })
    }

    /// Serve one connection until Shutdown/EOF, accounting its bytes
    /// incrementally (so live benches see traffic as it happens).
    pub fn handle_conn(self: &Arc<Self>, mut conn: Box<dyn Conn>) -> Result<()> {
        self.handle_conn_inner(&mut *conn)
    }

    /// Open a [`Session`]: the per-connection protocol state machine,
    /// detached from any transport.  The thread-per-conn path pumps it
    /// from a socket; the churn simulator drives thousands directly;
    /// the epoll gateway owns one per registered connection (which is
    /// why the session owns an `Arc` instead of borrowing — its
    /// lifetime is the connection's, not a stack frame's).
    /// Counts as one connection in [`DistributorStats::connections`].
    pub fn open_session(self: &Arc<Self>) -> Session {
        self.stats.connections.fetch_add(1, Ordering::Relaxed);
        Session {
            dist: Arc::clone(self),
            conn_seq: self.next_conn_seq.fetch_add(1, Ordering::Relaxed),
            client: String::from("unknown"),
            held: HashSet::new(),
            closed: false,
        }
    }

    fn handle_conn_inner(self: &Arc<Self>, conn: &mut dyn Conn) -> Result<()> {
        let mut session = self.open_session();
        let result = self.conn_loop(conn, &mut session);
        // However the pump ended — orderly shutdown, protocol
        // violation, vanished socket — closing the session runs the
        // active failure path and retires the client-table entry.
        session.close();
        result
    }

    /// The transport pump: recv -> [`Session::handle`] -> send, with
    /// incremental byte accounting.  All protocol behaviour lives in
    /// the session; this loop only moves frames and enforces shutdown.
    fn conn_loop(&self, conn: &mut dyn Conn, session: &mut Session) -> Result<()> {
        let (mut acc_sent, mut acc_recv) = (0u64, 0u64);
        let mut account = |conn: &mut dyn Conn, stats: &DistributorStats| {
            let (s, r) = conn.bytes();
            stats.bytes_sent.fetch_add(s - acc_sent, Ordering::Relaxed);
            stats.bytes_received.fetch_add(r - acc_recv, Ordering::Relaxed);
            acc_sent = s;
            acc_recv = r;
        };
        loop {
            if self.stopped() {
                let _ = conn.send(&Message::Shutdown);
                account(conn, &self.stats);
                return Ok(());
            }
            let msg = match conn.recv() {
                Ok(m) => m,
                Err(e) => {
                    account(conn, &self.stats);
                    return Err(e);
                }
            };
            account(conn, &self.stats);
            // A stop that lands while a ticket request is in flight
            // answers with Shutdown instead of dispatching more work.
            if self.stopped()
                && matches!(msg, Message::TicketRequest | Message::TicketBatchRequest { .. })
            {
                conn.send(&Message::Shutdown)?;
                return Ok(());
            }
            match session.handle(msg)? {
                Some(reply) => conn.send(&reply)?,
                None => return Ok(()), // orderly Shutdown
            }
        }
    }
}

/// One connection's half of the §2.1.2 protocol, as a transport-free
/// state machine: feed it inbound [`Message`]s, send back the replies.
///
/// Every request is answered by exactly one reply ([`Self::handle`]
/// returns `Some`), except `Shutdown` which ends the session (`None`).
/// The session tracks the tickets dispatched over it and not yet
/// answered by a result, an error report, or an explicit release;
/// [`Self::close`] releases those leftovers (the active failure path,
/// when [`DistributorConfig::release_on_disconnect`] is on) and retires
/// the client-table entry.  Dropping an unclosed session closes it, so
/// a vanished connection can never strand its batch by accident.
pub struct Session {
    dist: Arc<Distributor>,
    conn_seq: u64,
    client: String,
    /// Tickets dispatched over this session and not yet answered by a
    /// result, an error report, or an explicit release.
    held: HashSet<TicketId>,
    closed: bool,
}

impl Session {
    /// The client id announced by Hello (`"unknown"` before it).
    pub fn client(&self) -> &str {
        &self.client
    }

    /// Tickets currently dispatched-but-unanswered on this session,
    /// sorted by id (deterministic for the simulator's metrics).
    pub fn held_tickets(&self) -> Vec<TicketId> {
        let mut ids: Vec<TicketId> = self.held.iter().copied().collect();
        ids.sort();
        ids
    }

    /// Dispatch refusal for quarantined clients (R > 1 only).  Returns
    /// the `NoTicket` reply when the requesting client is serving a
    /// probation sentence; everything it still holds is handed back
    /// through the attributed release path so honest workers pick the
    /// tickets up within one sweep instead of waiting out the
    /// redistribution window.  `None` means the client is in good
    /// standing and dispatch proceeds normally.
    fn quarantine_gate(&mut self, d: &Arc<Distributor>) -> Option<Message> {
        if !d.store.config().verifying() {
            return None; // R = 1: no reputation layer, zero cost
        }
        if !matches!(
            d.store.client_standing(&self.client, d.clock.now_ms()),
            Standing::Quarantined { .. }
        ) {
            return None;
        }
        if !self.held.is_empty() {
            let ids = self.held_tickets();
            self.held.clear();
            let released = d
                .store
                .release_batch_from(&self.client, &ids)
                .into_iter()
                .filter(|&f| f)
                .count() as u64;
            d.stats.tickets_released.fetch_add(released, Ordering::Relaxed);
        }
        d.stats.noticket_quarantined.fetch_add(1, Ordering::Relaxed);
        Some(Message::NoTicket { retry_after_ms: d.cfg.idle_retry_ms })
    }

    /// Fold one vote outcome into the distributor counters.
    fn account_vote(d: &Distributor, out: &VoteOutcome) {
        let c = match out {
            VoteOutcome::Accepted { .. } => &d.stats.results_accepted,
            VoteOutcome::Duplicate { same_client: true } => &d.stats.results_duplicate,
            VoteOutcome::Duplicate { same_client: false } => &d.stats.results_duplicate_cross,
            VoteOutcome::Pending | VoteOutcome::Repeat => &d.stats.results_pending_quorum,
        };
        c.fetch_add(1, Ordering::Relaxed);
    }

    /// Handle one inbound message; returns the reply to send, or
    /// `None` when the session is over (orderly `Shutdown`).  An `Err`
    /// is a protocol violation: the caller should close the session
    /// (which releases whatever it still held).
    pub fn handle(&mut self, msg: Message) -> Result<Option<Message>> {
        let d = Arc::clone(&self.dist);
        match msg {
            Message::Hello { client: c, profile } => {
                self.client = c.clone();
                d.clients.lock().unwrap().insert(
                    c.clone(),
                    ClientInfo {
                        client: c,
                        profile,
                        connected_ms: d.clock.now_ms(),
                        conn_seq: self.conn_seq,
                        ..Default::default()
                    },
                );
                Ok(Some(Message::Ack))
            }
            Message::TicketRequest => {
                if let Some(refusal) = self.quarantine_gate(&d) {
                    return Ok(Some(refusal));
                }
                match d.store.next_ticket(&self.client, d.clock.now_ms()) {
                    Some(t) => {
                        d.stats.tickets_served.fetch_add(1, Ordering::Relaxed);
                        if let Some(ci) = d.clients.lock().unwrap().get_mut(self.client.as_str()) {
                            ci.tickets_served += 1;
                        }
                        self.held.insert(t.id);
                        Ok(Some(Message::Ticket {
                            ticket: t.id,
                            task: t.task,
                            task_name: t.task_name.clone(),
                            index: t.index,
                            payload: t.payload.clone(),
                        }))
                    }
                    None => Ok(Some(Message::NoTicket { retry_after_ms: d.cfg.idle_retry_ms })),
                }
            }
            Message::TicketBatchRequest { max } => {
                if let Some(refusal) = self.quarantine_gate(&d) {
                    return Ok(Some(refusal));
                }
                let k = max.clamp(1, d.cfg.max_batch.max(1));
                let batch = d.store.next_tickets(&self.client, d.clock.now_ms(), k);
                if batch.is_empty() {
                    Ok(Some(Message::NoTicket { retry_after_ms: d.cfg.idle_retry_ms }))
                } else {
                    d.stats.tickets_served.fetch_add(batch.len() as u64, Ordering::Relaxed);
                    if let Some(ci) = d.clients.lock().unwrap().get_mut(self.client.as_str()) {
                        ci.tickets_served += batch.len() as u64;
                    }
                    for t in &batch {
                        self.held.insert(t.id);
                    }
                    let tickets: Vec<WireTicket> = batch
                        .into_iter()
                        .map(|t| WireTicket {
                            ticket: t.id,
                            task: t.task,
                            task_name: t.task_name,
                            index: t.index,
                            payload: t.payload,
                        })
                        .collect();
                    Ok(Some(Message::Tickets { tickets }))
                }
            }
            Message::TaskRequest { task_name } => {
                d.stats.task_requests.fetch_add(1, Ordering::Relaxed);
                let def = d.registry.get(&task_name)?;
                // dataset_refs are per-ticket; the static advertisement
                // is empty (workers resolve refs from each payload).
                Ok(Some(Message::TaskCode {
                    task_name,
                    code_bytes: def.code_bytes(),
                    dataset_refs: Vec::new(),
                }))
            }
            Message::DataRequest { key } => {
                d.stats.data_requests.fetch_add(1, Ordering::Relaxed);
                let enc = d.datasets.encoded(&key)?;
                Ok(Some(Message::Data { key, shape: enc.0.clone(), b64: enc.1.clone() }))
            }
            Message::TicketResult { ticket, result } => {
                // `held` is trimmed only after a successful apply: if
                // `?` kills the session the close release still covers
                // the ticket (a no-op when it was already done).  The
                // vote entry point is the attributed form of complete:
                // at R = 1 it IS the legacy completion; at R > 1 it is
                // one ballot toward quorum.
                let out = d.store.vote(&self.client, ticket, result, d.clock.now_ms())?;
                self.held.remove(&ticket);
                Self::account_vote(&d, &out);
                if let Some(ci) = d.clients.lock().unwrap().get_mut(self.client.as_str()) {
                    ci.results += 1;
                }
                Ok(Some(Message::Ack))
            }
            Message::TicketResults { results } => {
                let n = results.len() as u64;
                let ids: Vec<TicketId> = results.iter().map(|(id, _)| *id).collect();
                // A mid-batch unknown ticket (a protocol-violating
                // client) applies the prefix, then `?` kills the
                // session with every id still in `held`: the applied
                // prefix releases as a no-op (done tickets do not move)
                // and the unapplied suffix is released for real, so
                // nothing strands.  The stats counters below are
                // skipped for that prefix; the store's progress
                // counters — the source of truth — stay exact either
                // way.
                let outcomes = d.store.vote_batch(&self.client, results, d.clock.now_ms())?;
                for id in &ids {
                    self.held.remove(id);
                }
                for out in &outcomes {
                    Self::account_vote(&d, out);
                }
                if let Some(ci) = d.clients.lock().unwrap().get_mut(self.client.as_str()) {
                    ci.results += n;
                }
                Ok(Some(Message::Ack))
            }
            Message::ErrorReport { ticket, message, stack } => {
                d.stats.errors_reported.fetch_add(1, Ordering::Relaxed);
                if let Some(ci) = d.clients.lock().unwrap().get_mut(self.client.as_str()) {
                    ci.errors += 1;
                }
                crate::log_warn!("distributor", "error report from {}: {message}", self.client);
                self.held.remove(&ticket);
                d.store.report_error_from(&self.client, ticket, format!("{message}\n{stack}"))?;
                // The paper: the browser reloads itself after reporting.
                Ok(Some(Message::Reload))
            }
            Message::ErrorReports { reports } => {
                let n = reports.len() as u64;
                d.stats.errors_reported.fetch_add(n, Ordering::Relaxed);
                if let Some(ci) = d.clients.lock().unwrap().get_mut(self.client.as_str()) {
                    ci.errors += n;
                }
                for r in reports {
                    crate::log_warn!(
                        "distributor",
                        "error report from {}: {}",
                        self.client,
                        r.message
                    );
                    self.held.remove(&r.ticket);
                    d.store.report_error_from(
                        &self.client,
                        r.ticket,
                        format!("{}\n{}", r.message, r.stack),
                    )?;
                }
                // One Reload acknowledges the whole batch: the client
                // reloads itself once, not once per failure.
                Ok(Some(Message::Reload))
            }
            Message::ReleaseTickets { tickets } => {
                for id in &tickets {
                    self.held.remove(id);
                }
                let released = d
                    .store
                    .release_batch_from(&self.client, &tickets)
                    .into_iter()
                    .filter(|&f| f)
                    .count() as u64;
                d.stats.tickets_released.fetch_add(released, Ordering::Relaxed);
                Ok(Some(Message::Ack))
            }
            Message::Shutdown => Ok(None),
            other => {
                anyhow::bail!("unexpected message from {}: {other:?}", self.client)
            }
        }
    }

    /// End the session: release whatever it still held (the active
    /// failure path — however the connection ended, the undone tickets
    /// re-enter dispatch now instead of stranding for the store's
    /// redistribution window) and retire the client-table entry (mark,
    /// don't erase: end-of-run summaries keep the history) so
    /// [`Distributor::client_count`] never reports ghost workers.
    /// Idempotent; also runs on drop.
    pub fn close(&mut self) {
        if self.closed {
            return;
        }
        self.closed = true;
        let d = Arc::clone(&self.dist);
        if d.cfg.release_on_disconnect && !self.held.is_empty() {
            let mut ids: Vec<TicketId> = self.held.drain().collect();
            ids.sort(); // deterministic release order for WAL transcripts
            let released = d
                .store
                .release_batch_from(&self.client, &ids)
                .into_iter()
                .filter(|&f| f)
                .count() as u64;
            if released > 0 {
                crate::log_debug!(
                    "distributor",
                    "released {released} in-flight tickets from disconnected {}",
                    self.client
                );
            }
            d.stats.tickets_released.fetch_add(released, Ordering::Relaxed);
        }
        let mut clients = d.clients.lock().unwrap();
        if let Some(ci) = clients.get_mut(&self.client) {
            if ci.conn_seq == self.conn_seq && !ci.disconnected {
                ci.disconnected = true;
                d.stats.clients_disconnected.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        self.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::TaskId;
    use crate::tasks::is_prime::IsPrimeTask;
    use crate::transport::local;
    use crate::transport::{LinkModel, WireError};
    use crate::util::json::Value;

    fn framework_with_tickets(n: usize) -> (Arc<Framework>, TaskId) {
        let fw = Framework::builder().build();
        let task = fw.create_task(Arc::new(IsPrimeTask));
        task.calculate(
            (0..n).map(|i| Value::obj(vec![("candidate", Value::num(i as f64 + 2.0))])).collect(),
        );
        let id = task.id;
        (fw, id)
    }

    #[test]
    fn protocol_happy_path() {
        let (fw, _task) = framework_with_tickets(1);
        let dist = Distributor::new(&fw);
        let (mut client, server) = local::pair(LinkModel::FAST_LAN, false);
        let d = Arc::clone(&dist);
        let h = std::thread::spawn(move || d.handle_conn(Box::new(server)).unwrap());

        client.send(&Message::Hello { client: "w0".into(), profile: "desktop".into() }).unwrap();
        assert_eq!(client.recv().unwrap(), Message::Ack);

        client.send(&Message::TicketRequest).unwrap();
        let (ticket, payload) = match client.recv().unwrap() {
            Message::Ticket { ticket, payload, task_name, .. } => {
                assert_eq!(task_name, "is_prime");
                (ticket, payload)
            }
            m => panic!("expected ticket, got {m:?}"),
        };
        assert_eq!(payload.get("candidate").unwrap().as_u64().unwrap(), 2);

        client.send(&Message::TaskRequest { task_name: "is_prime".into() }).unwrap();
        match client.recv().unwrap() {
            Message::TaskCode { code_bytes, .. } => assert!(code_bytes > 0),
            m => panic!("expected task code, got {m:?}"),
        }

        client
            .send(&Message::TicketResult {
                ticket,
                result: Value::obj(vec![("is_prime", Value::Bool(true))]),
            })
            .unwrap();
        assert_eq!(client.recv().unwrap(), Message::Ack);

        // No tickets left.
        client.send(&Message::TicketRequest).unwrap();
        assert!(matches!(client.recv().unwrap(), Message::NoTicket { .. }));

        client.send(&Message::Shutdown).unwrap();
        h.join().unwrap();
        assert_eq!(dist.stats.results_accepted.load(Ordering::Relaxed), 1);
        assert_eq!(dist.clients()[0].results, 1);
    }

    /// The batched protocol end to end, plus the compat requirement: a
    /// legacy client speaking only `TicketRequest`/`TicketResult`
    /// finishes the same task against the same distributor.
    #[test]
    fn batch_and_legacy_clients_interoperate() {
        let (fw, task) = framework_with_tickets(6);
        let dist = Distributor::new(&fw);
        let (mut batcher, server) = local::pair(LinkModel::FAST_LAN, false);
        let d = Arc::clone(&dist);
        let h = std::thread::spawn(move || d.handle_conn(Box::new(server)).unwrap());

        batcher.send(&Message::Hello { client: "b0".into(), profile: "desktop".into() }).unwrap();
        assert_eq!(batcher.recv().unwrap(), Message::Ack);
        batcher.send(&Message::TicketBatchRequest { max: 4 }).unwrap();
        let tickets = match batcher.recv().unwrap() {
            Message::Tickets { tickets } => tickets,
            m => panic!("expected tickets, got {m:?}"),
        };
        assert_eq!(tickets.len(), 4);
        // Dispatch order == VCT order: indexes 0..4 in sequence.
        assert_eq!(tickets.iter().map(|t| t.index).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        let results: Vec<_> = tickets.iter().map(|t| (t.ticket, Value::Bool(true))).collect();
        batcher.send(&Message::TicketResults { results }).unwrap();
        assert_eq!(batcher.recv().unwrap(), Message::Ack);
        batcher.send(&Message::Shutdown).unwrap();
        h.join().unwrap();

        // A legacy client drains the remaining two tickets one by one.
        let (mut legacy, server) = local::pair(LinkModel::FAST_LAN, false);
        let d = Arc::clone(&dist);
        let h = std::thread::spawn(move || d.handle_conn(Box::new(server)).unwrap());
        legacy.send(&Message::Hello { client: "l0".into(), profile: "tablet".into() }).unwrap();
        legacy.recv().unwrap();
        for _ in 0..2 {
            legacy.send(&Message::TicketRequest).unwrap();
            let ticket = match legacy.recv().unwrap() {
                Message::Ticket { ticket, .. } => ticket,
                m => panic!("expected ticket, got {m:?}"),
            };
            legacy.send(&Message::TicketResult { ticket, result: Value::Bool(false) }).unwrap();
            assert_eq!(legacy.recv().unwrap(), Message::Ack);
        }
        // Pool empty: a batch request is answered with NoTicket.
        legacy.send(&Message::TicketBatchRequest { max: 8 }).unwrap();
        assert!(matches!(legacy.recv().unwrap(), Message::NoTicket { .. }));
        legacy.send(&Message::Shutdown).unwrap();
        h.join().unwrap();

        assert_eq!(fw.store().progress(Some(task)).done, 6);
        assert_eq!(dist.stats.tickets_served.load(Ordering::Relaxed), 6);
        assert_eq!(dist.stats.results_accepted.load(Ordering::Relaxed), 6);
        assert_eq!(dist.stats.results_duplicate.load(Ordering::Relaxed), 0);
    }

    /// The server cap bounds one batch even when the client asks for
    /// more, and `max: 0` is clamped up to 1 rather than ignored.
    #[test]
    fn batch_request_clamped_to_server_cap() {
        let (fw, _task) = framework_with_tickets(DEFAULT_MAX_BATCH + 8);
        let dist = Distributor::new(&fw);
        let (mut client, server) = local::pair(LinkModel::FAST_LAN, false);
        let d = Arc::clone(&dist);
        let h = std::thread::spawn(move || d.handle_conn(Box::new(server)).unwrap());
        client.send(&Message::Hello { client: "w".into(), profile: "t".into() }).unwrap();
        client.recv().unwrap();
        client.send(&Message::TicketBatchRequest { max: DEFAULT_MAX_BATCH + 8 }).unwrap();
        match client.recv().unwrap() {
            Message::Tickets { tickets } => assert_eq!(tickets.len(), DEFAULT_MAX_BATCH),
            m => panic!("{m:?}"),
        }
        client.send(&Message::TicketBatchRequest { max: 0 }).unwrap();
        match client.recv().unwrap() {
            Message::Tickets { tickets } => assert_eq!(tickets.len(), 1),
            m => panic!("{m:?}"),
        }
        assert_eq!(fw.store().progress(None).in_flight, DEFAULT_MAX_BATCH + 1);
        client.send(&Message::Shutdown).unwrap();
        h.join().unwrap();
        // Handler exit releases the never-answered batch (the active
        // failure path), so nothing stays stranded in flight.
        let p = fw.store().progress(None);
        assert_eq!((p.pending, p.in_flight), (DEFAULT_MAX_BATCH + 8, 0));
        assert_eq!(
            dist.stats.tickets_released.load(Ordering::Relaxed),
            DEFAULT_MAX_BATCH as u64 + 1
        );
    }

    #[test]
    fn error_report_triggers_reload_and_requeue() {
        let (fw, _) = framework_with_tickets(1);
        let dist = Distributor::new(&fw);
        let (mut client, server) = local::pair(LinkModel::FAST_LAN, false);
        let d = Arc::clone(&dist);
        let h = std::thread::spawn(move || d.handle_conn(Box::new(server)).unwrap());
        client.send(&Message::Hello { client: "w0".into(), profile: "tablet".into() }).unwrap();
        client.recv().unwrap();
        client.send(&Message::TicketRequest).unwrap();
        let ticket = match client.recv().unwrap() {
            Message::Ticket { ticket, .. } => ticket,
            m => panic!("{m:?}"),
        };
        client
            .send(&Message::ErrorReport {
                ticket,
                message: "TypeError: x is undefined".into(),
                stack: "at task.run".into(),
            })
            .unwrap();
        assert_eq!(client.recv().unwrap(), Message::Reload);
        // Ticket is immediately available again.
        client.send(&Message::TicketRequest).unwrap();
        assert!(matches!(client.recv().unwrap(), Message::Ticket { .. }));
        client.send(&Message::Shutdown).unwrap();
        h.join().unwrap();
        assert_eq!(fw.store().error_count(), 1);
        let drained = fw.store().drain_errors();
        assert_eq!(drained.len(), 1);
        assert_eq!(fw.store().error_count(), 1, "drain keeps the cumulative count");
    }

    #[test]
    fn dataset_requests_served() {
        let (fw, _) = framework_with_tickets(1);
        fw.datasets().register("d1", crate::runtime::Tensor::new(vec![2], vec![1.0, 2.0]).unwrap());
        let dist = Distributor::new(&fw);
        let (mut client, server) = local::pair(LinkModel::FAST_LAN, false);
        let d = Arc::clone(&dist);
        let h = std::thread::spawn(move || {
            let _ = d.handle_conn(Box::new(server));
        });
        client.send(&Message::DataRequest { key: "d1".into() }).unwrap();
        match client.recv().unwrap() {
            Message::Data { key, shape, b64 } => {
                assert_eq!(key, "d1");
                assert_eq!(shape, vec![2]);
                assert_eq!(crate::util::base64::decode_f32(&b64).unwrap(), vec![1.0, 2.0]);
            }
            m => panic!("{m:?}"),
        }
        // Unknown dataset kills the connection (worker will reconnect).
        client.send(&Message::DataRequest { key: "nope".into() }).unwrap();
        assert!(client.recv().is_err());
        h.join().unwrap();
    }

    #[test]
    fn serve_accepts_multiple_connections() {
        let (fw, task) = framework_with_tickets(4);
        let dist = Distributor::new(&fw);
        let (listener, connector) = local::endpoint(LinkModel::FAST_LAN, false);
        let acceptor = dist.serve(Box::new(listener));
        let mut joins = Vec::new();
        for w in 0..2 {
            let connector = connector.clone();
            joins.push(std::thread::spawn(move || {
                let mut c = connector.connect().unwrap();
                c.send(&Message::Hello { client: format!("w{w}"), profile: "t".into() }).unwrap();
                c.recv().unwrap();
                loop {
                    c.send(&Message::TicketRequest).unwrap();
                    match c.recv().unwrap() {
                        Message::Ticket { ticket, .. } => {
                            c.send(&Message::TicketResult { ticket, result: Value::Null }).unwrap();
                            c.recv().unwrap();
                        }
                        Message::NoTicket { .. } => break,
                        m => panic!("{m:?}"),
                    }
                }
                c.send(&Message::Shutdown).unwrap();
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(fw.store().progress(Some(task)).done, 4);
        dist.stop();
        drop(connector);
        acceptor.join().unwrap();
        assert_eq!(dist.stats.connections.load(Ordering::Relaxed), 2);
    }

    /// First result wins exactly once: a redistributed ticket answered by
    /// two clients keeps the first value, counts the second as a
    /// duplicate, and still Acks the slow client (it must not reload).
    #[test]
    fn duplicate_result_wins_once() {
        let fw = Framework::builder()
            .store_config(crate::store::StoreConfig {
                requeue_after_ms: 0, // every in-flight ticket is immediately redistributable
                min_redistribute_ms: 0,
                requeue_on_error: true,
                ..crate::store::StoreConfig::default()
            })
            .build();
        let task = fw.create_task(Arc::new(IsPrimeTask));
        task.calculate(vec![Value::obj(vec![("candidate", Value::num(7.0))])]);
        let task_id = task.id;
        let dist = Distributor::new(&fw);

        let mut clients = Vec::new();
        let mut handlers = Vec::new();
        for i in 0..2 {
            let (mut c, s) = local::pair(LinkModel::FAST_LAN, false);
            let d = Arc::clone(&dist);
            handlers.push(std::thread::spawn(move || {
                let _ = d.handle_conn(Box::new(s));
            }));
            c.send(&Message::Hello { client: format!("w{i}"), profile: "t".into() }).unwrap();
            assert_eq!(c.recv().unwrap(), Message::Ack);
            clients.push(c);
        }
        let mut tickets = Vec::new();
        for c in clients.iter_mut() {
            c.send(&Message::TicketRequest).unwrap();
            match c.recv().unwrap() {
                Message::Ticket { ticket, .. } => tickets.push(ticket),
                m => panic!("expected ticket, got {m:?}"),
            }
        }
        assert_eq!(tickets[0], tickets[1], "both clients race the same ticket");

        clients[0]
            .send(&Message::TicketResult { ticket: tickets[0], result: Value::num(1.0) })
            .unwrap();
        assert_eq!(clients[0].recv().unwrap(), Message::Ack);
        clients[1]
            .send(&Message::TicketResult { ticket: tickets[1], result: Value::num(2.0) })
            .unwrap();
        assert_eq!(clients[1].recv().unwrap(), Message::Ack, "duplicate still acked");

        assert_eq!(dist.stats.results_accepted.load(Ordering::Relaxed), 1);
        // The slow answer came from a *different* client than the one
        // whose result won: it lands in the cross-client counter, not
        // the same-client retry counter (which would mask vote fraud).
        assert_eq!(dist.stats.results_duplicate.load(Ordering::Relaxed), 0);
        assert_eq!(dist.stats.results_duplicate_cross.load(Ordering::Relaxed), 1);
        let p = fw.store().progress(None);
        assert_eq!(p.done, 1);
        assert_eq!(p.duplicate_results, 1);
        assert_eq!(p.redistributions, 1);
        assert_eq!(fw.store().wait_results(task_id), vec![Value::num(1.0)]);
        for mut c in clients {
            c.send(&Message::Shutdown).unwrap();
        }
        for h in handlers {
            h.join().unwrap();
        }
    }

    /// Error-report accounting: the stat and store error counters move,
    /// the ticket returns to the pending pool exactly once, and the
    /// re-issued ticket carries the incremented distribution count.
    #[test]
    fn error_requeue_accounting() {
        let (fw, _) = framework_with_tickets(1);
        let dist = Distributor::new(&fw);
        let (mut client, server) = local::pair(LinkModel::FAST_LAN, false);
        let d = Arc::clone(&dist);
        let h = std::thread::spawn(move || d.handle_conn(Box::new(server)).unwrap());
        client.send(&Message::Hello { client: "w0".into(), profile: "t".into() }).unwrap();
        client.recv().unwrap();
        client.send(&Message::TicketRequest).unwrap();
        let ticket = match client.recv().unwrap() {
            Message::Ticket { ticket, .. } => ticket,
            m => panic!("{m:?}"),
        };
        let before = fw.store().progress(None);
        assert_eq!((before.pending, before.in_flight), (0, 1));

        client
            .send(&Message::ErrorReport { ticket, message: "boom".into(), stack: "s".into() })
            .unwrap();
        assert_eq!(client.recv().unwrap(), Message::Reload);
        assert_eq!(dist.stats.errors_reported.load(Ordering::Relaxed), 1);
        let after = fw.store().progress(None);
        assert_eq!((after.pending, after.in_flight, after.errors), (1, 0, 1));
        assert_eq!(dist.clients()[0].errors, 1);

        // The requeued ticket is served again with its history intact.
        client.send(&Message::TicketRequest).unwrap();
        match client.recv().unwrap() {
            Message::Ticket { ticket: t2, .. } => assert_eq!(t2, ticket),
            m => panic!("{m:?}"),
        }
        let p = fw.store().progress(None);
        assert_eq!((p.pending, p.in_flight), (0, 1));
        assert_eq!(p.redistributions, 1, "re-serving an errored ticket is a redistribution");
        client.send(&Message::Shutdown).unwrap();
        h.join().unwrap();
    }

    /// Virtual time pinned at t = 0: the default redistribution windows
    /// can never elapse, so only the active release path (or an error
    /// requeue) can bring a dispatched ticket back.  Replaces the old
    /// frozen-600-s window constants (DESIGN.md §2.5).
    fn frozen_framework(n: usize) -> Arc<Framework> {
        let fw = Framework::builder()
            .clock(Arc::new(crate::util::clock::VirtualClock::new()))
            .build();
        let task = fw.create_task(Arc::new(IsPrimeTask));
        task.calculate(
            (0..n).map(|i| Value::obj(vec![("candidate", Value::num(i as f64 + 2.0))])).collect(),
        );
        fw
    }

    /// The acceptance case: a connection that vanishes holding a
    /// prefetched batch has every undone ticket released immediately —
    /// re-dispatchable within the release round trip, not after
    /// `min_redistribute_ms`.
    #[test]
    fn dropped_connection_releases_prefetched_batch() {
        let fw = frozen_framework(6);
        let dist = Distributor::new(&fw);
        let (mut victim, server) = local::pair(LinkModel::FAST_LAN, false);
        let d = Arc::clone(&dist);
        let h = std::thread::spawn(move || {
            let _ = d.handle_conn(Box::new(server));
        });
        victim.send(&Message::Hello { client: "victim".into(), profile: "t".into() }).unwrap();
        victim.recv().unwrap();
        victim.send(&Message::TicketBatchRequest { max: 4 }).unwrap();
        match victim.recv().unwrap() {
            Message::Tickets { tickets } => assert_eq!(tickets.len(), 4),
            m => panic!("{m:?}"),
        }
        assert_eq!(fw.store().progress(None).in_flight, 4);
        drop(victim); // the killed browser: no result, no report, no shutdown
        h.join().unwrap();
        assert_eq!(dist.stats.tickets_released.load(Ordering::Relaxed), 4);
        let p = fw.store().progress(None);
        assert_eq!((p.pending, p.in_flight), (6, 0));
        // A healthy client gets the whole pool at once.
        let (mut healthy, server) = local::pair(LinkModel::FAST_LAN, false);
        let d = Arc::clone(&dist);
        let h = std::thread::spawn(move || {
            let _ = d.handle_conn(Box::new(server));
        });
        healthy.send(&Message::Hello { client: "healthy".into(), profile: "t".into() }).unwrap();
        healthy.recv().unwrap();
        healthy.send(&Message::TicketBatchRequest { max: 8 }).unwrap();
        match healthy.recv().unwrap() {
            Message::Tickets { tickets } => assert_eq!(tickets.len(), 6),
            m => panic!("{m:?}"),
        }
        drop(healthy);
        h.join().unwrap();
    }

    /// `release_on_disconnect: false` is the paper's passive baseline:
    /// a vanished connection's tickets stay stranded in flight until
    /// the redistribution windows elapse.
    #[test]
    fn disconnect_release_can_be_disabled() {
        let fw = frozen_framework(2);
        let dist = Distributor::new_with(
            &fw,
            DistributorConfig { release_on_disconnect: false, ..Default::default() },
        );
        let (mut victim, server) = local::pair(LinkModel::FAST_LAN, false);
        let d = Arc::clone(&dist);
        let h = std::thread::spawn(move || {
            let _ = d.handle_conn(Box::new(server));
        });
        victim.send(&Message::Hello { client: "victim".into(), profile: "t".into() }).unwrap();
        victim.recv().unwrap();
        victim.send(&Message::TicketBatchRequest { max: 2 }).unwrap();
        match victim.recv().unwrap() {
            Message::Tickets { tickets } => assert_eq!(tickets.len(), 2),
            m => panic!("{m:?}"),
        }
        drop(victim);
        h.join().unwrap();
        assert_eq!(dist.stats.tickets_released.load(Ordering::Relaxed), 0);
        let p = fw.store().progress(None);
        assert_eq!((p.pending, p.in_flight), (0, 2), "passive baseline strands the batch");
        // Nothing is served until the (frozen) windows elapse.
        let (mut probe, server) = local::pair(LinkModel::FAST_LAN, false);
        let d = Arc::clone(&dist);
        let h = std::thread::spawn(move || {
            let _ = d.handle_conn(Box::new(server));
        });
        probe.send(&Message::Hello { client: "probe".into(), profile: "t".into() }).unwrap();
        probe.recv().unwrap();
        probe.send(&Message::TicketRequest).unwrap();
        assert!(matches!(probe.recv().unwrap(), Message::NoTicket { .. }));
        probe.send(&Message::Shutdown).unwrap();
        h.join().unwrap();
    }

    /// Batched error reporting: a whole batch's failures in one
    /// message, answered by a single Reload, every ticket requeued at
    /// its creation-time VCT immediately.
    #[test]
    fn error_reports_batch_requeues_and_reloads_once() {
        let fw = frozen_framework(3);
        let dist = Distributor::new(&fw);
        let (mut client, server) = local::pair(LinkModel::FAST_LAN, false);
        let d = Arc::clone(&dist);
        let h = std::thread::spawn(move || {
            let _ = d.handle_conn(Box::new(server));
        });
        client.send(&Message::Hello { client: "w0".into(), profile: "t".into() }).unwrap();
        client.recv().unwrap();
        client.send(&Message::TicketBatchRequest { max: 3 }).unwrap();
        let tickets = match client.recv().unwrap() {
            Message::Tickets { tickets } => tickets,
            m => panic!("{m:?}"),
        };
        client
            .send(&Message::ErrorReports {
                reports: tickets[..2]
                    .iter()
                    .map(|t| WireError {
                        ticket: t.ticket,
                        message: "boom".into(),
                        stack: "stack".into(),
                    })
                    .collect(),
            })
            .unwrap();
        assert_eq!(client.recv().unwrap(), Message::Reload, "one Reload for the whole batch");
        assert_eq!(dist.stats.errors_reported.load(Ordering::Relaxed), 2);
        assert_eq!(fw.store().error_count(), 2);
        let p = fw.store().progress(None);
        assert_eq!((p.pending, p.in_flight, p.errors), (2, 1, 2));
        assert_eq!(dist.clients()[0].errors, 2);
        // The requeued tickets are immediately re-dispatchable despite
        // the frozen windows.
        client.send(&Message::TicketBatchRequest { max: 2 }).unwrap();
        match client.recv().unwrap() {
            Message::Tickets { tickets: again } => {
                assert_eq!(again.len(), 2);
                assert_eq!(again[0].ticket, tickets[0].ticket);
            }
            m => panic!("{m:?}"),
        }
        client.send(&Message::Shutdown).unwrap();
        h.join().unwrap();
        // The handler exit released what the client still held.
        assert_eq!(fw.store().progress(None).in_flight, 0);
        assert_eq!(dist.client_count(), 0, "no ghost workers after disconnect");
        assert_eq!(dist.stats.clients_disconnected.load(Ordering::Relaxed), 1);
        assert!(dist.clients()[0].disconnected, "entry kept for end-of-run summaries");
    }

    /// `ReleaseTickets` re-arms the undone remainder of a batch in one
    /// Ack'd round trip.
    #[test]
    fn release_tickets_message_rearms_pool() {
        let fw = frozen_framework(4);
        let dist = Distributor::new(&fw);
        let (mut client, server) = local::pair(LinkModel::FAST_LAN, false);
        let d = Arc::clone(&dist);
        let h = std::thread::spawn(move || {
            let _ = d.handle_conn(Box::new(server));
        });
        client.send(&Message::Hello { client: "w0".into(), profile: "t".into() }).unwrap();
        client.recv().unwrap();
        client.send(&Message::TicketBatchRequest { max: 4 }).unwrap();
        let tickets = match client.recv().unwrap() {
            Message::Tickets { tickets } => tickets,
            m => panic!("{m:?}"),
        };
        client
            .send(&Message::TicketResults {
                results: vec![(tickets[0].ticket, Value::Bool(true))],
            })
            .unwrap();
        assert_eq!(client.recv().unwrap(), Message::Ack);
        client
            .send(&Message::ReleaseTickets {
                tickets: tickets[1..].iter().map(|t| t.ticket).collect(),
            })
            .unwrap();
        assert_eq!(client.recv().unwrap(), Message::Ack);
        assert_eq!(dist.stats.tickets_released.load(Ordering::Relaxed), 3);
        let p = fw.store().progress(None);
        assert_eq!((p.pending, p.in_flight, p.done), (3, 0, 1));
        // Released tickets come back immediately, oldest first.
        client.send(&Message::TicketBatchRequest { max: 8 }).unwrap();
        match client.recv().unwrap() {
            Message::Tickets { tickets: again } => {
                assert_eq!(again.len(), 3);
                assert_eq!(again[0].ticket, tickets[1].ticket);
            }
            m => panic!("{m:?}"),
        }
        client.send(&Message::Shutdown).unwrap();
        h.join().unwrap();
    }

    /// The §2.1.2 redistribution window under virtual time: a stranded
    /// ticket (passive baseline, vanished holder) is re-dispatched
    /// exactly at `VCT + requeue_after_ms` — one virtual millisecond
    /// earlier it is still invisible.  Untestable before clock
    /// injection: wall-time tests could only freeze the window open or
    /// shut, never cross it deterministically.
    #[test]
    fn window_expiry_redispatches_exactly_at_vct_plus_window() {
        let vc = Arc::new(crate::util::clock::VirtualClock::new());
        let fw = Framework::builder().clock(vc.clone()).build();
        let task = fw.create_task(Arc::new(IsPrimeTask));
        task.calculate(vec![Value::obj(vec![("candidate", Value::num(5.0))])]);
        let dist = Distributor::new_with(
            &fw,
            DistributorConfig { release_on_disconnect: false, ..Default::default() },
        );

        let mut victim = dist.open_session();
        victim.handle(Message::Hello { client: "w0".into(), profile: "t".into() }).unwrap();
        let ticket = match victim.handle(Message::TicketRequest).unwrap().unwrap() {
            Message::Ticket { ticket, .. } => ticket,
            m => panic!("{m:?}"),
        };
        victim.close(); // vanishes mid-batch; passive mode strands the ticket

        let window = crate::store::StoreConfig::default().requeue_after_ms;
        let mut probe = dist.open_session();
        probe.handle(Message::Hello { client: "w1".into(), profile: "t".into() }).unwrap();
        vc.advance_to(window - 1);
        assert!(
            matches!(
                probe.handle(Message::TicketRequest).unwrap().unwrap(),
                Message::NoTicket { .. }
            ),
            "one virtual ms before the window elapses the ticket is still stranded"
        );
        vc.advance_to(window);
        match probe.handle(Message::TicketRequest).unwrap().unwrap() {
            Message::Ticket { ticket: again, .. } => {
                assert_eq!(again, ticket, "re-dispatched exactly at VCT + window");
            }
            m => panic!("{m:?}"),
        }
        assert_eq!(fw.store().progress(None).redistributions, 1);
        probe.close();
    }

    /// Quorum verification end to end at R = 3 / Q = 2 through the
    /// wire-protocol surface: an agreeing pair decides one ticket, a
    /// divergent ticket escalates to a tie-breaker, the lying minority
    /// is outvoted, flagged, and quarantined, and the quarantined
    /// client is then refused dispatch until probation expires.
    #[test]
    fn quorum_outvotes_flags_and_quarantines_liar() {
        let vc = Arc::new(crate::util::clock::VirtualClock::new());
        let fw = Framework::builder()
            .clock(vc.clone())
            .store_config(crate::store::StoreConfig {
                replication: 3,
                quorum: 2,
                ..crate::store::StoreConfig::default()
            })
            .build();
        let task = fw.create_task(Arc::new(IsPrimeTask));
        task.calculate(vec![
            Value::obj(vec![("candidate", Value::num(7.0))]),
            Value::obj(vec![("candidate", Value::num(9.0))]),
        ]);
        let task_id = task.id;
        let dist = Distributor::new(&fw);

        let mut sessions: Vec<Session> = (0..3)
            .map(|i| {
                let mut s = dist.open_session();
                s.handle(Message::Hello { client: format!("w{i}"), profile: "t".into() }).unwrap();
                s
            })
            .collect();
        let take = |sessions: &mut Vec<Session>, i: usize| -> TicketId {
            match sessions[i].handle(Message::TicketRequest).unwrap().unwrap() {
                Message::Ticket { ticket, .. } => ticket,
                m => panic!("{m:?}"),
            }
        };
        // Initial recruitment targets quorum (2) distinct clients per
        // ticket: w0 and w1 share the first ticket, w2 gets the second.
        let t1a = take(&mut sessions, 0);
        let t1b = take(&mut sessions, 1);
        assert_eq!(t1a, t1b, "one ticket recruits two distinct clients");
        let t2 = take(&mut sessions, 2);
        assert_ne!(t2, t1a);

        // w2 lies about its ticket; the vote parks short of quorum.
        sessions[2].handle(Message::TicketResult { ticket: t2, result: Value::Bool(true) }).unwrap();
        assert_eq!(dist.stats.results_pending_quorum.load(Ordering::Relaxed), 1);
        // The honest pair agrees on the first ticket: quorum decides.
        sessions[0]
            .handle(Message::TicketResult { ticket: t1a, result: Value::Bool(true) })
            .unwrap();
        sessions[1]
            .handle(Message::TicketResult { ticket: t1b, result: Value::Bool(true) })
            .unwrap();
        assert_eq!(dist.stats.results_accepted.load(Ordering::Relaxed), 1);

        // w0 takes the liar's ticket and answers honestly: one wrong
        // ballot vs one right ballot escalates to a tie-breaker...
        let t2b = take(&mut sessions, 0);
        assert_eq!(t2b, t2);
        sessions[0]
            .handle(Message::TicketResult { ticket: t2b, result: Value::Bool(false) })
            .unwrap();
        // ...and w1 breaks the tie: the liar is outvoted, flagged, and
        // (a fresh reputation) quarantined on the spot.
        let t2c = take(&mut sessions, 1);
        assert_eq!(t2c, t2);
        sessions[1]
            .handle(Message::TicketResult { ticket: t2c, result: Value::Bool(false) })
            .unwrap();
        assert_eq!(dist.stats.results_accepted.load(Ordering::Relaxed), 2);
        assert_eq!(dist.stats.results_pending_quorum.load(Ordering::Relaxed), 3);

        let vs = fw.store().verify_stats();
        assert_eq!((vs.verdicts, vs.votes_flagged), (2, 1));
        assert_eq!((vs.escalations, vs.quarantines), (1, 1));
        assert_eq!(fw.store().quarantined_clients(), vec!["w2".to_string()]);
        assert_eq!(
            fw.store().wait_results(task_id),
            vec![Value::Bool(true), Value::Bool(false)],
            "the liar's ballot never became a result"
        );

        // The quarantined client is refused dispatch.
        match sessions[2].handle(Message::TicketRequest).unwrap().unwrap() {
            Message::NoTicket { .. } => {}
            m => panic!("{m:?}"),
        }
        assert_eq!(dist.stats.noticket_quarantined.load(Ordering::Relaxed), 1);

        // Probation is a timer, not a death sentence: once it expires
        // the gate no longer refuses (the pool is simply empty now).
        vc.advance_to(crate::store::ticket::PROBATION_MS);
        match sessions[2].handle(Message::TicketRequest).unwrap().unwrap() {
            Message::NoTicket { .. } => {}
            m => panic!("{m:?}"),
        }
        assert_eq!(
            dist.stats.noticket_quarantined.load(Ordering::Relaxed),
            1,
            "post-probation NoTicket is an empty pool, not a quarantine refusal"
        );
        for mut s in sessions {
            s.close();
        }
    }
}
