//! CalculationFramework: the project/task programming model.
//!
//! The appendix's PrimeListMakerProject maps 1:1 onto this API:
//!
//! ```no_run
//! # use std::sync::Arc;
//! # use sashimi::coordinator::Framework;
//! # use sashimi::tasks::is_prime::IsPrimeTask;
//! # use sashimi::util::json::Value;
//! let fw = Framework::builder().build();
//! let task = fw.create_task(Arc::new(IsPrimeTask));          // createTask
//! let inputs = (1..=10_000)
//!     .map(|i| Value::obj(vec![("candidate", Value::num(i as f64))]))
//!     .collect();
//! task.calculate(inputs);                                     // divide + enqueue
//! let results = task.block();                                 // collect, in order
//! # let _ = results;
//! ```
//!
//! `calculate` divides the argument list into tickets in the store;
//! workers (browsers) pull and execute them through the distributor;
//! `block` waits and returns results "as if they were processed by the
//! local machine".

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::store::{Progress, Scheduler, StoreConfig, TaskId, TicketStore};
use crate::tasks::{DatasetStore, Registry, TaskDef};
use crate::util::clock::{Clock, WallClock};
use crate::util::json::Value;
use crate::util::lockcheck::{CheckedMutex, Rank};

pub struct FrameworkBuilder {
    store_cfg: StoreConfig,
    registry: Registry,
    scheduler: Option<Arc<dyn Scheduler>>,
    clock: Arc<dyn Clock>,
}

impl FrameworkBuilder {
    pub fn store_config(mut self, cfg: StoreConfig) -> Self {
        self.store_cfg = cfg;
        self
    }

    /// Inject a scheduling core (e.g. [`crate::store::NaiveStore`] for
    /// differential runs); overrides [`Self::store_config`], since the
    /// provided scheduler carries its own [`StoreConfig`].
    pub fn scheduler(mut self, scheduler: Arc<dyn Scheduler>) -> Self {
        self.scheduler = Some(scheduler);
        self
    }

    /// Inject a time source (DESIGN.md §2.5).  Every VCT timestamp the
    /// framework mints and every redistribution-window decision made by
    /// a [`Distributor`](crate::coordinator::Distributor) built from
    /// this framework reads it.  Defaults to the wall clock; tests and
    /// the churn simulator inject a
    /// [`VirtualClock`](crate::util::clock::VirtualClock) instead of
    /// freezing windows with unreachable constants.
    pub fn clock(mut self, clock: Arc<dyn Clock>) -> Self {
        self.clock = clock;
        self
    }

    pub fn register(mut self, def: Arc<dyn TaskDef>) -> Self {
        self.registry.register(def);
        self
    }

    pub fn build(self) -> Arc<Framework> {
        let store: Arc<dyn Scheduler> = match self.scheduler {
            Some(s) => s,
            None => Arc::new(TicketStore::new(self.store_cfg)),
        };
        // A recovered durable store may already hold tasks: fresh ids
        // start above them so a new project never merges into a
        // recovered ledger (use [`Framework::attach_task`] for those).
        let next_task = store.max_task_id().map(|t| t.0 + 1).unwrap_or(1);
        Arc::new(Framework {
            store,
            registry: Arc::new(CheckedMutex::new(Rank::framework_registry(), self.registry)),
            datasets: Arc::new(DatasetStore::new()),
            next_task: AtomicU64::new(next_task),
            clock: self.clock,
        })
    }
}

/// The running framework: ticket store + task registry + dataset store.
pub struct Framework {
    store: Arc<dyn Scheduler>,
    registry: Arc<CheckedMutex<Registry>>,
    datasets: Arc<DatasetStore>,
    next_task: AtomicU64,
    clock: Arc<dyn Clock>,
}

impl Framework {
    pub fn builder() -> FrameworkBuilder {
        FrameworkBuilder {
            store_cfg: StoreConfig::default(),
            registry: Registry::new(),
            scheduler: None,
            clock: Arc::new(WallClock),
        }
    }

    /// `this.createTask(SomeTask)`: register (idempotent) and get a handle.
    pub fn create_task(self: &Arc<Self>, def: Arc<dyn TaskDef>) -> TaskHandle {
        let name = def.name().to_string();
        self.registry.lock().unwrap().register(def);
        TaskHandle {
            id: TaskId(self.next_task.fetch_add(1, Ordering::SeqCst)),
            name,
            fw: Arc::clone(self),
        }
    }

    /// Re-attach to a task that already exists in the (recovered) store:
    /// registers the definition (idempotent) and returns a handle for
    /// `id` without allocating a fresh task id.  The durable-store
    /// restart path (`store::wal`): recover, attach, `block()` for the
    /// surviving results.
    pub fn attach_task(self: &Arc<Self>, id: TaskId, def: Arc<dyn TaskDef>) -> TaskHandle {
        let name = def.name().to_string();
        self.registry.lock().unwrap().register(def);
        TaskHandle { id, name, fw: Arc::clone(self) }
    }

    pub fn store(&self) -> &Arc<dyn Scheduler> {
        &self.store
    }

    pub fn datasets(&self) -> &Arc<DatasetStore> {
        &self.datasets
    }

    /// The injected time source ([`FrameworkBuilder::clock`]).
    pub fn clock(&self) -> &Arc<dyn Clock> {
        &self.clock
    }

    /// Snapshot of the registry (workers resolve task code through this).
    pub fn registry_snapshot(&self) -> Registry {
        self.registry.lock().unwrap().clone()
    }

    pub fn progress(&self) -> Progress {
        self.store.progress(None)
    }
}

/// Handle to one created task (the project's `task` object).
pub struct TaskHandle {
    pub id: TaskId,
    pub name: String,
    fw: Arc<Framework>,
}

impl TaskHandle {
    /// `task.calculate(inputs)`: divide the arguments into tickets.
    /// Creation timestamps (the VCT anchors) come from the framework's
    /// injected clock.
    pub fn calculate(&self, inputs: Vec<Value>) {
        self.fw.store.create_tickets(self.id, &self.name, inputs, self.fw.clock.now_ms());
    }

    /// `task.block(cb)`: wait for every ticket, results in input order.
    pub fn block(&self) -> Vec<Value> {
        self.fw.store.wait_results(self.id)
    }

    pub fn block_timeout(&self, timeout_ms: u64) -> Option<Vec<Value>> {
        self.fw.store.wait_results_timeout(self.id, timeout_ms)
    }

    /// Streaming consumption (hybrid trainer): next accepted result.
    pub fn next_completion(&self, timeout_ms: u64) -> Option<(usize, Value)> {
        self.fw.store.next_completion(self.id, timeout_ms)
    }

    pub fn progress(&self) -> Progress {
        self.fw.store.progress(Some(self.id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tasks::is_prime::IsPrimeTask;
    use crate::util::clock;
    use crate::util::json::Value;

    #[test]
    fn calculate_creates_tickets_and_block_waits() {
        let fw = Framework::builder().build();
        let task = fw.create_task(Arc::new(IsPrimeTask));
        task.calculate((0..5).map(|i| Value::num(i as f64)).collect());
        assert_eq!(task.progress().total, 5);
        assert_eq!(task.progress().pending, 5);

        // Simulate a worker completing tickets directly via the store.
        let store = fw.store().clone();
        let tid = task.id;
        let h = std::thread::spawn(move || {
            for _ in 0..5 {
                let t = store.next_ticket("w", clock::now_ms()).unwrap();
                store.complete(t.id, Value::num(t.index as f64 * 2.0)).unwrap();
            }
            let _ = tid;
        });
        let results = task.block();
        h.join().unwrap();
        assert_eq!(results.len(), 5);
        assert_eq!(results[3], Value::num(6.0));
    }

    #[test]
    fn task_ids_are_unique() {
        let fw = Framework::builder().build();
        let a = fw.create_task(Arc::new(IsPrimeTask));
        let b = fw.create_task(Arc::new(IsPrimeTask));
        assert_ne!(a.id, b.id);
    }

    #[test]
    fn block_timeout_on_unfinished_task() {
        let fw = Framework::builder().build();
        let task = fw.create_task(Arc::new(IsPrimeTask));
        task.calculate(vec![Value::num(3.0)]);
        assert!(task.block_timeout(20).is_none());
    }

    /// A store recovered with existing tasks: fresh ids allocate above
    /// them, and `attach_task` picks up the surviving ledger.
    #[test]
    fn recovered_tasks_do_not_collide_with_fresh_ones() {
        let store = Arc::new(crate::store::IndexedStore::new(StoreConfig::default()));
        store.create_tickets(TaskId(5), "is_prime", vec![Value::num(3.0)], 0);
        let t = store.next_ticket("w", 0).unwrap();
        store.complete(t.id, Value::num(1.0)).unwrap();
        let fw = Framework::builder().scheduler(store).build();
        let fresh = fw.create_task(Arc::new(IsPrimeTask));
        assert_eq!(fresh.id, TaskId(6), "fresh ids start above recovered tasks");
        let old = fw.attach_task(TaskId(5), Arc::new(IsPrimeTask));
        assert_eq!(old.id, TaskId(5));
        assert_eq!(old.block(), vec![Value::num(1.0)]);
    }

    /// An injected [`VirtualClock`](crate::util::clock::VirtualClock)
    /// stamps ticket creation times (the VCT anchors), so tests pin
    /// redistribution behaviour without freezing windows at unreachable
    /// constants.
    #[test]
    fn injected_virtual_clock_stamps_vct() {
        let vc = Arc::new(crate::util::clock::VirtualClock::at(1234));
        let fw = Framework::builder().clock(vc.clone()).build();
        let task = fw.create_task(Arc::new(IsPrimeTask));
        task.calculate(vec![Value::obj(vec![("candidate", Value::num(3.0))])]);
        assert_eq!(fw.clock().now_ms(), 1234);
        let t = fw.store().next_ticket("w", vc.now_ms()).unwrap();
        assert_eq!(t.created_ms, 1234, "VCT anchored to the injected clock");
    }

    /// The builder accepts any `Scheduler`; the naive reference behind
    /// the whole framework behaves like the default indexed store.
    #[test]
    fn injected_naive_scheduler_is_equivalent() {
        let fw = Framework::builder()
            .scheduler(Arc::new(crate::store::NaiveStore::new(StoreConfig::default())))
            .build();
        let task = fw.create_task(Arc::new(IsPrimeTask));
        task.calculate((0..3).map(|i| Value::num(i as f64)).collect());
        let store = Arc::clone(fw.store());
        let h = std::thread::spawn(move || {
            for _ in 0..3 {
                let t = store.next_ticket("w", clock::now_ms()).unwrap();
                store.complete(t.id, Value::num(t.index as f64 * 2.0)).unwrap();
            }
        });
        let results = task.block();
        h.join().unwrap();
        assert_eq!(results, vec![Value::num(0.0), Value::num(2.0), Value::num(4.0)]);
    }
}
