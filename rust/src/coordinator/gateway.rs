//! Async connection gateway: one epoll reactor thread for the whole
//! fleet.
//!
//! The thread-per-connection accept loop ([`Distributor::serve`]) costs
//! a stack per worker — fine for benches, fatal for the paper's "any
//! computer that opens a website" fleet.  The gateway multiplexes every
//! connection onto one reactor thread with level-triggered epoll
//! (hand-rolled over direct glibc FFI: the crate takes no async
//! runtime dependency), so idle connections cost one registered fd and
//! a few hundred bytes of buffer, and 100k of them are a HashMap, not
//! 100k stacks.
//!
//! Two listeners, one protocol:
//! * a **TCP** port speaking the legacy JSON-lines wire
//!   ([`LineFraming`]) — existing workers connect unchanged;
//! * a **WebSocket** port ([`WsFraming`]) — the same JSON documents in
//!   RFC 6455 text frames, so a browser (or `websocat`) is a complete
//!   client.
//!
//! Each connection owns a [`Session`] — the transport-free protocol
//! state machine — so wire semantics are byte-identical to the blocking
//! path and the in-process simulator (pinned by
//! `tests/transport_conformance.rs`).
//!
//! **Heartbeats / dead-peer detection.**  PR 5's release-on-disconnect
//! is only as fast as disconnect detection, and a silently-dead peer
//! (yanked cable, suspended laptop, NAT timeout) produces no FIN —
//! plain TCP would strand its tickets until the OS keepalive fires,
//! hours later.  The gateway bounds that to seconds: any inbound byte
//! refreshes a connection's liveness; after `heartbeat_ms` of silence a
//! WebSocket connection is pinged (browsers pong at transport level,
//! below the JS app); after `2 × heartbeat_ms` of silence any
//! connection is killed, dropping its session and releasing its held
//! tickets.  Plain TCP JSON connections get the silence-kill only — an
//! unsolicited line would desync the strict request/response protocol —
//! which is safe because legacy workers poll for tickets far more often
//! than any sane heartbeat window.  Heartbeats run on the wall clock
//! (`util::clock::now_ms`), independent of the store's possibly-virtual
//! clock: liveness of a socket is a real-time property.

use std::collections::HashMap;
use std::fs::File;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::{AsRawFd, FromRawFd, RawFd};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{bail, Context, Result};

use super::distributor::{Distributor, Session};
use crate::transport::framing::{Framing, Inbound, LineFraming};
use crate::transport::ws::{self, WsFraming};
use crate::transport::Message;
use crate::util::clock::now_ms;
use crate::util::lockcheck::{CheckedMutex, Rank};

/// Inbound buffer cap per connection (a dataset message is the largest
/// legitimate document; anything past this is a protocol violation).
const MAX_BUFFER: usize = 64 << 20;
/// Handshake header cap.
const MAX_HANDSHAKE: usize = 64 << 10;

// ---------------------------------------------------------------------
// Minimal glibc FFI: epoll + eventfd + rlimit.  Deliberately tiny — the
// five syscalls a reactor needs, nothing more.

mod sys {
    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;

    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLL_CTL_MOD: i32 = 3;
    pub const EPOLL_CLOEXEC: i32 = 0o2000000;

    pub const EFD_CLOEXEC: i32 = 0o2000000;
    pub const EFD_NONBLOCK: i32 = 0o4000;

    pub const RLIMIT_NOFILE: i32 = 7;

    /// `struct epoll_event` — packed on x86_64 (the kernel ABI),
    /// naturally aligned elsewhere.  Read its fields by value only.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    #[repr(C)]
    pub struct Rlimit {
        pub cur: u64,
        pub max: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: i32) -> i32;
        pub fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        pub fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        pub fn eventfd(initval: u32, flags: i32) -> i32;
        pub fn close(fd: i32) -> i32;
        pub fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
        pub fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
    }
}

/// RAII epoll instance.
struct Epoll {
    fd: i32,
}

impl Epoll {
    fn new() -> Result<Epoll> {
        // SAFETY: epoll_create1 takes no pointers; any flag value is
        // accepted by the kernel and errors surface as fd < 0.
        let fd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if fd < 0 {
            bail!("epoll_create1 failed: {}", std::io::Error::last_os_error());
        }
        Ok(Epoll { fd })
    }

    fn ctl(&self, op: i32, fd: RawFd, tok: u64, events: u32) -> Result<()> {
        let mut ev = sys::EpollEvent { events, data: tok };
        // SAFETY: `ev` is a live, properly aligned EpollEvent for the
        // duration of the call; the kernel only reads it during
        // epoll_ctl and keeps no reference afterwards.
        let rc = unsafe { sys::epoll_ctl(self.fd, op, fd, &mut ev) };
        if rc != 0 {
            bail!("epoll_ctl(op={op}) failed: {}", std::io::Error::last_os_error());
        }
        Ok(())
    }

    fn add(&self, fd: RawFd, tok: u64, events: u32) -> Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, tok, events)
    }

    fn modify(&self, fd: RawFd, tok: u64, events: u32) -> Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, tok, events)
    }

    fn del(&self, fd: RawFd) {
        let mut ev = sys::EpollEvent { events: 0, data: 0 };
        // SAFETY: `ev` is live and aligned for the call (pre-2.6.9
        // kernels require a non-null event even for DEL); failure is
        // benign here — the fd is being torn down anyway.
        unsafe { sys::epoll_ctl(self.fd, sys::EPOLL_CTL_DEL, fd, &mut ev) };
    }

    /// Wait for events; EINTR counts as zero events.
    fn wait(&self, events: &mut [sys::EpollEvent], timeout_ms: i32) -> usize {
        // SAFETY: `events` is a live mutable slice and the length
        // passed as maxevents is exactly its capacity, so the kernel
        // writes only within bounds; EpollEvent is plain-old-data, so
        // partially filled tails stay valid.
        let rc = unsafe {
            sys::epoll_wait(self.fd, events.as_mut_ptr(), events.len() as i32, timeout_ms)
        };
        if rc < 0 {
            0 // EINTR (or a dying fd at teardown): treat as a timeout tick
        } else {
            rc as usize
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        // SAFETY: `self.fd` is a valid epoll fd owned exclusively by
        // this struct (created in `new`, never duplicated or exposed),
        // and Drop runs once — no double-close, no use-after-close.
        unsafe { sys::close(self.fd) };
    }
}

/// Raise `RLIMIT_NOFILE` toward `want` (clamped to the hard limit);
/// returns the resulting soft limit.  The connection-scale tests call
/// this and skip when the environment cannot grant enough fds.
pub fn raise_nofile_limit(want: u64) -> Result<u64> {
    let mut rl = sys::Rlimit { cur: 0, max: 0 };
    // SAFETY: `rl` is a live, aligned Rlimit the kernel fills in; it
    // holds no pointers, so any written value is valid.
    if unsafe { sys::getrlimit(sys::RLIMIT_NOFILE, &mut rl) } != 0 {
        bail!("getrlimit failed: {}", std::io::Error::last_os_error());
    }
    if rl.cur >= want {
        return Ok(rl.cur);
    }
    let target = want.min(rl.max);
    let newrl = sys::Rlimit { cur: target, max: rl.max };
    // SAFETY: `newrl` is a live, aligned Rlimit read (not retained) by
    // the kernel for the duration of the call.
    if unsafe { sys::setrlimit(sys::RLIMIT_NOFILE, &newrl) } != 0 {
        bail!("setrlimit to {target} failed: {}", std::io::Error::last_os_error());
    }
    Ok(target)
}

/// `Threads:` from `/proc/self/status` — the scale tests assert the
/// gateway holds thousands of connections without a thread explosion.
pub fn process_thread_count() -> Option<u64> {
    proc_status_field("Threads:")
}

/// `VmRSS:` in kilobytes from `/proc/self/status`.
pub fn process_rss_kb() -> Option<u64> {
    proc_status_field("VmRSS:")
}

fn proc_status_field(name: &str) -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix(name) {
            return rest.split_whitespace().next()?.parse().ok();
        }
    }
    None
}

// ---------------------------------------------------------------------
// Gateway.

#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// Silence threshold in wall-clock ms: ping (WS) after this much,
    /// kill any connection after twice this much.  `0` disables
    /// heartbeats entirely (idle connections live forever — the
    /// connection-scale smoke uses this).
    pub heartbeat_ms: u64,
}

impl Default for GatewayConfig {
    fn default() -> GatewayConfig {
        GatewayConfig { heartbeat_ms: 10_000 }
    }
}

#[derive(Debug, Default)]
pub struct GatewayStats {
    /// Connections accepted over the gateway's lifetime.
    pub accepted: AtomicU64,
    /// Connections currently registered.
    pub open: AtomicU64,
    /// High-water mark of `open`.
    pub peak_open: AtomicU64,
    /// Connections killed for heartbeat silence (the dead-peer path).
    pub dead_peer_kills: AtomicU64,
    /// Connections killed for malformed frames / documents / handshakes.
    pub protocol_errors: AtomicU64,
    /// WS transport pings sent.
    pub pings_sent: AtomicU64,
}

/// The async accept front: owns the reactor thread, the listeners, and
/// the wakeup eventfd.  Construct with [`Gateway::bind`]; stop with
/// [`Gateway::shutdown`] (or [`Distributor::stop`] — the reactor honors
/// both).
pub struct Gateway {
    pub stats: GatewayStats,
    cfg: GatewayConfig,
    stop: AtomicBool,
    /// eventfd write handle: one 8-byte write wakes a parked reactor.
    waker: File,
    tcp_addr: Option<SocketAddr>,
    ws_addr: Option<SocketAddr>,
    thread: CheckedMutex<Option<JoinHandle<()>>>,
}

impl Gateway {
    /// Bind the requested listeners (`"host:port"`, port 0 for
    /// ephemeral) and start the reactor.  At least one of `tcp` / `ws`
    /// must be given.
    pub fn bind(
        dist: &Arc<Distributor>,
        cfg: GatewayConfig,
        tcp: Option<&str>,
        ws: Option<&str>,
    ) -> Result<Arc<Gateway>> {
        if tcp.is_none() && ws.is_none() {
            bail!("gateway needs at least one of a tcp or ws address");
        }
        let bind_one = |addr: &str| -> Result<TcpListener> {
            let l = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
            l.set_nonblocking(true).context("set_nonblocking on listener")?;
            Ok(l)
        };
        let tcp_l = tcp.map(bind_one).transpose()?;
        let ws_l = ws.map(bind_one).transpose()?;

        // SAFETY: eventfd takes no pointers; errors surface as efd < 0.
        let efd = unsafe { sys::eventfd(0, sys::EFD_CLOEXEC | sys::EFD_NONBLOCK) };
        if efd < 0 {
            bail!("eventfd failed: {}", std::io::Error::last_os_error());
        }
        // SAFETY: `efd` was just returned by a successful eventfd call,
        // so it is a valid, open fd owned by nobody else; `File` takes
        // sole ownership (the waker below is a dup'd clone, not a second
        // owner of this fd).
        let wake_read = unsafe { File::from_raw_fd(efd) };
        let waker = wake_read.try_clone().context("cloning eventfd")?;

        let gw = Arc::new(Gateway {
            stats: GatewayStats::default(),
            cfg,
            stop: AtomicBool::new(false),
            waker,
            tcp_addr: tcp_l.as_ref().and_then(|l| l.local_addr().ok()),
            ws_addr: ws_l.as_ref().and_then(|l| l.local_addr().ok()),
            thread: CheckedMutex::new(Rank::gateway_thread(), None),
        });
        let reactor = Reactor {
            gw: Arc::clone(&gw),
            dist: Arc::clone(dist),
            epoll: Epoll::new()?,
            wake: wake_read,
            tcp_listener: tcp_l,
            ws_listener: ws_l,
            conns: HashMap::new(),
            next_tok: TOK_FIRST_CONN,
        };
        let handle = std::thread::Builder::new()
            .name("sashimi-gateway".into())
            .spawn(move || reactor.run())
            .context("spawning gateway reactor")?;
        *gw.thread.lock().unwrap() = Some(handle);
        Ok(gw)
    }

    /// The bound TCP (JSON-lines) address, if a TCP listener was asked.
    pub fn tcp_addr(&self) -> Option<String> {
        self.tcp_addr.map(|a| a.to_string())
    }

    /// The bound WebSocket address, if a WS listener was asked.
    pub fn ws_addr(&self) -> Option<String> {
        self.ws_addr.map(|a| a.to_string())
    }

    /// Ask the reactor to exit (non-blocking; it notices immediately
    /// via the eventfd).  Open sessions are closed, releasing whatever
    /// tickets they held.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = (&self.waker).write_all(&1u64.to_ne_bytes());
    }

    /// Stop and join the reactor thread.
    pub fn shutdown(&self) {
        self.stop();
        if let Some(h) = self.thread.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Gateway {
    fn drop(&mut self) {
        self.stop();
        // Joining from drop would deadlock if the reactor's own Arc is
        // the last one; the thread exits on its own after stop().
    }
}

// ---------------------------------------------------------------------
// Reactor internals.

const TOK_WAKE: u64 = 0;
const TOK_TCP: u64 = 1;
const TOK_WS: u64 = 2;
const TOK_FIRST_CONN: u64 = 3;

enum Phase {
    /// WS only: accumulating the HTTP upgrade request.
    Handshake,
    /// Framed protocol traffic.
    Open,
}

/// One registered connection: socket + framing + protocol session +
/// liveness bookkeeping.  Dropping it closes the socket and the
/// session (releasing held tickets — the active failure path).
struct GwConn {
    tok: u64,
    stream: TcpStream,
    is_ws: bool,
    phase: Phase,
    framing: Box<dyn Framing>,
    inbuf: Vec<u8>,
    outbuf: Vec<u8>,
    session: Session,
    /// Wall-clock ms of the last inbound byte (any byte is liveness).
    last_recv_ms: u64,
    /// A ping is outstanding; don't ping again until bytes arrive.
    ping_sent: bool,
    /// EPOLLOUT interest is currently registered.
    want_write: bool,
    /// Orderly close: kill once `outbuf` drains.
    closing: bool,
}

struct Reactor {
    gw: Arc<Gateway>,
    dist: Arc<Distributor>,
    epoll: Epoll,
    wake: File,
    tcp_listener: Option<TcpListener>,
    ws_listener: Option<TcpListener>,
    conns: HashMap<u64, GwConn>,
    next_tok: u64,
}

impl Reactor {
    fn run(mut self) {
        if let Err(e) = self.register_fixed() {
            crate::log_warn!("gateway", "reactor setup failed: {e:#}");
            return;
        }
        let timeout_ms: i32 = if self.gw.cfg.heartbeat_ms == 0 {
            250
        } else {
            (self.gw.cfg.heartbeat_ms / 4).clamp(10, 250) as i32
        };
        let mut events = vec![sys::EpollEvent { events: 0, data: 0 }; 1024];
        loop {
            if self.gw.stop.load(Ordering::SeqCst) || self.dist.stopped() {
                break;
            }
            let n = self.epoll.wait(&mut events, timeout_ms);
            for e in &events[..n] {
                // Packed struct: copy fields out by value.
                let tok = e.data;
                let ev = e.events;
                match tok {
                    TOK_WAKE => {
                        let mut buf = [0u8; 8];
                        let _ = (&self.wake).read(&mut buf);
                    }
                    TOK_TCP => self.accept_all(false),
                    TOK_WS => self.accept_all(true),
                    _ => {
                        if let Some(mut c) = self.conns.remove(&tok) {
                            if self.drive(&mut c, ev) {
                                self.conns.insert(tok, c);
                            } else {
                                self.deregister(&mut c);
                            }
                        }
                    }
                }
            }
            self.sweep(now_ms());
        }
        self.drain_shutdown();
    }

    fn register_fixed(&self) -> Result<()> {
        self.epoll.add(self.wake.as_raw_fd(), TOK_WAKE, sys::EPOLLIN)?;
        if let Some(l) = &self.tcp_listener {
            self.epoll.add(l.as_raw_fd(), TOK_TCP, sys::EPOLLIN)?;
        }
        if let Some(l) = &self.ws_listener {
            self.epoll.add(l.as_raw_fd(), TOK_WS, sys::EPOLLIN)?;
        }
        Ok(())
    }

    fn accept_all(&mut self, is_ws: bool) {
        loop {
            let res = {
                let l = if is_ws { &self.ws_listener } else { &self.tcp_listener };
                let Some(l) = l else { return };
                l.accept()
            };
            match res {
                Ok((stream, _peer)) => self.register(stream, is_ws),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    // Usually fd exhaustion: log and back off until the
                    // next readiness tick rather than spinning.
                    crate::log_warn!("gateway", "accept failed: {e}");
                    return;
                }
            }
        }
    }

    fn register(&mut self, stream: TcpStream, is_ws: bool) {
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        stream.set_nodelay(true).ok();
        let tok = self.next_tok;
        self.next_tok += 1;
        if let Err(e) = self.epoll.add(stream.as_raw_fd(), tok, sys::EPOLLIN | sys::EPOLLRDHUP) {
            crate::log_warn!("gateway", "registering connection failed: {e:#}");
            return;
        }
        let c = GwConn {
            tok,
            stream,
            is_ws,
            phase: if is_ws { Phase::Handshake } else { Phase::Open },
            framing: if is_ws {
                Box::new(WsFraming::server())
            } else {
                Box::new(LineFraming::new())
            },
            inbuf: Vec::new(),
            outbuf: Vec::new(),
            session: self.dist.open_session(),
            last_recv_ms: now_ms(),
            ping_sent: false,
            want_write: false,
            closing: false,
        };
        self.gw.stats.accepted.fetch_add(1, Ordering::Relaxed);
        let open = self.gw.stats.open.fetch_add(1, Ordering::Relaxed) + 1;
        self.gw.stats.peak_open.fetch_max(open, Ordering::Relaxed);
        self.conns.insert(tok, c);
    }

    /// Unregister and account; the caller drops `c`, which closes the
    /// socket and the session (releasing its held tickets).
    fn deregister(&self, c: &mut GwConn) {
        self.epoll.del(c.stream.as_raw_fd());
        self.gw.stats.open.fetch_sub(1, Ordering::Relaxed);
    }

    /// Pump one connection for one readiness event.  Returns `false`
    /// when the connection must die.
    fn drive(&mut self, c: &mut GwConn, ev: u32) -> bool {
        if ev & (sys::EPOLLERR | sys::EPOLLHUP) != 0 {
            return false;
        }
        let mut eof = false;
        if ev & (sys::EPOLLIN | sys::EPOLLRDHUP) != 0 {
            let mut tmp = [0u8; 16384];
            loop {
                match c.stream.read(&mut tmp) {
                    Ok(0) => {
                        eof = true;
                        break;
                    }
                    Ok(n) => {
                        self.dist.stats.bytes_received.fetch_add(n as u64, Ordering::Relaxed);
                        c.inbuf.extend_from_slice(&tmp[..n]);
                        c.last_recv_ms = now_ms();
                        c.ping_sent = false;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        eof = true;
                        break;
                    }
                }
            }
        }
        if let Err(e) = self.process(c) {
            crate::log_debug!(
                "gateway",
                "protocol error from {} ({}): {e:#}",
                c.session.client(),
                if c.is_ws { "ws" } else { "tcp" }
            );
            self.gw.stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
            let close = c.framing.frame_close();
            c.outbuf.extend_from_slice(&close);
            let _ = self.flush(c); // best-effort goodbye
            return false;
        }
        if !self.flush(c) {
            return false;
        }
        if c.closing && c.outbuf.is_empty() {
            return false;
        }
        // EOF after processing: whatever was buffered has been handled;
        // the peer is gone.
        !eof
    }

    /// Consume `c.inbuf`: finish the WS handshake if pending, then
    /// extract and handle protocol documents.  `Err` = protocol
    /// violation, kill the connection.
    fn process(&mut self, c: &mut GwConn) -> Result<()> {
        if matches!(c.phase, Phase::Handshake) {
            let Some(end) = ws::find_header_end(&c.inbuf) else {
                if c.inbuf.len() > MAX_HANDSHAKE {
                    bail!("oversized websocket handshake ({} bytes)", c.inbuf.len());
                }
                return Ok(());
            };
            let head = String::from_utf8_lossy(&c.inbuf[..end]).into_owned();
            let resp = ws::server_handshake_response(&head)?;
            c.outbuf.extend_from_slice(resp.as_bytes());
            c.inbuf.drain(..end);
            c.phase = Phase::Open;
        }
        while let Some(inbound) = c.framing.extract(&mut c.inbuf)? {
            match inbound {
                Inbound::Msg(doc) => {
                    let msg = Message::decode(&doc)?;
                    // Same shutdown semantics as the blocking
                    // conn_loop: a stop that lands while a ticket
                    // request is in flight answers Shutdown instead of
                    // dispatching more work.
                    if self.dist.stopped()
                        && matches!(
                            msg,
                            Message::TicketRequest | Message::TicketBatchRequest { .. }
                        )
                    {
                        let f = c.framing.frame_msg(&Message::Shutdown.encode());
                        c.outbuf.extend_from_slice(&f);
                        continue;
                    }
                    match c.session.handle(msg)? {
                        Some(reply) => {
                            let f = c.framing.frame_msg(&reply.encode());
                            c.outbuf.extend_from_slice(&f);
                        }
                        None => {
                            // Orderly client Shutdown.
                            let f = c.framing.frame_close();
                            c.outbuf.extend_from_slice(&f);
                            c.closing = true;
                            return Ok(());
                        }
                    }
                }
                Inbound::Ping(payload) => {
                    let f = c.framing.frame_pong(&payload);
                    c.outbuf.extend_from_slice(&f);
                }
                Inbound::Pong => {} // the read already refreshed liveness
                Inbound::Close => {
                    let f = c.framing.frame_close();
                    c.outbuf.extend_from_slice(&f);
                    c.closing = true;
                    return Ok(());
                }
            }
        }
        if c.inbuf.len() > MAX_BUFFER {
            bail!("inbound buffer overflow ({} bytes without a complete frame)", c.inbuf.len());
        }
        Ok(())
    }

    /// Write as much of `c.outbuf` as the socket accepts, toggling
    /// EPOLLOUT interest to match.  Returns `false` when the
    /// connection must die.
    fn flush(&self, c: &mut GwConn) -> bool {
        while !c.outbuf.is_empty() {
            match c.stream.write(&c.outbuf) {
                Ok(0) => return false,
                Ok(n) => {
                    self.dist.stats.bytes_sent.fetch_add(n as u64, Ordering::Relaxed);
                    c.outbuf.drain(..n);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
        let want = !c.outbuf.is_empty();
        if want != c.want_write {
            let mut interest = sys::EPOLLIN | sys::EPOLLRDHUP;
            if want {
                interest |= sys::EPOLLOUT;
            }
            if self.epoll.modify(c.stream.as_raw_fd(), c.tok, interest).is_err() {
                return false;
            }
            c.want_write = want;
        }
        true
    }

    /// Heartbeat pass: ping quiet WS connections at `heartbeat_ms`,
    /// kill anything silent for `2 × heartbeat_ms`.
    fn sweep(&mut self, now: u64) {
        let hb = self.gw.cfg.heartbeat_ms;
        if hb == 0 {
            return;
        }
        let mut to_kill = Vec::new();
        let mut to_ping = Vec::new();
        for (&tok, c) in &self.conns {
            let silent = now.saturating_sub(c.last_recv_ms);
            if silent >= hb.saturating_mul(2) {
                to_kill.push(tok);
            } else if c.is_ws && !c.ping_sent && silent >= hb && matches!(c.phase, Phase::Open) {
                to_ping.push(tok);
            }
        }
        for tok in to_ping {
            if let Some(mut c) = self.conns.remove(&tok) {
                let f = c.framing.frame_ping();
                c.outbuf.extend_from_slice(&f);
                c.ping_sent = true;
                self.gw.stats.pings_sent.fetch_add(1, Ordering::Relaxed);
                if self.flush(&mut c) {
                    self.conns.insert(tok, c);
                } else {
                    self.deregister(&mut c);
                }
            }
        }
        for tok in to_kill {
            if let Some(mut c) = self.conns.remove(&tok) {
                crate::log_debug!(
                    "gateway",
                    "killing silent peer {} after {}ms (held {} tickets)",
                    c.session.client(),
                    now.saturating_sub(c.last_recv_ms),
                    c.session.held_tickets().len()
                );
                self.gw.stats.dead_peer_kills.fetch_add(1, Ordering::Relaxed);
                self.deregister(&mut c);
            }
        }
    }

    /// Reactor exit: tell every live connection Shutdown (best effort —
    /// sockets are non-blocking, one write attempt each), then drop
    /// them all, closing their sessions (and releasing held tickets).
    fn drain_shutdown(&mut self) {
        let toks: Vec<u64> = self.conns.keys().copied().collect();
        for tok in toks {
            if let Some(mut c) = self.conns.remove(&tok) {
                if matches!(c.phase, Phase::Open) {
                    let f = c.framing.frame_msg(&Message::Shutdown.encode());
                    c.outbuf.extend_from_slice(&f);
                    let f = c.framing.frame_close();
                    c.outbuf.extend_from_slice(&f);
                    let _ = self.flush(&mut c);
                }
                self.deregister(&mut c);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Framework;
    use crate::store::TicketId;
    use crate::tasks::is_prime::IsPrimeTask;
    use crate::transport::tcp::TcpConn;
    use crate::transport::ws::WsConn;
    use crate::transport::Conn;
    use crate::util::json::Value;

    fn fw_with_tickets(n: usize) -> Arc<Framework> {
        let fw = Framework::builder().build();
        let task = fw.create_task(std::sync::Arc::new(IsPrimeTask));
        task.calculate(
            (0..n).map(|i| Value::obj(vec![("candidate", Value::num(i as f64 + 2.0))])).collect(),
        );
        fw
    }

    #[test]
    fn gateway_serves_tcp_and_ws_hello() {
        let fw = fw_with_tickets(4);
        let dist = crate::coordinator::Distributor::new(&fw);
        let gw = Gateway::bind(
            &dist,
            GatewayConfig::default(),
            Some("127.0.0.1:0"),
            Some("127.0.0.1:0"),
        )
        .unwrap();

        let mut tcp = TcpConn::connect(&gw.tcp_addr().unwrap()).unwrap();
        tcp.send(&Message::Hello { client: "t0".into(), profile: "test".into() }).unwrap();
        assert_eq!(tcp.recv().unwrap(), Message::Ack);

        let mut wsc = WsConn::connect(&format!("ws://{}/", gw.ws_addr().unwrap())).unwrap();
        wsc.send(&Message::Hello { client: "w0".into(), profile: "browser".into() }).unwrap();
        assert_eq!(wsc.recv().unwrap(), Message::Ack);

        // Both clients pull work from the same store.
        tcp.send(&Message::TicketRequest).unwrap();
        let t1 = match tcp.recv().unwrap() {
            Message::Ticket { ticket, .. } => ticket,
            other => panic!("{other:?}"),
        };
        wsc.send(&Message::TicketRequest).unwrap();
        let t2 = match wsc.recv().unwrap() {
            Message::Ticket { ticket, .. } => ticket,
            other => panic!("{other:?}"),
        };
        assert_ne!(t1, t2);
        assert_eq!(dist.client_count(), 2);

        tcp.send(&Message::ReleaseTickets { tickets: vec![t1] }).unwrap();
        assert_eq!(tcp.recv().unwrap(), Message::Ack);
        wsc.send(&Message::ReleaseTickets { tickets: vec![t2] }).unwrap();
        assert_eq!(wsc.recv().unwrap(), Message::Ack);

        tcp.send(&Message::Shutdown).unwrap();
        wsc.send(&Message::Shutdown).unwrap();
        gw.shutdown();
        assert_eq!(dist.stats.tickets_released.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn dropping_a_gateway_client_releases_its_tickets() {
        let fw = fw_with_tickets(2);
        let dist = crate::coordinator::Distributor::new(&fw);
        let gw =
            Gateway::bind(&dist, GatewayConfig::default(), Some("127.0.0.1:0"), None).unwrap();
        let held: TicketId;
        {
            let mut tcp = TcpConn::connect(&gw.tcp_addr().unwrap()).unwrap();
            tcp.send(&Message::Hello { client: "t0".into(), profile: "test".into() }).unwrap();
            assert_eq!(tcp.recv().unwrap(), Message::Ack);
            tcp.send(&Message::TicketRequest).unwrap();
            held = match tcp.recv().unwrap() {
                Message::Ticket { ticket, .. } => ticket,
                other => panic!("{other:?}"),
            };
            // Dropped here: socket closes, reactor sees EOF.
        }
        let _ = held;
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while dist.stats.tickets_released.load(Ordering::Relaxed) < 1 {
            assert!(std::time::Instant::now() < deadline, "release never happened");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        gw.shutdown();
    }

    #[test]
    fn nofile_helpers_work() {
        let cur = raise_nofile_limit(64).unwrap();
        assert!(cur >= 64);
        assert!(process_thread_count().unwrap_or(1) >= 1);
        assert!(process_rss_kb().unwrap_or(1) >= 1);
    }
}
