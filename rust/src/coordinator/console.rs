//! The control console (§2.1.2): progress, clients, errors — the view
//! the paper's HTTPServer renders with responsive web design; here a
//! plain-text snapshot (printed by `sashimi console` / examples) since
//! there is no browser to style for.

use crate::coordinator::distributor::Distributor;
use crate::store::{Progress, Scheduler as _};

/// A renderable snapshot of a running distributor.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub progress: Progress,
    pub clients: Vec<(String, String, u64, u64, u64)>, // id, profile, tickets, results, errors
    pub tickets_served: u64,
    pub results_accepted: u64,
    pub duplicates: u64,
    pub errors: u64,
}

pub fn snapshot(d: &Distributor) -> Snapshot {
    use std::sync::atomic::Ordering;
    Snapshot {
        progress: d.store().progress(None),
        clients: d
            .clients()
            .into_iter()
            .map(|c| (c.client, c.profile, c.tickets_served, c.results, c.errors))
            .collect(),
        tickets_served: d.stats.tickets_served.load(Ordering::Relaxed),
        results_accepted: d.stats.results_accepted.load(Ordering::Relaxed),
        duplicates: d.stats.results_duplicate.load(Ordering::Relaxed),
        errors: d.stats.errors_reported.load(Ordering::Relaxed),
    }
}

pub fn render(s: &Snapshot) -> String {
    let mut out = String::new();
    out.push_str("== Sashimi console ==\n");
    out.push_str(&format!(
        "tickets: {} total | {} waiting | {} in-flight | {} executed | {} error reports | {} redistributions | {} duplicate results\n",
        s.progress.total,
        s.progress.pending,
        s.progress.in_flight,
        s.progress.done,
        s.progress.errors,
        s.progress.redistributions,
        s.progress.duplicate_results,
    ));
    out.push_str(&format!(
        "distributor: {} served | {} accepted | {} duplicates | {} errors\n",
        s.tickets_served, s.results_accepted, s.duplicates, s.errors
    ));
    out.push_str("clients:\n");
    let mut clients = s.clients.clone();
    clients.sort();
    for (id, profile, t, r, e) in &clients {
        out.push_str(&format!("  {id:<12} {profile:<10} tickets={t:<6} results={r:<6} errors={e}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_contains_counts() {
        let s = Snapshot {
            progress: Progress { total: 10, pending: 3, in_flight: 2, done: 5, ..Default::default() },
            clients: vec![("w1".into(), "tablet".into(), 4, 3, 1)],
            tickets_served: 6,
            results_accepted: 5,
            duplicates: 1,
            errors: 1,
        };
        let text = render(&s);
        assert!(text.contains("10 total"));
        assert!(text.contains("5 executed"));
        assert!(text.contains("w1"));
        assert!(text.contains("tablet"));
    }
}
