//! The control console (§2.1.2): progress, clients, errors — the view
//! the paper's HTTPServer renders with responsive web design; here a
//! plain-text snapshot (printed by `sashimi serve` / examples) since
//! there is no browser to style for.
//!
//! The per-render snapshot is built entirely from counters — the
//! distributor's atomics, the store's O(1) [`Progress`], the client
//! *count*, and the drained error buffer — so rendering the console on a
//! busy coordinator clones no per-client map (the `Distributor::clients`
//! vec-clone pattern retired here matches the earlier
//! `errors()`→`error_count`/`drain_errors` retirement).  The full
//! per-client table is still available on demand via [`render_clients`].

use crate::coordinator::distributor::Distributor;
use crate::store::{Progress, SchedStats, Scheduler as _, TicketId, VerifyStats};

/// How many drained error reports one render prints before eliding.
const MAX_ERRORS_SHOWN: usize = 5;

/// A renderable snapshot of a running distributor, counters only.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub progress: Progress,
    /// Number of *currently connected* clients (ghost workers whose
    /// connection ended are excluded; see `gone`).
    pub clients: usize,
    /// Connections that Hello'd and have since ended — connection
    /// churn (worker reloads included), not distinct lost clients.
    pub gone: u64,
    pub tickets_served: u64,
    pub results_accepted: u64,
    /// Same-client retries of done tickets (see `DistributorStats`).
    pub duplicates: u64,
    /// Cross-client late answers on done tickets — the shape vote fraud
    /// takes, reported separately so it cannot hide among retries.
    pub duplicates_cross: u64,
    /// Ballots recorded on tickets still short of quorum (R > 1 only).
    pub pending_quorum: u64,
    /// Ticket requests refused because the client is quarantined.
    pub refused_quarantine: u64,
    pub errors: u64,
    /// Tickets handed back through the active failure path (explicit
    /// releases + disconnect releases), immediately re-dispatchable.
    pub released: u64,
    /// Error reports drained from the store buffer by this snapshot (the
    /// console is the buffer's consumer, like the paper's error list);
    /// the cumulative `progress.errors` counter is unaffected.
    pub recent_errors: Vec<(TicketId, String)>,
    /// Dispatch-contention counters from [`Scheduler::stats`]
    /// (`dispatch_shards == 0` means the backend is uninstrumented and
    /// the line is omitted from the render).
    pub sched: SchedStats,
    /// Result-verification counters ([`Scheduler::verify_stats`]);
    /// `replication <= 1` means the layer is inactive and the verify
    /// line is omitted from the render (legacy output is unchanged).
    pub verify: VerifyStats,
}

pub fn snapshot(d: &Distributor) -> Snapshot {
    use std::sync::atomic::Ordering;
    let recent_errors = d.store().drain_errors();
    // The drain is destructive and `render` elides beyond a cap.  The
    // distributor already warn-logs each report's message at arrival,
    // so messages survive any log level; the full bodies (stack traces)
    // additionally land in the debug log when it is enabled.
    for (id, report) in &recent_errors {
        crate::log_debug!("console", "error report {id:?}: {report}");
    }
    Snapshot {
        progress: d.store().progress(None),
        clients: d.client_count(),
        gone: d.stats.clients_disconnected.load(Ordering::Relaxed),
        tickets_served: d.stats.tickets_served.load(Ordering::Relaxed),
        results_accepted: d.stats.results_accepted.load(Ordering::Relaxed),
        duplicates: d.stats.results_duplicate.load(Ordering::Relaxed),
        duplicates_cross: d.stats.results_duplicate_cross.load(Ordering::Relaxed),
        pending_quorum: d.stats.results_pending_quorum.load(Ordering::Relaxed),
        refused_quarantine: d.stats.noticket_quarantined.load(Ordering::Relaxed),
        errors: d.stats.errors_reported.load(Ordering::Relaxed),
        released: d.stats.tickets_released.load(Ordering::Relaxed),
        recent_errors,
        sched: d.store().stats(),
        verify: d.store().verify_stats(),
    }
}

pub fn render(s: &Snapshot) -> String {
    let mut out = String::new();
    out.push_str("== Sashimi console ==\n");
    out.push_str(&format!(
        "tickets: {} total | {} waiting | {} in-flight | {} executed | {} error reports | {} redistributions | {} duplicate results\n",
        s.progress.total,
        s.progress.pending,
        s.progress.in_flight,
        s.progress.done,
        s.progress.errors,
        s.progress.redistributions,
        s.progress.duplicate_results,
    ));
    out.push_str(&format!(
        "distributor: {} clients ({} conns ended) | {} served | {} accepted | {} duplicates | {} errors | {} released\n",
        s.clients, s.gone, s.tickets_served, s.results_accepted, s.duplicates, s.errors, s.released
    ));
    if s.sched.dispatch_shards > 0 {
        out.push_str(&format!(
            "dispatch: {} shards | {} lock acquisitions | {} steals ({} attempts) | ready depth {} (max {})\n",
            s.sched.dispatch_shards,
            s.sched.dispatch_locks,
            s.sched.steal_successes,
            s.sched.steal_attempts,
            s.sched.shard_depths.iter().sum::<usize>(),
            s.sched.shard_depths.iter().max().copied().unwrap_or(0),
        ));
    }
    if s.verify.replication > 1 {
        out.push_str(&format!(
            "verify: R={} Q={} | {} votes | {} verdicts | {} flagged | {} escalations | {} quarantines ({} active, {} trusted) | {} pending | {} cross-duplicates | {} refused\n",
            s.verify.replication,
            s.verify.quorum,
            s.verify.votes_recorded,
            s.verify.verdicts,
            s.verify.votes_flagged,
            s.verify.escalations,
            s.verify.quarantines,
            s.verify.quarantined_now,
            s.verify.trusted_now,
            s.pending_quorum,
            s.duplicates_cross,
            s.refused_quarantine,
        ));
    }
    for (id, report) in s.recent_errors.iter().take(MAX_ERRORS_SHOWN) {
        let first_line = report.lines().next().unwrap_or("");
        out.push_str(&format!("  error {id:?}: {first_line}\n"));
    }
    if s.recent_errors.len() > MAX_ERRORS_SHOWN {
        out.push_str(&format!(
            "  (+{} more; messages were logged at arrival)\n",
            s.recent_errors.len() - MAX_ERRORS_SHOWN
        ));
    }
    out
}

/// The on-demand per-client table (the paper's client-info view).  This
/// is the one place that clones the client map — call it from one-shot
/// reports (examples, end-of-run summaries), not per-render loops.
pub fn render_clients(d: &Distributor) -> String {
    let mut clients = d.clients();
    clients.sort_by(|a, b| a.client.cmp(&b.client));
    let mut out = String::from("clients:\n");
    for c in &clients {
        out.push_str(&format!(
            "  {:<12} {:<10} tickets={:<6} results={:<6} errors={}{}\n",
            c.client,
            c.profile,
            c.tickets_served,
            c.results,
            c.errors,
            if c.disconnected { " (gone)" } else { "" }
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_contains_counts() {
        let s = Snapshot {
            progress: Progress { total: 10, pending: 3, in_flight: 2, done: 5, ..Default::default() },
            clients: 3,
            gone: 1,
            tickets_served: 6,
            results_accepted: 5,
            duplicates: 1,
            duplicates_cross: 0,
            pending_quorum: 0,
            refused_quarantine: 0,
            errors: 1,
            released: 2,
            recent_errors: vec![(TicketId(4), "TypeError: x is undefined\nat task.run".into())],
            sched: SchedStats {
                dispatch_shards: 4,
                dispatch_locks: 17,
                steal_attempts: 6,
                steal_successes: 2,
                shard_depths: vec![1, 0, 2, 0],
                errors_dropped: 0,
            },
            verify: VerifyStats::default(),
        };
        let text = render(&s);
        assert!(text.contains("10 total"));
        assert!(text.contains("5 executed"));
        assert!(text.contains("3 clients (1 conns ended)"));
        assert!(text.contains("2 released"));
        assert!(text.contains("4 shards"));
        assert!(text.contains("2 steals (6 attempts)"));
        assert!(text.contains("ready depth 3 (max 2)"));
        assert!(text.contains("TypeError: x is undefined"));
        assert!(!text.contains("at task.run"), "only the first line of a report renders");
        assert!(!text.contains("verify:"), "verify line is omitted at R = 1");
    }

    #[test]
    fn verify_line_renders_only_when_replicating() {
        let s = Snapshot {
            progress: Progress::default(),
            clients: 0,
            gone: 0,
            tickets_served: 0,
            results_accepted: 0,
            duplicates: 0,
            duplicates_cross: 3,
            pending_quorum: 7,
            refused_quarantine: 2,
            errors: 0,
            released: 0,
            recent_errors: Vec::new(),
            sched: SchedStats::default(),
            verify: VerifyStats {
                replication: 3,
                quorum: 2,
                votes_recorded: 40,
                verdicts: 18,
                votes_flagged: 4,
                escalations: 2,
                quarantines: 1,
                quarantined_now: 1,
                trusted_now: 5,
            },
        };
        let text = render(&s);
        assert!(text.contains("verify: R=3 Q=2"));
        assert!(text.contains("40 votes"));
        assert!(text.contains("18 verdicts"));
        assert!(text.contains("4 flagged"));
        assert!(text.contains("1 quarantines (1 active, 5 trusted)"));
        assert!(text.contains("7 pending"));
        assert!(text.contains("3 cross-duplicates"));
        assert!(text.contains("2 refused"));
    }

    #[test]
    fn long_error_lists_are_elided() {
        let s = Snapshot {
            progress: Progress::default(),
            clients: 0,
            gone: 0,
            tickets_served: 0,
            results_accepted: 0,
            duplicates: 0,
            duplicates_cross: 0,
            pending_quorum: 0,
            refused_quarantine: 0,
            errors: 9,
            released: 0,
            recent_errors: (0..9).map(|i| (TicketId(i), format!("e{i}"))).collect(),
            sched: SchedStats::default(),
            verify: VerifyStats::default(),
        };
        let text = render(&s);
        assert!(text.contains("e4"));
        assert!(!text.contains("e5"), "reports beyond the cap elide");
        assert!(text.contains("(+4 more"));
        assert!(!text.contains("dispatch:"), "uninstrumented backends render no dispatch line");
    }
}
