//! Sashimi's server side: the CalculationFramework (projects & tasks),
//! the Distributor (ticket traffic + dataset APIs) and the control
//! console.
//!
//! Paper → module map:
//!
//! | Paper (§2.1)            | Here                         |
//! |-------------------------|------------------------------|
//! | CalculationFramework    | [`framework::Framework`]     |
//! | project / task / ticket | [`framework::TaskHandle`], [`crate::store`] |
//! | TicketDistributor       | [`distributor::Distributor`] |
//! | WebSocket front         | [`gateway::Gateway`] (epoll reactor, TCP + WS listeners) |
//! | HTTPServer dataset APIs | `DataRequest` handling in the distributor + [`crate::tasks::DatasetStore`] |
//! | control console         | [`console`]                  |

pub mod console;
pub mod distributor;
pub mod framework;
pub mod gateway;

pub use distributor::{Distributor, DistributorConfig, Session};
pub use framework::{Framework, TaskHandle};
pub use gateway::{Gateway, GatewayConfig};
