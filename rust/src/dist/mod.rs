//! The distributed deep-learning algorithms of the paper's §4, plus the
//! two baselines they are measured against.
//!
//! Everything here drives the *same* coordination substrate the rest of
//! Sashimi uses — the [`crate::store`] ticket store with virtual-created-
//! time redistribution, the [`crate::coordinator::Distributor`] protocol,
//! and real [`crate::worker::Worker`] browser loops over
//! [`crate::transport::local`] links — so the fault-tolerance semantics
//! of §2.1.2 carry over to training unchanged (a killed client's conv
//! batch is redistributed like any other ticket).
//!
//! Paper → module map (see `DESIGN.md` §4 for the full discussion):
//!
//! | Piece                                        | Here                |
//! |----------------------------------------------|---------------------|
//! | simulated cluster (server + N browser nodes) | [`cluster::Cluster`]|
//! | §4 hybrid algorithm (conv on clients, FC on the server, concurrent) | [`hybrid`] |
//! | MLitB-style data-parallel averaging (Meeds et al., 2014)            | [`mlitb`]  |
//! | synchronous-exchange SGD (Hidaka et al.'s DistML.js lineage)        | [`he_sync`] |
//! | analytic bytes-per-round model               | [`comm::CommModel`] |
//! | weighted gradient averaging                  | [`aggregate_gradients`] |
//!
//! The three trainers share one result shape ([`TrainResult`]) so the
//! Fig 5 bench and the ablations compare like with like.

pub mod cluster;
pub mod comm;
mod data_parallel;
pub mod he_sync;
pub mod hybrid;
pub mod mlitb;

pub use cluster::{Cluster, ClusterConfig};
pub use comm::CommModel;

use anyhow::{ensure, Result};

use crate::nn::metrics::Curve;
use crate::nn::params::ParamSet;

/// Throughput / traffic summary of one distributed training run, shared
/// by all three algorithms (printed by `sashimi hybrid|mlitb|hesync` and
/// the Fig 5 bench).
#[derive(Debug, Clone)]
pub struct DistStats {
    /// Which trainer produced this ("hybrid", "mlitb", "he_sync").
    pub algorithm: String,
    /// Number of worker nodes in the cluster.
    pub clients: usize,
    /// Conv-stack mini-batches per wall-clock second across the fleet.
    pub conv_batches_per_s: f64,
    /// Server-side FC update steps per wall-clock second (hybrid trains
    /// the FC block concurrently; the baselines count their aggregated
    /// server updates here).
    pub fc_steps_per_s: f64,
    /// Mean training loss observed during the final round.
    pub mean_loss_last_round: f64,
    /// Wire traffic during the run, server side: (sent, received) bytes.
    pub bytes: (u64, u64),
}

/// What a trainer returns: counters, the loss curve (one point per
/// round) and the run summary.
#[derive(Debug, Clone)]
pub struct TrainResult {
    /// Conv mini-batches processed by clients (hybrid) or full-gradient
    /// batches (baselines): `rounds * n_shards`.
    pub conv_batches: u64,
    /// Server-side FC/aggregate update steps, *including* replay steps.
    pub fc_steps: u64,
    /// Hybrid only: FC steps taken on cached feature batches while
    /// waiting for clients ("bounded replay", §4).  Zero for baselines.
    pub replay_steps: u64,
    /// (round, wall ms, mean loss) per round.
    pub loss_curve: Curve,
    /// Final model parameters after the last round (the hybrid trainer
    /// folds the server-trained FC block back into the full set).
    pub params: ParamSet,
    pub stats: DistStats,
}

/// Weighted mean of gradient sets: `Σ wᵢ gᵢ / Σ wᵢ`.
///
/// The paper weights each client's contribution by the number of samples
/// in its shard, so a straggler that processed a half-filled shard does
/// not drag the average (ablation 4 quantifies the bias of the plain
/// client mean).  All sets must share names and shapes.
pub fn aggregate_gradients(parts: &[(f32, ParamSet)]) -> Result<ParamSet> {
    ensure!(!parts.is_empty(), "aggregate_gradients: no gradients");
    let total: f32 = parts.iter().map(|(w, _)| *w).sum();
    ensure!(
        total > 0.0 && parts.iter().all(|(w, _)| *w >= 0.0),
        "aggregate_gradients: weights must be non-negative with positive sum (got total {total})"
    );
    let mut acc = parts[0].1.clone();
    acc.scale(parts[0].0 / total);
    for (w, g) in &parts[1..] {
        acc.axpy(w / total, g)?;
    }
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::params::test_support::tiny_net;
    use crate::runtime::Tensor;
    use crate::util::rng::SplitMix64;

    fn grad(seed: u64) -> ParamSet {
        let net = tiny_net();
        let mut rng = SplitMix64::new(seed);
        let mut g = ParamSet::zeros(&net);
        for name in ["conv1_w", "conv1_b", "fc_w", "fc_b"] {
            let shape = g.get(name).unwrap().shape().to_vec();
            g.set(name, Tensor::uniform(&shape, &mut rng, 1.0)).unwrap();
        }
        g
    }

    #[test]
    fn weighted_mean_matches_closed_form() {
        let (a, b) = (grad(1), grad(2));
        let out = aggregate_gradients(&[(3.0, a.clone()), (1.0, b.clone())]).unwrap();
        for name in ["conv1_w", "fc_b"] {
            let oa = a.get(name).unwrap().data();
            let ob = b.get(name).unwrap().data();
            for (i, v) in out.get(name).unwrap().data().iter().enumerate() {
                let want = (3.0 * oa[i] + 1.0 * ob[i]) / 4.0;
                assert!((v - want).abs() < 1e-6, "{name}[{i}]: {v} vs {want}");
            }
        }
    }

    #[test]
    fn rejects_empty_and_bad_weights() {
        assert!(aggregate_gradients(&[]).is_err());
        assert!(aggregate_gradients(&[(0.0, grad(1))]).is_err());
        assert!(aggregate_gradients(&[(-1.0, grad(1)), (2.0, grad(2))]).is_err());
    }

    #[test]
    fn single_part_is_identity() {
        let g = grad(7);
        let out = aggregate_gradients(&[(5.0, g.clone())]).unwrap();
        for name in g.names() {
            let a = g.get(name).unwrap().data();
            let b = out.get(name).unwrap().data();
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-6);
            }
        }
    }
}
