//! MLitB-style data-parallel baseline (Meeds et al., 2014).
//!
//! Each round the server publishes the *full* parameter set as a round
//! dataset, hands every shard a `grad_all` ticket, and applies each
//! client's full-network gradient as it arrives (MLitB's clients update
//! against the freshest available model rather than waiting on a
//! barrier; the strict-barrier variant is [`crate::dist::he_sync`], and
//! both run through the shared [`super::data_parallel`] driver).
//!
//! This is the comparison target for the paper's byte argument: every
//! round moves `(workers + shards) * |θ|` floats (see
//! [`crate::dist::CommModel::mlitb_floats`]), which the FC block
//! dominates at AlexNet scale.

use anyhow::Result;

use crate::dist::data_parallel::{self, Apply};
use crate::dist::{Cluster, TrainResult};

#[derive(Debug, Clone)]
pub struct MlitbConfig {
    pub rounds: u64,
    pub seed: u64,
}

/// Round-dataset key for the full parameter blob.
pub fn all_params_key(net: &str, round: u64) -> String {
    format!("{net}_allp_r{round}")
}

/// Run the MLitB-style baseline on a live cluster.
pub fn train(cluster: &Cluster, cfg: &MlitbConfig) -> Result<TrainResult> {
    data_parallel::train(cluster, cfg.rounds, cfg.seed, Apply::PerArrival, "mlitb")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_keys_are_distinct_per_round_and_net() {
        assert_eq!(all_params_key("mnist", 3), "mnist_allp_r3");
        assert_ne!(all_params_key("mnist", 1), all_params_key("mnist", 2));
        assert_ne!(all_params_key("mnist", 1), all_params_key("cifar", 1));
        // Never collides with the hybrid's conv-only round keys.
        assert_ne!(all_params_key("mnist", 1), crate::tasks::train::params_key("mnist", 1));
    }
}
