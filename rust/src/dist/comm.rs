//! Analytic communication model: floats moved per training round by the
//! hybrid algorithm vs the data-parallel baselines.
//!
//! This is the quantitative form of the paper's §4 argument for training
//! only the conv stack on clients: data-parallel SGD ships *every*
//! parameter (and gradient) each round, which for the FC-dominated CNNs
//! of 2015 (AlexNet: 58.6 M of 62.3 M parameters are FC) is hopeless on
//! browser-grade links.  The hybrid algorithm ships conv parameters,
//! boundary features and their cotangents instead, none of which grow
//! with the FC block.
//!
//! The model's accounting matches what the live cluster actually moves
//! (asserted by `tests/dist_training.rs::measured_bytes_match_comm_model`
//! against the distributor's byte counters):
//!
//! * hybrid, per round: every worker downloads the fresh conv-parameter
//!   blob (a round dataset), and every shard moves the boundary features
//!   up, the boundary cotangent down, and the conv gradients up;
//! * MLitB / he-sync, per round: every worker downloads the full
//!   parameter blob and every shard uploads a full gradient.  The two
//!   baselines move the same bytes — they differ in *when* (barriers),
//!   not in *what*.

use crate::runtime::NetSpec;

/// Per-model float counts the communication model needs.  Constructed
/// from a manifest [`NetSpec`] via [`CommModel::of`], or literally for
/// hypothetical scales (the ablations build AlexNet/VGG-16 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CommModel {
    /// Parameters in the conv stack (weights + biases).
    pub conv_params: usize,
    /// Parameters in the FC block.
    pub fc_params: usize,
    /// Floats at the conv/FC boundary for one mini-batch:
    /// `batch * fc_in` (what one ConvFwd result / dfeat payload carries).
    pub boundary: usize,
}

impl CommModel {
    /// Extract the three float counts from a manifest [`NetSpec`].
    pub fn of(spec: &NetSpec) -> CommModel {
        let conv_params: usize = spec
            .conv_param_names()
            .iter()
            .map(|n| spec.param_shapes[n].iter().product::<usize>())
            .sum();
        CommModel {
            conv_params,
            fc_params: spec.param_count() - conv_params,
            boundary: spec.batch * spec.fc_in,
        }
    }

    /// Floats per round moved by the hybrid algorithm with `workers`
    /// clients and `shards` mini-batch shards (both directions).
    pub fn hybrid_floats(&self, workers: usize, shards: usize) -> usize {
        workers * self.conv_params + shards * (2 * self.boundary + self.conv_params)
    }

    /// Floats per round moved by MLitB-style data-parallel averaging:
    /// full parameters down per worker, full gradients up per shard.
    pub fn mlitb_floats(&self, workers: usize, shards: usize) -> usize {
        (workers + shards) * (self.conv_params + self.fc_params)
    }

    /// Floats per round moved by synchronous-exchange SGD.  Identical to
    /// MLitB's volume; the barrier changes latency, not bytes.
    pub fn he_sync_floats(&self, workers: usize, shards: usize) -> usize {
        self.mlitb_floats(workers, shards)
    }

    /// Does the hybrid algorithm move fewer floats per round?  True in
    /// the FC-dominated regime the paper targets; false when the
    /// boundary dominates (small Fig-2-scale models).
    pub fn hybrid_wins(&self, workers: usize, shards: usize) -> bool {
        self.hybrid_floats(workers, shards) < self.mlitb_floats(workers, shards)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::params::test_support::tiny_net;

    /// The published AlexNet split the ablations also use.
    fn alexnet() -> CommModel {
        CommModel { conv_params: 3_700_000, fc_params: 58_600_000, boundary: 50 * 9216 }
    }

    #[test]
    fn of_extracts_spec_counts() {
        let m = CommModel::of(&tiny_net());
        // conv1_w 25*4 + conv1_b 4 = 104; fc_w 64*3 + fc_b 3 = 195.
        assert_eq!(m.conv_params, 104);
        assert_eq!(m.fc_params, 195);
        assert_eq!(m.boundary, 2 * 64);
    }

    /// The paper's claim, pinned at AlexNet scale: hybrid moves an order
    /// of magnitude fewer floats per round than either data-parallel
    /// baseline (and therefore than he_sync in particular).
    #[test]
    fn hybrid_beats_he_sync_on_fc_dominated_models() {
        let m = alexnet();
        let (w, s) = (4, 4);
        assert!(m.hybrid_wins(w, s));
        assert!(m.hybrid_floats(w, s) < m.he_sync_floats(w, s) / 10);
        // he_sync and mlitb move the same volume by construction.
        assert_eq!(m.he_sync_floats(w, s), m.mlitb_floats(w, s));
    }

    /// On boundary-dominated models (tiny/Fig-2 scale) the advantage
    /// flips — the regime `tests/dist_training.rs` measures on the wire.
    #[test]
    fn boundary_dominated_models_favor_mlitb() {
        let m = CommModel::of(&tiny_net());
        assert!(!m.hybrid_wins(2, 2));
        assert!(m.hybrid_floats(2, 2) > m.mlitb_floats(2, 2));
    }

    #[test]
    fn float_counts_scale_with_fleet() {
        let m = alexnet();
        assert!(m.hybrid_floats(2, 4) < m.hybrid_floats(4, 4));
        assert!(m.hybrid_floats(4, 2) < m.hybrid_floats(4, 4));
        assert!(m.mlitb_floats(2, 2) < m.mlitb_floats(4, 2));
    }
}
