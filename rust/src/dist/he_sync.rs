//! Synchronous-exchange SGD baseline (the scheme Hidaka et al. refine in
//! DistML.js): same work units and wire volume as [`crate::dist::mlitb`],
//! but with a strict barrier — the server waits for *every* shard's
//! full-network gradient, applies their sample-weighted mean as one
//! update, then starts the next round.  Both baselines share the
//! [`super::data_parallel`] driver; this one selects barrier application.
//!
//! The barrier is the point: bytes match MLitB exactly
//! ([`crate::dist::CommModel::he_sync_floats`]), so any throughput gap
//! against the hybrid algorithm is attributable to synchronisation and
//! gradient volume, not to a different workload.

use anyhow::Result;

use crate::dist::data_parallel::{self, Apply};
use crate::dist::{Cluster, TrainResult};

#[derive(Debug, Clone)]
pub struct HeSyncConfig {
    pub rounds: u64,
    pub seed: u64,
}

/// Run the synchronous baseline on a live cluster.
pub fn train(cluster: &Cluster, cfg: &HeSyncConfig) -> Result<TrainResult> {
    data_parallel::train(cluster, cfg.rounds, cfg.seed, Apply::Barrier, "he_sync")
}
